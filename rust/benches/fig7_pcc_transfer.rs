//! Fig. 7 regenerator: PCC conversion transfer for 3–10-bit CMP /
//! MUX-chain / NAND-NOR converters (closed-form + LFSR-measured).

use scnn::benchutil::bench;
use scnn::sc::lfsr::Lfsr;
use scnn::sc::pcc::{expected_output, pcc_bit, PccKind};

fn main() {
    println!("Fig. 7 — expected conversion value at quartile codes");
    for bits in 3..=10u32 {
        let total = 1u32 << bits;
        let picks = [total / 4, total / 2, 3 * total / 4];
        for kind in PccKind::ALL {
            let vals: Vec<String> = picks
                .iter()
                .map(|&x| format!("{:.4}", expected_output(kind, x, bits)))
                .collect();
            println!("  {bits}-bit {kind:?}: {vals:?} (ideal {:?})",
                picks.iter().map(|&x| format!("{:.4}", x as f64 / total as f64)).collect::<Vec<_>>());
        }
        // Assert the Fig. 7 visual claims: all three monotone; NAND-NOR sits
        // at or slightly above the ideal line (positive constant A_N).
        for kind in PccKind::ALL {
            let mut prev = -1.0;
            for x in 0..total {
                let v = expected_output(kind, x, bits);
                assert!(v >= prev - 1e-12, "{kind:?} {bits}-bit non-monotone");
                prev = v;
            }
        }
    }
    // Measured transfer through a real LFSR run (k = 2^14) — the paper's
    // simulation setup; also serves as the throughput bench.
    let bits = 8;
    bench("pcc_transfer_measure(8-bit, 3 kinds, k=16384)", 1, 3, || {
        for kind in PccKind::ALL {
            let mut l = Lfsr::new(bits, 1).expect("8-bit LFSR");
            let mut ones = 0u32;
            for _ in 0..16384 {
                let r = l.value();
                l.step();
                ones += pcc_bit(kind, 128, r, bits) as u32;
            }
            std::hint::black_box(ones);
        }
    });
}
