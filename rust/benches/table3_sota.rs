//! Table III regenerator: the "This Work" column (both technologies)
//! against the literature rows (cited constants, as in the paper).

use scnn::accel::layers::NetworkSpec;
use scnn::accel::system::{evaluate, SystemConfig};
use scnn::benchutil::{bench, print_table};
use scnn::tech::TechKind;

fn main() {
    // Literature rows are citations in the paper too (constants).
    let lit = [
        ("ISSCC'21 [46] digital 7nm", "19.6 mm²", "-", "8.9-16.5 TOPS/W", "3.27-5.22 TOPS/mm²"),
        ("TCAD'18 [8] SC 45nm", "22.9 mm²", "2600 mW", "5.66", "0.64"),
        ("TCASII'22 [47] SC 65nm", "0.006 mm²", "4.06 mW", "2.17", "1.44"),
        ("SSCL'22 [37] SC 14nm", "0.5 mm²", "16-68 mW", "4.4-75", "0.3-4.8"),
        ("TNNLS'23 [29] SC 40nm", "2.1 mm²", "651 mW", "0.34", "0.11"),
        ("JSSC'24 [30] SC 14nm", "0.06 mm²", "-", "35-140", "1.66-6.6"),
    ];
    println!("Literature rows (paper Table III):");
    for l in lit {
        println!("  {} | {} | {} | {} | {}", l.0, l.1, l.2, l.3, l.4);
    }

    let net = NetworkSpec::lenet5();
    let mut rows = Vec::new();
    for tech in [TechKind::Finfet10, TechKind::Rfet10] {
        let e = evaluate(&SystemConfig::paper(tech, 8), &net);
        let m = &e.metrics;
        rows.push(vec![
            format!("{tech}"),
            format!("{:.3}", m.area_mm2),
            format!("{:.1}", m.power_mw),
            format!("{:.2}", m.clock_ghz),
            format!("{:.2}", m.tops_per_watt()),
            format!("{:.2}", m.tops_per_mm2()),
        ]);
    }
    print_table(
        "Table III — This Work (paper: FinFET 0.299 mm²/25 mW/1.05 GHz/12.02/4.83; RFET 0.288/19/1.14/16.9/5.40)",
        &["tech", "area mm²", "power mW", "clock GHz", "TOPS/W", "TOPS/mm²"],
        &rows,
    );
    // The paper's conclusion ratios: +40.6% TOPS/W, +11.8% TOPS/mm².
    let fin = evaluate(&SystemConfig::paper(TechKind::Finfet10, 8), &net);
    let rf = evaluate(&SystemConfig::paper(TechKind::Rfet10, 8), &net);
    let tw = (rf.metrics.tops_per_watt() / fin.metrics.tops_per_watt() - 1.0) * 100.0;
    let tm = (rf.metrics.tops_per_mm2() / fin.metrics.tops_per_mm2() - 1.0) * 100.0;
    println!("RFET vs FinFET: TOPS/W {tw:+.1}% (paper +40.6), TOPS/mm² {tm:+.1}% (paper +11.8)");
    assert!(tw > 10.0, "RFET must clearly win TOPS/W");
    assert!(tm > 0.0, "RFET must win TOPS/mm²");
    bench("evaluate(paper config)", 1, 5, || {
        std::hint::black_box(evaluate(&SystemConfig::paper(TechKind::Rfet10, 8), &net));
    });
}
