//! Fig. 12 regenerator: SCNN (bitstream 2^n_bits) vs binary fixed-point
//! NN accuracy under varying quantization levels.

use scnn::accel::layers::NetworkSpec;
use scnn::accel::network::{classify, ForwardMode, ForwardPlan, QuantizedWeights};
use scnn::benchutil::{bench, print_table};
use scnn::data::{Artifacts, Dataset, ModelWeights};

// Per-image seeds make plan reuse impossible here; the analytic plan
// build is cheap, so the one-shot `ForwardPlan::once` is the right call.
fn fwd(n: &NetworkSpec, w: &QuantizedWeights, i: &[f64], m: ForwardMode) -> Vec<f64> {
    ForwardPlan::once(n, w, i, m)
}

fn main() {
    let artifacts = Artifacts::default_dir();
    if !artifacts.present() {
        eprintln!("artifacts missing — run `make artifacts`; skipping fig12");
        return;
    }
    let ds = Dataset::load(&artifacts.dataset("digits")).unwrap();
    // One name drives both the topology (registry) and the artifact paths.
    let net = NetworkSpec::by_name("lenet5").unwrap();
    let sc_raw = ModelWeights::load(&artifacts.weights(&net.name, "sc")).unwrap();
    let fx_raw = ModelWeights::load(&artifacts.weights(&net.name, "fixed")).unwrap();
    let n = 60.min(ds.len());
    let eval = |raw: &ModelWeights, bits: u32, mode_sc: bool| -> f64 {
        let weights = raw.quantize(bits);
        (0..n)
            .map(|i| {
                let img: Vec<f64> = ds.images[i].iter().map(|&v| v as f64).collect();
                let mode = if mode_sc {
                    // Paper: SC bitstream length = 2^n_bits, amplified by the
                    // training-noise deviation factor (see fig11 notes).
                    ForwardMode::NoisyExpectation { k: (1usize << bits) * 16, seed: 1 + i as u32 }
                } else {
                    ForwardMode::FixedPoint
                };
                let p = classify(&fwd(&net, &weights, &img, mode));
                (p == ds.labels[i] as usize) as usize
            })
            .sum::<usize>() as f64
            / n as f64
    };
    let mut rows = Vec::new();
    for bits in [3u32, 4, 5, 6, 7, 8] {
        rows.push(vec![
            format!("{bits}"),
            format!("{:.0}%", 100.0 * eval(&sc_raw, bits, true)),
            format!("{:.0}%", 100.0 * eval(&fx_raw, bits, false)),
        ]);
    }
    print_table(
        "Fig. 12 — SCNN (k=16·2^bits) vs fixed-point NN (synthetic digits)",
        &["bits", "SC-NN", "fixed-point NN"],
        &rows,
    );
    // Shape: SC approaches the fixed-point NN as bits (and k) grow.
    let sc8 = eval(&sc_raw, 8, true);
    let sc3 = eval(&sc_raw, 3, true);
    assert!(sc8 >= sc3, "SC accuracy must not degrade with more bits");
    bench("fig12_point(sc, 8-bit)", 0, 1, || {
        std::hint::black_box(eval(&sc_raw, 8, true));
    });
}
