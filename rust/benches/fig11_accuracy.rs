//! Fig. 11 regenerator: accuracy vs bitstream length at several system
//! precisions (the paper's SC-math-model methodology, §V-B) — now driven
//! through the `accel::precision` policy layer, so the same sweep covers
//! uniform plans, hand-written per-layer plans, and the greedy
//! accuracy-budget autotuner, each with its modeled per-layer-k energy.
//!
//! Known deviation (EXPERIMENTS.md): our training is not yet noise-aware,
//! so the learned signal sits lower relative to the SC sampling floor and
//! the accuracy knee lands at larger k than the paper's 32; the *shape*
//! (monotone rise to a precision-limited ceiling) reproduces.

use scnn::accel::layers::NetworkSpec;
use scnn::accel::network::{classify, ForwardMode, ForwardPlan, QuantizedWeights};
use scnn::accel::precision::{autotune, AutoTuneConfig, PrecisionPlan};
use scnn::benchutil::{bench, print_table};
use scnn::data::{Artifacts, Dataset, ModelWeights};
use scnn::engine::HardwareEstimate;
use scnn::tech::TechKind;

// Per-image noise seeds make plan reuse impossible here; the analytic
// plan build is cheap, so compiling per (image, plan) is the right call.
fn fwd_plan(
    n: &NetworkSpec,
    w: &QuantizedWeights,
    i: &[f64],
    plan: &PrecisionPlan,
    seed: u32,
) -> Vec<f64> {
    let mode = ForwardMode::NoisyExpectation { k: plan.max_k(), seed };
    ForwardPlan::compile_with_precision(n, w, mode, plan)
        .expect("valid plan")
        .run(i)
}

fn main() {
    let artifacts = Artifacts::default_dir();
    if !artifacts.present() {
        eprintln!("artifacts missing — run `make artifacts`; skipping fig11");
        return;
    }
    let ds = Dataset::load(&artifacts.dataset("digits")).unwrap();
    // One name drives both the topology (registry) and the artifact paths.
    let net = NetworkSpec::by_name("lenet5").unwrap();
    let raw = ModelWeights::load(&artifacts.weights(&net.name, "sc")).unwrap();
    let n = 60.min(ds.len());
    let n_compute = net.n_compute();
    // Accuracy of one precision plan over the first n test images.
    let acc = |w: &QuantizedWeights, plan: &PrecisionPlan| -> f64 {
        (0..n)
            .map(|i| {
                let img: Vec<f64> = ds.images[i].iter().map(|&v| v as f64).collect();
                let p = classify(&fwd_plan(&net, w, &img, plan, 1 + i as u32));
                (p == ds.labels[i] as usize) as usize
            })
            .sum::<usize>() as f64
            / n as f64
    };

    // ---- the classic Fig. 11 sweep, as Uniform(k) policies ----
    let ks = [32usize, 128, 512, 1024, 2048, 4096];
    let mut rows = Vec::new();
    for bits in [3u32, 4, 5, 6, 8] {
        let weights = raw.quantize(bits);
        let mut row = vec![format!("{bits}-bit")];
        for &k in &ks {
            let a = acc(&weights, &PrecisionPlan::uniform(k, n_compute));
            row.push(format!("{:.0}%", 100.0 * a));
        }
        rows.push(row);
    }
    let mut headers = vec!["precision".to_string()];
    headers.extend(ks.iter().map(|k| format!("k={k}")));
    let href: Vec<&str> = headers.iter().map(String::as_str).collect();
    print_table("Fig. 11 — accuracy vs bitstream length (synthetic digits)", &href, &rows);

    // Shape assertions: accuracy at the largest k beats the smallest, and
    // higher precision ceilings dominate lower ones at the ceiling.
    let w8 = raw.quantize(8);
    let w3 = raw.quantize(3);
    let a8_hi = acc(&w8, &PrecisionPlan::uniform(4096, n_compute));
    assert!(
        a8_hi > acc(&w8, &PrecisionPlan::uniform(32, n_compute)) + 0.3,
        "accuracy must rise with k"
    );
    assert!(
        a8_hi >= acc(&w3, &PrecisionPlan::uniform(4096, n_compute)),
        "precision ceiling ordering"
    );

    // ---- uniform vs per-layer vs autotuned plans (8-bit weights) ----
    // Each row: the plan, its accuracy under the §V-B noise model, and the
    // modeled per-layer-k energy of the paper's 8-channel RFET system.
    let energy = |plan: &PrecisionPlan| {
        HardwareEstimate::for_plan(TechKind::Rfet10, 8, plan, &net).metrics.energy_uj
    };
    let budget = 0.05;
    let tuned = autotune(
        &net,
        &w8,
        7,
        &AutoTuneConfig { accuracy_budget: budget, k_max: 1024, k_min: 32, calib_images: 12 },
    )
    .unwrap();
    let uniform_hi = PrecisionPlan::uniform(1024, n_compute);
    let plans: Vec<(String, PrecisionPlan)> = vec![
        ("uniform k=1024".into(), uniform_hi.clone()),
        ("uniform k=256".into(), PrecisionPlan::uniform(256, n_compute)),
        (
            "per-layer 1024,512,256,128,1024".into(),
            PrecisionPlan::per_layer(vec![1024, 512, 256, 128, 1024]),
        ),
        (format!("autotuned (budget {budget}) {:?}", tuned.ks()), tuned.clone()),
    ];
    let rows: Vec<Vec<String>> = plans
        .iter()
        .map(|(label, plan)| {
            vec![
                label.clone(),
                format!("{:.0}%", 100.0 * acc(&w8, plan)),
                format!("{:.3} µJ", energy(plan)),
                format!("{}", plan.total_cycles()),
            ]
        })
        .collect();
    print_table(
        "Fig. 11b — uniform vs per-layer precision plans (8-bit, lenet5)",
        &["plan", "accuracy", "modeled energy", "stream cycles"],
        &rows,
    );
    // The per-layer headline: the tuned plan undercuts the uniform-1024
    // ceiling on modeled energy while staying within the stated budget.
    assert!(energy(&tuned) < energy(&uniform_hi), "tuned plan must save energy");
    assert!(
        acc(&w8, &tuned) + budget + 0.051 >= acc(&w8, &uniform_hi),
        "tuned plan must hold the accuracy budget (plus test-set slack)"
    );

    bench("fig11_point(8-bit, k=1024, 60 imgs)", 0, 1, || {
        std::hint::black_box(acc(&w8, &PrecisionPlan::uniform(1024, n_compute)));
    });
}
