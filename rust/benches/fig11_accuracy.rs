//! Fig. 11 regenerator: accuracy vs bitstream length at several system
//! precisions (the paper's SC-math-model methodology, §V-B).
//!
//! Known deviation (EXPERIMENTS.md): our training is not yet noise-aware,
//! so the learned signal sits lower relative to the SC sampling floor and
//! the accuracy knee lands at larger k than the paper's 32; the *shape*
//! (monotone rise to a precision-limited ceiling) reproduces.

use scnn::accel::layers::NetworkSpec;
use scnn::accel::network::{classify, ForwardMode, ForwardPlan, QuantizedWeights};
use scnn::benchutil::{bench, print_table};
use scnn::data::{Artifacts, Dataset, ModelWeights};

// Per-image seeds make plan reuse impossible here; the analytic plan
// build is cheap, so the one-shot `ForwardPlan::once` is the right call.
fn fwd(n: &NetworkSpec, w: &QuantizedWeights, i: &[f64], m: ForwardMode) -> Vec<f64> {
    ForwardPlan::once(n, w, i, m)
}

fn main() {
    let artifacts = Artifacts::default_dir();
    if !artifacts.present() {
        eprintln!("artifacts missing — run `make artifacts`; skipping fig11");
        return;
    }
    let ds = Dataset::load(&artifacts.dataset("digits")).unwrap();
    // One name drives both the topology (registry) and the artifact paths.
    let net = NetworkSpec::by_name("lenet5").unwrap();
    let raw = ModelWeights::load(&artifacts.weights(&net.name, "sc")).unwrap();
    let n = 60.min(ds.len());
    let ks = [32usize, 128, 512, 1024, 2048, 4096];
    let mut rows = Vec::new();
    for bits in [3u32, 4, 5, 6, 8] {
        let weights = raw.quantize(bits);
        let mut row = vec![format!("{bits}-bit")];
        for &k in &ks {
            let correct: usize = (0..n)
                .map(|i| {
                    let img: Vec<f64> = ds.images[i].iter().map(|&v| v as f64).collect();
                    let p = classify(&fwd(
                        &net,
                        &weights,
                        &img,
                        ForwardMode::NoisyExpectation { k, seed: 1 + i as u32 },
                    ));
                    (p == ds.labels[i] as usize) as usize
                })
                .sum();
            row.push(format!("{:.0}%", 100.0 * correct as f64 / n as f64));
        }
        rows.push(row);
    }
    let mut headers = vec!["precision".to_string()];
    headers.extend(ks.iter().map(|k| format!("k={k}")));
    let href: Vec<&str> = headers.iter().map(String::as_str).collect();
    print_table("Fig. 11 — accuracy vs bitstream length (synthetic digits)", &href, &rows);

    // Shape assertions: accuracy at the largest k beats the smallest, and
    // higher precision ceilings dominate lower ones at the ceiling.
    let acc = |bits: u32, k: usize| -> f64 {
        let weights = raw.quantize(bits);
        (0..n)
            .map(|i| {
                let img: Vec<f64> = ds.images[i].iter().map(|&v| v as f64).collect();
                let p = classify(&fwd(
                    &net,
                    &weights,
                    &img,
                    ForwardMode::NoisyExpectation { k, seed: 1 + i as u32 },
                ));
                (p == ds.labels[i] as usize) as usize
            })
            .sum::<usize>() as f64
            / n as f64
    };
    assert!(acc(8, 4096) > acc(8, 32) + 0.3, "accuracy must rise with k");
    assert!(acc(8, 4096) >= acc(3, 4096), "precision ceiling ordering");
    bench("fig11_point(8-bit, k=1024, 60 imgs)", 0, 1, || {
        std::hint::black_box(acc(8, 1024));
    });
}
