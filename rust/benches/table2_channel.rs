//! Table II regenerator: channel-level area / min clock / energy.

use scnn::accel::channel::characterize_channel;
use scnn::benchutil::{bench, gain_pct, print_table};
use scnn::tech::TechKind;

fn main() {
    let fin = characterize_channel(TechKind::Finfet10);
    let rf = characterize_channel(TechKind::Rfet10);
    print_table(
        "Table II — channel (paper: FinFET 2475 µm² / 0.95 ns / 4.30 pJ; RFET 2359 / 0.88 / 3.07)",
        &["tech", "area µm²", "min clock ns", "energy pJ/cycle"],
        &[
            vec![
                format!("{}", fin.tech),
                format!("{:.0}", fin.area_um2),
                format!("{:.2}", fin.min_clock_ps / 1000.0),
                format!("{:.2}", fin.energy_per_cycle_fj / 1000.0),
            ],
            vec![
                format!("{}", rf.tech),
                format!("{:.0}", rf.area_um2),
                format!("{:.2}", rf.min_clock_ps / 1000.0),
                format!("{:.2}", rf.energy_per_cycle_fj / 1000.0),
            ],
        ],
    );
    println!(
        "gains: area {:+.1}% (paper 4.7), clock {:+.1}% (7.4), energy {:+.1}% (28.6)",
        gain_pct(fin.area_um2, rf.area_um2),
        gain_pct(fin.min_clock_ps, rf.min_clock_ps),
        gain_pct(fin.energy_per_cycle_fj, rf.energy_per_cycle_fj)
    );
    bench("characterize_channel(finfet)", 1, 3, || {
        std::hint::black_box(characterize_channel(TechKind::Finfet10));
    });
}
