//! §Perf hot-path benchmarks: the packed bitstream engine, the vertical
//! counter (APC front end), bit-exact LeNet-5 inference (single and
//! batched), gate-level characterization, and the PJRT serving path.
//!
//! Every fused kernel is benchmarked against the pre-fusion reference
//! implementation compiled into the same binary (`xnor` vs `xnor_into`,
//! `add` vs `add_xnor`/`add3`, `reference::forward_stochastic` vs the
//! fused/parallel engine), so the speedup column regenerates on any
//! machine. Before/after numbers live in EXPERIMENTS.md §Perf; a
//! machine-readable copy is written to `BENCH_hotpath.json` next to the
//! human output, the per-backend `engine::Session` batch-throughput
//! matrix (stochastic-fused / reference-per-bit / expectation / xla at
//! k=256 and k=1024) goes to `BENCH_engine.json`, the per-layer stage
//! breakdown (software median vs modeled hardware delay, per compiled
//! stage of `lenet5` and `mnist_strided`) goes to `BENCH_layers.json`,
//! and the `EnginePool` shard-scaling curve (img/s and p50/p99 vs shard
//! count, fused backend at k=256) goes to `BENCH_pool.json`, and the
//! fault-injection degradation curves (argmax agreement vs injected
//! bit-flip rate, stochastic at three stream lengths vs the binary
//! expectation datapath) go to `BENCH_faults.json`, and the bit-plane
//! transposed kernel comparison (img/s fused vs transposed at k=256 and
//! k=1024 on both 28x28 topologies, with the per-stage breakdown and the
//! >=2x speedup gate at k=1024) goes to `BENCH_bitplane.json`, and the
//! compiled-sparsity comparison (img/s and modeled energy, sparse vs
//! dense plans over channel-structured zeroed weights at densities
//! {100%, 50%, 25%} × k in {256, 1024}, argmax agreement asserted before
//! timing) goes to `BENCH_sparsity.json`.
//! Run with `cargo bench --bench hotpath`.
//!
//! Plans and scratch buffers are always built OUTSIDE the timed closures:
//! compile-once/run-many is the serving shape every kernel variant is
//! judged in, so compile cost never masquerades as inference cost.

use scnn::accel::layers::NetworkSpec;
use scnn::accel::network::{
    reference, weight_densities, ForwardMode, ForwardPlan, KernelPath, QuantizedWeights,
    SparsityPolicy,
};
use scnn::accel::par;
use scnn::accel::precision::{autotune, AutoTuneConfig, PrecisionPlan};
use scnn::benchutil::{bench, BenchResult, JsonReport};
use scnn::data::{Artifacts, Dataset, ModelWeights};
use scnn::engine::{classify, BackendKind, BatchPolicy, Engine, EngineConfig, Precision};
use scnn::sc::bitstream::{Bitstream, VerticalCounter};
use scnn::sc::rng::{self, XorShift64};

/// Record the fused result with its speedup over the reference run; if the
/// kernel has an acceptance gate (EXPERIMENTS.md §Perf), report it loudly.
fn record_pair(
    json: &mut JsonReport,
    baseline: &BenchResult,
    fused: &BenchResult,
    gate: Option<f64>,
    extra: &[(&str, f64)],
) -> f64 {
    let speedup = baseline.median_ns / fused.median_ns;
    match gate {
        Some(g) if speedup >= g => {
            println!("  -> {speedup:.2}x speedup vs reference (gate >={g}x: MET)")
        }
        Some(g) => println!("  -> {speedup:.2}x speedup vs reference (gate >={g}x: MISSED)"),
        None => println!("  -> {speedup:.2}x speedup vs reference"),
    }
    json.add(baseline, &[]);
    let mut fields = vec![("speedup_vs_reference", speedup)];
    if let Some(g) = gate {
        fields.push(("speedup_gate", g));
    }
    fields.extend_from_slice(extra);
    json.add(fused, &fields);
    speedup
}

fn main() {
    let mut json = JsonReport::new();

    // L3 hot loop 1: packed XNOR over 1024-bit streams —
    // allocating (reference) vs in-place (fused).
    let a = Bitstream::from_fn(1024, |t| t % 3 == 0);
    let b = Bitstream::from_fn(1024, |t| t % 5 == 0);
    let r_ref = bench("bitstream_xnor(1024b)/reference", 100, 2000, || {
        std::hint::black_box(a.xnor(&b));
    });
    let mut out = Bitstream::zeros(1024);
    let r_new = bench("bitstream_xnor(1024b)", 100, 2000, || {
        a.xnor_into(&b, &mut out);
        std::hint::black_box(&out);
    });
    let gbit = r_new.ops_per_sec(1024.0) / 1e9;
    println!("  -> {:.2} Gbit/s", gbit);
    record_pair(&mut json, &r_ref, &r_new, Some(3.0), &[("throughput_gbit_s", gbit)]);

    // SNG lane generation: per-bit from_fn (reference) vs word-at-a-time.
    let (code, bits, k) = (137u32, 8u32, 1024usize);
    let mask = (1u32 << bits) - 1;
    let gen_words = |base: u32, lane: u64| -> Bitstream {
        let mut state = rng::lane_state(base as u64, lane);
        Bitstream::from_fn_words(k, |w| {
            let n = (k - w * 64).min(64);
            let mut word = 0u64;
            for i in 0..n {
                state = rng::xorshift64_step(state);
                word |= ((code > ((state as u32) & mask)) as u64) << i;
            }
            word
        })
    };
    assert_eq!(
        gen_words(7, 3),
        reference::lane_stream(code, bits, k, 7, 3),
        "word-packed SNG must be bit-identical to the per-bit path"
    );
    let r_ref = bench("sng_lane_stream(1024b)/reference", 50, 1000, || {
        std::hint::black_box(reference::lane_stream(code, bits, k, 7, 3));
    });
    let r_new = bench("sng_lane_stream(1024b)", 50, 1000, || {
        std::hint::black_box(gen_words(7, 3));
    });
    record_pair(&mut json, &r_ref, &r_new, None, &[]);

    // L3 hot loop 2: vertical counter accumulating 25 streams —
    // fresh counter + per-stream add (reference) vs reused counter +
    // 3:2 carry-save add3 (fused).
    let streams: Vec<Bitstream> =
        (0..25).map(|j| Bitstream::from_fn(1024, |t| (t * (j + 3)) % 7 < 3)).collect();
    let r_ref = bench("vertical_counter(25x1024b)/reference", 50, 1000, || {
        let mut vc = VerticalCounter::new(1024, 25);
        for s in &streams {
            vc.add(s);
        }
        std::hint::black_box(vc.total());
    });
    let mut vc = VerticalCounter::new(1024, 25);
    let r_new = bench("vertical_counter(25x1024b)", 50, 1000, || {
        vc.reset();
        let mut it = streams.chunks_exact(3);
        for tri in &mut it {
            vc.add3(&tri[0], &tri[1], &tri[2]);
        }
        for s in it.remainder() {
            vc.add(s);
        }
        std::hint::black_box(vc.total());
    });
    let gbit = r_new.ops_per_sec(25.0 * 1024.0) / 1e9;
    println!("  -> {:.2} Gbit/s through the APC front end", gbit);
    record_pair(&mut json, &r_ref, &r_new, Some(3.0), &[("throughput_gbit_s", gbit)]);

    // The real MAC shape: accumulate 25 XNOR products — allocate-per-product
    // (reference) vs fused add_xnor.
    let wstreams: Vec<Bitstream> =
        (0..25).map(|j| Bitstream::from_fn(1024, |t| (t * (j + 11)) % 5 < 2)).collect();
    let r_ref = bench("apc_accumulate_xnor(25x1024b)/reference", 50, 1000, || {
        let mut vc = VerticalCounter::new(1024, 25);
        for (s, w) in streams.iter().zip(&wstreams) {
            vc.add(&s.xnor(w));
        }
        std::hint::black_box(vc.total());
    });
    let r_new = bench("apc_accumulate_xnor(25x1024b)", 50, 1000, || {
        vc.reset();
        for (s, w) in streams.iter().zip(&wstreams) {
            vc.add_xnor(s, w);
        }
        std::hint::black_box(vc.total());
    });
    record_pair(&mut json, &r_ref, &r_new, None, &[]);

    // Bit-exact LeNet-5 inference: per-bit/allocating reference vs the
    // fused parallel engine, plus the batched serving path. Runs on trained
    // weights when artifacts exist, synthetic weights otherwise (identical
    // compute cost).
    let net = NetworkSpec::by_name("lenet5").unwrap();
    let artifacts = Artifacts::default_dir();
    let trained = if artifacts.present() {
        ModelWeights::load(&artifacts.weights(&net.name, "sc")).ok().map(|w| w.quantize(8))
    } else {
        None
    };
    let synthetic = trained.is_none();
    let weights = trained
        .unwrap_or_else(|| QuantizedWeights::synthetic(&net, 8, 0x5EED).expect("valid topology"));
    if synthetic {
        println!("(artifacts missing — lenet5 benches use synthetic weights)");
    }
    let img: Vec<f64> = (0..28 * 28).map(|i| ((i % 17) as f64) / 17.0).collect();
    let plan = ForwardPlan::new(&net, &weights, ForwardMode::Stochastic { k: 32, seed: 7 });
    let fused_out = plan.run(&img);
    let golden = reference::forward_stochastic(&net, &weights, &img, 32, 7);
    assert_eq!(fused_out, golden, "fused engine must match the reference bit-for-bit");
    let r_ref = bench("bitexact_lenet5_inference(k=32)/reference", 1, 5, || {
        std::hint::black_box(reference::forward_stochastic(&net, &weights, &img, 32, 7));
    });
    let mut scr = scnn::accel::network::Scratch::default();
    let r_new = bench("bitexact_lenet5_inference(k=32)", 2, 20, || {
        std::hint::black_box(plan.run_with(&img, &mut scr, true));
    });
    record_pair(&mut json, &r_ref, &r_new, Some(5.0), &[]);

    // Batched forward: 32 images fanned across cores through one plan.
    let batch: Vec<Vec<f64>> = (0..32)
        .map(|s| (0..28 * 28).map(|i| (((i + s * 13) % 17) as f64) / 17.0).collect())
        .collect();
    let r_batch = bench("bitexact_lenet5_forward_batch(32imgs,k=32)", 1, 5, || {
        std::hint::black_box(plan.run_batch(&batch));
    });
    let img_s = r_batch.ops_per_sec(32.0);
    println!(
        "  -> {:.0} img/s on {} threads (single-image engine: {:.0} img/s)",
        img_s,
        par::max_threads(),
        r_new.ops_per_sec(1.0)
    );
    json.add(&r_batch, &[("img_per_s", img_s), ("threads", par::max_threads() as f64)]);

    // Analytic expectation forward through a pre-built plan and reused
    // scratch (this point used to compile inside the timed closure and
    // measured plan construction, not inference).
    let exp_plan = ForwardPlan::new(&net, &weights, ForwardMode::Expectation);
    let mut exp_scr = scnn::accel::network::Scratch::default();
    let r = bench("expectation_lenet5_inference", 2, 50, || {
        std::hint::black_box(exp_plan.run_with(&img, &mut exp_scr, true));
    });
    json.add(&r, &[]);

    // ---- bit-plane transposed kernel (BENCH_bitplane.json) ----
    // Fused lane-major vs transposed bit-plane batch throughput at
    // k in {256, 1024} on both 28x28 topologies, plus the per-stage
    // breakdown at k=1024. Transposed must beat fused by the
    // EXPERIMENTS.md §Perf gate (>=2x img/s at k=1024; informational at
    // k=256); bit-equality against the fused kernel is asserted on the
    // full batch before anything is timed.
    let mut bjson = JsonReport::new();
    for bname in ["lenet5", "mnist_strided"] {
        let bnet = NetworkSpec::by_name(bname).unwrap();
        let bweights = if bname == net.name {
            weights.clone()
        } else {
            QuantizedWeights::synthetic(&bnet, 8, 0x5EED).expect("valid topology")
        };
        for (k, nimg, warm, iters) in [(256usize, 16usize, 1usize, 3usize), (1024, 8, 1, 2)] {
            let prec = PrecisionPlan::uniform(k, bnet.n_compute());
            let mode = ForwardMode::Stochastic { k, seed: 7 };
            let fused_plan = ForwardPlan::compile_with_opts(
                &bnet, &bweights, mode, &prec, None, KernelPath::Fused,
            )
            .unwrap();
            let tr_plan = ForwardPlan::compile_with_opts(
                &bnet, &bweights, mode, &prec, None, KernelPath::Transposed,
            )
            .unwrap();
            let bimgs: Vec<Vec<f64>> = (0..nimg)
                .map(|s| {
                    (0..fused_plan.in_len())
                        .map(|i| (((i + s * 13) % 17) as f64) / 17.0)
                        .collect()
                })
                .collect();
            assert_eq!(
                fused_plan.run_batch(&bimgs),
                tr_plan.run_batch(&bimgs),
                "transposed kernel must match fused bit-for-bit before timing"
            );
            let r_f = bench(
                &format!("bitplane({bname},fused,k={k},{nimg}imgs)"),
                warm,
                iters,
                || {
                    std::hint::black_box(fused_plan.run_batch(&bimgs));
                },
            );
            let r_t = bench(
                &format!("bitplane({bname},transposed,k={k},{nimg}imgs)"),
                warm,
                iters,
                || {
                    std::hint::black_box(tr_plan.run_batch(&bimgs));
                },
            );
            let fused_img_s = r_f.ops_per_sec(nimg as f64);
            let tr_img_s = r_t.ops_per_sec(nimg as f64);
            let speedup = r_f.median_ns / r_t.median_ns;
            let gate = 2.0f64;
            if k == 1024 {
                let verdict = if speedup >= gate { "MET" } else { "MISSED" };
                println!(
                    "  -> {tr_img_s:.1} img/s transposed vs {fused_img_s:.1} fused: \
                     {speedup:.2}x speedup vs fused (gate >={gate}x: {verdict})"
                );
            } else {
                println!(
                    "  -> {tr_img_s:.1} img/s transposed vs {fused_img_s:.1} fused: \
                     {speedup:.2}x speedup vs fused (informational)"
                );
            }
            bjson.add(&r_f, &[("img_per_s", fused_img_s), ("k", k as f64), ("batch", nimg as f64)]);
            let mut fields = vec![
                ("img_per_s", tr_img_s),
                ("k", k as f64),
                ("batch", nimg as f64),
                ("speedup_vs_fused", speedup),
            ];
            if k == 1024 {
                fields.push(("speedup_gate", gate));
            }
            bjson.add(&r_t, &fields);
            if k == 1024 {
                // Per-stage breakdown: where the transposed layout wins
                // (one image, all cores, one warmed measured run).
                for (label, bplan) in [("fused", &fused_plan), ("transposed", &tr_plan)] {
                    let mut scr = scnn::accel::network::Scratch::default();
                    let mut timings = Vec::new();
                    bplan.run_with_timings(&bimgs[0], &mut scr, 0, &mut timings); // warm-up
                    timings.clear();
                    std::hint::black_box(bplan.run_with_timings(
                        &bimgs[0],
                        &mut scr,
                        0,
                        &mut timings,
                    ));
                    for t in &timings {
                        let r = BenchResult {
                            name: format!(
                                "bitplane_layer({bname},{label},{}:{},k=1024)",
                                t.layer, t.label
                            ),
                            median_ns: t.elapsed.as_nanos() as f64,
                            mean_ns: t.elapsed.as_nanos() as f64,
                            iters: 1,
                        };
                        bjson.add(
                            &r,
                            &[
                                ("layer_index", t.layer as f64),
                                ("k", 1024.0),
                                ("ops_executed", t.ops_executed as f64),
                                ("ops_skipped", t.ops_skipped as f64),
                            ],
                        );
                    }
                }
            }
        }
    }

    // ---- sparsity: compiled zero-skipping (BENCH_sparsity.json) ----
    // Channel-structured zeroing at weight densities {100%, 50%, 25%}:
    // lane j of EVERY output channel is zeroed when j % step != 0, so the
    // pruned plan's per-channel skip lists collapse to one shared window.
    // Both plans compile the SAME zeroed tensor — dense runs it unpruned,
    // sparse compiles a magnitude threshold of 1/256 (one 8-bit LSB) that
    // prunes exactly the zeroed lanes. Argmax agreement sparse-vs-dense
    // is asserted on the full batch BEFORE anything is timed (pruning
    // replaces each zero lane's sampled ~0.5 stream with its folded
    // expectation, so outputs are close but not bit-identical), and CI
    // gates that no sparse point is slower than dense and that 25%
    // density at k=1024 clears 1.5x on at least one topology.
    let mut sjson = JsonReport::new();
    let zero_code = scnn::sc::quantize_bipolar(0.0, 8);
    let sparsity = SparsityPolicy::threshold(1.0 / 256.0);
    for sname in ["lenet5", "mnist_strided"] {
        let snet = NetworkSpec::by_name(sname).unwrap();
        let base_w = if sname == net.name {
            weights.clone()
        } else {
            QuantizedWeights::synthetic(&snet, 8, 0x5EED).expect("valid topology")
        };
        for (density_pct, step) in [(100usize, 1usize), (50, 2), (25, 4)] {
            let mut sw = base_w.clone();
            if step > 1 {
                for lw in &mut sw.layers {
                    for row in &mut lw.codes {
                        for (j, c) in row.iter_mut().enumerate() {
                            if j % step != 0 {
                                *c = zero_code;
                            }
                        }
                    }
                }
            }
            let densities = weight_densities(&sw, sparsity);
            for (k, nimg, warm, iters) in [(256usize, 16usize, 1usize, 3usize), (1024, 8, 1, 2)] {
                let prec = PrecisionPlan::uniform(k, snet.n_compute());
                let mode = ForwardMode::Stochastic { k, seed: 7 };
                let dense_plan = ForwardPlan::compile_with_opts(
                    &snet, &sw, mode, &prec, None, KernelPath::Auto,
                )
                .unwrap();
                let sparse_plan = ForwardPlan::compile_with_sparsity(
                    &snet, &sw, mode, &prec, None, KernelPath::Auto, sparsity,
                )
                .unwrap();
                let simgs: Vec<Vec<f64>> = (0..nimg)
                    .map(|s| {
                        (0..dense_plan.in_len())
                            .map(|i| (((i + s * 13) % 17) as f64) / 17.0)
                            .collect()
                    })
                    .collect();
                let dense_out = dense_plan.run_batch(&simgs);
                let sparse_out = sparse_plan.run_batch(&simgs);
                let agree = dense_out
                    .iter()
                    .zip(&sparse_out)
                    .filter(|(d, s)| {
                        scnn::accel::network::classify(d) == scnn::accel::network::classify(s)
                    })
                    .count();
                assert!(
                    agree * 8 >= nimg * 7,
                    "sparsity({sname},density={density_pct}%,k={k}): argmax agreement \
                     {agree}/{nimg} is below the pre-timing bar"
                );
                let r_d = bench(
                    &format!("sparsity({sname},dense,density={density_pct},k={k},{nimg}imgs)"),
                    warm,
                    iters,
                    || {
                        std::hint::black_box(dense_plan.run_batch(&simgs));
                    },
                );
                let r_s = bench(
                    &format!("sparsity({sname},sparse,density={density_pct},k={k},{nimg}imgs)"),
                    warm,
                    iters,
                    || {
                        std::hint::black_box(sparse_plan.run_batch(&simgs));
                    },
                );
                let dense_img_s = r_d.ops_per_sec(nimg as f64);
                let sparse_img_s = r_s.ops_per_sec(nimg as f64);
                let speedup = r_d.median_ns / r_s.median_ns;
                let (executed, skipped) = sparse_plan.ops_per_image();
                let est = scnn::engine::HardwareEstimate::for_plan_density(
                    scnn::tech::TechKind::Rfet10,
                    8,
                    &prec,
                    &snet,
                    &densities,
                );
                let dense_est = scnn::engine::HardwareEstimate::for_plan_density(
                    scnn::tech::TechKind::Rfet10,
                    8,
                    &prec,
                    &snet,
                    &[],
                );
                println!(
                    "  -> {sparse_img_s:.1} img/s sparse vs {dense_img_s:.1} dense at \
                     {density_pct}% density, k={k}: {speedup:.2}x; {agree}/{nimg} argmax agree; \
                     {:.3} µJ modeled vs {:.3} dense",
                    est.metrics.energy_uj, dense_est.metrics.energy_uj
                );
                sjson.add(
                    &r_d,
                    &[
                        ("img_per_s", dense_img_s),
                        ("k", k as f64),
                        ("density_pct", density_pct as f64),
                        ("batch", nimg as f64),
                        ("modeled_energy_uj", dense_est.metrics.energy_uj),
                    ],
                );
                sjson.add(
                    &r_s,
                    &[
                        ("img_per_s", sparse_img_s),
                        ("k", k as f64),
                        ("density_pct", density_pct as f64),
                        ("batch", nimg as f64),
                        ("speedup_vs_dense", speedup),
                        ("agreement_pct", 100.0 * agree as f64 / nimg as f64),
                        ("ops_executed", executed as f64),
                        ("ops_skipped", skipped as f64),
                        ("modeled_energy_uj", est.metrics.energy_uj),
                    ],
                );
            }
        }
    }

    // ---- per-layer stage breakdown (BENCH_layers.json) ----
    // Software wall time per compiled stage (median over repeated timed
    // runs, one image, all cores) next to the modeled hardware delay
    // derived from the *same* stage descriptors by Algorithm 1 — one
    // record per layer so per-layer regressions are visible across PRs.
    let mut ljson = JsonReport::new();
    for lname in ["lenet5", "mnist_strided"] {
        let lnet = NetworkSpec::by_name(lname).unwrap();
        let lweights = if lname == net.name {
            weights.clone()
        } else {
            QuantizedWeights::synthetic(&lnet, 8, 0x5EED).expect("valid topology")
        };
        let plan = ForwardPlan::new(&lnet, &lweights, ForwardMode::Stochastic { k: 32, seed: 7 });
        let limg: Vec<f64> = (0..plan.in_len()).map(|i| ((i % 17) as f64) / 17.0).collect();
        let mut scr = scnn::accel::network::Scratch::default();
        let mut timings = Vec::new();
        plan.run_with_timings(&limg, &mut scr, 0, &mut timings); // warm-up
        let n_steps = timings.len();
        let runs = 7usize;
        let mut samples: Vec<Vec<f64>> = vec![Vec::with_capacity(runs); n_steps];
        for _ in 0..runs {
            timings.clear();
            std::hint::black_box(plan.run_with_timings(&limg, &mut scr, 0, &mut timings));
            for (si, t) in timings.iter().enumerate() {
                samples[si].push(t.elapsed.as_nanos() as f64);
            }
        }
        // Hardware-side per-layer delays from the same descriptors.
        let stages = lnet.stages().unwrap();
        let sched_cfg = scnn::accel::pipeline::ScheduleConfig {
            channels: 8,
            k: 32,
            clock_ps: 880.0,
            memory: scnn::accel::memory::MemoryModel::gddr5_paper(),
            bytes_per_operand: 1,
        };
        let sched = scnn::accel::pipeline::schedule_stages(&stages, &sched_cfg, 1);
        println!("per-layer breakdown ({lname}, k=32, 1 image):");
        for (si, t) in timings.iter().enumerate() {
            let (index, label) = (t.layer, t.label);
            let mut s = samples[si].clone();
            s.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let median = s[s.len() / 2];
            let mean = s.iter().sum::<f64>() / s.len() as f64;
            let hw = sched.layers.iter().find(|l| l.layer_index == index);
            println!(
                "  {index:>2} {label:<16} {median:>12.0} ns sw | {:>10.1} ns modeled hw | \
                 {} ops executed, {} skipped",
                hw.map(|l| l.delay_ns).unwrap_or(0.0),
                t.ops_executed,
                t.ops_skipped
            );
            let r = BenchResult {
                name: format!("layer({lname},{index}:{label},k=32)"),
                median_ns: median,
                mean_ns: mean,
                iters: runs,
            };
            let mut extra = vec![
                ("layer_index", index as f64),
                ("macs", stages[index].macs() as f64),
                ("ops_executed", t.ops_executed as f64),
                ("ops_skipped", t.ops_skipped as f64),
            ];
            if let Some(l) = hw {
                extra.push(("hw_delay_ns", l.delay_ns));
                extra.push(("hw_dram_bytes", l.dram_bytes as f64));
            }
            ljson.add(&r, &extra);
        }
    }

    // ---- engine::Session per-backend batch throughput ----
    // The serve-path comparison the engine API is judged by: images/s per
    // backend through one session (plan compiled once, dynamic batcher,
    // metrics on). Written to BENCH_engine.json alongside the kernel gates.
    let mut ejson = JsonReport::new();
    // max_batch == submitted batch: the batcher stops lingering the moment
    // the whole batch has arrived, so no timed iteration idles in the
    // 2 ms linger window.
    let mk_cfg = |kind: BackendKind, k: usize, nimg: usize| {
        EngineConfig::new(kind, net.clone())
            .with_quantized(weights.clone())
            .with_k(k)
            .with_seed(7)
            .with_batch(BatchPolicy { max_batch: nimg, ..BatchPolicy::default() })
    };
    let fimgs: Vec<Vec<f32>> = (0..16)
        .map(|s| (0..28 * 28).map(|i| (((i + s * 13) % 17) as f32) / 17.0).collect())
        .collect();
    let mut fused_k256_img_s = 0.0f64;
    for (k, nimg, warm, iters) in [(256usize, 16usize, 1usize, 3usize), (1024, 8, 1, 2)] {
        let session = Engine::open(mk_cfg(BackendKind::StochasticFused, k, nimg)).unwrap();
        let imgs = &fimgs[..nimg];
        let r = bench(
            &format!("engine_batch(stochastic-fused,k={k},{nimg}imgs)"),
            warm,
            iters,
            || {
                std::hint::black_box(session.infer_batch(imgs).unwrap());
            },
        );
        let img_s = r.ops_per_sec(nimg as f64);
        if k == 256 {
            fused_k256_img_s = img_s;
        }
        println!("  -> {img_s:.1} img/s");
        ejson.add(&r, &[("img_per_s", img_s), ("k", k as f64), ("batch", nimg as f64)]);
    }
    // Golden per-bit reference, one image (it is deliberately slow); the
    // k=1024 point only runs under SCNN_BENCH_FULL=1 to keep CI short.
    let one = &fimgs[..1];
    let session = Engine::open(mk_cfg(BackendKind::ReferencePerBit, 256, 1)).unwrap();
    let r = bench("engine_batch(reference-per-bit,k=256,1img)", 0, 1, || {
        std::hint::black_box(session.infer_batch(one).unwrap());
    });
    let ref_img_s = r.ops_per_sec(1.0);
    let engine_speedup = fused_k256_img_s / ref_img_s;
    println!("  -> {ref_img_s:.2} img/s; fused session is {engine_speedup:.1}x faster at k=256");
    ejson.add(
        &r,
        &[
            ("img_per_s", ref_img_s),
            ("k", 256.0),
            ("batch", 1.0),
            ("fused_speedup_at_k256", engine_speedup),
        ],
    );
    if std::env::var("SCNN_BENCH_FULL").is_ok() {
        let session = Engine::open(mk_cfg(BackendKind::ReferencePerBit, 1024, 1)).unwrap();
        let r = bench("engine_batch(reference-per-bit,k=1024,1img)", 0, 1, || {
            std::hint::black_box(session.infer_batch(one).unwrap());
        });
        ejson.add(&r, &[("img_per_s", r.ops_per_sec(1.0)), ("k", 1024.0), ("batch", 1.0)]);
    } else {
        println!("  (reference-per-bit at k=1024 skipped — set SCNN_BENCH_FULL=1 to include it)");
    }
    // Analytic expectation backend (k-independent) completes the matrix.
    let session = Engine::open(mk_cfg(BackendKind::Expectation, 256, 16)).unwrap();
    let r = bench("engine_batch(expectation,16imgs)", 1, 5, || {
        std::hint::black_box(session.infer_batch(&fimgs).unwrap());
    });
    ejson.add(&r, &[("img_per_s", r.ops_per_sec(16.0)), ("batch", 16.0)]);

    if artifacts.present() {
        let ds = Dataset::load(&artifacts.dataset("digits")).unwrap();
        // PJRT serving path (single image, batch-1 graph).
        let engine = scnn::runtime::Engine::load(&artifacts.hlo("lenet5", 1)).unwrap();
        let r = bench("pjrt_lenet5_b1", 2, 20, || {
            std::hint::black_box(engine.run_f32(&ds.images[0], &[1, 1, 28, 28]).unwrap());
        });
        json.add(&r, &[]);
        let eb = scnn::runtime::Engine::load(&artifacts.hlo("lenet5", 32)).unwrap();
        let mut flat = Vec::new();
        for i in 0..32 {
            flat.extend_from_slice(&ds.images[i]);
        }
        let r = bench("pjrt_lenet5_b32", 2, 10, || {
            std::hint::black_box(eb.run_f32(&flat, &[32, 1, 28, 28]).unwrap());
        });
        println!("  -> {:.0} img/s batched", r.ops_per_sec(32.0));
        json.add(&r, &[("img_per_s", r.ops_per_sec(32.0))]);

        // The same graphs behind an engine session (ladder + batcher).
        let session = Engine::open(
            EngineConfig::new(BackendKind::Xla, net.clone())
                .with_hlo_ladder(vec![
                    (1, artifacts.hlo("lenet5", 1)),
                    (8, artifacts.hlo("lenet5", 8)),
                    (32, artifacts.hlo("lenet5", 32)),
                ])
                .with_batch(BatchPolicy { max_batch: 16, ..BatchPolicy::default() }),
        )
        .unwrap();
        let r = bench("engine_batch(xla,16imgs)", 1, 5, || {
            std::hint::black_box(session.infer_batch(&fimgs).unwrap());
        });
        ejson.add(&r, &[("img_per_s", r.ops_per_sec(16.0)), ("batch", 16.0)]);
    } else {
        eprintln!("artifacts missing — PJRT hot-path benches skipped");
    }

    // ---- EnginePool scaling (BENCH_pool.json) ----
    // img/s and latency percentiles vs shard count for the fused backend
    // at k=256: each point opens `shards` sessions over ONE shared
    // compiled plan (engine::backend::shared_plan), splits the cores
    // between the shards, and is driven by 2×shards closed-loop client
    // threads through the pool router (in-flight concurrency capped at
    // the client count — not open-loop tail latency).
    let mut pjson = JsonReport::new();
    let pool_imgs: Vec<Vec<f32>> = (0..24)
        .map(|s| (0..28 * 28).map(|i| (((i + s * 29) % 17) as f32) / 17.0).collect())
        .collect();
    for shards in [1usize, 2, 4] {
        let per_shard_threads = (par::max_threads() / shards).max(1);
        let clients = 2 * shards;
        // max_batch == the ~2 concurrent clients each shard sees, so every
        // pool point fires its batches the moment its clients have queued —
        // no point idles in the linger window more than another (the same
        // no-linger-idle rule mk_cfg's engine benches follow).
        let cfg = mk_cfg(BackendKind::StochasticFused, 256, clients / shards)
            .with_threads(per_shard_threads);
        let pool = scnn::engine::EnginePool::open(scnn::engine::PoolConfig::replicated(
            cfg, shards,
        ))
        .unwrap();
        let r = bench(
            &format!("pool_infer(stochastic-fused,k=256,{shards}shards)"),
            1,
            2,
            || {
                let cursor = std::sync::atomic::AtomicUsize::new(0);
                std::thread::scope(|s| {
                    for _ in 0..clients {
                        s.spawn(|| loop {
                            let i =
                                cursor.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                            if i >= pool_imgs.len() {
                                break;
                            }
                            std::hint::black_box(
                                pool.infer(pool_imgs[i].clone()).unwrap(),
                            );
                        });
                    }
                });
            },
        );
        let img_s = r.ops_per_sec(pool_imgs.len() as f64);
        let m = pool.metrics();
        println!(
            "  -> {img_s:.1} img/s over {shards} shard(s), p50 {} µs  p99 {} µs",
            m.latency_percentile_us(50.0),
            m.latency_percentile_us(99.0)
        );
        pjson.add(
            &r,
            &[
                ("shards", shards as f64),
                ("img_per_s", img_s),
                ("p50_us", m.latency_percentile_us(50.0) as f64),
                ("p99_us", m.latency_percentile_us(99.0) as f64),
                ("threads_per_shard", per_shard_threads as f64),
                ("k", 256.0),
            ],
        );
    }

    // ---- per-layer precision plans (BENCH_precision.json) ----
    // The headline the PrecisionPlan refactor buys: throughput and
    // modeled energy of the fused engine at uniform k=256 vs a greedily
    // autotuned per-layer plan at (calibration-)equal accuracy. The
    // agreement column is measured against the noise-free expectation
    // argmax on the same 16 images.
    let mut prjson = JsonReport::new();
    let tuner = AutoTuneConfig { accuracy_budget: 0.1, k_max: 256, k_min: 32, calib_images: 12 };
    let tuned = autotune(&net, &weights, 7, &tuner).expect("autotune on lenet5");
    println!(
        "autotuned per-layer plan (budget {}, ceiling k={}): {:?}",
        tuner.accuracy_budget,
        tuner.k_max,
        tuned.ks()
    );
    let exp_session = Engine::open(mk_cfg(BackendKind::Expectation, 256, 16)).unwrap();
    let ideal: Vec<usize> =
        exp_session.infer_batch(&fimgs).unwrap().iter().map(|o| classify(o)).collect();
    for (label, plan) in [
        ("uniform-k256", PrecisionPlan::uniform(256, tuned.len())),
        ("autotuned", tuned.clone()),
    ] {
        let cfg = mk_cfg(BackendKind::StochasticFused, 256, 16)
            .with_precision(Precision::PerLayer(plan.ks().to_vec()));
        let session = Engine::open(cfg).unwrap();
        let r = bench(&format!("precision({label},k<=256,16imgs)"), 1, 3, || {
            std::hint::black_box(session.infer_batch(&fimgs).unwrap());
        });
        let img_s = r.ops_per_sec(16.0);
        let outs = session.infer_batch(&fimgs).unwrap();
        let agree =
            outs.iter().zip(&ideal).filter(|(o, &t)| classify(o) == t).count();
        let est = session.metrics().estimate.expect("SC sessions carry an estimate");
        println!(
            "  -> {img_s:.1} img/s, {:.3} µJ modeled, {agree}/16 agree with expectation",
            est.metrics.energy_uj
        );
        prjson.add(
            &r,
            &[
                ("img_per_s", img_s),
                ("modeled_energy_uj", est.metrics.energy_uj),
                ("agreement_pct", 100.0 * agree as f64 / 16.0),
                ("stream_cycles", plan.total_cycles() as f64),
                ("max_k", plan.max_k() as f64),
            ],
        );
    }

    // ---- fault injection: graceful degradation vs the binary cliff ----
    // (BENCH_faults.json) Argmax agreement against the clean expectation
    // baseline as the injected bit-flip rate rises, for the stochastic
    // datapath (flips land on the SC bitstreams, where one flipped bit
    // moves a value by 2/k) vs the analytic expectation datapath (the
    // same rate lands on the binary activation codes, where one flipped
    // MSB moves a value by half the range). Three stream lengths show how
    // longer streams buy more tolerance — the paper's error-resilience
    // claim, measured end to end on both 28x28 topologies.
    let mut fjson = JsonReport::new();
    let fault_rates = [0.0f64, 1e-3, 1e-2, 5e-2];
    let fault_ks = [32usize, 128, 512];
    for fname in ["lenet5", "mnist_strided"] {
        let fnet = NetworkSpec::by_name(fname).unwrap();
        let fweights = if fname == net.name {
            weights.clone()
        } else {
            QuantizedWeights::synthetic(&fnet, 8, 0x5EED).expect("valid topology")
        };
        let clean = ForwardPlan::new(&fnet, &fweights, ForwardMode::Expectation);
        let fault_imgs: Vec<Vec<f64>> = (0..16)
            .map(|s| {
                (0..clean.in_len()).map(|i| (((i + s * 13) % 17) as f64) / 17.0).collect()
            })
            .collect();
        let ideal: Vec<usize> = fault_imgs
            .iter()
            .map(|im| scnn::accel::network::classify(&clean.run(im)))
            .collect();
        let agreement = |plan: &ForwardPlan| -> f64 {
            let outs = plan.run_batch(&fault_imgs);
            let agree = outs
                .iter()
                .zip(&ideal)
                .filter(|(o, &t)| scnn::accel::network::classify(o) == t)
                .count();
            100.0 * agree as f64 / fault_imgs.len() as f64
        };
        println!("fault injection ({fname}, 16 images, agreement vs clean expectation):");
        for &rate in &fault_rates {
            let fp = scnn::faults::FaultPlan::new(0xFA_417).with_bit_flip_rate(rate);
            let faults = (rate > 0.0).then_some(&fp);
            for &k in &fault_ks {
                let plan = ForwardPlan::compile_with_precision_faults(
                    &fnet,
                    &fweights,
                    ForwardMode::Stochastic { k, seed: 7 },
                    &PrecisionPlan::uniform(k, fnet.n_compute()),
                    faults,
                )
                .unwrap();
                let t0 = std::time::Instant::now();
                let pct = agreement(&plan);
                let dt = t0.elapsed().as_nanos() as f64;
                println!("  stochastic k={k:<4} rate={rate:<6}: {pct:.1}% agree");
                let r = BenchResult {
                    name: format!("faults({fname},stochastic,k={k},rate={rate})"),
                    median_ns: dt,
                    mean_ns: dt,
                    iters: 1,
                };
                fjson.add(
                    &r,
                    &[("bit_flip_rate", rate), ("k", k as f64), ("agreement_pct", pct)],
                );
            }
            let plan = ForwardPlan::compile_with_precision_faults(
                &fnet,
                &fweights,
                ForwardMode::Expectation,
                &PrecisionPlan::uniform(32, fnet.n_compute()),
                faults,
            )
            .unwrap();
            let t0 = std::time::Instant::now();
            let pct = agreement(&plan);
            let dt = t0.elapsed().as_nanos() as f64;
            println!("  binary expectation  rate={rate:<6}: {pct:.1}% agree");
            let r = BenchResult {
                name: format!("faults({fname},expectation,rate={rate})"),
                median_ns: dt,
                mean_ns: dt,
                iters: 1,
            };
            fjson.add(&r, &[("bit_flip_rate", rate), ("agreement_pct", pct)]);
        }
    }

    // Gate-level simulator throughput (the Genus substitute).
    let lib = scnn::tech::CellLibrary::finfet10();
    let nl = scnn::sc::apc::build_netlist(25, 32, scnn::sc::apc::FaStyle::CmosCell)
        .expect("25-input k=32 APC is well-formed");
    let r = bench("apc25_power_sim(2048 cycles)", 1, 5, || {
        let mut s = XorShift64::new(1);
        std::hint::black_box(scnn::sim::estimate_power(&nl, &lib, 2048, |_, pins| {
            for p in pins.iter_mut() {
                *p = s.next_u64() & 1 == 1;
            }
        }));
    });
    json.add(&r, &[]);

    let path = std::path::Path::new("BENCH_hotpath.json");
    match json.write(path) {
        Ok(()) => println!(
            "\nwrote {} bench records to {}",
            json.len(),
            std::fs::canonicalize(path).unwrap_or_else(|_| path.to_path_buf()).display()
        ),
        Err(e) => eprintln!("could not write BENCH_hotpath.json: {e}"),
    }
    let epath = std::path::Path::new("BENCH_engine.json");
    match ejson.write(epath) {
        Ok(()) => println!(
            "wrote {} engine records to {}",
            ejson.len(),
            std::fs::canonicalize(epath).unwrap_or_else(|_| epath.to_path_buf()).display()
        ),
        Err(e) => eprintln!("could not write BENCH_engine.json: {e}"),
    }
    let lpath = std::path::Path::new("BENCH_layers.json");
    match ljson.write(lpath) {
        Ok(()) => println!(
            "wrote {} per-layer records to {}",
            ljson.len(),
            std::fs::canonicalize(lpath).unwrap_or_else(|_| lpath.to_path_buf()).display()
        ),
        Err(e) => eprintln!("could not write BENCH_layers.json: {e}"),
    }
    let ppath = std::path::Path::new("BENCH_pool.json");
    match pjson.write(ppath) {
        Ok(()) => println!(
            "wrote {} pool-scaling records to {}",
            pjson.len(),
            std::fs::canonicalize(ppath).unwrap_or_else(|_| ppath.to_path_buf()).display()
        ),
        Err(e) => eprintln!("could not write BENCH_pool.json: {e}"),
    }
    let prpath = std::path::Path::new("BENCH_precision.json");
    match prjson.write(prpath) {
        Ok(()) => println!(
            "wrote {} precision records to {}",
            prjson.len(),
            std::fs::canonicalize(prpath).unwrap_or_else(|_| prpath.to_path_buf()).display()
        ),
        Err(e) => eprintln!("could not write BENCH_precision.json: {e}"),
    }
    let fpath = std::path::Path::new("BENCH_faults.json");
    match fjson.write(fpath) {
        Ok(()) => println!(
            "wrote {} fault-injection records to {}",
            fjson.len(),
            std::fs::canonicalize(fpath).unwrap_or_else(|_| fpath.to_path_buf()).display()
        ),
        Err(e) => eprintln!("could not write BENCH_faults.json: {e}"),
    }
    let bpath = std::path::Path::new("BENCH_bitplane.json");
    match bjson.write(bpath) {
        Ok(()) => println!(
            "wrote {} bit-plane records to {}",
            bjson.len(),
            std::fs::canonicalize(bpath).unwrap_or_else(|_| bpath.to_path_buf()).display()
        ),
        Err(e) => eprintln!("could not write BENCH_bitplane.json: {e}"),
    }
    let spath = std::path::Path::new("BENCH_sparsity.json");
    match sjson.write(spath) {
        Ok(()) => println!(
            "wrote {} sparsity records to {}",
            sjson.len(),
            std::fs::canonicalize(spath).unwrap_or_else(|_| spath.to_path_buf()).display()
        ),
        Err(e) => eprintln!("could not write BENCH_sparsity.json: {e}"),
    }
}
