//! §Perf hot-path benchmarks: the packed bitstream engine, the vertical
//! counter (APC front end), one bit-exact LeNet-5 inference, gate-level
//! characterization, and the PJRT serving path. Before/after numbers live
//! in EXPERIMENTS.md §Perf.

use scnn::accel::layers::NetworkSpec;
use scnn::accel::network::{forward, ForwardMode};
use scnn::benchutil::bench;
use scnn::data::{Artifacts, Dataset, ModelWeights};
use scnn::sc::bitstream::{Bitstream, VerticalCounter};

fn main() {
    // L3 hot loop 1: packed XNOR over 1024-bit streams.
    let a = Bitstream::from_fn(1024, |t| t % 3 == 0);
    let b = Bitstream::from_fn(1024, |t| t % 5 == 0);
    let r = bench("bitstream_xnor(1024b)", 100, 2000, || {
        std::hint::black_box(a.xnor(&b));
    });
    println!("  -> {:.2} Gbit/s", r.ops_per_sec(1024.0) / 1e9);

    // L3 hot loop 2: vertical counter accumulating 25 product streams.
    let streams: Vec<Bitstream> =
        (0..25).map(|j| Bitstream::from_fn(1024, |t| (t * (j + 3)) % 7 < 3)).collect();
    let r = bench("vertical_counter(25x1024b)", 50, 1000, || {
        let mut vc = VerticalCounter::new(1024, 25);
        for s in &streams {
            vc.add(s);
        }
        std::hint::black_box(vc.total());
    });
    println!("  -> {:.2} Gbit/s through the APC front end", r.ops_per_sec(25.0 * 1024.0) / 1e9);

    let artifacts = Artifacts::default_dir();
    if artifacts.present() {
        let ds = Dataset::load(&artifacts.dataset("digits")).unwrap();
        let net = NetworkSpec::lenet5();
        let weights = ModelWeights::load(&artifacts.weights("lenet5", "sc")).unwrap().quantize(8);
        let img: Vec<f64> = ds.images[0].iter().map(|&v| v as f64).collect();
        bench("bitexact_lenet5_inference(k=32)", 1, 5, || {
            std::hint::black_box(forward(&net, &weights, &img, ForwardMode::Stochastic { k: 32, seed: 7 }));
        });
        bench("expectation_lenet5_inference", 1, 10, || {
            std::hint::black_box(forward(&net, &weights, &img, ForwardMode::Expectation));
        });
        // PJRT serving path (single image, batch-1 graph).
        let engine = scnn::runtime::Engine::load(&artifacts.hlo("lenet5", 1)).unwrap();
        bench("pjrt_lenet5_b1", 2, 20, || {
            std::hint::black_box(engine.run_f32(&ds.images[0], &[1, 1, 28, 28]).unwrap());
        });
        let eb = scnn::runtime::Engine::load(&artifacts.hlo("lenet5", 32)).unwrap();
        let mut flat = Vec::new();
        for i in 0..32 {
            flat.extend_from_slice(&ds.images[i]);
        }
        let r = bench("pjrt_lenet5_b32", 2, 10, || {
            std::hint::black_box(eb.run_f32(&flat, &[32, 1, 28, 28]).unwrap());
        });
        println!("  -> {:.0} img/s batched", r.ops_per_sec(32.0));
    } else {
        eprintln!("artifacts missing — PJRT hot-path benches skipped");
    }

    // Gate-level simulator throughput (the Genus substitute).
    let lib = scnn::tech::CellLibrary::finfet10();
    let nl = scnn::sc::apc::build_netlist(25, 32, scnn::sc::apc::FaStyle::CmosCell);
    bench("apc25_power_sim(2048 cycles)", 1, 5, || {
        let mut s = 1u64;
        std::hint::black_box(scnn::sim::estimate_power(&nl, &lib, 2048, |_, pins| {
            for p in pins.iter_mut() {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                *p = s & 1 == 1;
            }
        }));
    });
}
