//! Fig. 13 regenerator: system area/latency/energy + ADP/EDP/EDAP vs
//! channel count, and the optimal-channel selection (paper: 8).

use scnn::accel::layers::NetworkSpec;
use scnn::accel::metrics::argmin_by;
use scnn::accel::system::sweep_channels;
use scnn::benchutil::{bench, print_table};
use scnn::tech::TechKind;

fn main() {
    let net = NetworkSpec::lenet5();
    let counts = [1usize, 2, 4, 8, 16, 32];
    for tech in [TechKind::Finfet10, TechKind::Rfet10] {
        let evals = sweep_channels(tech, &net, &counts);
        let rows: Vec<Vec<String>> = evals
            .iter()
            .map(|e| {
                let m = &e.metrics;
                vec![
                    e.channels.to_string(),
                    format!("{:.1}", m.logic_area_mm2 * 1000.0),
                    format!("{:.2}", m.latency_us),
                    format!("{:.3}", m.energy_uj),
                    format!("{:.4}", m.adp()),
                    format!("{:.4}", m.edp()),
                    format!("{:.5}", m.edap()),
                ]
            })
            .collect();
        print_table(
            &format!("Fig. 13 — {tech} (logic area ×10⁻³ mm²)"),
            &["ch", "logic area", "latency µs", "energy µJ", "ADP", "EDP", "EDAP"],
            &rows,
        );
        let ms: Vec<_> = evals.iter().map(|e| e.metrics).collect();
        let best_adp = counts[argmin_by(&ms, |m| m.adp())];
        let best_edap = counts[argmin_by(&ms, |m| m.edap())];
        println!("optima: ADP {best_adp} ch, EDAP {best_edap} ch (paper: 8)");
        assert!((4..=16).contains(&best_edap), "EDAP optimum out of band");
        // Fig. 13 qualitative claims.
        for w in evals.windows(2) {
            assert!(w[1].metrics.latency_us <= w[0].metrics.latency_us * 1.001);
            assert!(w[1].metrics.logic_area_mm2 > w[0].metrics.logic_area_mm2);
        }
    }
    bench("sweep_channels(6 points, rfet)", 1, 3, || {
        std::hint::black_box(sweep_channels(TechKind::Rfet10, &net, &counts));
    });
}
