//! Table I regenerator: area/delay/energy of the 8-bit PCC and 25-input
//! APC, FinFET vs RFET, plus timing of the characterization itself.

use scnn::accel::channel::{characterize_apc, characterize_pcc};
use scnn::benchutil::{bench, gain_pct, print_table};
use scnn::tech::calibration as cal;
use scnn::tech::CellLibrary;

fn main() {
    let fin = CellLibrary::finfet10();
    let rf = CellLibrary::rfet10();
    let (fp, rp) = (characterize_pcc(&fin), characterize_pcc(&rf));
    let (fa, ra) = (characterize_apc(&fin), characterize_apc(&rf));

    let row = |r: &scnn::sim::BlockReport| {
        vec![
            r.tech.clone(),
            format!("{:.2}", r.area_um2),
            format!("{:.0}", r.delay_ps),
            format!("{:.2}", r.energy_per_cycle_fj),
        ]
    };
    print_table(
        "Table I — 8-bit PCC (paper: FinFET 2.21/242/4.11, RFET 2.01/142/2.89)",
        &["tech", "area µm²", "delay ps", "energy fJ"],
        &[row(&fp), row(&rp)],
    );
    println!(
        "gains: area {:+.1}% (paper 9.1), delay {:+.1}% (41.6), energy {:+.1}% (29.7)",
        gain_pct(fp.area_um2, rp.area_um2),
        gain_pct(fp.delay_ps, rp.delay_ps),
        gain_pct(fp.energy_per_cycle_fj, rp.energy_per_cycle_fj)
    );
    print_table(
        "Table I — 25-input APC (paper: FinFET 24.37/462/40.14, RFET 26.15/593/35.88)",
        &["tech", "area µm²", "delay ps", "energy fJ"],
        &[row(&fa), row(&ra)],
    );
    println!(
        "gains: area {:+.1}% (paper -7.2), delay {:+.1}% (-28.4), energy {:+.1}% (10.6)",
        gain_pct(fa.area_um2, ra.area_um2),
        gain_pct(fa.delay_ps, ra.delay_ps),
        gain_pct(fa.energy_per_cycle_fj, ra.energy_per_cycle_fj)
    );
    for (m, t) in [
        (fp.area_um2, cal::TABLE1_FINFET_PCC8.area_um2),
        (rp.energy_per_cycle_fj, cal::TABLE1_RFET_PCC8.energy_fj),
        (fa.delay_ps, cal::TABLE1_FINFET_APC25.delay_ps),
    ] {
        assert!(cal::rel_err(m, t) < 0.06, "calibration regression: {m} vs {t}");
    }
    bench("characterize_pcc(finfet)", 1, 5, || {
        std::hint::black_box(characterize_pcc(&fin));
    });
    bench("characterize_apc(rfet)", 1, 3, || {
        std::hint::black_box(characterize_apc(&rf));
    });
}
