//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The container this repo builds in has no crates.io registry, so the
//! subset of `anyhow` the codebase uses is reproduced here: a boxed-string
//! [`Error`], the [`Result`] alias, the [`Context`] extension trait, and the
//! [`anyhow!`] / [`bail!`] macros. Semantics match the real crate closely
//! enough for this codebase: context wraps are prepended to the message, and
//! any `std::error::Error` converts via `?` through [`Context`] or the
//! explicit `From` impls below.
//!
//! One deliberate divergence: this `Error` *does* implement
//! `std::error::Error` (the real one cannot, for coherence reasons we avoid
//! by enumerating `From` impls instead of a blanket one). That lets the
//! single generic [`Context`] impl cover `Result<T, anyhow::Error>` too.

use std::fmt;

/// A lightweight error: a rendered message chain.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msg: m.to_string() }
    }

    /// Prepend context to the message chain.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::msg(e)
    }
}

impl From<std::fmt::Error> for Error {
    fn from(e: std::fmt::Error) -> Self {
        Error::msg(e)
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Self {
        Error::msg(e)
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Self {
        Error::msg(e)
    }
}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error { msg: s }
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Error { msg: s.to_string() }
    }
}

/// `anyhow`-style result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context()` / `.with_context()`.
pub trait Context<T, E> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    /// Wrap the error with a lazily-built context message.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E> Context<T, E> for Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*).into())
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*).into());
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        let e = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        Err(e)?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_io() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().contains("gone"));
    }

    #[test]
    fn context_chains() {
        let r: Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::Other, "inner"));
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
        // Context on an already-anyhow result (our divergence makes this work).
        let r2: Result<()> = Err(anyhow!("mid"));
        let e2 = r2.context("top").unwrap_err();
        assert_eq!(e2.to_string(), "top: mid");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn bail_formats() {
        fn f(x: u32) -> Result<()> {
            if x > 2 {
                bail!("too big: {x}");
            }
            Ok(())
        }
        assert!(f(1).is_ok());
        assert_eq!(f(9).unwrap_err().to_string(), "too big: 9");
    }
}
