//! Offline stand-in for the `xla` PJRT bindings.
//!
//! The real serving path loads AOT-compiled HLO *text* (emitted by
//! `python -m compile.aot`) and executes it through PJRT. This environment
//! has no XLA runtime, so this crate parses the same HLO text into a tiny
//! instruction list and interprets it on the CPU. The public surface mirrors
//! the call sites in `scnn::runtime::Engine` exactly
//! (`PjRtClient::cpu` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `compile` → `execute`), so swapping the
//! real bindings back in is a Cargo.toml-only change.
//!
//! Supported op set (everything the lenet5/fake-model/sc_mac graphs and the
//! unit-test modules need): `parameter`, `constant` (scalar and 1-D list),
//! `broadcast`, `reshape`, `add`, `subtract`, `multiply`, `divide`,
//! `maximum`, `minimum`, `and`, `or`, `xor`, `reduce` (add / maximum /
//! multiply apply-computations), `tuple`. Unknown ops fail with a clear
//! message at compile time rather than silently at execute time.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;

// ---------------------------------------------------------------------------
// Literals
// ---------------------------------------------------------------------------

/// Element dtypes the interpreter carries.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    /// 32-bit float.
    F32,
    /// 32-bit unsigned integer.
    U32,
}

/// A host tensor (or tuple of tensors) exchanged with an executable.
#[derive(Debug, Clone, PartialEq)]
pub enum Literal {
    /// Dense f32 tensor, row-major.
    F32 {
        /// Dimension sizes.
        dims: Vec<usize>,
        /// Flat data.
        data: Vec<f32>,
    },
    /// Dense u32 tensor, row-major.
    U32 {
        /// Dimension sizes.
        dims: Vec<usize>,
        /// Flat data.
        data: Vec<u32>,
    },
    /// A tuple of literals (XLA results are tuples).
    Tuple(Vec<Literal>),
}

/// Native element types `Literal` can be built from / unpacked to.
pub trait NativeType: Copy {
    /// Wrap a flat vector as a rank-1 literal payload.
    fn wrap(dims: Vec<usize>, data: Vec<Self>) -> Literal;
    /// Extract a flat vector, failing on dtype mismatch.
    fn unwrap_literal(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(dims: Vec<usize>, data: Vec<Self>) -> Literal {
        Literal::F32 { dims, data }
    }
    fn unwrap_literal(lit: &Literal) -> Result<Vec<Self>> {
        match lit {
            Literal::F32 { data, .. } => Ok(data.clone()),
            other => bail!("literal is not f32: {other:?}"),
        }
    }
}

impl NativeType for u32 {
    fn wrap(dims: Vec<usize>, data: Vec<Self>) -> Literal {
        Literal::U32 { dims, data }
    }
    fn unwrap_literal(lit: &Literal) -> Result<Vec<Self>> {
        match lit {
            Literal::U32 { data, .. } => Ok(data.clone()),
            other => bail!("literal is not u32: {other:?}"),
        }
    }
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        T::wrap(vec![v.len()], v.to_vec())
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let new_dims: Vec<usize> = dims.iter().map(|&d| d as usize).collect();
        let count: usize = new_dims.iter().product();
        match self {
            Literal::F32 { data, .. } => {
                if data.len() != count {
                    bail!("reshape: {} elements into {:?}", data.len(), new_dims);
                }
                Ok(Literal::F32 { dims: new_dims, data: data.clone() })
            }
            Literal::U32 { data, .. } => {
                if data.len() != count {
                    bail!("reshape: {} elements into {:?}", data.len(), new_dims);
                }
                Ok(Literal::U32 { dims: new_dims, data: data.clone() })
            }
            Literal::Tuple(_) => bail!("cannot reshape a tuple literal"),
        }
    }

    /// Unwrap a single-element tuple.
    pub fn to_tuple1(&self) -> Result<Literal> {
        match self {
            Literal::Tuple(v) if v.len() == 1 => Ok(v[0].clone()),
            Literal::Tuple(v) => bail!("tuple has {} elements, expected 1", v.len()),
            other => bail!("not a tuple literal: {other:?}"),
        }
    }

    /// Flat element vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap_literal(self)
    }
}

// ---------------------------------------------------------------------------
// HLO parsing
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum BinKind {
    Add,
    Subtract,
    Multiply,
    Divide,
    Maximum,
    Minimum,
    And,
    Or,
    Xor,
}

#[derive(Debug, Clone)]
enum Op {
    Parameter(usize),
    ConstantScalar(f64),
    ConstantList(Vec<f64>),
    Broadcast { operand: String, dimensions: Vec<usize> },
    Reshape { operand: String },
    Binary { kind: BinKind, lhs: String, rhs: String },
    Reduce { operand: String, init: String, dimensions: Vec<usize>, apply: String },
    Tuple(Vec<String>),
}

#[derive(Debug, Clone)]
struct Instr {
    name: String,
    dtype: DType,
    dims: Vec<usize>,
    op: Op,
    is_root: bool,
}

#[derive(Debug, Clone)]
struct Computation {
    name: String,
    instrs: Vec<Instr>,
}

impl Computation {
    fn root(&self) -> Result<&Instr> {
        self.instrs
            .iter()
            .find(|i| i.is_root)
            .or_else(|| self.instrs.last())
            .ok_or_else(|| anyhow!("computation {} is empty", self.name))
    }
}

#[derive(Debug, Clone)]
struct Module {
    computations: Vec<Computation>,
    entry: String,
}

/// Split `s` on top-level commas (ignores commas inside `{}`, `()`, `[]`).
fn split_top_level(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut depth = 0i32;
    let mut cur = String::new();
    for c in s.chars() {
        match c {
            '{' | '(' | '[' => {
                depth += 1;
                cur.push(c);
            }
            '}' | ')' | ']' => {
                depth -= 1;
                cur.push(c);
            }
            ',' if depth == 0 => {
                out.push(cur.trim().to_string());
                cur.clear();
            }
            _ => cur.push(c),
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur.trim().to_string());
    }
    out
}

/// Parse a shape token like `f32[2,10]{1,0}`, `u32[]`, or `(f32[4]{0})`
/// (tuple types yield the first element's dtype; dims of a tuple are unused).
fn parse_shape(tok: &str) -> Result<(DType, Vec<usize>)> {
    let t = tok.trim().trim_start_matches('(');
    let dtype = if t.starts_with("f32") {
        DType::F32
    } else if t.starts_with("u32") || t.starts_with("s32") || t.starts_with("pred") {
        DType::U32
    } else {
        bail!("unsupported element type in shape {tok:?}");
    };
    let dims = match (t.find('['), t.find(']')) {
        (Some(a), Some(b)) if b > a => {
            let inner = &t[a + 1..b];
            if inner.trim().is_empty() {
                Vec::new()
            } else {
                inner
                    .split(',')
                    .map(|d| d.trim().parse::<usize>().map_err(|e| anyhow!("bad dim {d:?}: {e}")))
                    .collect::<Result<Vec<_>>>()?
            }
        }
        _ => Vec::new(),
    };
    Ok((dtype, dims))
}

/// Parse `{1,0}`- or `{}`-style dimension attribute payloads.
fn parse_dims_attr(s: &str) -> Result<Vec<usize>> {
    let inner = s.trim().trim_start_matches('{').trim_end_matches('}');
    if inner.trim().is_empty() {
        return Ok(Vec::new());
    }
    inner
        .split(',')
        .map(|d| d.trim().parse::<usize>().map_err(|e| anyhow!("bad dimension {d:?}: {e}")))
        .collect()
}

fn parse_instr(line: &str) -> Result<Instr> {
    let line = line.trim();
    let (is_root, line) = match line.strip_prefix("ROOT ") {
        Some(rest) => (true, rest),
        None => (false, line),
    };
    let (name, rhs) = line
        .split_once('=')
        .ok_or_else(|| anyhow!("instruction without '=': {line:?}"))?;
    let name = name.trim().to_string();
    let rhs = rhs.trim();
    // Shape token runs to the first space (HLO shape tokens contain no spaces).
    let (shape_tok, rest) = rhs
        .split_once(' ')
        .ok_or_else(|| anyhow!("instruction without op: {rhs:?}"))?;
    let (dtype, dims) = parse_shape(shape_tok)?;
    let rest = rest.trim();
    let open = rest.find('(').ok_or_else(|| anyhow!("op without operands: {rest:?}"))?;
    let opname = rest[..open].trim();
    // Find the matching close paren (operand lists may nest braces; HLO
    // text is ASCII so byte indexing is safe).
    let mut depth = 0i32;
    let mut close = None;
    for (i, c) in rest.bytes().enumerate().skip(open) {
        match c {
            b'(' | b'{' | b'[' => depth += 1,
            b')' | b'}' | b']' => {
                depth -= 1;
                if depth == 0 {
                    close = Some(i);
                    break;
                }
            }
            _ => {}
        }
    }
    let close = close.ok_or_else(|| anyhow!("unbalanced operand list: {rest:?}"))?;
    let args_str = &rest[open + 1..close];
    let attrs_str = rest[close + 1..].trim().trim_start_matches(',').trim();
    let args = split_top_level(args_str);
    let mut dimensions: Option<Vec<usize>> = None;
    let mut to_apply: Option<String> = None;
    for attr in split_top_level(attrs_str) {
        if let Some((k, v)) = attr.split_once('=') {
            match k.trim() {
                "dimensions" => dimensions = Some(parse_dims_attr(v)?),
                "to_apply" => to_apply = Some(v.trim().to_string()),
                _ => {} // layouts, metadata, sharding — irrelevant here
            }
        }
    }

    let bin = |kind: BinKind, args: &[String]| -> Result<Op> {
        if args.len() != 2 {
            bail!("binary op needs 2 operands, got {args:?}");
        }
        Ok(Op::Binary { kind, lhs: args[0].clone(), rhs: args[1].clone() })
    };

    let op = match opname {
        "parameter" => {
            let idx = args
                .first()
                .ok_or_else(|| anyhow!("parameter without index"))?
                .parse::<usize>()?;
            Op::Parameter(idx)
        }
        "constant" => {
            let payload = args.join(",");
            let payload = payload.trim();
            if let Some(list) = payload.strip_prefix('{') {
                let list = list.trim_end_matches('}');
                let vals = list
                    .split(',')
                    .filter(|s| !s.trim().is_empty())
                    .map(|s| s.trim().parse::<f64>().map_err(|e| anyhow!("bad constant {s:?}: {e}")))
                    .collect::<Result<Vec<_>>>()?;
                Op::ConstantList(vals)
            } else {
                Op::ConstantScalar(
                    payload.parse::<f64>().map_err(|e| anyhow!("bad constant {payload:?}: {e}"))?,
                )
            }
        }
        "broadcast" => Op::Broadcast {
            operand: args.first().ok_or_else(|| anyhow!("broadcast without operand"))?.clone(),
            dimensions: dimensions.unwrap_or_default(),
        },
        "reshape" | "bitcast" | "copy" | "convert" => Op::Reshape {
            operand: args.first().ok_or_else(|| anyhow!("{opname} without operand"))?.clone(),
        },
        "add" => bin(BinKind::Add, &args)?,
        "subtract" => bin(BinKind::Subtract, &args)?,
        "multiply" => bin(BinKind::Multiply, &args)?,
        "divide" => bin(BinKind::Divide, &args)?,
        "maximum" => bin(BinKind::Maximum, &args)?,
        "minimum" => bin(BinKind::Minimum, &args)?,
        "and" => bin(BinKind::And, &args)?,
        "or" => bin(BinKind::Or, &args)?,
        "xor" => bin(BinKind::Xor, &args)?,
        "reduce" => {
            if args.len() != 2 {
                bail!("reduce needs (operand, init), got {args:?}");
            }
            Op::Reduce {
                operand: args[0].clone(),
                init: args[1].clone(),
                dimensions: dimensions.ok_or_else(|| anyhow!("reduce without dimensions"))?,
                apply: to_apply.ok_or_else(|| anyhow!("reduce without to_apply"))?,
            }
        }
        "tuple" => Op::Tuple(args.to_vec()),
        other => bail!("unsupported HLO op {other:?}"),
    };
    Ok(Instr { name, dtype, dims, op, is_root })
}

fn parse_module(text: &str) -> Result<Module> {
    let mut computations = Vec::new();
    let mut entry = None;
    let mut current: Option<(String, bool, Vec<Instr>)> = None;
    for raw in text.lines() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with("HloModule") || line.starts_with("//") {
            continue;
        }
        if line == "}" {
            let (name, is_entry, instrs) =
                current.take().ok_or_else(|| anyhow!("unmatched '}}' in HLO text"))?;
            if is_entry {
                entry = Some(name.clone());
            }
            computations.push(Computation { name, instrs });
            continue;
        }
        if let Some(head) = line.strip_suffix('{') {
            if current.is_some() {
                bail!("nested computation in HLO text");
            }
            let head = head.trim();
            let (is_entry, name) = match head.strip_prefix("ENTRY ") {
                Some(n) => (true, n.trim()),
                None => (false, head),
            };
            // Full HLO dumps annotate signatures (`main.10 (x: f32[4]) -> ...`);
            // the name is the first token.
            let name = name
                .split(|c: char| c == ' ' || c == '(')
                .next()
                .unwrap_or(name)
                .trim_start_matches('%');
            current = Some((name.to_string(), is_entry, Vec::new()));
            continue;
        }
        match current.as_mut() {
            Some((name, _, instrs)) => {
                let instr = parse_instr(line)
                    .with_context(|| format!("in computation {name}, line {line:?}"))?;
                instrs.push(instr);
            }
            None => bail!("instruction outside computation: {line:?}"),
        }
    }
    let entry = entry.ok_or_else(|| anyhow!("HLO text has no ENTRY computation"))?;
    Ok(Module { computations, entry })
}

// ---------------------------------------------------------------------------
// Evaluation
// ---------------------------------------------------------------------------

/// Interpreter value: dims + f64 storage (exact for f32 and for the u32
/// ranges SC counters produce).
#[derive(Debug, Clone)]
struct Value {
    dims: Vec<usize>,
    data: Vec<f64>,
}

impl Value {
    fn scalar(v: f64) -> Self {
        Value { dims: Vec::new(), data: vec![v] }
    }
}

fn strides(dims: &[usize]) -> Vec<usize> {
    let mut s = vec![1usize; dims.len()];
    for i in (0..dims.len().saturating_sub(1)).rev() {
        s[i] = s[i + 1] * dims[i + 1];
    }
    s
}

fn apply_bin(kind: BinKind, a: f64, b: f64) -> f64 {
    match kind {
        BinKind::Add => a + b,
        BinKind::Subtract => a - b,
        BinKind::Multiply => a * b,
        BinKind::Divide => a / b,
        BinKind::Maximum => a.max(b),
        BinKind::Minimum => a.min(b),
        BinKind::And => ((a as u64) & (b as u64)) as f64,
        BinKind::Or => ((a as u64) | (b as u64)) as f64,
        BinKind::Xor => ((a as u64) ^ (b as u64)) as f64,
    }
}

impl Module {
    fn computation(&self, name: &str) -> Result<&Computation> {
        self.computations
            .iter()
            .find(|c| c.name == name)
            .ok_or_else(|| anyhow!("unknown computation {name:?}"))
    }

    /// Reduce combiner kind from an apply-computation's root op.
    fn combiner(&self, name: &str) -> Result<BinKind> {
        let root = self.computation(name)?.root()?;
        match &root.op {
            Op::Binary { kind, .. } => Ok(*kind),
            other => bail!("unsupported reduce combiner {other:?} in {name:?}"),
        }
    }

    fn evaluate(&self, args: &[&Literal]) -> Result<Literal> {
        let comp = self.computation(&self.entry)?;
        let mut env: HashMap<&str, Value> = HashMap::new();
        for instr in &comp.instrs {
            let get = |env: &HashMap<&str, Value>, n: &str| -> Result<Value> {
                env.get(n).cloned().ok_or_else(|| anyhow!("undefined operand {n:?}"))
            };
            let v = match &instr.op {
                Op::Parameter(i) => {
                    let lit = args
                        .get(*i)
                        .ok_or_else(|| anyhow!("missing argument {i} (got {})", args.len()))?;
                    let (dims, data) = match lit {
                        Literal::F32 { dims, data } => {
                            (dims.clone(), data.iter().map(|&x| x as f64).collect())
                        }
                        Literal::U32 { dims, data } => {
                            (dims.clone(), data.iter().map(|&x| x as f64).collect())
                        }
                        Literal::Tuple(_) => bail!("tuple parameters unsupported"),
                    };
                    let expected: usize = instr.dims.iter().product();
                    let got: usize = dims.iter().product();
                    if expected != got {
                        bail!(
                            "parameter {i} element count {got} != declared {expected} ({:?})",
                            instr.dims
                        );
                    }
                    // Trust the declared dims (callers reshape before execute).
                    Value { dims: instr.dims.clone(), data }
                }
                Op::ConstantScalar(c) => Value::scalar(*c),
                Op::ConstantList(vs) => Value { dims: vec![vs.len()], data: vs.clone() },
                Op::Reshape { operand } => {
                    let o = get(&env, operand)?;
                    let expected: usize = instr.dims.iter().product();
                    if o.data.len() != expected {
                        bail!("reshape {}: {} -> {:?}", instr.name, o.data.len(), instr.dims);
                    }
                    Value { dims: instr.dims.clone(), data: o.data }
                }
                Op::Broadcast { operand, dimensions } => {
                    let o = get(&env, operand)?;
                    if dimensions.len() != o.dims.len() {
                        bail!(
                            "broadcast {}: {} mapped dims for rank-{} operand",
                            instr.name,
                            dimensions.len(),
                            o.dims.len()
                        );
                    }
                    let out_dims = instr.dims.clone();
                    let out_strides = strides(&out_dims);
                    let in_strides = strides(&o.dims);
                    let count: usize = out_dims.iter().product();
                    let mut data = vec![0.0f64; count];
                    for (flat, slot) in data.iter_mut().enumerate() {
                        let mut in_flat = 0usize;
                        for (j, &od) in dimensions.iter().enumerate() {
                            let coord = (flat / out_strides[od]) % out_dims[od];
                            in_flat += coord * in_strides[j];
                        }
                        *slot = o.data[in_flat];
                    }
                    Value { dims: out_dims, data }
                }
                Op::Binary { kind, lhs, rhs } => {
                    let a = get(&env, lhs)?;
                    let b = get(&env, rhs)?;
                    if a.data.len() != b.data.len() {
                        bail!("binary {}: shape mismatch {:?} vs {:?}", instr.name, a.dims, b.dims);
                    }
                    let data =
                        a.data.iter().zip(&b.data).map(|(&x, &y)| apply_bin(*kind, x, y)).collect();
                    Value { dims: a.dims, data }
                }
                Op::Reduce { operand, init, dimensions, apply } => {
                    let o = get(&env, operand)?;
                    let init_v = get(&env, init)?;
                    let init_s = *init_v.data.first().ok_or_else(|| anyhow!("empty reduce init"))?;
                    let kind = self.combiner(apply)?;
                    let out_dims: Vec<usize> = o
                        .dims
                        .iter()
                        .enumerate()
                        .filter(|(i, _)| !dimensions.contains(i))
                        .map(|(_, &d)| d)
                        .collect();
                    let out_count: usize = out_dims.iter().product::<usize>().max(1);
                    let mut data = vec![init_s; out_count];
                    let in_strides = strides(&o.dims);
                    let out_strides = strides(&out_dims);
                    for (flat, &x) in o.data.iter().enumerate() {
                        let mut out_flat = 0usize;
                        let mut oi = 0usize;
                        for (i, &d) in o.dims.iter().enumerate() {
                            if dimensions.contains(&i) {
                                continue;
                            }
                            let coord = (flat / in_strides[i]) % d;
                            out_flat += coord * out_strides[oi];
                            oi += 1;
                        }
                        data[out_flat] = apply_bin(kind, data[out_flat], x);
                    }
                    Value { dims: out_dims, data }
                }
                Op::Tuple(_) => continue, // materialized from env at the end
            };
            env.insert(instr.name.as_str(), v);
        }
        // Materialize the root.
        let root = comp.root()?;
        let to_literal = |instr: &Instr, v: &Value| -> Literal {
            match instr.dtype {
                DType::F32 => Literal::F32 {
                    dims: instr.dims.clone(),
                    data: v.data.iter().map(|&x| x as f32).collect(),
                },
                DType::U32 => Literal::U32 {
                    dims: instr.dims.clone(),
                    data: v.data.iter().map(|&x| x as u32).collect(),
                },
            }
        };
        match &root.op {
            Op::Tuple(names) => {
                let mut elems = Vec::with_capacity(names.len());
                for n in names {
                    let instr = comp
                        .instrs
                        .iter()
                        .find(|i| &i.name == n)
                        .ok_or_else(|| anyhow!("tuple element {n:?} undefined"))?;
                    let v = env.get(n.as_str()).ok_or_else(|| anyhow!("tuple element {n:?} unevaluated"))?;
                    elems.push(to_literal(instr, v));
                }
                Ok(Literal::Tuple(elems))
            }
            _ => {
                let v = env
                    .get(root.name.as_str())
                    .ok_or_else(|| anyhow!("root {:?} unevaluated", root.name))?;
                Ok(Literal::Tuple(vec![to_literal(root, v)]))
            }
        }
    }
}

// ---------------------------------------------------------------------------
// PJRT-shaped surface
// ---------------------------------------------------------------------------

/// Stand-in for the PJRT CPU client.
pub struct PjRtClient;

impl PjRtClient {
    /// Create the CPU client (always succeeds in the interpreter).
    pub fn cpu() -> Result<Self> {
        Ok(PjRtClient)
    }

    /// Platform name, mirroring PJRT's `"cpu"`.
    pub fn platform_name(&self) -> String {
        "cpu".to_string()
    }

    /// "Compile" a computation (the interpreter just carries the module).
    pub fn compile(&self, computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable { module: computation.module.clone() })
    }
}

/// Parsed HLO module, analogous to `HloModuleProto`.
pub struct HloModuleProto {
    module: Module,
}

impl HloModuleProto {
    /// Parse HLO text from a file.
    pub fn from_text_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading HLO text {path}"))?;
        Self::from_text(&text)
    }

    /// Parse HLO text from a string.
    pub fn from_text(text: &str) -> Result<Self> {
        Ok(HloModuleProto { module: parse_module(text)? })
    }
}

/// A computation ready to compile.
pub struct XlaComputation {
    module: Module,
}

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        XlaComputation { module: proto.module.clone() }
    }
}

/// A device buffer holding one result.
pub struct PjRtBuffer {
    lit: Literal,
}

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

/// A compiled (here: interpretable) executable.
pub struct PjRtLoadedExecutable {
    module: Module,
}

impl PjRtLoadedExecutable {
    /// Execute with the given arguments; returns per-device, per-output
    /// buffers like PJRT (`[0][0]` is the result tuple).
    pub fn execute<T: std::borrow::Borrow<Literal>>(
        &self,
        args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        let lits: Vec<&Literal> = args.iter().map(|a| a.borrow()).collect();
        let out = self.module.evaluate(&lits)?;
        Ok(vec![vec![PjRtBuffer { lit: out }]])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ADD_ONE: &str = r#"HloModule add_one, entry_computation_layout={(f32[4]{0})->(f32[4]{0})}

ENTRY main {
  x = f32[4]{0} parameter(0)
  one = f32[] constant(1)
  ones = f32[4]{0} broadcast(one), dimensions={}
  sum = f32[4]{0} add(x, ones)
  ROOT out = (f32[4]{0}) tuple(sum)
}
"#;

    #[test]
    fn add_one_runs() {
        let m = HloModuleProto::from_text(ADD_ONE).unwrap();
        let exe = PjRtClient::cpu().unwrap().compile(&XlaComputation::from_proto(&m)).unwrap();
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[4]).unwrap();
        let out = exe.execute::<Literal>(&[lit]).unwrap()[0][0]
            .to_literal_sync()
            .unwrap()
            .to_tuple1()
            .unwrap()
            .to_vec::<f32>()
            .unwrap();
        assert_eq!(out, vec![2.0, 3.0, 4.0, 5.0]);
    }

    const REDUCE_MODEL: &str = r#"HloModule fake_b2, entry_computation_layout={(f32[2,1,2,2]{3,2,1,0})->(f32[2,10]{1,0})}

add {
  a = f32[] parameter(0)
  b = f32[] parameter(1)
  ROOT s = f32[] add(a, b)
}

ENTRY main {
  x = f32[2,1,2,2]{3,2,1,0} parameter(0)
  xr = f32[2,4]{1,0} reshape(x)
  w = f32[10]{0} constant({0.1,0.2,0.3,0.4,0.5,0.6,0.7,0.8,0.9,1.0})
  zero = f32[] constant(0)
  sums = f32[2]{0} reduce(xr, zero), dimensions={1}, to_apply=add
  sb = f32[2,10]{1,0} broadcast(sums), dimensions={0}
  wb = f32[2,10]{1,0} broadcast(w), dimensions={1}
  prod = f32[2,10]{1,0} multiply(sb, wb)
  ROOT out = (f32[2,10]{1,0}) tuple(prod)
}
"#;

    #[test]
    fn reduce_broadcast_model_runs() {
        let m = HloModuleProto::from_text(REDUCE_MODEL).unwrap();
        let exe = PjRtClient::cpu().unwrap().compile(&XlaComputation::from_proto(&m)).unwrap();
        // Image 0 sums to 1.0, image 1 sums to 2.0.
        let input: Vec<f32> = vec![0.25, 0.25, 0.25, 0.25, 0.5, 0.5, 0.5, 0.5];
        let lit = Literal::vec1(&input).reshape(&[2, 1, 2, 2]).unwrap();
        let out = exe.execute::<Literal>(&[lit]).unwrap()[0][0]
            .to_literal_sync()
            .unwrap()
            .to_tuple1()
            .unwrap()
            .to_vec::<f32>()
            .unwrap();
        assert_eq!(out.len(), 20);
        assert!((out[9] - 1.0).abs() < 1e-6); // 1.0 * w[9]
        assert!((out[10] - 0.2).abs() < 1e-6); // 2.0 * w[0]
        assert!((out[19] - 2.0).abs() < 1e-6); // 2.0 * w[9]
    }

    #[test]
    fn unsupported_op_fails_at_parse() {
        let bad = "ENTRY main {\n  x = f32[2]{0} parameter(0)\n  y = f32[2]{0} tanh(x)\n  ROOT out = (f32[2]{0}) tuple(y)\n}\n";
        assert!(HloModuleProto::from_text(bad).is_err());
    }

    #[test]
    fn u32_roundtrip() {
        let hlo = "ENTRY main {\n  a = u32[3]{0} parameter(0)\n  b = u32[3]{0} parameter(1)\n  s = u32[3]{0} add(a, b)\n  ROOT out = (u32[3]{0}) tuple(s)\n}\n";
        let m = HloModuleProto::from_text(hlo).unwrap();
        let exe = PjRtClient::cpu().unwrap().compile(&XlaComputation::from_proto(&m)).unwrap();
        let a = Literal::vec1(&[1u32, 2, 3]);
        let b = Literal::vec1(&[10u32, 20, 30]);
        let out = exe.execute::<Literal>(&[a, b]).unwrap()[0][0]
            .to_literal_sync()
            .unwrap()
            .to_tuple1()
            .unwrap()
            .to_vec::<u32>()
            .unwrap();
        assert_eq!(out, vec![11, 22, 33]);
    }
}
