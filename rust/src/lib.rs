//! # scnn — RFET-based Stochastic-Computing Neural-Network Accelerator
//!
//! A from-scratch reproduction of *"An Energy-Efficient RFET-Based
//! Stochastic Computing Neural Network Accelerator"* (Lu et al., 2025)
//! as a three-layer Rust + JAX + Pallas system.
//!
//! ## The engine API
//!
//! All inference goes through **one entry point**: [`engine`]. A typed
//! [`engine::EngineConfig`] selects a datapath, and
//! [`engine::Engine::open`] returns an [`engine::Session`] that owns the
//! compiled state (plans, scratch arenas, PJRT executables), dynamically
//! batches concurrent requests, applies backpressure on the streaming
//! `submit`/`drain` path, and records per-session metrics — latency
//! histogram, throughput, and the modeled hardware cost of the run.
//!
//! | Backend kind        | What it runs                              | Contract                           |
//! |---------------------|-------------------------------------------|------------------------------------|
//! | `StochasticFused`   | fused word-packed bit-exact SC datapath   | bit-identical to `ReferencePerBit` |
//! | `ReferencePerBit`   | per-bit golden reference (slow)           | the fixed point everything matches |
//! | `Expectation`       | SC expectation model (no sampling noise)  | ≈ stochastic as k → ∞              |
//! | `NoisyExpectation`  | expectation + analytic k-cycle noise      | the paper's §V-B methodology       |
//! | `FixedPoint`        | binary MAC + hard ReLU baseline           | Fig. 12 comparison axis            |
//! | `Xla`               | AOT-compiled HLO graphs via PJRT          | the trained serving graph          |
//!
//! ```no_run
//! # fn main() -> anyhow::Result<()> {
//! use scnn::accel::layers::NetworkSpec;
//! use scnn::engine::{BackendKind, Engine, EngineConfig};
//!
//! let cfg = EngineConfig::new(BackendKind::StochasticFused, NetworkSpec::lenet5())
//!     .with_weights_file("artifacts/lenet5_sc.weights.bin")
//!     .with_k(256);
//! let session = Engine::open(cfg)?;
//! let _logits = session.infer(vec![0.0; 28 * 28])?;
//! println!("{}", session.metrics().summary());
//! # Ok(())
//! # }
//! ```
//!
//! The pre-engine free functions `accel::network::forward` /
//! `forward_batch` are `#[deprecated]` shims kept bit-compatible during
//! the migration window.
//!
//! ## Layer map
//!
//! * **L3 (this crate)** — the engine/serving stack above, plus every
//!   hardware substrate the paper depends on: standard-cell technology
//!   models ([`tech`]), a gate-level netlist builder ([`netlist`]) with
//!   logic/timing/power simulation ([`sim`]), the stochastic-computing
//!   primitive zoo ([`sc`]), the accelerator architecture + performance
//!   model ([`accel`]), and the serving façade ([`coordinator`]) driving
//!   AOT-compiled JAX graphs through PJRT ([`runtime`]).
//! * **L2** — the JAX LeNet-5 / SC-equivalent model (`python/compile/model.py`),
//!   lowered once to HLO text in `artifacts/`.
//! * **L1** — Pallas kernels for the SC hot-spot (`python/compile/kernels/`).
//!
//! Python never runs on the request path; after `make artifacts` the `scnn`
//! binary is self-contained.
//!
//! See `README.md` for the architecture tour and `DESIGN.md` for the full
//! system inventory mapping every table/figure in the paper to a bench
//! target.

pub mod accel;
pub mod benchutil;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod netlist;
pub mod runtime;
pub mod sc;
pub mod sim;
pub mod tech;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
