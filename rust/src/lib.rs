//! # scnn — RFET-based Stochastic-Computing Neural-Network Accelerator
//!
//! A from-scratch reproduction of *"An Energy-Efficient RFET-Based
//! Stochastic Computing Neural Network Accelerator"* (Lu et al., 2025)
//! as a three-layer Rust + JAX + Pallas system.
//!
//! ## The engine API
//!
//! All inference goes through **one entry point**: [`engine`]. A typed
//! [`engine::EngineConfig`] selects a datapath, and
//! [`engine::Engine::open`] returns an [`engine::Session`] that owns the
//! compiled state (plans, scratch arenas, PJRT executables), dynamically
//! batches concurrent requests, applies backpressure on the streaming
//! `submit`/`drain` path, and records per-session metrics — latency
//! histogram, throughput, and the modeled hardware cost of the run.
//!
//! For serving at scale, [`engine::EnginePool`] shards N sessions behind
//! one router (round-robin / least-queue-depth / hash-affinity placement),
//! with admission-control shedding (typed
//! [`engine::EngineError::Rejected`]), automatic rerouting away from dead
//! shards, graceful drain, a process-wide compiled-plan cache (homogeneous
//! shards compile once), and merged [`engine::PoolMetrics`]. The request
//! path is panic-free by construction: `engine/` and `coordinator/` build
//! under `#![deny(clippy::unwrap_used)]`, and every failure mode is a
//! typed [`engine::EngineError`].
//!
//! | Backend kind        | What it runs                              | Contract                           |
//! |---------------------|-------------------------------------------|------------------------------------|
//! | `StochasticFused`   | fused word-packed bit-exact SC datapath   | bit-identical to `ReferencePerBit` |
//! | `ReferencePerBit`   | per-bit golden reference (slow)           | the fixed point everything matches |
//! | `Expectation`       | SC expectation model (no sampling noise)  | ≈ stochastic as k → ∞              |
//! | `NoisyExpectation`  | expectation + analytic k-cycle noise      | the paper's §V-B methodology       |
//! | `FixedPoint`        | binary MAC + hard ReLU baseline           | Fig. 12 comparison axis            |
//! | `Xla`               | AOT-compiled HLO graphs via PJRT          | the trained serving graph          |
//!
//! ```no_run
//! # fn main() -> anyhow::Result<()> {
//! use scnn::accel::layers::NetworkSpec;
//! use scnn::engine::{BackendKind, Engine, EngineConfig};
//!
//! let cfg = EngineConfig::new(BackendKind::StochasticFused, NetworkSpec::lenet5())
//!     .with_weights_file("artifacts/lenet5_sc.weights.bin")
//!     .with_k(256);
//! let session = Engine::open(cfg)?;
//! let _logits = session.infer(vec![0.0; 28 * 28])?;
//! println!("{}", session.metrics().summary());
//! # Ok(())
//! # }
//! ```
//!
//! Beyond in-process calls, [`serve`] exposes a pool over HTTP/1.1
//! (`/v1/infer`, `/v1/batch`, `/metrics`, `/healthz`) with API-key
//! tenants, token-bucket quotas, and Prometheus metrics — all on
//! `std::net`, since the deployment container is offline.
//!
//! ## The stage IR (how a network becomes a datapath)
//!
//! Topologies are described by the typed vocabulary of [`accel::layers`]
//! — square/strided/rectangular/depthwise `Conv`, `MaxPool`, SC
//! counter-based `AvgPool`, `GlobalAvgPool`, `Dense`, and the SC
//! scaled-add residual `Add` — and **compiled** before anything runs:
//!
//! ```text
//! NetworkSpec ──stages()──▶ Vec<StageDescriptor>     (accel::stage)
//!                 │            shapes, neurons/fan-in, weight shapes,
//!                 │            residual save points; malformed stacks
//!                 │            are typed errors, not panics
//!                 │
//! Precision ─resolve─▶ PrecisionPlan                 (accel::precision)
//!   Uniform(k)           one bitstream length per compute stage
//!   PerLayer([k…])       (word-multiple, typed-validated; the Auto
//!   Auto{budget}          policy runs the greedy accuracy-budget tuner)
//!                 │
//!                 ├─▶ ForwardPlan::compile_with_precision
//!                 │       — LayerStage objects (fused SC / analytic),
//!                 │         each compute stage at its own k
//!                 ├─▶ network::reference    — per-bit golden model,
//!                 │                           same per-layer plan
//!                 └─▶ accel::pipeline/system — Algorithm 1 schedule,
//!                     DRAM traffic, energy roll-up, per-layer-k exact
//! ```
//!
//! Because the fused engine and the per-bit reference read the *same*
//! gather tables from the same descriptors — and honor the *same*
//! [`accel::precision::PrecisionPlan`] — their bit-exact parity is
//! structural; and because the hardware model costs the same descriptors
//! at the same per-layer lengths, the modeled schedule can never disagree
//! with the software datapath about what a layer is or how many stream
//! cycles it spends. Adjacent stages at different `k` rescale through the
//! S2B→B2S value boundary every stage already owns.
//! [`accel::layers::NetworkSpec::by_name`] is the
//! single registry behind every stringly network lookup
//! (`lenet5` / `cifar_net` / `mnist_strided`).
//!
//! ## Layer map
//!
//! * **L3 (this crate)** — the engine/serving stack above, plus every
//!   hardware substrate the paper depends on: standard-cell technology
//!   models ([`tech`]), a gate-level netlist builder ([`netlist`]) with
//!   logic/timing/power simulation ([`sim`]), the stochastic-computing
//!   primitive zoo ([`sc`]), the accelerator architecture + performance
//!   model ([`accel`]), and the serving façade ([`coordinator`]) driving
//!   AOT-compiled JAX graphs through PJRT ([`runtime`]).
//! * **L2** — the JAX LeNet-5 / SC-equivalent model (`python/compile/model.py`),
//!   lowered once to HLO text in `artifacts/`.
//! * **L1** — Pallas kernels for the SC hot-spot (`python/compile/kernels/`).
//!
//! Python never runs on the request path; after `make artifacts` the `scnn`
//! binary is self-contained.
//!
//! See `README.md` for the architecture tour and `DESIGN.md` for the full
//! system inventory mapping every table/figure in the paper to a bench
//! target.

pub mod accel;
pub mod analyze;
pub mod benchutil;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod faults;
pub mod netlist;
pub mod runtime;
pub mod sc;
pub mod serve;
pub mod sim;
pub mod tech;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
