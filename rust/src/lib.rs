//! # scnn — RFET-based Stochastic-Computing Neural-Network Accelerator
//!
//! A from-scratch reproduction of *"An Energy-Efficient RFET-Based
//! Stochastic Computing Neural Network Accelerator"* (Lu et al., 2025)
//! as a three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordinator and every hardware substrate the
//!   paper depends on: standard-cell technology models ([`tech`]), a
//!   gate-level netlist builder ([`netlist`]) with logic/timing/power
//!   simulation ([`sim`]), the stochastic-computing primitive zoo ([`sc`]),
//!   the accelerator architecture + performance model ([`accel`]), and a
//!   tokio serving coordinator ([`coordinator`]) that drives AOT-compiled
//!   JAX graphs through PJRT ([`runtime`]).
//! * **L2** — the JAX LeNet-5 / SC-equivalent model (`python/compile/model.py`),
//!   lowered once to HLO text in `artifacts/`.
//! * **L1** — Pallas kernels for the SC hot-spot (`python/compile/kernels/`).
//!
//! Python never runs on the request path; after `make artifacts` the `scnn`
//! binary is self-contained.
//!
//! See `DESIGN.md` for the full system inventory and the experiment index
//! mapping every table/figure in the paper to a bench target.

pub mod accel;
pub mod benchutil;
pub mod coordinator;
pub mod data;
pub mod netlist;
pub mod runtime;
pub mod sc;
pub mod sim;
pub mod tech;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
