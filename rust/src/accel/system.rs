//! System-level roll-up (§V-C/D): combines the channel characterization
//! (Table II), the Algorithm 1 schedule, the SRAM macro, and the top-level
//! buffering into per-design-point area / latency / energy / power — the
//! generator behind Fig. 13 and Table III's "This Work" column.

use crate::accel::channel::{characterize_channel, ChannelReport};
use crate::accel::layers::NetworkSpec;
use crate::accel::memory::MemoryModel;
use crate::accel::metrics::SystemMetrics;
use crate::accel::pipeline::{schedule_stages_sparse, NetworkSchedule, ScheduleConfig};
use crate::accel::precision::PrecisionPlan;
use crate::accel::stage;
use crate::tech::sram::SramMacro;
use crate::tech::TechKind;

/// Top-level overhead that is *not* per-channel logic: ping-pong
/// activation/weight shift registers, output buffers, global control and
/// clocking. The paper keeps all memory/buffering in FinFET for both
/// systems (§V), so this block is technology-independent. Sized so the
/// 8-channel FinFET system lands on Table III's 0.299 mm² total.
pub const TOP_OVERHEAD_UM2: f64 = 272_600.0;
/// Leakage of the top-level buffering (nW) — FinFET register files.
pub const TOP_OVERHEAD_LEAKAGE_NW: f64 = 90_000.0;
/// Switching energy of top-level buffers per active cycle (fJ) — shift
/// registers stream operands continuously while a layer runs.
pub const TOP_OVERHEAD_ENERGY_FJ_PER_CYCLE: f64 = 400.0;

/// A full accelerator configuration.
#[derive(Debug, Clone)]
pub struct SystemConfig {
    /// Technology of the logic (memory stays FinFET either way).
    pub tech: TechKind,
    /// Channel count.
    pub channels: usize,
    /// Bitstream length k.
    pub k: usize,
    /// On-chip SRAM.
    pub sram: SramMacro,
    /// Off-chip memory.
    pub memory: MemoryModel,
}

impl SystemConfig {
    /// The paper's configuration (§V): 8 channels, k = 32, 10 kB SRAM.
    pub fn paper(tech: TechKind, channels: usize) -> Self {
        SystemConfig {
            tech,
            channels,
            k: 32,
            sram: SramMacro::paper_10kb(),
            memory: MemoryModel::gddr5_paper(),
        }
    }
}

/// Evaluation result of one design point on one workload.
#[derive(Debug, Clone)]
pub struct SystemEvaluation {
    /// The configuration evaluated.
    pub channels: usize,
    /// Technology.
    pub tech: TechKind,
    /// Channel characterization used.
    pub channel: ChannelReport,
    /// The workload schedule.
    pub schedule: NetworkSchedule,
    /// Aggregate metrics.
    pub metrics: SystemMetrics,
    /// Area breakdown: (label, µm²).
    pub area_breakdown: Vec<(&'static str, f64)>,
}

/// Evaluate a configuration on a workload, reusing a pre-computed channel
/// report (characterization is deterministic per technology). The
/// schedule, DRAM/SRAM traffic, and op counts all derive from the
/// network's compiled stage descriptors — the same IR the software
/// backends lower from.
pub fn evaluate_with_channel(
    cfg: &SystemConfig,
    net: &NetworkSpec,
    channel: &ChannelReport,
) -> SystemEvaluation {
    let plan = PrecisionPlan::uniform(cfg.k, net.n_compute());
    evaluate_with_channel_precise(cfg, net, channel, &plan)
}

/// [`evaluate_with_channel`] under a per-layer [`PrecisionPlan`]: the
/// Algorithm 1 schedule — and through it every k-scaled figure (delay,
/// switching energy, utilization, leakage-over-latency) — is costed at
/// each compute layer's **own** bitstream length, while the k-independent
/// parts (area, DRAM/SRAM traffic) are unchanged. This is the roll-up
/// behind the per-layer-precision headline: same workload, shorter
/// streams where the network tolerates them, strictly less modeled
/// energy.
pub fn evaluate_with_channel_precise(
    cfg: &SystemConfig,
    net: &NetworkSpec,
    channel: &ChannelReport,
    precision: &PrecisionPlan,
) -> SystemEvaluation {
    evaluate_with_channel_sparse(cfg, net, channel, precision, &[])
}

/// [`evaluate_with_channel_precise`] under a per-compute-layer surviving
/// weight-lane density (`accel::network::weight_densities` / a compiled
/// plan's `stage_densities`): pruned lanes vanish from the modeled
/// SNG/APC datapath, so per-layer `k` × density compound through the
/// schedule into delay, switching energy, operand traffic, and the
/// binary-equivalent op count. An empty slice means dense.
pub fn evaluate_with_channel_sparse(
    cfg: &SystemConfig,
    net: &NetworkSpec,
    channel: &ChannelReport,
    precision: &PrecisionPlan,
    densities: &[f64],
) -> SystemEvaluation {
    let stages = net
        .stages()
        .unwrap_or_else(|e| panic!("system::evaluate({}): {e:#}", net.name));
    let clock_ps = channel.min_clock_ps;
    let sched_cfg = ScheduleConfig {
        channels: cfg.channels,
        k: cfg.k,
        clock_ps,
        memory: cfg.memory,
        bytes_per_operand: 1,
    };
    let schedule = schedule_stages_sparse(&stages, &sched_cfg, precision, densities, 1);

    // ---- area ----
    let logic_area = cfg.channels as f64 * channel.area_um2;
    let sram_area = cfg.sram.area_um2();
    let area_um2 = logic_area + sram_area + TOP_OVERHEAD_UM2;

    // ---- energy per inference ----
    // Switching: channels burn their per-cycle energy while active. The
    // active fraction is the schedule utilization (idle MACs see held
    // operands — no toggling), so total switching scales with the actual
    // MAC·cycles executed, matching the paper's "switching-induced energy
    // remains constant" observation across channel counts.
    let per_mac_cycle_fj =
        channel.energy_per_cycle_fj / crate::accel::pipeline::MACS_PER_CHANNEL as f64;
    let switching_fj = schedule.active_mac_cycles as f64 * per_mac_cycle_fj
        + schedule.total_cycles as f64 * TOP_OVERHEAD_ENERGY_FJ_PER_CYCLE;
    // SRAM traffic: every off-chip byte is staged through the buffer once
    // (write + read).
    let sram_fj = cfg.sram.read_energy_fj(schedule.dram_bytes as usize)
        + cfg.sram.write_energy_fj(schedule.dram_bytes as usize);
    // Leakage over the inference latency.
    let leak_nw = cfg.channels as f64 * channel.leakage_nw
        + cfg.sram.leakage_nw()
        + TOP_OVERHEAD_LEAKAGE_NW;
    // Units: 1 nW = 1e-9 J/s = (1e-9 · 1e15 fJ) / 1e9 ns = 1e-3 fJ/ns.
    let leakage_fj = leak_nw * 1e-3 * schedule.latency_ns;

    let energy_fj = switching_fj + sram_fj + leakage_fj;
    let energy_uj = energy_fj * 1e-9;
    let latency_us = schedule.latency_ns * 1e-3;
    let power_mw = energy_uj / latency_us * 1000.0;
    let clock_ghz = 1000.0 / clock_ps;
    // Binary-equivalent ops: 2 per MAC (multiply + accumulate). Pruned
    // lanes are not ops — the sparse TOPS figure counts surviving work
    // only, so sparsity is not allowed to inflate apparent throughput.
    let ops = if densities.is_empty() {
        2.0 * stage::total_macs(&stages) as f64
    } else {
        2.0 * stages
            .iter()
            .filter(|s| s.neurons > 0)
            .map(|s| {
                let d = s
                    .weight_layer
                    .and_then(|wl| densities.get(wl).copied())
                    .unwrap_or(1.0)
                    .clamp(f64::MIN_POSITIVE, 1.0);
                s.neurons as f64 * (s.fan_in as f64 * d).ceil().max(1.0)
            })
            .sum::<f64>()
    };
    let tops = ops / schedule.latency_ns / 1000.0;

    let metrics = SystemMetrics {
        channels: cfg.channels,
        area_mm2: area_um2 * 1e-6,
        logic_area_mm2: logic_area * 1e-6,
        latency_us,
        energy_uj,
        power_mw,
        clock_ghz,
        tops,
    };
    let pcc_area = crate::accel::channel::PCCS_PER_CHANNEL as f64
        * channel.pcc.area_um2
        * cfg.channels as f64;
    let apc_area = crate::accel::pipeline::MACS_PER_CHANNEL as f64
        * channel.apc.area_um2
        * cfg.channels as f64;
    let tree_area = channel.adder_tree.area_um2 * cfg.channels as f64;
    let area_breakdown = vec![
        ("pcc", pcc_area),
        ("apc", apc_area),
        ("adder_tree", tree_area),
        ("other_logic", logic_area - pcc_area - apc_area - tree_area),
        ("sram", sram_area),
        ("buffers+control", TOP_OVERHEAD_UM2),
    ];

    SystemEvaluation {
        channels: cfg.channels,
        tech: cfg.tech,
        channel: channel.clone(),
        schedule,
        metrics,
        area_breakdown,
    }
}

/// Evaluate a configuration on a workload (characterizes the channel).
pub fn evaluate(cfg: &SystemConfig, net: &NetworkSpec) -> SystemEvaluation {
    let channel = characterize_channel(cfg.tech);
    evaluate_with_channel(cfg, net, &channel)
}

/// Sweep channel counts for one technology on one workload (Fig. 13).
pub fn sweep_channels(
    tech: TechKind,
    net: &NetworkSpec,
    channel_counts: &[usize],
) -> Vec<SystemEvaluation> {
    let channel = characterize_channel(tech);
    channel_counts
        .iter()
        .map(|&c| evaluate_with_channel(&SystemConfig::paper(tech, c), net, &channel))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::metrics::argmin_by;

    #[test]
    fn area_linear_in_channels() {
        let net = NetworkSpec::lenet5();
        let evals = sweep_channels(TechKind::Finfet10, &net, &[1, 2, 4, 8]);
        let a1 = evals[0].metrics.area_mm2;
        let a8 = evals[3].metrics.area_mm2;
        let per_channel = evals[0].channel.area_um2 * 1e-6;
        assert!(((a8 - a1) - 7.0 * per_channel).abs() < 1e-9);
    }

    #[test]
    fn latency_monotone_nonincreasing() {
        let net = NetworkSpec::lenet5();
        let evals = sweep_channels(TechKind::Rfet10, &net, &[1, 2, 4, 8, 16]);
        for w in evals.windows(2) {
            assert!(w[1].metrics.latency_us <= w[0].metrics.latency_us * 1.001);
        }
    }

    #[test]
    fn switching_energy_roughly_constant_across_channels() {
        // §V-C: "The energy consumption of the logic part remains
        // relatively unchanged" — leakage varies, switching does not.
        let net = NetworkSpec::lenet5();
        let evals = sweep_channels(TechKind::Finfet10, &net, &[2, 8, 16]);
        let e: Vec<f64> = evals.iter().map(|ev| ev.metrics.energy_uj).collect();
        for w in e.windows(2) {
            assert!((w[1] - w[0]).abs() / w[0] < 0.35, "energy drifted: {e:?}");
        }
    }

    #[test]
    fn rfet_beats_finfet_at_paper_config() {
        let net = NetworkSpec::lenet5();
        let fin = evaluate(&SystemConfig::paper(TechKind::Finfet10, 8), &net);
        let rf = evaluate(&SystemConfig::paper(TechKind::Rfet10, 8), &net);
        assert!(rf.metrics.area_mm2 < fin.metrics.area_mm2);
        assert!(rf.metrics.latency_us < fin.metrics.latency_us);
        assert!(rf.metrics.energy_uj < fin.metrics.energy_uj);
        assert!(rf.metrics.edap() < fin.metrics.edap());
        // Table III directions: TOPS/W and TOPS/mm² improve with RFETs.
        assert!(rf.metrics.tops_per_watt() > fin.metrics.tops_per_watt());
        assert!(rf.metrics.tops_per_mm2() > fin.metrics.tops_per_mm2());
    }

    #[test]
    fn optimal_channels_in_paper_range() {
        // §V-C finds 8 channels optimal by ADP/EDAP; our model should put
        // the EDAP optimum in the same neighborhood (4–16).
        let net = NetworkSpec::lenet5();
        for tech in [TechKind::Finfet10, TechKind::Rfet10] {
            let counts = [1usize, 2, 4, 8, 16, 32];
            let evals = sweep_channels(tech, &net, &counts);
            let ms: Vec<_> = evals.iter().map(|e| e.metrics).collect();
            let best = counts[argmin_by(&ms, |m| m.edap())];
            assert!(
                (4..=16).contains(&best),
                "{tech:?}: EDAP optimum at {best} channels"
            );
        }
    }

    #[test]
    fn extended_topology_rolls_up_from_the_stage_ir() {
        // The strided/depthwise/avgpool MNIST variant evaluates through
        // the same descriptors; it is far smaller than LeNet-5, so its
        // modeled latency and energy must come in below.
        let small = evaluate(
            &SystemConfig::paper(TechKind::Rfet10, 8),
            &NetworkSpec::mnist_strided(),
        );
        let lenet = evaluate(&SystemConfig::paper(TechKind::Rfet10, 8), &NetworkSpec::lenet5());
        assert!(small.metrics.latency_us < lenet.metrics.latency_us);
        assert!(small.metrics.energy_uj < lenet.metrics.energy_uj);
        assert_eq!(small.schedule.layers.len(), 4, "four compute stages");
        assert_eq!(small.metrics.area_mm2, lenet.metrics.area_mm2, "area is workload-free");
    }

    #[test]
    fn per_layer_precision_lowers_energy_not_area() {
        // Shrinking any layer below the uniform ceiling strictly lowers
        // modeled energy and latency; area and off-chip traffic are
        // k-independent.
        let net = NetworkSpec::lenet5();
        let channel = characterize_channel(TechKind::Rfet10);
        let mut cfg = SystemConfig::paper(TechKind::Rfet10, 8);
        cfg.k = 1024;
        let uniform = evaluate_with_channel_precise(
            &cfg,
            &net,
            &channel,
            &PrecisionPlan::uniform(1024, 5),
        );
        let tapered = evaluate_with_channel_precise(
            &cfg,
            &net,
            &channel,
            &PrecisionPlan::per_layer(vec![256, 256, 128, 64, 1024]),
        );
        assert!(tapered.metrics.energy_uj < uniform.metrics.energy_uj);
        assert!(tapered.metrics.latency_us < uniform.metrics.latency_us);
        assert_eq!(tapered.metrics.area_mm2, uniform.metrics.area_mm2);
        assert_eq!(tapered.schedule.dram_bytes, uniform.schedule.dram_bytes);
        // The uniform-plan path is exactly the scalar path.
        let scalar = evaluate_with_channel(&cfg, &net, &channel);
        assert_eq!(scalar.metrics.energy_uj, uniform.metrics.energy_uj);
        assert_eq!(scalar.schedule.total_cycles, uniform.schedule.total_cycles);
    }

    #[test]
    fn sparsity_lowers_modeled_energy_and_compounds_with_precision() {
        let net = NetworkSpec::lenet5();
        let channel = characterize_channel(TechKind::Rfet10);
        let mut cfg = SystemConfig::paper(TechKind::Rfet10, 8);
        cfg.k = 1024;
        let plan = PrecisionPlan::uniform(1024, 5);
        let dense = evaluate_with_channel_precise(&cfg, &net, &channel, &plan);
        // Empty densities == dense exactly.
        let empty = evaluate_with_channel_sparse(&cfg, &net, &channel, &plan, &[]);
        assert_eq!(empty.metrics.energy_uj, dense.metrics.energy_uj);
        assert_eq!(empty.metrics.tops, dense.metrics.tops);
        // Quarter density: less switching work, less traffic, less
        // energy; area is density-independent; the op count shrinks too
        // (sparsity must not inflate TOPS with skipped work).
        let sparse = evaluate_with_channel_sparse(&cfg, &net, &channel, &plan, &[0.25; 5]);
        assert!(sparse.metrics.energy_uj < dense.metrics.energy_uj);
        assert!(sparse.metrics.latency_us <= dense.metrics.latency_us * 1.001);
        assert_eq!(sparse.metrics.area_mm2, dense.metrics.area_mm2);
        assert!(sparse.schedule.dram_bytes < dense.schedule.dram_bytes);
        // Sparsity compounds with per-layer precision.
        let tapered = PrecisionPlan::per_layer(vec![256, 256, 128, 64, 1024]);
        let both = evaluate_with_channel_sparse(&cfg, &net, &channel, &tapered, &[0.25; 5]);
        assert!(both.metrics.energy_uj < sparse.metrics.energy_uj);
    }

    #[test]
    fn finfet_total_area_near_table3() {
        let net = NetworkSpec::lenet5();
        let fin = evaluate(&SystemConfig::paper(TechKind::Finfet10, 8), &net);
        let err = (fin.metrics.area_mm2 - 0.299).abs() / 0.299;
        assert!(err < 0.15, "area {} mm²", fin.metrics.area_mm2);
    }
}
