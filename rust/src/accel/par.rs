//! Minimal scoped data-parallel helpers.
//!
//! rayon is not vendored in this offline environment (Cargo.toml note), so
//! the stochastic forward's neuron/batch parallelism runs on
//! `std::thread::scope` with a shared atomic task cursor — the same dynamic
//! self-balancing a work-stealing pool gives for this shape of workload
//! (uniform-ish chunks claimed greedily by whichever worker is free).
//!
//! Determinism: chunks are disjoint `&mut` slices written at fixed indices,
//! and every chunk's result depends only on its input (never on scheduling),
//! so output is bit-identical for any thread count — including 1. Set
//! `SCNN_THREADS=1` to force the serial path (useful for profiling).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};

/// Worker count: `SCNN_THREADS` if set (≥1), else the machine's available
/// parallelism. Cached for the process lifetime.
pub fn max_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("SCNN_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
    })
}

/// Split `data` into `chunk_len`-sized pieces and run
/// `f(&mut state, chunk_index, chunk)` over them in parallel, with one
/// `init()`-built state per worker (scratch buffers survive across all the
/// chunks a worker claims — the allocation-free steady state).
///
/// Chunks are claimed dynamically off an atomic cursor, so uneven chunk
/// costs self-balance. Runs serially (no threads spawned) when the machine
/// has one core, `SCNN_THREADS=1`, or there is only one chunk.
pub fn par_chunks_mut_with<T, S, I, F>(data: &mut [T], chunk_len: usize, init: I, f: F)
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize, &mut [T]) + Sync,
{
    par_chunks_mut_with_threads(data, chunk_len, 0, init, f)
}

/// [`par_chunks_mut_with`] with an explicit worker cap: `threads = 0` uses
/// every core ([`max_threads`]), `threads = 1` runs serially, any other
/// value caps the pool — the per-session thread knob of the engine
/// (`EngineConfig::threads`). Output is bit-identical for any cap.
pub fn par_chunks_mut_with_threads<T, S, I, F>(
    data: &mut [T],
    chunk_len: usize,
    threads: usize,
    init: I,
    f: F,
) where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0, "chunk_len must be positive");
    if data.is_empty() {
        return;
    }
    let cap = if threads == 0 { max_threads() } else { threads.min(max_threads()) };
    let n_chunks = data.len().div_ceil(chunk_len);
    let threads = cap.min(n_chunks);
    if threads <= 1 {
        let mut state = init();
        for (i, chunk) in data.chunks_mut(chunk_len).enumerate() {
            f(&mut state, i, chunk);
        }
        return;
    }
    // Hand each chunk out exactly once: an atomic cursor indexes a slot
    // vector; the Mutex-per-slot is uncontended (each slot is taken once).
    let slots: Vec<Mutex<Option<(usize, &mut [T])>>> =
        data.chunks_mut(chunk_len).enumerate().map(|c| Mutex::new(Some(c))).collect();
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                let mut state = init();
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= slots.len() {
                        break;
                    }
                    if let Some((ci, chunk)) = slots[i].lock().unwrap().take() {
                        f(&mut state, ci, chunk);
                    }
                }
            });
        }
    });
}

/// Convenience wrapper without per-worker state.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    par_chunks_mut_with(data, chunk_len, || (), |(), i, c| f(i, c));
}

/// [`par_chunks_mut`] with an explicit worker cap (0 = every core).
pub fn par_chunks_mut_threads<T, F>(data: &mut [T], chunk_len: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    par_chunks_mut_with_threads(data, chunk_len, threads, || (), |(), i, c| f(i, c));
}

/// Chunk length that yields a few chunks per worker for dynamic balance.
pub fn balanced_chunk_len(total: usize) -> usize {
    balanced_chunk_len_for(total, 0)
}

/// [`balanced_chunk_len`] for an explicit worker cap (0 = every core).
pub fn balanced_chunk_len_for(total: usize, threads: usize) -> usize {
    let t = if threads == 0 { max_threads() } else { threads.min(max_threads()) };
    (total / (t * 4)).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_every_element_once() {
        let mut v = vec![0u32; 1037];
        par_chunks_mut(&mut v, 10, |_, chunk| {
            for x in chunk {
                *x += 1;
            }
        });
        assert!(v.iter().all(|&x| x == 1));
    }

    #[test]
    fn chunk_indices_align_with_offsets() {
        let mut v = vec![0usize; 256];
        par_chunks_mut(&mut v, 7, |ci, chunk| {
            for (j, x) in chunk.iter_mut().enumerate() {
                *x = ci * 7 + j;
            }
        });
        for (i, &x) in v.iter().enumerate() {
            assert_eq!(x, i);
        }
    }

    #[test]
    fn worker_state_is_reused_not_shared() {
        // Each worker counts the chunks it processed into its own state;
        // the per-chunk writes must still cover everything exactly once.
        let mut v = vec![0u8; 100];
        par_chunks_mut_with(
            &mut v,
            3,
            || 0usize,
            |seen, _, chunk| {
                *seen += 1;
                for x in chunk {
                    *x += 1;
                }
            },
        );
        assert!(v.iter().all(|&x| x == 1));
    }

    #[test]
    fn empty_and_single_chunk_paths() {
        let mut empty: Vec<u32> = Vec::new();
        par_chunks_mut(&mut empty, 4, |_, _| panic!("no chunks expected"));
        let mut one = vec![1u32, 2, 3];
        par_chunks_mut(&mut one, 100, |ci, chunk| {
            assert_eq!(ci, 0);
            assert_eq!(chunk.len(), 3);
        });
    }

    #[test]
    fn balanced_chunk_is_positive() {
        assert!(balanced_chunk_len(0) >= 1);
        assert!(balanced_chunk_len(1_000_000) >= 1);
        assert!(max_threads() >= 1);
        assert_eq!(balanced_chunk_len(1_000_000), balanced_chunk_len_for(1_000_000, 0));
        assert_eq!(balanced_chunk_len_for(100, 1), 25);
    }

    #[test]
    fn thread_cap_still_covers_everything() {
        for threads in [0usize, 1, 2, 7] {
            let mut v = vec![0u32; 513];
            par_chunks_mut_threads(&mut v, 8, threads, |_, chunk| {
                for x in chunk {
                    *x += 1;
                }
            });
            assert!(v.iter().all(|&x| x == 1), "threads={threads}");
        }
    }
}
