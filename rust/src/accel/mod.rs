//! Accelerator architecture and performance model (§IV, §V).
//!
//! * [`layers`] — the typed CNN layer vocabulary and built-in topologies
//!   (LeNet-5, CIFAR net, the strided-conv/avgpool MNIST variant);
//! * [`stage`] — the compiled per-layer stage IR every backend and the
//!   hardware model lower from (shape inference, gather tables, value
//!   kernels);
//! * [`precision`] — per-layer bitstream-length plans ([`precision::PrecisionPlan`]),
//!   the typed [`precision::Precision`] policy, and the accuracy-budget
//!   autotuner;
//! * [`memory`] — the GDDR5 off-chip model (224 B/ns);
//! * [`pipeline`] — Algorithm 1: non/partial/full pipelining per layer;
//! * [`channel`] — Fig. 9 channel assembly + Table I/II characterization;
//! * [`system`] — whole-accelerator roll-up (Fig. 13, Table III);
//! * [`metrics`] — ADP/EDP/EDAP and TOPS-derived figures of merit;
//! * [`network`] — bit-exact / expectation / fixed-point SCNN inference;
//! * [`par`] — scoped data-parallel helpers (the offline rayon substitute).

pub mod channel;
pub mod layers;
pub mod memory;
pub mod metrics;
pub mod network;
pub mod par;
pub mod pipeline;
pub mod precision;
pub mod stage;
pub mod system;
