//! Bit-exact SCNN inference (§V-B): the full stochastic datapath — SNG →
//! XNOR → APC → B2S → ReLU/MP → S2B — executed layer by layer on packed
//! bitstreams. This is the engine behind Fig. 11/12 and the validation path
//! of the serving coordinator.
//!
//! A fixed-point (non-stochastic) forward pass over the *same* quantized
//! weights provides the "binary NN" baseline of Fig. 12, and an
//! expectation-mode forward (the SC math model without sampling noise)
//! mirrors `python/compile/model.py`.
//!
//! # Engine architecture
//!
//! Inference runs through a [`ForwardPlan`], compiled from the **stage IR**
//! of [`crate::accel::stage`]: [`ForwardPlan::compile`] lowers each
//! [`StageDescriptor`] into one [`LayerStage`] object — a compute stage
//! (conv / strided conv / depthwise conv / dense) with its im2col gather
//! table, per-layer B2S random sequence, and pre-generated weight/padding
//! SNG streams, or a value stage (max/avg/global pooling, the SC
//! scaled-add residual merge). Everything image-independent is computed
//! once at plan
//! build and shared by every image and every thread. Per image, a reusable
//! [`Scratch`] arena holds the activation planes (plus saved residual
//! branches), so steady-state inference performs **no per-neuron heap
//! allocation**: each neuron is one fused pass (word-packed SNG lanes →
//! [`VerticalCounter::add_xnor_words`] → [`VerticalCounter::b2s_ones`])
//! with zero intermediate bitstreams.
//!
//! Work is parallelized with [`crate::accel::par`]: [`ForwardPlan::run`]
//! fans neuron chunks across cores inside each layer;
//! [`ForwardPlan::run_batch`] fans whole images (the serving-path shape).
//! Outputs are **bit-identical** for any thread count and to the per-bit
//! implementation kept in [`reference`] as the golden model — which lowers
//! from the *same* stage descriptors and gather tables, so geometric
//! parity is by construction (asserted in tests, measured in
//! `rust/benches/hotpath.rs`).
//!
//! This module is the *datapath* layer. The public inference entry point is
//! [`crate::engine`]: a session owns one plan (or PJRT ladder), batches
//! requests, and records per-session metrics. For one-shot raw-f64
//! plan-level access, use [`ForwardPlan::once`] / [`ForwardPlan::once_batch`].

use crate::accel::layers::{NetworkSpec, Shape};
use crate::accel::par;
use crate::accel::precision::{self, PrecisionPlan};
use crate::accel::stage::{self, GatherTable, StageDescriptor, StageOp};
use crate::faults::FaultPlan;
use crate::sc::bitplane;
use crate::sc::bitstream::VerticalCounter;
use crate::sc::neuron;
use crate::sc::rng;
use crate::sc::{dequantize_bipolar, quantize_bipolar};
use anyhow::{bail, Result};
use std::sync::Arc;

/// One compute layer's quantized weights plus its re-encoder affine.
///
/// The S2B counter recovers `sp = (v+1)*2^m - n` (= the smoothed-ReLU of
/// the pre-activation); the binary-domain re-encoder then applies
/// `a_next = clip(g*(sp - mu), 0, 1)` before the next layer's SNG — the
/// programmable-scale B2S/SNG boundary, trained jointly with the weights
/// in `python/compile/model.py` (same math, same constants).
#[derive(Debug, Clone)]
pub struct LayerWeights {
    /// `[neuron][fan_in]` bipolar weight codes.
    pub codes: Vec<Vec<u32>>,
    /// Re-encoder gain.
    pub gamma: f64,
    /// Re-encoder offset.
    pub mu: f64,
}

/// Quantized network weights: per compute layer, `[neuron][fan_in]` bipolar
/// codes at the system precision.
#[derive(Debug, Clone)]
pub struct QuantizedWeights {
    /// Precision in bits.
    pub bits: u32,
    /// Per compute-layer weights.
    pub layers: Vec<LayerWeights>,
}

impl QuantizedWeights {
    /// Random-but-deterministic weights sized from the network's stage IR
    /// (one tensor per compute stage, [`StageDescriptor::weight_shape`]).
    /// Same compute cost as trained weights — used by the benches, the
    /// CLI's `--synthetic` mode, and tests of topologies without trained
    /// artifacts.
    pub fn synthetic(net: &NetworkSpec, bits: u32, seed: u64) -> Result<Self> {
        let stages = net.stages()?;
        let mut g = rng::XorShift64::new(seed);
        let mut layers = Vec::new();
        for st in &stages {
            let Some((rows, cols)) = st.weight_shape() else { continue };
            let codes: Vec<Vec<u32>> = (0..rows)
                .map(|_| {
                    (0..cols)
                        .map(|_| {
                            let v = (g.next_u64() % 2000) as f64 / 1250.0 - 0.8;
                            quantize_bipolar(v, bits)
                        })
                        .collect()
                })
                .collect();
            layers.push(LayerWeights { codes, gamma: 0.2, mu: 1.0 });
        }
        Ok(QuantizedWeights { bits, layers })
    }
}

/// How a forward pass is executed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ForwardMode {
    /// Full bit-exact stochastic simulation with bitstream length k.
    Stochastic {
        /// Bitstream length in cycles.
        k: usize,
        /// Master seed for every SNG lane.
        seed: u32,
    },
    /// SC expectation model (no sampling noise) — matches the JAX model.
    Expectation,
    /// Expectation model + analytic k-cycle sampling noise — the paper's
    /// own Fig. 11/12 methodology ("the mathematical model of SC is
    /// encapsulated as a Python function" §V-B): the neuron value is the
    /// expectation perturbed by the binomial noise of a k-cycle stream.
    NoisyExpectation {
        /// Modeled bitstream length.
        k: usize,
        /// Noise seed.
        seed: u32,
    },
    /// Plain fixed-point MAC + hard ReLU (the Fig. 12 baseline).
    FixedPoint,
}

impl ForwardMode {
    /// The bitstream length this mode models (`None` for the analytic
    /// modes that own no `k`).
    pub fn k(&self) -> Option<usize> {
        match *self {
            ForwardMode::Stochastic { k, .. } | ForwardMode::NoisyExpectation { k, .. } => {
                Some(k)
            }
            ForwardMode::Expectation | ForwardMode::FixedPoint => None,
        }
    }

    /// True when the mode's arithmetic depends on `k` — the modes a
    /// [`PrecisionPlan`] applies to.
    pub fn uses_k(&self) -> bool {
        self.k().is_some()
    }

    /// This mode with its `k` replaced by one stage's planned length (the
    /// analytic modes pass through unchanged) — how
    /// [`ForwardPlan::compile_with_precision`] specializes the shared mode
    /// per compute stage.
    pub fn with_stage_k(self, k: usize) -> Self {
        match self {
            ForwardMode::Stochastic { seed, .. } => ForwardMode::Stochastic { k, seed },
            ForwardMode::NoisyExpectation { seed, .. } => {
                ForwardMode::NoisyExpectation { k, seed }
            }
            other => other,
        }
    }
}

/// Which SC compute kernel a stochastic compute stage lowers to at
/// [`ForwardPlan::compile_with_opts`] time. All three paths (including the
/// per-bit [`reference`]) are **bit-exact** with each other — they share
/// the same SNG generation keys, gather tables, and B2S randoms — so the
/// choice is purely a speed/falsifiability knob (property-tested in
/// `tests/stage_ir.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelPath {
    /// Resolve per stage: currently the bit-plane transposed kernel for
    /// every stochastic compute stage (the fastest path). The default.
    #[default]
    Auto,
    /// The lane-at-a-time fused kernel: one
    /// [`VerticalCounter::add_xnor_words`] pass per fan-in lane, then the
    /// fused B2S/ReLU/S2B popcount. Kept selectable as the speedup
    /// baseline and as a mid-point between `Transposed` and [`reference`].
    Fused,
    /// The 64-lane bit-plane transposed kernel: weight streams are
    /// re-packed at compile into cycle-major planes
    /// ([`crate::sc::bitplane`]), activations are transposed in L1-sized
    /// tiles per gather window, and one XNOR+`count_ones` word covers 64
    /// fan-in lanes at once.
    Transposed,
}

impl KernelPath {
    /// Stable label, folded into the engine's compiled-artifact
    /// fingerprint and printed by the benches.
    pub fn label(&self) -> &'static str {
        match self {
            KernelPath::Auto => "auto",
            KernelPath::Fused => "fused",
            KernelPath::Transposed => "transposed",
        }
    }

    /// The concrete kernel `Auto` resolves to for **dense** stochastic
    /// compute stages (`Fused`/`Transposed` pass through). For dense
    /// plans `Auto` and its resolution compile to the same artifact and
    /// share one cache entry; under an active [`SparsityPolicy`] `Auto`
    /// additionally resolves per stage by pruning structure (see
    /// [`ForwardPlan::compile_with_sparsity`]), so sparse fingerprints
    /// key on the unresolved label instead.
    pub fn resolved(self) -> KernelPath {
        match self {
            KernelPath::Auto => KernelPath::Transposed,
            other => other,
        }
    }
}

/// Compile-time weight-sparsity policy of a [`ForwardPlan`]: prune weight
/// lanes whose dequantized bipolar magnitude is **strictly below**
/// `threshold` out of the datapath. The quantized zero code dequantizes
/// to exactly 0.0 (its XNOR product stream carries probability 0.5 — pure
/// noise with zero expected contribution), so any positive threshold
/// prunes it; `threshold == 0.0` disables pruning entirely and compiles
/// today's dense plans bit-for-bit (the back-compat anchor, property-
/// tested in `tests/stage_ir.rs`).
///
/// Pruning semantics (shared bit-exactly by the fused kernel, the
/// transposed kernel, and the per-bit [`reference`]):
///
/// - Each output channel keeps a compact skip list of **surviving**
///   original lane indices; SNG streams are generated (and stored) only
///   for survivors, keyed by their original lane index.
/// - The APC width, the B2S rescale `2^m`, and the correlated-OR ReLU
///   floor derive from the channel's **surviving** fan-in: the pruned
///   lanes' 0.5-expectation (+1 count bias each, in expectation) and the
///   matching `-1` term of the `sp = (v+1)·2^m − n` recovery cancel, so
///   dropping a lane folds its bias out of the stage in one move.
/// - A stuck-at APC lane on a *pruned* lane is compiled away with the
///   lane (the column no longer exists); stuck faults on surviving lanes
///   inject exactly as before, addressed by original lane index.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SparsityPolicy {
    /// Magnitude floor: lanes with `|w| < threshold` are pruned.
    /// `0.0` = off.
    pub threshold: f64,
}

impl Default for SparsityPolicy {
    fn default() -> Self {
        SparsityPolicy::OFF
    }
}

impl SparsityPolicy {
    /// The disabled policy: nothing is pruned, plans compile dense.
    pub const OFF: SparsityPolicy = SparsityPolicy { threshold: 0.0 };

    /// Prune lanes with `|dequantized weight| < threshold`.
    pub fn threshold(threshold: f64) -> Self {
        SparsityPolicy { threshold }
    }

    /// True when the policy prunes nothing (threshold 0.0).
    pub fn is_off(&self) -> bool {
        self.threshold == 0.0
    }

    /// Whether a quantized weight code is pruned under this policy.
    pub fn prunes(&self, code: u32, bits: u32) -> bool {
        self.threshold > 0.0 && dequantize_bipolar(code, bits).abs() < self.threshold
    }

    /// Validate the threshold range: it must be finite, non-negative, and
    /// below 1.0 (a threshold of 1.0 or more prunes every representable
    /// weight). Degenerate values are typed errors at the engine
    /// boundary (`EngineError::InvalidSparsity`).
    pub fn validate(&self) -> std::result::Result<(), String> {
        if !self.threshold.is_finite() {
            return Err(format!("sparsity threshold must be finite, got {}", self.threshold));
        }
        if self.threshold < 0.0 {
            return Err(format!("sparsity threshold must be >= 0.0, got {}", self.threshold));
        }
        if self.threshold >= 1.0 {
            return Err(format!(
                "sparsity threshold must be < 1.0 (1.0 prunes every weight), got {}",
                self.threshold
            ));
        }
        Ok(())
    }
}

/// Per-compute-layer pruning summary of a [`SparsityPolicy`] over a
/// weight tensor — the shared input of the analyzer's sparsity lints
/// (SC011/SC012), the engine's density-aware energy model, and the
/// degenerate-threshold validation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PruneStat {
    /// Dense fan-in (lanes per output channel).
    pub fan_in: usize,
    /// Total weight lanes across output channels.
    pub lanes: usize,
    /// Lanes pruned across output channels.
    pub pruned: usize,
    /// Smallest surviving fan-in over the layer's output channels.
    pub min_fan_in: usize,
}

impl PruneStat {
    /// Surviving-lane fraction in (0, 1].
    pub fn density(&self) -> f64 {
        if self.lanes == 0 {
            1.0
        } else {
            (self.lanes - self.pruned) as f64 / self.lanes as f64
        }
    }
}

/// Pruning statistics per compute layer for a policy over quantized
/// weights (no streams are generated — pure code inspection).
pub fn prune_stats(weights: &QuantizedWeights, sparsity: SparsityPolicy) -> Vec<PruneStat> {
    weights
        .layers
        .iter()
        .map(|lw| {
            let fan_in = lw.codes.first().map_or(0, |row| row.len());
            let mut lanes = 0usize;
            let mut pruned = 0usize;
            let mut min_fan_in = fan_in;
            for row in &lw.codes {
                lanes += row.len();
                let cut = row.iter().filter(|&&c| sparsity.prunes(c, weights.bits)).count();
                pruned += cut;
                min_fan_in = min_fan_in.min(row.len() - cut);
            }
            PruneStat { fan_in, lanes, pruned, min_fan_in }
        })
        .collect()
}

/// Per-compute-layer surviving weight-lane density under a policy
/// (all 1.0 when the policy is off) — the `weight_density` input of
/// `accel::pipeline` / `accel::system`'s sparsity-aware cost model.
pub fn weight_densities(weights: &QuantizedWeights, sparsity: SparsityPolicy) -> Vec<f64> {
    prune_stats(weights, sparsity).iter().map(|s| s.density()).collect()
}

/// Bit-reverse the low `bits` bits of `t` (van der Corput sequence) —
/// in hardware: a counter with reversed output wiring.
fn bit_reverse(t: u32, bits: u32) -> u32 {
    t.reverse_bits() >> (32 - bits)
}

/// B2S comparison randoms, uniform over 2^(m+1), shared across a layer's
/// neurons (the ReLU/MaxPool correlation of Fig. 2): a van der Corput
/// (bit-reversed counter) sequence over the comparison domain —
/// balanced/stratified for ANY bitstream length, deterministic. An LFSR
/// here is a trap: its 2^w − 1 period never divides k, so wide layers
/// (m+1 = 9..11) sample half a period and inherit a large threshold skew.
fn layer_r4(n: usize, k: usize, seed: u32) -> Vec<u32> {
    let m1 = neuron::m_bits(n) + 1;
    let offset = seed % (1u32 << m1);
    (0..k as u32)
        .map(|t| bit_reverse(t.wrapping_add(offset) & ((1 << m1) - 1), m1))
        .collect()
}

/// One operand lane's comparator-PCC stream from an *ideal* per-lane
/// random source, written word-at-a-time into `out` (64 xorshift steps and
/// packed comparisons per word instead of a per-bit closure + `set`).
///
/// Faithfulness note (DESIGN.md §Substitutions): the paper's accuracy
/// experiments run a mathematical SC model inside PyTorch — not a
/// gate-exact netlist replay — so per-lane ideal randomness is the same
/// abstraction level. Physically it corresponds to per-PCC decorrelated
/// RNS (shuffled LFSR networks, or the MTJ true-random sources of [14]);
/// naive sharing of one m-sequence across lanes correlates the XNOR
/// products and biases every neuron (tested in `sng`/`network` tests).
/// Bit-compatible with [`reference::lane_stream`].
fn lane_stream_words(code: u32, bits: u32, k: usize, base: u32, lane: u64, out: &mut [u64]) {
    debug_assert_eq!(out.len(), k.div_ceil(64));
    let mut state = rng::lane_state(base as u64, lane);
    let mask = (1u32 << bits) - 1;
    for (w, slot) in out.iter_mut().enumerate() {
        let n = (k - w * 64).min(64);
        let mut word = 0u64;
        for i in 0..n {
            state = rng::xorshift64_step(state);
            word |= ((code > ((state as u32) & mask)) as u64) << i;
        }
        *slot = word;
    }
}

/// Mix the neuron site indices into a noise counter.
fn noise_ctr(oc: usize, idx: usize) -> u32 {
    (oc as u32).wrapping_mul(0x0101_0101).wrapping_add(idx as u32)
}

/// Layer boundary: sp -> next activation (or logits when `final_layer`).
fn reencode(sp: f64, gamma: f64, mu: f64, final_layer: bool) -> f64 {
    let y = gamma * (sp - mu);
    if final_layer {
        y
    } else {
        y.clamp(0.0, 1.0)
    }
}

/// One compiled, executable stage of a [`ForwardPlan`] — the object-safe
/// face of the stage IR. [`ForwardPlan::compile`] lowers every
/// [`StageDescriptor`] into one implementation: the compute stage
/// (conv / strided / depthwise / dense, fused stochastic or analytic) or
/// a value-domain stage (max/avg/global pooling, the SC scaled-add
/// residual).
///
/// Contract: [`LayerStage::run`] reads the current activation from
/// `scr.act` and leaves its output in `scr.act` (using `scr.out` as the
/// double buffer); saved residual branches live in `scr.saved` under the
/// producing layer's index.
pub trait LayerStage: Send + Sync {
    /// Source layer index in the [`NetworkSpec`].
    fn index(&self) -> usize;

    /// Stage label (see [`StageDescriptor::label`]); reported by
    /// [`ForwardPlan::run_with_timings`].
    fn label(&self) -> &'static str;

    /// Keep this stage's output alive for a later residual merge.
    fn save_output(&self) -> bool;

    /// Execute the stage on the scratch arena with the given worker cap
    /// (0 = every core). Bit-identical output for any cap.
    fn run(&self, scr: &mut Scratch, threads: usize);

    /// Static per-image op accounting `(executed, skipped)` in SC
    /// lane-cycle products (MACs for the analytic modes): the work the
    /// compiled stage performs vs. the work the sparsity policy pruned
    /// out at compile. Value stages (pooling/residual) report `(0, 0)`.
    /// Runtime activation-sparsity skips are *not* included — they are
    /// surfaced per run through [`ForwardPlan::run_with_timings`].
    fn ops(&self) -> (u64, u64) {
        (0, 0)
    }

    /// `(weight-layer index, surviving weight-lane density)` for compute
    /// stages, `None` for value stages — the compiled counterpart of
    /// [`weight_densities`].
    fn weight_density(&self) -> Option<(usize, f64)> {
        None
    }
}

/// The identity shared by every [`LayerStage`] implementation.
struct StageMeta {
    index: usize,
    label: &'static str,
    save_output: bool,
}

impl StageMeta {
    fn of(st: &StageDescriptor) -> Self {
        StageMeta { index: st.index, label: st.label(), save_output: st.save_output }
    }
}

/// Expands the three metadata getters of [`LayerStage`] from the embedded
/// [`StageMeta`] (the `run` body stays per-implementation).
macro_rules! stage_meta_getters {
    () => {
        fn index(&self) -> usize {
            self.meta.index
        }
        fn label(&self) -> &'static str {
            self.meta.label
        }
        fn save_output(&self) -> bool {
            self.meta.save_output
        }
    };
}

/// Max pool over recovered values.
struct MaxPoolStage {
    meta: StageMeta,
    size: usize,
    in_shape: Shape,
}

impl LayerStage for MaxPoolStage {
    stage_meta_getters!();

    fn run(&self, scr: &mut Scratch, _threads: usize) {
        let (act, out) = (&scr.act, &mut scr.out);
        stage::max_pool_into(act, self.in_shape, self.size, out);
        std::mem::swap(&mut scr.act, &mut scr.out);
    }
}

/// Average pool (SC counter-based scaled add) over recovered values.
struct AvgPoolStage {
    meta: StageMeta,
    size: usize,
    in_shape: Shape,
}

impl LayerStage for AvgPoolStage {
    stage_meta_getters!();

    fn run(&self, scr: &mut Scratch, _threads: usize) {
        let (act, out) = (&scr.act, &mut scr.out);
        stage::avg_pool_into(act, self.in_shape, self.size, out);
        std::mem::swap(&mut scr.act, &mut scr.out);
    }
}

/// Spatial mean per channel.
struct GlobalAvgPoolStage {
    meta: StageMeta,
    in_shape: Shape,
}

impl LayerStage for GlobalAvgPoolStage {
    stage_meta_getters!();

    fn run(&self, scr: &mut Scratch, _threads: usize) {
        let (act, out) = (&scr.act, &mut scr.out);
        stage::global_avg_pool_into(act, self.in_shape, out);
        std::mem::swap(&mut scr.act, &mut scr.out);
    }
}

/// SC scaled-add residual merge with the saved output of layer `from`.
struct AddStage {
    meta: StageMeta,
    from: usize,
}

impl LayerStage for AddStage {
    stage_meta_getters!();

    fn run(&self, scr: &mut Scratch, _threads: usize) {
        let Scratch { act, out, saved, .. } = scr;
        stage::scaled_add_into(act, &saved[self.from], out);
        std::mem::swap(&mut scr.act, &mut scr.out);
    }
}

/// Image-independent state of one compute layer.
struct LayerPlan {
    /// Compute-layer index (into `QuantizedWeights::layers`).
    wl: usize,
    out_ch: usize,
    fan_in: usize,
    /// The stage's gather table — the *same* structure (and indexing
    /// implementation) the per-bit reference reads, so the two datapaths
    /// cannot diverge on geometry.
    gather: GatherTable,
    /// Activation sites feeding this layer (c·h·w of the input shape).
    in_sites: usize,
    relu: bool,
    final_layer: bool,
    gamma: f64,
    mu: f64,
    /// 2^m for this fan-in (the SC scaled-add divisor).
    scale: f64,
    /// Compiled B2S/ReLU comparison floor: `fan_in` when the stage applies
    /// the correlated-OR ReLU, 0 otherwise. Hoisted out of the per-image
    /// kernels — one `max(2c, floor) > r4` per cycle is all that remains.
    floor: u32,
    // --- stochastic-mode constants (empty in analytic modes) ---
    /// Lane seed base for this layer.
    base: u32,
    /// Shared B2S comparison randoms.
    r4: Vec<u32>,
    /// All weight SNG streams, packed `[(oc·fan_in + j)·words ..][..words]`.
    wgt_words: Vec<u64>,
    /// Zero-code padding SNG streams, `[j·words..][..words]` (empty when no
    /// window needs padding).
    pad_words: Vec<u64>,
    // --- analytic-mode constants (empty in stochastic mode) ---
    /// Dequantized weights, `[oc·fan_in + j]`.
    wq: Vec<f64>,
    /// Dequantized zero code (padding value).
    zq: f64,
    /// Weight-sparsity skip lists (`None` = dense: the policy is off or
    /// no lane of this layer fell below the threshold, and the compiled
    /// artifact is bit-for-bit the dense plan). When `Some`, the
    /// stochastic `wgt_words` hold only surviving lanes, packed
    /// `[(pruned.off[oc] + sj)·words ..]`.
    pruned: Option<PrunedLayer>,
}

/// Compile-time pruning state of one compute layer under an active
/// [`SparsityPolicy`]: the per-channel skip lists plus every constant the
/// B2S/ReLU/S2B recovery derives from the **surviving** fan-in. Pruning a
/// lane folds its bias out in one move: the lane's 0.5-probability XNOR
/// stream adds `k/2` expected counts and the recovery `sp = (v+1)·2^m − n`
/// subtracts 1 per lane — dropping both sides together keeps the
/// expectation and lets `m`, the ReLU floor, and the comparison randoms
/// shrink to the surviving width.
struct PrunedLayer {
    /// Per output channel: surviving original lane indices, ascending.
    /// Original indices key the SNG streams, the gather-window lookups,
    /// and the fault addressing, so all three kernels and the per-bit
    /// reference inject and gather identically.
    surv: Vec<Vec<u32>>,
    /// Packed-stream offsets, in lanes: survivor `sj` of channel `oc`
    /// owns `wgt_words[(off[oc] as usize + sj)·words ..][..words]`.
    off: Vec<u32>,
    /// Total surviving lanes across channels.
    lanes: usize,
    /// Per-channel `2^m` of the surviving fan-in (B2S rescale).
    scale: Vec<f64>,
    /// Per-channel B2S/ReLU comparison floor: the surviving fan-in when
    /// the stage applies the correlated-OR ReLU, 0 otherwise.
    floor: Vec<u32>,
    /// Per-channel index into `r4` (stochastic mode only).
    r4_of: Vec<u32>,
    /// Deduplicated B2S comparison sequences: [`layer_r4`] depends on the
    /// fan-in only through `m_bits`, so channels of equal surviving width
    /// share one sequence (stochastic mode only).
    r4: Vec<Vec<u32>>,
    /// Every channel survives the same lane set (channel-structured
    /// sparsity) — the transposed kernel keeps its shared-tile fast path
    /// exactly when this holds.
    shared: bool,
}

/// Compute one layer's pruning state: `Ok(None)` when the policy prunes
/// nothing here (the dense fallback), a typed error when a channel loses
/// every lane. `r4`/`r4_of` are derived only in stochastic mode
/// (`stream = Some((k, base))`).
fn prune_layer(
    st: &StageDescriptor,
    lw: &LayerWeights,
    bits: u32,
    sparsity: SparsityPolicy,
    stream: Option<(usize, u32)>,
) -> Result<Option<PrunedLayer>> {
    if sparsity.is_off() {
        return Ok(None);
    }
    let mut surv: Vec<Vec<u32>> = Vec::with_capacity(lw.codes.len());
    let mut any = false;
    for (oc, row) in lw.codes.iter().enumerate() {
        let keep: Vec<u32> = row
            .iter()
            .enumerate()
            .filter(|&(_, &c)| !sparsity.prunes(c, bits))
            .map(|(j, _)| j as u32)
            .collect();
        if keep.is_empty() {
            bail!(
                "layer {} ({}): sparsity threshold {} prunes output channel {oc} to fan-in 0",
                st.index,
                st.label(),
                sparsity.threshold
            );
        }
        any |= keep.len() < row.len();
        surv.push(keep);
    }
    if !any {
        return Ok(None);
    }
    let shared = surv.windows(2).all(|w| w[0] == w[1]);
    let relu = st.relu;
    let n_ch = surv.len();
    let (mut off, mut scale, mut floor, mut r4_of) = (
        Vec::with_capacity(n_ch),
        Vec::with_capacity(n_ch),
        Vec::with_capacity(n_ch),
        Vec::with_capacity(n_ch),
    );
    let mut lanes = 0usize;
    let mut r4: Vec<Vec<u32>> = Vec::new();
    let mut r4_m: Vec<u32> = Vec::new();
    for s in &surv {
        let n = s.len();
        off.push(lanes as u32);
        lanes += n;
        scale.push((1u64 << neuron::m_bits(n)) as f64);
        floor.push(if relu { n as u32 } else { 0 });
        if let Some((k, base)) = stream {
            let m = neuron::m_bits(n);
            let idx = match r4_m.iter().position(|&x| x == m) {
                Some(i) => i,
                None => {
                    r4_m.push(m);
                    r4.push(layer_r4(n, k, base));
                    r4.len() - 1
                }
            };
            r4_of.push(idx as u32);
        }
    }
    Ok(Some(PrunedLayer { surv, off, lanes, scale, floor, r4_of, r4, shared }))
}

/// Compile-time state of the bit-plane transposed kernel
/// ([`KernelPath::Transposed`]): the weight SNG streams re-packed
/// cycle-major so one `u64` word holds 64 fan-in lanes of one cycle.
///
/// Layout: `wgt_tr[((oc·k_words + cw)·64 + t)·lane_blocks + b]` bit `l` is
/// weight lane `b·64 + l`'s XNOR operand bit at cycle `cw·64 + t`. Tail
/// lanes (`≥ fan_in`) carry all-ones weight bits and the runtime tile
/// carries all-zero activation bits there, so XNOR yields 0 and no lane
/// mask is needed in the hot loop. Stuck-at APC lanes are resolved here
/// too: the lane's weight bits become the stuck constant and the runtime
/// tile feeds all-ones (XNOR identity), reproducing the fused path's
/// constant-stream accumulate bit-for-bit.
struct TransposedPlan {
    /// Fan-in lane blocks of 64: the largest surviving per-channel
    /// fan-in (the dense fan-in when unpruned), `div_ceil(64)`.
    lane_blocks: usize,
    /// Transposed weight planes (see layout above).
    wgt_tr: Vec<u64>,
    /// Per-**original**-lane stuck-at flags (`stuck[j]` = lane j is
    /// dead); empty when the fault plan pins no lane of this layer.
    stuck: Vec<bool>,
    /// Closed-form all-zero-tile cycle counts,
    /// `zero_ones[(oc·k_words + cw)·64 + t]` = the XNOR popcount of an
    /// all-zero activation tile against channel `oc`'s weight plane at
    /// cycle `cw·64 + t` (`XNOR(0, w) = !w`, and tail lanes carry
    /// all-ones weight bits so they contribute 0) — the runtime
    /// activation-sparsity short-circuit adds these instead of walking
    /// lane blocks.
    zero_ones: Vec<u32>,
}

impl TransposedPlan {
    /// Re-pack a stochastic [`LayerPlan`]'s lane-major weight words into
    /// transposed bit planes, one 64×64 [`bitplane::transpose64`] tile at
    /// a time. Pure layout: the stream bits (keys, faults, padding) are
    /// exactly the ones the fused path would read. Under a pruned layer,
    /// plane lane `sj` is the channel's `sj`-th *surviving* lane and the
    /// tail (from the surviving fan-in up) pads with XNOR identities —
    /// the same re-pack PR 8 applies at the dense fan-in.
    fn build(lp: &LayerPlan, words: usize, faults: Option<&FaultPlan>) -> Self {
        let fan_in = lp.fan_in;
        let pruned = lp.pruned.as_ref();
        let max_fan = match pruned {
            Some(p) => p.surv.iter().map(Vec::len).max().unwrap_or(0),
            None => fan_in,
        };
        let lane_blocks = max_fan.div_ceil(bitplane::LANES);
        let stuck: Vec<bool> = match faults {
            Some(f) if !f.stuck_lanes.is_empty() => {
                let v: Vec<bool> = (0..fan_in).map(|j| f.stuck(lp.wl, j).is_some()).collect();
                if v.iter().any(|&s| s) {
                    v
                } else {
                    Vec::new()
                }
            }
            _ => Vec::new(),
        };
        let mut wgt_tr = vec![0u64; lp.out_ch * words * bitplane::LANES * lane_blocks];
        let mut zero_ones = vec![0u32; lp.out_ch * words * bitplane::LANES];
        let mut cols = [0u64; bitplane::LANES];
        for oc in 0..lp.out_ch {
            let surv = pruned.map(|p| p.surv[oc].as_slice());
            let n_oc = surv.map_or(fan_in, <[u32]>::len);
            let lane0 = pruned.map_or(oc * fan_in, |p| p.off[oc] as usize);
            for b in 0..lane_blocks {
                for cw in 0..words {
                    for (l, col) in cols.iter_mut().enumerate() {
                        let sj = b * bitplane::LANES + l;
                        *col = if sj >= n_oc {
                            // Tail lane: all-ones vs the tile's all-zeros.
                            !0u64
                        } else {
                            let j = surv.map_or(sj, |s| s[sj] as usize);
                            if let Some(v) = faults.and_then(|f| f.stuck(lp.wl, j)) {
                                // Stuck lane: the constant vs the tile's
                                // all-ones (XNOR identity).
                                if v {
                                    !0u64
                                } else {
                                    0u64
                                }
                            } else {
                                lp.wgt_words[(lane0 + sj) * words + cw]
                            }
                        };
                    }
                    bitplane::transpose64(&mut cols);
                    let dst = (oc * words + cw) * bitplane::LANES * lane_blocks + b;
                    for (t, &row) in cols.iter().enumerate() {
                        wgt_tr[dst + t * lane_blocks] = row;
                    }
                }
            }
            // The channel's zero-tile counts fall out of the finished
            // planes (stuck lanes never see a zero tile: their tile bits
            // are forced to all-ones, so the short-circuit cannot fire).
            for cw in 0..words {
                let plane = (oc * words + cw) * bitplane::LANES * lane_blocks;
                for t in 0..bitplane::LANES {
                    let row = &wgt_tr[plane + t * lane_blocks..][..lane_blocks];
                    zero_ones[(oc * words + cw) * bitplane::LANES + t] =
                        bitplane::zero_xnor_count(row);
                }
            }
        }
        TransposedPlan { lane_blocks, wgt_tr, stuck, zero_ones }
    }
}

/// Reusable per-image scratch arena: all buffers grow to the largest layer
/// once and are reused across layers and calls — the engine's steady state
/// allocates nothing per neuron.
#[derive(Default)]
pub struct Scratch {
    act: Vec<f64>,
    out: Vec<f64>,
    acodes: Vec<u32>,
    aq: Vec<f64>,
    act_words: Vec<u64>,
    /// Saved step outputs feeding later residual merges, by layer index.
    saved: Vec<Vec<f64>>,
    vc: VerticalCounter,
    /// Transposed-kernel tile buffers, reused across stages and images.
    tr: TrScratch,
    /// Window-major staging of the transposed kernel's outputs before the
    /// scatter back to the engine's channel-major layout.
    tr_out: Vec<f64>,
    /// `(executed, skipped)` op counts of the stage that ran last —
    /// seeded with the stage's static accounting by the step loop, then
    /// adjusted by the transposed kernel's runtime zero-tile skips.
    stage_ops: (u64, u64),
}

/// Worker-local scratch of the bit-plane transposed kernel: the activation
/// tile for one (window, cycle-word) pair, the 64×64 transpose staging
/// block, and the per-neuron S2B accumulators for the window's output
/// channels. Grown once per stage shape ([`TrScratch::reconfigure`]
/// reuses the allocations, like [`VerticalCounter::reconfigure`]).
struct TrScratch {
    /// Activation tile: 64 cycles × `lane_blocks` words, cycle-major.
    tile: Vec<u64>,
    /// Transpose staging: one 64-lane × 64-cycle bit block.
    cols: [u64; bitplane::LANES],
    /// Per-output-channel S2B `ones` accumulators.
    ones: Vec<u32>,
}

impl Default for TrScratch {
    fn default() -> Self {
        TrScratch { tile: Vec::new(), cols: [0; bitplane::LANES], ones: Vec::new() }
    }
}

impl TrScratch {
    /// Size the buffers for a stage (keeps capacity across calls).
    fn reconfigure(&mut self, lane_blocks: usize, out_ch: usize) {
        self.tile.clear();
        self.tile.resize(bitplane::LANES * lane_blocks, 0);
        self.ones.clear();
        self.ones.resize(out_ch, 0);
    }
}

/// One step's share of an inference — see
/// [`ForwardPlan::run_with_timings`]: wall-clock plus the stage's op
/// accounting, so the `BENCH_layers.json` sw-vs-hw comparison separates
/// executed work from sparsity-skipped work instead of crediting skipped
/// lanes as throughput.
#[derive(Debug, Clone, Copy)]
pub struct StepTiming {
    /// Source layer index.
    pub layer: usize,
    /// Stage label (see [`StageDescriptor::label`]).
    pub label: &'static str,
    /// Wall-clock duration of the step.
    pub elapsed: std::time::Duration,
    /// SC lane-cycle products (MACs in the analytic modes) the stage
    /// executed this run.
    pub ops_executed: u64,
    /// Lane-cycle products skipped this run: compile-time pruned weight
    /// lanes plus all-zero activation tiles short-circuited at runtime
    /// by the transposed kernel. Value stages report 0/0.
    pub ops_skipped: u64,
}

/// A compiled forward pass: the [`crate::accel::stage`] IR of a
/// [`NetworkSpec`] + [`QuantizedWeights`] + [`ForwardMode`] lowered into
/// per-layer [`LayerStage`] objects — gather tables, random sequences,
/// and pre-generated weight streams for compute stages; value kernels for
/// pooling/residual stages. Build once, run many — an engine session
/// keeps one plan for its whole lifetime.
pub struct ForwardPlan {
    /// Expected input length (c·h·w of the network input).
    in_len: usize,
    /// Output length (classes).
    out_len: usize,
    /// Per-compute-stage bitstream lengths this plan was compiled with
    /// (a uniform placeholder for the analytic modes that own no `k`).
    precision: PrecisionPlan,
    steps: Vec<Box<dyn LayerStage>>,
}

impl ForwardPlan {
    /// Compile a plan for the given network, weights, and mode, with a
    /// **uniform** precision taken from the mode's own `k`. Malformed
    /// networks (see [`NetworkSpec::validate`]), mismatched weight
    /// tensors, and degenerate bitstream lengths (`k == 0`, non-multiples
    /// of [`precision::WORD`]) are typed errors, surfaced by
    /// `Engine::open` / the CLI.
    pub fn compile(
        net: &NetworkSpec,
        weights: &QuantizedWeights,
        mode: ForwardMode,
    ) -> Result<Self> {
        let plan = PrecisionPlan::uniform(mode.k().unwrap_or(precision::WORD), net.n_compute());
        Self::compile_with_precision(net, weights, mode, &plan)
    }

    /// [`ForwardPlan::compile`] with a per-compute-stage [`PrecisionPlan`]:
    /// each compute stage generates, accumulates, and recovers streams of
    /// its **own** planned length (the mode's `k` is a placeholder for the
    /// k-sensitive modes — the plan wins per stage). Adjacent stages with
    /// different `k` rescale through the S2B→B2S value boundary every
    /// stage already owns; the fused engine and the per-bit reference stay
    /// bit-identical under any valid plan (property-tested in
    /// `tests/stage_ir.rs`). The plan is validated against the network —
    /// wrong length, `k == 0`, or [`precision::WORD`]-misaligned stages
    /// are typed errors.
    pub fn compile_with_precision(
        net: &NetworkSpec,
        weights: &QuantizedWeights,
        mode: ForwardMode,
        precision: &PrecisionPlan,
    ) -> Result<Self> {
        Self::compile_with_precision_faults(net, weights, mode, precision, None)
    }

    /// [`ForwardPlan::compile_with_precision`] with an optional
    /// [`FaultPlan`] compiled into the datapath: SRAM weight upsets are
    /// applied to the stored codes before lowering (all modes), and the
    /// stochastic stages inject stream bit flips, stuck-at APC lanes, and
    /// SNG correlation faults exactly as described on [`FaultPlan`]. The
    /// analytic (expectation / fixed-point) stages map the same
    /// `bit_flip_rate` onto the quantized activation-code bits — the
    /// binary side of the robustness comparison. The fused engine and the
    /// per-bit reference ([`reference::forward_stochastic_plan_faulted`])
    /// stay **bit-exact** under any identical fault plan, because every
    /// injected fault is a pure function of the plan seed and the stream's
    /// own generation key.
    pub fn compile_with_precision_faults(
        net: &NetworkSpec,
        weights: &QuantizedWeights,
        mode: ForwardMode,
        precision: &PrecisionPlan,
        faults: Option<&FaultPlan>,
    ) -> Result<Self> {
        Self::compile_with_opts(net, weights, mode, precision, faults, KernelPath::default())
    }

    /// [`ForwardPlan::compile_with_precision_faults`] plus an explicit
    /// [`KernelPath`] selecting which stochastic compute kernel each stage
    /// lowers to. `Auto` (the default everywhere else) resolves to the
    /// bit-plane transposed kernel; `Fused` keeps the lane-at-a-time
    /// kernel as a baseline. The choice never changes outputs — all paths
    /// are bit-exact — only the compiled layout and speed. Compiles
    /// dense (no sparsity policy).
    pub fn compile_with_opts(
        net: &NetworkSpec,
        weights: &QuantizedWeights,
        mode: ForwardMode,
        precision: &PrecisionPlan,
        faults: Option<&FaultPlan>,
        kernel: KernelPath,
    ) -> Result<Self> {
        Self::compile_with_sparsity(
            net,
            weights,
            mode,
            precision,
            faults,
            kernel,
            SparsityPolicy::OFF,
        )
    }

    /// The full compile entry point: [`ForwardPlan::compile_with_opts`]
    /// plus a [`SparsityPolicy`] compiled into every stage. Weight lanes
    /// below the policy threshold are pruned out of the gather walks into
    /// per-channel skip lists (see [`SparsityPolicy`] for the exact
    /// semantics and the bias-folding math); `SparsityPolicy::OFF`
    /// reproduces the dense artifact bit-for-bit.
    ///
    /// Kernel interaction: pinned `Fused`/`Transposed` paths are honored
    /// (both pruned implementations are bit-exact). `Auto` resolves per
    /// stage — channel-structured pruning (every channel survives the
    /// same lane set) keeps the transposed kernel's shared activation
    /// tile, while unstructured pruning on a shared-window stage routes
    /// to the fused skip-list kernel, because re-tiling the activation
    /// transpose per output channel costs more than the pruned XNOR pass
    /// saves. Degenerate policies (non-finite/negative/≥1.0 thresholds,
    /// or a threshold that prunes some channel to fan-in 0) are typed
    /// errors.
    #[allow(clippy::too_many_arguments)]
    pub fn compile_with_sparsity(
        net: &NetworkSpec,
        weights: &QuantizedWeights,
        mode: ForwardMode,
        precision: &PrecisionPlan,
        faults: Option<&FaultPlan>,
        kernel: KernelPath,
        sparsity: SparsityPolicy,
    ) -> Result<Self> {
        sparsity
            .validate()
            .map_err(|e| anyhow::anyhow!("network {:?}: {e}", net.name))?;
        // Storage faults strike before any datapath runs: corrupt the
        // weight SRAM once, then lower the corrupted tensor normally.
        let corrupted;
        let weights = match faults {
            Some(f) if f.sram_upset_rate > 0.0 => {
                corrupted = f.corrupt_weights(weights);
                &corrupted
            }
            _ => weights,
        };
        let stages = net.stages()?;
        // Site-addressed faults must land inside the compiled plan: a
        // stuck lane aimed at a nonexistent layer/lane would silently
        // never fire, and a fault campaign "surviving" it proves nothing.
        // (This check runs before the is_noop filter — a plan carrying
        // only out-of-bounds sites is exactly the mistake it catches.)
        if let Some(f) = faults {
            f.validate_sites(&stages)
                .map_err(|e| anyhow::anyhow!("network {:?}: {e}", net.name))?;
        }
        let faults: Option<Arc<FaultPlan>> =
            faults.filter(|f| !f.is_noop()).map(|f| Arc::new(f.clone()));
        let n_compute = stages.iter().filter(|s| s.is_compute()).count();
        if weights.layers.len() != n_compute {
            bail!(
                "network {:?} has {n_compute} compute layers but the weights carry {}",
                net.name,
                weights.layers.len()
            );
        }
        if mode.uses_k() {
            precision
                .validate_for(n_compute)
                .map_err(|e| anyhow::anyhow!("network {:?}: {e}", net.name))?;
        }
        let bits = weights.bits;
        let mut steps: Vec<Box<dyn LayerStage>> = Vec::with_capacity(stages.len());
        for st in &stages {
            let meta = StageMeta::of(st);
            let boxed: Box<dyn LayerStage> = match st.op {
                StageOp::Conv(_) | StageOp::Dense { .. } => {
                    let table = stage::gather(st).expect("compute stages have gather tables");
                    let wl = st.weight_layer.expect("compute stages carry a weight layer");
                    // Specialize the shared mode to this stage's planned k
                    // (no-op for the analytic modes).
                    let mode = if mode.uses_k() {
                        mode.with_stage_k(precision.k_for(wl))
                    } else {
                        mode
                    };
                    let (k, words) = match mode {
                        ForwardMode::Stochastic { k, .. } => (k, k.div_ceil(64)),
                        _ => (0, 0),
                    };
                    let mut lp = build_layer_plan(
                        weights,
                        st,
                        table,
                        mode,
                        faults.as_deref(),
                        sparsity,
                    )?;
                    // Per-stage kernel resolution (see
                    // `compile_with_sparsity`): Auto routes unstructured-
                    // pruned shared-window stages to the fused skip-list
                    // kernel; per-channel (depthwise) tables already
                    // re-tile per channel, so they stay transposed.
                    let resolved = match kernel {
                        KernelPath::Auto
                            if lp.pruned.as_ref().is_some_and(|p| !p.shared)
                                && !lp.gather.per_channel =>
                        {
                            KernelPath::Fused
                        }
                        other => other.resolved(),
                    };
                    let tr = match (mode, resolved) {
                        (ForwardMode::Stochastic { .. }, KernelPath::Transposed) => {
                            let tr = TransposedPlan::build(&lp, words, faults.as_deref());
                            // The transposed planes replace the lane-major
                            // weight copy — only the activation/padding
                            // gathers still read lane-major words.
                            lp.wgt_words = Vec::new();
                            Some(tr)
                        }
                        _ => None,
                    };
                    // Static op accounting: lane-cycle products in the
                    // stochastic mode, MACs in the analytic modes.
                    let cycles = if let ForwardMode::Stochastic { k, .. } = mode {
                        k as u64
                    } else {
                        1
                    };
                    let n_win = lp.gather.n_win as u64;
                    let ops = match &lp.pruned {
                        Some(p) => {
                            let exec = p.lanes as u64 * n_win * cycles;
                            let dense = (lp.out_ch * lp.fan_in) as u64 * n_win * cycles;
                            (exec, dense - exec)
                        }
                        None => ((lp.out_ch * lp.fan_in) as u64 * n_win * cycles, 0),
                    };
                    Box::new(ComputeStage {
                        meta,
                        lp,
                        mode,
                        k,
                        words,
                        bits,
                        faults: faults.clone(),
                        tr,
                        ops,
                    })
                }
                StageOp::MaxPool { size } => {
                    Box::new(MaxPoolStage { meta, size, in_shape: st.in_shape })
                }
                StageOp::AvgPool { size } => {
                    Box::new(AvgPoolStage { meta, size, in_shape: st.in_shape })
                }
                StageOp::GlobalAvgPool => {
                    Box::new(GlobalAvgPoolStage { meta, in_shape: st.in_shape })
                }
                StageOp::Add { from } => Box::new(AddStage { meta, from }),
            };
            steps.push(boxed);
        }
        let in_len = stages[0].in_len();
        let out_len = stages.last().expect("validated networks are non-empty").out_len();
        Ok(ForwardPlan { in_len, out_len, precision: precision.clone(), steps })
    }

    /// [`ForwardPlan::compile`], panicking on invalid input — for the
    /// built-in topologies and tests where the stack is known-good.
    pub fn new(net: &NetworkSpec, weights: &QuantizedWeights, mode: ForwardMode) -> Self {
        Self::compile(net, weights, mode)
            .unwrap_or_else(|e| panic!("ForwardPlan::new({}): {e:#}", net.name))
    }

    /// Output length (class count) of the compiled network.
    pub fn out_len(&self) -> usize {
        self.out_len
    }

    /// Expected input length (c·h·w).
    pub fn in_len(&self) -> usize {
        self.in_len
    }

    /// The per-compute-stage precision this plan was compiled with.
    ///
    /// Contract note: for the **analytic** modes (expectation /
    /// fixed-point), which own no `k`, the plan is a placeholder and the
    /// shared-plan cache deliberately keys without it — a cache-shared
    /// analytic plan reports whichever equivalent config compiled first.
    /// Read a session's own resolved plan via `Session::precision()`; this
    /// accessor is authoritative only for the k-sensitive modes.
    pub fn precision(&self) -> &PrecisionPlan {
        &self.precision
    }

    /// One inference with a fresh scratch arena, parallelized across
    /// neurons within each layer.
    pub fn run(&self, input: &[f64]) -> Vec<f64> {
        let mut scr = Scratch::default();
        self.run_with(input, &mut scr, true)
    }

    /// Compile a plan and run it once — the supported one-shot for
    /// tests/tools that genuinely want compile-plus-run per call. Repeated
    /// inference should build one plan (or open an `engine::Session`).
    pub fn once(
        net: &NetworkSpec,
        weights: &QuantizedWeights,
        input: &[f64],
        mode: ForwardMode,
    ) -> Vec<f64> {
        ForwardPlan::new(net, weights, mode).run(input)
    }

    /// Compile a plan and run a batch once (see [`ForwardPlan::once`]).
    pub fn once_batch(
        net: &NetworkSpec,
        weights: &QuantizedWeights,
        inputs: &[Vec<f64>],
        mode: ForwardMode,
    ) -> Vec<Vec<f64>> {
        ForwardPlan::new(net, weights, mode).run_batch(inputs)
    }

    /// One inference with a caller-owned scratch arena. `parallel` fans
    /// neuron chunks across cores (bit-identical output either way); pass
    /// `false` when the caller already parallelizes at a coarser grain.
    pub fn run_with(&self, input: &[f64], scr: &mut Scratch, parallel: bool) -> Vec<f64> {
        self.run_with_threads(input, scr, if parallel { 0 } else { 1 })
    }

    /// [`ForwardPlan::run_with`] with an explicit worker cap on the
    /// per-layer neuron parallelism: 0 = every core, 1 = serial, n = at
    /// most n threads (the engine's per-session thread knob). Output is
    /// bit-identical for any cap.
    pub fn run_with_threads(&self, input: &[f64], scr: &mut Scratch, threads: usize) -> Vec<f64> {
        self.run_inner(input, scr, threads, None)
    }

    /// [`ForwardPlan::run_with_threads`] that additionally appends one
    /// [`StepTiming`] record per executed step — layer index, stage
    /// label, wall-clock, and the executed/skipped op split — the
    /// per-layer software cost breakdown behind `BENCH_layers.json`.
    /// Output is bit-identical to the untimed paths.
    pub fn run_with_timings(
        &self,
        input: &[f64],
        scr: &mut Scratch,
        threads: usize,
        timings: &mut Vec<StepTiming>,
    ) -> Vec<f64> {
        self.run_inner(input, scr, threads, Some(timings))
    }

    fn run_inner(
        &self,
        input: &[f64],
        scr: &mut Scratch,
        threads: usize,
        mut timings: Option<&mut Vec<StepTiming>>,
    ) -> Vec<f64> {
        assert_eq!(input.len(), self.in_len, "input length mismatch");
        scr.act.clear();
        scr.act.extend_from_slice(input);
        if scr.saved.len() < self.steps.len() {
            scr.saved.resize_with(self.steps.len(), Vec::new);
        }
        for step in &self.steps {
            let t0 = timings.is_some().then(std::time::Instant::now);
            // Seed with the stage's static accounting; the transposed
            // kernel moves runtime zero-tile skips across the split.
            scr.stage_ops = step.ops();
            step.run(scr, threads);
            if step.save_output() {
                let Scratch { act, saved, .. } = scr;
                saved[step.index()].clear();
                saved[step.index()].extend_from_slice(act);
            }
            if let (Some(ts), Some(t0)) = (timings.as_mut(), t0) {
                ts.push(StepTiming {
                    layer: step.index(),
                    label: step.label(),
                    elapsed: t0.elapsed(),
                    ops_executed: scr.stage_ops.0,
                    ops_skipped: scr.stage_ops.1,
                });
            }
        }
        scr.act.clone()
    }

    /// Static per-image op accounting summed over every stage:
    /// `(executed, skipped)` SC lane-cycle products (MACs in analytic
    /// modes). `skipped` counts compile-time pruned weight lanes; the
    /// transposed kernel's runtime zero-tile skips are per-run and
    /// reported by [`ForwardPlan::run_with_timings`] instead.
    pub fn ops_per_image(&self) -> (u64, u64) {
        self.steps.iter().fold((0, 0), |(e, s), step| {
            let (a, b) = step.ops();
            (e + a, s + b)
        })
    }

    /// Per-compute-layer surviving weight-lane density of this compiled
    /// plan, indexed by weight layer (all 1.0 for dense plans) — the
    /// measured-at-compile input of the density-aware cost model.
    pub fn stage_densities(&self) -> Vec<f64> {
        let mut out = vec![1.0; self.precision.len()];
        for step in &self.steps {
            if let Some((wl, d)) = step.weight_density() {
                if wl < out.len() {
                    out[wl] = d;
                }
            }
        }
        out
    }

    /// Batched inference: images fan out across cores, the plan's windows /
    /// randoms / weight streams are shared, and each worker reuses one
    /// scratch arena across all the images it claims. Output `[i]` is
    /// bit-identical to `run(&inputs[i])`.
    pub fn run_batch(&self, inputs: &[Vec<f64>]) -> Vec<Vec<f64>> {
        self.run_batch_threads(inputs, 0)
    }

    /// [`ForwardPlan::run_batch`] with an explicit worker cap (0 = every
    /// core). Output is bit-identical for any cap.
    pub fn run_batch_threads(&self, inputs: &[Vec<f64>], threads: usize) -> Vec<Vec<f64>> {
        let mut results: Vec<Vec<f64>> = vec![Vec::new(); inputs.len()];
        par::par_chunks_mut_with_threads(&mut results, 1, threads, Scratch::default, |scr, i, slot| {
            slot[0] = self.run_with_threads(&inputs[i], scr, 1);
        });
        results
    }
}

/// A Conv/Dense compute layer behind the [`LayerStage`] face: the
/// [`LayerPlan`] constants plus the mode/precision knobs its executors
/// need.
struct ComputeStage {
    meta: StageMeta,
    lp: LayerPlan,
    mode: ForwardMode,
    /// Stochastic stream length (0 in analytic modes).
    k: usize,
    /// Words per stream.
    words: usize,
    bits: u32,
    /// Compiled-in fault injection (`None` = clean datapath).
    faults: Option<Arc<FaultPlan>>,
    /// Transposed bit-plane layout (`Some` iff the stage lowered to
    /// [`KernelPath::Transposed`]).
    tr: Option<TransposedPlan>,
    /// Static `(executed, skipped)` op accounting per image (lane-cycle
    /// products; MACs for analytic modes), fixed at compile from the
    /// pruning state.
    ops: (u64, u64),
}

impl LayerStage for ComputeStage {
    stage_meta_getters!();

    fn run(&self, scr: &mut Scratch, threads: usize) {
        match self.mode {
            ForwardMode::Stochastic { .. } if self.tr.is_some() => {
                self.run_stochastic_transposed(scr, threads)
            }
            ForwardMode::Stochastic { .. } => self.run_stochastic(scr, threads),
            _ => self.run_analytic(scr, threads),
        }
        std::mem::swap(&mut scr.act, &mut scr.out);
    }

    fn ops(&self) -> (u64, u64) {
        self.ops
    }

    fn weight_density(&self) -> Option<(usize, f64)> {
        let total = (self.lp.out_ch * self.lp.fan_in).max(1);
        let lanes = self.lp.pruned.as_ref().map_or(total, |p| p.lanes);
        Some((self.lp.wl, lanes as f64 / total as f64))
    }
}

impl ComputeStage {
    /// The fused stochastic layer: per neuron, one pass of
    /// `add_xnor_words` over the gather window followed by the fused
    /// B2S→ReLU→S2B popcount. Reads `scr.act`, writes `scr.out`.
    fn run_stochastic(&self, scr: &mut Scratch, threads: usize) {
        let lp = &self.lp;
        let (k, words) = (self.k, self.words);
        self.gen_act_streams(scr);
        let faults = self.faults.as_deref();
        // Constant streams for stuck-at APC lanes (XNOR with all-ones is
        // the identity, so a dead lane reuses the live accumulate path).
        let stuck_const: Option<(Vec<u64>, Vec<u64>)> = faults
            .filter(|f| !f.stuck_lanes.is_empty())
            .map(|_| (vec![!0u64; words], vec![0u64; words]));
        let total = lp.out_ch * lp.gather.n_win;
        scr.out.clear();
        scr.out.resize(total, 0.0);
        let floor = lp.floor;
        let act_words: &[u64] = &scr.act_words;
        let out: &mut [f64] = &mut scr.out;
        let pruned = lp.pruned.as_ref();
        let worker = |vc: &mut VerticalCounter, start: usize, slice: &mut [f64]| {
            for (off, slot) in slice.iter_mut().enumerate() {
                let g = start + off;
                let (oc, wi) = (g / lp.gather.n_win, g % lp.gather.n_win);
                let window = lp.gather.window(oc, wi);
                vc.reset();
                let (ones, n_f, scale) = match pruned {
                    // The pruned neuron: walk the channel's skip list —
                    // survivor sj keeps its original lane j for the
                    // window lookup and the fault addressing, and owns
                    // packed stream slot off[oc] + sj. Recovery uses the
                    // surviving fan-in's 2^m / floor (bias folding).
                    Some(p) => {
                        let surv = &p.surv[oc];
                        let lane0 = p.off[oc] as usize;
                        for (sj, &j32) in surv.iter().enumerate() {
                            let j = j32 as usize;
                            if let Some((ones_w, zeros_w)) = &stuck_const {
                                if let Some(v) = faults.and_then(|f| f.stuck(lp.wl, j)) {
                                    vc.add_xnor_words(if v { ones_w } else { zeros_w }, ones_w);
                                    continue;
                                }
                            }
                            let a = match window[j] {
                                Some(i) => &act_words[i * words..(i + 1) * words],
                                None => &lp.pad_words[j * words..(j + 1) * words],
                            };
                            let w = &lp.wgt_words[(lane0 + sj) * words..][..words];
                            vc.add_xnor_words(a, w);
                        }
                        let ones = vc.b2s_ones(&p.r4[p.r4_of[oc] as usize], p.floor[oc]);
                        (ones, surv.len() as f64, p.scale[oc])
                    }
                    None => {
                        let wbase = oc * lp.fan_in * words;
                        for (j, &src) in window.iter().enumerate() {
                            if let Some((ones_w, zeros_w)) = &stuck_const {
                                if let Some(v) = faults.and_then(|f| f.stuck(lp.wl, j)) {
                                    vc.add_xnor_words(if v { ones_w } else { zeros_w }, ones_w);
                                    continue;
                                }
                            }
                            let a = match src {
                                Some(i) => &act_words[i * words..(i + 1) * words],
                                None => &lp.pad_words[j * words..(j + 1) * words],
                            };
                            let w = &lp.wgt_words[wbase + j * words..wbase + (j + 1) * words];
                            vc.add_xnor_words(a, w);
                        }
                        (vc.b2s_ones(&lp.r4, floor), lp.fan_in as f64, lp.scale)
                    }
                };
                let v = 2.0 * (ones as f64 / k as f64) - 1.0;
                let sp = (v + 1.0) * scale - n_f;
                *slot = reencode(sp, lp.gamma, lp.mu, lp.final_layer);
            }
        };
        if threads != 1 && total > 1 {
            let chunk = par::balanced_chunk_len_for(total, threads);
            par::par_chunks_mut_with_threads(
                out,
                chunk,
                threads,
                || VerticalCounter::new(k, lp.fan_in),
                |vc, ci, slice| worker(vc, ci * chunk, slice),
            );
        } else {
            scr.vc.reconfigure(k, lp.fan_in);
            worker(&mut scr.vc, 0, out);
        }
    }

    /// Quantize `scr.act` and generate the per-image activation SNG
    /// streams (one packed lane per input site, bit-flip faults applied)
    /// into `scr.act_words` — shared by both stochastic kernels. Weight
    /// and padding streams are compile-time plan state, so across a batch
    /// only this per-image step repeats: the SNG work for every weight
    /// lane is reused by every image and every thread.
    fn gen_act_streams(&self, scr: &mut Scratch) {
        let lp = &self.lp;
        let (k, words, bits) = (self.k, self.words, self.bits);
        scr.acodes.clear();
        scr.acodes.extend(scr.act.iter().map(|&v| quantize_bipolar(v, bits)));
        assert_eq!(scr.acodes.len(), lp.in_sites, "layer input size mismatch");
        let faults = self.faults.as_deref();
        // Per-image activation SNG streams, one packed lane per site.
        scr.act_words.clear();
        scr.act_words.resize(lp.in_sites * words, 0);
        for (p, &code) in scr.acodes.iter().enumerate() {
            let slot = &mut scr.act_words[p * words..(p + 1) * words];
            lane_stream_words(code, bits, k, lp.base, p as u64, slot);
            if let Some(f) = faults {
                f.flip_words(lp.base, p as u64, k, slot);
            }
        }
    }

    /// The bit-plane transposed stochastic layer ([`KernelPath::Transposed`]):
    /// per (window, cycle-word) pair, gather the window's lane-major
    /// activation words into an L1-sized tile, [`bitplane::transpose64`]
    /// it cycle-major, and accumulate every output channel's B2S `ones`
    /// with one XNOR+`count_ones` word per 64 fan-in lanes per cycle —
    /// the tile is built once and shared across all output channels of
    /// the window (depthwise tables re-tile per channel). Produces
    /// bit-identical `ones` counts to [`ComputeStage::run_stochastic`] and
    /// the per-bit [`reference`]: the streams, the gather geometry, and
    /// the `max(2c, floor) > r4` comparison are all exactly shared — only
    /// the iteration order over (lane, cycle) changes, and integer
    /// popcount sums are order-independent. Reads `scr.act`, writes
    /// `scr.out`.
    fn run_stochastic_transposed(&self, scr: &mut Scratch, threads: usize) {
        let lp = &self.lp;
        let tr = self.tr.as_ref().expect("transposed stages carry their planes");
        let (k, words) = (self.k, self.words);
        self.gen_act_streams(scr);
        let (out_ch, n_win) = (lp.out_ch, lp.gather.n_win);
        let total = out_ch * n_win;
        let lb = tr.lane_blocks;
        let fan_in = lp.fan_in;
        let pruned = lp.pruned.as_ref();
        // Unstructured (per-channel) survivor sets force per-channel
        // tiles, exactly like depthwise gather tables always have.
        let per_channel = lp.gather.per_channel || pruned.is_some_and(|p| !p.shared);
        let floor = lp.floor;
        // Runtime activation-sparsity skips (lane-cycles), summed across
        // workers for the run_with_timings ops breakdown.
        let zero_skips = std::sync::atomic::AtomicU64::new(0);
        let Scratch { act_words, out, tr_out, tr: tr_scr, .. } = &mut *scr;
        out.clear();
        out.resize(total, 0.0);
        tr_out.clear();
        tr_out.resize(total, 0.0);
        let act_words: &[u64] = act_words;
        let tr_out: &mut [f64] = tr_out.as_mut_slice();
        // Build one (window, cycle-word) activation tile: the 64
        // lane-major stream words of each lane block, transposed
        // cycle-major. 64·lane_blocks words — L1-resident for every
        // shipped topology. Under pruning, tile lane sj is the channel's
        // sj-th surviving lane. Returns true when the whole tile is zero
        // (every gathered activation word is 0): the caller then takes
        // the closed-form count instead of walking lane blocks.
        let build_tile = |st: &mut TrScratch, oc: usize, wi: usize, cw: usize| -> bool {
            let window = lp.gather.window(oc, wi);
            let surv = pruned.map(|p| p.surv[oc].as_slice());
            let n_oc = surv.map_or(fan_in, <[u32]>::len);
            let mut any = 0u64;
            for b in 0..lb {
                let mut blk = 0u64;
                for (l, col) in st.cols.iter_mut().enumerate() {
                    let sj = b * bitplane::LANES + l;
                    *col = if sj >= n_oc {
                        // Tail lane: zeros against the plane's all-ones.
                        0
                    } else {
                        let j = surv.map_or(sj, |s| s[sj] as usize);
                        if !tr.stuck.is_empty() && tr.stuck[j] {
                            // Stuck lane: the XNOR identity against the
                            // compiled-in constant (and a tile that can
                            // never read as all-zero).
                            !0u64
                        } else {
                            match window[j] {
                                Some(i) => act_words[i * words + cw],
                                None => lp.pad_words[j * words + cw],
                            }
                        }
                    };
                    blk |= *col;
                }
                if blk == 0 {
                    // All-zero block: its transpose is zeros — clear the
                    // tile rows directly (the tile is reused across
                    // (window, cycle-word) pairs and may hold stale bits).
                    for t in 0..bitplane::LANES {
                        st.tile[t * lb + b] = 0;
                    }
                } else {
                    bitplane::transpose64(&mut st.cols);
                    for (t, &row) in st.cols.iter().enumerate() {
                        st.tile[t * lb + b] = row;
                    }
                }
                any |= blk;
            }
            any == 0
        };
        // Window-major worker over flat units g = wi·out_ch + oc, so a
        // chunk walks whole (window, channel-range) groups and the tile
        // build amortizes across the group. Dense stages (n_win = 1)
        // split their single window's channel range across workers.
        let worker = |st: &mut TrScratch, start: usize, slice: &mut [f64]| {
            let mut local_skip = 0u64;
            let end = start + slice.len();
            let mut g = start;
            while g < end {
                let wi = g / out_ch;
                let oc0 = g - wi * out_ch;
                let gend = end.min((wi + 1) * out_ch);
                let nn = gend - g;
                st.ones[..nn].fill(0);
                for cw in 0..words {
                    let valid = (k - cw * 64).min(64);
                    let mut zero = false;
                    if !per_channel {
                        zero = build_tile(st, 0, wi, cw);
                    }
                    for oi in 0..nn {
                        let oc = oc0 + oi;
                        if per_channel {
                            zero = build_tile(st, oc, wi, cw);
                        }
                        let (n_oc, floor_oc, r4) = match pruned {
                            Some(p) => (
                                p.surv[oc].len(),
                                p.floor[oc],
                                p.r4[p.r4_of[oc] as usize].as_slice(),
                            ),
                            None => (fan_in, floor, lp.r4.as_slice()),
                        };
                        let r4 = &r4[cw * 64..cw * 64 + valid];
                        let mut ones = 0u32;
                        if zero {
                            // All-zero activation tile: XNOR(0, w) = !w,
                            // so each cycle's count is the compile-time
                            // complement popcount — no lane-block walk.
                            let zc = &tr.zero_ones
                                [(oc * words + cw) * bitplane::LANES..][..valid];
                            for (&z, &r) in zc.iter().zip(r4) {
                                ones += ((2 * z).max(floor_oc) > r) as u32;
                            }
                            local_skip += n_oc as u64 * valid as u64;
                        } else {
                            let wrow = &tr.wgt_tr[(oc * words + cw) * bitplane::LANES * lb..]
                                [..bitplane::LANES * lb];
                            for (t, &r) in r4.iter().enumerate() {
                                let c = bitplane::xnor_count(
                                    &st.tile[t * lb..(t + 1) * lb],
                                    &wrow[t * lb..(t + 1) * lb],
                                );
                                ones += ((2 * c).max(floor_oc) > r) as u32;
                            }
                        }
                        st.ones[oi] += ones;
                    }
                }
                for (oi, slot) in slice[g - start..gend - start].iter_mut().enumerate() {
                    let (n_oc, scale) = match pruned {
                        Some(p) => {
                            let oc = oc0 + oi;
                            (p.surv[oc].len(), p.scale[oc])
                        }
                        None => (fan_in, lp.scale),
                    };
                    let v = 2.0 * (st.ones[oi] as f64 / k as f64) - 1.0;
                    let sp = (v + 1.0) * scale - n_oc as f64;
                    *slot = reencode(sp, lp.gamma, lp.mu, lp.final_layer);
                }
                g = gend;
            }
            if local_skip > 0 {
                zero_skips.fetch_add(local_skip, std::sync::atomic::Ordering::Relaxed);
            }
        };
        if threads != 1 && total > 1 {
            let chunk = par::balanced_chunk_len_for(total, threads);
            par::par_chunks_mut_with_threads(
                &mut *tr_out,
                chunk,
                threads,
                || {
                    let mut st = TrScratch::default();
                    st.reconfigure(lb, out_ch);
                    st
                },
                |st, ci, slice| worker(st, ci * chunk, slice),
            );
        } else {
            tr_scr.reconfigure(lb, out_ch);
            worker(tr_scr, 0, &mut *tr_out);
        }
        // Scatter window-major staging back to the engine's
        // channel-major activation layout.
        for wi in 0..n_win {
            for oc in 0..out_ch {
                out[oc * n_win + wi] = tr_out[wi * out_ch + oc];
            }
        }
        // Move the runtime zero-tile skips from the executed side of the
        // static split to the skipped side (total is invariant).
        let moved = zero_skips.into_inner();
        scr.stage_ops = (self.ops.0.saturating_sub(moved), self.ops.1 + moved);
    }

    /// Expectation / noisy-expectation / fixed-point layer over the same
    /// quantized codes. Reads `scr.act`, writes `scr.out`.
    fn run_analytic(&self, scr: &mut Scratch, threads: usize) {
        let lp = &self.lp;
        let bits = self.bits;
        scr.acodes.clear();
        scr.acodes.extend(scr.act.iter().map(|&v| quantize_bipolar(v, bits)));
        assert_eq!(scr.acodes.len(), lp.in_sites, "layer input size mismatch");
        if let Some(f) = self.faults.as_deref() {
            // The binary datapath's view of the same upset rate: flips land
            // on binary-weighted code bits, so a single hit can swing the
            // value by half its range — the cliff the SC streams avoid.
            for (p, code) in scr.acodes.iter_mut().enumerate() {
                *code ^= f.flip_code(lp.wl, p, bits);
            }
        }
        scr.aq.clear();
        scr.aq.extend(scr.acodes.iter().map(|&c| dequantize_bipolar(c, bits)));
        let total = lp.out_ch * lp.gather.n_win;
        scr.out.clear();
        scr.out.resize(total, 0.0);
        let aq: &[f64] = &scr.aq;
        let out: &mut [f64] = &mut scr.out;
        let mode = self.mode;
        let layer_seed = lp.wl as u32;
        let pruned = lp.pruned.as_ref();
        let worker = |start: usize, slice: &mut [f64]| {
            for (off, slot) in slice.iter_mut().enumerate() {
                let g = start + off;
                let (oc, wi) = (g / lp.gather.n_win, g % lp.gather.n_win);
                let wq = &lp.wq[oc * lp.fan_in..(oc + 1) * lp.fan_in];
                let window = lp.gather.window(oc, wi);
                let mut pre = 0.0f64;
                let mut var = 0.0f64;
                // Pruned lanes drop out of the sum AND the variance: the
                // analytic model mirrors the stochastic datapath, which
                // no longer runs those product streams.
                let (n_f, scale_f) = match pruned {
                    Some(p) => {
                        for &j32 in &p.surv[oc] {
                            let j = j32 as usize;
                            let a = match window[j] {
                                Some(i) => aq[i],
                                None => lp.zq,
                            };
                            let pj = a * wq[j];
                            pre += pj;
                            var += 1.0 - pj * pj;
                        }
                        (p.surv[oc].len(), p.scale[oc])
                    }
                    None => {
                        for (j, &src) in window.iter().enumerate() {
                            let a = match src {
                                Some(i) => aq[i],
                                None => lp.zq,
                            };
                            let pj = a * wq[j];
                            pre += pj;
                            var += 1.0 - pj * pj;
                        }
                        (lp.fan_in, lp.scale)
                    }
                };
                // sp: the value the S2B counter recovers.
                let sp = match mode {
                    ForwardMode::Expectation | ForwardMode::NoisyExpectation { .. } => {
                        if lp.relu {
                            // `scale_f` is the compiled 2^m of the
                            // (surviving) fan-in — the per-call m_bits
                            // shift is hoisted out of this loop.
                            let v = neuron::expectation_smooth_relu_scaled(
                                pre, var, n_f, scale_f,
                            );
                            (v + 1.0) * scale_f - n_f as f64
                        } else {
                            pre
                        }
                    }
                    ForwardMode::FixedPoint => {
                        if lp.relu {
                            pre.max(0.0)
                        } else {
                            pre
                        }
                    }
                    ForwardMode::Stochastic { .. } => unreachable!(),
                };
                let sp = if let ForwardMode::NoisyExpectation { k, seed } = mode {
                    // Sampling error of a k-cycle low-discrepancy stream on
                    // the recovered value. With van der Corput /
                    // progressive-precision SNGs (the setup hardware SCNNs
                    // at k=32 rely on, §II-C refs), the conversion error
                    // scales as O(1/k), not the binomial O(1/sqrt(k)):
                    // sigma_v ~ 3*sqrt(P(1-P))/k.
                    let v = (sp + n_f as f64) / scale_f - 1.0;
                    let p = ((v + 1.0) / 2.0).clamp(1e-6, 1.0 - 1e-6);
                    let sigma = 3.0 * (p * (1.0 - p)).sqrt() / k as f64;
                    let z = rng::gauss(seed ^ noise_ctr(oc, g), layer_seed);
                    let v = v + sigma * z;
                    (v + 1.0) * scale_f - n_f as f64
                } else {
                    sp
                };
                *slot = reencode(sp, lp.gamma, lp.mu, lp.final_layer);
            }
        };
        if threads != 1 && total > 1 {
            let chunk = par::balanced_chunk_len_for(total, threads);
            par::par_chunks_mut_threads(out, chunk, threads, |ci, slice| {
                worker(ci * chunk, slice)
            });
        } else {
            worker(0, out);
        }
    }
}

/// Lower one compute stage into its executable [`LayerPlan`], checking the
/// weight tensor against [`StageDescriptor::weight_shape`].
fn build_layer_plan(
    weights: &QuantizedWeights,
    st: &StageDescriptor,
    table: GatherTable,
    mode: ForwardMode,
    faults: Option<&FaultPlan>,
    sparsity: SparsityPolicy,
) -> Result<LayerPlan> {
    let bits = weights.bits;
    let wl = st.weight_layer.expect("compute stages carry a weight layer");
    let lw = &weights.layers[wl];
    let (out_ch, fan_in) = st.weight_shape().expect("compute stages have a weight shape");
    if lw.codes.len() != out_ch {
        bail!(
            "layer {} ({}): weights have {} output rows, expected {out_ch}",
            st.index,
            st.label(),
            lw.codes.len()
        );
    }
    if let Some(row) = lw.codes.iter().find(|row| row.len() != fan_in) {
        bail!(
            "layer {} ({}): a weight row has {} codes, expected fan-in {fan_in}",
            st.index,
            st.label(),
            row.len()
        );
    }
    let final_layer = st.final_compute;
    let scale = (1u64 << neuron::m_bits(fan_in)) as f64;
    let needs_pad = table.needs_padding();
    // The lane seed base — a pure function of the mode seed and the
    // weight-layer index, shared by every kernel and the reference.
    let layer_seed = wl as u32;
    let stream = match mode {
        ForwardMode::Stochastic { k, seed } => {
            Some((k, seed ^ layer_seed.wrapping_mul(0x9E37_79B9)))
        }
        _ => None,
    };
    let pruned = prune_layer(st, lw, bits, sparsity, stream)?;
    let mut lp = LayerPlan {
        wl,
        out_ch,
        fan_in,
        gather: table,
        in_sites: st.in_len(),
        relu: st.relu,
        final_layer,
        gamma: lw.gamma,
        mu: lw.mu,
        scale,
        floor: if st.relu { fan_in as u32 } else { 0 },
        base: 0,
        r4: Vec::new(),
        wgt_words: Vec::new(),
        pad_words: Vec::new(),
        wq: Vec::new(),
        zq: 0.0,
        pruned,
    };
    match mode {
        ForwardMode::Stochastic { k, .. } => {
            // RNS sharing *with signal shuffling* (§I): every PCC sees a
            // per-lane wire-permuted view of the shared source, so product
            // streams are pairwise decorrelated and the per-cycle count
            // variance matches the independent-product model the network
            // was trained through. (Sharing the raw source across all
            // multiplier lanes makes counts swing coherently — a large,
            // k-independent positive bias through the smoothed ReLU.)
            let (_, base) = stream.expect("stochastic mode carries stream constants");
            let words = k.div_ceil(64);
            lp.base = base;
            // An SNG correlation fault drops the lane's wire shuffle: the
            // PCC compares its own code against the *raw activation RNS*
            // of site j — the correlated-product failure mode the
            // per-lane keys exist to prevent. Flip masks key on the
            // actual generation key, so every kernel and the reference
            // inject identically. Keys always use the ORIGINAL lane
            // index, pruned or not.
            let key_of = |oc: usize, j: usize| -> (u32, u64) {
                if faults.is_some_and(|f| f.correlated_weight_lane(wl, oc, j)) {
                    (base, j as u64)
                } else {
                    (base ^ 0x5EED_CAFE, ((oc as u64) << 20) + j as u64)
                }
            };
            match &lp.pruned {
                Some(p) => {
                    // Pruned layer: SNG work and stream storage shrink to
                    // the survivors, packed densely per channel. The
                    // per-channel comparison randoms live in the pruned
                    // pool; lp.r4 stays empty.
                    lp.wgt_words = vec![0u64; p.lanes * words];
                    for (oc, wcodes) in lw.codes.iter().enumerate() {
                        let lane0 = p.off[oc] as usize;
                        for (sj, &j32) in p.surv[oc].iter().enumerate() {
                            let j = j32 as usize;
                            let (lbase, lane) = key_of(oc, j);
                            let slot = &mut lp.wgt_words[(lane0 + sj) * words..][..words];
                            lane_stream_words(wcodes[j], bits, k, lbase, lane, slot);
                            if let Some(f) = faults {
                                f.flip_words(lbase, lane, k, slot);
                            }
                        }
                    }
                }
                None => {
                    lp.r4 = layer_r4(fan_in, k, base);
                    lp.wgt_words = vec![0u64; out_ch * fan_in * words];
                    for (oc, wcodes) in lw.codes.iter().enumerate() {
                        for (j, &code) in wcodes.iter().enumerate() {
                            let (lbase, lane) = key_of(oc, j);
                            let slot = &mut lp.wgt_words[(oc * fan_in + j) * words..][..words];
                            lane_stream_words(code, bits, k, lbase, lane, slot);
                            if let Some(f) = faults {
                                f.flip_words(lbase, lane, k, slot);
                            }
                        }
                    }
                }
            }
            // Per-lane padding streams, only for layers with border
            // windows — indexed by original lane, pruned or not.
            if needs_pad {
                let zero_code = quantize_bipolar(0.0, bits);
                lp.pad_words = vec![0u64; fan_in * words];
                for j in 0..fan_in {
                    let slot = &mut lp.pad_words[j * words..][..words];
                    lane_stream_words(zero_code, bits, k, base, (1u64 << 40) + j as u64, slot);
                    if let Some(f) = faults {
                        f.flip_words(base, (1u64 << 40) + j as u64, k, slot);
                    }
                }
            }
        }
        _ => {
            lp.zq = dequantize_bipolar(quantize_bipolar(0.0, bits), bits);
            lp.wq = Vec::with_capacity(out_ch * fan_in);
            for wcodes in &lw.codes {
                lp.wq.extend(wcodes.iter().map(|&c| dequantize_bipolar(c, bits)));
            }
        }
    }
    Ok(lp)
}

/// Argmax over the final layer values (ties resolve to the last maximal
/// index). Generic over the element type so the f64 datapath and the f32
/// serving path (`crate::engine::classify`) share one implementation.
pub fn classify<T: PartialOrd>(output: &[T]) -> usize {
    output
        .iter()
        .enumerate()
        .max_by(|(_, a), (_, b)| a.partial_cmp(b).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}

/// The per-bit stochastic forward, kept as the golden reference
/// implementation: every stream is generated one bit at a time through
/// `from_fn`, every XNOR product allocates, and neurons run serially —
/// exactly the original datapath. It lowers from the **same stage IR and
/// gather tables** as the fused engine ([`crate::accel::stage`]), so the
/// two can only diverge on the stream arithmetic itself — which the
/// golden tests pin bit-for-bit; the speedup is measured in
/// `rust/benches/hotpath.rs`.
#[doc(hidden)]
pub mod reference {
    use super::*;
    use crate::sc::bitstream::Bitstream;

    /// Per-bit lane stream (the original SNG path). Bit-compatible with
    /// the engine's word-packed `lane_stream_words`.
    pub fn lane_stream(code: u32, bits: u32, k: usize, base: u32, lane: u64) -> Bitstream {
        let mut state = rng::lane_state(base as u64, lane);
        let mask = (1u32 << bits) - 1;
        Bitstream::from_fn(k, |_| {
            state = rng::xorshift64_step(state);
            code > ((state as u32) & mask)
        })
    }

    /// Bit-exact stochastic inference, original per-bit/allocating path,
    /// walking the same compiled stage descriptors as [`ForwardPlan`]
    /// with one uniform bitstream length.
    pub fn forward_stochastic(
        net: &NetworkSpec,
        weights: &QuantizedWeights,
        input: &[f64],
        k: usize,
        seed: u32,
    ) -> Vec<f64> {
        let plan = PrecisionPlan::uniform(k, net.n_compute());
        forward_stochastic_plan(net, weights, input, &plan, seed)
    }

    /// [`forward_stochastic`] under a per-layer [`PrecisionPlan`]: every
    /// compute stage runs at its own planned length, rescaling through the
    /// S2B→B2S value boundary exactly like the fused engine — the golden
    /// model the per-layer parity property tests pin against.
    pub fn forward_stochastic_plan(
        net: &NetworkSpec,
        weights: &QuantizedWeights,
        input: &[f64],
        precision: &PrecisionPlan,
        seed: u32,
    ) -> Vec<f64> {
        forward_stochastic_plan_faulted(net, weights, input, precision, seed, None)
    }

    /// [`forward_stochastic_plan_faulted`] under a [`SparsityPolicy`]: the
    /// per-bit golden model of `ForwardPlan::compile_with_sparsity`.
    /// Pruned lanes are skipped in the window walk, the APC/B2S constants
    /// come from each channel's *surviving* fan-in, and the S2B recovery
    /// subtracts the surviving count — the same bias-folding contract the
    /// fused and transposed kernels implement.
    pub fn forward_stochastic_plan_sparse(
        net: &NetworkSpec,
        weights: &QuantizedWeights,
        input: &[f64],
        precision: &PrecisionPlan,
        seed: u32,
        faults: Option<&FaultPlan>,
        sparsity: SparsityPolicy,
    ) -> Vec<f64> {
        forward_ref_inner(net, weights, input, precision, seed, faults, sparsity)
    }

    /// [`forward_stochastic_plan`] under an optional [`FaultPlan`]: the
    /// per-bit golden model of
    /// `ForwardPlan::compile_with_precision_faults` — SRAM upsets corrupt
    /// the stored weights first, then every stream is generated one bit at
    /// a time with flips, stuck lanes, and correlation faults injected
    /// through [`FaultPlan::flip_bit`] / [`FaultPlan::stuck`] /
    /// [`FaultPlan::correlated_weight_lane`]. Must stay bit-exact with the
    /// fused engine under any identical plan.
    pub fn forward_stochastic_plan_faulted(
        net: &NetworkSpec,
        weights: &QuantizedWeights,
        input: &[f64],
        precision: &PrecisionPlan,
        seed: u32,
        faults: Option<&FaultPlan>,
    ) -> Vec<f64> {
        forward_ref_inner(net, weights, input, precision, seed, faults, SparsityPolicy::OFF)
    }

    #[allow(clippy::too_many_arguments)]
    fn forward_ref_inner(
        net: &NetworkSpec,
        weights: &QuantizedWeights,
        input: &[f64],
        precision: &PrecisionPlan,
        seed: u32,
        faults: Option<&FaultPlan>,
        sparsity: SparsityPolicy,
    ) -> Vec<f64> {
        let corrupted;
        let weights = match faults {
            Some(f) if f.sram_upset_rate > 0.0 => {
                corrupted = f.corrupt_weights(weights);
                &corrupted
            }
            _ => weights,
        };
        let faults = faults.filter(|f| !f.is_noop());
        let stages = net
            .stages()
            .unwrap_or_else(|e| panic!("reference::forward_stochastic({}): {e:#}", net.name));
        let n_compute = stages.iter().filter(|s| s.is_compute()).count();
        assert_eq!(
            precision.len(),
            n_compute,
            "precision plan must cover every compute stage of {}",
            net.name
        );
        let bits = weights.bits;
        let mut act: Vec<f64> = input.to_vec();
        let mut saved: Vec<Vec<f64>> = vec![Vec::new(); stages.len()];
        for st in &stages {
            act = match st.op {
                StageOp::Conv(_) | StageOp::Dense { .. } => {
                    let table = stage::gather(st).expect("compute stages have gather tables");
                    let wl = st.weight_layer.expect("compute stages carry a weight layer");
                    let k = precision.k_for(wl);
                    run_layer(st, &table, &act, weights, bits, k, seed, faults, sparsity)
                }
                StageOp::MaxPool { size } => {
                    let mut next = Vec::new();
                    stage::max_pool_into(&act, st.in_shape, size, &mut next);
                    next
                }
                StageOp::AvgPool { size } => {
                    let mut next = Vec::new();
                    stage::avg_pool_into(&act, st.in_shape, size, &mut next);
                    next
                }
                StageOp::GlobalAvgPool => {
                    let mut next = Vec::new();
                    stage::global_avg_pool_into(&act, st.in_shape, &mut next);
                    next
                }
                StageOp::Add { from } => {
                    let mut next = Vec::new();
                    stage::scaled_add_into(&act, &saved[from], &mut next);
                    next
                }
            };
            if st.save_output {
                saved[st.index] = act.clone();
            }
        }
        act
    }

    /// A lane stream with the fault plan's per-bit flips applied — the
    /// per-bit view of the word-mask injection the fused engine performs.
    fn lane_stream_faulted(
        code: u32,
        bits: u32,
        k: usize,
        base: u32,
        lane: u64,
        faults: Option<&FaultPlan>,
    ) -> Bitstream {
        let s = lane_stream(code, bits, k, base, lane);
        match faults {
            Some(f) if f.bit_flip_rate > 0.0 => {
                Bitstream::from_fn(k, |t| s.get(t) ^ f.flip_bit(base, lane, t))
            }
            _ => s,
        }
    }

    /// One per-bit compute layer over a stage's gather table.
    #[allow(clippy::too_many_arguments)]
    fn run_layer(
        st: &StageDescriptor,
        table: &GatherTable,
        act: &[f64],
        weights: &QuantizedWeights,
        bits: u32,
        k: usize,
        seed: u32,
        faults: Option<&FaultPlan>,
        sparsity: SparsityPolicy,
    ) -> Vec<f64> {
        let wl = st.weight_layer.expect("compute stages carry a weight layer");
        let lw = &weights.layers[wl];
        let (out_ch, fan_in) = st.weight_shape().expect("compute stages have a weight shape");
        let final_layer = st.final_compute;
        let layer_seed = wl as u32;
        let base = seed ^ layer_seed.wrapping_mul(0x9E37_79B9);
        let r4 = layer_r4(fan_in, k, base);
        let acodes: Vec<u32> = act.iter().map(|&v| quantize_bipolar(v, bits)).collect();
        let act_streams: Vec<Bitstream> = acodes
            .iter()
            .enumerate()
            .map(|(p, &c)| lane_stream_faulted(c, bits, k, base, p as u64, faults))
            .collect();
        let zero_code = quantize_bipolar(0.0, bits);
        let pad_streams: Vec<Bitstream> = (0..fan_in)
            .map(|j| lane_stream_faulted(zero_code, bits, k, base, (1 << 40) + j as u64, faults))
            .collect();
        let mut out = Vec::with_capacity(out_ch * table.n_win);
        for oc in 0..out_ch {
            let wcodes = &lw.codes[oc];
            assert_eq!(wcodes.len(), fan_in, "weight fan-in mismatch");
            // Pruned lanes drop out of the window walk entirely; every
            // APC/B2S constant below derives from the surviving fan-in.
            let keep: Vec<bool> = wcodes.iter().map(|&c| !sparsity.prunes(c, bits)).collect();
            let n_oc = keep.iter().filter(|&&kp| kp).count();
            assert!(n_oc > 0, "sparsity pruned channel {oc} of layer {wl} to fan-in 0");
            let scale_oc = (1u64 << neuron::m_bits(n_oc)) as f64;
            let r4_pruned;
            let r4_oc = if n_oc == fan_in {
                &r4
            } else {
                r4_pruned = layer_r4(n_oc, k, base);
                &r4_pruned
            };
            let wgt_streams: Vec<Bitstream> = wcodes
                .iter()
                .enumerate()
                .map(|(j, &c)| {
                    // Same correlation-fault key selection as the fused
                    // engine: a hit lane shares the raw activation RNS.
                    let (lbase, lane) =
                        if faults.is_some_and(|f| f.correlated_weight_lane(wl, oc, j)) {
                            (base, j as u64)
                        } else {
                            (base ^ 0x5EED_CAFE, ((oc as u64) << 20) + j as u64)
                        };
                    lane_stream_faulted(c, bits, k, lbase, lane, faults)
                })
                .collect();
            for wi in 0..table.n_win {
                let mut vc = VerticalCounter::new(k, fan_in);
                for (j, &src) in table.window(oc, wi).iter().enumerate() {
                    // Prune check before the stuck check: a pruned lane's
                    // APC slot no longer exists, so a stuck fault
                    // addressed at it never fires — matching the compiled
                    // kernels, which only walk survivors.
                    if !keep[j] {
                        continue;
                    }
                    if let Some(v) = faults.and_then(|f| f.stuck(wl, j)) {
                        vc.add(&if v { Bitstream::ones(k) } else { Bitstream::zeros(k) });
                        continue;
                    }
                    let a = match src {
                        Some(i) => &act_streams[i],
                        None => &pad_streams[j],
                    };
                    vc.add(&a.xnor(&wgt_streams[j]));
                }
                let o = neuron::b2s_stream(&vc, r4_oc);
                let o = if st.relu {
                    o.or(&neuron::relu_zero_stream(n_oc, r4_oc))
                } else {
                    o
                };
                // S2B recovery + re-encoder affine, from surviving fan-in.
                let sp = (o.value_bipolar() + 1.0) * scale_oc - n_oc as f64;
                out.push(reencode(sp, lw.gamma, lw.mu, final_layer));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::layers::{Conv2d, LayerKind, LayerSpec};

    /// Shorthands for the plan-level one-shots.
    fn fwd(n: &NetworkSpec, w: &QuantizedWeights, i: &[f64], m: ForwardMode) -> Vec<f64> {
        ForwardPlan::once(n, w, i, m)
    }
    fn fwd_batch(
        n: &NetworkSpec,
        w: &QuantizedWeights,
        i: &[Vec<f64>],
        m: ForwardMode,
    ) -> Vec<Vec<f64>> {
        ForwardPlan::once_batch(n, w, i, m)
    }

    fn tiny_net() -> NetworkSpec {
        NetworkSpec {
            name: "tiny".into(),
            input: (1, 6, 6),
            layers: vec![
                LayerSpec::active(LayerKind::conv(1, 2, 3, 1)),
                LayerSpec::linear(LayerKind::MaxPool { size: 2 }),
                LayerSpec::linear(LayerKind::Dense { inputs: 18, outputs: 3 }),
            ],
        }
    }

    /// A network exercising every extended op: strided conv, depthwise
    /// conv, SC scaled-add residual, average pool, global average pool.
    fn extended_net() -> NetworkSpec {
        NetworkSpec {
            name: "tiny-extended".into(),
            input: (1, 8, 8),
            layers: vec![
                LayerSpec::active(LayerKind::Conv(
                    Conv2d::square(1, 4, 3, 1).with_stride(2, 2),
                )),
                LayerSpec::active(LayerKind::Conv(Conv2d::square(4, 4, 3, 1).depthwise())),
                LayerSpec::linear(LayerKind::Add { from: 0 }),
                LayerSpec::linear(LayerKind::AvgPool { size: 2 }),
                LayerSpec::active(LayerKind::Conv(Conv2d::square(4, 6, 1, 0))),
                LayerSpec::linear(LayerKind::GlobalAvgPool),
                LayerSpec::linear(LayerKind::Dense { inputs: 6, outputs: 3 }),
            ],
        }
    }

    fn seeded_weights(net: &NetworkSpec, bits: u32, seed: u64) -> QuantizedWeights {
        // Synthetic codes with per-layer affines in the calibrated range.
        let mut w = QuantizedWeights::synthetic(net, bits, seed.max(1)).unwrap();
        for (i, l) in w.layers.iter_mut().enumerate() {
            l.gamma = 0.35 + 0.1 * i as f64;
            l.mu = 0.9;
        }
        w
    }

    fn tiny_weights(bits: u32, seed: u64) -> QuantizedWeights {
        let mut s = seed.max(1);
        let mut rng = move || {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            (s % 2000) as f64 / 1000.0 - 1.0
        };
        let l0: Vec<Vec<u32>> = (0..2)
            .map(|_| (0..9).map(|_| quantize_bipolar(rng() * 0.5, bits)).collect())
            .collect();
        let l1: Vec<Vec<u32>> = (0..3)
            .map(|_| (0..18).map(|_| quantize_bipolar(rng() * 0.9, bits)).collect())
            .collect();
        QuantizedWeights {
            bits,
            layers: vec![
                // Affines roughly where calibration would put them for
                // these fan-ins (mu near the smoothed-ReLU bias floor).
                LayerWeights { codes: l0, gamma: 0.35, mu: 0.9 },
                LayerWeights { codes: l1, gamma: 1.0, mu: 1.2 },
            ],
        }
    }

    fn tiny_input() -> Vec<f64> {
        (0..36).map(|i| ((i % 7) as f64) / 7.0).collect()
    }

    fn extended_input() -> Vec<f64> {
        (0..64).map(|i| ((i % 9) as f64) / 9.0).collect()
    }

    #[test]
    fn output_shapes_consistent_across_modes() {
        let net = tiny_net();
        let w = tiny_weights(8, 42);
        let input = tiny_input();
        for mode in [
            ForwardMode::FixedPoint,
            ForwardMode::Expectation,
            ForwardMode::Stochastic { k: 64, seed: 7 },
        ] {
            let out = fwd(&net, &w, &input, mode);
            assert_eq!(out.len(), 3, "{mode:?}");
            assert!(out.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn fused_engine_matches_reference_bit_exactly() {
        let net = tiny_net();
        let w = tiny_weights(8, 42);
        let input = tiny_input();
        // Lengths below, at, and across the 64-bit packing boundary.
        for k in [16usize, 64, 104] {
            for seed in [3u32, 7] {
                let fused = fwd(&net, &w, &input, ForwardMode::Stochastic { k, seed });
                let golden = reference::forward_stochastic(&net, &w, &input, k, seed);
                assert_eq!(fused, golden, "k={k} seed={seed}");
            }
        }
    }

    #[test]
    fn extended_ops_fused_matches_reference_bit_exactly() {
        // Strided conv, depthwise conv, residual add, avgpool, global
        // avgpool: the fused engine and the per-bit golden model lower the
        // same stage IR and must agree bit-for-bit.
        let net = extended_net();
        let w = seeded_weights(&net, 8, 17);
        let input = extended_input();
        for k in [32usize, 104] {
            for seed in [5u32, 11] {
                let fused = fwd(&net, &w, &input, ForwardMode::Stochastic { k, seed });
                let golden = reference::forward_stochastic(&net, &w, &input, k, seed);
                assert_eq!(fused, golden, "k={k} seed={seed}");
                assert_eq!(fused.len(), 3);
                assert!(fused.iter().all(|v| v.is_finite()));
            }
        }
    }

    /// Forward with an explicitly pinned kernel path (uniform k).
    fn fwd_kernel(
        net: &NetworkSpec,
        w: &QuantizedWeights,
        input: &[f64],
        k: usize,
        seed: u32,
        kernel: KernelPath,
        faults: Option<&crate::faults::FaultPlan>,
    ) -> Vec<f64> {
        let plan = PrecisionPlan::uniform(k, net.n_compute());
        ForwardPlan::compile_with_opts(
            net,
            w,
            ForwardMode::Stochastic { k, seed },
            &plan,
            faults,
            kernel,
        )
        .unwrap()
        .run(input)
    }

    #[test]
    fn kernel_paths_agree_bit_exactly_across_packing_boundaries() {
        // Fused, transposed, and per-bit reference on fan-ins (9, 18) and
        // stream lengths (104, 136) that are NOT multiples of 64 — the
        // tail-cycle and tail-lane handling of the transposed layout.
        let net = tiny_net();
        let w = tiny_weights(8, 42);
        let input = tiny_input();
        for k in [16usize, 64, 104, 136] {
            let fused = fwd_kernel(&net, &w, &input, k, 7, KernelPath::Fused, None);
            let tr = fwd_kernel(&net, &w, &input, k, 7, KernelPath::Transposed, None);
            assert_eq!(fused, tr, "k={k}");
            assert_eq!(tr, reference::forward_stochastic(&net, &w, &input, k, 7), "k={k}");
        }
    }

    #[test]
    fn transposed_kernel_covers_extended_ops_and_faults() {
        // Strided, depthwise (per-channel tiles), residual, pooling —
        // clean and under every fault class at once, including stuck
        // lanes inside the depthwise stage.
        let net = extended_net();
        let w = seeded_weights(&net, 8, 17);
        let input = extended_input();
        let f = crate::faults::FaultPlan::new(11)
            .with_bit_flip_rate(0.02)
            .with_stuck_lane(2, 1, false)
            .with_stuck_lane(1, 0, true)
            .with_sng_correlation_rate(0.2)
            .with_sram_upset_rate(0.05);
        for faults in [None, Some(&f)] {
            for k in [32usize, 104] {
                let fused = fwd_kernel(&net, &w, &input, k, 5, KernelPath::Fused, faults);
                let tr = fwd_kernel(&net, &w, &input, k, 5, KernelPath::Transposed, faults);
                assert_eq!(fused, tr, "k={k} faulted={}", faults.is_some());
                let plan = PrecisionPlan::uniform(k, net.n_compute());
                let golden = reference::forward_stochastic_plan_faulted(
                    &net, &w, &input, &plan, 5, faults,
                );
                assert_eq!(tr, golden, "k={k} faulted={}", faults.is_some());
            }
        }
    }

    #[test]
    fn transposed_kernel_crosses_lane_block_boundaries() {
        // Dense fan-ins straddling the 64-lane block width (63, 64, 65,
        // 130): tail lanes must contribute exactly zero.
        for inputs in [63usize, 64, 65, 130] {
            let net = NetworkSpec {
                name: format!("lanes-{inputs}"),
                input: (1, 1, inputs),
                layers: vec![
                    LayerSpec::active(LayerKind::Dense { inputs, outputs: 4 }),
                    LayerSpec::linear(LayerKind::Dense { inputs: 4, outputs: 2 }),
                ],
            };
            let w = seeded_weights(&net, 8, inputs as u64);
            let input: Vec<f64> = (0..inputs).map(|i| ((i % 11) as f64) / 11.0).collect();
            for k in [64usize, 104] {
                let fused = fwd_kernel(&net, &w, &input, k, 9, KernelPath::Fused, None);
                let tr = fwd_kernel(&net, &w, &input, k, 9, KernelPath::Transposed, None);
                assert_eq!(fused, tr, "inputs={inputs} k={k}");
                assert_eq!(
                    tr,
                    reference::forward_stochastic(&net, &w, &input, k, 9),
                    "inputs={inputs} k={k}"
                );
            }
        }
    }

    #[test]
    fn transposed_kernel_is_thread_count_invariant() {
        let net = extended_net();
        let w = seeded_weights(&net, 8, 23);
        let input = extended_input();
        let plan = PrecisionPlan::uniform(128, net.n_compute());
        let fp = ForwardPlan::compile_with_opts(
            &net,
            &w,
            ForwardMode::Stochastic { k: 128, seed: 3 },
            &plan,
            None,
            KernelPath::Transposed,
        )
        .unwrap();
        let mut scr = Scratch::default();
        let serial = fp.run_with_threads(&input, &mut scr, 1);
        for threads in [0usize, 2, 3] {
            assert_eq!(
                serial,
                fp.run_with_threads(&input, &mut scr, threads),
                "threads={threads}"
            );
        }
        let imgs = vec![input.clone(); 5];
        for out in fp.run_batch(&imgs) {
            assert_eq!(out, serial);
        }
    }

    #[test]
    fn extended_ops_run_in_every_mode() {
        let net = extended_net();
        let w = seeded_weights(&net, 8, 23);
        let input = extended_input();
        for mode in [
            ForwardMode::FixedPoint,
            ForwardMode::Expectation,
            ForwardMode::NoisyExpectation { k: 256, seed: 3 },
            ForwardMode::Stochastic { k: 64, seed: 3 },
        ] {
            let out = fwd(&net, &w, &input, mode);
            assert_eq!(out.len(), 3, "{mode:?}");
            assert!(out.iter().all(|v| v.is_finite()), "{mode:?}");
        }
    }

    #[test]
    fn mnist_strided_topology_runs_end_to_end() {
        let net = NetworkSpec::mnist_strided();
        let w = QuantizedWeights::synthetic(&net, 8, 0x5EED).unwrap();
        let input: Vec<f64> = (0..28 * 28).map(|i| ((i % 13) as f64) / 13.0).collect();
        let plan = ForwardPlan::new(&net, &w, ForwardMode::Stochastic { k: 32, seed: 7 });
        assert_eq!(plan.in_len(), 28 * 28);
        assert_eq!(plan.out_len(), 10);
        let fused = plan.run(&input);
        let golden = reference::forward_stochastic(&net, &w, &input, 32, 7);
        assert_eq!(fused, golden);
    }

    #[test]
    fn uniform_precision_plan_is_bit_exact_with_scalar_k() {
        // The back-compat contract: compiling through an explicit
        // Uniform-k PrecisionPlan is the same artifact as the scalar-k
        // path — bit-for-bit, fused and reference.
        let net = tiny_net();
        let w = tiny_weights(8, 42);
        let input = tiny_input();
        for k in [16usize, 64, 104] {
            let mode = ForwardMode::Stochastic { k, seed: 7 };
            let scalar = fwd(&net, &w, &input, mode);
            let plan = PrecisionPlan::uniform(k, 2);
            let planned = ForwardPlan::compile_with_precision(&net, &w, mode, &plan)
                .unwrap()
                .run(&input);
            assert_eq!(scalar, planned, "k={k}");
            assert_eq!(
                planned,
                reference::forward_stochastic_plan(&net, &w, &input, &plan, 7)
            );
        }
    }

    #[test]
    fn per_layer_plans_rescale_across_stage_boundaries_bit_exactly() {
        // Adjacent stages at different k: the fused engine and the
        // per-bit reference agree bit-for-bit through the S2B→B2S
        // rescaling boundary, on both the simple and extended stacks.
        let net = tiny_net();
        let w = tiny_weights(8, 42);
        let input = tiny_input();
        for ks in [vec![64usize, 16], vec![16, 104], vec![32, 32]] {
            let plan = PrecisionPlan::per_layer(ks.clone());
            let mode = ForwardMode::Stochastic { k: plan.max_k(), seed: 5 };
            let fused = ForwardPlan::compile_with_precision(&net, &w, mode, &plan)
                .unwrap()
                .run(&input);
            let golden = reference::forward_stochastic_plan(&net, &w, &input, &plan, 5);
            assert_eq!(fused, golden, "ks={ks:?}");
            assert!(fused.iter().all(|v| v.is_finite()));
        }
        let net = extended_net();
        let w = seeded_weights(&net, 8, 17);
        let input = extended_input();
        let plan = PrecisionPlan::per_layer(vec![96, 32, 64, 16]);
        let mode = ForwardMode::Stochastic { k: 96, seed: 11 };
        let plan_fwd = ForwardPlan::compile_with_precision(&net, &w, mode, &plan).unwrap();
        assert_eq!(plan_fwd.precision(), &plan);
        assert_eq!(
            plan_fwd.run(&input),
            reference::forward_stochastic_plan(&net, &w, &input, &plan, 11)
        );
    }

    #[test]
    fn compile_rejects_degenerate_bitstream_lengths() {
        let net = tiny_net();
        let w = tiny_weights(8, 1);
        let input_mode = |k| ForwardMode::Stochastic { k, seed: 1 };
        // k == 0 and word-misaligned k are typed errors, not kernel UB.
        for bad_k in [0usize, 100, 7] {
            let err = ForwardPlan::compile(&net, &w, input_mode(bad_k))
                .unwrap_err()
                .to_string();
            assert!(
                err.contains("k = 0") || err.contains("multiple"),
                "k={bad_k}: {err}"
            );
        }
        // A per-layer plan of the wrong length is rejected too.
        let plan = PrecisionPlan::per_layer(vec![32]);
        let err = ForwardPlan::compile_with_precision(&net, &w, input_mode(32), &plan)
            .unwrap_err()
            .to_string();
        assert!(err.contains("compute layers"), "{err}");
        // NoisyExpectation is k-sensitive and validated the same way...
        assert!(ForwardPlan::compile(
            &net,
            &w,
            ForwardMode::NoisyExpectation { k: 100, seed: 1 }
        )
        .is_err());
        // ...while the analytic modes own no k and ignore the plan length.
        assert!(ForwardPlan::compile(&net, &w, ForwardMode::Expectation).is_ok());
        assert!(ForwardPlan::compile(&net, &w, ForwardMode::FixedPoint).is_ok());
    }

    #[test]
    fn compile_rejects_malformed_input_without_panicking() {
        // Wrong weight-layer count.
        let net = tiny_net();
        let mut w = tiny_weights(8, 1);
        w.layers.pop();
        let err = ForwardPlan::compile(&net, &w, ForwardMode::Expectation)
            .unwrap_err()
            .to_string();
        assert!(err.contains("compute layers"), "{err}");
        // Wrong fan-in on one row.
        let mut w = tiny_weights(8, 1);
        w.layers[1].codes[2].pop();
        let err = ForwardPlan::compile(&net, &w, ForwardMode::Expectation)
            .unwrap_err()
            .to_string();
        assert!(err.contains("fan-in"), "{err}");
        // Invalid network (non-divisible pool) surfaces the shape error.
        let bad = NetworkSpec {
            name: "bad".into(),
            input: (1, 7, 7),
            layers: vec![
                LayerSpec::active(LayerKind::conv(1, 2, 1, 0)),
                LayerSpec::linear(LayerKind::MaxPool { size: 2 }),
            ],
        };
        let err = ForwardPlan::compile(&bad, &tiny_weights(8, 1), ForwardMode::Expectation)
            .unwrap_err()
            .to_string();
        assert!(err.contains("does not divide"), "{err}");
    }

    #[test]
    fn compile_rejects_fault_sites_outside_the_plan() {
        let net = tiny_net();
        let w = tiny_weights(8, 1);
        let mode = ForwardMode::Stochastic { k: 32, seed: 1 };
        let plan = PrecisionPlan::uniform(32, 2);
        // tiny_net compute layers: conv fan-in 9, dense fan-in 18.
        for (bad, needle) in [
            (FaultPlan::new(1).with_stuck_lane(0, 9, true), "fan-in"),
            (FaultPlan::new(1).with_stuck_lane(1, 18, false), "fan-in"),
            (FaultPlan::new(1).with_stuck_lane(2, 0, true), "compute layers"),
        ] {
            let err = ForwardPlan::compile_with_precision_faults(
                &net,
                &w,
                mode,
                &plan,
                Some(&bad),
            )
            .unwrap_err()
            .to_string();
            assert!(err.contains(needle), "{err}");
        }
        // The boundary sites compile.
        let ok = FaultPlan::new(1).with_stuck_lane(0, 8, true).with_stuck_lane(1, 17, false);
        assert!(ForwardPlan::compile_with_precision_faults(&net, &w, mode, &plan, Some(&ok))
            .is_ok());
    }

    #[test]
    fn forward_batch_matches_single_image_forward() {
        let net = tiny_net();
        let w = tiny_weights(8, 21);
        let inputs: Vec<Vec<f64>> = (0..5)
            .map(|s| (0..36).map(|i| (((i + s * 5) % 9) as f64) / 9.0).collect())
            .collect();
        for mode in [
            ForwardMode::FixedPoint,
            ForwardMode::Expectation,
            ForwardMode::NoisyExpectation { k: 256, seed: 5 },
            ForwardMode::Stochastic { k: 96, seed: 11 },
        ] {
            let batch = fwd_batch(&net, &w, &inputs, mode);
            assert_eq!(batch.len(), inputs.len());
            for (i, input) in inputs.iter().enumerate() {
                let single = fwd(&net, &w, input, mode);
                assert_eq!(batch[i], single, "{mode:?} image {i}");
            }
        }
    }

    #[test]
    fn plan_and_scratch_reuse_are_deterministic() {
        let net = tiny_net();
        let w = tiny_weights(8, 9);
        let plan = ForwardPlan::new(&net, &w, ForwardMode::Stochastic { k: 32, seed: 2 });
        assert_eq!(plan.in_len(), 36);
        assert_eq!(plan.out_len(), 3);
        let mut scr = Scratch::default();
        let a = plan.run_with(&tiny_input(), &mut scr, true);
        let b = plan.run_with(&tiny_input(), &mut scr, false);
        let c = plan.run(&tiny_input());
        assert_eq!(a, b);
        assert_eq!(a, c);
    }

    #[test]
    fn scratch_reuse_covers_residual_saves() {
        // The saved-branch buffers must reset between images: two different
        // images through one scratch arena give the same answers as fresh
        // arenas.
        let net = extended_net();
        let w = seeded_weights(&net, 8, 31);
        let plan = ForwardPlan::new(&net, &w, ForwardMode::Stochastic { k: 48, seed: 9 });
        let imgs: Vec<Vec<f64>> =
            (0..3).map(|s| (0..64).map(|i| (((i + s * 7) % 11) as f64) / 11.0).collect()).collect();
        let mut scr = Scratch::default();
        for img in &imgs {
            let reused = plan.run_with(img, &mut scr, false);
            assert_eq!(reused, plan.run(img));
        }
    }

    #[test]
    fn timed_run_is_bit_identical_and_labels_stages() {
        let net = extended_net();
        let w = seeded_weights(&net, 8, 13);
        let plan = ForwardPlan::new(&net, &w, ForwardMode::Stochastic { k: 32, seed: 1 });
        let mut scr = Scratch::default();
        let mut timings = Vec::new();
        let timed = plan.run_with_timings(&extended_input(), &mut scr, 1, &mut timings);
        assert_eq!(timed, plan.run(&extended_input()));
        let labels: Vec<&str> = timings.iter().map(|t| t.label).collect();
        assert_eq!(
            labels,
            vec!["conv", "depthwise-conv", "add", "avgpool", "conv", "global-avgpool", "dense"]
        );
        let indices: Vec<usize> = timings.iter().map(|t| t.layer).collect();
        assert_eq!(indices, (0..7).collect::<Vec<_>>());
        // Dense plan: every compute stage reports executed ops, none
        // skipped; pure data-movement stages report (0, 0).
        for t in &timings {
            assert_eq!(t.ops_skipped, 0, "{}", t.label);
            match t.label {
                "add" | "avgpool" | "global-avgpool" => assert_eq!(t.ops_executed, 0),
                _ => assert!(t.ops_executed > 0, "{}", t.label),
            }
        }
        let (exec, skip) = plan.ops_per_image();
        assert_eq!(exec, timings.iter().map(|t| t.ops_executed).sum::<u64>());
        assert_eq!(skip, 0);
        assert_eq!(plan.stage_densities(), vec![1.0; net.n_compute()]);
    }

    #[test]
    fn stochastic_approaches_expectation_with_length() {
        let net = tiny_net();
        let w = tiny_weights(8, 11);
        let input = tiny_input();
        let exp = fwd(&net, &w, &input, ForwardMode::Expectation);
        let err_at = |k: usize| -> f64 {
            let st = fwd(&net, &w, &input, ForwardMode::Stochastic { k, seed: 3 });
            st.iter().zip(&exp).map(|(a, b)| (a - b).abs()).sum::<f64>() / exp.len() as f64
        };
        let e16 = err_at(16);
        let e256 = err_at(256);
        assert!(
            e256 < e16 * 0.8,
            "longer bitstreams must track expectation better: e16={e16} e256={e256}"
        );
        // Logits live in the sp domain (scale 2^m ≈ 32 for fan-in 18), so
        // the stochastic noise floor is ~32× a stream-value error.
        assert!(e256 < 3.0, "e256={e256}");
    }

    #[test]
    fn classification_agrees_between_expectation_and_long_stochastic() {
        // Sampling noise at k=4096 is ~0.01 in stream value; only
        // decisions with a larger expectation margin are required to agree.
        let net = tiny_net();
        let w = tiny_weights(8, 5);
        let mut decided = 0;
        let mut agree = 0;
        for s in 0..20 {
            let input: Vec<f64> = (0..36).map(|i| (((i + s * 3) % 9) as f64) / 9.0).collect();
            let exp = fwd(&net, &w, &input, ForwardMode::Expectation);
            let e = classify(&exp);
            let mut sorted = exp.clone();
            sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let margin = sorted[0] - sorted[1];
            if margin < 0.02 {
                continue; // below the stochastic noise floor — a coin flip
            }
            decided += 1;
            let st = classify(&fwd(
                &net,
                &w,
                &input,
                ForwardMode::Stochastic { k: 4096, seed: 1 + s as u32 },
            ));
            agree += (e == st) as usize;
        }
        assert!(decided >= 3, "test needs decidable cases, got {decided}");
        assert!(
            agree * 10 >= decided * 8,
            "agreement {agree}/{decided} on decided cases"
        );
    }

    #[test]
    fn expectation_monotone_in_bitwidth_fidelity() {
        // Higher quantization precision must not change the fixed-point
        // prediction drastically: 8-bit and 7-bit agree on argmax usually.
        let net = tiny_net();
        let input = tiny_input();
        let mut agree = 0;
        for seed in 0..10u64 {
            let w8 = tiny_weights(8, 100 + seed);
            let p8 = classify(&fwd(&net, &w8, &input, ForwardMode::FixedPoint));
            // Re-quantize same real weights at 6 bits by code shifting.
            let w6 = QuantizedWeights {
                bits: 6,
                layers: w8
                    .layers
                    .iter()
                    .map(|l| LayerWeights {
                        codes: l
                            .codes
                            .iter()
                            .map(|n| n.iter().map(|&c| c >> 2).collect())
                            .collect(),
                        gamma: l.gamma,
                        mu: l.mu,
                    })
                    .collect(),
            };
            let p6 = classify(&fwd(&net, &w6, &input, ForwardMode::FixedPoint));
            agree += (p8 == p6) as usize;
        }
        assert!(agree >= 7, "agreement {agree}");
    }

    /// Fused forward under a fault plan (uniform k).
    fn fwd_faulted(
        net: &NetworkSpec,
        w: &QuantizedWeights,
        input: &[f64],
        k: usize,
        seed: u32,
        f: &crate::faults::FaultPlan,
    ) -> Vec<f64> {
        let plan = PrecisionPlan::uniform(k, net.n_compute());
        ForwardPlan::compile_with_precision_faults(
            net,
            w,
            ForwardMode::Stochastic { k, seed },
            &plan,
            Some(f),
        )
        .unwrap()
        .run(input)
    }

    #[test]
    fn fused_matches_reference_under_every_fault_class() {
        use crate::faults::FaultPlan;
        let net = tiny_net();
        let w = tiny_weights(8, 42);
        let input = tiny_input();
        let plans = [
            FaultPlan::new(1).with_bit_flip_rate(0.02),
            FaultPlan::new(2).with_stuck_lane(0, 4, true).with_stuck_lane(1, 2, false),
            FaultPlan::new(3).with_sng_correlation_rate(0.3),
            FaultPlan::new(4).with_sram_upset_rate(0.2),
            // Everything at once, across the word boundary.
            FaultPlan::new(5)
                .with_bit_flip_rate(0.01)
                .with_stuck_lane(1, 0, true)
                .with_sng_correlation_rate(0.15)
                .with_sram_upset_rate(0.1),
        ];
        for f in &plans {
            for k in [64usize, 104] {
                let fused = fwd_faulted(&net, &w, &input, k, 7, f);
                let precision = PrecisionPlan::uniform(k, 2);
                let golden = reference::forward_stochastic_plan_faulted(
                    &net,
                    &w,
                    &input,
                    &precision,
                    7,
                    Some(f),
                );
                assert_eq!(fused, golden, "faults={f:?} k={k}");
                assert!(fused.iter().all(|v| v.is_finite()));
            }
        }
    }

    #[test]
    fn extended_ops_stay_bit_exact_under_faults() {
        use crate::faults::FaultPlan;
        let net = extended_net();
        let w = seeded_weights(&net, 8, 17);
        let input = extended_input();
        let f = FaultPlan::new(11)
            .with_bit_flip_rate(0.02)
            .with_stuck_lane(2, 1, false)
            .with_sng_correlation_rate(0.2)
            .with_sram_upset_rate(0.05);
        let precision = PrecisionPlan::per_layer(vec![96, 32, 64, 16]);
        let mode = ForwardMode::Stochastic { k: 96, seed: 9 };
        let fused = ForwardPlan::compile_with_precision_faults(
            &net,
            &w,
            mode,
            &precision,
            Some(&f),
        )
        .unwrap()
        .run(&input);
        let golden = reference::forward_stochastic_plan_faulted(
            &net,
            &w,
            &input,
            &precision,
            9,
            Some(&f),
        );
        assert_eq!(fused, golden);
    }

    #[test]
    fn noop_fault_plan_compiles_to_the_clean_datapath() {
        use crate::faults::FaultPlan;
        let net = tiny_net();
        let w = tiny_weights(8, 42);
        let input = tiny_input();
        let clean = fwd(&net, &w, &input, ForwardMode::Stochastic { k: 64, seed: 3 });
        let noop = FaultPlan::new(123);
        assert_eq!(clean, fwd_faulted(&net, &w, &input, 64, 3, &noop));
    }

    #[test]
    fn faulted_runs_are_deterministic_and_seed_keyed() {
        use crate::faults::FaultPlan;
        let net = tiny_net();
        let w = tiny_weights(8, 42);
        let input = tiny_input();
        let f = FaultPlan::new(7).with_bit_flip_rate(0.05);
        let a = fwd_faulted(&net, &w, &input, 64, 3, &f);
        let b = fwd_faulted(&net, &w, &input, 64, 3, &f);
        assert_eq!(a, b, "same plan, same output");
        // At 5% flips over every lane of a 36-site layer, two different
        // fault seeds producing identical outputs is astronomically
        // unlikely — and a heavily faulted run differs from clean.
        let c = fwd_faulted(&net, &w, &input, 64, 3, &FaultPlan::new(8).with_bit_flip_rate(0.05));
        assert_ne!(a, c, "fault seed keys the injection");
        let clean = fwd(&net, &w, &input, ForwardMode::Stochastic { k: 64, seed: 3 });
        assert_ne!(a, clean, "5% stream flips must perturb the output");
    }

    #[test]
    fn analytic_modes_take_code_flips_through_the_same_plan() {
        use crate::faults::FaultPlan;
        let net = tiny_net();
        let w = tiny_weights(8, 42);
        let input = tiny_input();
        let f = FaultPlan::new(21).with_bit_flip_rate(0.05);
        for mode in [ForwardMode::Expectation, ForwardMode::FixedPoint] {
            let plan = PrecisionPlan::uniform(precision::WORD, 2);
            let faulted = ForwardPlan::compile_with_precision_faults(
                &net,
                &w,
                mode,
                &plan,
                Some(&f),
            )
            .unwrap()
            .run(&input);
            let clean = fwd(&net, &w, &input, mode);
            assert_ne!(faulted, clean, "{mode:?}: code flips must land");
            assert!(faulted.iter().all(|v| v.is_finite()));
            // Deterministic here too.
            let again = ForwardPlan::compile_with_precision_faults(
                &net,
                &w,
                mode,
                &plan,
                Some(&f),
            )
            .unwrap()
            .run(&input);
            assert_eq!(faulted, again);
        }
    }

    #[test]
    fn classify_picks_argmax() {
        assert_eq!(classify(&[0.1, 0.9, -0.3]), 1);
        assert_eq!(classify(&[-5.0, -2.0, -9.0]), 1);
    }

    // ------------------------------------------------------------------
    // Sparsity: compile-time pruning + runtime zero-tile short-circuit.
    // ------------------------------------------------------------------

    /// Forward through `compile_with_sparsity` with a pinned kernel.
    #[allow(clippy::too_many_arguments)]
    fn fwd_sparse(
        net: &NetworkSpec,
        w: &QuantizedWeights,
        input: &[f64],
        k: usize,
        seed: u32,
        kernel: KernelPath,
        faults: Option<&crate::faults::FaultPlan>,
        threshold: f64,
    ) -> Vec<f64> {
        let plan = PrecisionPlan::uniform(k, net.n_compute());
        ForwardPlan::compile_with_sparsity(
            net,
            w,
            ForwardMode::Stochastic { k, seed },
            &plan,
            faults,
            kernel,
            SparsityPolicy::threshold(threshold),
        )
        .unwrap()
        .run(input)
    }

    /// Zero out the same lane positions across every output channel of
    /// each layer — channel-structured sparsity, the shape real pruning
    /// schedules produce and the transposed shared-tile fast path keeps.
    fn structured_zeroed(mut w: QuantizedWeights, lanes: &[usize]) -> QuantizedWeights {
        let zero = quantize_bipolar(0.0, w.bits);
        for l in &mut w.layers {
            for row in &mut l.codes {
                for &j in lanes {
                    if j < row.len() {
                        row[j] = zero;
                    }
                }
            }
        }
        w
    }

    #[test]
    fn sparse_kernels_match_reference_structured() {
        // Structured zeros (same lanes across all channels): survivors
        // stay channel-shared, so the transposed kernel keeps its shared
        // tiles and must still agree with fused and per-bit reference.
        let net = tiny_net();
        let w = structured_zeroed(tiny_weights(8, 42), &[1, 4, 7]);
        let input = tiny_input();
        let sp = SparsityPolicy::threshold(0.05);
        let stats = prune_stats(&w, sp);
        assert!(stats.iter().all(|s| s.min_fan_in > 0));
        assert!(stats.iter().any(|s| s.pruned > 0), "zeros must actually prune");
        for k in [64usize, 104] {
            let plan = PrecisionPlan::uniform(k, net.n_compute());
            let golden = reference::forward_stochastic_plan_sparse(
                &net, &w, &input, &plan, 7, None, sp,
            );
            for kernel in [KernelPath::Fused, KernelPath::Transposed, KernelPath::Auto] {
                let got = fwd_sparse(&net, &w, &input, k, 7, kernel, None, 0.05);
                assert_eq!(got, golden, "k={k} kernel={kernel:?}");
            }
        }
    }

    #[test]
    fn sparse_kernels_match_reference_unstructured_with_faults() {
        // Unstructured magnitude pruning (different survivors per
        // channel) on the extended stack, clean and under every fault
        // class at once. Auto resolves shared-window pruned stages to the
        // fused skip-list kernel; a pinned transposed plan re-tiles per
        // channel — all must agree with the per-bit reference.
        let net = extended_net();
        let w = seeded_weights(&net, 8, 17);
        let input = extended_input();
        let sp = SparsityPolicy::threshold(0.12);
        let stats = prune_stats(&w, sp);
        assert!(stats.iter().all(|s| s.min_fan_in > 0), "{stats:?}");
        assert!(stats.iter().any(|s| s.pruned > 0), "{stats:?}");
        let f = crate::faults::FaultPlan::new(11)
            .with_bit_flip_rate(0.02)
            .with_stuck_lane(2, 1, false)
            .with_stuck_lane(1, 0, true)
            .with_sng_correlation_rate(0.2)
            .with_sram_upset_rate(0.05);
        for faults in [None, Some(&f)] {
            for k in [32usize, 104] {
                let plan = PrecisionPlan::uniform(k, net.n_compute());
                let golden = reference::forward_stochastic_plan_sparse(
                    &net, &w, &input, &plan, 5, faults, sp,
                );
                for kernel in [KernelPath::Fused, KernelPath::Transposed, KernelPath::Auto] {
                    let got = fwd_sparse(&net, &w, &input, k, 5, kernel, faults, 0.12);
                    assert_eq!(
                        got,
                        golden,
                        "k={k} kernel={kernel:?} faulted={}",
                        faults.is_some()
                    );
                }
            }
        }
    }

    #[test]
    fn sparse_pruning_crosses_lane_block_boundaries() {
        // Fan-in 130 pruned down across the 64-lane block width: the
        // re-packed survivor blocks and their tail padding must stay
        // bit-exact through the transposed layout.
        let inputs = 130usize;
        let net = NetworkSpec {
            name: "sparse-lanes".into(),
            input: (1, 1, inputs),
            layers: vec![
                LayerSpec::active(LayerKind::Dense { inputs, outputs: 4 }),
                LayerSpec::linear(LayerKind::Dense { inputs: 4, outputs: 2 }),
            ],
        };
        let w = seeded_weights(&net, 8, 130);
        let input: Vec<f64> = (0..inputs).map(|i| ((i % 11) as f64) / 11.0).collect();
        let sp = SparsityPolicy::threshold(0.3);
        assert!(prune_stats(&w, sp).iter().all(|s| s.min_fan_in > 0));
        for k in [64usize, 104] {
            let plan = PrecisionPlan::uniform(k, net.n_compute());
            let golden =
                reference::forward_stochastic_plan_sparse(&net, &w, &input, &plan, 9, None, sp);
            let fused = fwd_sparse(&net, &w, &input, k, 9, KernelPath::Fused, None, 0.3);
            let tr = fwd_sparse(&net, &w, &input, k, 9, KernelPath::Transposed, None, 0.3);
            assert_eq!(fused, golden, "k={k}");
            assert_eq!(tr, golden, "k={k}");
        }
    }

    #[test]
    fn threshold_zero_reproduces_dense_plans_bit_for_bit() {
        // The back-compat anchor: SparsityPolicy::OFF is the identity.
        let net = extended_net();
        let w = seeded_weights(&net, 8, 17);
        let input = extended_input();
        for kernel in [KernelPath::Fused, KernelPath::Transposed, KernelPath::Auto] {
            let dense = fwd_kernel(&net, &w, &input, 64, 5, kernel, None);
            let sparse0 = fwd_sparse(&net, &w, &input, 64, 5, kernel, None, 0.0);
            assert_eq!(dense, sparse0, "kernel={kernel:?}");
        }
        let plan = PrecisionPlan::uniform(64, net.n_compute());
        assert_eq!(
            reference::forward_stochastic_plan_faulted(&net, &w, &input, &plan, 5, None),
            reference::forward_stochastic_plan_sparse(
                &net,
                &w,
                &input,
                &plan,
                5,
                None,
                SparsityPolicy::OFF
            ),
        );
    }

    #[test]
    fn analytic_modes_take_pruning_through_the_same_plan() {
        // Expectation / FixedPoint / NoisyExpectation skip pruned lanes
        // and fold the bias from surviving fan-in — pruning must move the
        // analytic output (the pruned lanes carried nonzero weight mass
        // at threshold 0.12) while staying finite and deterministic.
        let net = tiny_net();
        let w = tiny_weights(8, 42);
        let input = tiny_input();
        let sp = SparsityPolicy::threshold(0.12);
        assert!(prune_stats(&w, sp).iter().any(|s| s.pruned > 0));
        for mode in [
            ForwardMode::Expectation,
            ForwardMode::FixedPoint,
            ForwardMode::NoisyExpectation { k: 256, seed: 5 },
        ] {
            let plan = PrecisionPlan::uniform(256, net.n_compute());
            let run = |t: f64| {
                ForwardPlan::compile_with_sparsity(
                    &net,
                    &w,
                    mode,
                    &plan,
                    None,
                    KernelPath::Auto,
                    SparsityPolicy::threshold(t),
                )
                .unwrap()
                .run(&input)
            };
            let sparse = run(0.12);
            assert!(sparse.iter().all(|v| v.is_finite()), "{mode:?}");
            assert_eq!(sparse, run(0.12), "{mode:?} must be deterministic");
            assert_ne!(sparse, run(0.0), "{mode:?} pruning must take effect");
        }
    }

    #[test]
    fn degenerate_sparsity_thresholds_are_typed_errors() {
        let net = tiny_net();
        let w = tiny_weights(8, 42);
        let mode = ForwardMode::Stochastic { k: 64, seed: 1 };
        let plan = PrecisionPlan::uniform(64, 2);
        let compile = |sp: SparsityPolicy| {
            ForwardPlan::compile_with_sparsity(
                &net,
                &w,
                mode,
                &plan,
                None,
                KernelPath::Auto,
                sp,
            )
        };
        for (t, needle) in [
            (-0.1, ">= 0.0"),
            (1.0, "< 1.0"),
            (1.5, "< 1.0"),
            (f64::NAN, "finite"),
        ] {
            let err = compile(SparsityPolicy::threshold(t)).unwrap_err().to_string();
            assert!(err.contains(needle), "t={t}: {err}");
        }
        // A threshold that prunes an entire output channel to fan-in 0 is
        // a compile error naming the channel, not a silent dead neuron.
        let dead = structured_zeroed(tiny_weights(8, 42), &[0, 1, 2, 3, 4, 5, 6, 7, 8]);
        assert!(
            compile(SparsityPolicy::threshold(0.05)).is_ok(),
            "baseline weights must compile"
        );
        let err = ForwardPlan::compile_with_sparsity(
            &net,
            &dead,
            mode,
            &plan,
            None,
            KernelPath::Auto,
            SparsityPolicy::threshold(0.05),
        )
        .unwrap_err()
        .to_string();
        assert!(err.contains("fan-in 0"), "{err}");
    }

    #[test]
    fn zero_activation_tiles_short_circuit_bit_exactly() {
        // Bipolar −1.0 activations quantize to code 0 → all-zero SC
        // streams → all-zero transposed tiles, the case the closed-form
        // zero-tile count short-circuits. All-zero and mixed inputs, with
        // and without stream faults (a flipped bit revives a tile; the
        // shortcut keys on actual content), must stay bit-exact.
        let net = tiny_net();
        let w = tiny_weights(8, 42);
        let all_neg = vec![-1.0f64; 36];
        let mut mixed = tiny_input();
        for (i, v) in mixed.iter_mut().enumerate() {
            if i % 3 == 0 {
                *v = -1.0;
            }
        }
        let f = crate::faults::FaultPlan::new(3).with_bit_flip_rate(0.02);
        for input in [&all_neg, &mixed] {
            for faults in [None, Some(&f)] {
                for k in [64usize, 104] {
                    let fused = fwd_kernel(&net, &w, input, k, 7, KernelPath::Fused, faults);
                    let tr = fwd_kernel(&net, &w, input, k, 7, KernelPath::Transposed, faults);
                    assert_eq!(fused, tr, "k={k} faulted={}", faults.is_some());
                    // And with weight pruning layered on top.
                    let sf = fwd_sparse(&net, &w, input, k, 7, KernelPath::Fused, faults, 0.12);
                    let st =
                        fwd_sparse(&net, &w, input, k, 7, KernelPath::Transposed, faults, 0.12);
                    assert_eq!(sf, st, "sparse k={k} faulted={}", faults.is_some());
                }
            }
        }
    }

    #[test]
    fn pruned_plans_report_ops_and_densities() {
        let net = tiny_net();
        let w = structured_zeroed(tiny_weights(8, 42), &[1, 4, 7]);
        let input = tiny_input();
        let mode = ForwardMode::Stochastic { k: 64, seed: 7 };
        let plan = PrecisionPlan::uniform(64, 2);
        let compile = |t: f64, kernel: KernelPath| {
            ForwardPlan::compile_with_sparsity(
                &net,
                &w,
                mode,
                &plan,
                None,
                kernel,
                SparsityPolicy::threshold(t),
            )
            .unwrap()
        };
        let dense = compile(0.0, KernelPath::Transposed);
        let sparse = compile(0.05, KernelPath::Transposed);
        let (de, ds) = dense.ops_per_image();
        let (se, ss) = sparse.ops_per_image();
        assert_eq!(ds, 0);
        assert_eq!(se + ss, de, "pruned work moves to skipped, never vanishes");
        assert!(ss > 0 && se < de);
        // Lanes {1, 4, 7} were zeroed in every channel of both layers.
        let densities = sparse.stage_densities();
        assert_eq!(densities.len(), 2);
        assert!(densities[0] < 1.0);
        assert_eq!(dense.stage_densities(), vec![1.0, 1.0]);
        // Runtime accounting: a −1.0 input zeroes activation tiles, so
        // the transposed run reports extra skipped ops on top of the
        // static pruned count — and exec+skip stays conserved.
        let mut scr = Scratch::default();
        let mut timings = Vec::new();
        let all_neg = vec![-1.0f64; 36];
        sparse.run_with_timings(&all_neg, &mut scr, 1, &mut timings);
        let texec: u64 = timings.iter().map(|t| t.ops_executed).sum();
        let tskip: u64 = timings.iter().map(|t| t.ops_skipped).sum();
        assert_eq!(texec + tskip, de);
        assert!(tskip > ss, "zero activation tiles must add runtime skips");
        // A no-zero input reports exactly the static split.
        timings.clear();
        sparse.run_with_timings(&input, &mut scr, 1, &mut timings);
        assert_eq!(timings.iter().map(|t| t.ops_skipped).sum::<u64>(), ss);
    }

    #[test]
    fn prune_stats_and_densities_are_consistent() {
        let w = tiny_weights(8, 42);
        let off = prune_stats(&w, SparsityPolicy::OFF);
        assert!(off.iter().all(|s| s.pruned == 0 && (s.density() - 1.0).abs() < 1e-12));
        let sp = SparsityPolicy::threshold(0.2);
        let stats = prune_stats(&w, sp);
        let dens = weight_densities(&w, sp);
        assert_eq!(stats.len(), 2);
        for (s, d) in stats.iter().zip(&dens) {
            assert_eq!(s.density(), *d);
            assert!(s.min_fan_in <= s.fan_in);
            assert!((s.lanes - s.pruned) as f64 / s.lanes as f64 == *d);
        }
        // validate() accepts the whole legal range.
        assert!(SparsityPolicy::OFF.validate().is_ok());
        assert!(SparsityPolicy::threshold(0.999).validate().is_ok());
        assert!(!SparsityPolicy::threshold(0.1).is_off());
        // The exact-zero code is pruned at any positive threshold; the
        // policy is strict-<, so threshold 0 prunes nothing.
        let zero = quantize_bipolar(0.0, 8);
        assert!(SparsityPolicy::threshold(1e-9).prunes(zero, 8));
        assert!(!SparsityPolicy::OFF.prunes(zero, 8));
    }
}
