//! Bit-exact SCNN inference (§V-B): the full stochastic datapath — SNG →
//! XNOR → APC → B2S → ReLU/MP → S2B — executed layer by layer on packed
//! bitstreams. This is the engine behind Fig. 11/12 and the validation path
//! of the serving coordinator.
//!
//! A fixed-point (non-stochastic) forward pass over the *same* quantized
//! weights provides the "binary NN" baseline of Fig. 12, and an
//! expectation-mode forward (the SC math model without sampling noise)
//! mirrors `python/compile/model.py`.

use crate::accel::layers::{LayerKind, NetworkSpec, Shape};
use crate::sc::bitstream::{Bitstream, VerticalCounter};
use crate::sc::lfsr::Lfsr;
use crate::sc::neuron;
use crate::sc::pcc::{pcc_bit, PccKind};
use crate::sc::{dequantize_bipolar, quantize_bipolar};

/// One compute layer's quantized weights plus its re-encoder affine.
///
/// The S2B counter recovers `sp = (v+1)*2^m - n` (= the smoothed-ReLU of
/// the pre-activation); the binary-domain re-encoder then applies
/// `a_next = clip(g*(sp - mu), 0, 1)` before the next layer's SNG — the
/// programmable-scale B2S/SNG boundary, trained jointly with the weights
/// in `python/compile/model.py` (same math, same constants).
#[derive(Debug, Clone)]
pub struct LayerWeights {
    /// `[neuron][fan_in]` bipolar weight codes.
    pub codes: Vec<Vec<u32>>,
    /// Re-encoder gain.
    pub gamma: f64,
    /// Re-encoder offset.
    pub mu: f64,
}

/// Quantized network weights: per compute layer, `[neuron][fan_in]` bipolar
/// codes at the system precision.
#[derive(Debug, Clone)]
pub struct QuantizedWeights {
    /// Precision in bits.
    pub bits: u32,
    /// Per compute-layer weights.
    pub layers: Vec<LayerWeights>,
}

/// How a forward pass is executed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ForwardMode {
    /// Full bit-exact stochastic simulation with bitstream length k.
    Stochastic { k: usize, seed: u32 },
    /// SC expectation model (no sampling noise) — matches the JAX model.
    Expectation,
    /// Expectation model + analytic k-cycle sampling noise — the paper's
    /// own Fig. 11/12 methodology ("the mathematical model of SC is
    /// encapsulated as a Python function" §V-B): the neuron value is the
    /// expectation perturbed by the binomial noise of a k-cycle stream.
    NoisyExpectation { k: usize, seed: u32 },
    /// Plain fixed-point MAC + hard ReLU (the Fig. 12 baseline).
    FixedPoint,
}

/// Random sequences for one layer's stream generation.
struct LayerRandoms {
    /// B2S comparison randoms, uniform over 2^(m+1), shared across the
    /// layer's neurons (the ReLU/MaxPool correlation of Fig. 2).
    r4: Vec<u32>,
}

/// One operand lane's comparator-PCC stream from an *ideal* per-lane
/// random source (splitmix/xorshift seeded by lane).
///
/// Faithfulness note (DESIGN.md §Substitutions): the paper's accuracy
/// experiments run a mathematical SC model inside PyTorch — not a
/// gate-exact netlist replay — so per-lane ideal randomness is the same
/// abstraction level. Physically it corresponds to per-PCC decorrelated
/// RNS (shuffled LFSR networks, or the MTJ true-random sources of [14]);
/// naive sharing of one m-sequence across lanes correlates the XNOR
/// products and biases every neuron (tested in `sng`/`network` tests).
fn lane_stream(code: u32, bits: u32, k: usize, base: u32, lane: u64) -> Bitstream {
    let mut s = (base as u64) ^ lane.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    // splitmix64 scramble so consecutive lanes are far apart.
    s = (s ^ (s >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    s = (s ^ (s >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    let mut state = (s ^ (s >> 31)) | 1;
    let mask = (1u32 << bits) - 1;
    Bitstream::from_fn(k, |_| {
        state ^= state << 13;
        state ^= state >> 7;
        state ^= state << 17;
        code > ((state as u32) & mask)
    })
}

/// Bit-reverse the low `bits` bits of `t` (van der Corput sequence) —
/// in hardware: a counter with reversed output wiring.
fn bit_reverse(t: u32, bits: u32) -> u32 {
    t.reverse_bits() >> (32 - bits)
}

fn layer_randoms(_bits: u32, n: usize, k: usize, seed: u32) -> LayerRandoms {
    // B2S r4: a van der Corput (bit-reversed counter) sequence over the
    // 2^(m+1) comparison domain — balanced/stratified for ANY bitstream
    // length, deterministic, and shared across the layer's neurons (the
    // ReLU/MaxPool correlation of Fig. 2). An LFSR here is a trap: its
    // 2^w − 1 period never divides k, so wide layers (m+1 = 9..11) sample
    // half a period and inherit a large threshold skew.
    let m1 = neuron::m_bits(n) + 1;
    let offset = seed % (1u32 << m1);
    let r4 = (0..k as u32)
        .map(|t| bit_reverse(t.wrapping_add(offset) & ((1 << m1) - 1), m1))
        .collect();
    LayerRandoms { r4 }
}

/// Im2col-style gather: the flat input indices feeding each output neuron
/// of a conv layer (None = zero padding), plus neurons-per-output-channel
/// bookkeeping handled by the caller.
fn conv_gather(
    input: Shape,
    kernel: usize,
    padding: usize,
) -> (Vec<Vec<Option<usize>>>, usize, usize) {
    let (c, h, w) = input;
    let oh = h + 2 * padding - kernel + 1;
    let ow = w + 2 * padding - kernel + 1;
    let mut windows = Vec::with_capacity(oh * ow);
    for oy in 0..oh {
        for ox in 0..ow {
            let mut idx = Vec::with_capacity(c * kernel * kernel);
            for ic in 0..c {
                for ky in 0..kernel {
                    for kx in 0..kernel {
                        let iy = oy + ky;
                        let ix = ox + kx;
                        if iy < padding || ix < padding || iy - padding >= h || ix - padding >= w
                        {
                            idx.push(None);
                        } else {
                            idx.push(Some(ic * h * w + (iy - padding) * w + (ix - padding)));
                        }
                    }
                }
            }
            windows.push(idx);
        }
    }
    (windows, oh, ow)
}

/// One inference through the SCNN.
///
/// `input`: bipolar values in [−1, 1], flattened (c·h·w). Returns the
/// output-layer values (bipolar stream values for stochastic/expectation
/// modes; raw pre-activation sums for fixed-point).
pub fn forward(
    net: &NetworkSpec,
    weights: &QuantizedWeights,
    input: &[f64],
    mode: ForwardMode,
) -> Vec<f64> {
    let bits = weights.bits;
    let mut act: Vec<f64> = input.to_vec();
    let mut shape = net.input;
    let mut wl = 0usize; // compute-layer index
    let mut li = 0usize;
    while li < net.layers.len() {
        let layer = &net.layers[li];
        match &layer.kind {
            LayerKind::Conv { out_ch, kernel, padding, .. } => {
                // Fuse a following MaxPool into this layer (the SC pipeline
                // pools on correlated streams before S2B).
                let pool = match net.layers.get(li + 1) {
                    Some(l) => match l.kind {
                        LayerKind::MaxPool { size } => Some(size),
                        _ => None,
                    },
                    None => None,
                };
                let (windows, oh, ow) = conv_gather(shape, *kernel, *padding);
                let lw = &weights.layers[wl];
                let n = windows[0].len();
                // Quantize activations once per layer.
                let acodes: Vec<u32> =
                    act.iter().map(|&v| quantize_bipolar(v, bits)).collect();
                let final_layer = wl + 1 == weights.layers.len();
                let out = run_layer(
                    &windows,
                    &acodes,
                    lw,
                    *out_ch,
                    n,
                    bits,
                    layer.relu,
                    mode,
                    wl as u32,
                    final_layer,
                );
                let (mut new_act, mut new_shape) = (out, (*out_ch, oh, ow));
                if let Some(size) = pool {
                    new_act = max_pool_values(&new_act, new_shape, size);
                    new_shape = (new_shape.0, new_shape.1 / size, new_shape.2 / size);
                    li += 1; // consume the pool layer
                }
                act = new_act;
                shape = new_shape;
                wl += 1;
            }
            LayerKind::Dense { outputs, .. } => {
                let n = shape.0 * shape.1 * shape.2;
                let windows: Vec<Vec<Option<usize>>> =
                    vec![(0..n).map(Some).collect()];
                let lw = &weights.layers[wl];
                let acodes: Vec<u32> =
                    act.iter().map(|&v| quantize_bipolar(v, bits)).collect();
                let final_layer = wl + 1 == weights.layers.len();
                let out = run_layer(
                    &windows,
                    &acodes,
                    lw,
                    *outputs,
                    n,
                    bits,
                    layer.relu,
                    mode,
                    wl as u32,
                    final_layer,
                );
                act = out;
                shape = (*outputs, 1, 1);
                wl += 1;
            }
            LayerKind::MaxPool { size } => {
                // Standalone pool (not fused): pool on values.
                act = max_pool_values(&act, shape, *size);
                shape = (shape.0, shape.1 / size, shape.2 / size);
            }
        }
        li += 1;
    }
    act
}

/// Max-pool plain values (used outside the fused stream path).
fn max_pool_values(v: &[f64], shape: Shape, size: usize) -> Vec<f64> {
    let (c, h, w) = shape;
    let (oh, ow) = (h / size, w / size);
    let mut out = Vec::with_capacity(c * oh * ow);
    for ic in 0..c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut m = f64::MIN;
                for ky in 0..size {
                    for kx in 0..size {
                        m = m.max(v[ic * h * w + (oy * size + ky) * w + (ox * size + kx)]);
                    }
                }
                out.push(m);
            }
        }
    }
    out
}

/// Deterministic per-site standard normal via splitmix + Box–Muller.
fn gauss(site: u32, stream: u32) -> f64 {
    let mut s = ((site as u64) << 32 | stream as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    s = (s ^ (s >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    s = (s ^ (s >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    s ^= s >> 31;
    let u1 = ((s >> 11) as f64 / (1u64 << 53) as f64).max(1e-12);
    let u2 = (s & 0xFFFF_FFFF) as f64 / 4294967296.0;
    (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
}

/// Mix the neuron site indices into a noise counter.
fn noise_ctr(oc: usize, idx: usize) -> u32 {
    (oc as u32).wrapping_mul(0x0101_0101).wrapping_add(idx as u32)
}

/// Layer boundary: sp -> next activation (or logits when `final_layer`).
fn reencode(sp: f64, gamma: f64, mu: f64, final_layer: bool) -> f64 {
    let y = gamma * (sp - mu);
    if final_layer {
        y
    } else {
        y.clamp(0.0, 1.0)
    }
}

/// Execute one compute layer in the requested mode.
#[allow(clippy::too_many_arguments)]
fn run_layer(
    windows: &[Vec<Option<usize>>],
    acodes: &[u32],
    layer_weights: &LayerWeights,
    out_ch: usize,
    fan_in: usize,
    bits: u32,
    relu: bool,
    mode: ForwardMode,
    layer_seed: u32,
    final_layer: bool,
) -> Vec<f64> {
    match mode {
        ForwardMode::Stochastic { k, seed } => {
            let rnd = layer_randoms(bits, fan_in, k, seed ^ (layer_seed.wrapping_mul(0x9E3779B9)));
            // RNS sharing *with signal shuffling* (§I): every PCC sees a
            // per-lane wire-permuted view of the shared source, so product
            // streams are pairwise decorrelated and the per-cycle count
            // variance matches the independent-product model the network
            // was trained through. (Sharing the raw source across all
            // multiplier lanes makes counts swing coherently — a large,
            // k-independent positive bias through the smoothed ReLU.)
            let base = seed ^ layer_seed.wrapping_mul(0x9E3779B9);
            let act_streams: Vec<Bitstream> = acodes
                .iter()
                .enumerate()
                .map(|(p, &c)| lane_stream(c, bits, k, base, p as u64))
                .collect();
            let zero_code = quantize_bipolar(0.0, bits);
            // Per-lane padding streams (border windows).
            let pad_streams: Vec<Bitstream> = (0..fan_in)
                .map(|j| lane_stream(zero_code, bits, k, base, (1 << 40) + j as u64))
                .collect();
            let scale = (1u64 << neuron::m_bits(fan_in)) as f64;
            let mut out = Vec::with_capacity(out_ch * windows.len());
            for oc in 0..out_ch {
                let wcodes = &layer_weights.codes[oc];
                assert_eq!(wcodes.len(), fan_in, "weight fan-in mismatch");
                let wgt_streams: Vec<Bitstream> = wcodes
                    .iter()
                    .enumerate()
                    .map(|(j, &c)| {
                        lane_stream(c, bits, k, base ^ 0x5EED_CAFE, ((oc as u64) << 20) + j as u64)
                    })
                    .collect();
                for win in windows {
                    let mut vc = VerticalCounter::new(k, fan_in);
                    for (j, &src) in win.iter().enumerate() {
                        let a = match src {
                            Some(i) => &act_streams[i],
                            None => &pad_streams[j],
                        };
                        vc.add(&a.xnor(&wgt_streams[j]));
                    }
                    let o = neuron::b2s_stream(&vc, &rnd.r4);
                    let o = if relu {
                        o.or(&neuron::relu_zero_stream(fan_in, &rnd.r4))
                    } else {
                        o
                    };
                    // S2B recovery + re-encoder affine.
                    let sp = (o.value_bipolar() + 1.0) * scale - fan_in as f64;
                    out.push(reencode(sp, layer_weights.gamma, layer_weights.mu, final_layer));
                }
            }
            out
        }
        ForwardMode::Expectation
        | ForwardMode::NoisyExpectation { .. }
        | ForwardMode::FixedPoint => {
            let zero_code = quantize_bipolar(0.0, bits);
            let aq: Vec<f64> =
                acodes.iter().map(|&c| dequantize_bipolar(c, bits)).collect();
            let zq = dequantize_bipolar(zero_code, bits);
            let scale = (1u64 << neuron::m_bits(fan_in)) as f64;
            let mut out = Vec::with_capacity(out_ch * windows.len());
            for oc in 0..out_ch {
                let wq: Vec<f64> = layer_weights.codes[oc]
                    .iter()
                    .map(|&c| dequantize_bipolar(c, bits))
                    .collect();
                for win in windows {
                    let mut pre = 0.0f64;
                    let mut var = 0.0f64;
                    for (j, &src) in win.iter().enumerate() {
                        let a = match src {
                            Some(i) => aq[i],
                            None => zq,
                        };
                        let p = a * wq[j];
                        pre += p;
                        var += 1.0 - p * p;
                    }
                    // sp: the value the S2B counter recovers.
                    let sp = match mode {
                        ForwardMode::Expectation | ForwardMode::NoisyExpectation { .. } => {
                            if relu {
                                let v = neuron::expectation_smooth_relu(pre, var, fan_in);
                                (v + 1.0) * scale - fan_in as f64
                            } else {
                                pre
                            }
                        }
                        ForwardMode::FixedPoint => {
                            if relu {
                                pre.max(0.0)
                            } else {
                                pre
                            }
                        }
                        ForwardMode::Stochastic { .. } => unreachable!(),
                    };
                    let sp = if let ForwardMode::NoisyExpectation { k, seed } = mode {
                        // Sampling error of a k-cycle low-discrepancy
                        // stream on the recovered value. With van der
                        // Corput / progressive-precision SNGs (the setup
                        // hardware SCNNs at k=32 rely on, §II-C refs), the
                        // conversion error scales as O(1/k), not the
                        // binomial O(1/sqrt(k)): sigma_v ~ 3*sqrt(P(1-P))/k.
                        let v = (sp + fan_in as f64) / scale - 1.0;
                        let p = ((v + 1.0) / 2.0).clamp(1e-6, 1.0 - 1e-6);
                        let sigma = 3.0 * (p * (1.0 - p)).sqrt() / k as f64;
                        let z = gauss(seed ^ noise_ctr(oc, out.len()), layer_seed);
                        let v = v + sigma * z;
                        (v + 1.0) * scale - fan_in as f64
                    } else {
                        sp
                    };
                    out.push(reencode(sp, layer_weights.gamma, layer_weights.mu, final_layer));
                }
            }
            out
        }
    }
}

/// Argmax over the final layer values.
pub fn classify(output: &[f64]) -> usize {
    output
        .iter()
        .enumerate()
        .max_by(|(_, a), (_, b)| a.partial_cmp(b).unwrap())
        .map(|(i, _)| i)
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::layers::LayerSpec;

    fn tiny_net() -> NetworkSpec {
        NetworkSpec {
            name: "tiny".into(),
            input: (1, 6, 6),
            layers: vec![
                LayerSpec {
                    kind: LayerKind::Conv { in_ch: 1, out_ch: 2, kernel: 3, padding: 1 },
                    relu: true,
                },
                LayerSpec { kind: LayerKind::MaxPool { size: 2 }, relu: false },
                LayerSpec { kind: LayerKind::Dense { inputs: 18, outputs: 3 }, relu: false },
            ],
        }
    }

    fn tiny_weights(bits: u32, seed: u64) -> QuantizedWeights {
        let mut s = seed.max(1);
        let mut rng = move || {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            (s % 2000) as f64 / 1000.0 - 1.0
        };
        let l0: Vec<Vec<u32>> = (0..2)
            .map(|_| (0..9).map(|_| quantize_bipolar(rng() * 0.5, bits)).collect())
            .collect();
        let l1: Vec<Vec<u32>> = (0..3)
            .map(|_| (0..18).map(|_| quantize_bipolar(rng() * 0.9, bits)).collect())
            .collect();
        QuantizedWeights {
            bits,
            layers: vec![
                // Affines roughly where calibration would put them for
                // these fan-ins (mu near the smoothed-ReLU bias floor).
                LayerWeights { codes: l0, gamma: 0.35, mu: 0.9 },
                LayerWeights { codes: l1, gamma: 1.0, mu: 1.2 },
            ],
        }
    }

    fn tiny_input() -> Vec<f64> {
        (0..36).map(|i| ((i % 7) as f64) / 7.0).collect()
    }

    #[test]
    fn output_shapes_consistent_across_modes() {
        let net = tiny_net();
        let w = tiny_weights(8, 42);
        let input = tiny_input();
        for mode in [
            ForwardMode::FixedPoint,
            ForwardMode::Expectation,
            ForwardMode::Stochastic { k: 64, seed: 7 },
        ] {
            let out = forward(&net, &w, &input, mode);
            assert_eq!(out.len(), 3, "{mode:?}");
            assert!(out.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn stochastic_approaches_expectation_with_length() {
        let net = tiny_net();
        let w = tiny_weights(8, 11);
        let input = tiny_input();
        let exp = forward(&net, &w, &input, ForwardMode::Expectation);
        let err_at = |k: usize| -> f64 {
            let st = forward(&net, &w, &input, ForwardMode::Stochastic { k, seed: 3 });
            st.iter().zip(&exp).map(|(a, b)| (a - b).abs()).sum::<f64>() / exp.len() as f64
        };
        let e16 = err_at(16);
        let e256 = err_at(256);
        assert!(
            e256 < e16 * 0.8,
            "longer bitstreams must track expectation better: e16={e16} e256={e256}"
        );
        // Logits live in the sp domain (scale 2^m ≈ 32 for fan-in 18), so
        // the stochastic noise floor is ~32× a stream-value error.
        assert!(e256 < 3.0, "e256={e256}");
    }

    #[test]
    fn classification_agrees_between_expectation_and_long_stochastic() {
        // Sampling noise at k=4096 is ~0.01 in stream value; only
        // decisions with a larger expectation margin are required to agree.
        let net = tiny_net();
        let w = tiny_weights(8, 5);
        let mut decided = 0;
        let mut agree = 0;
        for s in 0..20 {
            let input: Vec<f64> = (0..36).map(|i| (((i + s * 3) % 9) as f64) / 9.0).collect();
            let exp = forward(&net, &w, &input, ForwardMode::Expectation);
            let e = classify(&exp);
            let mut sorted = exp.clone();
            sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let margin = sorted[0] - sorted[1];
            if margin < 0.02 {
                continue; // below the stochastic noise floor — a coin flip
            }
            decided += 1;
            let st = classify(&forward(
                &net,
                &w,
                &input,
                ForwardMode::Stochastic { k: 4096, seed: 1 + s as u32 },
            ));
            agree += (e == st) as usize;
        }
        assert!(decided >= 3, "test needs decidable cases, got {decided}");
        assert!(
            agree * 10 >= decided * 8,
            "agreement {agree}/{decided} on decided cases"
        );
    }

    #[test]
    fn expectation_monotone_in_bitwidth_fidelity() {
        // Higher quantization precision must not change the fixed-point
        // prediction drastically: 8-bit and 7-bit agree on argmax usually.
        let net = tiny_net();
        let input = tiny_input();
        let mut agree = 0;
        for seed in 0..10u64 {
            let w8 = tiny_weights(8, 100 + seed);
            let p8 = classify(&forward(&net, &w8, &input, ForwardMode::FixedPoint));
            // Re-quantize same real weights at 6 bits by code shifting.
            let w6 = QuantizedWeights {
                bits: 6,
                layers: w8
                    .layers
                    .iter()
                    .map(|l| LayerWeights {
                        codes: l
                            .codes
                            .iter()
                            .map(|n| n.iter().map(|&c| c >> 2).collect())
                            .collect(),
                        gamma: l.gamma,
                        mu: l.mu,
                    })
                    .collect(),
            };
            let p6 = classify(&forward(&net, &w6, &input, ForwardMode::FixedPoint));
            agree += (p8 == p6) as usize;
        }
        assert!(agree >= 7, "agreement {agree}");
    }

    #[test]
    fn classify_picks_argmax() {
        assert_eq!(classify(&[0.1, 0.9, -0.3]), 1);
        assert_eq!(classify(&[-5.0, -2.0, -9.0]), 1);
    }
}
