//! CNN layer and network descriptors: shapes, neuron/fan-in accounting, and
//! the two evaluation networks of the paper (§V-B) — LeNet-5 for MNIST and
//! the Yu et al. [45]-style CIFAR network.

/// One layer of a convolutional network.
#[derive(Debug, Clone, PartialEq)]
pub enum LayerKind {
    /// 2-D convolution (square kernel, stride 1).
    Conv { in_ch: usize, out_ch: usize, kernel: usize, padding: usize },
    /// Non-overlapping max pool (square window).
    MaxPool { size: usize },
    /// Fully connected.
    Dense { inputs: usize, outputs: usize },
}

/// A layer plus its activation.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerSpec {
    /// The layer operation.
    pub kind: LayerKind,
    /// Apply ReLU at the layer output (via the correlated-OR trick in SC).
    pub relu: bool,
}

/// (channels, height, width) activation shape.
pub type Shape = (usize, usize, usize);

/// A full network description.
#[derive(Debug, Clone)]
pub struct NetworkSpec {
    /// Network name (reports / artifact naming).
    pub name: String,
    /// Input shape.
    pub input: Shape,
    /// Layers in order.
    pub layers: Vec<LayerSpec>,
}

impl LayerSpec {
    /// Output shape given the input shape.
    pub fn output_shape(&self, input: Shape) -> Shape {
        let (c, h, w) = input;
        match &self.kind {
            LayerKind::Conv { in_ch, out_ch, kernel, padding } => {
                assert_eq!(*in_ch, c, "conv input channels mismatch");
                let oh = h + 2 * padding - kernel + 1;
                let ow = w + 2 * padding - kernel + 1;
                (*out_ch, oh, ow)
            }
            LayerKind::MaxPool { size } => (c, h / size, w / size),
            LayerKind::Dense { inputs, outputs } => {
                assert_eq!(*inputs, c * h * w, "dense input size mismatch");
                (*outputs, 1, 1)
            }
        }
    }

    /// Number of neurons (MAC-owning outputs) in this layer; pooling has
    /// none (it rides on the producing layer's correlated streams).
    pub fn neurons(&self, input: Shape) -> usize {
        match &self.kind {
            LayerKind::Conv { .. } | LayerKind::Dense { .. } => {
                let (c, h, w) = self.output_shape(input);
                c * h * w
            }
            LayerKind::MaxPool { .. } => 0,
        }
    }

    /// Fan-in (products per neuron).
    pub fn fan_in(&self, _input: Shape) -> usize {
        match &self.kind {
            LayerKind::Conv { in_ch, kernel, .. } => in_ch * kernel * kernel,
            LayerKind::Dense { inputs, .. } => *inputs,
            LayerKind::MaxPool { .. } => 0,
        }
    }
}

impl NetworkSpec {
    /// Per-layer input shapes (same length as `layers`).
    pub fn input_shapes(&self) -> Vec<Shape> {
        let mut shapes = Vec::with_capacity(self.layers.len());
        let mut s = self.input;
        for l in &self.layers {
            shapes.push(s);
            s = l.output_shape(s);
        }
        shapes
    }

    /// Final output shape.
    pub fn output_shape(&self) -> Shape {
        self.layers.iter().fold(self.input, |s, l| l.output_shape(s))
    }

    /// Total multiply-accumulate operations for one inference.
    pub fn total_macs(&self) -> u64 {
        self.input_shapes()
            .iter()
            .zip(&self.layers)
            .map(|(&s, l)| l.neurons(s) as u64 * l.fan_in(s) as u64)
            .sum()
    }

    /// Total neurons across compute layers.
    pub fn total_neurons(&self) -> u64 {
        self.input_shapes()
            .iter()
            .zip(&self.layers)
            .map(|(&s, l)| l.neurons(s) as u64)
            .sum()
    }

    /// LeNet-5 as used for MNIST in §V-B (28×28 input, padding-2 first
    /// conv, 6-16 feature maps, 120-84-10 classifier).
    pub fn lenet5() -> Self {
        NetworkSpec {
            name: "lenet5".into(),
            input: (1, 28, 28),
            layers: vec![
                LayerSpec {
                    kind: LayerKind::Conv { in_ch: 1, out_ch: 6, kernel: 5, padding: 2 },
                    relu: true,
                },
                LayerSpec { kind: LayerKind::MaxPool { size: 2 }, relu: false },
                LayerSpec {
                    kind: LayerKind::Conv { in_ch: 6, out_ch: 16, kernel: 5, padding: 0 },
                    relu: true,
                },
                LayerSpec { kind: LayerKind::MaxPool { size: 2 }, relu: false },
                LayerSpec { kind: LayerKind::Dense { inputs: 400, outputs: 120 }, relu: true },
                LayerSpec { kind: LayerKind::Dense { inputs: 120, outputs: 84 }, relu: true },
                LayerSpec { kind: LayerKind::Dense { inputs: 84, outputs: 10 }, relu: false },
            ],
        }
    }

    /// The CIFAR-10 network following the structure of the reference work
    /// [45] (conv32-pool-conv32-pool-conv64-pool-dense).
    pub fn cifar_net() -> Self {
        NetworkSpec {
            name: "cifar_net".into(),
            input: (3, 32, 32),
            layers: vec![
                LayerSpec {
                    kind: LayerKind::Conv { in_ch: 3, out_ch: 32, kernel: 5, padding: 2 },
                    relu: true,
                },
                LayerSpec { kind: LayerKind::MaxPool { size: 2 }, relu: false },
                LayerSpec {
                    kind: LayerKind::Conv { in_ch: 32, out_ch: 32, kernel: 5, padding: 2 },
                    relu: true,
                },
                LayerSpec { kind: LayerKind::MaxPool { size: 2 }, relu: false },
                LayerSpec {
                    kind: LayerKind::Conv { in_ch: 32, out_ch: 64, kernel: 5, padding: 2 },
                    relu: true,
                },
                LayerSpec { kind: LayerKind::MaxPool { size: 2 }, relu: false },
                LayerSpec { kind: LayerKind::Dense { inputs: 1024, outputs: 10 }, relu: false },
            ],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lenet5_shapes() {
        let net = NetworkSpec::lenet5();
        let shapes = net.input_shapes();
        assert_eq!(shapes[0], (1, 28, 28));
        assert_eq!(net.layers[0].output_shape(shapes[0]), (6, 28, 28)); // pad 2
        assert_eq!(net.layers[1].output_shape((6, 28, 28)), (6, 14, 14));
        assert_eq!(net.layers[2].output_shape((6, 14, 14)), (16, 10, 10));
        assert_eq!(net.layers[3].output_shape((16, 10, 10)), (16, 5, 5));
        assert_eq!(net.output_shape(), (10, 1, 1));
    }

    #[test]
    fn lenet5_neuron_counts() {
        let net = NetworkSpec::lenet5();
        let shapes = net.input_shapes();
        // conv1: 28·28·6 = 4704 neurons of fan-in 25.
        assert_eq!(net.layers[0].neurons(shapes[0]), 4704);
        assert_eq!(net.layers[0].fan_in(shapes[0]), 25);
        // conv2: 10·10·16 = 1600 neurons of fan-in 150.
        assert_eq!(net.layers[2].neurons(shapes[2]), 1600);
        assert_eq!(net.layers[2].fan_in(shapes[2]), 150);
        // dense1: 120 neurons of fan-in 400.
        assert_eq!(net.layers[4].neurons(shapes[4]), 120);
        assert_eq!(net.layers[4].fan_in(shapes[4]), 400);
        // Total MACs: 4704·25 + 1600·150 + 120·400 + 84·120 + 10·84.
        assert_eq!(net.total_macs(), 4704 * 25 + 1600 * 150 + 48000 + 10080 + 840);
    }

    #[test]
    fn cifar_net_shapes() {
        let net = NetworkSpec::cifar_net();
        assert_eq!(net.output_shape(), (10, 1, 1));
        let shapes = net.input_shapes();
        assert_eq!(net.layers[4].output_shape(shapes[4]), (64, 8, 8));
    }

    #[test]
    #[should_panic(expected = "dense input size mismatch")]
    fn dense_mismatch_panics() {
        let l = LayerSpec { kind: LayerKind::Dense { inputs: 100, outputs: 10 }, relu: false };
        l.output_shape((1, 28, 28));
    }
}
