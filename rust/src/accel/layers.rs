//! CNN layer and network descriptors: the typed layer vocabulary every
//! backend and the hardware model lower from (via [`crate::accel::stage`]),
//! plus the built-in topologies — the paper's two evaluation networks
//! (§V-B: LeNet-5 for MNIST and the Yu et al. [45]-style CIFAR network)
//! and the strided-conv/avgpool MNIST variant exercising the extended ops.
//!
//! Shape inference has two faces:
//! * [`LayerSpec::try_output_shape`] / [`NetworkSpec::validate`] — the
//!   non-panicking pass; every malformed stack (channel mismatch,
//!   non-divisible pool window, dangling residual) is a typed error the
//!   engine and CLI surface instead of an internal assert;
//! * [`LayerSpec::output_shape`] / [`NetworkSpec::input_shapes`] — the
//!   panicking conveniences for code that runs *after* validation.

use anyhow::{bail, Result};

/// A 2-D convolution: rectangular kernel, stride, symmetric zero padding,
/// optionally depthwise (each output channel reads only its own input
/// channel). Output spatial size follows the standard floor convention:
/// `o = (i + 2·padding − kernel) / stride + 1`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Conv2d {
    /// Input channels.
    pub in_ch: usize,
    /// Output channels (must equal `in_ch` when `depthwise`).
    pub out_ch: usize,
    /// Kernel size as (height, width).
    pub kernel: (usize, usize),
    /// Stride as (vertical, horizontal).
    pub stride: (usize, usize),
    /// Symmetric zero padding on every edge.
    pub padding: usize,
    /// Depthwise: channel c of the output convolves only channel c of the
    /// input (fan-in `kh·kw` instead of `in_ch·kh·kw`).
    pub depthwise: bool,
}

impl Conv2d {
    /// Square stride-1 convolution — the paper's original conv vocabulary.
    pub fn square(in_ch: usize, out_ch: usize, kernel: usize, padding: usize) -> Self {
        Conv2d {
            in_ch,
            out_ch,
            kernel: (kernel, kernel),
            stride: (1, 1),
            padding,
            depthwise: false,
        }
    }

    /// Set a (possibly anisotropic) stride.
    pub fn with_stride(mut self, sy: usize, sx: usize) -> Self {
        self.stride = (sy, sx);
        self
    }

    /// Set a rectangular kernel.
    pub fn with_kernel(mut self, kh: usize, kw: usize) -> Self {
        self.kernel = (kh, kw);
        self
    }

    /// Make the convolution depthwise (`out_ch` must equal `in_ch`).
    pub fn depthwise(mut self) -> Self {
        self.depthwise = true;
        self
    }

    /// Products per neuron.
    pub fn fan_in(&self) -> usize {
        let (kh, kw) = self.kernel;
        if self.depthwise {
            kh * kw
        } else {
            self.in_ch * kh * kw
        }
    }
}

/// One layer of a convolutional network.
#[derive(Debug, Clone, PartialEq)]
pub enum LayerKind {
    /// 2-D convolution (see [`Conv2d`] for stride/kernel/depthwise knobs).
    Conv(Conv2d),
    /// Non-overlapping max pool (square window; input must divide evenly —
    /// validation rejects silent truncation).
    MaxPool {
        /// Pool window size.
        size: usize,
    },
    /// Non-overlapping average pool (square window, same divisibility
    /// rule). In SC hardware this is the counter-based scaled add of
    /// SC-DCNN-style pooling units (`sc::neuron::avg_pool_stream`).
    AvgPool {
        /// Pool window size.
        size: usize,
    },
    /// Average over the whole spatial extent: (c, h, w) → (c, 1, 1).
    GlobalAvgPool,
    /// Fully connected.
    Dense {
        /// Flattened input size (must equal c·h·w of the incoming shape).
        inputs: usize,
        /// Output neurons.
        outputs: usize,
    },
    /// Elementwise residual merge with the output of an earlier layer:
    /// `out = (cur + layers[from].output) / 2` — the SC scaled add (a
    /// MUX with select probability ½), applied on the recovered values at
    /// the layer boundary by every backend. Shapes must match.
    Add {
        /// Index (into `NetworkSpec::layers`) of the merged branch.
        from: usize,
    },
}

impl LayerKind {
    /// Square stride-1 convolution shorthand (the original vocabulary).
    pub fn conv(in_ch: usize, out_ch: usize, kernel: usize, padding: usize) -> Self {
        LayerKind::Conv(Conv2d::square(in_ch, out_ch, kernel, padding))
    }
}

/// A layer plus its activation.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerSpec {
    /// The layer operation.
    pub kind: LayerKind,
    /// Apply ReLU at the layer output (via the correlated-OR trick in SC).
    /// Only meaningful on compute layers (Conv/Dense); validation rejects
    /// it elsewhere.
    pub relu: bool,
}

impl LayerSpec {
    /// A compute layer with ReLU.
    pub fn active(kind: LayerKind) -> Self {
        LayerSpec { kind, relu: true }
    }

    /// A layer without activation.
    pub fn linear(kind: LayerKind) -> Self {
        LayerSpec { kind, relu: false }
    }
}

/// (channels, height, width) activation shape.
pub type Shape = (usize, usize, usize);

/// A full network description.
#[derive(Debug, Clone)]
pub struct NetworkSpec {
    /// Network name (reports / artifact naming / [`NetworkSpec::by_name`]).
    pub name: String,
    /// Input shape.
    pub input: Shape,
    /// Layers in order.
    pub layers: Vec<LayerSpec>,
}

impl LayerSpec {
    /// Output shape given the input shape — non-panicking shape inference.
    ///
    /// [`LayerKind::Add`] needs whole-network context (the `from` branch),
    /// which this per-layer view cannot check; it is shape-preserving
    /// here and fully validated by [`NetworkSpec::validate`].
    pub fn try_output_shape(&self, input: Shape) -> Result<Shape> {
        let (c, h, w) = input;
        match &self.kind {
            LayerKind::Conv(cv) => {
                if cv.in_ch != c {
                    bail!("conv expects {} input channels, got {c}", cv.in_ch);
                }
                if cv.depthwise && cv.out_ch != cv.in_ch {
                    bail!(
                        "depthwise conv must map channels 1:1 ({} in vs {} out)",
                        cv.in_ch,
                        cv.out_ch
                    );
                }
                let (kh, kw) = cv.kernel;
                let (sy, sx) = cv.stride;
                if kh == 0 || kw == 0 || sy == 0 || sx == 0 || cv.out_ch == 0 {
                    bail!("conv kernel/stride/channels must be positive (got {cv:?})");
                }
                if h + 2 * cv.padding < kh || w + 2 * cv.padding < kw {
                    bail!(
                        "conv kernel {kh}x{kw} exceeds padded input {}x{}",
                        h + 2 * cv.padding,
                        w + 2 * cv.padding
                    );
                }
                let oh = (h + 2 * cv.padding - kh) / sy + 1;
                let ow = (w + 2 * cv.padding - kw) / sx + 1;
                Ok((cv.out_ch, oh, ow))
            }
            LayerKind::MaxPool { size } | LayerKind::AvgPool { size } => {
                let label = if matches!(self.kind, LayerKind::MaxPool { .. }) {
                    "maxpool"
                } else {
                    "avgpool"
                };
                if *size == 0 {
                    bail!("{label} window must be positive");
                }
                if h % size != 0 || w % size != 0 {
                    bail!(
                        "{label} window {size} does not divide the {h}x{w} input \
                         (silent truncation is rejected; pad or resize upstream)"
                    );
                }
                Ok((c, h / size, w / size))
            }
            LayerKind::GlobalAvgPool => Ok((c, 1, 1)),
            LayerKind::Dense { inputs, outputs } => {
                if *inputs != c * h * w {
                    bail!(
                        "dense expects {inputs} inputs but the incoming shape \
                         {c}x{h}x{w} flattens to {}",
                        c * h * w
                    );
                }
                Ok((*outputs, 1, 1))
            }
            LayerKind::Add { .. } => Ok(input),
        }
    }

    /// Output shape given the input shape; panics on malformed stacks (use
    /// [`LayerSpec::try_output_shape`] / [`NetworkSpec::validate`] first on
    /// untrusted input).
    pub fn output_shape(&self, input: Shape) -> Shape {
        self.try_output_shape(input).expect("layer shape mismatch")
    }

    /// Number of neurons (MAC-owning outputs) in this layer; pooling and
    /// residual merges have none (they ride on the producing layer's
    /// correlated streams / recovered values).
    pub fn neurons(&self, input: Shape) -> usize {
        match &self.kind {
            LayerKind::Conv(_) | LayerKind::Dense { .. } => {
                let (c, h, w) = self.output_shape(input);
                c * h * w
            }
            _ => 0,
        }
    }

    /// Fan-in (products per neuron).
    pub fn fan_in(&self, _input: Shape) -> usize {
        match &self.kind {
            LayerKind::Conv(cv) => cv.fan_in(),
            LayerKind::Dense { inputs, .. } => *inputs,
            _ => 0,
        }
    }

    /// True for MAC-owning (weight-carrying) layers.
    pub fn is_compute(&self) -> bool {
        matches!(self.kind, LayerKind::Conv(_) | LayerKind::Dense { .. })
    }
}

impl NetworkSpec {
    /// Validate the whole stack: non-panicking shape inference over every
    /// layer plus the cross-layer rules (residual targets, activation
    /// placement, at least one compute layer). Returns the per-layer
    /// *input* shapes (same length as `layers`) so callers get the
    /// inferred geometry for free; [`crate::accel::stage`] builds the full
    /// stage IR on top of this.
    pub fn validate(&self) -> Result<Vec<Shape>> {
        if self.layers.is_empty() {
            bail!("network {:?} has no layers", self.name);
        }
        let mut shapes = Vec::with_capacity(self.layers.len());
        let mut s = self.input;
        if s.0 == 0 || s.1 == 0 || s.2 == 0 {
            bail!("network {:?} input shape {s:?} has a zero dimension", self.name);
        }
        let mut any_compute = false;
        for (li, l) in self.layers.iter().enumerate() {
            if l.relu && !l.is_compute() {
                bail!("layer {li} of {:?}: relu is only defined on conv/dense layers", self.name);
            }
            if let LayerKind::Add { from } = l.kind {
                if from >= li {
                    bail!(
                        "layer {li} of {:?}: residual add references layer {from}, \
                         which is not an earlier layer",
                        self.name
                    );
                }
                let branch = self.layers[from]
                    .try_output_shape(shapes[from])
                    .expect("earlier layers already validated");
                if branch != s {
                    bail!(
                        "layer {li} of {:?}: residual add merges shape {branch:?} \
                         (layer {from} output) into shape {s:?}",
                        self.name
                    );
                }
            }
            shapes.push(s);
            s = l
                .try_output_shape(s)
                .map_err(|e| e.context(format!("layer {li} of network {:?}", self.name)))?;
            any_compute |= l.is_compute();
        }
        if !any_compute {
            bail!("network {:?} has no compute (conv/dense) layer", self.name);
        }
        Ok(shapes)
    }

    /// Per-layer input shapes (same length as `layers`); panics on
    /// malformed stacks (validate first on untrusted input).
    pub fn input_shapes(&self) -> Vec<Shape> {
        let mut shapes = Vec::with_capacity(self.layers.len());
        let mut s = self.input;
        for l in &self.layers {
            shapes.push(s);
            s = l.output_shape(s);
        }
        shapes
    }

    /// Final output shape.
    pub fn output_shape(&self) -> Shape {
        self.layers.iter().fold(self.input, |s, l| l.output_shape(s))
    }

    /// Number of MAC-owning (weight-carrying) layers — what a weight
    /// tensor or a per-layer precision plan must cover, one entry each.
    pub fn n_compute(&self) -> usize {
        self.layers.iter().filter(|l| l.is_compute()).count()
    }

    /// Total multiply-accumulate operations for one inference.
    pub fn total_macs(&self) -> u64 {
        self.input_shapes()
            .iter()
            .zip(&self.layers)
            .map(|(&s, l)| l.neurons(s) as u64 * l.fan_in(s) as u64)
            .sum()
    }

    /// Total neurons across compute layers.
    pub fn total_neurons(&self) -> u64 {
        self.input_shapes()
            .iter()
            .zip(&self.layers)
            .map(|(&s, l)| l.neurons(s) as u64)
            .sum()
    }

    /// Names of every built-in topology, in [`NetworkSpec::by_name`] order.
    pub const NAMES: [&'static str; 3] = ["lenet5", "cifar_net", "mnist_strided"];

    /// The single registry behind every stringly network lookup (CLI
    /// flags, benches, examples): resolve a built-in topology by name.
    pub fn by_name(name: &str) -> Result<Self> {
        match name {
            "lenet5" => Ok(Self::lenet5()),
            "cifar_net" => Ok(Self::cifar_net()),
            "mnist_strided" => Ok(Self::mnist_strided()),
            other => bail!("unknown network {other:?} (one of {})", Self::NAMES.join("|")),
        }
    }

    /// LeNet-5 as used for MNIST in §V-B (28×28 input, padding-2 first
    /// conv, 6-16 feature maps, 120-84-10 classifier).
    pub fn lenet5() -> Self {
        NetworkSpec {
            name: "lenet5".into(),
            input: (1, 28, 28),
            layers: vec![
                LayerSpec::active(LayerKind::conv(1, 6, 5, 2)),
                LayerSpec::linear(LayerKind::MaxPool { size: 2 }),
                LayerSpec::active(LayerKind::conv(6, 16, 5, 0)),
                LayerSpec::linear(LayerKind::MaxPool { size: 2 }),
                LayerSpec::active(LayerKind::Dense { inputs: 400, outputs: 120 }),
                LayerSpec::active(LayerKind::Dense { inputs: 120, outputs: 84 }),
                LayerSpec::linear(LayerKind::Dense { inputs: 84, outputs: 10 }),
            ],
        }
    }

    /// The CIFAR-10 network following the structure of the reference work
    /// [45] (conv32-pool-conv32-pool-conv64-pool-dense).
    pub fn cifar_net() -> Self {
        NetworkSpec {
            name: "cifar_net".into(),
            input: (3, 32, 32),
            layers: vec![
                LayerSpec::active(LayerKind::conv(3, 32, 5, 2)),
                LayerSpec::linear(LayerKind::MaxPool { size: 2 }),
                LayerSpec::active(LayerKind::conv(32, 32, 5, 2)),
                LayerSpec::linear(LayerKind::MaxPool { size: 2 }),
                LayerSpec::active(LayerKind::conv(32, 64, 5, 2)),
                LayerSpec::linear(LayerKind::MaxPool { size: 2 }),
                LayerSpec::linear(LayerKind::Dense { inputs: 1024, outputs: 10 }),
            ],
        }
    }

    /// The strided-conv + average-pool MNIST variant exercising the
    /// extended vocabulary end to end: a stride-2 stem, a depthwise
    /// refinement merged back through an SC scaled-add residual, average
    /// pooling (the SC-DCNN-style counter-based pooling unit), a second
    /// stride-2 conv, global average pooling, and a linear classifier.
    ///
    /// ```text
    /// (1,28,28) ─conv 3×3 s2 p1─▶ (8,14,14) ─depthwise 3×3─▶ (8,14,14)
    ///           ─add(from conv1)─▶ (8,14,14) ─avgpool2─▶ (8,7,7)
    ///           ─conv 3×3 s2 p1─▶ (16,4,4) ─global avg─▶ (16,1,1)
    ///           ─dense─▶ 10 classes
    /// ```
    pub fn mnist_strided() -> Self {
        NetworkSpec {
            name: "mnist_strided".into(),
            input: (1, 28, 28),
            layers: vec![
                LayerSpec::active(LayerKind::Conv(
                    Conv2d::square(1, 8, 3, 1).with_stride(2, 2),
                )),
                LayerSpec::active(LayerKind::Conv(Conv2d::square(8, 8, 3, 1).depthwise())),
                LayerSpec::linear(LayerKind::Add { from: 0 }),
                LayerSpec::linear(LayerKind::AvgPool { size: 2 }),
                LayerSpec::active(LayerKind::Conv(
                    Conv2d::square(8, 16, 3, 1).with_stride(2, 2),
                )),
                LayerSpec::linear(LayerKind::GlobalAvgPool),
                LayerSpec::linear(LayerKind::Dense { inputs: 16, outputs: 10 }),
            ],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lenet5_shapes() {
        let net = NetworkSpec::lenet5();
        let shapes = net.input_shapes();
        assert_eq!(shapes[0], (1, 28, 28));
        assert_eq!(net.layers[0].output_shape(shapes[0]), (6, 28, 28)); // pad 2
        assert_eq!(net.layers[1].output_shape((6, 28, 28)), (6, 14, 14));
        assert_eq!(net.layers[2].output_shape((6, 14, 14)), (16, 10, 10));
        assert_eq!(net.layers[3].output_shape((16, 10, 10)), (16, 5, 5));
        assert_eq!(net.output_shape(), (10, 1, 1));
        assert_eq!(net.validate().unwrap(), shapes);
    }

    #[test]
    fn lenet5_neuron_counts() {
        let net = NetworkSpec::lenet5();
        let shapes = net.input_shapes();
        // conv1: 28·28·6 = 4704 neurons of fan-in 25.
        assert_eq!(net.layers[0].neurons(shapes[0]), 4704);
        assert_eq!(net.layers[0].fan_in(shapes[0]), 25);
        // conv2: 10·10·16 = 1600 neurons of fan-in 150.
        assert_eq!(net.layers[2].neurons(shapes[2]), 1600);
        assert_eq!(net.layers[2].fan_in(shapes[2]), 150);
        // dense1: 120 neurons of fan-in 400.
        assert_eq!(net.layers[4].neurons(shapes[4]), 120);
        assert_eq!(net.layers[4].fan_in(shapes[4]), 400);
        // Total MACs: 4704·25 + 1600·150 + 120·400 + 84·120 + 10·84.
        assert_eq!(net.total_macs(), 4704 * 25 + 1600 * 150 + 48000 + 10080 + 840);
    }

    #[test]
    fn cifar_net_shapes() {
        let net = NetworkSpec::cifar_net();
        assert_eq!(net.output_shape(), (10, 1, 1));
        let shapes = net.input_shapes();
        assert_eq!(net.layers[4].output_shape(shapes[4]), (64, 8, 8));
    }

    #[test]
    fn mnist_strided_shapes() {
        let net = NetworkSpec::mnist_strided();
        let shapes = net.validate().unwrap();
        assert_eq!(shapes[1], (8, 14, 14)); // stride-2 stem
        assert_eq!(shapes[3], (8, 14, 14)); // after the residual merge
        assert_eq!(shapes[4], (8, 7, 7)); // after avgpool
        assert_eq!(shapes[5], (16, 4, 4)); // second stride-2 conv
        assert_eq!(net.output_shape(), (10, 1, 1));
        // Depthwise fan-in is kernel-only.
        assert_eq!(net.layers[1].fan_in(shapes[1]), 9);
        assert_eq!(net.layers[0].fan_in(shapes[0]), 9);
        assert_eq!(net.layers[4].fan_in(shapes[4]), 8 * 9);
    }

    #[test]
    fn strided_and_rectangular_conv_shapes() {
        let l = LayerSpec::linear(LayerKind::Conv(
            Conv2d::square(3, 4, 3, 1).with_stride(2, 2),
        ));
        assert_eq!(l.try_output_shape((3, 28, 28)).unwrap(), (4, 14, 14));
        // Floor convention on odd extents: (7+2-3)/2+1 = 4.
        assert_eq!(l.try_output_shape((3, 7, 7)).unwrap(), (4, 4, 4));
        let rect = LayerSpec::linear(LayerKind::Conv(
            Conv2d::square(1, 2, 1, 0).with_kernel(3, 5).with_stride(1, 2),
        ));
        assert_eq!(rect.try_output_shape((1, 9, 11)).unwrap(), (2, 7, 4));
    }

    #[test]
    fn validate_rejects_non_divisible_pool() {
        // The old silent-truncation bug: 7/2 floored to 3. Now an error.
        for kind in [LayerKind::MaxPool { size: 2 }, LayerKind::AvgPool { size: 2 }] {
            let l = LayerSpec::linear(kind);
            let err = l.try_output_shape((1, 7, 8)).unwrap_err().to_string();
            assert!(err.contains("does not divide"), "{err}");
        }
        let net = NetworkSpec {
            name: "bad-pool".into(),
            input: (1, 7, 7),
            layers: vec![
                LayerSpec::active(LayerKind::conv(1, 2, 1, 0)),
                LayerSpec::linear(LayerKind::MaxPool { size: 2 }),
            ],
        };
        let err = net.validate().unwrap_err().to_string();
        assert!(err.contains("bad-pool") && err.contains("does not divide"), "{err}");
    }

    #[test]
    fn validate_rejects_cross_layer_violations() {
        // Residual referencing a later layer.
        let net = NetworkSpec {
            name: "bad-add".into(),
            input: (1, 4, 4),
            layers: vec![
                LayerSpec::linear(LayerKind::Add { from: 0 }),
                LayerSpec::linear(LayerKind::Dense { inputs: 16, outputs: 2 }),
            ],
        };
        assert!(net.validate().is_err());
        // Residual shape mismatch.
        let net = NetworkSpec {
            name: "bad-add-shape".into(),
            input: (1, 4, 4),
            layers: vec![
                LayerSpec::active(LayerKind::conv(1, 2, 3, 0)),
                LayerSpec::linear(LayerKind::Add { from: 0 }),
            ],
        };
        let err = net.validate().unwrap_err().to_string();
        assert!(err.contains("merges shape"), "{err}");
        // ReLU on a pooling layer.
        let net = NetworkSpec {
            name: "bad-relu".into(),
            input: (1, 4, 4),
            layers: vec![
                LayerSpec::active(LayerKind::conv(1, 2, 3, 1)),
                LayerSpec::active(LayerKind::MaxPool { size: 2 }),
            ],
        };
        assert!(net.validate().is_err());
        // Depthwise with a channel expansion.
        let net = NetworkSpec {
            name: "bad-dw".into(),
            input: (2, 4, 4),
            layers: vec![LayerSpec::active(LayerKind::Conv(
                Conv2d::square(2, 4, 3, 1).depthwise(),
            ))],
        };
        assert!(net.validate().is_err());
    }

    #[test]
    fn by_name_registry_round_trips() {
        for name in NetworkSpec::NAMES {
            let net = NetworkSpec::by_name(name).unwrap();
            assert_eq!(net.name, name);
            net.validate().unwrap();
        }
        assert!(NetworkSpec::by_name("resnet-152").is_err());
    }

    #[test]
    #[should_panic(expected = "layer shape mismatch")]
    fn dense_mismatch_panics() {
        let l = LayerSpec::linear(LayerKind::Dense { inputs: 100, outputs: 10 });
        l.output_shape((1, 28, 28));
    }
}
