//! Off-chip memory model: the paper's GDDR5 at 7000 MHz delivering
//! ≈224 B/ns (§IV-A), consumed by Algorithm 1's pipeline scheduler.

/// Off-chip memory characteristics.
#[derive(Debug, Clone, Copy)]
pub struct MemoryModel {
    /// Sustained load bandwidth in bytes per nanosecond.
    pub bandwidth_bytes_per_ns: f64,
    /// Energy per byte transferred from off-chip (pJ/B) — GDDR5-class I/O.
    pub energy_pj_per_byte: f64,
}

impl MemoryModel {
    /// The paper's GDDR5 configuration: 7000 MHz, ≈224 B/ns.
    pub fn gddr5_paper() -> Self {
        MemoryModel { bandwidth_bytes_per_ns: 224.0, energy_pj_per_byte: 10.0 }
    }

    /// Bytes loadable during one clock period of `clock_ps` picoseconds.
    pub fn bytes_per_cycle(&self, clock_ps: f64) -> f64 {
        self.bandwidth_bytes_per_ns * clock_ps / 1000.0
    }

    /// Time (ns) to load `bytes`.
    pub fn load_time_ns(&self, bytes: u64) -> f64 {
        bytes as f64 / self.bandwidth_bytes_per_ns
    }

    /// Transfer energy (pJ) for `bytes`.
    pub fn transfer_energy_pj(&self, bytes: u64) -> f64 {
        bytes as f64 * self.energy_pj_per_byte
    }
}

/// Apply a single-event upset to a stored `width`-bit word: flip bit
/// `bit % width`. The fault-injection subsystem (`crate::faults`) routes
/// every SRAM weight upset through this one function so the fused engine
/// and the per-bit reference corrupt storage identically.
pub fn upset_word(code: u32, width: u32, bit: u32) -> u32 {
    code ^ (1 << (bit % width.max(1)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn upset_flips_exactly_one_in_range_bit() {
        for bit in 0..16 {
            let c = upset_word(0xAB, 8, bit);
            assert_eq!((c ^ 0xAB).count_ones(), 1);
            assert!((c ^ 0xAB).trailing_zeros() < 8, "upset stays in the word");
        }
        assert_eq!(upset_word(upset_word(0x5A, 8, 3), 8, 3), 0x5A, "involutive");
        assert_eq!(upset_word(0, 0, 7), 1, "zero width degrades to bit 0");
    }

    #[test]
    fn paper_bandwidth() {
        let m = MemoryModel::gddr5_paper();
        // 0.88 ns clock (RFET Table II) ⇒ ~197 B per cycle.
        let b = m.bytes_per_cycle(880.0);
        assert!((b - 197.12).abs() < 0.01);
    }

    #[test]
    fn load_time_scales() {
        let m = MemoryModel::gddr5_paper();
        assert!((m.load_time_ns(224) - 1.0).abs() < 1e-12);
        assert!((m.load_time_ns(2240) - 10.0).abs() < 1e-12);
    }
}
