//! Per-layer bitstream precision: the compiled [`PrecisionPlan`], the
//! typed [`Precision`] policy it is resolved from, and the greedy
//! accuracy-budget [`autotune`]r.
//!
//! In stochastic computing, latency and energy scale **linearly** with the
//! bitstream length `k`, so `k` is the single most valuable knob the
//! system owns — and one global scalar wastes it: early conv layers feed
//! wide fan-ins whose averaging already suppresses sampling noise, while a
//! 10-way classifier head lives or dies by its stream resolution (the
//! SC-DCNN observation: optimize precision per network component, not per
//! network). A [`PrecisionPlan`] assigns every *compute* stage of the
//! [`crate::accel::stage`] IR its own `k`, and is honored identically by
//! the fused engine, the per-bit golden reference, the analytic
//! noisy-expectation model, and the hardware schedule/energy roll-up
//! ([`crate::accel::pipeline`] / [`crate::accel::system`]).
//!
//! # Inter-stage rescaling
//!
//! Adjacent stages with different `k` need no explicit stream-domain
//! converter in this architecture: every compute stage already ends in an
//! S2B counter (recovering a binary value from its own `k_i` cycles) and
//! the next stage's SNG re-samples that value at its own `k_{i+1}` — the
//! S2B→B2S boundary *is* the rescaler, and it is exercised bit-exactly by
//! the cross-backend parity tests. What changes with a plan is the length
//! of every stream a stage generates, counts, and compares — per stage.
//!
//! # Word alignment
//!
//! Stage lengths must be positive multiples of [`WORD`] cycles: the
//! SNG/APC datapath generates and drains streams in word-granular chunks
//! (and the hardware counters are read out on word boundaries), so a
//! ragged tail would model cycles the machine cannot schedule. Degenerate
//! lengths (`k == 0`, misaligned `k`) are typed [`PrecisionError`]s,
//! rejected by [`PrecisionPlan::validate`] — and therefore by
//! `EngineConfig::validate` and `ForwardPlan::compile` — instead of
//! flowing silently into the kernels.

use crate::accel::layers::NetworkSpec;
use crate::accel::network::{classify, ForwardMode, ForwardPlan, QuantizedWeights, Scratch};
use crate::sc::rng::XorShift64;
use anyhow::{anyhow, Result};
use std::fmt;

/// Stream-length granularity in cycles: every stage `k` must be a
/// positive multiple of this (see the module docs on word alignment).
pub const WORD: usize = 8;

/// Why a precision plan (or policy) failed validation.
#[derive(Debug, Clone, PartialEq)]
pub enum PrecisionError {
    /// A stage was assigned a zero-cycle stream. `stage` is the compute
    /// layer index when known (`None` for a uniform policy).
    ZeroK {
        /// Compute-layer index, when per-layer.
        stage: Option<usize>,
    },
    /// A stage length is not a multiple of [`WORD`] cycles.
    Misaligned {
        /// Compute-layer index, when per-layer.
        stage: Option<usize>,
        /// The offending length.
        k: usize,
    },
    /// A per-layer plan's length disagrees with the network's compute
    /// stage count.
    WrongLength {
        /// Compute stages in the network.
        expected: usize,
        /// Entries in the plan.
        got: usize,
    },
    /// The plan carries no stages at all.
    Empty,
    /// An autotune accuracy budget outside `[0, 1)`.
    BadBudget {
        /// The offending budget.
        budget: f64,
    },
}

impl fmt::Display for PrecisionError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let at = |stage: &Option<usize>| match stage {
            Some(s) => format!(" (compute layer {s})"),
            None => String::new(),
        };
        match self {
            PrecisionError::ZeroK { stage } => {
                write!(f, "bitstream length k = 0{}: every stage needs k >= {WORD}", at(stage))
            }
            PrecisionError::Misaligned { stage, k } => write!(
                f,
                "bitstream length k = {k}{} is not a multiple of the {WORD}-cycle word",
                at(stage)
            ),
            PrecisionError::WrongLength { expected, got } => write!(
                f,
                "per-layer precision plan has {got} entries but the network has \
                 {expected} compute layers"
            ),
            PrecisionError::Empty => write!(f, "precision plan covers no compute layers"),
            PrecisionError::BadBudget { budget } => {
                write!(f, "accuracy budget {budget} outside [0, 1)")
            }
        }
    }
}

impl std::error::Error for PrecisionError {}

/// `Some(k)` when every length in `ks` is the same `k` (`None` when empty
/// or mixed) — the one uniformity check behind
/// [`PrecisionPlan::as_uniform`] and `EngineConfig::uniform_k`.
pub fn uniform_of(ks: &[usize]) -> Option<usize> {
    match ks.split_first() {
        Some((k, rest)) if rest.iter().all(|x| x == k) => Some(*k),
        _ => None,
    }
}

/// Check one stage length: positive and [`WORD`]-aligned.
pub fn check_k(k: usize, stage: Option<usize>) -> Result<(), PrecisionError> {
    if k == 0 {
        Err(PrecisionError::ZeroK { stage })
    } else if k % WORD != 0 {
        Err(PrecisionError::Misaligned { stage, k })
    } else {
        Ok(())
    }
}

/// A compiled per-layer precision assignment: one bitstream length per
/// **compute** stage (indexed like `QuantizedWeights::layers`, i.e. by
/// [`crate::accel::stage::StageDescriptor::weight_layer`]). Pool/residual
/// stages operate on recovered values and carry no `k`.
///
/// Built from a [`Precision`] policy (`EngineConfig::resolved_precision`)
/// or directly; compiled into `ForwardPlan` alongside the stage IR and
/// threaded through the hardware model, so the software datapaths and the
/// modeled schedule can never disagree about a layer's stream length.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PrecisionPlan {
    ks: Vec<usize>,
}

impl PrecisionPlan {
    /// The same `k` for every one of `n_layers` compute stages — exactly
    /// today's scalar-`k` behavior.
    pub fn uniform(k: usize, n_layers: usize) -> Self {
        PrecisionPlan { ks: vec![k; n_layers] }
    }

    /// One `k` per compute stage, front to back.
    pub fn per_layer(ks: Vec<usize>) -> Self {
        PrecisionPlan { ks }
    }

    /// Per-compute-stage lengths, front to back.
    pub fn ks(&self) -> &[usize] {
        &self.ks
    }

    /// Compute stages covered.
    pub fn len(&self) -> usize {
        self.ks.len()
    }

    /// True when the plan covers no stages.
    pub fn is_empty(&self) -> bool {
        self.ks.is_empty()
    }

    /// The bitstream length of compute stage `wl` (the stage's
    /// `weight_layer` index). Panics on out-of-range `wl` — validate the
    /// plan against the network first.
    pub fn k_for(&self, wl: usize) -> usize {
        self.ks[wl]
    }

    /// The largest stage length (0 for an empty plan) — the figure a
    /// single-`k` consumer (labels, mode placeholders) should quote.
    pub fn max_k(&self) -> usize {
        self.ks.iter().copied().max().unwrap_or(0)
    }

    /// `Some(k)` when every stage shares one length.
    pub fn as_uniform(&self) -> Option<usize> {
        uniform_of(&self.ks)
    }

    /// Sum of the per-stage lengths — the serial stream-cycle count the
    /// plan spends per inference (the latency/energy proxy a tuner
    /// minimizes).
    pub fn total_cycles(&self) -> usize {
        self.ks.iter().sum()
    }

    /// Every stage length positive and [`WORD`]-aligned, plan non-empty.
    pub fn validate(&self) -> Result<(), PrecisionError> {
        if self.ks.is_empty() {
            return Err(PrecisionError::Empty);
        }
        for (wl, &k) in self.ks.iter().enumerate() {
            check_k(k, Some(wl))?;
        }
        Ok(())
    }

    /// [`PrecisionPlan::validate`] plus the length check against a
    /// network's compute-stage count.
    pub fn validate_for(&self, n_compute: usize) -> Result<(), PrecisionError> {
        if self.ks.len() != n_compute {
            return Err(PrecisionError::WrongLength { expected: n_compute, got: self.ks.len() });
        }
        self.validate()
    }
}

/// The typed precision policy an `EngineConfig` carries: how the per-layer
/// [`PrecisionPlan`] is produced at session open.
#[derive(Debug, Clone, PartialEq)]
pub enum Precision {
    /// One global `k` (back-compat: `EngineConfig::with_k` sets this).
    Uniform(usize),
    /// Explicit per-compute-layer lengths, front to back (CLI
    /// `--k-per-layer`).
    PerLayer(Vec<usize>),
    /// Let the greedy [`autotune`]r shrink per-layer `k` front-to-back
    /// against a held-out calibration batch until the budget binds (CLI
    /// `--k-auto-budget`).
    Auto {
        /// Largest tolerated drop in calibration agreement, in `[0, 1)`
        /// (e.g. `0.05` = five points of calibration accuracy).
        accuracy_budget: f64,
    },
}

impl Precision {
    /// Stable lowercase label (metrics, bench records).
    pub fn label(&self) -> &'static str {
        match self {
            Precision::Uniform(_) => "uniform",
            Precision::PerLayer(_) => "per-layer",
            Precision::Auto { .. } => "auto",
        }
    }
}

/// Knobs of the greedy autotuner. `Precision::Auto` uses
/// [`AutoTuneConfig::new`] with the policy's budget; benches and tests
/// tighten `k_max`/`calib_images` for speed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AutoTuneConfig {
    /// Largest tolerated drop in calibration agreement, in `[0, 1)`.
    /// Resolution is `1 / calib_images` — budgets below that allow no
    /// flips at all.
    pub accuracy_budget: f64,
    /// Starting (and maximum) uniform length — the accuracy ceiling the
    /// budget is measured against.
    pub k_max: usize,
    /// Smallest length a stage may shrink to (the paper's base k = 32 by
    /// default).
    pub k_min: usize,
    /// Held-out calibration images (deterministic from the seed).
    pub calib_images: usize,
}

impl AutoTuneConfig {
    /// Defaults: shrink from a k = 1024 ceiling toward the paper's k = 32
    /// floor over 12 calibration images.
    pub fn new(accuracy_budget: f64) -> Self {
        AutoTuneConfig { accuracy_budget, k_max: 1024, k_min: 32, calib_images: 12 }
    }
}

/// Greedily shrink per-layer bitstream lengths front-to-back until the
/// accuracy budget binds.
///
/// Methodology (the paper's own §V-B accuracy harness): candidate plans
/// are scored with the **analytic noisy-expectation model** at the plan's
/// per-layer `k` — the same O(1/k) sampling-error model Fig. 11/12 are
/// generated from — against the noise-free expectation argmax on a
/// deterministic held-out calibration batch, so a tuning run costs
/// analytic forwards, not bit-level simulation. Starting from uniform
/// `k_max`, each layer's `k` is halved (front to back, staying
/// [`WORD`]-aligned and `>= k_min`) while calibration agreement stays
/// within `accuracy_budget` of the `k_max` baseline; the first rejected
/// halving freezes that layer.
///
/// Fully deterministic for a fixed `(net, weights, seed, config)` — the
/// calibration batch, the noise draws, and the greedy order all derive
/// from the arguments (asserted in `tests/stage_ir.rs`).
pub fn autotune(
    net: &NetworkSpec,
    weights: &QuantizedWeights,
    seed: u32,
    cfg: &AutoTuneConfig,
) -> Result<PrecisionPlan> {
    if !(0.0..1.0).contains(&cfg.accuracy_budget) {
        return Err(anyhow!("{}", PrecisionError::BadBudget { budget: cfg.accuracy_budget }));
    }
    check_k(cfg.k_max, None).map_err(|e| anyhow!("autotune k_max: {e}"))?;
    check_k(cfg.k_min, None).map_err(|e| anyhow!("autotune k_min: {e}"))?;
    if cfg.k_min > cfg.k_max {
        return Err(anyhow!("autotune: k_min {} exceeds k_max {}", cfg.k_min, cfg.k_max));
    }
    let stages = net.stages()?;
    let n = stages.iter().filter(|s| s.is_compute()).count();
    let in_len = stages[0].in_len();

    // Deterministic held-out calibration batch in [0, 1).
    let mut g = XorShift64::new(((seed as u64) << 1) | 1);
    let calib: Vec<Vec<f64>> = (0..cfg.calib_images.max(1))
        .map(|_| (0..in_len).map(|_| (g.next_u64() % 1000) as f64 / 1000.0).collect())
        .collect();

    // Noise-free ideal predictions — the agreement target.
    let exp = ForwardPlan::compile(net, weights, ForwardMode::Expectation)?;
    let mut scr = Scratch::default();
    let truth: Vec<usize> =
        calib.iter().map(|img| classify(&exp.run_with(img, &mut scr, false))).collect();

    // Calibration agreement of one candidate plan under the per-layer
    // noisy-expectation model (per-image noise seeds, like fig11).
    let score = |ks: &[usize]| -> Result<f64> {
        let plan = PrecisionPlan::per_layer(ks.to_vec());
        let mut scr = Scratch::default();
        let mut agree = 0usize;
        for (i, img) in calib.iter().enumerate() {
            let mode = ForwardMode::NoisyExpectation {
                k: plan.max_k(),
                seed: seed ^ 0x9E37_79B9u32.wrapping_mul(i as u32 + 1),
            };
            let p = ForwardPlan::compile_with_precision(net, weights, mode, &plan)?;
            agree += (classify(&p.run_with(img, &mut scr, false)) == truth[i]) as usize;
        }
        Ok(agree as f64 / calib.len() as f64)
    };

    let mut ks = vec![cfg.k_max; n];
    let baseline = score(&ks)?;
    let floor = baseline - cfg.accuracy_budget;
    for i in 0..n {
        loop {
            let cand = ks[i] / 2;
            if cand < cfg.k_min || cand % WORD != 0 {
                break;
            }
            let prev = ks[i];
            ks[i] = cand;
            if score(&ks)? + 1e-12 < floor {
                ks[i] = prev; // this halving broke the budget: freeze the layer
                break;
            }
        }
    }
    Ok(PrecisionPlan::per_layer(ks))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::system::{evaluate_with_channel_precise, SystemConfig};
    use crate::engine::metrics::cached_channel_report;
    use crate::tech::TechKind;

    #[test]
    fn plan_accessors_and_uniformity() {
        let u = PrecisionPlan::uniform(64, 3);
        assert_eq!(u.ks(), &[64, 64, 64]);
        assert_eq!(u.len(), 3);
        assert_eq!(u.as_uniform(), Some(64));
        assert_eq!(u.max_k(), 64);
        assert_eq!(u.total_cycles(), 192);
        let p = PrecisionPlan::per_layer(vec![128, 64, 32]);
        assert_eq!(p.as_uniform(), None);
        assert_eq!(p.max_k(), 128);
        assert_eq!(p.k_for(2), 32);
        assert!(!p.is_empty());
        assert!(PrecisionPlan::per_layer(vec![]).is_empty());
    }

    #[test]
    fn validate_rejects_degenerate_lengths() {
        assert!(PrecisionPlan::uniform(64, 2).validate().is_ok());
        assert_eq!(
            PrecisionPlan::per_layer(vec![]).validate(),
            Err(PrecisionError::Empty)
        );
        assert_eq!(
            PrecisionPlan::per_layer(vec![64, 0]).validate(),
            Err(PrecisionError::ZeroK { stage: Some(1) })
        );
        assert_eq!(
            PrecisionPlan::per_layer(vec![64, 100]).validate(),
            Err(PrecisionError::Misaligned { stage: Some(1), k: 100 })
        );
        assert_eq!(
            PrecisionPlan::uniform(64, 2).validate_for(3),
            Err(PrecisionError::WrongLength { expected: 3, got: 2 })
        );
        assert!(PrecisionPlan::uniform(64, 3).validate_for(3).is_ok());
        // Every error renders a distinct, informative message.
        let msgs: Vec<String> = [
            PrecisionError::ZeroK { stage: None },
            PrecisionError::ZeroK { stage: Some(2) },
            PrecisionError::Misaligned { stage: Some(1), k: 100 },
            PrecisionError::WrongLength { expected: 3, got: 2 },
            PrecisionError::Empty,
            PrecisionError::BadBudget { budget: 1.5 },
        ]
        .iter()
        .map(|e| e.to_string())
        .collect();
        let mut seen = std::collections::HashSet::new();
        for m in &msgs {
            assert!(seen.insert(m.clone()), "duplicate display: {m}");
        }
        assert!(msgs[2].contains("multiple"), "{}", msgs[2]);
    }

    #[test]
    fn policy_labels() {
        assert_eq!(Precision::Uniform(32).label(), "uniform");
        assert_eq!(Precision::PerLayer(vec![32]).label(), "per-layer");
        assert_eq!(Precision::Auto { accuracy_budget: 0.1 }.label(), "auto");
    }

    #[test]
    fn autotune_rejects_bad_knobs() {
        let net = NetworkSpec::mnist_strided();
        let w = QuantizedWeights::synthetic(&net, 8, 1).unwrap();
        let bad = AutoTuneConfig { accuracy_budget: 1.5, ..AutoTuneConfig::new(0.1) };
        assert!(autotune(&net, &w, 7, &bad).is_err());
        let bad = AutoTuneConfig { k_max: 100, ..AutoTuneConfig::new(0.1) };
        assert!(autotune(&net, &w, 7, &bad).is_err());
        let bad = AutoTuneConfig { k_min: 512, k_max: 256, ..AutoTuneConfig::new(0.1) };
        assert!(autotune(&net, &w, 7, &bad).is_err());
    }

    #[test]
    fn autotune_is_deterministic_and_respects_bounds() {
        let net = NetworkSpec::mnist_strided();
        let w = QuantizedWeights::synthetic(&net, 8, 0x5EED).unwrap();
        let cfg = AutoTuneConfig {
            accuracy_budget: 0.25,
            k_max: 256,
            k_min: 32,
            calib_images: 6,
        };
        let a = autotune(&net, &w, 7, &cfg).unwrap();
        let b = autotune(&net, &w, 7, &cfg).unwrap();
        assert_eq!(a, b, "same inputs must tune to the same plan");
        assert_eq!(a.len(), 4, "mnist_strided has four compute stages");
        for &k in a.ks() {
            assert!((cfg.k_min..=cfg.k_max).contains(&k), "k {k} out of bounds");
            assert_eq!(k % WORD, 0, "k {k} must stay word-aligned");
        }
        a.validate_for(4).unwrap();
    }

    #[test]
    fn tuned_plan_beats_uniform_ceiling_on_modeled_energy() {
        // The headline claim: an autotuned plan spends strictly less
        // modeled energy than the uniform k_max ceiling it was budgeted
        // against, on a bundled MNIST topology.
        let net = NetworkSpec::mnist_strided();
        let w = QuantizedWeights::synthetic(&net, 8, 0x5EED).unwrap();
        let cfg = AutoTuneConfig {
            accuracy_budget: 0.34,
            k_max: 1024,
            k_min: 32,
            calib_images: 6,
        };
        let tuned = autotune(&net, &w, 7, &cfg).unwrap();
        assert!(
            tuned.total_cycles() < tuned.len() * cfg.k_max,
            "a generous budget must shrink at least one layer: {tuned:?}"
        );
        let channel = cached_channel_report(TechKind::Rfet10);
        let sys = SystemConfig::paper(TechKind::Rfet10, 8);
        let uniform =
            evaluate_with_channel_precise(&sys, &net, channel, &PrecisionPlan::uniform(1024, 4));
        let shrunk = evaluate_with_channel_precise(&sys, &net, channel, &tuned);
        assert!(
            shrunk.metrics.energy_uj < uniform.metrics.energy_uj,
            "tuned {} µJ vs uniform-1024 {} µJ",
            shrunk.metrics.energy_uj,
            uniform.metrics.energy_uj
        );
        assert!(shrunk.metrics.latency_us < uniform.metrics.latency_us);
    }
}
