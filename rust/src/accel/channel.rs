//! Channel-level characterization (§IV-A Fig. 9, §V Table II).
//!
//! One channel = SNG bank (2 shared LFSRs + one PCC per multiplier operand)
//! → 16 MAC units (25 XNOR multipliers + 25-input APC each) → configurable
//! adder tree → B2S → ReLU/MP → S2B. The channel report composes the
//! individually characterized blocks; this mirrors the paper's observation
//! that PCCs dominate both channel area and energy.
//!
//! **Clocking.** The single-cycle critical path runs LFSR→PCC→XNOR→counter
//! into the APC's pipeline register; the accumulator, adder-tree levels,
//! B2S and S2B stages are registered separately. A global synthesis margin
//! (clock uncertainty + routing derate, identical for both technologies)
//! scales the raw path to the reported min clock period.

use crate::accel::pipeline::{MACS_PER_CHANNEL, MAC_WIDTH};
use crate::netlist::Netlist;
use crate::sc::apc::FaStyle;
use crate::sc::{adder_tree, apc, converters, pcc};
use crate::sim;
use crate::tech::{CellKind, CellLibrary, TechKind};

/// Synthesis margin applied to raw topological paths (clock uncertainty,
/// routing derate, OCV) — one constant for both technologies so ratios are
/// purely architectural.
pub const CLOCK_MARGIN: f64 = 1.675;

/// System precision in bits (8-bit accuracy per §IV-A).
pub const PRECISION_BITS: u32 = 8;
/// Bitstream length k = 32 (§V-B).
pub const BITSTREAM_LEN: usize = 32;

/// Per-block and channel-level characterization under one technology.
#[derive(Debug, Clone)]
pub struct ChannelReport {
    /// Technology characterized.
    pub tech: TechKind,
    /// 8-bit PCC block report (Table I column).
    pub pcc: sim::BlockReport,
    /// 25-input APC block report (Table I column).
    pub apc: sim::BlockReport,
    /// Adder-tree report (16 × 10-bit operands).
    pub adder_tree: sim::BlockReport,
    /// B2S comparator report.
    pub b2s: sim::BlockReport,
    /// S2B counter report.
    pub s2b: sim::BlockReport,
    /// Total channel area (µm²).
    pub area_um2: f64,
    /// Minimum clock period (ps) after margin.
    pub min_clock_ps: f64,
    /// Average switching energy per clock cycle (fJ).
    pub energy_per_cycle_fj: f64,
    /// Channel leakage (nW).
    pub leakage_nw: f64,
}

/// PCC kind each technology uses (the paper compares MUX-chain FinFET
/// against NAND-NOR RFET).
pub fn pcc_kind_for(tech: TechKind) -> pcc::PccKind {
    match tech {
        TechKind::Finfet10 => pcc::PccKind::MuxChain,
        TechKind::Rfet10 => pcc::PccKind::NandNor,
    }
}

/// FA style each technology uses.
pub fn fa_style_for(tech: TechKind) -> FaStyle {
    match tech {
        TechKind::Finfet10 => FaStyle::CmosCell,
        TechKind::Rfet10 => FaStyle::RfetCompact,
    }
}

/// Deterministic xorshift for stimulus.
fn xorshift(seed: u64) -> impl FnMut() -> u64 {
    let mut s = seed.max(1);
    move || {
        s ^= s << 13;
        s ^= s >> 7;
        s ^= s << 17;
        s
    }
}

/// PCC stimulus: X held for a full bitstream window (operands are static
/// during conversion), R random every cycle.
fn pcc_stimulus(bits: u32) -> impl FnMut(usize, &mut Vec<bool>) {
    let mut rng = xorshift(0x5eed);
    let mut x: u64 = 0xB5;
    move |t, pins| {
        if t % BITSTREAM_LEN == 0 {
            x = rng();
        }
        let r = rng();
        for i in 0..bits as usize {
            pins[i] = (x >> i) & 1 == 1;
            pins[bits as usize + i] = (r >> i) & 1 == 1;
        }
    }
}

/// Random-bit stimulus with '1'-density ≈ 1/2 (APC inputs are XNOR products
/// of near-balanced bipolar streams).
fn random_stimulus(seed: u64) -> impl FnMut(usize, &mut Vec<bool>) {
    let mut rng = xorshift(seed);
    move |_t, pins| {
        for p in pins.iter_mut() {
            *p = rng() % 2 == 1;
        }
    }
}

/// Characterize the 8-bit PCC for `tech` (Table I, PCC columns).
pub fn characterize_pcc(lib: &CellLibrary) -> sim::BlockReport {
    let kind = pcc_kind_for(lib.kind);
    let nl = pcc::build_netlist(kind, PRECISION_BITS);
    sim::characterize(&nl, lib, 2048, pcc_stimulus(PRECISION_BITS))
}

/// Characterize the 25-input APC for `tech` (Table I, APC columns).
pub fn characterize_apc(lib: &CellLibrary) -> sim::BlockReport {
    let nl = apc::build_netlist(MAC_WIDTH, BITSTREAM_LEN, fa_style_for(lib.kind))
        .expect("MAC_WIDTH and BITSTREAM_LEN are nonzero paper constants");
    sim::characterize(&nl, lib, 2048, random_stimulus(0xAAC))
}

/// Characterize the configurable adder tree (16 operands × 10 bits).
pub fn characterize_adder_tree(lib: &CellLibrary) -> sim::BlockReport {
    let nl = adder_tree::build_netlist(MACS_PER_CHANNEL, 10, fa_style_for(lib.kind))
        .expect("MACS_PER_CHANNEL is a nonzero paper constant");
    sim::characterize(&nl, lib, 512, random_stimulus(0x7ee))
}

/// Characterize the B2S comparator (count width + 1 bits).
pub fn characterize_b2s(lib: &CellLibrary) -> sim::BlockReport {
    let nl = converters::build_b2s_netlist(6);
    sim::characterize(&nl, lib, 1024, random_stimulus(0xB25))
}

/// Characterize the S2B output counter (8-bit).
pub fn characterize_s2b(lib: &CellLibrary) -> sim::BlockReport {
    let nl = converters::build_s2b_netlist(8);
    sim::characterize(&nl, lib, 1024, random_stimulus(0x52B))
}

/// Raw (pre-margin) single-cycle critical path: PCC → XNOR → APC counter
/// into the pipeline register.
fn mac_stage_path_ps(lib: &CellLibrary, pcc_delay: f64) -> f64 {
    // Counter-only delay: build the 25-input counter without accumulator.
    let mut nl = Netlist::new("counter25");
    let ins = nl.inputs(MAC_WIDTH);
    let outs = apc::build_parallel_counter(&mut nl, fa_style_for(lib.kind), &ins)
        .expect("MAC_WIDTH is a nonzero paper constant");
    for o in outs {
        nl.mark_output(o);
    }
    let counter = sim::analyze_timing(&nl, lib).critical_path_ps;
    let xnor = lib.cell(CellKind::Xnor2).delay_ps;
    let dff = lib.cell(CellKind::Dff).delay_ps;
    pcc_delay + xnor + counter + dff
}

/// Number of PCC instances per channel: one per multiplier operand
/// (activations + weights) across all MACs.
pub const PCCS_PER_CHANNEL: usize = 2 * MACS_PER_CHANNEL * MAC_WIDTH;
/// XNOR multipliers per channel.
pub const XNORS_PER_CHANNEL: usize = MACS_PER_CHANNEL * MAC_WIDTH;

/// Characterize one full channel under `tech`.
pub fn characterize_channel(tech: TechKind) -> ChannelReport {
    let lib = CellLibrary::for_kind(tech);
    let pcc_rep = characterize_pcc(&lib);
    let apc_rep = characterize_apc(&lib);
    let tree_rep = characterize_adder_tree(&lib);
    let b2s_rep = characterize_b2s(&lib);
    let s2b_rep = characterize_s2b(&lib);

    let xnor = lib.cell(CellKind::Xnor2);
    let dff = lib.cell(CellKind::Dff);
    // Two shared 8-bit LFSRs (act + weight RNS) + one 6-bit B2S LFSR:
    // 22 DFFs + a handful of feedback XORs.
    let lfsr_dffs = 22.0;
    let xor = lib.cell(CellKind::Xor2);

    let area_um2 = PCCS_PER_CHANNEL as f64 * pcc_rep.area_um2
        + XNORS_PER_CHANNEL as f64 * xnor.area_um2
        + MACS_PER_CHANNEL as f64 * apc_rep.area_um2
        + tree_rep.area_um2
        + MACS_PER_CHANNEL as f64 * (b2s_rep.area_um2 + s2b_rep.area_um2)
        + lfsr_dffs * dff.area_um2
        + 6.0 * xor.area_um2;

    // Energy/cycle: PCCs convert every cycle; every multiplier toggles with
    // its products; APCs count every cycle; tree/B2S/S2B follow.
    let xnor_energy = XNORS_PER_CHANNEL as f64 * 0.5 * xnor.switch_energy_fj;
    let lfsr_energy = lfsr_dffs
        * dff.switch_energy_fj
        * (crate::sim::power::DFF_CLOCK_ENERGY_FRACTION + 0.5)
        + 6.0 * 0.5 * xor.switch_energy_fj;
    let energy_per_cycle_fj = PCCS_PER_CHANNEL as f64 * pcc_rep.energy_per_cycle_fj
        + xnor_energy
        + MACS_PER_CHANNEL as f64 * apc_rep.energy_per_cycle_fj
        + tree_rep.energy_per_cycle_fj
        + MACS_PER_CHANNEL as f64 * (b2s_rep.energy_per_cycle_fj + s2b_rep.energy_per_cycle_fj)
        + lfsr_energy;

    let leakage_nw = PCCS_PER_CHANNEL as f64 * pcc_rep.leakage_nw
        + XNORS_PER_CHANNEL as f64 * xnor.leakage_nw
        + MACS_PER_CHANNEL as f64 * apc_rep.leakage_nw
        + tree_rep.leakage_nw
        + MACS_PER_CHANNEL as f64 * (b2s_rep.leakage_nw + s2b_rep.leakage_nw)
        + lfsr_dffs * dff.leakage_nw;

    // Min clock: the MAC stage dominates; tree levels / converters are
    // individually registered and shorter.
    let mac_path = mac_stage_path_ps(&lib, pcc_rep.delay_ps);
    let stage_paths = [
        mac_path,
        tree_rep.delay_ps / 2.0 + dff.delay_ps, // tree pipelined in 2 stages
        b2s_rep.delay_ps + dff.delay_ps,
        s2b_rep.delay_ps + dff.delay_ps,
    ];
    let min_clock_ps =
        CLOCK_MARGIN * stage_paths.iter().fold(0.0f64, |m, &p| m.max(p));

    ChannelReport {
        tech,
        pcc: pcc_rep,
        apc: apc_rep,
        adder_tree: tree_rep,
        b2s: b2s_rep,
        s2b: s2b_rep,
        area_um2,
        min_clock_ps,
        energy_per_cycle_fj,
        leakage_nw,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tech::calibration::{self, rel_err};

    #[test]
    fn table1_pcc_reproduced() {
        let fin = characterize_pcc(&CellLibrary::finfet10());
        let rf = characterize_pcc(&CellLibrary::rfet10());
        let t = calibration::CALIBRATION_RTOL;
        assert!(rel_err(fin.area_um2, calibration::TABLE1_FINFET_PCC8.area_um2) < t, "fin area {}", fin.area_um2);
        assert!(rel_err(fin.delay_ps, calibration::TABLE1_FINFET_PCC8.delay_ps) < t, "fin delay {}", fin.delay_ps);
        assert!(rel_err(rf.area_um2, calibration::TABLE1_RFET_PCC8.area_um2) < t, "rfet area {}", rf.area_um2);
        assert!(rel_err(rf.delay_ps, calibration::TABLE1_RFET_PCC8.delay_ps) < t, "rfet delay {}", rf.delay_ps);
    }

    #[test]
    fn table1_pcc_energy_reproduced() {
        let fin = characterize_pcc(&CellLibrary::finfet10());
        let rf = characterize_pcc(&CellLibrary::rfet10());
        let t = calibration::CALIBRATION_RTOL;
        assert!(
            rel_err(fin.energy_per_cycle_fj, calibration::TABLE1_FINFET_PCC8.energy_fj) < t,
            "fin energy {}",
            fin.energy_per_cycle_fj
        );
        assert!(
            rel_err(rf.energy_per_cycle_fj, calibration::TABLE1_RFET_PCC8.energy_fj) < t,
            "rfet energy {}",
            rf.energy_per_cycle_fj
        );
    }

    #[test]
    fn table1_apc_reproduced() {
        let fin = characterize_apc(&CellLibrary::finfet10());
        let rf = characterize_apc(&CellLibrary::rfet10());
        let t = calibration::CALIBRATION_RTOL;
        assert!(rel_err(fin.area_um2, calibration::TABLE1_FINFET_APC25.area_um2) < t, "fin area {}", fin.area_um2);
        assert!(rel_err(rf.area_um2, calibration::TABLE1_RFET_APC25.area_um2) < t, "rfet area {}", rf.area_um2);
        assert!(rel_err(fin.delay_ps, calibration::TABLE1_FINFET_APC25.delay_ps) < t, "fin delay {}", fin.delay_ps);
        assert!(rel_err(rf.delay_ps, calibration::TABLE1_RFET_APC25.delay_ps) < t, "rfet delay {}", rf.delay_ps);
        assert!(
            rel_err(fin.energy_per_cycle_fj, calibration::TABLE1_FINFET_APC25.energy_fj) < t,
            "fin energy {}",
            fin.energy_per_cycle_fj
        );
        assert!(
            rel_err(rf.energy_per_cycle_fj, calibration::TABLE1_RFET_APC25.energy_fj) < t,
            "rfet energy {}",
            rf.energy_per_cycle_fj
        );
    }

    #[test]
    fn table2_channel_predicted() {
        let fin = characterize_channel(TechKind::Finfet10);
        let rf = characterize_channel(TechKind::Rfet10);
        let t = calibration::PREDICTION_RTOL;
        assert!(
            rel_err(fin.area_um2, calibration::TABLE2_FINFET_CHANNEL.area_um2) < t,
            "fin channel area {}",
            fin.area_um2
        );
        assert!(
            rel_err(rf.area_um2, calibration::TABLE2_RFET_CHANNEL.area_um2) < t,
            "rfet channel area {}",
            rf.area_um2
        );
        assert!(
            rel_err(fin.energy_per_cycle_fj, calibration::TABLE2_FINFET_CHANNEL.energy_fj) < t,
            "fin channel energy {}",
            fin.energy_per_cycle_fj
        );
        assert!(
            rel_err(rf.energy_per_cycle_fj, calibration::TABLE2_RFET_CHANNEL.energy_fj) < t,
            "rfet channel energy {}",
            rf.energy_per_cycle_fj
        );
        // The paper's headline directions must hold: RFET smaller, faster,
        // and much lower energy at channel level.
        assert!(rf.area_um2 < fin.area_um2, "RFET channel must be smaller");
        assert!(rf.min_clock_ps < fin.min_clock_ps, "RFET channel must clock faster");
        assert!(
            rf.energy_per_cycle_fj < 0.85 * fin.energy_per_cycle_fj,
            "RFET channel energy must be well below FinFET"
        );
    }
}
