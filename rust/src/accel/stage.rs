//! The compiled per-layer **stage IR**: one [`StageDescriptor`] per layer
//! of a [`NetworkSpec`], produced by the single non-panicking
//! shape-inference pass [`NetworkSpec::stages`]. Every consumer lowers
//! from this IR instead of re-walking the layer vocabulary:
//!
//! * the fused stochastic engine and the per-bit golden reference build
//!   their gather tables from [`gather`] (shared, so bit-exact parity of
//!   the datapaths is parity *by construction*);
//! * the analytic expectation / noisy / fixed-point paths lower the same
//!   descriptors to dequantized-weight loops;
//! * the hardware model ([`crate::accel::pipeline`] /
//!   [`crate::accel::system`]) derives each layer's schedule, DRAM traffic
//!   and energy from the descriptor's `neurons`/`fan_in` — no ad-hoc
//!   `NetworkSpec` walks;
//! * weight loaders and synthetic-weight generators size their tensors
//!   from [`StageDescriptor::weight_shape`].
//!
//! Stages that own no MACs (pooling, global pooling, residual merges)
//! operate on the *recovered* values at the layer boundary — the SC
//! pipeline recovers binary codes at every S2B anyway, so max pooling is a
//! plain max, average pooling is the counter-based scaled add of SC-DCNN
//! (behavioral stream kernel in [`crate::sc::neuron::avg_pool_stream`]),
//! and the residual [`LayerKind::Add`] is the SC MUX scaled add
//! `(a + b) / 2`. The value kernels live here so every backend executes
//! the identical f64 math.

use crate::accel::layers::{Conv2d, LayerKind, NetworkSpec, Shape};
use anyhow::{bail, Result};

/// The operation a compiled stage performs (the layer vocabulary with all
/// shape questions already answered).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StageOp {
    /// 2-D convolution (square/rectangular, strided, optionally depthwise).
    Conv(Conv2d),
    /// Fully connected.
    Dense {
        /// Flattened input size.
        inputs: usize,
        /// Output neurons.
        outputs: usize,
    },
    /// Non-overlapping max pool.
    MaxPool {
        /// Window size.
        size: usize,
    },
    /// Non-overlapping average pool (SC counter-based scaled add).
    AvgPool {
        /// Window size.
        size: usize,
    },
    /// Spatial mean per channel.
    GlobalAvgPool,
    /// SC scaled-add residual merge with the saved output of layer `from`.
    Add {
        /// Producing layer index.
        from: usize,
    },
}

/// One compiled stage: everything the software backends and the hardware
/// model need to lower this layer, computed once by [`NetworkSpec::stages`].
#[derive(Debug, Clone, PartialEq)]
pub struct StageDescriptor {
    /// Layer index in the source [`NetworkSpec`].
    pub index: usize,
    /// The operation.
    pub op: StageOp,
    /// Fused ReLU at the stage output (compute stages only).
    pub relu: bool,
    /// Activation shape entering the stage.
    pub in_shape: Shape,
    /// Activation shape leaving the stage.
    pub out_shape: Shape,
    /// MAC-owning outputs (0 for pool/add stages).
    pub neurons: usize,
    /// Products per neuron (0 for pool/add stages).
    pub fan_in: usize,
    /// Index into `QuantizedWeights::layers` (compute stages only).
    pub weight_layer: Option<usize>,
    /// This stage's output is consumed later by a residual merge and must
    /// be kept alive past the next stage.
    pub save_output: bool,
    /// Last compute stage of the network (its outputs are logits — the
    /// re-encoder skips the [0, 1] clamp).
    pub final_compute: bool,
}

impl StageDescriptor {
    /// Stable lowercase label (schedules, bench records, reports).
    pub fn label(&self) -> &'static str {
        match self.op {
            StageOp::Conv(c) if c.depthwise => "depthwise-conv",
            StageOp::Conv(_) => "conv",
            StageOp::Dense { .. } => "dense",
            StageOp::MaxPool { .. } => "maxpool",
            StageOp::AvgPool { .. } => "avgpool",
            StageOp::GlobalAvgPool => "global-avgpool",
            StageOp::Add { .. } => "add",
        }
    }

    /// True for MAC-owning (weight-carrying) stages.
    pub fn is_compute(&self) -> bool {
        self.weight_layer.is_some()
    }

    /// Multiply-accumulates this stage performs per inference.
    pub fn macs(&self) -> u64 {
        self.neurons as u64 * self.fan_in as u64
    }

    /// Weight tensor shape `(rows, cols)` — `rows` output channels /
    /// neurons of `cols = fan_in` codes each — for compute stages.
    pub fn weight_shape(&self) -> Option<(usize, usize)> {
        match self.op {
            StageOp::Conv(c) => Some((c.out_ch, c.fan_in())),
            StageOp::Dense { inputs, outputs } => Some((outputs, inputs)),
            _ => None,
        }
    }

    /// Flattened input length (c·h·w of `in_shape`).
    pub fn in_len(&self) -> usize {
        self.in_shape.0 * self.in_shape.1 * self.in_shape.2
    }

    /// Flattened output length.
    pub fn out_len(&self) -> usize {
        self.out_shape.0 * self.out_shape.1 * self.out_shape.2
    }
}

/// Total MACs of a compiled stage list (equals
/// [`NetworkSpec::total_macs`] on the same network).
pub fn total_macs(stages: &[StageDescriptor]) -> u64 {
    stages.iter().map(|s| s.macs()).sum()
}

impl NetworkSpec {
    /// Compile the network into its stage IR: one descriptor per layer,
    /// with shapes inferred, weight layers numbered, residual save points
    /// marked, and every malformed stack rejected with a typed error (see
    /// [`NetworkSpec::validate`], which this subsumes).
    pub fn stages(&self) -> Result<Vec<StageDescriptor>> {
        let in_shapes = self.validate()?;
        let mut save = vec![false; self.layers.len()];
        for l in &self.layers {
            if let LayerKind::Add { from } = l.kind {
                save[from] = true;
            }
        }
        let last_compute = self
            .layers
            .iter()
            .rposition(|l| l.is_compute())
            .expect("validate guarantees a compute layer");
        let mut wl = 0usize;
        let mut stages = Vec::with_capacity(self.layers.len());
        for (li, l) in self.layers.iter().enumerate() {
            let in_shape = in_shapes[li];
            let out_shape = l
                .try_output_shape(in_shape)
                .expect("validate already inferred every shape");
            let op = match &l.kind {
                LayerKind::Conv(c) => StageOp::Conv(*c),
                LayerKind::Dense { inputs, outputs } => {
                    StageOp::Dense { inputs: *inputs, outputs: *outputs }
                }
                LayerKind::MaxPool { size } => StageOp::MaxPool { size: *size },
                LayerKind::AvgPool { size } => StageOp::AvgPool { size: *size },
                LayerKind::GlobalAvgPool => StageOp::GlobalAvgPool,
                LayerKind::Add { from } => StageOp::Add { from: *from },
            };
            let weight_layer = if l.is_compute() {
                wl += 1;
                Some(wl - 1)
            } else {
                None
            };
            stages.push(StageDescriptor {
                index: li,
                op,
                relu: l.relu,
                in_shape,
                out_shape,
                neurons: l.neurons(in_shape),
                fan_in: l.fan_in(in_shape),
                weight_layer,
                save_output: save[li],
                final_compute: li == last_compute,
            });
        }
        Ok(stages)
    }
}

/// Im2col-style gather table of a compute stage: the flat input indices
/// feeding each output neuron (`None` = zero padding).
#[derive(Debug, Clone)]
pub struct GatherTable {
    /// Gather windows. For `per_channel` tables the layout is
    /// output-channel-major: window `oc · n_win + wi` feeds output channel
    /// `oc`'s spatial site `wi`; otherwise all output channels share the
    /// `n_win` spatial windows.
    pub windows: Vec<Vec<Option<usize>>>,
    /// Spatial windows per output channel (`oh · ow`; 1 for dense).
    pub n_win: usize,
    /// True when every output channel has its own windows (depthwise).
    pub per_channel: bool,
}

impl GatherTable {
    /// The gather window feeding output channel `oc`, spatial site `wi`.
    pub fn window(&self, oc: usize, wi: usize) -> &[Option<usize>] {
        if self.per_channel {
            &self.windows[oc * self.n_win + wi]
        } else {
            &self.windows[wi]
        }
    }

    /// True when any window touches zero padding.
    pub fn needs_padding(&self) -> bool {
        self.windows.iter().any(|w| w.iter().any(|s| s.is_none()))
    }
}

/// Build the gather table of a compute stage (`None` for pool/add stages).
/// Both the fused word-packed engine and the per-bit reference read their
/// windows from here, so the two datapaths cannot diverge on geometry.
pub fn gather(desc: &StageDescriptor) -> Option<GatherTable> {
    match desc.op {
        StageOp::Conv(c) => Some(conv_gather(desc.in_shape, &c)),
        StageOp::Dense { inputs, .. } => Some(GatherTable {
            windows: vec![(0..inputs).map(Some).collect()],
            n_win: 1,
            per_channel: false,
        }),
        _ => None,
    }
}

/// Gather table of a (possibly strided / rectangular / depthwise)
/// convolution. Window order is `oy`-major then `ox`; within a window the
/// lane order is `ic, ky, kx` — identical to the original stride-1 path,
/// so existing `lenet5`/`cifar_net` streams are bit-compatible.
fn conv_gather(input: Shape, c: &Conv2d) -> GatherTable {
    let (ch, h, w) = input;
    let (kh, kw) = c.kernel;
    let (sy, sx) = c.stride;
    let p = c.padding;
    let oh = (h + 2 * p - kh) / sy + 1;
    let ow = (w + 2 * p - kw) / sx + 1;
    let n_win = oh * ow;
    // Depthwise windows read one channel; shared windows read all of them.
    let per_channel = c.depthwise;
    let channel_groups: Vec<Vec<usize>> = if per_channel {
        (0..ch).map(|ic| vec![ic]).collect()
    } else {
        vec![(0..ch).collect()]
    };
    let mut windows = Vec::with_capacity(if per_channel { ch * n_win } else { n_win });
    for group in &channel_groups {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut idx = Vec::with_capacity(group.len() * kh * kw);
                for &ic in group {
                    for ky in 0..kh {
                        for kx in 0..kw {
                            let iy = oy * sy + ky;
                            let ix = ox * sx + kx;
                            if iy < p || ix < p || iy - p >= h || ix - p >= w {
                                idx.push(None);
                            } else {
                                idx.push(Some(ic * h * w + (iy - p) * w + (ix - p)));
                            }
                        }
                    }
                }
                windows.push(idx);
            }
        }
    }
    GatherTable { windows, n_win, per_channel }
}

// ---- value-domain stage kernels (shared by every backend) ---------------

/// Max-pool plain values into `out` (the SC pipeline pools on correlated
/// streams before S2B; on recovered values the same max applies).
pub fn max_pool_into(v: &[f64], shape: Shape, size: usize, out: &mut Vec<f64>) {
    let (c, h, w) = shape;
    let (oh, ow) = (h / size, w / size);
    out.clear();
    out.reserve(c * oh * ow);
    for ic in 0..c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut m = f64::MIN;
                for ky in 0..size {
                    for kx in 0..size {
                        m = m.max(v[ic * h * w + (oy * size + ky) * w + (ox * size + kx)]);
                    }
                }
                out.push(m);
            }
        }
    }
}

/// Average-pool plain values into `out` — the recovered-value equivalent
/// of the counter-based SC scaled add
/// ([`crate::sc::neuron::avg_pool_stream`] is the stream-level kernel).
pub fn avg_pool_into(v: &[f64], shape: Shape, size: usize, out: &mut Vec<f64>) {
    let (c, h, w) = shape;
    let (oh, ow) = (h / size, w / size);
    let inv = 1.0 / (size * size) as f64;
    out.clear();
    out.reserve(c * oh * ow);
    for ic in 0..c {
        for oy in 0..oh {
            for ox in 0..ow {
                let mut s = 0.0;
                for ky in 0..size {
                    for kx in 0..size {
                        s += v[ic * h * w + (oy * size + ky) * w + (ox * size + kx)];
                    }
                }
                out.push(s * inv);
            }
        }
    }
}

/// Spatial mean per channel into `out`: (c, h, w) → c values.
pub fn global_avg_pool_into(v: &[f64], shape: Shape, out: &mut Vec<f64>) {
    let (c, h, w) = shape;
    let inv = 1.0 / (h * w) as f64;
    out.clear();
    out.reserve(c);
    for ic in 0..c {
        let s: f64 = v[ic * h * w..(ic + 1) * h * w].iter().sum();
        out.push(s * inv);
    }
}

/// The SC scaled-add residual merge `(a + b) / 2` into `out` — a MUX with
/// select probability ½ on the two recovered activations.
pub fn scaled_add_into(a: &[f64], b: &[f64], out: &mut Vec<f64>) {
    debug_assert_eq!(a.len(), b.len(), "residual operands must agree in size");
    out.clear();
    out.extend(a.iter().zip(b).map(|(&x, &y)| 0.5 * (x + y)));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::layers::LayerSpec;

    #[test]
    fn lenet5_stage_ir_matches_layer_walk() {
        let net = NetworkSpec::lenet5();
        let stages = net.stages().unwrap();
        assert_eq!(stages.len(), net.layers.len());
        // Weight layers number 0..5 over the compute stages.
        let wls: Vec<Option<usize>> = stages.iter().map(|s| s.weight_layer).collect();
        assert_eq!(wls, vec![Some(0), None, Some(1), None, Some(2), Some(3), Some(4)]);
        assert_eq!(total_macs(&stages), net.total_macs());
        assert!(stages.iter().all(|s| !s.save_output), "no residuals in lenet5");
        assert_eq!(stages.last().unwrap().out_shape, (10, 1, 1));
        assert!(stages.last().unwrap().final_compute);
        assert_eq!(stages[0].weight_shape(), Some((6, 25)));
        assert_eq!(stages[4].weight_shape(), Some((120, 400)));
    }

    #[test]
    fn mnist_strided_stage_ir() {
        let net = NetworkSpec::mnist_strided();
        let stages = net.stages().unwrap();
        assert!(stages[0].save_output, "the stem feeds the residual");
        assert!(!stages[1].save_output);
        assert_eq!(stages[2].op, StageOp::Add { from: 0 });
        assert_eq!(stages[2].neurons, 0);
        assert_eq!(stages[1].label(), "depthwise-conv");
        assert_eq!(stages[1].weight_shape(), Some((8, 9)));
        assert_eq!(stages[5].label(), "global-avgpool");
        assert_eq!(total_macs(&stages), net.total_macs());
    }

    #[test]
    fn conv_gather_matches_stride1_reference_layout() {
        // 1×4×4 input, 3×3 kernel, padding 1: window (0,0) touches the
        // top-left padding exactly like the original implementation.
        let c = Conv2d::square(1, 2, 3, 1);
        let t = conv_gather((1, 4, 4), &c);
        assert_eq!(t.n_win, 16);
        assert!(!t.per_channel);
        assert!(t.needs_padding());
        let w00 = t.window(0, 0);
        assert_eq!(w00.len(), 9);
        assert_eq!(w00[0], None); // (-1,-1)
        assert_eq!(w00[4], Some(0)); // center = input (0,0)
        assert_eq!(w00[8], Some(5)); // (1,1)
        // Interior window has no padding.
        let w5 = t.window(1, 5); // shared across output channels
        assert!(w5.iter().all(|s| s.is_some()));
    }

    #[test]
    fn strided_gather_skips_sites() {
        let c = Conv2d::square(1, 1, 3, 1).with_stride(2, 2);
        let t = conv_gather((1, 4, 4), &c);
        // (4+2-3)/2+1 = 2 per axis.
        assert_eq!(t.n_win, 4);
        // Window (0,1) centers at input column 2: lane (ky=1,kx=1) reads
        // flat index 0*4 + 2.
        let w = t.window(0, 1);
        assert_eq!(w[4], Some(2));
    }

    #[test]
    fn depthwise_gather_is_per_channel() {
        let c = Conv2d::square(3, 3, 3, 1).depthwise();
        let t = conv_gather((3, 4, 4), &c);
        assert!(t.per_channel);
        assert_eq!(t.windows.len(), 3 * 16);
        // Channel 2's center lane reads from channel 2's plane: flat
        // index 2·(4·4) + 1·4 + 1 = 37 for the (1,1) site.
        let w = t.window(2, 5); // oy=1, ox=1
        assert_eq!(w.len(), 9);
        assert_eq!(w[4], Some(37));
    }

    #[test]
    fn dense_gather_is_the_identity_window() {
        let net = NetworkSpec {
            name: "d".into(),
            input: (1, 2, 2),
            layers: vec![LayerSpec::linear(crate::accel::layers::LayerKind::Dense {
                inputs: 4,
                outputs: 3,
            })],
        };
        let stages = net.stages().unwrap();
        let t = gather(&stages[0]).unwrap();
        assert_eq!(t.n_win, 1);
        assert_eq!(t.window(2, 0), &[Some(0), Some(1), Some(2), Some(3)][..]);
        assert!(gather(&StageDescriptor {
            op: StageOp::GlobalAvgPool,
            ..stages[0].clone()
        })
        .is_none());
    }

    #[test]
    fn value_kernels_compute_expected_reductions() {
        // 1 channel, 2×2.
        let v = [1.0, 3.0, 5.0, 7.0];
        let mut out = Vec::new();
        max_pool_into(&v, (1, 2, 2), 2, &mut out);
        assert_eq!(out, vec![7.0]);
        avg_pool_into(&v, (1, 2, 2), 2, &mut out);
        assert_eq!(out, vec![4.0]);
        global_avg_pool_into(&v, (1, 2, 2), &mut out);
        assert_eq!(out, vec![4.0]);
        // Two channels.
        let v2 = [1.0, 3.0, 5.0, 7.0, 2.0, 2.0, 2.0, 2.0];
        global_avg_pool_into(&v2, (2, 2, 2), &mut out);
        assert_eq!(out, vec![4.0, 2.0]);
        scaled_add_into(&[0.2, 0.8], &[0.6, 0.0], &mut out);
        assert_eq!(out, vec![0.4, 0.4]);
    }
}
