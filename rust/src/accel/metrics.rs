//! System-level figures of merit: ADP, EDP, EDAP (the channel-count
//! selection criteria of §V-C) and the Table III throughput metrics.

/// One system design point.
#[derive(Debug, Clone, Copy)]
pub struct SystemMetrics {
    /// Channels instantiated.
    pub channels: usize,
    /// Total die area (logic + SRAM + buffers), mm².
    pub area_mm2: f64,
    /// Channel-logic area only, mm² — the paper's Fig. 13 "logic part"
    /// curve, used for the ADP/EDAP channel-selection study (the fixed
    /// buffer/control overhead would otherwise mask the channel cost).
    pub logic_area_mm2: f64,
    /// Per-inference latency, µs.
    pub latency_us: f64,
    /// Per-inference energy, µJ.
    pub energy_uj: f64,
    /// Average power during inference, mW.
    pub power_mw: f64,
    /// Clock frequency, GHz.
    pub clock_ghz: f64,
    /// Binary-equivalent tera-ops per second (2 ops per MAC).
    pub tops: f64,
}

impl SystemMetrics {
    /// Area–delay product (mm²·µs) over the logic area (§V-C convention).
    pub fn adp(&self) -> f64 {
        self.logic_area_mm2 * self.latency_us
    }

    /// Energy–delay product (µJ·µs).
    pub fn edp(&self) -> f64 {
        self.energy_uj * self.latency_us
    }

    /// Energy–delay–area product (µJ·µs·mm²) over the logic area.
    pub fn edap(&self) -> f64 {
        self.energy_uj * self.latency_us * self.logic_area_mm2
    }

    /// TOPS per watt.
    pub fn tops_per_watt(&self) -> f64 {
        self.tops / (self.power_mw / 1000.0)
    }

    /// TOPS per mm².
    pub fn tops_per_mm2(&self) -> f64 {
        self.tops / self.area_mm2
    }
}

/// Index of the design point minimizing a figure of merit.
pub fn argmin_by<F: Fn(&SystemMetrics) -> f64>(points: &[SystemMetrics], f: F) -> usize {
    points
        .iter()
        .enumerate()
        .min_by(|(_, a), (_, b)| f(a).partial_cmp(&f(b)).unwrap())
        .map(|(i, _)| i)
        .expect("non-empty design space")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(area: f64, lat: f64, en: f64) -> SystemMetrics {
        SystemMetrics {
            channels: 1,
            area_mm2: area,
            logic_area_mm2: area,
            latency_us: lat,
            energy_uj: en,
            power_mw: en / lat * 1000.0,
            clock_ghz: 1.0,
            tops: 1.0,
        }
    }

    #[test]
    fn products_multiply() {
        let p = point(2.0, 3.0, 5.0);
        assert!((p.adp() - 6.0).abs() < 1e-12);
        assert!((p.edp() - 15.0).abs() < 1e-12);
        assert!((p.edap() - 30.0).abs() < 1e-12);
    }

    #[test]
    fn efficiency_metrics() {
        let p = point(0.5, 1.0, 0.02);
        // power = 20 mW, tops = 1 ⇒ 50 TOPS/W; 2 TOPS/mm².
        assert!((p.tops_per_watt() - 50.0).abs() < 1e-9);
        assert!((p.tops_per_mm2() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn argmin_finds_minimum() {
        let pts = vec![point(2.0, 2.0, 2.0), point(1.0, 1.0, 1.0), point(3.0, 1.0, 1.0)];
        assert_eq!(argmin_by(&pts, |p| p.edap()), 1);
        assert_eq!(argmin_by(&pts, |p| p.adp()), 1);
    }
}
