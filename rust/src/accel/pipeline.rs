//! Algorithm 1: the pipeline strategy that matches on-chip compute
//! parallelism to the off-chip loading bandwidth (§IV-B, Fig. 10).
//!
//! Per layer, with `n_onchip` neurons resident at once and `n_memcover`
//! neurons whose operands memory can deliver per clock cycle:
//!
//! * `n_onchip < n_memcover` → **non-pipelined**: every resident neuron
//!   computes in parallel; `D = ceil(n/n_onchip) · k · τ` (line 8).
//! * otherwise `incycle_pipe = ceil(n_onchip/n_memcover)` load cycles fill
//!   the on-chip units;
//!   * `incycle_pipe < k` → **partially pipelined** (Fig. 10):
//!     `D = [groups·(k+1) + incycle_pipe − 1] · τ` (line 14);
//!   * else → **fully pipelined** (memory-bound): loading overlaps compute
//!     completely; `D = (groups·incycle_pipe + k) · τ` — the paper's line
//!     17 with the group factor made explicit (for `groups = 1` the two
//!     coincide).
//!
//! The scheduler consumes the compiled **stage IR**
//! ([`crate::accel::stage::StageDescriptor`]): each stage's `neurons` /
//! `fan_in` determine its residency, memory coverage and traffic, so the
//! hardware model and the software datapaths cost the *same* per-layer
//! descriptors — there is no separate `NetworkSpec` walk to drift out of
//! sync.

use crate::accel::layers::NetworkSpec;
use crate::accel::memory::MemoryModel;
use crate::accel::precision::PrecisionPlan;
use crate::accel::stage::StageDescriptor;

/// Inputs a MAC unit multiplies per cycle (25 parallel multipliers, §IV-A).
pub const MAC_WIDTH: usize = 25;
/// MAC units per channel (§IV-A).
pub const MACS_PER_CHANNEL: usize = 16;

/// Hardware configuration relevant to scheduling.
#[derive(Debug, Clone, Copy)]
pub struct ScheduleConfig {
    /// Number of channels.
    pub channels: usize,
    /// Bitstream length k.
    pub k: usize,
    /// Clock period in picoseconds.
    pub clock_ps: f64,
    /// Off-chip memory.
    pub memory: MemoryModel,
    /// Operand precision in bytes (8-bit system → 1).
    pub bytes_per_operand: usize,
}

impl ScheduleConfig {
    /// Total MAC units.
    pub fn total_macs(&self) -> usize {
        self.channels * MACS_PER_CHANNEL
    }
}

/// Which of Algorithm 1's three regimes a layer falls into.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PipelineMode {
    /// Memory outruns compute; no pipelining needed (line 7).
    NonPipelined,
    /// Loading interleaves with compute inside a bitstream window (line 13).
    PartiallyPipelined,
    /// Memory-bound; compute fully hidden behind loading (line 16).
    FullyPipelined,
}

/// Schedule of one layer on the accelerator.
#[derive(Debug, Clone)]
pub struct LayerSchedule {
    /// Source layer index in the network (stage descriptor index).
    pub layer_index: usize,
    /// Stage label (`conv`, `depthwise-conv`, `dense`, ...).
    pub label: &'static str,
    /// Regime chosen by Algorithm 1.
    pub mode: PipelineMode,
    /// Bitstream length this layer was scheduled at (per-layer under a
    /// [`PrecisionPlan`], the global `k` otherwise).
    pub k: usize,
    /// Surviving weight-lane density this layer was costed at (1.0 for
    /// dense plans): pruned lanes own no SNG/APC slot, no MAC·cycle, and
    /// no operand traffic, so every fan-in-derived quantity scales by it.
    pub weight_density: f64,
    /// Neurons resident on chip at once.
    pub n_onchip: usize,
    /// Neurons whose operands memory covers per clock cycle.
    pub n_memcover: usize,
    /// ceil(n_onchip / n_memcover) (meaningful when pipelined).
    pub incycle_pipe: usize,
    /// ceil(neurons / n_onchip) — outer iterations over the layer.
    pub groups: usize,
    /// Layer delay in ns.
    pub delay_ns: f64,
    /// Bytes loaded from off-chip for this layer.
    pub dram_bytes: u64,
    /// MAC·cycles of actual compute (for energy/utilization accounting).
    pub active_mac_cycles: u64,
    /// Total cycles the layer occupies the machine.
    pub total_cycles: u64,
}

/// Algorithm 1's regime decision for one on-chip configuration; returns the
/// chosen mode and the cycles one pass over the layer takes.
fn regime(n_onchip: usize, n_memcover: usize, groups: usize, k: usize) -> (PipelineMode, u64) {
    let k64 = k as u64;
    if n_onchip < n_memcover {
        // Line 7–8: Dlayer = cycle_unpipe · k · τ.
        (PipelineMode::NonPipelined, groups as u64 * k64)
    } else {
        let incycle_pipe = n_onchip.div_ceil(n_memcover);
        if incycle_pipe < k {
            // Line 14: Dlayer = [cycle_pipe·(k+1) + incycle_pipe − 1] · τ.
            (
                PipelineMode::PartiallyPipelined,
                groups as u64 * (k64 + 1) + incycle_pipe as u64 - 1,
            )
        } else {
            // Line 17 with the group factor explicit: loading dominates.
            (
                PipelineMode::FullyPipelined,
                groups as u64 * incycle_pipe as u64 + k64,
            )
        }
    }
}

/// Apply Algorithm 1 to one compiled stage (`None` for stages owning no
/// MACs — pooling and residual merges ride on the producing layer).
pub fn schedule_layer(stage: &StageDescriptor, cfg: &ScheduleConfig) -> Option<LayerSchedule> {
    schedule_layer_batch(stage, cfg, 1)
}

/// Apply Algorithm 1 to one compiled stage with weight-stationary
/// batching: a resident neuron group's weights are loaded once and reused
/// across all `batch` images, so steady-state operand traffic per
/// neuron-image is the activation bytes plus `1/batch` of the weight
/// bytes. `batch = 1` is exactly the paper's single-image schedule.
pub fn schedule_layer_batch(
    stage: &StageDescriptor,
    cfg: &ScheduleConfig,
    batch: usize,
) -> Option<LayerSchedule> {
    schedule_layer_k(stage, cfg, batch, cfg.k)
}

/// [`schedule_layer_batch`] at an explicit per-layer bitstream length
/// (overriding `cfg.k`) — the building block of the precision-aware
/// schedule: every Algorithm 1 quantity that scales with the stream
/// length (the regime decision, the compute window, the active MAC·cycle
/// count) is evaluated at **this layer's** `k`.
pub fn schedule_layer_k(
    stage: &StageDescriptor,
    cfg: &ScheduleConfig,
    batch: usize,
    k: usize,
) -> Option<LayerSchedule> {
    schedule_layer_kd(stage, cfg, batch, k, 1.0)
}

/// [`schedule_layer_k`] at an explicit surviving weight-lane density in
/// (0, 1]: a pruned layer's effective fan-in is `ceil(fan_in · density)`
/// (at least 1), and residency, memory coverage, operand traffic, and
/// active MAC·cycles all follow from the effective fan-in — the hardware
/// analogue of the compiled skip lists, where pruned lanes simply do not
/// exist in the datapath.
pub fn schedule_layer_kd(
    stage: &StageDescriptor,
    cfg: &ScheduleConfig,
    batch: usize,
    k: usize,
    density: f64,
) -> Option<LayerSchedule> {
    let batch = batch.max(1);
    let neurons = stage.neurons;
    if neurons == 0 {
        return None; // pooling / residual stages ride on the producing layer
    }
    let density = density.clamp(f64::MIN_POSITIVE, 1.0);
    let fan_in = (((stage.fan_in as f64) * density).ceil() as usize).max(1);
    let macs_per_neuron = fan_in.div_ceil(MAC_WIDTH);
    let n_onchip = (cfg.total_macs() / macs_per_neuron).max(1).min(neurons);
    // Operand bytes per neuron-image: activations at system precision plus
    // the batch-amortized weights.
    let bytes_per_neuron =
        (fan_in * cfg.bytes_per_operand) as f64 * (1.0 + 1.0 / batch as f64);
    let n_memcover =
        ((cfg.memory.bytes_per_cycle(cfg.clock_ps) / bytes_per_neuron).floor() as usize).max(1);
    let groups = neurons.div_ceil(n_onchip);

    let (mode, per_image_cycles) = regime(n_onchip, n_memcover, groups, k);
    let total_cycles = per_image_cycles * batch as u64;
    let incycle_pipe = n_onchip.div_ceil(n_memcover);
    let delay_ns = total_cycles as f64 * cfg.clock_ps / 1000.0;
    // Off-chip traffic: activations per image, weights once per batch.
    let dram_bytes =
        (neurons * fan_in * cfg.bytes_per_operand) as u64 * (batch as u64 + 1);
    let active_mac_cycles = neurons as u64 * macs_per_neuron as u64 * k as u64 * batch as u64;
    Some(LayerSchedule {
        layer_index: stage.index,
        label: stage.label(),
        mode,
        k,
        weight_density: density,
        n_onchip,
        n_memcover,
        incycle_pipe,
        groups,
        delay_ns,
        dram_bytes,
        active_mac_cycles,
        total_cycles,
    })
}

/// Whole-network schedule.
#[derive(Debug, Clone)]
pub struct NetworkSchedule {
    /// Per compute-layer schedules, in layer order.
    pub layers: Vec<LayerSchedule>,
    /// End-to-end latency per inference (ns).
    pub latency_ns: f64,
    /// Total off-chip traffic (bytes).
    pub dram_bytes: u64,
    /// Total active MAC·cycles.
    pub active_mac_cycles: u64,
    /// Total machine cycles.
    pub total_cycles: u64,
    /// Average MAC-array utilization in [0, 1].
    pub utilization: f64,
}

/// Schedule a compiled stage list (the shared entry point: the software
/// engine, the system roll-up and the benches all pass the same
/// descriptors).
pub fn schedule_stages(
    stages: &[StageDescriptor],
    cfg: &ScheduleConfig,
    batch: usize,
) -> NetworkSchedule {
    schedule_stages_with(stages, cfg, batch, |_| (cfg.k, 1.0))
}

/// Schedule a compiled stage list under a per-layer [`PrecisionPlan`]:
/// each compute stage is costed at its **own** planned bitstream length
/// (by `weight_layer` index), so modeled delay, energy-relevant
/// MAC·cycles, and utilization reflect the same per-layer `k` the
/// software datapaths execute. The plan must cover every compute stage
/// (compile it through `ForwardPlan`/`EngineConfig` first); stages beyond
/// the plan fall back to `cfg.k` defensively.
pub fn schedule_stages_precise(
    stages: &[StageDescriptor],
    cfg: &ScheduleConfig,
    precision: &PrecisionPlan,
    batch: usize,
) -> NetworkSchedule {
    schedule_stages_sparse(stages, cfg, precision, &[], batch)
}

/// [`schedule_stages_precise`] under a per-compute-layer surviving
/// weight-lane density (from [`crate::accel::network::weight_densities`]
/// or a compiled plan's `stage_densities`): per-layer `k` **and** density
/// compound, so a layer at half length and half density is costed at a
/// quarter of its dense-uniform MAC·cycles. An empty (or short) density
/// slice falls back to 1.0 — dense — per missing layer.
pub fn schedule_stages_sparse(
    stages: &[StageDescriptor],
    cfg: &ScheduleConfig,
    precision: &PrecisionPlan,
    densities: &[f64],
    batch: usize,
) -> NetworkSchedule {
    schedule_stages_with(stages, cfg, batch, |s| {
        let k = s
            .weight_layer
            .and_then(|wl| precision.ks().get(wl).copied())
            .unwrap_or(cfg.k);
        let d = s
            .weight_layer
            .and_then(|wl| densities.get(wl).copied())
            .unwrap_or(1.0);
        (k, d)
    })
}

/// Shared body of the stage-list schedulers: schedule every MAC-owning
/// stage at the (bitstream length, weight density) `kd_of` assigns it.
fn schedule_stages_with(
    stages: &[StageDescriptor],
    cfg: &ScheduleConfig,
    batch: usize,
    kd_of: impl Fn(&StageDescriptor) -> (usize, f64),
) -> NetworkSchedule {
    let layers: Vec<LayerSchedule> = stages
        .iter()
        .filter_map(|s| {
            let (k, d) = kd_of(s);
            schedule_layer_kd(s, cfg, batch, k, d)
        })
        .collect();
    let latency_ns = layers.iter().map(|l| l.delay_ns).sum();
    let dram_bytes = layers.iter().map(|l| l.dram_bytes).sum();
    let active_mac_cycles = layers.iter().map(|l| l.active_mac_cycles).sum();
    let total_cycles: u64 = layers.iter().map(|l| l.total_cycles).sum();
    let capacity = total_cycles as f64 * cfg.total_macs() as f64;
    let utilization =
        if capacity > 0.0 { (active_mac_cycles as f64 / capacity).min(1.0) } else { 0.0 };
    NetworkSchedule { layers, latency_ns, dram_bytes, active_mac_cycles, total_cycles, utilization }
}

/// Schedule every compute layer of `net`. Panics on malformed networks —
/// compile the stage IR first ([`NetworkSpec::stages`]) on untrusted input.
pub fn schedule_network(net: &NetworkSpec, cfg: &ScheduleConfig) -> NetworkSchedule {
    schedule_network_batch(net, cfg, 1)
}

/// Schedule every compute layer of `net` for a `batch` of images with
/// weight-stationary reuse (the hardware analogue of the software engine's
/// batched forward: per-layer constants amortized across the batch).
pub fn schedule_network_batch(
    net: &NetworkSpec,
    cfg: &ScheduleConfig,
    batch: usize,
) -> NetworkSchedule {
    let stages = net
        .stages()
        .unwrap_or_else(|e| panic!("schedule_network({}): {e:#}", net.name));
    schedule_stages(&stages, cfg, batch)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(channels: usize) -> ScheduleConfig {
        ScheduleConfig {
            channels,
            k: 32,
            clock_ps: 880.0,
            memory: MemoryModel::gddr5_paper(),
            bytes_per_operand: 1,
        }
    }

    #[test]
    fn lenet_conv1_is_memory_bound_at_8_channels() {
        let net = NetworkSpec::lenet5();
        let stages = net.stages().unwrap();
        let s = schedule_layer(&stages[0], &cfg(8)).unwrap();
        // fan-in 25 ⇒ 50 B/neuron; ~197 B/cycle ⇒ n_memcover = 3;
        // n_onchip = 128 ⇒ incycle = 43 ≥ k=32 ⇒ fully pipelined.
        assert_eq!(s.n_memcover, 3);
        assert_eq!(s.n_onchip, 128);
        assert_eq!(s.mode, PipelineMode::FullyPipelined);
        assert_eq!(s.groups, 4704usize.div_ceil(128));
        assert_eq!(s.label, "conv");
        assert_eq!(s.layer_index, 0);
    }

    #[test]
    fn tiny_layer_is_not_pipelined() {
        // fc3: 10 neurons of fan-in 84 ⇒ 4 MACs each; memory covers ≥1.
        let net = NetworkSpec::lenet5();
        let stages = net.stages().unwrap();
        let s = schedule_layer(&stages[6], &cfg(8)).unwrap();
        assert!(s.n_onchip <= 32);
        // 168 B per neuron > 197 B/cycle? 168 < 197 ⇒ memcover = 1;
        // n_onchip = 128/4 = 32 > 1 ⇒ pipelined.
        assert_ne!(s.mode, PipelineMode::NonPipelined);
    }

    #[test]
    fn latency_decreases_with_channels_then_saturates() {
        let net = NetworkSpec::lenet5();
        let lat: Vec<f64> = [1, 2, 4, 8, 16]
            .iter()
            .map(|&c| schedule_network(&net, &cfg(c)).latency_ns)
            .collect();
        for w in lat.windows(2) {
            assert!(w[1] <= w[0] * 1.001, "latency must not increase: {lat:?}");
        }
        // Saturation: the 8→16 improvement is much smaller than 1→2.
        let first_gain = lat[0] / lat[1];
        let last_gain = lat[3] / lat[4];
        assert!(first_gain > last_gain, "first={first_gain} last={last_gain}");
    }

    #[test]
    fn active_mac_cycles_independent_of_channels() {
        // Total switching work is architecture-independent (the paper's
        // "energy remains relatively unchanged" observation).
        let net = NetworkSpec::lenet5();
        let a = schedule_network(&net, &cfg(1)).active_mac_cycles;
        let b = schedule_network(&net, &cfg(16)).active_mac_cycles;
        assert_eq!(a, b);
    }

    #[test]
    fn pooling_layers_do_not_schedule() {
        let net = NetworkSpec::lenet5();
        let sched = schedule_network(&net, &cfg(8));
        // 7 layers, 2 pools ⇒ 5 compute layers.
        assert_eq!(sched.layers.len(), 5);
        // Labels and indices come from the stage descriptors.
        let labels: Vec<&str> = sched.layers.iter().map(|l| l.label).collect();
        assert_eq!(labels, vec!["conv", "conv", "dense", "dense", "dense"]);
        let idx: Vec<usize> = sched.layers.iter().map(|l| l.layer_index).collect();
        assert_eq!(idx, vec![0, 2, 4, 5, 6]);
    }

    #[test]
    fn extended_stages_schedule_through_the_same_ir() {
        // The strided/depthwise/avgpool topology schedules its four
        // compute stages; pool/add stages own no machine time.
        let net = NetworkSpec::mnist_strided();
        let sched = schedule_network(&net, &cfg(8));
        let labels: Vec<&str> = sched.layers.iter().map(|l| l.label).collect();
        assert_eq!(labels, vec!["conv", "depthwise-conv", "conv", "dense"]);
        assert!(sched.latency_ns > 0.0);
        // Depthwise fan-in (9) needs one MAC per neuron, so the whole MAC
        // array (8 ch × 16 MACs) fills with resident neurons.
        assert_eq!(sched.layers[1].n_onchip, 128);
        let stages = net.stages().unwrap();
        let direct = schedule_stages(&stages, &cfg(8), 1);
        assert_eq!(direct.total_cycles, sched.total_cycles);
    }

    #[test]
    fn batch_one_equals_single_image_schedule() {
        let net = NetworkSpec::lenet5();
        let a = schedule_network(&net, &cfg(8));
        let b = schedule_network_batch(&net, &cfg(8), 1);
        assert_eq!(a.total_cycles, b.total_cycles);
        assert_eq!(a.dram_bytes, b.dram_bytes);
        assert_eq!(a.active_mac_cycles, b.active_mac_cycles);
    }

    #[test]
    fn batching_amortizes_weight_traffic_and_lifts_utilization() {
        let net = NetworkSpec::lenet5();
        let single = schedule_network_batch(&net, &cfg(8), 1);
        let batched = schedule_network_batch(&net, &cfg(8), 32);
        // Per-image DRAM traffic strictly drops (weights loaded once).
        assert!(
            (batched.dram_bytes as f64 / 32.0) < single.dram_bytes as f64,
            "batched {} vs single {}",
            batched.dram_bytes / 32,
            single.dram_bytes
        );
        // Weight reuse can only improve (or preserve) MAC utilization.
        assert!(
            batched.utilization >= single.utilization - 1e-12,
            "batched {} vs single {}",
            batched.utilization,
            single.utilization
        );
        // Per-image latency must not degrade.
        assert!(batched.latency_ns / 32.0 <= single.latency_ns * 1.001);
    }

    #[test]
    fn precise_schedule_costs_each_layer_at_its_own_k() {
        let net = NetworkSpec::lenet5();
        let stages = net.stages().unwrap();
        let c = cfg(8);
        // A uniform plan reproduces the scalar-k schedule exactly.
        let uniform = schedule_stages_precise(
            &stages,
            &c,
            &PrecisionPlan::uniform(32, 5),
            1,
        );
        let scalar = schedule_stages(&stages, &c, 1);
        assert_eq!(uniform.total_cycles, scalar.total_cycles);
        assert_eq!(uniform.active_mac_cycles, scalar.active_mac_cycles);
        assert!(uniform.layers.iter().all(|l| l.k == 32));
        // Shrinking one layer's k shrinks only that layer's cycles; DRAM
        // traffic is k-independent.
        let plan = PrecisionPlan::per_layer(vec![32, 16, 32, 32, 32]);
        let mixed = schedule_stages_precise(&stages, &c, &plan, 1);
        assert_eq!(mixed.layers[1].k, 16);
        assert!(mixed.layers[1].total_cycles < scalar.layers[1].total_cycles);
        assert_eq!(mixed.layers[0].total_cycles, scalar.layers[0].total_cycles);
        assert_eq!(mixed.dram_bytes, scalar.dram_bytes);
        assert!(mixed.active_mac_cycles < scalar.active_mac_cycles);
        assert!(mixed.latency_ns < scalar.latency_ns);
    }

    #[test]
    fn sparse_schedule_scales_with_density_and_is_dense_at_one() {
        let net = NetworkSpec::lenet5();
        let stages = net.stages().unwrap();
        let c = cfg(8);
        let plan = PrecisionPlan::uniform(32, 5);
        let dense = schedule_stages_precise(&stages, &c, &plan, 1);
        // Density 1.0 everywhere (explicit or defaulted) is the dense
        // schedule exactly.
        for ds in [vec![], vec![1.0; 5]] {
            let s = schedule_stages_sparse(&stages, &c, &plan, &ds, 1);
            assert_eq!(s.total_cycles, dense.total_cycles);
            assert_eq!(s.active_mac_cycles, dense.active_mac_cycles);
            assert_eq!(s.dram_bytes, dense.dram_bytes);
            assert!(s.layers.iter().all(|l| l.weight_density == 1.0));
        }
        // Quarter density shrinks compute work and operand traffic.
        let quarter = schedule_stages_sparse(&stages, &c, &plan, &[0.25; 5], 1);
        assert!(quarter.active_mac_cycles < dense.active_mac_cycles);
        assert!(quarter.dram_bytes < dense.dram_bytes);
        assert!(quarter.latency_ns <= dense.latency_ns * 1.001);
        assert!(quarter.layers.iter().all(|l| l.weight_density == 0.25));
        // Monotone: half density sits between quarter and dense.
        let half = schedule_stages_sparse(&stages, &c, &plan, &[0.5; 5], 1);
        assert!(half.active_mac_cycles <= dense.active_mac_cycles);
        assert!(half.active_mac_cycles >= quarter.active_mac_cycles);
        // Per-layer: only the layer with density < 1 changes its MACs.
        let one = schedule_stages_sparse(&stages, &c, &plan, &[1.0, 0.5, 1.0, 1.0, 1.0], 1);
        assert!(one.layers[1].active_mac_cycles < dense.layers[1].active_mac_cycles);
        assert_eq!(one.layers[0].active_mac_cycles, dense.layers[0].active_mac_cycles);
    }

    #[test]
    fn non_pipelined_regime_reachable() {
        // Huge fan-out memory: crank bandwidth so memory covers everything.
        let mut c = cfg(1);
        c.memory.bandwidth_bytes_per_ns = 1e6;
        let net = NetworkSpec::lenet5();
        let stages = net.stages().unwrap();
        let s = schedule_layer(&stages[0], &c).unwrap();
        assert_eq!(s.mode, PipelineMode::NonPipelined);
        assert_eq!(s.total_cycles, s.groups as u64 * 32);
    }
}
