//! Stochastic number generators: RNS (LFSR) + PCC (§II-C, Fig. 3), with the
//! RNS-sharing optimization the paper discusses (one LFSR's state feeds many
//! PCCs through per-consumer bit shuffles, §I).

use crate::netlist::Netlist;
use crate::sc::bitstream::Bitstream;
use crate::sc::lfsr::{self, Lfsr, UnsupportedLfsrWidth};
use crate::sc::pcc::{self, PccKind};

/// A single binary→stochastic generator.
#[derive(Debug, Clone)]
pub struct Sng {
    lfsr: Lfsr,
    kind: PccKind,
    bits: u32,
}

impl Sng {
    /// SNG of `bits` precision using PCC `kind`, seeded at `seed`. Widths
    /// outside the LFSR table (3..=16) are a typed error, not a panic.
    pub fn new(bits: u32, kind: PccKind, seed: u32) -> Result<Self, UnsupportedLfsrWidth> {
        Ok(Sng { lfsr: Lfsr::new(bits, seed)?, kind, bits })
    }

    /// Precision in bits.
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Generate a `len`-cycle bitstream encoding code `x` (0..2^bits).
    pub fn generate(&mut self, x: u32, len: usize) -> Bitstream {
        Bitstream::from_fn(len, |_| {
            let r = self.lfsr.value();
            self.lfsr.step();
            pcc::pcc_bit(self.kind, x, r, self.bits)
        })
    }

    /// Generate streams for many codes *sharing* this SNG's random sequence
    /// (fully correlated outputs — SCC ≈ +1 for the comparator PCC). This is
    /// the correlation the Frasser neuron exploits for ReLU/MP (§II-B).
    pub fn generate_correlated(&mut self, xs: &[u32], len: usize) -> Vec<Bitstream> {
        let rs: Vec<u32> = (0..len)
            .map(|_| {
                let r = self.lfsr.value();
                self.lfsr.step();
                r
            })
            .collect();
        xs.iter()
            .map(|&x| Bitstream::from_fn(len, |t| pcc::pcc_bit(self.kind, x, rs[t], self.bits)))
            .collect()
    }
}

/// A shared random-number source: one LFSR whose state is rotated by a
/// per-consumer offset before feeding each PCC — the classic SNG-sharing
/// area optimization (bitstreams become decorrelated enough for multiply).
#[derive(Debug, Clone)]
pub struct SharedRns {
    lfsr: Lfsr,
    bits: u32,
}

impl SharedRns {
    /// Shared RNS of width `bits` (3..=16; typed error otherwise).
    pub fn new(bits: u32, seed: u32) -> Result<Self, UnsupportedLfsrWidth> {
        Ok(SharedRns { lfsr: Lfsr::new(bits, seed)?, bits })
    }

    /// Advance one cycle and return per-consumer shuffled views of the
    /// state: consumer j sees the state bit-reversed (odd j) and rotated by
    /// ⌊j/2⌋ — fixed wire permutations, free in hardware. Bit reversal maps
    /// the sequence onto its reciprocal-polynomial m-sequence, which is the
    /// key decorrelator for comparator PCCs (plain rotation leaves the
    /// MSB-dominated comparisons strongly correlated).
    pub fn step_views(&mut self, n: usize) -> Vec<u32> {
        let s = self.lfsr.value();
        self.lfsr.step();
        let b = self.bits;
        let mask = (1u32 << b) - 1;
        let rev = s.reverse_bits() >> (32 - b);
        (0..n as u32)
            .map(|j| {
                let base = if j % 2 == 1 { rev } else { s };
                let rot = (j / 2) % b;
                if rot == 0 {
                    base
                } else {
                    ((base << rot) | (base >> (b - rot))) & mask
                }
            })
            .collect()
    }

    /// Generate one stream per (code, consumer-index) pair, all driven from
    /// this single LFSR.
    pub fn generate_shuffled(&mut self, kind: PccKind, xs: &[u32], len: usize) -> Vec<Bitstream> {
        let mut streams = vec![Bitstream::zeros(len); xs.len()];
        for t in 0..len {
            let views = self.step_views(xs.len());
            for (j, (&x, view)) in xs.iter().zip(views).enumerate() {
                if pcc::pcc_bit(kind, x, view, self.bits) {
                    streams[j].set(t, true);
                }
            }
        }
        streams
    }
}

/// Build the netlist of a complete `bits`-bit SNG: LFSR (DFF ring with XOR
/// feedback) + the chosen PCC (Fig. 3).
///
/// Primary inputs: the X code bits (LSB first), then a 1-bit `seed_in` that
/// XORs into the feedback — pulsing it once kicks the register out of the
/// absorbing all-zero reset state (the hardware equivalent of a preset pin).
///
/// Widths outside the tabulated 3..=16 range are a typed
/// [`UnsupportedLfsrWidth`] error (previously a panic).
pub fn build_netlist(kind: PccKind, bits: u32) -> Result<Netlist, UnsupportedLfsrWidth> {
    let tap_mask = lfsr::taps_for(bits)?;
    let mut nl = Netlist::new(format!("sng_{kind:?}_{bits}b"));
    let x = nl.inputs(bits as usize);
    let seed_in = nl.input();

    // DFF ring. The feedback net only exists after the tap XOR tree is
    // built, so stage 0 is created with a placeholder D and rewired below.
    let placeholder = nl.constant(false);
    let mut qs: Vec<crate::netlist::NetId> = Vec::with_capacity(bits as usize);
    let mut d = placeholder;
    for _ in 0..bits {
        let q = nl.dff(d);
        qs.push(q);
        d = q;
    }
    // Feedback = XOR of tap-stage Qs (same primitive polynomials as the
    // behavioral `Lfsr` — one shared table), XORed with seed_in.
    let tap_qs: Vec<_> = (0..bits)
        .filter(|i| (tap_mask >> i) & 1 == 1)
        .map(|i| qs[i as usize])
        .collect();
    let mut fb = tap_qs[0];
    for &t in &tap_qs[1..] {
        fb = nl.xor2(fb, t);
    }
    fb = nl.xor2(fb, seed_in);
    nl.rewire_gate_input(0, 0, fb); // close the ring at DFF_0.D

    // PCC consuming the LFSR state as R.
    let pcc_nl = pcc::build_netlist(kind, bits);
    let mut bind: Vec<_> = x.clone();
    bind.extend(qs.iter().copied());
    let outs = nl.absorb(&pcc_nl, &bind);
    nl.mark_output(outs[0]);
    Ok(nl)
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::sc::{dequantize_unipolar, quantize_unipolar};

    #[test]
    fn sng_encodes_values_over_full_period() {
        // Over one full LFSR period, a comparator SNG produces exactly
        // x ones out of 2^n − 1 cycles (R takes every non-zero value once).
        let bits = 8;
        for &v in &[0.125f64, 0.5, 0.9] {
            let x = quantize_unipolar(v, bits);
            let mut sng = Sng::new(bits, PccKind::Comparator, 1).unwrap();
            let len = (1usize << bits) - 1;
            let bs = sng.generate(x, len);
            // X > R for R in 1..=255 happens exactly x−1 times... R covers
            // 1..255 (no zero) ⇒ ones = #{r : r < x, r ≥ 1} = x−1 for x ≥ 1.
            let expected = x.saturating_sub(1);
            assert_eq!(bs.count_ones(), expected, "v={v}");
            let err = (bs.value_unipolar() - dequantize_unipolar(x, bits)).abs();
            assert!(err < 2.0 / len as f64);
        }
    }

    #[test]
    fn correlated_generation_yields_scc_one() {
        let mut sng = Sng::new(8, PccKind::Comparator, 7).unwrap();
        let streams = sng.generate_correlated(&[60, 180], 255);
        assert!(streams[0].scc(&streams[1]) > 0.99);
        // And OR gives max, not sum (the [29] trick).
        let or = streams[0].or(&streams[1]);
        assert!((or.value_unipolar() - streams[1].value_unipolar()).abs() < 1e-9);
    }

    #[test]
    fn shared_rns_streams_decorrelated_enough_to_multiply() {
        let mut rns = SharedRns::new(10, 33).unwrap();
        let len = 1023;
        let a_code = 3 * 1024 / 4; // 0.75
        let b_code = 1024 / 2; // 0.5
        let streams = rns.generate_shuffled(PccKind::Comparator, &[a_code, b_code], len);
        let prod = streams[0].and(&streams[1]).value_unipolar();
        assert!((prod - 0.375).abs() < 0.06, "prod={prod}");
    }

    #[test]
    fn unsupported_widths_are_typed_errors() {
        assert_eq!(
            Sng::new(17, PccKind::Comparator, 1).unwrap_err(),
            UnsupportedLfsrWidth(17)
        );
        assert_eq!(SharedRns::new(2, 1).unwrap_err(), UnsupportedLfsrWidth(2));
        assert_eq!(
            build_netlist(PccKind::Comparator, 20).unwrap_err(),
            UnsupportedLfsrWidth(20)
        );
    }

    #[test]
    fn sng_netlist_matches_behavioral_sequence() {
        use crate::sim::Evaluator;
        let bits = 4;
        for kind in PccKind::ALL {
            for x in [0u32, 0b1010, 0b1111] {
                let nl = build_netlist(kind, bits).unwrap();
                let mut ev = Evaluator::new(&nl);
                // Pulse seed_in on cycle 0: the ring leaves the absorbing
                // all-zero state into state 1 — the behavioral LFSR's seed.
                let mut behavioral = Sng::new(bits, kind, 1).unwrap();
                let len = 40;
                let reference = behavioral.generate(x, len);
                let mut pins: Vec<bool> = (0..bits).map(|i| (x >> i) & 1 == 1).collect();
                pins.push(true); // seed_in, cycle 0 only
                ev.set_inputs(&pins);
                ev.propagate();
                ev.tick();
                *pins.last_mut().unwrap() = false;
                for t in 0..len {
                    ev.set_inputs(&pins);
                    ev.propagate();
                    assert_eq!(
                        ev.outputs()[0],
                        reference.get(t),
                        "{kind:?} x={x} cycle {t}"
                    );
                    ev.tick();
                }
            }
        }
    }
}
