//! Bit-plane transposed stream primitives: the data-layout core of the
//! 64-lane SC compute kernel (`accel::network`'s transposed path).
//!
//! The fused kernels walk one SNG lane at a time — for every lane of a
//! neuron's fan-in they XNOR `k/64` stream words into a
//! [`crate::sc::bitstream::VerticalCounter`]. The transposed layout packs
//! the streams the *other* way: one `u64` word holds the same cycle `t` of
//! **64 adjacent lanes**, so the per-cycle APC count `c_t` of a whole
//! 64-lane block is a single `XNOR + count_ones`, and the B2S comparison
//! `max(2·c_t, floor) > r4[t]` runs immediately on the finished count —
//! no bit-plane ripple adder, no per-lane pass.
//!
//! ```text
//! lane-major (fused):             bit-plane transposed:
//!   word[lane][cw] bit t            word[t][block] bit l
//!   = lane's cycle cw·64+t          = lane block·64+l's cycle t
//! ```
//!
//! The pivot between the two layouts is [`transpose64`], an in-place
//! 64×64 bit-matrix transpose (recursive butterfly, LSB-first
//! convention matching the stream packing of `accel::network`): gather 64
//! lane-major words for one cycle-word, transpose, and the rows come out
//! cycle-major. Weights are transposed once at `ForwardPlan` compile;
//! activations are transposed per L1-sized tile at run time.

/// Lanes covered by one transposed word (the `u64` width).
pub const LANES: usize = 64;

/// In-place 64×64 bit-matrix transpose with the **LSB-first** bit
/// convention used by the packed SNG streams: on return,
/// `out[r] bit c == in[c] bit r`.
///
/// Classic recursive block-swap (Hacker's Delight §7-3, mirrored for
/// LSB-first packing): at step size `j`, swap the high-`j` bits of word
/// `k` with the low-`j` bits of word `k|j` for every `k` with bit `j`
/// clear. Runs in 6·64 word operations — far below the cost of the
/// per-bit gathers it replaces.
pub fn transpose64(a: &mut [u64; 64]) {
    let mut j = 32usize;
    let mut m = 0x0000_0000_FFFF_FFFFu64;
    loop {
        let mut k = 0;
        while k < 64 {
            let t = ((a[k] >> j) ^ a[k | j]) & m;
            a[k] ^= t << j;
            a[k | j] ^= t;
            k = (k + j + 1) & !j;
        }
        j >>= 1;
        if j == 0 {
            break;
        }
        m ^= m << j;
    }
}

/// The per-cycle APC count of a transposed row pair: the number of lanes
/// whose XNOR product bit is 1 at this cycle, summed over the row's lane
/// blocks. `a` and `w` are one cycle's activation / weight rows
/// (`lane_blocks` words each); lanes beyond the fan-in must already be
/// arranged to contribute 0 (the compiled weight planes pair all-ones
/// tail-lane weight bits with all-zero tail-lane activation bits, so no
/// runtime mask is needed).
#[inline]
pub fn xnor_count(a: &[u64], w: &[u64]) -> u32 {
    debug_assert_eq!(a.len(), w.len());
    a.iter().zip(w).map(|(&x, &y)| (!(x ^ y)).count_ones()).sum()
}

/// The per-cycle APC count of a transposed weight row against an
/// **all-zero activation row**: `XNOR(0, w) = !w`, so the count is the
/// number of *clear* weight bits across the row's lane blocks. Lets the
/// transposed kernel's zero-tile short-circuit replace a whole lane-block
/// walk with one precomputed constant per (channel, cycle-word, cycle) —
/// the activation-sparsity fast path. Tail lanes (weight bits forced to
/// all-ones at compile) contribute 0, exactly like [`xnor_count`].
#[inline]
pub fn zero_xnor_count(w: &[u64]) -> u32 {
    w.iter().map(|&y| (!y).count_ones()).sum()
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    struct Gen(u64);
    impl Gen {
        fn next(&mut self) -> u64 {
            self.0 ^= self.0 << 13;
            self.0 ^= self.0 >> 7;
            self.0 ^= self.0 << 17;
            self.0
        }
    }

    fn naive_transpose(a: &[u64; 64]) -> [u64; 64] {
        let mut out = [0u64; 64];
        for (r, slot) in out.iter_mut().enumerate() {
            for (c, &word) in a.iter().enumerate() {
                *slot |= ((word >> r) & 1) << c;
            }
        }
        out
    }

    #[test]
    fn transpose64_matches_naive_per_bit_transpose() {
        let mut g = Gen(0xB17_9A7E5);
        for _ in 0..50 {
            let mut a = [0u64; 64];
            for w in a.iter_mut() {
                *w = g.next();
            }
            let want = naive_transpose(&a);
            let mut got = a;
            transpose64(&mut got);
            assert_eq!(got, want);
        }
    }

    #[test]
    fn transpose64_is_an_involution() {
        let mut g = Gen(0x5EED);
        let mut a = [0u64; 64];
        for w in a.iter_mut() {
            *w = g.next();
        }
        let orig = a;
        transpose64(&mut a);
        transpose64(&mut a);
        assert_eq!(a, orig);
    }

    #[test]
    fn transpose64_on_identity_and_edges() {
        // Identity matrix (bit r of word r) is its own transpose.
        let mut eye = [0u64; 64];
        for (r, w) in eye.iter_mut().enumerate() {
            *w = 1u64 << r;
        }
        let mut t = eye;
        transpose64(&mut t);
        assert_eq!(t, eye);
        // A single row becomes a single column.
        let mut a = [0u64; 64];
        a[5] = !0;
        transpose64(&mut a);
        assert!(a.iter().all(|&w| w == 1 << 5));
    }

    #[test]
    fn zero_xnor_count_matches_xnor_count_on_zero_activations() {
        let mut g = Gen(0xFEED);
        for len in [1usize, 3, 8] {
            let w: Vec<u64> = (0..len).map(|_| g.next()).collect();
            let zeros = vec![0u64; len];
            assert_eq!(zero_xnor_count(&w), xnor_count(&zeros, &w));
        }
        // All-ones tail-lane weights contribute nothing.
        assert_eq!(zero_xnor_count(&[!0u64, !0]), 0);
    }

    #[test]
    fn xnor_count_matches_per_bit_count() {
        let mut g = Gen(0xC0DE);
        for len in [1usize, 2, 7] {
            let a: Vec<u64> = (0..len).map(|_| g.next()).collect();
            let w: Vec<u64> = (0..len).map(|_| g.next()).collect();
            let mut want = 0u32;
            for (x, y) in a.iter().zip(&w) {
                for b in 0..64 {
                    want += (((x >> b) & 1) == ((y >> b) & 1)) as u32;
                }
            }
            assert_eq!(xnor_count(&a, &w), want);
        }
    }
}
