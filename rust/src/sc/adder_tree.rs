//! Configurable adder tree (§IV-A): sums the APC outputs of several MAC
//! units so neurons wider than one MAC's 25 inputs (fully connected layers)
//! can be formed; bypassed for convolutional layers.
//!
//! Degenerate inputs (no operands, mismatched widths) are **typed errors**,
//! not panics: these builders run during session/pool startup and channel
//! characterization, where a malformed request must surface as a
//! recoverable error instead of unwinding a worker thread.

use crate::netlist::{NetId, Netlist};
use crate::sc::apc::FaStyle;
use anyhow::{bail, Result};

/// Behavioral adder tree: plain summation (the hardware is exact).
pub fn sum(values: &[u64]) -> u64 {
    values.iter().sum()
}

/// Emit a ripple-carry adder for two equal-width operands; returns
/// `width + 1` output bits (LSB first). Empty or unequal operands are a
/// typed error.
pub fn build_ripple_adder(
    nl: &mut Netlist,
    style: FaStyle,
    a: &[NetId],
    b: &[NetId],
) -> Result<Vec<NetId>> {
    if a.is_empty() {
        bail!("ripple adder needs operand width >= 1");
    }
    if a.len() != b.len() {
        bail!("ripple adder needs equal widths, got {} vs {}", a.len(), b.len());
    }
    let mut out = Vec::with_capacity(a.len() + 1);
    let mut carry: Option<NetId> = None;
    for i in 0..a.len() {
        let (s, cy) = match carry {
            None => nl.half_adder(a[i], b[i]),
            Some(c) => match style {
                FaStyle::CmosCell => nl.full_adder_cell(a[i], b[i], c),
                FaStyle::RfetCompact => nl.full_adder_rfet(a[i], b[i], c),
            },
        };
        out.push(s);
        carry = Some(cy);
    }
    match carry {
        Some(c) => out.push(c),
        None => bail!("ripple adder produced no carry for width {}", a.len()),
    }
    Ok(out)
}

/// Build a balanced adder tree over `operands` (each a little-endian bit
/// vector of identical width). Returns the sum bits (LSB first, width
/// `w + ceil(log2(m))`). A single operand passes through unchanged; zero
/// operands (and mismatched widths) are typed errors.
pub fn build_adder_tree(
    nl: &mut Netlist,
    style: FaStyle,
    operands: &[Vec<NetId>],
) -> Result<Vec<NetId>> {
    if operands.is_empty() {
        bail!("adder tree needs >= 1 operand");
    }
    let w = operands[0].len();
    if let Some(bad) = operands.iter().position(|o| o.len() != w) {
        bail!("adder tree operand {bad} has width {}, expected {w}", operands[bad].len());
    }
    let mut level: Vec<Vec<NetId>> = operands.to_vec();
    while level.len() > 1 {
        let mut next = Vec::with_capacity(level.len().div_ceil(2));
        let mut it = level.chunks(2);
        for pair in &mut it {
            if pair.len() == 2 {
                // Pad the shorter operand with constant 0s if widths differ
                // (can happen when an odd operand skipped a level).
                let wmax = pair[0].len().max(pair[1].len());
                let pad = |nl: &mut Netlist, v: &Vec<NetId>| -> Vec<NetId> {
                    let mut v = v.clone();
                    while v.len() < wmax {
                        let z = nl.constant(false);
                        v.push(z);
                    }
                    v
                };
                let a = pad(nl, &pair[0]);
                let b = pad(nl, &pair[1]);
                next.push(build_ripple_adder(nl, style, &a, &b)?);
            } else {
                next.push(pair[0].clone());
            }
        }
        level = next;
    }
    match level.pop() {
        Some(bits) => Ok(bits),
        None => bail!("adder tree reduction lost its root level"),
    }
}

/// Build a standalone adder-tree netlist summing `m` operands of `width`
/// bits (PIs: operand 0 bits, operand 1 bits, ...; POs: the sum).
/// `m == 0` or `width == 0` are typed errors.
pub fn build_netlist(m: usize, width: usize, style: FaStyle) -> Result<Netlist> {
    if width == 0 {
        bail!("adder tree needs operand width >= 1");
    }
    let mut nl = Netlist::new(format!("adder_tree_{m}x{width}b_{style:?}"));
    let operands: Vec<Vec<NetId>> = (0..m).map(|_| nl.inputs(width)).collect();
    let sum_bits = build_adder_tree(&mut nl, style, &operands)?;
    for &b in &sum_bits {
        nl.mark_output(b);
    }
    Ok(nl)
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::sc::apc::decode_output;
    use crate::sim::Evaluator;

    fn xorshift(seed: u64) -> impl FnMut() -> u64 {
        let mut s = seed.max(1);
        move || {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            s
        }
    }

    #[test]
    fn ripple_adder_adds() {
        for style in [FaStyle::CmosCell, FaStyle::RfetCompact] {
            let mut nl = Netlist::new("add");
            let a = nl.inputs(6);
            let b = nl.inputs(6);
            let out = build_ripple_adder(&mut nl, style, &a, &b).unwrap();
            for &o in &out {
                nl.mark_output(o);
            }
            let mut ev = Evaluator::new(&nl);
            for (x, y) in [(0u64, 0u64), (63, 63), (21, 42), (13, 7)] {
                let mut pins = Vec::new();
                for i in 0..6 {
                    pins.push((x >> i) & 1 == 1);
                }
                for i in 0..6 {
                    pins.push((y >> i) & 1 == 1);
                }
                ev.set_inputs(&pins);
                ev.propagate();
                assert_eq!(decode_output(&ev.outputs()), x + y, "{style:?} {x}+{y}");
            }
        }
    }

    #[test]
    fn ripple_adder_rejects_degenerate_operands() {
        let mut nl = Netlist::new("bad");
        // Empty operands.
        let err = build_ripple_adder(&mut nl, FaStyle::CmosCell, &[], &[]).unwrap_err();
        assert!(err.to_string().contains("width >= 1"), "{err}");
        // Mismatched widths.
        let a = nl.inputs(3);
        let b = nl.inputs(2);
        let err = build_ripple_adder(&mut nl, FaStyle::CmosCell, &a, &b).unwrap_err();
        assert!(err.to_string().contains("equal widths"), "{err}");
    }

    #[test]
    fn tree_sums_many_operands() {
        for m in [2usize, 3, 6, 16] {
            let width = 5;
            let nl = build_netlist(m, width, FaStyle::CmosCell).unwrap();
            let mut ev = Evaluator::new(&nl);
            let mut rng = xorshift(m as u64);
            for _ in 0..50 {
                let vals: Vec<u64> = (0..m).map(|_| rng() % 32).collect();
                let mut pins = Vec::new();
                for &v in &vals {
                    for i in 0..width {
                        pins.push((v >> i) & 1 == 1);
                    }
                }
                ev.set_inputs(&pins);
                ev.propagate();
                assert_eq!(decode_output(&ev.outputs()), sum(&vals), "m={m} {vals:?}");
            }
        }
    }

    #[test]
    fn zero_operands_is_a_typed_error() {
        let mut nl = Netlist::new("empty");
        let err = build_adder_tree(&mut nl, FaStyle::CmosCell, &[]).unwrap_err();
        assert!(err.to_string().contains(">= 1 operand"), "{err}");
        assert!(build_netlist(0, 5, FaStyle::CmosCell).is_err());
        assert!(build_netlist(4, 0, FaStyle::CmosCell).is_err());
    }

    #[test]
    fn one_operand_passes_through_identity() {
        // The 1-input tree adds no gates: the sum IS the operand.
        let mut nl = Netlist::new("one");
        let op = nl.inputs(4);
        let out = build_adder_tree(&mut nl, FaStyle::RfetCompact, &[op.clone()]).unwrap();
        assert_eq!(out, op, "single operand returned unchanged");
        // And evaluates as the identity through a full netlist.
        let nl = build_netlist(1, 4, FaStyle::CmosCell).unwrap();
        let mut ev = Evaluator::new(&nl);
        for v in [0u64, 5, 15] {
            let pins: Vec<bool> = (0..4).map(|i| (v >> i) & 1 == 1).collect();
            ev.set_inputs(&pins);
            ev.propagate();
            assert_eq!(decode_output(&ev.outputs()), v);
        }
    }

    #[test]
    fn mismatched_operand_widths_are_typed_errors() {
        let mut nl = Netlist::new("mixed");
        let a = nl.inputs(4);
        let b = nl.inputs(3);
        let err = build_adder_tree(&mut nl, FaStyle::CmosCell, &[a, b]).unwrap_err();
        assert!(err.to_string().contains("width"), "{err}");
    }

    #[test]
    fn behavioral_sum() {
        assert_eq!(sum(&[1, 2, 3, 4]), 10);
        assert_eq!(sum(&[]), 0);
    }
}
