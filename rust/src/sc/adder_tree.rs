//! Configurable adder tree (§IV-A): sums the APC outputs of several MAC
//! units so neurons wider than one MAC's 25 inputs (fully connected layers)
//! can be formed; bypassed for convolutional layers.

use crate::netlist::{NetId, Netlist};
use crate::sc::apc::FaStyle;

/// Behavioral adder tree: plain summation (the hardware is exact).
pub fn sum(values: &[u64]) -> u64 {
    values.iter().sum()
}

/// Emit a ripple-carry adder for two equal-width operands; returns
/// `width + 1` output bits (LSB first).
pub fn build_ripple_adder(
    nl: &mut Netlist,
    style: FaStyle,
    a: &[NetId],
    b: &[NetId],
) -> Vec<NetId> {
    assert_eq!(a.len(), b.len(), "ripple adder needs equal widths");
    let mut out = Vec::with_capacity(a.len() + 1);
    let mut carry: Option<NetId> = None;
    for i in 0..a.len() {
        let (s, cy) = match carry {
            None => nl.half_adder(a[i], b[i]),
            Some(c) => match style {
                FaStyle::CmosCell => nl.full_adder_cell(a[i], b[i], c),
                FaStyle::RfetCompact => nl.full_adder_rfet(a[i], b[i], c),
            },
        };
        out.push(s);
        carry = Some(cy);
    }
    out.push(carry.expect("width >= 1"));
    out
}

/// Build a balanced adder tree over `operands` (each a little-endian bit
/// vector of identical width). Returns the sum bits (LSB first, width
/// `w + ceil(log2(m))`).
pub fn build_adder_tree(
    nl: &mut Netlist,
    style: FaStyle,
    operands: &[Vec<NetId>],
) -> Vec<NetId> {
    assert!(!operands.is_empty());
    let w = operands[0].len();
    assert!(operands.iter().all(|o| o.len() == w), "operand width mismatch");
    let mut level: Vec<Vec<NetId>> = operands.to_vec();
    while level.len() > 1 {
        let mut next = Vec::with_capacity(level.len().div_ceil(2));
        let mut it = level.chunks(2);
        for pair in &mut it {
            if pair.len() == 2 {
                // Pad the shorter operand with constant 0s if widths differ
                // (can happen when an odd operand skipped a level).
                let wmax = pair[0].len().max(pair[1].len());
                let pad = |nl: &mut Netlist, v: &Vec<NetId>| -> Vec<NetId> {
                    let mut v = v.clone();
                    while v.len() < wmax {
                        let z = nl.constant(false);
                        v.push(z);
                    }
                    v
                };
                let a = pad(nl, &pair[0]);
                let b = pad(nl, &pair[1]);
                next.push(build_ripple_adder(nl, style, &a, &b));
            } else {
                next.push(pair[0].clone());
            }
        }
        level = next;
    }
    level.pop().unwrap()
}

/// Build a standalone adder-tree netlist summing `m` operands of `width`
/// bits (PIs: operand 0 bits, operand 1 bits, ...; POs: the sum).
pub fn build_netlist(m: usize, width: usize, style: FaStyle) -> Netlist {
    let mut nl = Netlist::new(format!("adder_tree_{m}x{width}b_{style:?}"));
    let operands: Vec<Vec<NetId>> = (0..m).map(|_| nl.inputs(width)).collect();
    let sum_bits = build_adder_tree(&mut nl, style, &operands);
    for &b in &sum_bits {
        nl.mark_output(b);
    }
    nl
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sc::apc::decode_output;
    use crate::sim::Evaluator;

    fn xorshift(seed: u64) -> impl FnMut() -> u64 {
        let mut s = seed.max(1);
        move || {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            s
        }
    }

    #[test]
    fn ripple_adder_adds() {
        for style in [FaStyle::CmosCell, FaStyle::RfetCompact] {
            let mut nl = Netlist::new("add");
            let a = nl.inputs(6);
            let b = nl.inputs(6);
            let out = build_ripple_adder(&mut nl, style, &a, &b);
            for &o in &out {
                nl.mark_output(o);
            }
            let mut ev = Evaluator::new(&nl);
            for (x, y) in [(0u64, 0u64), (63, 63), (21, 42), (13, 7)] {
                let mut pins = Vec::new();
                for i in 0..6 {
                    pins.push((x >> i) & 1 == 1);
                }
                for i in 0..6 {
                    pins.push((y >> i) & 1 == 1);
                }
                ev.set_inputs(&pins);
                ev.propagate();
                assert_eq!(decode_output(&ev.outputs()), x + y, "{style:?} {x}+{y}");
            }
        }
    }

    #[test]
    fn tree_sums_many_operands() {
        for m in [2usize, 3, 6, 16] {
            let width = 5;
            let nl = build_netlist(m, width, FaStyle::CmosCell);
            let mut ev = Evaluator::new(&nl);
            let mut rng = xorshift(m as u64);
            for _ in 0..50 {
                let vals: Vec<u64> = (0..m).map(|_| rng() % 32).collect();
                let mut pins = Vec::new();
                for &v in &vals {
                    for i in 0..width {
                        pins.push((v >> i) & 1 == 1);
                    }
                }
                ev.set_inputs(&pins);
                ev.propagate();
                assert_eq!(decode_output(&ev.outputs()), sum(&vals), "m={m} {vals:?}");
            }
        }
    }

    #[test]
    fn behavioral_sum() {
        assert_eq!(sum(&[1, 2, 3, 4]), 10);
        assert_eq!(sum(&[]), 0);
    }
}
