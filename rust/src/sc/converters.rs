//! Binary↔stochastic converters (§II-B, §IV-A).
//!
//! * **B2S** — re-enters the stochastic domain after an APC/adder-tree:
//!   compares the binary count against a random number each cycle (a PCC by
//!   another name). When several B2S units share one random source their
//!   outputs are fully correlated — the property the ReLU/MaxPool OR trick
//!   relies on (Fig. 2).
//! * **S2B** — leaves the stochastic domain at layer boundaries: a counter
//!   that tallies the '1's of a stream over its full length.

use crate::netlist::Netlist;
use crate::sc::bitstream::Bitstream;
use crate::sc::lfsr::{Lfsr, UnsupportedLfsrWidth};

/// Behavioral B2S: stream whose bit t is `code > r_t` for a shared random
/// sequence `rs` (values uniform in 0..2^bits). P(1) = code / 2^bits.
pub fn b2s_with_randoms(code: u32, rs: &[u32]) -> Bitstream {
    Bitstream::from_fn(rs.len(), |t| code > rs[t])
}

/// Behavioral B2S driving its own LFSR (independent output). Widths
/// outside the LFSR table (3..=16) are a typed error, not a panic.
///
/// The random sequence is materialized once and compared through
/// [`b2s_with_randoms`] — the same hoist the compiled engine applies at
/// `ForwardPlan::compile`, where each layer's comparison sequence and
/// threshold floor are stage constants rather than per-call work.
pub fn b2s(code: u32, bits: u32, len: usize, seed: u32) -> Result<Bitstream, UnsupportedLfsrWidth> {
    let mut lfsr = Lfsr::new(bits, seed)?;
    let rs: Vec<u32> = (0..len)
        .map(|_| {
            let r = lfsr.value();
            lfsr.step();
            r
        })
        .collect();
    Ok(b2s_with_randoms(code, &rs))
}

/// Behavioral S2B: the count of ones (the unipolar code of the stream,
/// scaled by its length).
pub fn s2b(bs: &Bitstream) -> u64 {
    bs.count_ones() as u64
}

/// Build the S2B counter netlist: one stream input incremented into a
/// `width`-bit counter of half adders + DFFs.
///
/// PIs: the stream bit. POs: the counter register (LSB first).
pub fn build_s2b_netlist(width: usize) -> Netlist {
    let mut nl = Netlist::new(format!("s2b_{width}b"));
    let input = nl.input();
    let placeholder = nl.constant(false);
    let first_dff = nl.num_gates();
    let qs: Vec<_> = (0..width).map(|_| nl.dff(placeholder)).collect();
    let mut carry = input;
    let mut next = Vec::with_capacity(width);
    for &q in &qs {
        let (s, c) = nl.half_adder(q, carry);
        next.push(s);
        carry = c;
    }
    for (i, &d) in next.iter().enumerate() {
        nl.rewire_gate_input(first_dff + i, 0, d);
    }
    for &q in &qs {
        nl.mark_output(q);
    }
    nl
}

/// Build a B2S netlist: an `bits`-bit comparator against an external random
/// number (PIs: code bits then R bits; PO: stochastic bit). Structurally a
/// comparator PCC — shared here so channel assembly reads naturally.
pub fn build_b2s_netlist(bits: u32) -> Netlist {
    let mut nl = crate::sc::pcc::build_netlist(crate::sc::pcc::PccKind::Comparator, bits);
    nl.name = format!("b2s_{bits}b");
    nl
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::sc::apc::decode_output;
    use crate::sim::Evaluator;

    #[test]
    fn b2s_probability_over_full_period() {
        let bits = 8;
        let len = 255;
        for code in [0u32, 50, 128, 255] {
            let bs = b2s(code, bits, len, 1).unwrap();
            // Over a full period R covers 1..=255 once: ones = max(code−1,0).
            assert_eq!(bs.count_ones(), code.saturating_sub(1));
        }
    }

    #[test]
    fn shared_randoms_correlate_b2s_outputs() {
        let rs: Vec<u32> = {
            let mut l = Lfsr::new(8, 5).unwrap();
            (0..255)
                .map(|_| {
                    let v = l.value();
                    l.step();
                    v
                })
                .collect()
        };
        let a = b2s_with_randoms(80, &rs);
        let b = b2s_with_randoms(200, &rs);
        assert!(a.scc(&b) > 0.99);
        // Correlated OR = max (the ReLU/MP property).
        assert_eq!(a.or(&b).count_ones(), b.count_ones());
    }

    #[test]
    fn s2b_counts() {
        let bs = Bitstream::from_bits(&[true, true, false, true]);
        assert_eq!(s2b(&bs), 3);
    }

    #[test]
    fn s2b_netlist_counts_stream() {
        let nl = build_s2b_netlist(6);
        let mut ev = Evaluator::new(&nl);
        let pattern = [true, false, true, true, true, false, false, true];
        for &b in &pattern {
            ev.set_inputs(&[b]);
            ev.propagate();
            ev.tick();
        }
        ev.propagate();
        assert_eq!(decode_output(&ev.outputs()), 5);
    }

    #[test]
    fn b2s_netlist_is_a_comparator() {
        let nl = build_b2s_netlist(4);
        let mut ev = Evaluator::new(&nl);
        for code in 0..16u32 {
            for r in 0..16u32 {
                let mut pins = Vec::new();
                for i in 0..4 {
                    pins.push((code >> i) & 1 == 1);
                }
                for i in 0..4 {
                    pins.push((r >> i) & 1 == 1);
                }
                ev.set_inputs(&pins);
                ev.propagate();
                assert_eq!(ev.outputs()[0], code > r);
            }
        }
    }
}
