//! Linear-feedback shift registers — the random-number source (RNS) of the
//! paper's SNGs (§II-C, Fig. 3).
//!
//! Fibonacci LFSRs with primitive feedback polynomials for 3–16 bits, so
//! every width cycles through all 2ⁿ−1 non-zero states before repeating.

/// Primitive-polynomial tap masks (bit i set ⇒ stage i+1 participates in the
/// XOR feedback) for maximal-length LFSRs, widths 3..=16.
/// Taps follow the standard Xilinx/Alfke table, e.g. 4-bit: x⁴+x³+1.
const TAPS: [(u32, u32); 14] = [
    (3, 0b110),                // x3 + x2 + 1
    (4, 0b1100),               // x4 + x3 + 1
    (5, 0b10100),              // x5 + x3 + 1
    (6, 0b110000),             // x6 + x5 + 1
    (7, 0b1100000),            // x7 + x6 + 1
    (8, 0b10111000),           // x8 + x6 + x5 + x4 + 1
    (9, 0b100010000),          // x9 + x5 + 1
    (10, 0b1001000000),        // x10 + x7 + 1
    (11, 0b10100000000),       // x11 + x9 + 1
    (12, 0b111000001000),      // x12 + x11 + x10 + x4 + 1
    (13, 0b1110010000000),     // x13 + x12 + x11 + x8 + 1
    (14, 0b11100000000010),    // x14 + x13 + x12 + x2 + 1
    (15, 0b110000000000000),   // x15 + x14 + 1
    (16, 0b1101000000001000),  // x16 + x15 + x13 + x4 + 1
];

/// Typed error for a register width outside the tabulated 3..=16 range —
/// the request path must never panic on a malformed width, so the table
/// miss is a matchable error instead of an assertion.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct UnsupportedLfsrWidth(pub u32);

impl std::fmt::Display for UnsupportedLfsrWidth {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "no primitive polynomial for {}-bit LFSR (3..=16)", self.0)
    }
}

impl std::error::Error for UnsupportedLfsrWidth {}

/// Tap mask of the primitive polynomial for width `bits` — the one table
/// behind both the behavioral [`Lfsr`] and the SNG netlist builder
/// ([`crate::sc::sng::build_netlist`]).
pub fn taps_for(bits: u32) -> Result<u32, UnsupportedLfsrWidth> {
    TAPS.iter()
        .find(|&&(b, _)| b == bits)
        .map(|&(_, t)| t)
        .ok_or(UnsupportedLfsrWidth(bits))
}

/// A maximal-length Fibonacci LFSR of 3–16 bits.
#[derive(Debug, Clone)]
pub struct Lfsr {
    state: u32,
    taps: u32,
    bits: u32,
}

impl Lfsr {
    /// Create an LFSR of width `bits` seeded with `seed` (any non-zero
    /// value; zero is mapped to 1, the all-zero state being absorbing).
    /// Widths outside 3..=16 are a typed [`UnsupportedLfsrWidth`] error.
    pub fn new(bits: u32, seed: u32) -> Result<Self, UnsupportedLfsrWidth> {
        let taps = taps_for(bits)?;
        let mask = (1u32 << bits) - 1;
        let state = if seed & mask == 0 { 1 } else { seed & mask };
        Ok(Lfsr { state, taps, bits })
    }

    /// Register width.
    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// Current n-bit state (used as the random number R of the SNG).
    pub fn value(&self) -> u32 {
        self.state
    }

    /// Advance one clock; returns the new state.
    pub fn step(&mut self) -> u32 {
        let fb = (self.state & self.taps).count_ones() & 1;
        self.state = ((self.state << 1) | fb) & ((1u32 << self.bits) - 1);
        self.state
    }

    /// The sequence period: 2ⁿ − 1 for a maximal LFSR.
    pub fn period(&self) -> u64 {
        (1u64 << self.bits) - 1
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn all_widths_are_maximal_length() {
        for bits in 3..=16u32 {
            let mut l = Lfsr::new(bits, 1).unwrap();
            let period = l.period();
            // For large widths, walk the full period only up to 16 bits
            // (65535 steps) — cheap enough to verify exhaustively.
            let mut seen = HashSet::new();
            seen.insert(l.value());
            for _ in 0..period {
                l.step();
                assert_ne!(l.value(), 0, "{bits}-bit LFSR hit the absorbing state");
                seen.insert(l.value());
            }
            assert_eq!(
                seen.len() as u64,
                period,
                "{bits}-bit LFSR is not maximal-length"
            );
            // After exactly `period` steps we are back at the seed.
            assert_eq!(l.value(), 1);
        }
    }

    #[test]
    fn zero_seed_is_corrected() {
        let l = Lfsr::new(8, 0).unwrap();
        assert_ne!(l.value(), 0);
    }

    #[test]
    fn state_distribution_is_near_uniform() {
        // Over a full period every non-zero state appears exactly once, so
        // the mean state value is 2^{n-1} (+ tiny bias from missing zero).
        let mut l = Lfsr::new(10, 123).unwrap();
        let period = l.period();
        let mut sum = 0u64;
        for _ in 0..period {
            sum += l.step() as u64;
        }
        let mean = sum as f64 / period as f64;
        assert!((mean - 512.0).abs() < 1.0, "mean={mean}");
    }

    #[test]
    fn unsupported_width_is_a_typed_error() {
        for bits in [0u32, 1, 2, 17, 32] {
            let err = Lfsr::new(bits, 1).unwrap_err();
            assert_eq!(err, UnsupportedLfsrWidth(bits));
            assert!(err.to_string().contains("no primitive polynomial"), "{err}");
            assert_eq!(taps_for(bits).unwrap_err(), UnsupportedLfsrWidth(bits));
        }
        assert_eq!(taps_for(4).unwrap(), 0b1100);
    }
}
