//! The correlated SC neuron of Frasser et al. [29] (§II-B, Fig. 2) — the
//! paper's adopted neuron structure — implemented bit-exactly on packed
//! bitstreams.
//!
//! Dataflow per neuron (bipolar encoding throughout):
//!
//! ```text
//! act codes ─SNG(R1,shared)─┐
//!                            ├─ XNOR ─→ VerticalCounter (APC) ─ c_t
//! wgt codes ─SNG(R2,shared)─┘                                   │
//!                    B2S: o_t = (2·c_t > r4_t), r4 shared ───────┘
//!                    ReLU: o_t OR (n > r4_t)        (correlated max)
//!                    MaxPool: OR across neurons     (correlated max)
//!                    S2B: count ones → next-layer code
//! ```
//!
//! **Scaling convention.** With `n` products and `m = ceil(log2(n+1))`, the
//! B2S comparison `2·c_t > r4` (r4 uniform over 2^(m+1) values) yields a
//! stream of bipolar value `v = (Σ aⱼwⱼ + n)/2^m − 1`; the affine map is the
//! SC-inherent scaled addition. ReLU-at-zero in the Σ-domain corresponds to
//! the threshold stream `n > r4` (bipolar value of a zero pre-activation).
//! The training-side SC-equivalent model in `python/compile/model.py`
//! applies the identical map ([`expectation`] is the shared oracle).

use crate::sc::bitstream::{Bitstream, VerticalCounter};

/// Comparator width for a fan-in of `n`: m = ceil(log2(n+1)) bits hold the
/// per-cycle count; the B2S comparator works in the 2^(m+1) domain.
pub fn m_bits(n: usize) -> u32 {
    (usize::BITS - n.leading_zeros()) as u32
}

/// Accumulate the per-cycle counts of the XNOR products of paired
/// activation/weight streams (the multiplier array + APC front end).
/// Uses the fused [`VerticalCounter::add_xnor`] kernel — no intermediate
/// product stream is materialized.
pub fn mac_counts(acts: &[Bitstream], weights: &[Bitstream]) -> VerticalCounter {
    assert_eq!(acts.len(), weights.len(), "act/weight fan-in mismatch");
    assert!(!acts.is_empty());
    let len = acts[0].len();
    let mut vc = VerticalCounter::new(len, acts.len());
    for (a, w) in acts.iter().zip(weights) {
        vc.add_xnor(a, w);
    }
    vc
}

/// B2S over accumulated counts: bit t = (2·c_t > r4_t), with `r4` uniform
/// over 0..2^(m+1). Output bipolar value ≈ (Σ aw + n)/2^m − 1.
pub fn b2s_stream(vc: &VerticalCounter, r4: &[u32]) -> Bitstream {
    assert_eq!(vc.len(), r4.len(), "random sequence length mismatch");
    Bitstream::from_fn(vc.len(), |t| 2 * vc.count_at(t) > r4[t])
}

/// The correlated zero-threshold stream for ReLU: bit t = (n > r4_t) — the
/// bipolar representation of a zero pre-activation under the same r4.
pub fn relu_zero_stream(n: usize, r4: &[u32]) -> Bitstream {
    Bitstream::from_fn(r4.len(), |t| n as u32 > r4[t])
}

/// Full neuron forward: products → counts → B2S (→ optional ReLU).
pub fn forward(
    acts: &[Bitstream],
    weights: &[Bitstream],
    r4: &[u32],
    relu: bool,
) -> Bitstream {
    let vc = mac_counts(acts, weights);
    let o = b2s_stream(&vc, r4);
    if relu {
        o.or(&relu_zero_stream(acts.len(), r4))
    } else {
        o
    }
}

/// S2B popcount of the neuron output without materializing the output
/// stream: `forward(...).count_ones()` computed via the fused
/// [`VerticalCounter::b2s_ones`] kernel. This is what the inference engine
/// in `accel::network` runs per neuron.
pub fn forward_ones(acts: &[Bitstream], weights: &[Bitstream], r4: &[u32], relu: bool) -> u32 {
    let vc = mac_counts(acts, weights);
    let floor = if relu { acts.len() as u32 } else { 0 };
    vc.b2s_ones(r4, floor)
}

/// Max-pool a group of correlated neuron streams (OR = max for fully
/// correlated streams, Fig. 2).
pub fn max_pool(streams: &[Bitstream]) -> Bitstream {
    assert!(!streams.is_empty());
    streams[1..].iter().fold(streams[0].clone(), |acc, s| acc.or(s))
}

/// Comparison sequence for the counter-based average pooler over `n`
/// streams: a sawtooth counter over the 2n-value comparison domain —
/// exactly stratified whenever 2n divides the stream length, deterministic
/// for any seed phase. (In hardware: a mod-2n up-counter.)
pub fn avg_select_seq(n: usize, k: usize, seed: u32) -> Vec<u32> {
    let domain = 2 * n as u32;
    (0..k as u32).map(|t| t.wrapping_add(seed) % domain).collect()
}

/// SC average pooling — the counter-based scaled add of SC-DCNN-style
/// pooling units: the per-cycle population count `c_t` of the pooled
/// streams (an APC, no multiplier) is re-encoded as
/// `out_t = (2·c_t > r_t)` with `r` uniform over `0..2n`
/// ([`avg_select_seq`]). Since `P(r < 2c) = 2c/2n = c/n` exactly, the
/// output probability is the *mean* of the input probabilities — a scaled
/// add with no 1/2^m headroom loss, which is why SC accelerators prefer
/// average pooling where the model allows it.
///
/// The inference engine applies the recovered-value equivalent
/// ([`crate::accel::stage::avg_pool_into`], a plain mean); this behavioral
/// kernel pins the stream-level hardware semantics.
pub fn avg_pool_stream(streams: &[Bitstream], r: &[u32]) -> Bitstream {
    assert!(!streams.is_empty());
    let len = streams[0].len();
    assert_eq!(r.len(), len, "select sequence length mismatch");
    let mut vc = VerticalCounter::new(len, streams.len());
    for s in streams {
        vc.add(s);
    }
    Bitstream::from_fn(len, |t| 2 * vc.count_at(t) > r[t])
}

/// Expected bipolar output value of the neuron for pre-activation sum
/// `pre = Σ aⱼwⱼ` with fan-in `n`, using a *hard* ReLU — the asymptotic
/// (zero-variance) oracle.
pub fn expectation(pre: f64, n: usize, relu: bool) -> f64 {
    let scale = (1u64 << m_bits(n)) as f64;
    let x = if relu { pre.max(0.0) } else { pre };
    (x + n as f64) / scale - 1.0
}

/// Expected bipolar output with the *SC-smoothed* ReLU.
///
/// The correlated-OR ReLU operates per cycle: out_t = (max(2·c_t, n) > r4),
/// so the expected value is E[max(2c, n)]/2^m − 1, which exceeds the hard
/// ReLU whenever the count fluctuates around the zero level (Jensen). With
/// 2c ≈ Normal(pre + n, σ²), σ² = 4·Σ pⱼ(1−pⱼ) = Σ (1 − (aⱼwⱼ)²):
///
///   E[max(Y, n)] = n + σ·[φ(z) + z·Φ(z)],  z = pre/σ.
///
/// This is the exact model `python/compile/model.py` trains through — SC
/// hardware implements a softplus-like activation, not a sharp ReLU.
pub fn expectation_smooth_relu(pre: f64, sigma2: f64, n: usize) -> f64 {
    expectation_smooth_relu_scaled(pre, sigma2, n, (1u64 << m_bits(n)) as f64)
}

/// [`expectation_smooth_relu`] with the 2^m divisor precomputed — the
/// compiled-stage form: `accel::network` stores `scale` once per layer at
/// `ForwardPlan::compile` and hoists the per-call [`m_bits`] shift out of
/// its per-neuron loops.
pub fn expectation_smooth_relu_scaled(pre: f64, sigma2: f64, n: usize, scale: f64) -> f64 {
    let sigma = sigma2.max(0.0).sqrt();
    let softplus = if sigma < 1e-9 {
        pre.max(0.0)
    } else {
        let z = pre / sigma;
        sigma * (phi(z) + z * cap_phi(z))
    };
    (softplus + n as f64) / scale - 1.0
}

/// Per-cycle count variance of `2c` for product values `aw` (each in
/// [−1, 1]): Σ (1 − (aⱼwⱼ)²), assuming independent product streams.
pub fn count_variance(products: &[f64]) -> f64 {
    products.iter().map(|&v| 1.0 - v * v).sum()
}

/// Standard normal pdf.
fn phi(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Standard normal cdf via an Abramowitz–Stegun erf approximation
/// (|err| < 1.5e-7 — far below SC sampling noise).
fn cap_phi(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

/// Error function approximation (Abramowitz & Stegun 7.1.26).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::sc::lfsr::Lfsr;
    use crate::sc::pcc::{pcc_bit, PccKind};
    use crate::sc::{dequantize_bipolar, quantize_bipolar};

    /// Generate correlated bipolar streams for codes via one shared LFSR of
    /// width `lfsr_bits ≥ bits` (activation and weight banks must use
    /// *different* random sequences or XNOR products bias badly — same
    /// polynomial at a phase offset is not enough; see StreamBank in
    /// `accel::network`).
    fn gen_correlated(
        codes: &[u32],
        bits: u32,
        lfsr_bits: u32,
        len: usize,
        seed: u32,
    ) -> Vec<Bitstream> {
        let mut l = Lfsr::new(lfsr_bits, seed).unwrap();
        let mask = (1u32 << bits) - 1;
        let rs: Vec<u32> = (0..len)
            .map(|_| {
                let v = l.value() & mask;
                l.step();
                v
            })
            .collect();
        codes
            .iter()
            .map(|&c| Bitstream::from_fn(len, |t| pcc_bit(PccKind::Comparator, c, rs[t], bits)))
            .collect()
    }

    fn r4_sequence(n: usize, len: usize, seed: u32) -> Vec<u32> {
        let m1 = m_bits(n) + 1;
        let mut l = Lfsr::new(m1.max(3), seed).unwrap();
        (0..len)
            .map(|_| {
                let v = l.value() & ((1 << m1) - 1);
                l.step();
                v
            })
            .collect()
    }

    #[test]
    fn m_bits_covers_counts() {
        assert_eq!(m_bits(25), 5);
        assert_eq!(m_bits(32), 6);
        assert_eq!(m_bits(1), 1);
        for n in 1..100usize {
            assert!((1usize << m_bits(n)) > n);
        }
    }

    #[test]
    fn neuron_tracks_expectation() {
        let bits = 8;
        let len = 4096;
        let n = 25;
        // Activation values spread over [-1,1]; weights alternating sign.
        let avals: Vec<f64> = (0..n).map(|j| (j as f64 / n as f64) * 1.6 - 0.8).collect();
        let wvals: Vec<f64> =
            (0..n).map(|j| if j % 2 == 0 { 0.6 } else { -0.4 }).collect();
        let acodes: Vec<u32> = avals.iter().map(|&v| quantize_bipolar(v, bits)).collect();
        let wcodes: Vec<u32> = wvals.iter().map(|&v| quantize_bipolar(v, bits)).collect();
        // Quantized values (what the hardware actually encodes).
        let aq: Vec<f64> = acodes.iter().map(|&c| dequantize_bipolar(c, bits)).collect();
        let wq: Vec<f64> = wcodes.iter().map(|&c| dequantize_bipolar(c, bits)).collect();
        let pre: f64 = aq.iter().zip(&wq).map(|(a, w)| a * w).sum();

        let acts = gen_correlated(&acodes, bits, bits, len, 17);
        let wgts = gen_correlated(&wcodes, bits, bits + 3, len, 101);
        let r4 = r4_sequence(n, len, 7);
        let products: Vec<f64> = aq.iter().zip(&wq).map(|(a, w)| a * w).collect();
        for relu in [false, true] {
            let out = forward(&acts, &wgts, &r4, relu);
            let got = out.value_bipolar();
            let want = if relu {
                expectation_smooth_relu(pre, count_variance(&products), n)
            } else {
                expectation(pre, n, relu)
            };
            assert!(
                (got - want).abs() < 0.08,
                "relu={relu}: got {got}, want {want} (pre={pre})"
            );
        }
    }

    #[test]
    fn forward_ones_matches_streamed_forward() {
        let bits = 8;
        let len = 1000; // crosses word boundaries
        let n = 12;
        let acodes: Vec<u32> =
            (0..n).map(|j| quantize_bipolar((j as f64 / n as f64) - 0.4, bits)).collect();
        let wcodes: Vec<u32> =
            (0..n).map(|j| quantize_bipolar(if j % 2 == 0 { 0.5 } else { -0.3 }, bits)).collect();
        let acts = gen_correlated(&acodes, bits, bits, len, 9);
        let wgts = gen_correlated(&wcodes, bits, bits + 3, len, 77);
        let r4 = r4_sequence(n, len, 3);
        for relu in [false, true] {
            let streamed = forward(&acts, &wgts, &r4, relu).count_ones();
            assert_eq!(forward_ones(&acts, &wgts, &r4, relu), streamed, "relu={relu}");
        }
    }

    #[test]
    fn relu_clamps_negative_preactivations() {
        let bits = 8;
        let len = 4096;
        let n = 9;
        // Strongly negative pre-activation: all acts 0.9, all weights -0.9.
        let acodes = vec![quantize_bipolar(0.9, bits); n];
        let wcodes = vec![quantize_bipolar(-0.9, bits); n];
        let acts = gen_correlated(&acodes, bits, bits, len, 3);
        let wgts = gen_correlated(&wcodes, bits, bits + 3, len, 91);
        let r4 = r4_sequence(n, len, 11);
        let no_relu = forward(&acts, &wgts, &r4, false).value_bipolar();
        let relu = forward(&acts, &wgts, &r4, true).value_bipolar();
        let zero_level = expectation(0.0, n, false);
        assert!(no_relu < zero_level - 0.1, "pre-activation should be negative");
        assert!((relu - zero_level).abs() < 0.05, "ReLU should clamp at zero level");
    }

    #[test]
    fn max_pool_takes_the_max() {
        let bits = 8;
        let len = 2048;
        let n = 4;
        let r4 = r4_sequence(n, len, 5);
        // Three neurons with increasing pre-activations via weights.
        let acodes = vec![quantize_bipolar(0.8, bits); n];
        let acts = gen_correlated(&acodes, bits, bits, len, 23);
        let mut streams = Vec::new();
        let mut exps = Vec::new();
        for (i, wv) in [(0, -0.5f64), (1, 0.1), (2, 0.7)] {
            let wcodes = vec![quantize_bipolar(wv, bits); n];
            let wgts = gen_correlated(&wcodes, bits, bits + 3, len, 41 + i);
            streams.push(forward(&acts, &wgts, &r4, false));
            let aq = dequantize_bipolar(acodes[0], bits);
            let wq = dequantize_bipolar(wcodes[0], bits);
            exps.push(expectation(n as f64 * aq * wq, n, false));
        }
        let pooled = max_pool(&streams).value_bipolar();
        let want = exps.iter().fold(f64::MIN, |m, &e| m.max(e));
        assert!((pooled - want).abs() < 0.08, "pooled={pooled} want={want}");
    }

    #[test]
    fn avg_pool_stream_takes_the_mean() {
        let bits = 8;
        let len = 4096;
        // Four streams of known bipolar values; the pooled stream's value
        // must be their mean (the SC scaled add).
        let vals = [-0.6f64, -0.1, 0.3, 0.8];
        let codes: Vec<u32> = vals.iter().map(|&v| quantize_bipolar(v, bits)).collect();
        let streams = gen_correlated(&codes, bits, bits, len, 29);
        let r = avg_select_seq(streams.len(), len, 5);
        assert!(r.iter().all(|&x| x < 8), "domain is 2n = 8");
        let pooled = avg_pool_stream(&streams, &r).value_bipolar();
        let want: f64 = codes.iter().map(|&c| dequantize_bipolar(c, bits)).sum::<f64>() / 4.0;
        assert!((pooled - want).abs() < 0.05, "pooled={pooled} want={want}");
    }

    #[test]
    fn avg_pool_stream_is_exact_on_stratified_constant_counts() {
        // All-ones and all-zeros streams: count is constant (2 of 4), so a
        // full sawtooth period recovers exactly p = 1/2.
        let len = 512; // multiple of 2n = 8
        let ones = Bitstream::from_fn(len, |_| true);
        let zeros = Bitstream::from_fn(len, |_| false);
        let streams = vec![ones.clone(), ones, zeros.clone(), zeros];
        let r = avg_select_seq(4, len, 0);
        let pooled = avg_pool_stream(&streams, &r);
        assert_eq!(pooled.count_ones() as usize, len / 2);
    }

    #[test]
    fn expectation_bounds() {
        for n in [9usize, 25, 150] {
            let lo = expectation(-(n as f64), n, false);
            let hi = expectation(n as f64, n, false);
            assert!(lo >= -1.0 - 1e-9);
            assert!(hi <= 1.0 + 1e-9);
        }
    }
}
