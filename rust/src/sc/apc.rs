//! Accumulative parallel counters (§III-B, Fig. 8).
//!
//! The APC is the stochastic→binary workhorse of the SC neuron: each clock
//! cycle it counts the '1's across its parallel inputs (a Wallace-style
//! full-adder reduction, Fig. 8a) and accumulates the count in a binary
//! register. Two full-adder styles are supported:
//!
//! * [`FaStyle::CmosCell`] — the conventional 28-transistor CMOS FA cell
//!   (Fig. 8b), used by the FinFET baseline;
//! * [`FaStyle::RfetCompact`] — the paper's XOR3 + MAJ3 + inverters
//!   composite (Fig. 8c), used by the RFET design.
//!
//! An *approximate* front end (after Kim et al. [36]) is also provided: it
//! OR-combines input pairs before counting, halving the reduction tree at
//! the cost of an upward bias for correlated/high-density inputs.

use crate::netlist::{NetId, Netlist};
use anyhow::{bail, Result};

/// Which full-adder implementation the netlist instantiates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaStyle {
    /// Monolithic CMOS FA standard cell (28 T, Fig. 8b).
    CmosCell,
    /// RFET compact FA: XOR3 + MAJ3 + 2 inverters (Fig. 8c).
    RfetCompact,
}

/// Behavioral APC: counts ones per cycle, accumulates across cycles.
#[derive(Debug, Clone)]
pub struct Apc {
    inputs: usize,
    acc: u64,
    cycles: usize,
}

impl Apc {
    /// An APC with `inputs` parallel inputs.
    pub fn new(inputs: usize) -> Self {
        Apc { inputs, acc: 0, cycles: 0 }
    }

    /// Number of parallel inputs.
    pub fn inputs(&self) -> usize {
        self.inputs
    }

    /// Process one cycle; returns this cycle's count.
    pub fn step(&mut self, bits: &[bool]) -> u32 {
        assert_eq!(bits.len(), self.inputs, "APC input arity mismatch");
        let c = bits.iter().filter(|&&b| b).count() as u32;
        self.acc += c as u64;
        self.cycles += 1;
        c
    }

    /// Accumulated count over all cycles so far.
    pub fn accumulated(&self) -> u64 {
        self.acc
    }

    /// Cycles processed.
    pub fn cycles(&self) -> usize {
        self.cycles
    }

    /// Clear the accumulator.
    pub fn reset(&mut self) {
        self.acc = 0;
        self.cycles = 0;
    }
}

/// Behavioral approximate count (OR-paired front end, [36]-style): input
/// pairs are OR-combined into single weight-1 bits, halving the reduction
/// tree. Lower-bounds the exact count (a pair with both bits set loses 1);
/// exact for sparse inputs — the common case for SC products, whose '1'
/// densities multiply down.
pub fn approximate_count(bits: &[bool]) -> u32 {
    let mut c = 0u32;
    let mut i = 0;
    while i + 1 < bits.len() {
        c += (bits[i] | bits[i + 1]) as u32;
        i += 2;
    }
    if i < bits.len() {
        c += bits[i] as u32;
    }
    c
}

/// Emit a full adder in the requested style; returns (sum, carry).
fn fa(nl: &mut Netlist, style: FaStyle, a: NetId, b: NetId, c: NetId) -> (NetId, NetId) {
    match style {
        FaStyle::CmosCell => nl.full_adder_cell(a, b, c),
        FaStyle::RfetCompact => nl.full_adder_rfet(a, b, c),
    }
}

/// Reduce `inputs` weight-1 bits to a binary count (LSB first) with a
/// Wallace-style column reduction of FAs/HAs. An empty input slice is a
/// typed error (the reduction has no defined output width), and the column
/// pops are checked: a malformed reduction surfaces as an error instead of
/// a panic during session/channel construction.
pub fn build_parallel_counter(
    nl: &mut Netlist,
    style: FaStyle,
    inputs: &[NetId],
) -> Result<Vec<NetId>> {
    if inputs.is_empty() {
        bail!("parallel counter needs >= 1 input");
    }
    let out_bits = (usize::BITS - inputs.len().leading_zeros()) as usize;
    let mut columns: Vec<Vec<NetId>> = vec![Vec::new(); out_bits];
    columns[0] = inputs.to_vec();
    for w in 0..out_bits {
        while columns[w].len() > 1 {
            let (s, cy) = if columns[w].len() >= 3 {
                match (columns[w].pop(), columns[w].pop(), columns[w].pop()) {
                    (Some(c), Some(b), Some(a)) => fa(nl, style, a, b, c),
                    _ => bail!("parallel counter column {w} under-ran a full adder"),
                }
            } else {
                match (columns[w].pop(), columns[w].pop()) {
                    (Some(b), Some(a)) => nl.half_adder(a, b),
                    _ => bail!("parallel counter column {w} under-ran a half adder"),
                }
            };
            columns[w].insert(0, s);
            if w + 1 < out_bits {
                columns[w + 1].push(cy);
            }
            // A full column at max weight cannot carry out: the count
            // fits in out_bits by construction.
        }
    }
    columns
        .into_iter()
        .enumerate()
        .map(|(w, col)| {
            col.into_iter()
                .next()
                .ok_or_else(|| anyhow::anyhow!("counter column {w} empty after reduction"))
        })
        .collect()
}

/// Build a complete APC netlist: parallel counter + binary accumulator
/// sized for `max_cycles` of accumulation.
///
/// Primary inputs: the `inputs` parallel bits. Primary outputs: the
/// accumulator register (LSB first). `inputs == 0` and `max_cycles == 0`
/// (which has no defined accumulator width) are typed errors.
pub fn build_netlist(inputs: usize, max_cycles: usize, style: FaStyle) -> Result<Netlist> {
    if max_cycles == 0 {
        bail!("APC needs max_cycles >= 1 to size its accumulator");
    }
    let mut nl = Netlist::new(format!("apc_{inputs}in_{max_cycles}cyc_{style:?}"));
    let ins = nl.inputs(inputs);
    let count = build_parallel_counter(&mut nl, style, &ins)?;
    let cnt_bits = count.len();
    // Accumulator width: counter bits + ceil(log2(max_cycles)).
    let acc_bits = cnt_bits + (usize::BITS - (max_cycles - 1).leading_zeros()) as usize;

    // Register Q nets exist only after the DFFs; the adder reads Q and the
    // DFF Ds read the adder — close the loop with rewire, like the LFSR.
    let placeholder = nl.constant(false);
    let first_dff_gate = nl.num_gates();
    let qs: Vec<NetId> = (0..acc_bits).map(|_| nl.dff(placeholder)).collect();

    // q + count adder: HA at bit 0, FA while count bits remain, HA for the
    // carry tail.
    let mut carry: Option<NetId> = None;
    let mut next: Vec<NetId> = Vec::with_capacity(acc_bits);
    for i in 0..acc_bits {
        let cnt = count.get(i).copied();
        let (s, cy) = match (cnt, carry) {
            (Some(c), Some(cr)) => {
                let (s, cy) = fa(&mut nl, style, qs[i], c, cr);
                (s, Some(cy))
            }
            (Some(c), None) => {
                let (s, cy) = nl.half_adder(qs[i], c);
                (s, Some(cy))
            }
            (None, Some(cr)) => {
                let (s, cy) = nl.half_adder(qs[i], cr);
                (s, Some(cy))
            }
            (None, None) => (qs[i], None),
        };
        next.push(s);
        carry = cy;
    }
    for (i, &d) in next.iter().enumerate() {
        nl.rewire_gate_input(first_dff_gate + i, 0, d);
    }
    for &q in &qs {
        nl.mark_output(q);
    }
    Ok(nl)
}

/// Read an accumulator value from netlist outputs (LSB first).
pub fn decode_output(bits: &[bool]) -> u64 {
    bits.iter().enumerate().map(|(i, &b)| (b as u64) << i).sum()
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::sc::rng::XorShift64;
    use crate::sim::Evaluator;
    use crate::tech::CellKind;

    fn xorshift(seed: u64) -> impl FnMut() -> u64 {
        let mut g = XorShift64::new(seed);
        move || g.next_u64()
    }

    #[test]
    fn behavioral_accumulates() {
        let mut apc = Apc::new(4);
        assert_eq!(apc.step(&[true, true, false, true]), 3);
        assert_eq!(apc.step(&[false, false, false, false]), 0);
        assert_eq!(apc.step(&[true, true, true, true]), 4);
        assert_eq!(apc.accumulated(), 7);
        assert_eq!(apc.cycles(), 3);
        apc.reset();
        assert_eq!(apc.accumulated(), 0);
    }

    #[test]
    fn parallel_counter_counts_exactly() {
        for style in [FaStyle::CmosCell, FaStyle::RfetCompact] {
            for n in [1usize, 2, 3, 7, 15, 25] {
                let mut nl = Netlist::new("pc");
                let ins = nl.inputs(n);
                let outs = build_parallel_counter(&mut nl, style, &ins).unwrap();
                for &o in &outs {
                    nl.mark_output(o);
                }
                let mut ev = Evaluator::new(&nl);
                let mut rng = xorshift(n as u64 * 31 + 1);
                for _ in 0..200 {
                    let bits: Vec<bool> = (0..n).map(|_| rng() % 2 == 1).collect();
                    ev.set_inputs(&bits);
                    ev.propagate();
                    let count = decode_output(&ev.outputs());
                    let expected = bits.iter().filter(|&&b| b).count() as u64;
                    assert_eq!(count, expected, "{style:?} n={n}");
                }
            }
        }
    }

    #[test]
    fn counter_structure_matches_calibration() {
        // The 25-input counter must use 20 FA + 2 HA (DESIGN.md §Calibration).
        let mut nl = Netlist::new("pc25");
        let ins = nl.inputs(25);
        let _ = build_parallel_counter(&mut nl, FaStyle::CmosCell, &ins).unwrap();
        let counts = nl.cell_counts();
        assert_eq!(counts[&CellKind::FullAdder], 20);
        assert_eq!(counts[&CellKind::HalfAdder], 2);
    }

    #[test]
    fn degenerate_inputs_are_typed_errors() {
        // 0-input counter: no defined output width.
        let mut nl = Netlist::new("pc0");
        let err = build_parallel_counter(&mut nl, FaStyle::CmosCell, &[]).unwrap_err();
        assert!(err.to_string().contains(">= 1 input"), "{err}");
        assert!(build_netlist(0, 32, FaStyle::CmosCell).is_err());
        // 0-cycle APC: the accumulator-width formula would underflow.
        let err = build_netlist(25, 0, FaStyle::CmosCell).unwrap_err();
        assert!(err.to_string().contains("max_cycles"), "{err}");
    }

    #[test]
    fn one_input_counter_is_a_wire() {
        // The 1-input counter adds no arithmetic cells: count == the bit.
        let mut nl = Netlist::new("pc1");
        let ins = nl.inputs(1);
        let outs = build_parallel_counter(&mut nl, FaStyle::CmosCell, &ins).unwrap();
        assert_eq!(outs, ins);
        let counts = nl.cell_counts();
        assert!(!counts.contains_key(&CellKind::FullAdder));
        assert!(!counts.contains_key(&CellKind::HalfAdder));
        // And a full 1-input APC still accumulates correctly.
        let nl = build_netlist(1, 8, FaStyle::CmosCell).unwrap();
        let mut ev = Evaluator::new(&nl);
        for _ in 0..5 {
            ev.set_inputs(&[true]);
            ev.propagate();
            ev.tick();
        }
        ev.propagate();
        assert_eq!(decode_output(&ev.outputs()), 5);
    }

    #[test]
    fn apc25_structure_matches_calibration() {
        // Full APC (k=32): 24 FA + 8 HA + 10 DFF.
        let nl = build_netlist(25, 32, FaStyle::CmosCell).unwrap();
        let counts = nl.cell_counts();
        assert_eq!(counts[&CellKind::FullAdder], 24);
        assert_eq!(counts[&CellKind::HalfAdder], 8);
        assert_eq!(counts[&CellKind::Dff], 10);
        // RFET flavor: 24 XOR3 + 24 MAJ3 (+ 2 inv each) instead of FA cells.
        let rf = build_netlist(25, 32, FaStyle::RfetCompact).unwrap();
        let rc = rf.cell_counts();
        assert_eq!(rc[&CellKind::Xor3], 24);
        assert_eq!(rc[&CellKind::Maj3], 24);
        assert_eq!(rc[&CellKind::Dff], 10);
        assert!(!rc.contains_key(&CellKind::FullAdder));
    }

    #[test]
    fn apc_netlist_accumulates_like_behavioral() {
        for style in [FaStyle::CmosCell, FaStyle::RfetCompact] {
            let n = 15;
            let k = 32;
            let nl = build_netlist(n, k, style).unwrap();
            let mut ev = Evaluator::new(&nl);
            let mut model = Apc::new(n);
            let mut rng = xorshift(99);
            for _ in 0..k {
                let bits: Vec<bool> = (0..n).map(|_| rng() % 3 == 0).collect();
                model.step(&bits);
                ev.set_inputs(&bits);
                ev.propagate();
                ev.tick();
            }
            ev.propagate();
            assert_eq!(
                decode_output(&ev.outputs()),
                model.accumulated(),
                "{style:?}"
            );
        }
    }

    #[test]
    fn approximate_count_lower_bounds_exact() {
        let mut rng = xorshift(5);
        for _ in 0..500 {
            let bits: Vec<bool> = (0..25).map(|_| rng() % 4 == 0).collect();
            let exact = bits.iter().filter(|&&b| b).count() as u32;
            let approx = approximate_count(&bits);
            assert!(approx <= exact, "OR-pairing can only lose counts");
            assert!(2 * approx >= exact, "each pair loses at most half");
        }
    }

    #[test]
    fn approximate_count_exact_when_sparse() {
        // No pair with both bits set ⇒ exact.
        let mut bits = vec![false; 25];
        bits[0] = true;
        bits[5] = true;
        bits[24] = true;
        assert_eq!(approximate_count(&bits), 3);
    }
}
