//! Probability-conversion circuits (PCCs): the binary→stochastic half of an
//! SNG (§II-C, Fig. 4) and the paper's core circuit contribution — the RFET
//! NAND-NOR reconfigurable chain with Lemma 1's inverter-insertion rule
//! (§III-A, Fig. 6).
//!
//! Each kind has a *behavioral* bit function (used in the accuracy
//! experiments and by [`crate::sc::sng`]) and a *netlist builder* (used for
//! the Table I hardware comparison). The behavioral NAND-NOR model is
//! asserted bit-identical to its gate netlist in the tests.

use crate::netlist::Netlist;

/// Which PCC microarchitecture converts code → stochastic bit.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PccKind {
    /// Magnitude comparator: bit = (X > R) (Fig. 4a).
    Comparator,
    /// MUX-chain (Ding et al. [12], Fig. 4b): P = X / 2^N.
    MuxChain,
    /// RFET NAND-NOR reconfigurable chain (Fig. 6c, Lemma 1).
    NandNor,
}

impl PccKind {
    /// All kinds, for sweeps.
    pub const ALL: [PccKind; 3] = [PccKind::Comparator, PccKind::MuxChain, PccKind::NandNor];
}

/// Lemma 1's inverter-insertion rule: whether stage `i` (1-indexed) of an
/// `n`-stage NAND-NOR chain takes the *inverted* X bit.
///
/// > If N is even, add inverters to all Xi with even index.
/// > If N is odd, add inverters to all Xi with odd index.
pub fn nandnor_stage_inverted(n: u32, i: u32) -> bool {
    debug_assert!((1..=n).contains(&i));
    if n % 2 == 0 {
        i % 2 == 0
    } else {
        i % 2 == 1
    }
}

/// One output bit of a PCC of kind `kind` with `bits`-bit input code `x`
/// and random number `r` (both interpreted LSB-first, stage i consuming
/// bit i−1 in the chain designs).
pub fn pcc_bit(kind: PccKind, x: u32, r: u32, bits: u32) -> bool {
    debug_assert!(bits >= 1 && bits <= 16);
    let mask = (1u64 << bits) - 1;
    let x = (x as u64) & mask;
    let r = (r as u64) & mask;
    match kind {
        PccKind::Comparator => x > r,
        PccKind::MuxChain => {
            // O_0 = 0; stage i: O_i = R_i ? X_i : O_{i-1}  (LSB first).
            let mut o = false;
            for i in 0..bits {
                let xi = (x >> i) & 1 == 1;
                let ri = (r >> i) & 1 == 1;
                o = if ri { xi } else { o };
            }
            o
        }
        PccKind::NandNor => {
            // Lemma 1, eqs. (4)–(6): O_0 = 0; stage i applies NAND or NOR of
            // (O_{i-1}, R_i) selected by the (possibly inverted) X_i.
            // prog = 1 → NOR. From eqs. (5)/(6): for N even, odd stages
            // select NOR when X_i = 1 (prog = X_i) and even stages when
            // X_i = 0 (prog = !X_i); parities swap for N odd.
            let mut o = false;
            for i in 1..=bits {
                let xi = (x >> (i - 1)) & 1 == 1;
                let ri = (r >> (i - 1)) & 1 == 1;
                let prog = if nandnor_stage_inverted(bits, i) { !xi } else { xi };
                o = if prog { !(o | ri) } else { !(o & ri) };
            }
            o
        }
    }
}

/// Exact expected output of a PCC for input code `x`, averaging over all
/// 2^bits equiprobable R values (i.e. ideal independent R bits with
/// p = 0.5). For the chain PCCs this uses the stage recurrence of Lemma 1's
/// proof; for the comparator it is x / 2^bits by construction.
pub fn expected_output(kind: PccKind, x: u32, bits: u32) -> f64 {
    match kind {
        PccKind::Comparator => x as f64 / (1u64 << bits) as f64,
        PccKind::MuxChain => {
            // m_i = ½ m_{i-1} + ½ X_i  (select X_i with prob ½).
            let mut m = 0.0f64;
            for i in 0..bits {
                let xi = ((x >> i) & 1) as f64;
                m = 0.5 * m + 0.5 * xi;
            }
            m
        }
        PccKind::NandNor => {
            // NAND stage: E = 1 − ½ m;  NOR stage: E = ½ − ½ m  (eqs. 9–10).
            let mut m = 0.0f64;
            for i in 1..=bits {
                let xi = (x >> (i - 1)) & 1 == 1;
                let prog = if nandnor_stage_inverted(bits, i) { !xi } else { xi };
                m = if prog { 0.5 * (1.0 - m) } else { 1.0 - 0.5 * m };
            }
            m
        }
    }
}

/// Build the gate netlist of an `bits`-bit PCC.
///
/// Primary inputs: X[0..bits] (LSB first) then R[0..bits]; one primary
/// output (the stochastic bit).
pub fn build_netlist(kind: PccKind, bits: u32) -> Netlist {
    let mut nl = Netlist::new(format!("pcc_{kind:?}_{bits}b"));
    let x = nl.inputs(bits as usize);
    let r = nl.inputs(bits as usize);
    let out = match kind {
        PccKind::Comparator => {
            // Iterative magnitude comparator, LSB→MSB so the most
            // significant difference decides: gt_i = (xᵢ & !rᵢ) | (xᵢ ≡ rᵢ) & gt_{i−1}.
            let mut gt = nl.constant(false);
            for i in 0..bits as usize {
                let nr = nl.inv(r[i]);
                let here = nl.and2(x[i], nr);
                let eq = nl.xnor2(x[i], r[i]);
                let keep = nl.and2(eq, gt);
                gt = nl.or2(here, keep);
            }
            gt
        }
        PccKind::MuxChain => {
            let mut o = nl.constant(false);
            for i in 0..bits as usize {
                o = nl.mux21(o, x[i], r[i]);
            }
            o
        }
        PccKind::NandNor => {
            // Fig. 6c: NandNor chain with inverters inserted on the X inputs
            // per Lemma 1's parity rule.
            let mut o = nl.constant(false);
            for i in 1..=bits {
                let xi = x[(i - 1) as usize];
                let prog = if nandnor_stage_inverted(bits, i) { nl.inv(xi) } else { xi };
                o = nl.nandnor(o, r[(i - 1) as usize], prog);
            }
            o
        }
    };
    nl.mark_output(out);
    nl
}

/// Number of inverters Lemma 1's rule inserts for an `n`-stage chain.
pub fn nandnor_inverter_count(n: u32) -> u32 {
    (1..=n).filter(|&i| nandnor_stage_inverted(n, i)).count() as u32
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::sim::Evaluator;

    /// Average PCC output over every R value (exhaustive, uniform R).
    fn exhaustive_mean(kind: PccKind, x: u32, bits: u32) -> f64 {
        let total = 1u64 << bits;
        let ones: u64 =
            (0..total).filter(|&r| pcc_bit(kind, x, r as u32, bits)).count() as u64;
        ones as f64 / total as f64
    }

    #[test]
    fn comparator_probability_is_exact() {
        for bits in [3u32, 4, 6] {
            for x in 0..(1u32 << bits) {
                let m = exhaustive_mean(PccKind::Comparator, x, bits);
                assert!((m - x as f64 / (1u64 << bits) as f64).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn mux_chain_matches_eq1() {
        // Eq. (1): P = Σ X_i 2^i / 2^N over uniform independent R bits.
        for bits in [3u32, 4, 8] {
            for x in 0..(1u32 << bits) {
                let m = exhaustive_mean(PccKind::MuxChain, x, bits);
                assert!(
                    (m - x as f64 / (1u64 << bits) as f64).abs() < 1e-12,
                    "bits={bits} x={x} m={m}"
                );
            }
        }
    }

    #[test]
    fn nandnor_matches_lemma1_recurrence() {
        // The behavioral chain must equal the stage recurrence exactly.
        for bits in 3..=10u32 {
            for x in 0..(1u32 << bits) {
                let m = exhaustive_mean(PccKind::NandNor, x, bits);
                let e = expected_output(PccKind::NandNor, x, bits);
                assert!((m - e).abs() < 1e-12, "bits={bits} x={x} m={m} e={e}");
            }
        }
    }

    #[test]
    fn nandnor_approximates_x_over_2n() {
        // Lemma 1's conclusion (eqs. 21–22): m_N ≈ Σ 2^{k-1} X_k / 2^N, with
        // a small constant bias A_N (the paper's Fig. 7 shows the slight
        // upward offset at small bit lengths).
        for bits in 3..=10u32 {
            let mut max_err = 0.0f64;
            for x in 0..(1u32 << bits) {
                let m = expected_output(PccKind::NandNor, x, bits);
                let ideal = x as f64 / (1u64 << bits) as f64;
                max_err = max_err.max((m - ideal).abs());
            }
            // The residual constant A_N of eq. (18) is on the order of one
            // LSB (2^-N); e.g. A_3 = 1/8, A_4 = 0.
            assert!(
                max_err <= 1.6 / (1u64 << bits) as f64,
                "bits={bits} max_err={max_err}"
            );
            // Monotonicity in X is what the conversion needs (Fig. 7):
            let mut prev = -1.0;
            for x in 0..(1u32 << bits) {
                let m = expected_output(PccKind::NandNor, x, bits);
                assert!(m >= prev - 1e-12, "non-monotone at bits={bits} x={x}");
                prev = m;
            }
        }
    }

    #[test]
    fn nandnor_bias_positive_at_small_widths() {
        // Fig. 7: "the NAND-NOR PCC results in a slightly higher value
        // compared to the other two methods" for small bit lengths.
        for bits in 3..=6u32 {
            let mid = 1u32 << (bits - 1);
            let m = expected_output(PccKind::NandNor, mid, bits);
            assert!(m >= 0.5 - 1e-12, "bits={bits} mid response {m}");
        }
    }

    #[test]
    fn inverter_rule_counts() {
        assert_eq!(nandnor_inverter_count(8), 4); // even N → even indices
        assert_eq!(nandnor_inverter_count(7), 4); // odd N → odd indices 1,3,5,7
        assert_eq!(nandnor_inverter_count(4), 2);
        assert_eq!(nandnor_inverter_count(3), 2);
    }

    #[test]
    fn netlists_match_behavioral_bit_for_bit() {
        for kind in PccKind::ALL {
            for bits in [3u32, 4, 8] {
                let nl = build_netlist(kind, bits);
                let mut ev = Evaluator::new(&nl);
                for x in 0..(1u32 << bits) {
                    // Sample a subset of R values to keep the test fast.
                    for r in (0..(1u32 << bits)).step_by(3) {
                        let mut pins = Vec::new();
                        for i in 0..bits {
                            pins.push((x >> i) & 1 == 1);
                        }
                        for i in 0..bits {
                            pins.push((r >> i) & 1 == 1);
                        }
                        ev.set_inputs(&pins);
                        ev.propagate();
                        assert_eq!(
                            ev.outputs()[0],
                            pcc_bit(kind, x, r, bits),
                            "{kind:?} bits={bits} x={x} r={r}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn netlist_structure_matches_paper() {
        use crate::tech::CellKind;
        // 8-bit MUX chain: exactly 8 MUX21s.
        let mux = build_netlist(PccKind::MuxChain, 8);
        assert_eq!(mux.cell_counts()[&CellKind::Mux21], 8);
        assert_eq!(mux.num_gates(), 8);
        // 8-bit NAND-NOR chain: 8 NandNor + 4 inverters (Lemma 1, N even).
        let nn = build_netlist(PccKind::NandNor, 8);
        assert_eq!(nn.cell_counts()[&CellKind::NandNor], 8);
        assert_eq!(nn.cell_counts()[&CellKind::Inv], 4);
    }
}
