//! Stochastic-computing primitives: every component of the paper's SCNN
//! datapath, each with a fast *behavioral* model (used by the accuracy
//! experiments and the serving hot path) and, where the paper characterizes
//! hardware, a *netlist builder* (used with [`crate::tech`] +
//! [`crate::sim`] for the Table I/II area/delay/energy comparisons).
//!
//! Components (paper section in parentheses):
//! * [`bitstream`] — packed bitstreams, SC multiply, correlation (II-A);
//! * [`bitplane`] — transposed bit-plane layout: 64-lane XNOR+popcount
//!   words and the 64×64 bit transpose behind the fast kernels (II-A);
//! * [`lfsr`] — maximal-length LFSR random-number sources (II-C);
//! * [`pcc`] — CMP / MUX-chain / RFET NAND-NOR probability-conversion
//!   circuits, incl. Lemma 1's inverter-insertion rule (II-C, III-A);
//! * [`sng`] — stochastic number generators with RNS sharing (II-C);
//! * [`rng`] — shared deterministic RNG kernels (xorshift64, splitmix64);
//! * [`apc`] — accumulative parallel counters, exact + approximate (III-B);
//! * [`adder_tree`] — configurable adder tree for wide neurons (IV-A);
//! * [`converters`] — B2S and S2B converters (II-B, IV-A);
//! * [`neuron`] — the Frasser correlated SC neuron [29] (II-B).

// The SC datapath is the bit-exactness contract of the whole crate: a
// panic here takes down a serving shard mid-request, so fallible paths
// must return typed errors (tests opt back in per-module).
#![deny(clippy::unwrap_used)]

pub mod adder_tree;
pub mod apc;
pub mod bitplane;
pub mod bitstream;
pub mod converters;
pub mod lfsr;
pub mod neuron;
pub mod pcc;
pub mod rng;
pub mod sng;

pub use bitstream::Bitstream;
pub use lfsr::{Lfsr, UnsupportedLfsrWidth};
pub use pcc::PccKind;

/// Quantize a real value in [0, 1] to an `bits`-bit unipolar code.
pub fn quantize_unipolar(v: f64, bits: u32) -> u32 {
    let levels = (1u64 << bits) as f64;
    let q = (v.clamp(0.0, 1.0) * levels).round() as u64;
    q.min((1u64 << bits) - 1) as u32
}

/// Quantize a real value in [-1, 1] to an `bits`-bit code under *bipolar*
/// encoding: value v ↦ probability (v+1)/2 ↦ code.
pub fn quantize_bipolar(v: f64, bits: u32) -> u32 {
    quantize_unipolar((v.clamp(-1.0, 1.0) + 1.0) / 2.0, bits)
}

/// The unipolar value an `bits`-bit code represents (code / 2^bits).
pub fn dequantize_unipolar(code: u32, bits: u32) -> f64 {
    code as f64 / (1u64 << bits) as f64
}

/// The bipolar value an `bits`-bit code represents.
pub fn dequantize_bipolar(code: u32, bits: u32) -> f64 {
    2.0 * dequantize_unipolar(code, bits) - 1.0
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn quantize_roundtrip_unipolar() {
        for bits in [3u32, 8] {
            for code in 0..(1u32 << bits) {
                let v = dequantize_unipolar(code, bits);
                assert_eq!(quantize_unipolar(v, bits), code);
            }
        }
    }

    #[test]
    fn quantize_bipolar_endpoints() {
        assert_eq!(quantize_bipolar(-1.0, 8), 0);
        assert_eq!(quantize_bipolar(1.0, 8), 255);
        // Bipolar zero sits at mid-code.
        assert_eq!(quantize_bipolar(0.0, 8), 128);
    }

    #[test]
    fn quantize_clamps() {
        assert_eq!(quantize_unipolar(2.0, 4), 15);
        assert_eq!(quantize_unipolar(-1.0, 4), 0);
        assert_eq!(quantize_bipolar(5.0, 4), 15);
    }
}
