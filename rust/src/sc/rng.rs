//! Shared deterministic RNG helpers for the SC datapath, tests, and benches.
//!
//! Before this module existed the xorshift/splitmix kernels were copy-pasted
//! in three places (`benches/hotpath.rs`, the `sc::bitstream` tests, and the
//! lane seeding in `accel::network`); they are now defined once here. All
//! generators are tiny, allocation-free, and bit-reproducible across
//! platforms — the stochastic forward's bit-exactness guarantee rests on
//! these exact update rules, so **do not change the constants or the shift
//! triples** without regenerating every golden vector.

/// Weyl increment of splitmix64 (also the lane-spreading multiplier).
pub const GOLDEN_GAMMA: u64 = 0x9E37_79B9_7F4A_7C15;

/// The splitmix64 finalizer (Stafford mix13): a strong 64→64 bit mixer.
#[inline]
pub fn mix64(mut x: u64) -> u64 {
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// One xorshift64 step (shift triple 13/7/17). The all-zero state is a
/// fixed point; seed through [`XorShift64::new`] or [`lane_state`].
#[inline]
pub fn xorshift64_step(mut s: u64) -> u64 {
    s ^= s << 13;
    s ^= s >> 7;
    s ^= s << 17;
    s
}

/// Derive the xorshift state for one operand lane: splitmix-scrambled
/// `base ^ lane·γ`, forced odd so the state is never zero. This is the
/// per-PCC decorrelated-RNS abstraction of `accel::network` (DESIGN.md
/// §Substitutions) — consecutive lanes land far apart in the sequence.
#[inline]
pub fn lane_state(base: u64, lane: u64) -> u64 {
    mix64(base ^ lane.wrapping_mul(GOLDEN_GAMMA)) | 1
}

/// xorshift64 PRNG (13/7/17), the workhorse stream generator.
#[derive(Debug, Clone)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    /// Seeded generator; zero seeds are nudged to 1 (xorshift fixed point).
    pub fn new(seed: u64) -> Self {
        XorShift64 { state: seed.max(1) }
    }

    /// Generator from a pre-scrambled nonzero state (e.g. [`lane_state`]).
    pub fn from_state(state: u64) -> Self {
        debug_assert!(state != 0, "xorshift64 cannot run from the zero state");
        XorShift64 { state }
    }

    /// Next 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = xorshift64_step(self.state);
        self.state
    }

    /// Next 32-bit value (low half — matches the lane-stream comparators).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        self.next_u64() as u32
    }
}

/// splitmix64 PRNG — used to derive independent seeds from one master seed.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Seeded generator (any seed is fine, including 0).
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Next 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(GOLDEN_GAMMA);
        mix64(self.state)
    }
}

/// Deterministic per-site standard normal via splitmix + Box–Muller
/// (the analytic SC sampling-noise model of `ForwardMode::NoisyExpectation`).
pub fn gauss(site: u32, stream: u32) -> f64 {
    let key = ((site as u64) << 32) | stream as u64;
    let s = mix64(key.wrapping_mul(GOLDEN_GAMMA));
    let u1 = ((s >> 11) as f64 / (1u64 << 53) as f64).max(1e-12);
    let u2 = (s & 0xFFFF_FFFF) as f64 / 4294967296.0;
    (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_matches_reference_vector() {
        // Known-answer test for splitmix64 seeded with 0 (Vigna's reference).
        let mut g = SplitMix64::new(0);
        assert_eq!(g.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(g.next_u64(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(g.next_u64(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn xorshift_matches_reference_vector() {
        let mut g = XorShift64::new(1);
        assert_eq!(g.next_u64(), 0x4082_2041);
        assert_eq!(g.next_u64(), 0x1000_4106_0C01_1441);
        assert_eq!(g.next_u64(), 0x9B1E_842F_6E86_2629);
    }

    #[test]
    fn zero_seed_is_nudged() {
        let mut g = XorShift64::new(0);
        assert_ne!(g.next_u64(), 0);
    }

    #[test]
    fn lane_state_is_odd_and_spread() {
        for lane in 0..64u64 {
            let s = lane_state(7, lane);
            assert_eq!(s & 1, 1);
        }
        // Adjacent lanes decorrelate: top halves differ.
        assert_ne!(lane_state(7, 0) >> 32, lane_state(7, 1) >> 32);
    }

    #[test]
    fn mix64_known_point() {
        // mix64 is a bijection with 0 as a fixed point (why lane_state or-s 1).
        assert_eq!(mix64(0), 0);
        assert_ne!(mix64(1), mix64(2));
    }

    #[test]
    fn gauss_is_deterministic_and_roughly_normal() {
        assert_eq!(gauss(3, 5).to_bits(), gauss(3, 5).to_bits());
        let n = 4096;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for i in 0..n {
            let z = gauss(i, 17);
            sum += z;
            sumsq += z * z;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.1, "mean={mean}");
        assert!((var - 1.0).abs() < 0.15, "var={var}");
    }

    #[test]
    fn xorshift_distribution_smoke() {
        let mut g = XorShift64::new(42);
        let n = 1 << 14;
        let ones: u32 = (0..n).map(|_| (g.next_u64() & 1) as u32).sum();
        let frac = ones as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.05, "lsb bias {frac}");
    }
}
