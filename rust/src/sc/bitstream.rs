//! Packed stochastic bitstreams and bit-parallel SC arithmetic.
//!
//! Bitstreams are stored 64 lanes per `u64` word; all SC operations
//! (unipolar AND-multiply, bipolar XNOR-multiply, correlated-OR max) are
//! word-parallel. This is the L3 hot path: the bit-exact SCNN accuracy
//! experiments (Fig. 11/12) and the serving-side validation both run on it.
//!
//! # Fused-kernel API
//!
//! The steady-state inference loop is allocation-free. Three API families
//! support that (EXPERIMENTS.md §Perf has the measured effect):
//!
//! * **word-at-a-time construction** — [`Bitstream::from_fn_words`] builds
//!   64 bits per generator call instead of one ([`Bitstream::from_fn`] stays
//!   as the simple/reference path);
//! * **in-place operators** — [`Bitstream::xnor_into`], [`and_into`],
//!   [`or_into`], [`not_into`] write into a caller-owned output stream,
//!   reusing its buffer (the allocating [`xnor`]/[`and`]/[`or`]/[`not`]
//!   remain for convenience and as the reference semantics);
//! * **fused accumulation** — [`VerticalCounter::add_xnor`] accumulates the
//!   XNOR product of two streams directly into the counter planes with no
//!   intermediate stream, and [`VerticalCounter::add3`] retires three
//!   streams per ripple pass with a 3:2 carry-save step.
//!   [`VerticalCounter::b2s_ones`] then fuses B2S + ReLU-max + S2B into a
//!   single popcount pass so a whole SC neuron runs without materializing
//!   any intermediate bitstream.
//!
//! [`xnor`]: Bitstream::xnor
//! [`and`]: Bitstream::and
//! [`or`]: Bitstream::or
//! [`not`]: Bitstream::not
//! [`and_into`]: Bitstream::and_into
//! [`or_into`]: Bitstream::or_into
//! [`not_into`]: Bitstream::not_into

/// A fixed-length stochastic bitstream (bit t = value of the stream at
/// clock cycle t). Trailing bits of the last word are kept at zero.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Bitstream {
    words: Vec<u64>,
    len: usize,
}

impl Bitstream {
    /// All-zero stream of `len` bits.
    pub fn zeros(len: usize) -> Self {
        Bitstream { words: vec![0; len.div_ceil(64)], len }
    }

    /// All-one stream of `len` bits.
    pub fn ones(len: usize) -> Self {
        let mut b = Bitstream { words: vec![!0u64; len.div_ceil(64)], len };
        b.mask_tail();
        b
    }

    /// Build from a bit-generator called once per cycle.
    pub fn from_fn(len: usize, mut f: impl FnMut(usize) -> bool) -> Self {
        let mut b = Bitstream::zeros(len);
        for t in 0..len {
            if f(t) {
                b.set(t, true);
            }
        }
        b
    }

    /// Build from a word-generator called once per 64 cycles: `f(w)` returns
    /// the packed bits for cycles `64w..64w+64` (bit i of the word = cycle
    /// `64w+i`). Surplus tail bits are masked off. This is the fast path for
    /// stream generators that can produce whole words (SNG lanes, constant
    /// patterns) — one call per 64 cycles instead of one per cycle.
    pub fn from_fn_words(len: usize, mut f: impl FnMut(usize) -> u64) -> Self {
        let n_words = len.div_ceil(64);
        let mut words = Vec::with_capacity(n_words);
        for w in 0..n_words {
            words.push(f(w));
        }
        let mut b = Bitstream { words, len };
        b.mask_tail();
        b
    }

    /// Refill this stream in place from a word-generator (same contract as
    /// [`from_fn_words`], reusing the existing buffer — no allocation when
    /// the word count is unchanged).
    ///
    /// [`from_fn_words`]: Bitstream::from_fn_words
    pub fn fill_from_fn_words(&mut self, len: usize, mut f: impl FnMut(usize) -> u64) {
        let n_words = len.div_ceil(64);
        self.words.clear();
        self.words.reserve(n_words);
        for w in 0..n_words {
            self.words.push(f(w));
        }
        self.len = len;
        self.mask_tail();
    }

    /// Build from a slice of bools.
    pub fn from_bits(bits: &[bool]) -> Self {
        Bitstream::from_fn(bits.len(), |t| bits[t])
    }

    /// Length in cycles.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the stream has zero length.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The packed words (trailing bits zero).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Bit at cycle `t`.
    pub fn get(&self, t: usize) -> bool {
        assert!(t < self.len);
        (self.words[t / 64] >> (t % 64)) & 1 == 1
    }

    /// Set bit at cycle `t`.
    pub fn set(&mut self, t: usize, v: bool) {
        assert!(t < self.len);
        let (w, s) = (t / 64, t % 64);
        if v {
            self.words[w] |= 1 << s;
        } else {
            self.words[w] &= !(1 << s);
        }
    }

    fn mask_tail(&mut self) {
        let rem = self.len % 64;
        if rem != 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= (1u64 << rem) - 1;
            }
        }
    }

    /// Number of '1' bits.
    pub fn count_ones(&self) -> u32 {
        self.words.iter().map(|w| w.count_ones()).sum()
    }

    /// Unipolar value: P(1) = ones / len.
    pub fn value_unipolar(&self) -> f64 {
        self.count_ones() as f64 / self.len as f64
    }

    /// Bipolar value: 2·P(1) − 1.
    pub fn value_bipolar(&self) -> f64 {
        2.0 * self.value_unipolar() - 1.0
    }

    fn zip(&self, other: &Self, f: impl Fn(u64, u64) -> u64) -> Self {
        assert_eq!(self.len, other.len, "bitstream length mismatch");
        let mut out = Bitstream {
            words: self.words.iter().zip(&other.words).map(|(&a, &b)| f(a, b)).collect(),
            len: self.len,
        };
        out.mask_tail();
        out
    }

    /// In-place variant of [`zip`](Bitstream::zip): writes into `out`,
    /// resizing its buffer only when the word count changed.
    fn zip_into(&self, other: &Self, out: &mut Self, f: impl Fn(u64, u64) -> u64) {
        assert_eq!(self.len, other.len, "bitstream length mismatch");
        out.len = self.len;
        out.words.resize(self.words.len(), 0);
        for ((o, &a), &b) in out.words.iter_mut().zip(&self.words).zip(&other.words) {
            *o = f(a, b);
        }
        out.mask_tail();
    }

    /// Bitwise AND — unipolar SC multiply (Fig. 1a).
    pub fn and(&self, other: &Self) -> Self {
        self.zip(other, |a, b| a & b)
    }

    /// Bitwise OR — scaled-add for independent streams, *max* for fully
    /// correlated streams (the ReLU/MP trick of [29], Fig. 2).
    pub fn or(&self, other: &Self) -> Self {
        self.zip(other, |a, b| a | b)
    }

    /// Bitwise XNOR — bipolar SC multiply (Fig. 1b).
    pub fn xnor(&self, other: &Self) -> Self {
        self.zip(other, |a, b| !(a ^ b))
    }

    /// Bitwise XOR.
    pub fn xor(&self, other: &Self) -> Self {
        self.zip(other, |a, b| a ^ b)
    }

    /// Bitwise NOT — computes 1−p (unipolar) / −v (bipolar).
    pub fn not(&self) -> Self {
        let mut out =
            Bitstream { words: self.words.iter().map(|&w| !w).collect(), len: self.len };
        out.mask_tail();
        out
    }

    /// Allocation-free [`and`](Bitstream::and): result written into `out`.
    pub fn and_into(&self, other: &Self, out: &mut Self) {
        self.zip_into(other, out, |a, b| a & b);
    }

    /// Allocation-free [`or`](Bitstream::or): result written into `out`.
    pub fn or_into(&self, other: &Self, out: &mut Self) {
        self.zip_into(other, out, |a, b| a | b);
    }

    /// Allocation-free [`xnor`](Bitstream::xnor): result written into `out`.
    pub fn xnor_into(&self, other: &Self, out: &mut Self) {
        self.zip_into(other, out, |a, b| !(a ^ b));
    }

    /// Allocation-free [`xor`](Bitstream::xor): result written into `out`.
    pub fn xor_into(&self, other: &Self, out: &mut Self) {
        self.zip_into(other, out, |a, b| a ^ b);
    }

    /// Allocation-free [`not`](Bitstream::not): result written into `out`.
    pub fn not_into(&self, out: &mut Self) {
        out.len = self.len;
        out.words.resize(self.words.len(), 0);
        for (o, &a) in out.words.iter_mut().zip(&self.words) {
            *o = !a;
        }
        out.mask_tail();
    }

    /// Stochastic cross-correlation (SCC) of two streams [26]:
    /// +1 = fully correlated, 0 = independent, −1 = anti-correlated.
    pub fn scc(&self, other: &Self) -> f64 {
        assert_eq!(self.len, other.len);
        let n = self.len as f64;
        let p1 = self.value_unipolar();
        let p2 = other.value_unipolar();
        let p11 = self.and(other).count_ones() as f64 / n;
        let delta = p11 - p1 * p2;
        let denom = if delta > 0.0 {
            p1.min(p2) - p1 * p2
        } else {
            p1 * p2 - (p1 + p2 - 1.0).max(0.0)
        };
        if denom.abs() < 1e-12 {
            0.0
        } else {
            delta / denom
        }
    }
}

/// Bit-sliced vertical counter: accumulates per-cycle population counts of
/// many parallel streams without unpacking bits.
///
/// This is the software analogue of the APC's parallel-counter front end:
/// after `add`-ing every product stream of a neuron, `count_at(t)` is
/// exactly the APC input count at cycle `t`, and the whole structure costs
/// O(words × planes) per stream instead of O(bits).
///
/// Planes are stored in one flat allocation (plane-major), so a counter can
/// be [`reset`](VerticalCounter::reset) and reused across neurons with zero
/// further allocation — the backbone of the fused stochastic forward.
#[derive(Debug, Clone)]
pub struct VerticalCounter {
    /// Flat plane storage: plane `p` occupies
    /// `planes[p·words_per_plane .. (p+1)·words_per_plane]`; bit `t%64` of
    /// word `t/64` in plane `p` is bit `p` of the per-cycle count at `t`.
    planes: Vec<u64>,
    words_per_plane: usize,
    n_planes: usize,
    len: usize,
    added: usize,
}

impl Default for VerticalCounter {
    /// An empty zero-capacity counter (reconfigure before use).
    fn default() -> Self {
        VerticalCounter::new(0, 0)
    }
}

impl VerticalCounter {
    /// Counter for streams of `len` cycles, able to count up to
    /// `max_count` streams.
    pub fn new(len: usize, max_count: usize) -> Self {
        let mut vc = VerticalCounter {
            planes: Vec::new(),
            words_per_plane: 0,
            n_planes: 0,
            len: 0,
            added: 0,
        };
        vc.reconfigure(len, max_count);
        vc
    }

    /// Re-dimension for a new stream length / capacity, reusing the existing
    /// allocation when it is large enough, and clear all counts.
    pub fn reconfigure(&mut self, len: usize, max_count: usize) {
        let bits = (usize::BITS - max_count.leading_zeros()) as usize; // ceil(log2(max+1))
        self.words_per_plane = len.div_ceil(64);
        self.n_planes = bits;
        self.len = len;
        self.added = 0;
        self.planes.clear();
        self.planes.resize(self.words_per_plane * bits, 0);
    }

    /// Clear all counts, keeping dimensions and allocation.
    pub fn reset(&mut self) {
        self.planes.fill(0);
        self.added = 0;
    }

    /// Number of streams added so far.
    pub fn added(&self) -> usize {
        self.added
    }

    /// Stream length in cycles.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no cycles are tracked.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of count bit-planes.
    pub fn planes(&self) -> usize {
        self.n_planes
    }

    #[inline]
    fn bump_added(&mut self, by: usize) {
        self.added += by;
        assert!(
            self.n_planes >= usize::BITS as usize
                || (1usize << self.n_planes) > self.added,
            "VerticalCounter overflow: {} streams exceed {} planes",
            self.added,
            self.n_planes
        );
    }

    /// Ripple-insert a word of weight-`2^p` bits at word index `w`,
    /// starting at plane `p`.
    #[inline]
    fn ripple(&mut self, w: usize, mut carry: u64, mut p: usize) {
        while carry != 0 {
            debug_assert!(p < self.n_planes, "ripple past the top plane");
            let idx = p * self.words_per_plane + w;
            let new_carry = self.planes[idx] & carry;
            self.planes[idx] ^= carry;
            carry = new_carry;
            p += 1;
        }
    }

    /// Mask for the (possibly partial) final word.
    #[inline]
    fn tail_mask(&self) -> u64 {
        let rem = self.len % 64;
        if rem == 0 {
            !0u64
        } else {
            (1u64 << rem) - 1
        }
    }

    /// Add one stream to the per-cycle counts (ripple-carry across planes).
    pub fn add(&mut self, bs: &Bitstream) {
        assert_eq!(bs.len(), self.len, "stream length mismatch");
        self.bump_added(1);
        for (w, &bits) in bs.words().iter().enumerate() {
            self.ripple(w, bits, 0);
        }
    }

    /// Fused XNOR-accumulate: add the bipolar product stream `a XNOR b`
    /// without materializing it (`vc.add_xnor(a, b) ≡ vc.add(&a.xnor(b))`,
    /// with zero intermediate allocation).
    pub fn add_xnor(&mut self, a: &Bitstream, b: &Bitstream) {
        assert_eq!(a.len(), self.len, "stream length mismatch");
        assert_eq!(b.len(), self.len, "stream length mismatch");
        self.add_xnor_words(a.words(), b.words());
    }

    /// Word-slice form of [`add_xnor`](VerticalCounter::add_xnor), for
    /// operands held in flat scratch arenas. Slices must hold exactly the
    /// counter's word count; bits past `len` in the last word are ignored.
    pub fn add_xnor_words(&mut self, a: &[u64], b: &[u64]) {
        assert_eq!(a.len(), self.words_per_plane, "operand word-count mismatch");
        assert_eq!(b.len(), self.words_per_plane, "operand word-count mismatch");
        self.bump_added(1);
        let last = self.words_per_plane.wrapping_sub(1);
        let tail = self.tail_mask();
        for w in 0..self.words_per_plane {
            // XNOR sets the tail garbage bits; mask them on the final word.
            let mut x = !(a[w] ^ b[w]);
            if w == last {
                x &= tail;
            }
            self.ripple(w, x, 0);
        }
    }

    /// Add three streams with one 3:2 carry-save step: the weight-1 sum
    /// `a⊕b⊕c` and the weight-2 majority carry are rippled in together, so
    /// three streams cost roughly one ripple pass instead of three
    /// (`vc.add3(a, b, c) ≡ vc.add(a); vc.add(b); vc.add(c)`).
    pub fn add3(&mut self, a: &Bitstream, b: &Bitstream, c: &Bitstream) {
        assert_eq!(a.len(), self.len, "stream length mismatch");
        assert_eq!(b.len(), self.len, "stream length mismatch");
        assert_eq!(c.len(), self.len, "stream length mismatch");
        self.bump_added(3);
        for w in 0..self.words_per_plane {
            let (aw, bw, cw) = (a.words()[w], b.words()[w], c.words()[w]);
            let sum = aw ^ bw ^ cw;
            let carry = (aw & bw) | (aw & cw) | (bw & cw);
            self.ripple(w, sum, 0);
            self.ripple(w, carry, 1);
        }
    }

    /// Count at cycle `t` (how many added streams had a 1).
    pub fn count_at(&self, t: usize) -> u32 {
        assert!(t < self.len);
        let (w, s) = (t / 64, t % 64);
        (0..self.n_planes)
            .map(|p| (((self.planes[p * self.words_per_plane + w] >> s) & 1) as u32) << p)
            .sum()
    }

    /// Sum of counts over all cycles (= Σ popcount of added streams).
    pub fn total(&self) -> u64 {
        (0..self.n_planes)
            .map(|p| {
                let plane = &self.planes[p * self.words_per_plane..(p + 1) * self.words_per_plane];
                (plane.iter().map(|w| w.count_ones() as u64).sum::<u64>()) << p
            })
            .sum()
    }

    /// Fused B2S → ReLU-max → S2B: the number of cycles where
    /// `max(2·count, floor) > r4[t]` — i.e. the S2B popcount of the neuron
    /// output stream `(2c_t > r4_t) OR (floor > r4_t)`, without building
    /// either stream. Pass `floor = n` for the correlated-OR ReLU of a
    /// fan-in-`n` neuron (Fig. 2), `floor = 0` for no activation.
    pub fn b2s_ones(&self, r4: &[u32], floor: u32) -> u32 {
        assert_eq!(r4.len(), self.len, "random sequence length mismatch");
        let mut ones = 0u32;
        for w in 0..self.words_per_plane {
            let valid = (self.len - w * 64).min(64);
            let base = w * 64;
            for s in 0..valid {
                let mut c = 0u32;
                for p in 0..self.n_planes {
                    c |= (((self.planes[p * self.words_per_plane + w] >> s) & 1) as u32) << p;
                }
                ones += ((2 * c).max(floor) > r4[base + s]) as u32;
            }
        }
        ones
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::sc::rng::XorShift64;

    #[test]
    fn construction_and_counting() {
        let b = Bitstream::from_bits(&[true, false, true, true]);
        assert_eq!(b.len(), 4);
        assert_eq!(b.count_ones(), 3);
        assert!((b.value_unipolar() - 0.75).abs() < 1e-12);
        assert!((b.value_bipolar() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn tail_masking_preserved_by_ops() {
        let a = Bitstream::ones(70);
        let b = Bitstream::ones(70).not();
        assert_eq!(a.count_ones(), 70);
        assert_eq!(b.count_ones(), 0);
        assert_eq!(a.not().count_ones(), 0);
        assert_eq!(a.xnor(&a).count_ones(), 70);
    }

    #[test]
    fn from_fn_words_matches_from_fn() {
        let mut rng = XorShift64::new(99);
        for len in [1usize, 63, 64, 65, 130, 1024] {
            let bits: Vec<bool> = (0..len).map(|_| rng.next_u64() % 2 == 1).collect();
            let a = Bitstream::from_fn(len, |t| bits[t]);
            let b = Bitstream::from_fn_words(len, |w| {
                let mut word = 0u64;
                for (i, &bit) in bits.iter().skip(w * 64).take(64).enumerate() {
                    word |= (bit as u64) << i;
                }
                word
            });
            assert_eq!(a, b, "len={len}");
        }
    }

    #[test]
    fn from_fn_words_masks_surplus_tail_bits() {
        // Generator hands back all-ones words; only `len` bits may survive.
        let b = Bitstream::from_fn_words(70, |_| !0u64);
        assert_eq!(b.count_ones(), 70);
        assert_eq!(b, Bitstream::ones(70));
    }

    #[test]
    fn fill_from_fn_words_reuses_buffer() {
        let mut b = Bitstream::zeros(128);
        b.fill_from_fn_words(70, |_| !0u64);
        assert_eq!(b.len(), 70);
        assert_eq!(b.count_ones(), 70);
        b.fill_from_fn_words(128, |w| if w == 0 { 1 } else { 2 });
        assert_eq!(b.len(), 128);
        assert_eq!(b.count_ones(), 2);
        assert!(b.get(0) && b.get(65));
    }

    #[test]
    fn in_place_ops_match_allocating_ops() {
        let mut rng = XorShift64::new(5);
        for len in [1usize, 64, 100, 257] {
            let a = Bitstream::from_fn(len, |_| rng.next_u64() % 2 == 1);
            let b = Bitstream::from_fn(len, |_| rng.next_u64() % 3 == 0);
            // Start from a deliberately wrong-sized, junk-filled output.
            let mut out = Bitstream::ones(3);
            a.xnor_into(&b, &mut out);
            assert_eq!(out, a.xnor(&b), "xnor len={len}");
            a.and_into(&b, &mut out);
            assert_eq!(out, a.and(&b), "and len={len}");
            a.or_into(&b, &mut out);
            assert_eq!(out, a.or(&b), "or len={len}");
            a.xor_into(&b, &mut out);
            assert_eq!(out, a.xor(&b), "xor len={len}");
            a.not_into(&mut out);
            assert_eq!(out, a.not(), "not len={len}");
        }
    }

    #[test]
    fn unipolar_multiply_with_independent_streams() {
        // Deterministic independent-ish streams via distinct rngs.
        let mut r1 = XorShift64::new(11);
        let mut r2 = XorShift64::new(877);
        let len = 1 << 16;
        let a = Bitstream::from_fn(len, |_| r1.next_u64() % 100 < 40); // p=0.4
        let b = Bitstream::from_fn(len, |_| r2.next_u64() % 100 < 50); // p=0.5
        let prod = a.and(&b).value_unipolar();
        assert!((prod - 0.2).abs() < 0.02, "prod={prod}");
    }

    #[test]
    fn bipolar_multiply_with_xnor() {
        let mut r1 = XorShift64::new(5);
        let mut r2 = XorShift64::new(999);
        let len = 1 << 16;
        // a = +0.5 (p=0.75), b = -0.4 (p=0.3)
        let a = Bitstream::from_fn(len, |_| r1.next_u64() % 100 < 75);
        let b = Bitstream::from_fn(len, |_| r2.next_u64() % 100 < 30);
        let prod = a.xnor(&b).value_bipolar();
        assert!((prod - (-0.2)).abs() < 0.03, "prod={prod}");
    }

    #[test]
    fn correlated_or_is_max() {
        // Same comparator random source ⇒ fully correlated streams.
        let mut rng = XorShift64::new(3);
        let len = 1 << 14;
        let rs: Vec<u64> = (0..len).map(|_| rng.next_u64() % 1000).collect();
        let a = Bitstream::from_fn(len, |t| rs[t] < 300);
        let b = Bitstream::from_fn(len, |t| rs[t] < 700);
        assert!(a.scc(&b) > 0.99);
        let m = a.or(&b).value_unipolar();
        assert!((m - 0.7).abs() < 0.02, "max={m}");
    }

    #[test]
    fn scc_of_independent_streams_near_zero() {
        let mut r1 = XorShift64::new(21);
        let mut r2 = XorShift64::new(77);
        let len = 1 << 16;
        let a = Bitstream::from_fn(len, |_| r1.next_u64() % 2 == 0);
        let b = Bitstream::from_fn(len, |_| r2.next_u64() % 2 == 0);
        assert!(a.scc(&b).abs() < 0.05);
    }

    #[test]
    fn vertical_counter_matches_naive() {
        let mut rng = XorShift64::new(42);
        let len = 130; // crosses word boundaries
        let streams: Vec<Bitstream> =
            (0..25).map(|_| Bitstream::from_fn(len, |_| rng.next_u64() % 3 == 0)).collect();
        let mut vc = VerticalCounter::new(len, 25);
        for s in &streams {
            vc.add(s);
        }
        for t in 0..len {
            let naive: u32 = streams.iter().map(|s| s.get(t) as u32).sum();
            assert_eq!(vc.count_at(t), naive, "cycle {t}");
        }
        let naive_total: u64 = streams.iter().map(|s| s.count_ones() as u64).sum();
        assert_eq!(vc.total(), naive_total);
    }

    #[test]
    fn add_xnor_equals_add_of_xnor() {
        let mut rng = XorShift64::new(7);
        for len in [1usize, 64, 100, 300] {
            let pairs: Vec<(Bitstream, Bitstream)> = (0..9)
                .map(|_| {
                    (
                        Bitstream::from_fn(len, |_| rng.next_u64() % 2 == 1),
                        Bitstream::from_fn(len, |_| rng.next_u64() % 3 != 0),
                    )
                })
                .collect();
            let mut fused = VerticalCounter::new(len, pairs.len());
            let mut composed = VerticalCounter::new(len, pairs.len());
            for (a, b) in &pairs {
                fused.add_xnor(a, b);
                composed.add(&a.xnor(b));
            }
            assert_eq!(fused.added(), composed.added());
            for t in 0..len {
                assert_eq!(fused.count_at(t), composed.count_at(t), "len={len} t={t}");
            }
            assert_eq!(fused.total(), composed.total());
        }
    }

    #[test]
    fn add3_equals_three_adds() {
        let mut rng = XorShift64::new(13);
        for len in [1usize, 65, 192, 200] {
            let ss: Vec<Bitstream> =
                (0..6).map(|_| Bitstream::from_fn(len, |_| rng.next_u64() % 2 == 1)).collect();
            let mut fused = VerticalCounter::new(len, 6);
            let mut plain = VerticalCounter::new(len, 6);
            fused.add3(&ss[0], &ss[1], &ss[2]);
            fused.add3(&ss[3], &ss[4], &ss[5]);
            for s in &ss {
                plain.add(s);
            }
            assert_eq!(fused.added(), plain.added());
            for t in 0..len {
                assert_eq!(fused.count_at(t), plain.count_at(t), "len={len} t={t}");
            }
        }
    }

    #[test]
    fn reset_and_reconfigure_reuse() {
        let mut vc = VerticalCounter::new(100, 10);
        let s = Bitstream::ones(100);
        vc.add(&s);
        assert_eq!(vc.total(), 100);
        vc.reset();
        assert_eq!(vc.added(), 0);
        assert_eq!(vc.total(), 0);
        vc.add(&s);
        assert_eq!(vc.total(), 100);
        // Shrinking reconfigure must fully clear state.
        vc.reconfigure(64, 3);
        assert_eq!(vc.len(), 64);
        assert_eq!(vc.total(), 0);
        vc.add(&Bitstream::ones(64));
        assert_eq!(vc.total(), 64);
    }

    #[test]
    fn b2s_ones_matches_streamed_b2s() {
        let mut rng = XorShift64::new(31);
        let len = 200;
        let n: usize = 7;
        let streams: Vec<Bitstream> =
            (0..n).map(|_| Bitstream::from_fn(len, |_| rng.next_u64() % 2 == 1)).collect();
        let mut vc = VerticalCounter::new(len, n);
        for s in &streams {
            vc.add(s);
        }
        let m1 = usize::BITS - n.leading_zeros() + 1;
        let r4: Vec<u32> =
            (0..len).map(|_| (rng.next_u64() % (1u64 << m1)) as u32).collect();
        // floor = 0: plain B2S.
        let plain = Bitstream::from_fn(len, |t| 2 * vc.count_at(t) > r4[t]);
        assert_eq!(vc.b2s_ones(&r4, 0), plain.count_ones());
        // floor = n: B2S OR the ReLU zero-threshold stream.
        let zero = Bitstream::from_fn(len, |t| n as u32 > r4[t]);
        assert_eq!(vc.b2s_ones(&r4, n as u32), plain.or(&zero).count_ones());
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn length_mismatch_panics() {
        let a = Bitstream::zeros(8);
        let b = Bitstream::zeros(9);
        let _ = a.and(&b);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn counter_overflow_panics() {
        let mut vc = VerticalCounter::new(10, 3);
        let s = Bitstream::ones(10);
        vc.add(&s);
        vc.add(&s);
        vc.add(&s);
        vc.add(&s); // 4 > max_count 3
    }
}
