//! Packed stochastic bitstreams and bit-parallel SC arithmetic.
//!
//! Bitstreams are stored 64 lanes per `u64` word; all SC operations
//! (unipolar AND-multiply, bipolar XNOR-multiply, correlated-OR max) are
//! word-parallel. This is the L3 hot path: the bit-exact SCNN accuracy
//! experiments (Fig. 11/12) and the serving-side validation both run on it.

/// A fixed-length stochastic bitstream (bit t = value of the stream at
/// clock cycle t). Trailing bits of the last word are kept at zero.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Bitstream {
    words: Vec<u64>,
    len: usize,
}

impl Bitstream {
    /// All-zero stream of `len` bits.
    pub fn zeros(len: usize) -> Self {
        Bitstream { words: vec![0; len.div_ceil(64)], len }
    }

    /// All-one stream of `len` bits.
    pub fn ones(len: usize) -> Self {
        let mut b = Bitstream { words: vec![!0u64; len.div_ceil(64)], len };
        b.mask_tail();
        b
    }

    /// Build from a bit-generator called once per cycle.
    pub fn from_fn(len: usize, mut f: impl FnMut(usize) -> bool) -> Self {
        let mut b = Bitstream::zeros(len);
        for t in 0..len {
            if f(t) {
                b.set(t, true);
            }
        }
        b
    }

    /// Build from a slice of bools.
    pub fn from_bits(bits: &[bool]) -> Self {
        Bitstream::from_fn(bits.len(), |t| bits[t])
    }

    /// Length in cycles.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the stream has zero length.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The packed words (trailing bits zero).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Bit at cycle `t`.
    pub fn get(&self, t: usize) -> bool {
        assert!(t < self.len);
        (self.words[t / 64] >> (t % 64)) & 1 == 1
    }

    /// Set bit at cycle `t`.
    pub fn set(&mut self, t: usize, v: bool) {
        assert!(t < self.len);
        let (w, s) = (t / 64, t % 64);
        if v {
            self.words[w] |= 1 << s;
        } else {
            self.words[w] &= !(1 << s);
        }
    }

    fn mask_tail(&mut self) {
        let rem = self.len % 64;
        if rem != 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= (1u64 << rem) - 1;
            }
        }
    }

    /// Number of '1' bits.
    pub fn count_ones(&self) -> u32 {
        self.words.iter().map(|w| w.count_ones()).sum()
    }

    /// Unipolar value: P(1) = ones / len.
    pub fn value_unipolar(&self) -> f64 {
        self.count_ones() as f64 / self.len as f64
    }

    /// Bipolar value: 2·P(1) − 1.
    pub fn value_bipolar(&self) -> f64 {
        2.0 * self.value_unipolar() - 1.0
    }

    fn zip(&self, other: &Self, f: impl Fn(u64, u64) -> u64) -> Self {
        assert_eq!(self.len, other.len, "bitstream length mismatch");
        let mut out = Bitstream {
            words: self.words.iter().zip(&other.words).map(|(&a, &b)| f(a, b)).collect(),
            len: self.len,
        };
        out.mask_tail();
        out
    }

    /// Bitwise AND — unipolar SC multiply (Fig. 1a).
    pub fn and(&self, other: &Self) -> Self {
        self.zip(other, |a, b| a & b)
    }

    /// Bitwise OR — scaled-add for independent streams, *max* for fully
    /// correlated streams (the ReLU/MP trick of [29], Fig. 2).
    pub fn or(&self, other: &Self) -> Self {
        self.zip(other, |a, b| a | b)
    }

    /// Bitwise XNOR — bipolar SC multiply (Fig. 1b).
    pub fn xnor(&self, other: &Self) -> Self {
        self.zip(other, |a, b| !(a ^ b))
    }

    /// Bitwise XOR.
    pub fn xor(&self, other: &Self) -> Self {
        self.zip(other, |a, b| a ^ b)
    }

    /// Bitwise NOT — computes 1−p (unipolar) / −v (bipolar).
    pub fn not(&self) -> Self {
        let mut out =
            Bitstream { words: self.words.iter().map(|&w| !w).collect(), len: self.len };
        out.mask_tail();
        out
    }

    /// Stochastic cross-correlation (SCC) of two streams [26]:
    /// +1 = fully correlated, 0 = independent, −1 = anti-correlated.
    pub fn scc(&self, other: &Self) -> f64 {
        assert_eq!(self.len, other.len);
        let n = self.len as f64;
        let p1 = self.value_unipolar();
        let p2 = other.value_unipolar();
        let p11 = self.and(other).count_ones() as f64 / n;
        let delta = p11 - p1 * p2;
        let denom = if delta > 0.0 {
            p1.min(p2) - p1 * p2
        } else {
            p1 * p2 - (p1 + p2 - 1.0).max(0.0)
        };
        if denom.abs() < 1e-12 {
            0.0
        } else {
            delta / denom
        }
    }
}

/// Bit-sliced vertical counter: accumulates per-cycle population counts of
/// many parallel streams without unpacking bits.
///
/// This is the software analogue of the APC's parallel-counter front end:
/// after `add`-ing every product stream of a neuron, `count_at(t)` is
/// exactly the APC input count at cycle `t`, and the whole structure costs
/// O(words × planes) per stream instead of O(bits).
#[derive(Debug, Clone)]
pub struct VerticalCounter {
    /// planes[p] holds bit p of the per-cycle count, packed like a stream.
    planes: Vec<Vec<u64>>,
    len: usize,
    added: usize,
}

impl VerticalCounter {
    /// Counter for streams of `len` cycles, able to count up to
    /// `max_count` streams.
    pub fn new(len: usize, max_count: usize) -> Self {
        let bits = usize::BITS - max_count.leading_zeros(); // ceil(log2(max+1))
        VerticalCounter {
            planes: vec![vec![0u64; len.div_ceil(64)]; bits as usize],
            len,
            added: 0,
        }
    }

    /// Number of streams added so far.
    pub fn added(&self) -> usize {
        self.added
    }

    /// Stream length in cycles.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no cycles are tracked.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Add one stream to the per-cycle counts (ripple-carry across planes).
    pub fn add(&mut self, bs: &Bitstream) {
        assert_eq!(bs.len(), self.len, "stream length mismatch");
        self.added += 1;
        assert!(
            (1usize << self.planes.len()) > self.added,
            "VerticalCounter overflow: {} streams exceed {} planes",
            self.added,
            self.planes.len()
        );
        for (w, &bits) in bs.words().iter().enumerate() {
            let mut carry = bits;
            for plane in &mut self.planes {
                let new_carry = plane[w] & carry;
                plane[w] ^= carry;
                carry = new_carry;
                if carry == 0 {
                    break;
                }
            }
        }
    }

    /// Count at cycle `t` (how many added streams had a 1).
    pub fn count_at(&self, t: usize) -> u32 {
        assert!(t < self.len);
        let (w, s) = (t / 64, t % 64);
        self.planes
            .iter()
            .enumerate()
            .map(|(p, plane)| (((plane[w] >> s) & 1) as u32) << p)
            .sum()
    }

    /// Sum of counts over all cycles (= Σ popcount of added streams).
    pub fn total(&self) -> u64 {
        self.planes
            .iter()
            .enumerate()
            .map(|(p, plane)| {
                (plane.iter().map(|w| w.count_ones() as u64).sum::<u64>()) << p
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xorshift(seed: u64) -> impl FnMut() -> u64 {
        let mut s = seed.max(1);
        move || {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            s
        }
    }

    #[test]
    fn construction_and_counting() {
        let b = Bitstream::from_bits(&[true, false, true, true]);
        assert_eq!(b.len(), 4);
        assert_eq!(b.count_ones(), 3);
        assert!((b.value_unipolar() - 0.75).abs() < 1e-12);
        assert!((b.value_bipolar() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn tail_masking_preserved_by_ops() {
        let a = Bitstream::ones(70);
        let b = Bitstream::ones(70).not();
        assert_eq!(a.count_ones(), 70);
        assert_eq!(b.count_ones(), 0);
        assert_eq!(a.not().count_ones(), 0);
        assert_eq!(a.xnor(&a).count_ones(), 70);
    }

    #[test]
    fn unipolar_multiply_with_independent_streams() {
        // Deterministic independent-ish streams via distinct rngs.
        let mut r1 = xorshift(11);
        let mut r2 = xorshift(877);
        let len = 1 << 16;
        let a = Bitstream::from_fn(len, |_| r1() % 100 < 40); // p=0.4
        let b = Bitstream::from_fn(len, |_| r2() % 100 < 50); // p=0.5
        let prod = a.and(&b).value_unipolar();
        assert!((prod - 0.2).abs() < 0.02, "prod={prod}");
    }

    #[test]
    fn bipolar_multiply_with_xnor() {
        let mut r1 = xorshift(5);
        let mut r2 = xorshift(999);
        let len = 1 << 16;
        // a = +0.5 (p=0.75), b = -0.4 (p=0.3)
        let a = Bitstream::from_fn(len, |_| r1() % 100 < 75);
        let b = Bitstream::from_fn(len, |_| r2() % 100 < 30);
        let prod = a.xnor(&b).value_bipolar();
        assert!((prod - (-0.2)).abs() < 0.03, "prod={prod}");
    }

    #[test]
    fn correlated_or_is_max() {
        // Same comparator random source ⇒ fully correlated streams.
        let mut rng = xorshift(3);
        let len = 1 << 14;
        let rs: Vec<u64> = (0..len).map(|_| rng() % 1000).collect();
        let a = Bitstream::from_fn(len, |t| rs[t] < 300);
        let b = Bitstream::from_fn(len, |t| rs[t] < 700);
        assert!(a.scc(&b) > 0.99);
        let m = a.or(&b).value_unipolar();
        assert!((m - 0.7).abs() < 0.02, "max={m}");
    }

    #[test]
    fn scc_of_independent_streams_near_zero() {
        let mut r1 = xorshift(21);
        let mut r2 = xorshift(77);
        let len = 1 << 16;
        let a = Bitstream::from_fn(len, |_| r1() % 2 == 0);
        let b = Bitstream::from_fn(len, |_| r2() % 2 == 0);
        assert!(a.scc(&b).abs() < 0.05);
    }

    #[test]
    fn vertical_counter_matches_naive() {
        let mut rng = xorshift(42);
        let len = 130; // crosses word boundaries
        let streams: Vec<Bitstream> =
            (0..25).map(|_| Bitstream::from_fn(len, |_| rng() % 3 == 0)).collect();
        let mut vc = VerticalCounter::new(len, 25);
        for s in &streams {
            vc.add(s);
        }
        for t in 0..len {
            let naive: u32 = streams.iter().map(|s| s.get(t) as u32).sum();
            assert_eq!(vc.count_at(t), naive, "cycle {t}");
        }
        let naive_total: u64 = streams.iter().map(|s| s.count_ones() as u64).sum();
        assert_eq!(vc.total(), naive_total);
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn length_mismatch_panics() {
        let a = Bitstream::zeros(8);
        let b = Bitstream::zeros(9);
        let _ = a.and(&b);
    }
}
