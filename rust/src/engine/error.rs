//! Typed errors for the request path: every way a [`crate::engine::Session`]
//! or [`crate::engine::EnginePool`] can refuse or lose a request, as a
//! matchable enum instead of a panic or an opaque string.
//!
//! The request path never panics: a dead worker, a closed session, a full
//! admission queue, and a poisoned client-side lock all surface as
//! [`EngineError`] variants, so servers can distinguish "back off and retry"
//! ([`EngineError::Rejected`]) from "this shard is gone"
//! ([`EngineError::WorkerDied`]) from "this request was bad"
//! ([`EngineError::Request`]).

use std::fmt;
use std::time::Duration;

/// What went wrong on the request path. Convertible into [`anyhow::Error`]
/// (the crate-wide error type) with `?`, so typed call sites compose with
/// the rest of the codebase; match on it where the variant matters.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EngineError {
    /// The session (or pool) was gracefully closed; no new submissions are
    /// accepted. Previously-submitted work is still drainable.
    Closed,
    /// The worker thread behind the session exited without a graceful
    /// close (backend panic or abnormal shutdown). The session is dead;
    /// a pool marks the shard unhealthy and reroutes.
    WorkerDied,
    /// `drain` was called with nothing outstanding — a protocol misuse
    /// (submit-then-drain pairs are unbalanced), reported instead of
    /// silently returning nothing.
    EmptyQueue,
    /// Admission control shed this request: the global in-flight queue is
    /// full. The hint is a backoff estimate derived from recently observed
    /// service latency — retry after roughly that long.
    Rejected {
        /// Suggested client backoff before retrying.
        retry_after_hint: Duration,
    },
    /// Every shard of the pool is unhealthy (all workers died); nothing
    /// can serve the request.
    NoHealthyShards,
    /// A precision policy failed validation at the config boundary:
    /// `k == 0`, a stage length that is not a multiple of the
    /// [`crate::accel::precision::WORD`]-cycle word, a per-layer plan of
    /// the wrong length, or an out-of-range autotune budget. The payload
    /// is the rendered [`crate::accel::precision::PrecisionError`].
    InvalidPrecision(String),
    /// A sparsity policy failed validation at the config boundary: a
    /// negative, non-finite, or ≥ 1.0 threshold (see
    /// [`crate::accel::network::SparsityPolicy::validate`]), or a
    /// threshold that prunes some channel's fan-in to zero at plan
    /// compile. The payload is the rendered reason.
    InvalidSparsity(String),
    /// A client-side lock was poisoned by a panicking sibling thread. The
    /// payload names the lock.
    LockPoisoned(&'static str),
    /// The client-side deadline configured via
    /// `EngineConfig::with_deadline` elapsed before the worker responded.
    /// The request itself is NOT cancelled — the worker still serves it
    /// and frees its admission slot — but this caller stops waiting. The
    /// shard is not presumed dead (see [`EngineError::is_shard_fatal`]).
    Timeout {
        /// How long the caller waited before giving up.
        elapsed: Duration,
    },
    /// The configuration failed the [`crate::analyze`] static pre-flight
    /// at session open: at least one `Error`-severity diagnostic (stream
    /// correlation, counter overflow, dataflow, degrade-policy...) proves
    /// the datapath would misbehave. The payload is the analyzer's
    /// error summary (`; `-joined coded diagnostics). Warnings never
    /// produce this — they surface in `SessionMetrics::analysis_warnings`.
    Analysis(String),
    /// The request reached a live backend and failed there (malformed
    /// input, executable error). The payload preserves the backend's
    /// message.
    Request(String),
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EngineError::Closed => write!(f, "engine session closed (submit after close)"),
            EngineError::WorkerDied => write!(f, "engine worker thread died"),
            EngineError::EmptyQueue => {
                write!(f, "drain called with no outstanding submissions")
            }
            EngineError::Rejected { retry_after_hint } => write!(
                f,
                "request shed by admission control (queue full); retry after ~{} µs",
                retry_after_hint.as_micros()
            ),
            EngineError::NoHealthyShards => {
                write!(f, "no healthy shards available to serve the request")
            }
            EngineError::InvalidPrecision(what) => {
                write!(f, "invalid precision policy: {what}")
            }
            EngineError::InvalidSparsity(what) => {
                write!(f, "invalid sparsity policy: {what}")
            }
            EngineError::LockPoisoned(what) => {
                write!(f, "lock poisoned by a panicked client thread: {what}")
            }
            EngineError::Timeout { elapsed } => write!(
                f,
                "request deadline exceeded after {} µs",
                elapsed.as_micros()
            ),
            EngineError::Analysis(what) => {
                write!(f, "configuration failed static analysis: {what}")
            }
            EngineError::Request(msg) => write!(f, "request failed: {msg}"),
        }
    }
}

impl std::error::Error for EngineError {}

impl From<EngineError> for anyhow::Error {
    fn from(e: EngineError) -> Self {
        anyhow::Error::msg(e)
    }
}

impl EngineError {
    /// Fold an [`anyhow::Error`] from a session call back into the typed
    /// space. The vendored `anyhow` stand-in renders errors to strings (no
    /// downcasting), so the two lifecycle variants are recognized by
    /// **exact** display equality — the session emits them unwrapped, and
    /// backend failures are always prefixed (`batch failed: ...`), so a
    /// request-level error merely *containing* a lifecycle phrase cannot
    /// be misclassified as a dead shard. Everything else is preserved as
    /// [`EngineError::Request`]. Used by the pool when a session reported
    /// through the crate-wide error type.
    pub fn from_request(e: anyhow::Error) -> Self {
        let msg = e.to_string();
        if msg == EngineError::WorkerDied.to_string() {
            EngineError::WorkerDied
        } else if msg == EngineError::Closed.to_string() {
            EngineError::Closed
        } else if let Some(us) = msg
            .strip_prefix("request deadline exceeded after ")
            .and_then(|rest| rest.strip_suffix(" µs"))
            .and_then(|n| n.parse::<u64>().ok())
        {
            // Timeout carries a variable elapsed time, so it is recognized
            // by its unambiguous prefix/suffix frame rather than exact
            // equality; a backend message would arrive prefixed.
            EngineError::Timeout { elapsed: Duration::from_micros(us) }
        } else {
            EngineError::Request(msg)
        }
    }

    /// True for the variants that mean the serving shard itself is gone
    /// (as opposed to this one request being bad or shed).
    pub fn is_shard_fatal(&self) -> bool {
        matches!(self, EngineError::Closed | EngineError::WorkerDied)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn displays_are_distinct_and_informative() {
        let variants: Vec<EngineError> = vec![
            EngineError::Closed,
            EngineError::WorkerDied,
            EngineError::EmptyQueue,
            EngineError::Rejected { retry_after_hint: Duration::from_micros(250) },
            EngineError::NoHealthyShards,
            EngineError::InvalidPrecision("k = 100 is not a multiple of 8".into()),
            EngineError::InvalidSparsity("sparsity threshold must be < 1.0, got 1.5".into()),
            EngineError::LockPoisoned("results"),
            EngineError::Timeout { elapsed: Duration::from_micros(5000) },
            EngineError::Analysis("error[SC001] stage 0: aliased weight-lane keys".into()),
            EngineError::Request("bad image".into()),
        ];
        let mut seen = std::collections::HashSet::new();
        for v in &variants {
            assert!(seen.insert(v.to_string()), "duplicate display for {v:?}");
        }
        assert!(EngineError::Rejected { retry_after_hint: Duration::from_micros(250) }
            .to_string()
            .contains("250"));
    }

    #[test]
    fn converts_into_anyhow_preserving_message() {
        let e: anyhow::Error = EngineError::WorkerDied.into();
        assert!(e.to_string().contains("worker thread died"));
        let folded = EngineError::from_request(anyhow::anyhow!("boom"));
        assert_eq!(folded, EngineError::Request("boom".into()));
        // The lifecycle variants round-trip through the string error type.
        assert_eq!(
            EngineError::from_request(EngineError::WorkerDied.into()),
            EngineError::WorkerDied
        );
        assert_eq!(
            EngineError::from_request(EngineError::Closed.into()),
            EngineError::Closed
        );
        // A request-level error merely *mentioning* a lifecycle phrase is
        // NOT misclassified as a dead shard (exact match, not contains).
        let wrapped =
            anyhow::anyhow!("batch failed: downstream engine worker thread died mid-call");
        assert!(matches!(EngineError::from_request(wrapped), EngineError::Request(_)));
        // Timeout round-trips with its elapsed time intact...
        let t = EngineError::Timeout { elapsed: Duration::from_micros(1234) };
        assert_eq!(EngineError::from_request(t.clone().into()), t);
        // ...and a message merely containing the phrase stays a Request.
        let fake = anyhow::anyhow!("batch failed: request deadline exceeded after 9 µs");
        assert!(matches!(EngineError::from_request(fake), EngineError::Request(_)));
    }

    #[test]
    fn shard_fatal_classification() {
        assert!(EngineError::Closed.is_shard_fatal());
        assert!(EngineError::WorkerDied.is_shard_fatal());
        assert!(!EngineError::Request("x".into()).is_shard_fatal());
        assert!(
            !EngineError::Rejected { retry_after_hint: Duration::ZERO }.is_shard_fatal()
        );
        // A deadline miss says nothing about shard health.
        assert!(!EngineError::Timeout { elapsed: Duration::from_millis(5) }.is_shard_fatal());
    }
}
