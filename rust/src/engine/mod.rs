//! The unified inference engine: **every** way to run the SCNN — fused
//! bit-exact stochastic, per-bit golden reference, analytic expectation /
//! noisy-expectation / fixed-point, and the PJRT executable ladder — behind
//! one [`Session`] opened from one typed [`EngineConfig`], and scaled out
//! behind one [`EnginePool`] of session shards.
//!
//! ```text
//! EngineConfig ──Engine::open──▶ Session ──▶ worker thread
//!   backend kind                   │            │ Box<dyn Backend>
//!   net + weights                  │ infer      │   StochasticFused
//!   k / bits / seed                │ infer_batch│   ReferencePerBit
//!   threads / batch policy         │ submit     │   Expectation(+noisy/fixed)
//!   tech / channels                │ drain      │   Xla (PJRT ladder)
//!                                  ▼            ▼
//!                             SessionMetrics (latency histogram,
//!                             throughput, modeled energy/area)
//!
//! PoolConfig ──EnginePool::open──▶ router ──▶ shard 0: Session
//!   N shard configs                 │    └──▶ shard 1: Session ...
//!   placement policy                └─ admission control, reroute,
//!   global queue depth                 PoolMetrics (merged)
//! ```
//!
//! # Why a session object
//!
//! The compiled state behind an inference — gather tables, layer randoms,
//! every weight SNG stream, PJRT executables — is expensive to build and
//! cheap to reuse. A [`Session`] owns that state on a dedicated worker
//! thread (PJRT handles are not `Send`-safe to share), batches concurrent
//! requests through one dynamic batcher for **every** backend, and carries
//! its own [`SessionMetrics`]: exact latency percentiles, a log₂ histogram,
//! throughput, and the modeled hardware cost of the run via
//! [`crate::accel::system`].
//!
//! # Request paths
//!
//! * [`Session::infer`] — one blocking request (concurrent callers are
//!   coalesced by the batcher);
//! * [`Session::infer_batch`] — a whole slice, pipelined through the
//!   batcher, results in input order;
//! * [`Session::submit`] / [`Session::drain`] — the streaming serve path:
//!   `submit` enqueues without waiting (blocking only when
//!   `BatchPolicy::queue_depth` requests are already in flight —
//!   backpressure), `drain` collects every outstanding result in
//!   submission order.
//!
//! # Session lifecycle (the streaming state machine)
//!
//! ```text
//!            submit/infer                close()              queue empty
//! Open ────────────────────▶ Serving ─────────────▶ Draining ───────────▶ Closed
//!   │                           │                      │
//!   └──────── worker panic ─────┴──────────────────────┘─────▶ Dead
//! ```
//!
//! * **Open/Serving** — requests accepted; `submit` blocks only for
//!   per-session backpressure (`BatchPolicy::queue_depth`).
//! * **Draining** ([`Session::close`]) — no new submissions
//!   ([`EngineError::Closed`]); work already queued is still executed and
//!   responded to; `close` returns once the worker has exited. Results
//!   remain collectable via [`Session::drain`]. Idempotent.
//! * **Closed** — `submit`/`infer` return [`EngineError::Closed`];
//!   `drain` still yields previously-completed results, then
//!   [`EngineError::EmptyQueue`].
//! * **Dead** — the worker exited *without* a graceful close (a backend
//!   panic unwound the worker thread). `submit`/`infer` return
//!   [`EngineError::WorkerDied`]; outstanding `drain` items resolve to
//!   per-item `WorkerDied` errors. **Nothing blocks forever**: a worker
//!   exit guard (armed even across panics) wakes every submitter parked on
//!   the backpressure condvar, and `drain` never waits on a channel whose
//!   sender is gone.
//! * `drain` with nothing outstanding is a protocol misuse and returns
//!   [`EngineError::EmptyQueue`] instead of silently succeeding.
//!
//! [`EnginePool`] composes N sessions behind the same contract (plus
//! admission-control shedding via [`EngineError::Rejected`] and automatic
//! rerouting away from Dead shards); see [`pool`].
//!
//! The HTTP front door over a pool lives in [`crate::serve`]; it records
//! per-tenant outcomes here via [`EnginePool::note_tenant`].

#![deny(clippy::unwrap_used)]

pub mod backend;
pub mod config;
pub mod error;
pub mod metrics;
pub mod pool;

pub use crate::accel::network::KernelPath;
pub use crate::accel::precision::{Precision, PrecisionPlan};
pub use backend::Backend;
pub use config::{BackendKind, BatchPolicy, DegradePolicy, EngineConfig, WeightSource};
pub use error::EngineError;
pub use metrics::{
    HardwareEstimate, LatencyHistogram, PoolMetrics, ServeStats, SessionMetrics, TenantStats,
};
pub use pool::{EnginePool, Placement, PoolConfig, PoolTicket, TenantOutcome};

use crate::accel::layers::NetworkSpec;
use crate::tech::TechKind;
use anyhow::{anyhow, Result};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::{Duration, Instant};

/// Argmax over a logit slice (the serving dtype). Delegates to the generic
/// [`crate::accel::network::classify`], so the f32 serving path and the f64
/// datapath can never diverge on tie or NaN handling.
pub fn classify(output: &[f32]) -> usize {
    crate::accel::network::classify(output)
}

/// Lock a client-side mutex, recovering from poisoning. These locks guard
/// short counter/metric/queue updates that stay consistent even when a
/// sibling client thread panicked mid-critical-section, so recovery is
/// strictly better than propagating the panic across the serving process.
pub(crate) fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// The engine entry point: opens [`Session`]s / [`EnginePool`]s and
/// evaluates configurations.
pub struct Engine;

impl Engine {
    /// Open a session: spawn the worker, build the configured backend on
    /// it (compiling plans / executables), and return once it is ready.
    pub fn open(config: EngineConfig) -> Result<Session> {
        Session::open(config)
    }

    /// Open a sharded pool of sessions behind one front door (see
    /// [`EnginePool::open`]).
    pub fn open_pool(config: PoolConfig) -> Result<EnginePool> {
        EnginePool::open(config)
    }

    /// The modeled-hardware estimate for a configuration without opening a
    /// session (`None` for [`BackendKind::Xla`]). This is what `sweep`
    /// iterates over.
    pub fn estimate(config: &EngineConfig) -> Option<HardwareEstimate> {
        config.estimate()
    }
}

/// Handle to one in-flight [`Session::submit`] request.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Ticket(u64);

/// Outcome of a non-blocking [`Session::try_submit`]. The image is handed
/// back on every non-accepted outcome, so callers that probe several
/// sessions (the pool router) move it along without cloning.
#[derive(Debug)]
pub enum TrySubmit {
    /// Queued; collect the result with [`Session::drain`].
    Accepted(Ticket),
    /// The session is at its backpressure bound; the image is returned.
    Full(Vec<f32>),
    /// The session cannot accept (closed, or its worker died); the typed
    /// reason and the image are returned.
    Refused(EngineError, Vec<f32>),
}

/// A classification request travelling to the worker.
struct InferRequest {
    image: Vec<f32>,
    enqueued: Instant,
    respond: mpsc::Sender<Result<Vec<f32>>>,
}

/// What travels over the worker channel: work, or the graceful-shutdown
/// sentinel sent by [`Session::close`].
enum Request {
    Infer(InferRequest),
    Shutdown,
}

/// State shared between the session handle and its worker.
struct Shared {
    recorder: Mutex<Recorder>,
    inflight: Mutex<usize>,
    done: Condvar,
    /// Set by [`Session::close`]: no new submissions.
    closed: AtomicBool,
    /// Set by the worker's exit guard (even across panics): the worker is
    /// gone and nothing will ever release backpressure slots again.
    worker_exited: AtomicBool,
    /// Most recently observed request latency (µs), stored by the worker
    /// as it records metrics — the cheap signal behind the pool's
    /// `retry_after_hint` (no client dally, no recorder lock).
    last_latency_us: AtomicU64,
    /// Client-side deadline misses (see `EngineConfig::with_deadline`).
    /// Counted on the client path, so it lives outside the recorder.
    timeouts: AtomicU64,
}

/// The worker-side metrics recorder.
#[derive(Default)]
struct Recorder {
    serve: ServeStats,
    hist: LatencyHistogram,
    batches: usize,
    rejected: usize,
    failed: usize,
    /// Times the worker swapped in a degraded precision plan after
    /// sustained SLO breaches (see `EngineConfig::with_degrade`).
    degrade_events: usize,
    /// Lane-cycle ops executed by the compiled plan over every served
    /// image (static per-image accounting × images; see
    /// [`crate::engine::backend::Backend::ops_per_image`]).
    ops_executed: u64,
    /// Lane-cycle ops skipped by sparsity over every served image.
    ops_skipped: u64,
}

/// What the worker reports back once its backend is built.
struct BackendInfo {
    name: &'static str,
    in_len: usize,
    out_len: usize,
    /// The resolved per-layer precision plan (None for XLA) — resolved on
    /// the worker (an `Auto` policy runs the tuner there) and reported
    /// back so the session's hardware estimate and introspection see the
    /// same plan the datapath executes.
    precision: Option<PrecisionPlan>,
    /// Per-compute-layer surviving weight-lane density of the compiled
    /// plan (empty = dense), feeding the session's density-aware hardware
    /// estimate.
    densities: Vec<f64>,
}

/// An open inference session: one backend, one dynamic batcher, one
/// metrics recorder. Cheap to share by reference across client threads.
/// See the module docs for the lifecycle state machine.
pub struct Session {
    tx: mpsc::Sender<Request>,
    shared: Arc<Shared>,
    pending: Mutex<VecDeque<(Ticket, mpsc::Receiver<Result<Vec<f32>>>)>>,
    next_ticket: AtomicU64,
    worker: Option<std::thread::JoinHandle<()>>,
    info: BackendInfo,
    /// Inputs for the modeled-hardware estimate (None for XLA), evaluated
    /// lazily on first [`Session::metrics`] — channel characterization is
    /// gate-level-simulation heavy and many sessions never read metrics.
    /// The per-layer precision the estimate is costed at comes from the
    /// worker-resolved plan in [`BackendInfo`].
    estimate_inputs: Option<(TechKind, usize, NetworkSpec)>,
    estimate: OnceLock<Option<HardwareEstimate>>,
    opened: Instant,
    queue_depth: usize,
    /// Client-side wait bound (`EngineConfig::with_deadline`): how long
    /// any blocking wait for a response may last before it resolves to
    /// [`EngineError::Timeout`] instead of parking forever.
    deadline: Option<Duration>,
    /// Warning-severity diagnostics the [`crate::analyze`] pre-flight
    /// raised at open (errors refuse the session instead) — surfaced in
    /// [`SessionMetrics::analysis_warnings`].
    analysis_warnings: usize,
}

impl Session {
    /// Open a session from a validated configuration (see [`Engine::open`]).
    ///
    /// After the cheap shape validation, the [`crate::analyze`] static
    /// pre-flight runs over the resolved configuration (in-process
    /// backends only): any `Error`-severity diagnostic — correlated SNG
    /// streams, an overflowable accumulator, a broken residual, an
    /// incompatible degrade floor — refuses the session with
    /// [`EngineError::Analysis`] before a worker thread is ever spawned.
    /// Warnings are tolerated and counted in
    /// [`SessionMetrics::analysis_warnings`].
    pub fn open(config: EngineConfig) -> Result<Self> {
        config.validate()?;
        let analysis_warnings = if config.backend == BackendKind::Xla {
            0 // the XLA path owns no SC datapath to analyze
        } else {
            let weights = config.resolve_weights()?;
            let resolved = config.resolved_precision(&weights)?;
            let report = crate::analyze::analyze_engine_config(&config, &resolved);
            if report.has_errors() {
                return Err(EngineError::Analysis(report.error_summary()).into());
            }
            report.warning_count()
        };
        let estimate_inputs = if config.backend == BackendKind::Xla {
            None
        } else {
            Some((config.tech, config.channels, config.net.clone()))
        };
        let queue_depth = config.batch.queue_depth.max(1);
        let deadline = config.deadline;
        let shared = Arc::new(Shared {
            recorder: Mutex::new(Recorder::default()),
            inflight: Mutex::new(0),
            done: Condvar::new(),
            closed: AtomicBool::new(false),
            worker_exited: AtomicBool::new(false),
            last_latency_us: AtomicU64::new(0),
            timeouts: AtomicU64::new(0),
        });
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<BackendInfo>>();
        let shared_w = Arc::clone(&shared);
        let worker = std::thread::Builder::new()
            .name("scnn-engine".into())
            .spawn(move || worker_loop(config, rx, shared_w, ready_tx))
            .map_err(|e| anyhow!("spawning engine worker: {e}"))?;
        let info = ready_rx
            .recv()
            .map_err(|_| anyhow!("engine worker died during startup"))??;
        Ok(Session {
            tx,
            shared,
            pending: Mutex::new(VecDeque::new()),
            next_ticket: AtomicU64::new(0),
            worker: Some(worker),
            info,
            estimate_inputs,
            estimate: OnceLock::new(),
            opened: Instant::now(),
            queue_depth,
            deadline,
            analysis_warnings,
        })
    }

    /// Backend label (e.g. `stochastic-fused`).
    pub fn backend(&self) -> &str {
        self.info.name
    }

    /// Expected flattened input length.
    pub fn in_len(&self) -> usize {
        self.info.in_len
    }

    /// Flattened output length (class count).
    pub fn out_len(&self) -> usize {
        self.info.out_len
    }

    /// The per-layer bitstream lengths this session's datapath executes —
    /// the resolved [`PrecisionPlan`] (including an autotuned one), `None`
    /// for the XLA backend. What the hardware estimate is costed at.
    pub fn precision(&self) -> Option<&PrecisionPlan> {
        self.info.precision.as_ref()
    }

    /// True once [`Session::close`] has been called (the session accepts no
    /// new submissions).
    pub fn is_closed(&self) -> bool {
        self.shared.closed.load(Ordering::Acquire)
    }

    /// True while the worker thread is alive. False after a graceful close
    /// completes **or** after an abnormal worker death — combine with
    /// [`Session::is_closed`] to distinguish the two (this is what
    /// [`EnginePool`] does to decide whether to mark a shard unhealthy).
    pub fn worker_alive(&self) -> bool {
        !self.shared.worker_exited.load(Ordering::Acquire)
    }

    /// The most recently observed request latency in µs (0 before any
    /// request completed), as measured by the worker — enqueue to
    /// response, queueing included, client-side dally excluded. Feeds the
    /// pool's shed-backoff hints.
    pub fn last_latency_us(&self) -> u64 {
        self.shared.last_latency_us.load(Ordering::Relaxed)
    }

    /// Block until a backpressure slot frees up, then claim it. Wakes with
    /// a typed error if the session closes or the worker dies while
    /// waiting — never parks forever on a dead worker.
    fn acquire_slot(&self) -> Result<(), EngineError> {
        let mut n = lock_recover(&self.shared.inflight);
        loop {
            if self.shared.closed.load(Ordering::Acquire) {
                return Err(EngineError::Closed);
            }
            if self.shared.worker_exited.load(Ordering::Acquire) {
                return Err(EngineError::WorkerDied);
            }
            if *n < self.queue_depth {
                *n += 1;
                return Ok(());
            }
            n = self.shared.done.wait(n).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// The typed reason a send to the worker failed.
    fn send_failure(&self) -> EngineError {
        if self.shared.closed.load(Ordering::Acquire) {
            EngineError::Closed
        } else {
            EngineError::WorkerDied
        }
    }

    /// Enqueue one request (claiming a backpressure slot) and return the
    /// response channel.
    fn send_request(
        &self,
        image: Vec<f32>,
    ) -> Result<mpsc::Receiver<Result<Vec<f32>>>, EngineError> {
        self.acquire_slot()?;
        let (rtx, rrx) = mpsc::channel();
        let req = Request::Infer(InferRequest { image, enqueued: Instant::now(), respond: rtx });
        if self.tx.send(req).is_err() {
            release_slots(&self.shared, 1);
            return Err(self.send_failure());
        }
        Ok(rrx)
    }

    /// Wait for one response, honoring the session deadline. Without a
    /// deadline this blocks until the worker responds or dies; with one it
    /// resolves to [`EngineError::Timeout`] after `deadline` — the worker
    /// still serves the request and frees its slot, only this caller stops
    /// waiting. A dropped response channel after a graceful close means
    /// the request raced the shutdown sentinel — report Closed, not a
    /// worker death (send_failure makes that distinction).
    fn await_response(&self, rrx: mpsc::Receiver<Result<Vec<f32>>>) -> Result<Vec<f32>> {
        match self.deadline {
            None => rrx
                .recv()
                .map_err(|_| anyhow::Error::from(self.send_failure()))
                .and_then(|r| r),
            Some(d) => {
                let started = Instant::now();
                match rrx.recv_timeout(d) {
                    Ok(r) => r,
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        self.shared.timeouts.fetch_add(1, Ordering::Relaxed);
                        Err(EngineError::Timeout { elapsed: started.elapsed() }.into())
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        Err(self.send_failure().into())
                    }
                }
            }
        }
    }

    /// Classify one image (blocking). Returns the logits. Typed failures
    /// ([`EngineError::Closed`] / [`EngineError::WorkerDied`] /
    /// [`EngineError::Timeout`]) convert into the crate-wide error type.
    pub fn infer(&self, image: Vec<f32>) -> Result<Vec<f32>> {
        let rrx = self.send_request(image)?;
        self.await_response(rrx)
    }

    /// Run a whole slice through the batcher; results in input order. The
    /// images are pipelined (submission overlaps execution), so batches
    /// form even from a single caller thread.
    pub fn infer_batch(&self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let mut receivers = Vec::with_capacity(images.len());
        for img in images {
            receivers.push(self.send_request(img.clone())?);
        }
        let mut outs = Vec::with_capacity(receivers.len());
        for rrx in receivers {
            outs.push(self.await_response(rrx)?);
        }
        Ok(outs)
    }

    /// Non-blocking slot claim: `Ok(false)` instead of parking when the
    /// session is at `queue_depth`.
    fn try_acquire_slot(&self) -> Result<bool, EngineError> {
        let mut n = lock_recover(&self.shared.inflight);
        if self.shared.closed.load(Ordering::Acquire) {
            return Err(EngineError::Closed);
        }
        if self.shared.worker_exited.load(Ordering::Acquire) {
            return Err(EngineError::WorkerDied);
        }
        if *n < self.queue_depth {
            *n += 1;
            Ok(true)
        } else {
            Ok(false)
        }
    }

    /// Enqueue one request without waiting for its result. Blocks only for
    /// backpressure: at most `BatchPolicy::queue_depth` requests may be in
    /// flight. Collect results with [`Session::drain`]. After
    /// [`Session::close`] returns [`EngineError::Closed`]; after an
    /// abnormal worker death returns [`EngineError::WorkerDied`].
    pub fn submit(&self, image: Vec<f32>) -> Result<Ticket, EngineError> {
        self.acquire_slot()?;
        self.register_submit(image).map_err(|(e, _)| e)
    }

    /// Non-blocking [`Session::submit`]: reports [`TrySubmit::Full`] when
    /// the session is at its backpressure bound instead of parking the
    /// caller, and hands the image back on every non-accepted outcome.
    /// The pool's shed-don't-block submit path is built on this.
    pub fn try_submit(&self, image: Vec<f32>) -> TrySubmit {
        match self.try_acquire_slot() {
            Err(e) => return TrySubmit::Refused(e, image),
            Ok(false) => return TrySubmit::Full(image),
            Ok(true) => {}
        }
        match self.register_submit(image) {
            Ok(ticket) => TrySubmit::Accepted(ticket),
            Err((e, image)) => TrySubmit::Refused(e, image),
        }
    }

    /// Shared tail of [`Session::submit`]/[`Session::try_submit`], entered
    /// with a backpressure slot already claimed. A failed send hands the
    /// image back alongside the typed reason.
    fn register_submit(&self, image: Vec<f32>) -> Result<Ticket, (EngineError, Vec<f32>)> {
        // Ticket allocation, channel send, and the pending push happen
        // under one lock so concurrent submitters cannot interleave them —
        // drain()'s submission-order contract depends on pending order
        // matching the worker's arrival order.
        let mut pending = lock_recover(&self.pending);
        let (rtx, rrx) = mpsc::channel();
        let req = Request::Infer(InferRequest { image, enqueued: Instant::now(), respond: rtx });
        if let Err(mpsc::SendError(req)) = self.tx.send(req) {
            drop(pending);
            release_slots(&self.shared, 1);
            let image = match req {
                Request::Infer(r) => r.image,
                Request::Shutdown => Vec::new(), // we only ever send Infer here
            };
            return Err((self.send_failure(), image));
        }
        let ticket = Ticket(self.next_ticket.fetch_add(1, Ordering::Relaxed));
        pending.push_back((ticket, rrx));
        Ok(ticket)
    }

    /// Wait for every outstanding [`Session::submit`] and return the
    /// results in submission order. With nothing outstanding this is a
    /// protocol misuse and returns [`EngineError::EmptyQueue`]. Items whose
    /// worker died before responding resolve to per-item
    /// [`EngineError::WorkerDied`] errors — drain never blocks on a dead
    /// worker.
    #[allow(clippy::type_complexity)]
    pub fn drain(&self) -> Result<Vec<(Ticket, Result<Vec<f32>>)>, EngineError> {
        if lock_recover(&self.pending).is_empty() {
            return Err(EngineError::EmptyQueue);
        }
        let mut done = Vec::new();
        while let Ok(item) = self.drain_one() {
            done.push(item);
        }
        Ok(done)
    }

    /// Pop the **oldest** outstanding submission and wait for its result
    /// (the single-step form of [`Session::drain`]; the pool's ordered
    /// cross-shard drain is built on it). Returns
    /// [`EngineError::EmptyQueue`] when nothing is outstanding; an item
    /// whose worker died resolves to a per-item error, never a hang.
    #[allow(clippy::type_complexity)]
    pub fn drain_one(&self) -> Result<(Ticket, Result<Vec<f32>>), EngineError> {
        // Pop outside the wait so concurrent submitters are not blocked.
        let next = lock_recover(&self.pending).pop_front();
        match next {
            None => Err(EngineError::EmptyQueue),
            Some((ticket, rrx)) => {
                // Closed vs WorkerDied vs Timeout per await_response: an
                // item whose submit raced a graceful close resolves
                // Closed, not as a worker death.
                let res = self.await_response(rrx);
                Ok((ticket, res))
            }
        }
    }

    /// Number of submitted-but-undrained requests.
    pub fn outstanding(&self) -> usize {
        lock_recover(&self.pending).len()
    }

    /// Gracefully close the session (the Draining transition of the state
    /// machine): new submissions are refused with [`EngineError::Closed`],
    /// work already queued is executed and responded to, and this call
    /// returns once the worker thread has exited. Idempotent and safe to
    /// call from any thread; results of earlier submits stay collectable
    /// via [`Session::drain`].
    pub fn close(&self) {
        if !self.shared.closed.swap(true, Ordering::AcqRel) {
            // First closer: wake submitters parked on backpressure so they
            // observe Closed, then send the worker its shutdown sentinel.
            {
                let _g = lock_recover(&self.shared.inflight);
                self.shared.done.notify_all();
            }
            let _ = self.tx.send(Request::Shutdown);
        }
        let mut g = lock_recover(&self.shared.inflight);
        while !self.shared.worker_exited.load(Ordering::Acquire) {
            let (g2, _) = self
                .shared
                .done
                .wait_timeout(g, std::time::Duration::from_millis(20))
                .unwrap_or_else(PoisonError::into_inner);
            g = g2;
        }
    }

    /// Snapshot of this session's metrics. The first call evaluates the
    /// modeled-hardware estimate (cached for the session's lifetime).
    pub fn metrics(&self) -> SessionMetrics {
        let estimate = *self.estimate.get_or_init(|| {
            match (&self.estimate_inputs, &self.info.precision) {
                (Some((tech, channels, net)), Some(plan)) => {
                    Some(HardwareEstimate::for_plan_density(
                        *tech,
                        *channels,
                        plan,
                        net,
                        &self.info.densities,
                    ))
                }
                _ => None,
            }
        });
        let rec = lock_recover(&self.shared.recorder);
        SessionMetrics {
            backend: self.info.name.to_string(),
            requests: rec.serve.count(),
            rejected: rec.rejected,
            failed: rec.failed,
            batches: rec.batches,
            timeouts: self.shared.timeouts.load(Ordering::Relaxed) as usize,
            degrade_events: rec.degrade_events,
            analysis_warnings: self.analysis_warnings,
            ops_executed: rec.ops_executed,
            ops_skipped: rec.ops_skipped,
            wall: self.opened.elapsed(),
            serve: rec.serve.clone(),
            histogram: rec.hist.clone(),
            estimate,
        }
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        // Closing the request channel stops the worker loop.
        let (dummy_tx, _) = mpsc::channel();
        let _ = std::mem::replace(&mut self.tx, dummy_tx);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// One graceful-degradation step: halve every per-layer stage length,
/// keeping each a positive multiple of the precision
/// [`crate::accel::precision::WORD`] and clamping to the policy floor.
/// `None` when the plan is already at the floor everywhere (nothing left
/// to give up).
fn degraded_ks(plan: &PrecisionPlan, min_k: usize) -> Option<Vec<usize>> {
    use crate::accel::precision::WORD;
    let floor = (min_k.max(WORD) / WORD) * WORD;
    let ks: Vec<usize> =
        plan.ks().iter().map(|&k| ((k / 2) / WORD * WORD).max(floor)).collect();
    if ks == plan.ks() {
        None
    } else {
        Some(ks)
    }
}

fn release_slots(shared: &Shared, n: usize) {
    let mut g = lock_recover(&shared.inflight);
    *g = g.saturating_sub(n);
    shared.done.notify_all();
}

/// The worker: builds the backend, then drains the queue in dynamic
/// batches — block for the first request, linger for more, execute,
/// respond. On a [`Request::Shutdown`] sentinel it finishes the batch in
/// hand and exits; on *any* exit (including a panic unwinding out of the
/// backend) the guard below publishes the death and wakes every parked
/// submitter.
fn worker_loop(
    cfg: EngineConfig,
    rx: mpsc::Receiver<Request>,
    shared: Arc<Shared>,
    ready: mpsc::Sender<Result<BackendInfo>>,
) {
    struct ExitGuard(Arc<Shared>);
    impl Drop for ExitGuard {
        fn drop(&mut self) {
            let _g = lock_recover(&self.0.inflight);
            self.0.worker_exited.store(true, Ordering::Release);
            self.0.done.notify_all();
        }
    }
    let _exit = ExitGuard(Arc::clone(&shared));

    let batch_max = cfg.batch.max_batch.max(1);
    let linger = cfg.batch.linger;
    let (mut backend, mut current_plan) = match backend::build(&cfg) {
        Ok((b, precision)) => {
            let info = BackendInfo {
                name: b.name(),
                in_len: b.in_len(),
                out_len: b.out_len(),
                precision: precision.clone(),
                densities: b.stage_densities(),
            };
            let _ = ready.send(Ok(info));
            (b, precision)
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let in_len = backend.in_len();

    // Graceful-degradation state: consecutive SLO breaches, and whether
    // the plan has already hit its floor (no point retrying every batch).
    let mut breaches = 0usize;
    let mut degrade_exhausted = false;
    // Chaos accounting (`EngineConfig::with_chaos_panic_after`).
    let mut served_total = 0usize;

    let mut shutdown = false;
    while !shutdown {
        let first = match rx.recv() {
            Ok(Request::Infer(r)) => r,
            Ok(Request::Shutdown) => break,
            Err(_) => return, // session dropped
        };
        let mut pending = vec![first];
        let deadline = Instant::now() + linger;
        while pending.len() < batch_max {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Request::Infer(r)) => pending.push(r),
                Ok(Request::Shutdown) => {
                    shutdown = true;
                    break;
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }

        // Reject malformed requests individually (typed, so clients and
        // the pool can fold them back into [`EngineError::Request`]
        // without string matching); batch the rest.
        let mut valid: Vec<InferRequest> = Vec::with_capacity(pending.len());
        let mut rejected = 0usize;
        for r in pending {
            if r.image.len() != in_len {
                let e = EngineError::Request(format!(
                    "request image has {} elements, expected {in_len}",
                    r.image.len()
                ));
                let _ = r.respond.send(Err(e.into()));
                rejected += 1;
            } else {
                valid.push(r);
            }
        }
        if rejected > 0 {
            lock_recover(&shared.recorder).rejected += rejected;
            release_slots(&shared, rejected);
        }
        if valid.is_empty() {
            continue;
        }
        let inputs: Vec<Vec<f32>> =
            valid.iter_mut().map(|r| std::mem::take(&mut r.image)).collect();
        let bsz = valid.len();
        // Chaos hook: an injected per-batch stall, for exercising the
        // deadline and shed paths under test without a slow backend.
        if let Some(d) = cfg.chaos_slow {
            std::thread::sleep(d);
        }
        let breached = match backend.infer_batch(&inputs) {
            Ok(outs) if outs.len() == bsz => {
                let ops = backend.ops_per_image();
                let mut rec = lock_recover(&shared.recorder);
                rec.batches += 1;
                rec.ops_executed += ops.0 * bsz as u64;
                rec.ops_skipped += ops.1 * bsz as u64;
                let mut slowest = Duration::ZERO;
                for (r, out) in valid.iter().zip(outs) {
                    // Record before responding: clients may read metrics
                    // right after their reply arrives.
                    let lat = r.enqueued.elapsed();
                    slowest = slowest.max(lat);
                    rec.serve.record(lat, bsz);
                    rec.hist.record_us(lat.as_micros() as u64);
                    shared
                        .last_latency_us
                        .store(lat.as_micros() as u64, Ordering::Relaxed);
                    let _ = r.respond.send(Ok(out));
                }
                cfg.degrade.is_some_and(|p| slowest > p.latency_slo)
            }
            Ok(outs) => {
                lock_recover(&shared.recorder).failed += bsz;
                for r in &valid {
                    let _ = r.respond.send(Err(anyhow!(
                        "backend returned {} outputs for a batch of {bsz}",
                        outs.len()
                    )));
                }
                true
            }
            Err(e) => {
                // Count before responding so a failed run is visible in
                // metrics the moment callers see their errors.
                lock_recover(&shared.recorder).failed += bsz;
                let msg = format!("{e:#}");
                for r in &valid {
                    let _ = r.respond.send(Err(anyhow!("batch failed: {msg}")));
                }
                true
            }
        };
        release_slots(&shared, bsz);
        served_total += bsz;

        // Graceful degradation: after `breach_window` consecutive SLO
        // breaches (or failed batches), swap in a cheaper precision plan —
        // halved per-layer stage lengths, clamped to the policy floor —
        // instead of letting the session miss its SLO indefinitely.
        if let Some(policy) = cfg.degrade {
            breaches = if breached { breaches + 1 } else { 0 };
            if breaches >= policy.breach_window && !degrade_exhausted {
                breaches = 0;
                match current_plan.as_ref().and_then(|p| degraded_ks(p, policy.min_k)) {
                    Some(ks) => {
                        let dcfg =
                            cfg.clone().with_precision(Precision::PerLayer(ks));
                        match backend::build(&dcfg) {
                            Ok((b, plan)) => {
                                backend = b;
                                current_plan = plan;
                                lock_recover(&shared.recorder).degrade_events += 1;
                            }
                            Err(_) => degrade_exhausted = true,
                        }
                    }
                    None => degrade_exhausted = true,
                }
            }
        }

        // Chaos hook: die abnormally after N served requests — while
        // holding the recorder lock, so the chaos tests exercise shard
        // rerouting and client-side lock-poison recovery in one blow.
        if cfg.chaos_panic_after.is_some_and(|n| served_total >= n) {
            let _g = lock_recover(&shared.recorder);
            panic!("chaos: injected worker panic after {served_total} requests");
        }
    }

    // Graceful-close tail: a submit racing with close() may have enqueued
    // behind the shutdown sentinel — refuse those typed instead of leaving
    // their callers to a channel error.
    while let Ok(req) = rx.try_recv() {
        if let Request::Infer(r) = req {
            let _ = r.respond.send(Err(EngineError::Closed.into()));
            release_slots(&shared, 1);
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::accel::layers::{LayerKind, LayerSpec, NetworkSpec};
    use crate::accel::network::{
        ForwardMode, ForwardPlan, LayerWeights, QuantizedWeights, SparsityPolicy,
    };
    use crate::sc::quantize_bipolar;
    use std::time::Duration;

    fn tiny_net() -> NetworkSpec {
        NetworkSpec {
            name: "tiny".into(),
            input: (1, 4, 4),
            layers: vec![LayerSpec {
                kind: LayerKind::Dense { inputs: 16, outputs: 3 },
                relu: false,
            }],
        }
    }

    fn tiny_weights(bits: u32) -> QuantizedWeights {
        let codes: Vec<Vec<u32>> = (0..3)
            .map(|oc| {
                (0..16)
                    .map(|j| quantize_bipolar(((oc * 7 + j) % 11) as f64 / 5.5 - 1.0, bits))
                    .collect()
            })
            .collect();
        QuantizedWeights { bits, layers: vec![LayerWeights { codes, gamma: 1.0, mu: 0.0 }] }
    }

    fn cfg(kind: BackendKind) -> EngineConfig {
        EngineConfig::new(kind, tiny_net()).with_quantized(tiny_weights(8)).with_k(64)
    }

    fn image(phase: usize) -> Vec<f32> {
        (0..16).map(|j| ((j + phase) % 10) as f32 / 10.0).collect()
    }

    #[test]
    fn session_matches_direct_plan() {
        let session = Engine::open(cfg(BackendKind::Expectation)).unwrap();
        assert_eq!(session.backend(), "expectation");
        assert_eq!(session.in_len(), 16);
        assert_eq!(session.out_len(), 3);
        let served = session.infer(image(0)).unwrap();
        let plan = ForwardPlan::new(&tiny_net(), &tiny_weights(8), ForwardMode::Expectation);
        let direct = plan.run(&image(0).iter().map(|&v| v as f64).collect::<Vec<_>>());
        for (s, d) in served.iter().zip(&direct) {
            assert!((*s as f64 - d).abs() < 1e-6, "served {s} direct {d}");
        }
    }

    #[test]
    fn fused_session_is_bit_exact_vs_reference_session() {
        let fused = Engine::open(cfg(BackendKind::StochasticFused)).unwrap();
        let golden = Engine::open(cfg(BackendKind::ReferencePerBit)).unwrap();
        for phase in 0..3 {
            let a = fused.infer(image(phase)).unwrap();
            let b = golden.infer(image(phase)).unwrap();
            assert_eq!(a, b, "phase {phase}");
        }
    }

    #[test]
    fn session_reports_its_resolved_precision_plan() {
        let session = Engine::open(cfg(BackendKind::StochasticFused)).unwrap();
        assert_eq!(
            session.precision().map(PrecisionPlan::ks),
            Some(&[64usize][..]),
            "a uniform k resolves to a uniform plan"
        );
        let per = Engine::open(
            cfg(BackendKind::StochasticFused).with_precision(Precision::PerLayer(vec![48])),
        )
        .unwrap();
        assert_eq!(per.precision().map(PrecisionPlan::ks), Some(&[48usize][..]));
        // The per-layer session is bit-exact vs the reference at the same
        // plan, and its hardware estimate is costed at the plan's k.
        let golden = Engine::open(
            cfg(BackendKind::ReferencePerBit).with_precision(Precision::PerLayer(vec![48])),
        )
        .unwrap();
        for phase in 0..2 {
            assert_eq!(
                per.infer(image(phase)).unwrap(),
                golden.infer(image(phase)).unwrap(),
                "phase {phase}"
            );
        }
        let m = per.metrics();
        assert_eq!(m.estimate.expect("SC backends carry an estimate").k, 48);
    }

    #[test]
    fn infer_batch_preserves_order_and_matches_infer() {
        let session = Engine::open(cfg(BackendKind::StochasticFused)).unwrap();
        let images: Vec<Vec<f32>> = (0..9).map(image).collect();
        let batch = session.infer_batch(&images).unwrap();
        assert_eq!(batch.len(), 9);
        for (i, img) in images.iter().enumerate() {
            assert_eq!(batch[i], session.infer(img.clone()).unwrap(), "image {i}");
        }
    }

    #[test]
    fn submit_drain_streams_in_order_with_backpressure() {
        let mut config = cfg(BackendKind::Expectation);
        config.batch = BatchPolicy {
            max_batch: 4,
            linger: Duration::from_millis(1),
            queue_depth: 2, // force the backpressure path
        };
        let session = Engine::open(config).unwrap();
        let mut tickets = Vec::new();
        for phase in 0..10 {
            tickets.push(session.submit(image(phase)).unwrap());
        }
        assert_eq!(session.outstanding(), 10);
        let results = session.drain().unwrap();
        assert_eq!(session.outstanding(), 0);
        assert_eq!(results.len(), 10);
        for (i, (ticket, res)) in results.iter().enumerate() {
            assert_eq!(*ticket, tickets[i], "submission order preserved");
            let logits = res.as_ref().unwrap();
            assert_eq!(logits, &session.infer(image(i)).unwrap());
        }
        assert_eq!(
            session.drain().unwrap_err(),
            EngineError::EmptyQueue,
            "drain on an empty queue is a typed protocol error"
        );
    }

    #[test]
    fn try_submit_reports_full_instead_of_blocking() {
        let mut config = cfg(BackendKind::Expectation);
        config.batch = BatchPolicy {
            max_batch: 8,
            // A long linger holds the first request's backpressure slot
            // open deterministically while we probe the full queue.
            linger: Duration::from_millis(200),
            queue_depth: 1,
        };
        let session = Engine::open(config).unwrap();
        assert!(matches!(session.try_submit(image(0)), TrySubmit::Accepted(_)));
        // The single slot is held while the worker lingers: try_submit
        // must report full, not park like submit would — and it hands the
        // image back untouched.
        match session.try_submit(image(1)) {
            TrySubmit::Full(img) => assert_eq!(img, image(1)),
            other => panic!("expected Full, got {other:?}"),
        }
        let results = session.drain().unwrap();
        assert_eq!(results.len(), 1);
        // Slot released: accepted again; closed: refused typed with the
        // image returned.
        assert!(matches!(session.try_submit(image(2)), TrySubmit::Accepted(_)));
        session.close();
        match session.try_submit(image(3)) {
            TrySubmit::Refused(EngineError::Closed, img) => assert_eq!(img, image(3)),
            other => panic!("expected Refused(Closed), got {other:?}"),
        }
        let tail = session.drain().unwrap();
        assert_eq!(tail.len(), 1, "the pre-close submission was still served");
    }

    #[test]
    fn drain_without_submissions_is_typed_error() {
        let session = Engine::open(cfg(BackendKind::Expectation)).unwrap();
        assert_eq!(session.drain().unwrap_err(), EngineError::EmptyQueue);
    }

    #[test]
    fn close_refuses_new_work_but_finishes_queued_work() {
        let session = Engine::open(cfg(BackendKind::Expectation)).unwrap();
        let mut tickets = Vec::new();
        for phase in 0..6 {
            tickets.push(session.submit(image(phase)).unwrap());
        }
        assert!(!session.is_closed());
        session.close();
        assert!(session.is_closed());
        assert!(!session.worker_alive(), "close waits for the worker to exit");
        // New work is refused typed — on both the streaming and blocking paths.
        assert_eq!(session.submit(image(0)).unwrap_err(), EngineError::Closed);
        let e = session.infer(image(0)).unwrap_err();
        assert!(e.to_string().contains("closed"), "{e}");
        // Queued work was executed before the worker exited.
        let results = session.drain().unwrap();
        assert_eq!(results.len(), 6);
        for (i, (ticket, res)) in results.iter().enumerate() {
            assert_eq!(*ticket, tickets[i]);
            assert!(res.is_ok(), "queued request {i} served across close: {res:?}");
        }
        // close is idempotent.
        session.close();
    }

    #[test]
    fn malformed_requests_rejected_and_counted() {
        let session = Engine::open(cfg(BackendKind::Expectation)).unwrap();
        assert!(session.infer(vec![0.0; 5]).is_err());
        let ok = session.infer(image(1));
        assert!(ok.is_ok(), "valid requests still served after a rejection");
        let m = session.metrics();
        assert_eq!(m.rejected, 1);
        assert_eq!(m.requests, 1);
    }

    #[test]
    fn malformed_reject_folds_back_to_a_typed_request_error() {
        let session = Engine::open(cfg(BackendKind::Expectation)).unwrap();
        let e = session.infer(vec![0.0; 5]).unwrap_err();
        match EngineError::from_request(e) {
            EngineError::Request(msg) => {
                assert!(msg.contains("5 elements, expected 16"), "{msg}");
            }
            other => panic!("expected Request, got {other:?}"),
        }
        assert_eq!(session.metrics().rejected, 1);
    }

    #[test]
    fn deadline_resolves_to_typed_timeout_instead_of_blocking() {
        let config = cfg(BackendKind::Expectation)
            .with_deadline(Duration::from_millis(1))
            .with_chaos_slow(Duration::from_millis(400));
        let session = Engine::open(config).unwrap();
        let e = session.infer(image(0)).unwrap_err();
        match EngineError::from_request(e) {
            EngineError::Timeout { elapsed } => {
                assert!(elapsed >= Duration::from_millis(1), "elapsed {elapsed:?}");
            }
            other => panic!("expected Timeout, got {other:?}"),
        }
        let m = session.metrics();
        assert_eq!(m.timeouts, 1, "the deadline miss is surfaced in metrics");
    }

    #[test]
    fn degraded_ks_halves_word_aligned_down_to_the_floor() {
        let plan = PrecisionPlan::per_layer(vec![512, 104, 16]);
        assert_eq!(degraded_ks(&plan, 8), Some(vec![256, 48, 8]));
        let floor = PrecisionPlan::per_layer(vec![8, 8]);
        assert_eq!(degraded_ks(&floor, 8), None, "nothing left to give up");
        // A floor above some stages clamps them instead of halving below it.
        let plan = PrecisionPlan::per_layer(vec![128, 32]);
        assert_eq!(degraded_ks(&plan, 32), Some(vec![64, 32]));
        assert_eq!(degraded_ks(&PrecisionPlan::per_layer(vec![64, 32]), 32), Some(vec![32, 32]));
        assert_eq!(degraded_ks(&PrecisionPlan::per_layer(vec![32, 32]), 32), None);
    }

    #[test]
    fn metrics_count_requests_batches_and_estimate() {
        let session = Engine::open(cfg(BackendKind::StochasticFused)).unwrap();
        let images: Vec<Vec<f32>> = (0..12).map(image).collect();
        session.infer_batch(&images).unwrap();
        let m = session.metrics();
        assert_eq!(m.requests, 12);
        assert_eq!(m.failed, 0);
        assert!(m.batches >= 1);
        assert_eq!(m.histogram.count(), 12);
        assert_eq!(m.serve.count(), 12);
        assert!(m.mean_batch() >= 1.0);
        assert!(m.throughput_rps() > 0.0);
        let est = m.estimate.expect("SC backends carry a hardware estimate");
        assert!(est.metrics.energy_uj > 0.0);
        assert!(m.estimated_total_energy_uj().unwrap() > 0.0);
        assert!(m.summary().contains("stochastic-fused"));
        assert!(m.ops_executed > 0, "served images accumulate executed ops");
        assert_eq!(m.ops_skipped, 0, "a dense plan skips nothing");
    }

    #[test]
    fn sparse_session_counts_skipped_ops_and_matches_reference() {
        let sparse = |kind| cfg(kind).with_sparsity(SparsityPolicy::threshold(0.1));
        let fused = Engine::open(sparse(BackendKind::StochasticFused)).unwrap();
        let golden = Engine::open(sparse(BackendKind::ReferencePerBit)).unwrap();
        for phase in 0..3 {
            let a = fused.infer(image(phase)).unwrap();
            let b = golden.infer(image(phase)).unwrap();
            assert_eq!(a, b, "sparse sessions stay bit-exact, phase {phase}");
        }
        let m = fused.metrics();
        assert!(m.ops_skipped > 0, "tiny_weights holds near-zero lanes at threshold 0.1");
        assert!(m.ops_executed > 0);
        assert!(m.summary().contains("sparsity:"), "{}", m.summary());
        // The session's modeled energy reflects the pruned schedule.
        let dense = Engine::open(cfg(BackendKind::StochasticFused)).unwrap();
        dense.infer(image(0)).unwrap();
        let de = dense.metrics().estimate.unwrap();
        let se = m.estimate.unwrap();
        assert!(se.metrics.energy_uj < de.metrics.energy_uj);
        // Degenerate thresholds are refused at open with the typed error.
        let bad = cfg(BackendKind::StochasticFused).with_sparsity(SparsityPolicy::threshold(1.5));
        let err = Engine::open(bad).unwrap_err().to_string();
        assert!(err.contains("sparsity"), "{err}");
    }

    #[test]
    fn open_fails_on_invalid_config() {
        // No weights.
        let bad = EngineConfig::new(BackendKind::StochasticFused, tiny_net());
        assert!(Engine::open(bad).is_err());
        // Xla without a ladder.
        let bad = EngineConfig::new(BackendKind::Xla, tiny_net());
        assert!(Engine::open(bad).is_err());
        // Xla with a missing artifact: the error comes from the worker.
        let bad = EngineConfig::new(BackendKind::Xla, tiny_net())
            .with_hlo_ladder(vec![(1, std::path::PathBuf::from("/nonexistent.hlo.txt"))]);
        assert!(Engine::open(bad).is_err());
    }

    #[test]
    fn classify_picks_last_argmax_like_network_classify() {
        assert_eq!(classify(&[0.1, 0.9, -0.3]), 1);
        assert_eq!(classify(&[-5.0, -2.0, -9.0]), 1);
        let f64s = [0.25f64, 0.5, 0.5];
        let f32s = [0.25f32, 0.5, 0.5];
        assert_eq!(classify(&f32s), crate::accel::network::classify(&f64s));
    }
}
