//! The unified inference engine: **every** way to run the SCNN — fused
//! bit-exact stochastic, per-bit golden reference, analytic expectation /
//! noisy-expectation / fixed-point, and the PJRT executable ladder — behind
//! one [`Session`] opened from one typed [`EngineConfig`].
//!
//! ```text
//! EngineConfig ──Engine::open──▶ Session ──▶ worker thread
//!   backend kind                   │            │ Box<dyn Backend>
//!   net + weights                  │ infer      │   StochasticFused
//!   k / bits / seed                │ infer_batch│   ReferencePerBit
//!   threads / batch policy         │ submit     │   Expectation(+noisy/fixed)
//!   tech / channels                │ drain      │   Xla (PJRT ladder)
//!                                  ▼            ▼
//!                             SessionMetrics (latency histogram,
//!                             throughput, modeled energy/area)
//! ```
//!
//! # Why a session object
//!
//! The compiled state behind an inference — gather tables, layer randoms,
//! every weight SNG stream, PJRT executables — is expensive to build and
//! cheap to reuse. A [`Session`] owns that state on a dedicated worker
//! thread (PJRT handles are not `Send`-safe to share), batches concurrent
//! requests through one dynamic batcher for **every** backend, and carries
//! its own [`SessionMetrics`]: exact latency percentiles, a log₂ histogram,
//! throughput, and the modeled hardware cost of the run via
//! [`crate::accel::system`].
//!
//! # Request paths
//!
//! * [`Session::infer`] — one blocking request (concurrent callers are
//!   coalesced by the batcher);
//! * [`Session::infer_batch`] — a whole slice, pipelined through the
//!   batcher, results in input order;
//! * [`Session::submit`] / [`Session::drain`] — the streaming serve path:
//!   `submit` enqueues without waiting (blocking only when
//!   `BatchPolicy::queue_depth` requests are already in flight —
//!   backpressure), `drain` collects every outstanding result in
//!   submission order.
//!
//! The free functions `accel::network::forward` / `forward_batch` are
//! deprecated shims over the same machinery; new code opens a session.

pub mod backend;
pub mod config;
pub mod metrics;

pub use backend::Backend;
pub use config::{BackendKind, BatchPolicy, EngineConfig, WeightSource};
pub use metrics::{HardwareEstimate, LatencyHistogram, ServeStats, SessionMetrics};

use crate::accel::layers::NetworkSpec;
use crate::tech::TechKind;
use anyhow::{anyhow, Result};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock};
use std::time::Instant;

/// Argmax over a logit slice (the serving dtype). Delegates to the generic
/// [`crate::accel::network::classify`], so the f32 serving path and the f64
/// datapath can never diverge on tie or NaN handling.
pub fn classify(output: &[f32]) -> usize {
    crate::accel::network::classify(output)
}

/// The engine entry point: opens [`Session`]s and evaluates configurations.
pub struct Engine;

impl Engine {
    /// Open a session: spawn the worker, build the configured backend on
    /// it (compiling plans / executables), and return once it is ready.
    pub fn open(config: EngineConfig) -> Result<Session> {
        Session::open(config)
    }

    /// The modeled-hardware estimate for a configuration without opening a
    /// session (`None` for [`BackendKind::Xla`]). This is what `sweep`
    /// iterates over.
    pub fn estimate(config: &EngineConfig) -> Option<HardwareEstimate> {
        config.estimate()
    }
}

/// Handle to one in-flight [`Session::submit`] request.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct Ticket(u64);

/// A classification request travelling to the worker.
struct Request {
    image: Vec<f32>,
    enqueued: Instant,
    respond: mpsc::Sender<Result<Vec<f32>>>,
}

/// State shared between the session handle and its worker.
struct Shared {
    recorder: Mutex<Recorder>,
    inflight: Mutex<usize>,
    done: Condvar,
}

/// The worker-side metrics recorder.
#[derive(Default)]
struct Recorder {
    serve: ServeStats,
    hist: LatencyHistogram,
    batches: usize,
    rejected: usize,
    failed: usize,
}

/// What the worker reports back once its backend is built.
struct BackendInfo {
    name: &'static str,
    in_len: usize,
    out_len: usize,
}

/// An open inference session: one backend, one dynamic batcher, one
/// metrics recorder. Cheap to share by reference across client threads.
pub struct Session {
    tx: mpsc::Sender<Request>,
    shared: Arc<Shared>,
    pending: Mutex<VecDeque<(Ticket, mpsc::Receiver<Result<Vec<f32>>>)>>,
    next_ticket: AtomicU64,
    worker: Option<std::thread::JoinHandle<()>>,
    info: BackendInfo,
    /// Inputs for the modeled-hardware estimate (None for XLA), evaluated
    /// lazily on first [`Session::metrics`] — channel characterization is
    /// gate-level-simulation heavy and many sessions never read metrics.
    estimate_inputs: Option<(TechKind, usize, usize, NetworkSpec)>,
    estimate: OnceLock<Option<HardwareEstimate>>,
    opened: Instant,
    queue_depth: usize,
}

impl Session {
    /// Open a session from a validated configuration (see [`Engine::open`]).
    pub fn open(config: EngineConfig) -> Result<Self> {
        config.validate()?;
        let estimate_inputs = if config.backend == BackendKind::Xla {
            None
        } else {
            Some((config.tech, config.channels, config.k, config.net.clone()))
        };
        let queue_depth = config.batch.queue_depth.max(1);
        let shared = Arc::new(Shared {
            recorder: Mutex::new(Recorder::default()),
            inflight: Mutex::new(0),
            done: Condvar::new(),
        });
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<BackendInfo>>();
        let shared_w = Arc::clone(&shared);
        let worker = std::thread::Builder::new()
            .name("scnn-engine".into())
            .spawn(move || worker_loop(config, rx, shared_w, ready_tx))
            .map_err(|e| anyhow!("spawning engine worker: {e}"))?;
        let info = ready_rx
            .recv()
            .map_err(|_| anyhow!("engine worker died during startup"))??;
        Ok(Session {
            tx,
            shared,
            pending: Mutex::new(VecDeque::new()),
            next_ticket: AtomicU64::new(0),
            worker: Some(worker),
            info,
            estimate_inputs,
            estimate: OnceLock::new(),
            opened: Instant::now(),
            queue_depth,
        })
    }

    /// Backend label (e.g. `stochastic-fused`).
    pub fn backend(&self) -> &str {
        self.info.name
    }

    /// Expected flattened input length.
    pub fn in_len(&self) -> usize {
        self.info.in_len
    }

    /// Flattened output length (class count).
    pub fn out_len(&self) -> usize {
        self.info.out_len
    }

    /// Block until a backpressure slot frees up, then claim it.
    fn acquire_slot(&self) {
        let mut n = self.shared.inflight.lock().unwrap();
        while *n >= self.queue_depth {
            n = self.shared.done.wait(n).unwrap();
        }
        *n += 1;
    }

    /// Enqueue one request (claiming a backpressure slot) and return the
    /// response channel.
    fn send_request(&self, image: Vec<f32>) -> Result<mpsc::Receiver<Result<Vec<f32>>>> {
        self.acquire_slot();
        let (rtx, rrx) = mpsc::channel();
        let req = Request { image, enqueued: Instant::now(), respond: rtx };
        if self.tx.send(req).is_err() {
            release_slots(&self.shared, 1);
            return Err(anyhow!("engine session stopped"));
        }
        Ok(rrx)
    }

    /// Classify one image (blocking). Returns the logits.
    pub fn infer(&self, image: Vec<f32>) -> Result<Vec<f32>> {
        let rrx = self.send_request(image)?;
        rrx.recv().map_err(|_| anyhow!("engine worker dropped request"))?
    }

    /// Run a whole slice through the batcher; results in input order. The
    /// images are pipelined (submission overlaps execution), so batches
    /// form even from a single caller thread.
    pub fn infer_batch(&self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let mut receivers = Vec::with_capacity(images.len());
        for img in images {
            receivers.push(self.send_request(img.clone())?);
        }
        let mut outs = Vec::with_capacity(receivers.len());
        for rrx in receivers {
            outs.push(rrx.recv().map_err(|_| anyhow!("engine worker dropped request"))??);
        }
        Ok(outs)
    }

    /// Enqueue one request without waiting for its result. Blocks only for
    /// backpressure: at most `BatchPolicy::queue_depth` requests may be in
    /// flight. Collect results with [`Session::drain`].
    pub fn submit(&self, image: Vec<f32>) -> Result<Ticket> {
        self.acquire_slot();
        // Ticket allocation, channel send, and the pending push happen
        // under one lock so concurrent submitters cannot interleave them —
        // drain()'s submission-order contract depends on pending order
        // matching the worker's arrival order.
        let mut pending = self.pending.lock().unwrap();
        let (rtx, rrx) = mpsc::channel();
        let req = Request { image, enqueued: Instant::now(), respond: rtx };
        if self.tx.send(req).is_err() {
            drop(pending);
            release_slots(&self.shared, 1);
            return Err(anyhow!("engine session stopped"));
        }
        let ticket = Ticket(self.next_ticket.fetch_add(1, Ordering::Relaxed));
        pending.push_back((ticket, rrx));
        Ok(ticket)
    }

    /// Wait for every outstanding [`Session::submit`] and return the
    /// results in submission order.
    pub fn drain(&self) -> Vec<(Ticket, Result<Vec<f32>>)> {
        let mut done = Vec::new();
        loop {
            // Pop outside the wait so concurrent submitters are not blocked.
            let next = self.pending.lock().unwrap().pop_front();
            match next {
                None => break,
                Some((ticket, rrx)) => {
                    let res = rrx
                        .recv()
                        .map_err(|_| anyhow!("engine worker dropped request"))
                        .and_then(|r| r);
                    done.push((ticket, res));
                }
            }
        }
        done
    }

    /// Number of submitted-but-undrained requests.
    pub fn outstanding(&self) -> usize {
        self.pending.lock().unwrap().len()
    }

    /// Snapshot of this session's metrics. The first call evaluates the
    /// modeled-hardware estimate (cached for the session's lifetime).
    pub fn metrics(&self) -> SessionMetrics {
        let estimate = *self.estimate.get_or_init(|| {
            self.estimate_inputs
                .as_ref()
                .map(|&(tech, channels, k, ref net)| {
                    HardwareEstimate::for_config(tech, channels, k, net)
                })
        });
        let rec = self.shared.recorder.lock().unwrap();
        SessionMetrics {
            backend: self.info.name.to_string(),
            requests: rec.serve.count(),
            rejected: rec.rejected,
            failed: rec.failed,
            batches: rec.batches,
            wall: self.opened.elapsed(),
            serve: rec.serve.clone(),
            histogram: rec.hist.clone(),
            estimate,
        }
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        // Closing the request channel stops the worker loop.
        let (dummy_tx, _) = mpsc::channel();
        let _ = std::mem::replace(&mut self.tx, dummy_tx);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn release_slots(shared: &Shared, n: usize) {
    let mut g = shared.inflight.lock().unwrap();
    *g = g.saturating_sub(n);
    shared.done.notify_all();
}

/// The worker: builds the backend, then drains the queue in dynamic
/// batches — block for the first request, linger for more, execute, respond.
fn worker_loop(
    cfg: EngineConfig,
    rx: mpsc::Receiver<Request>,
    shared: Arc<Shared>,
    ready: mpsc::Sender<Result<BackendInfo>>,
) {
    let batch_max = cfg.batch.max_batch.max(1);
    let linger = cfg.batch.linger;
    let mut backend = match backend::build(&cfg) {
        Ok(b) => {
            let info =
                BackendInfo { name: b.name(), in_len: b.in_len(), out_len: b.out_len() };
            let _ = ready.send(Ok(info));
            b
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let in_len = backend.in_len();

    loop {
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // session dropped
        };
        let mut pending = vec![first];
        let deadline = Instant::now() + linger;
        while pending.len() < batch_max {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => pending.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }

        // Reject malformed requests individually; batch the rest.
        let mut valid: Vec<Request> = Vec::with_capacity(pending.len());
        let mut rejected = 0usize;
        for r in pending {
            if r.image.len() != in_len {
                let msg = anyhow!(
                    "request image has {} elements, expected {in_len}",
                    r.image.len()
                );
                let _ = r.respond.send(Err(msg));
                rejected += 1;
            } else {
                valid.push(r);
            }
        }
        if rejected > 0 {
            shared.recorder.lock().unwrap().rejected += rejected;
            release_slots(&shared, rejected);
        }
        if valid.is_empty() {
            continue;
        }
        let inputs: Vec<Vec<f32>> =
            valid.iter_mut().map(|r| std::mem::take(&mut r.image)).collect();
        let bsz = valid.len();
        match backend.infer_batch(&inputs) {
            Ok(outs) if outs.len() == bsz => {
                let mut rec = shared.recorder.lock().unwrap();
                rec.batches += 1;
                for (r, out) in valid.iter().zip(outs) {
                    // Record before responding: clients may read metrics
                    // right after their reply arrives.
                    let lat = r.enqueued.elapsed();
                    rec.serve.record(lat, bsz);
                    rec.hist.record_us(lat.as_micros() as u64);
                    let _ = r.respond.send(Ok(out));
                }
            }
            Ok(outs) => {
                shared.recorder.lock().unwrap().failed += bsz;
                for r in &valid {
                    let _ = r.respond.send(Err(anyhow!(
                        "backend returned {} outputs for a batch of {bsz}",
                        outs.len()
                    )));
                }
            }
            Err(e) => {
                // Count before responding so a failed run is visible in
                // metrics the moment callers see their errors.
                shared.recorder.lock().unwrap().failed += bsz;
                let msg = format!("{e:#}");
                for r in &valid {
                    let _ = r.respond.send(Err(anyhow!("batch failed: {msg}")));
                }
            }
        }
        release_slots(&shared, bsz);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::layers::{LayerKind, LayerSpec, NetworkSpec};
    use crate::accel::network::{ForwardMode, ForwardPlan, LayerWeights, QuantizedWeights};
    use crate::sc::quantize_bipolar;
    use std::time::Duration;

    fn tiny_net() -> NetworkSpec {
        NetworkSpec {
            name: "tiny".into(),
            input: (1, 4, 4),
            layers: vec![LayerSpec {
                kind: LayerKind::Dense { inputs: 16, outputs: 3 },
                relu: false,
            }],
        }
    }

    fn tiny_weights(bits: u32) -> QuantizedWeights {
        let codes: Vec<Vec<u32>> = (0..3)
            .map(|oc| {
                (0..16)
                    .map(|j| quantize_bipolar(((oc * 7 + j) % 11) as f64 / 5.5 - 1.0, bits))
                    .collect()
            })
            .collect();
        QuantizedWeights { bits, layers: vec![LayerWeights { codes, gamma: 1.0, mu: 0.0 }] }
    }

    fn cfg(kind: BackendKind) -> EngineConfig {
        EngineConfig::new(kind, tiny_net()).with_quantized(tiny_weights(8)).with_k(64)
    }

    fn image(phase: usize) -> Vec<f32> {
        (0..16).map(|j| ((j + phase) % 10) as f32 / 10.0).collect()
    }

    #[test]
    fn session_matches_direct_plan() {
        let session = Engine::open(cfg(BackendKind::Expectation)).unwrap();
        assert_eq!(session.backend(), "expectation");
        assert_eq!(session.in_len(), 16);
        assert_eq!(session.out_len(), 3);
        let served = session.infer(image(0)).unwrap();
        let plan = ForwardPlan::new(&tiny_net(), &tiny_weights(8), ForwardMode::Expectation);
        let direct = plan.run(&image(0).iter().map(|&v| v as f64).collect::<Vec<_>>());
        for (s, d) in served.iter().zip(&direct) {
            assert!((*s as f64 - d).abs() < 1e-6, "served {s} direct {d}");
        }
    }

    #[test]
    fn fused_session_is_bit_exact_vs_reference_session() {
        let fused = Engine::open(cfg(BackendKind::StochasticFused)).unwrap();
        let golden = Engine::open(cfg(BackendKind::ReferencePerBit)).unwrap();
        for phase in 0..3 {
            let a = fused.infer(image(phase)).unwrap();
            let b = golden.infer(image(phase)).unwrap();
            assert_eq!(a, b, "phase {phase}");
        }
    }

    #[test]
    fn infer_batch_preserves_order_and_matches_infer() {
        let session = Engine::open(cfg(BackendKind::StochasticFused)).unwrap();
        let images: Vec<Vec<f32>> = (0..9).map(image).collect();
        let batch = session.infer_batch(&images).unwrap();
        assert_eq!(batch.len(), 9);
        for (i, img) in images.iter().enumerate() {
            assert_eq!(batch[i], session.infer(img.clone()).unwrap(), "image {i}");
        }
    }

    #[test]
    fn submit_drain_streams_in_order_with_backpressure() {
        let mut config = cfg(BackendKind::Expectation);
        config.batch = BatchPolicy {
            max_batch: 4,
            linger: Duration::from_millis(1),
            queue_depth: 2, // force the backpressure path
        };
        let session = Engine::open(config).unwrap();
        let mut tickets = Vec::new();
        for phase in 0..10 {
            tickets.push(session.submit(image(phase)).unwrap());
        }
        assert_eq!(session.outstanding(), 10);
        let results = session.drain();
        assert_eq!(session.outstanding(), 0);
        assert_eq!(results.len(), 10);
        for (i, (ticket, res)) in results.iter().enumerate() {
            assert_eq!(*ticket, tickets[i], "submission order preserved");
            let logits = res.as_ref().unwrap();
            assert_eq!(logits, &session.infer(image(i)).unwrap());
        }
        assert!(session.drain().is_empty(), "drain on an empty queue is empty");
    }

    #[test]
    fn malformed_requests_rejected_and_counted() {
        let session = Engine::open(cfg(BackendKind::Expectation)).unwrap();
        assert!(session.infer(vec![0.0; 5]).is_err());
        let ok = session.infer(image(1));
        assert!(ok.is_ok(), "valid requests still served after a rejection");
        let m = session.metrics();
        assert_eq!(m.rejected, 1);
        assert_eq!(m.requests, 1);
    }

    #[test]
    fn metrics_count_requests_batches_and_estimate() {
        let session = Engine::open(cfg(BackendKind::StochasticFused)).unwrap();
        let images: Vec<Vec<f32>> = (0..12).map(image).collect();
        session.infer_batch(&images).unwrap();
        let m = session.metrics();
        assert_eq!(m.requests, 12);
        assert_eq!(m.failed, 0);
        assert!(m.batches >= 1);
        assert_eq!(m.histogram.count(), 12);
        assert_eq!(m.serve.count(), 12);
        assert!(m.mean_batch() >= 1.0);
        assert!(m.throughput_rps() > 0.0);
        let est = m.estimate.expect("SC backends carry a hardware estimate");
        assert!(est.metrics.energy_uj > 0.0);
        assert!(m.estimated_total_energy_uj().unwrap() > 0.0);
        assert!(m.summary().contains("stochastic-fused"));
    }

    #[test]
    fn open_fails_on_invalid_config() {
        // No weights.
        let bad = EngineConfig::new(BackendKind::StochasticFused, tiny_net());
        assert!(Engine::open(bad).is_err());
        // Xla without a ladder.
        let bad = EngineConfig::new(BackendKind::Xla, tiny_net());
        assert!(Engine::open(bad).is_err());
        // Xla with a missing artifact: the error comes from the worker.
        let bad = EngineConfig::new(BackendKind::Xla, tiny_net())
            .with_hlo_ladder(vec![(1, std::path::PathBuf::from("/nonexistent.hlo.txt"))]);
        assert!(Engine::open(bad).is_err());
    }

    #[test]
    fn classify_picks_last_argmax_like_network_classify() {
        assert_eq!(classify(&[0.1, 0.9, -0.3]), 1);
        assert_eq!(classify(&[-5.0, -2.0, -9.0]), 1);
        let f64s = [0.25f64, 0.5, 0.5];
        let f32s = [0.25f32, 0.5, 0.5];
        assert_eq!(classify(&f32s), crate::accel::network::classify(&f64s));
    }
}
