//! Per-session serving metrics: request/latency accounting ([`ServeStats`]),
//! a log₂-bucketed [`LatencyHistogram`], and the modeled-hardware
//! [`HardwareEstimate`] derived from [`crate::accel::system::evaluate_with_channel`].
//!
//! Every [`crate::engine::Session`] owns one recorder; `serve`, `simulate`,
//! and `sweep` all report through the same [`SessionMetrics`] snapshot, so a
//! served workload, a simulated workload, and a design-space point print the
//! same figures of merit.

use crate::accel::channel::{characterize_channel, ChannelReport};
use crate::accel::layers::NetworkSpec;
use crate::accel::memory::MemoryModel;
use crate::accel::metrics::SystemMetrics;
use crate::accel::system::{evaluate_with_channel, SystemConfig};
use crate::tech::sram::SramMacro;
use crate::tech::TechKind;
use std::sync::OnceLock;
use std::time::Duration;

/// Records per-request latencies (for percentiles) and a running batch-size
/// mean. Memory is bounded: the first [`ServeStats::EXACT_CAP`] latencies
/// are kept exactly; beyond that, reservoir sampling keeps a uniform sample
/// over the whole request history, so long-lived serving sessions do not
/// grow without bound.
#[derive(Debug, Clone)]
pub struct ServeStats {
    latencies_us: Vec<u64>,
    batch_sum: u64,
    total_requests: usize,
    /// Deterministic xorshift state for reservoir replacement.
    rng: u64,
}

impl Default for ServeStats {
    fn default() -> Self {
        ServeStats {
            latencies_us: Vec::new(),
            batch_sum: 0,
            total_requests: 0,
            rng: 0x9E37_79B9_7F4A_7C15,
        }
    }
}

impl ServeStats {
    /// Latency samples kept (exactly below this count, reservoir beyond).
    pub const EXACT_CAP: usize = 1 << 16;

    /// New empty recorder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one completed request.
    pub fn record(&mut self, latency: Duration, batch: usize) {
        self.total_requests += 1;
        self.batch_sum += batch as u64;
        let us = latency.as_micros() as u64;
        if self.latencies_us.len() < Self::EXACT_CAP {
            self.latencies_us.push(us);
        } else {
            // Algorithm R: replace a random slot with probability CAP/n so
            // the reservoir stays a uniform sample of all n requests.
            self.rng ^= self.rng << 13;
            self.rng ^= self.rng >> 7;
            self.rng ^= self.rng << 17;
            let j = (self.rng % self.total_requests as u64) as usize;
            if j < Self::EXACT_CAP {
                self.latencies_us[j] = us;
            }
        }
    }

    /// Requests completed.
    pub fn count(&self) -> usize {
        self.total_requests
    }

    /// Latency percentile in microseconds (p in [0, 100]), over the
    /// (sampled) latency record.
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        if self.latencies_us.is_empty() {
            return 0;
        }
        let mut v = self.latencies_us.clone();
        v.sort_unstable();
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)]
    }

    /// Mean batch size, where "batch" is the coalesced request group the
    /// batcher handed to the backend in one call — a scheduling metric. A
    /// backend may further chunk the group internally (the XLA ladder
    /// executes e.g. 20 requests as 8+8+1+1+1+1); that executable width is
    /// not what is recorded here.
    pub fn mean_batch(&self) -> f64 {
        if self.total_requests == 0 {
            return 0.0;
        }
        self.batch_sum as f64 / self.total_requests as f64
    }

    /// Merge another recorder into this one (latency samples concatenate
    /// up to the reservoir cap).
    pub fn merge(&mut self, other: &ServeStats) {
        self.latencies_us.extend_from_slice(&other.latencies_us);
        self.latencies_us.truncate(Self::EXACT_CAP);
        self.batch_sum += other.batch_sum;
        self.total_requests += other.total_requests;
    }
}

/// Power-of-two latency histogram: bucket 0 holds sub-microsecond requests,
/// bucket `b ≥ 1` holds latencies in `[2^(b-1), 2^b)` µs. Fixed 32 buckets
/// (the last one saturates), so snapshots are cheap to clone and merge.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    buckets: [u64; 32],
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram { buckets: [0; 32] }
    }
}

impl LatencyHistogram {
    /// New empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one latency in microseconds.
    pub fn record_us(&mut self, us: u64) {
        let b = (64 - us.leading_zeros() as usize).min(31);
        self.buckets[b] += 1;
    }

    /// Total recorded samples.
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Occupied buckets as `(lo_us, hi_us_exclusive, count)` triples.
    pub fn nonzero_buckets(&self) -> Vec<(u64, u64, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter(|&(_, &n)| n > 0)
            .map(|(b, &n)| {
                let lo = if b == 0 { 0 } else { 1u64 << (b - 1) };
                (lo, 1u64 << b, n)
            })
            .collect()
    }

    /// Upper bound (exclusive, µs) of the bucket containing percentile `p`.
    pub fn percentile_bound_us(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (p / 100.0 * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (b, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= target {
                return 1u64 << b;
            }
        }
        1u64 << 31
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
    }
}

/// Modeled-hardware figures for the accelerator a session's datapath
/// simulates: the §V system roll-up (area / latency / energy / power /
/// TOPS-derived metrics) at the session's technology, channel count, and
/// bitstream length. `None` for the XLA backend (it models no SC hardware).
#[derive(Debug, Clone, Copy)]
pub struct HardwareEstimate {
    /// Logic technology.
    pub tech: TechKind,
    /// Channel count.
    pub channels: usize,
    /// Bitstream length the hardware is evaluated at.
    pub k: usize,
    /// The system metrics (per-inference latency/energy, ADP/EDP/EDAP...).
    pub metrics: SystemMetrics,
}

impl HardwareEstimate {
    /// Evaluate the paper's system model for one configuration on one
    /// workload (SRAM/memory fixed at the §V setup). Channel
    /// characterization is cached per technology for the process lifetime.
    pub fn for_config(tech: TechKind, channels: usize, k: usize, net: &NetworkSpec) -> Self {
        let channel = cached_channel_report(tech);
        let cfg = SystemConfig {
            tech,
            channels: channels.max(1),
            k: k.max(1),
            sram: SramMacro::paper_10kb(),
            memory: MemoryModel::gddr5_paper(),
        };
        let eval = evaluate_with_channel(&cfg, net, channel);
        HardwareEstimate { tech, channels: cfg.channels, k: cfg.k, metrics: eval.metrics }
    }
}

/// Channel characterization for a technology, computed once per process
/// (it is deterministic per [`TechKind`] and gate-level-simulation heavy).
pub fn cached_channel_report(tech: TechKind) -> &'static ChannelReport {
    static FINFET: OnceLock<ChannelReport> = OnceLock::new();
    static RFET: OnceLock<ChannelReport> = OnceLock::new();
    let cell = match tech {
        TechKind::Finfet10 => &FINFET,
        TechKind::Rfet10 => &RFET,
    };
    cell.get_or_init(|| characterize_channel(tech))
}

/// Snapshot of one session's serving statistics plus its modeled-hardware
/// estimate — the single reporting struct behind `serve`, `simulate`, and
/// `sweep`.
#[derive(Debug, Clone)]
pub struct SessionMetrics {
    /// Backend label (e.g. `stochastic-fused`).
    pub backend: String,
    /// Requests completed successfully.
    pub requests: usize,
    /// Requests rejected (malformed input).
    pub rejected: usize,
    /// Requests that reached the backend but failed during execution.
    pub failed: usize,
    /// Batches executed.
    pub batches: usize,
    /// Wall time since the session was opened.
    pub wall: Duration,
    /// Exact per-request records (percentiles, mean batch).
    pub serve: ServeStats,
    /// Log₂ latency histogram.
    pub histogram: LatencyHistogram,
    /// Modeled-hardware figures (None for the XLA backend).
    pub estimate: Option<HardwareEstimate>,
}

impl SessionMetrics {
    /// Mean coalesced batch size (see [`ServeStats::mean_batch`]).
    pub fn mean_batch(&self) -> f64 {
        self.serve.mean_batch()
    }

    /// Exact latency percentile in µs.
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        self.serve.latency_percentile_us(p)
    }

    /// Completed requests per second of session wall time.
    pub fn throughput_rps(&self) -> f64 {
        let secs = self.wall.as_secs_f64();
        if secs > 0.0 {
            self.requests as f64 / secs
        } else {
            0.0
        }
    }

    /// Modeled energy for every completed inference (µJ), when the session
    /// has a hardware estimate.
    pub fn estimated_total_energy_uj(&self) -> Option<f64> {
        self.estimate.map(|e| e.metrics.energy_uj * self.requests as f64)
    }

    /// Multi-line human-readable report (the common tail of `serve` /
    /// `simulate` output).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "backend {}: {} requests ({} rejected, {} failed) in {} batches, mean batch {:.1}\n",
            self.backend,
            self.requests,
            self.rejected,
            self.failed,
            self.batches,
            self.mean_batch()
        );
        s.push_str(&format!(
            "latency p50 {} µs  p99 {} µs  throughput {:.0} req/s\n",
            self.latency_percentile_us(50.0),
            self.latency_percentile_us(99.0),
            self.throughput_rps()
        ));
        if let Some(e) = self.estimate {
            let m = &e.metrics;
            s.push_str(&format!(
                "modeled hardware: {} ×{}ch @ k={} — {:.3} mm², {:.2} µs, {:.3} µJ/inf, \
                 {:.2} TOPS/W",
                e.tech,
                e.channels,
                e.k,
                m.area_mm2,
                m.latency_us,
                m.energy_uj,
                m.tops_per_watt()
            ));
            if let Some(total) = self.estimated_total_energy_uj() {
                s.push_str(&format!(" ({total:.1} µJ modeled for this run)"));
            }
            s.push('\n');
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut s = ServeStats::new();
        for i in 1..=100u64 {
            s.record(Duration::from_micros(i), 1);
        }
        assert_eq!(s.count(), 100);
        assert!(s.latency_percentile_us(50.0) <= s.latency_percentile_us(99.0));
        assert_eq!(s.latency_percentile_us(0.0), 1);
        assert_eq!(s.latency_percentile_us(100.0), 100);
    }

    #[test]
    fn empty_stats_safe() {
        let s = ServeStats::new();
        assert_eq!(s.latency_percentile_us(99.0), 0);
        assert_eq!(s.mean_batch(), 0.0);
    }

    #[test]
    fn serve_stats_memory_is_bounded() {
        let mut s = ServeStats::new();
        let n = ServeStats::EXACT_CAP + 1000;
        for i in 0..n {
            s.record(Duration::from_micros(i as u64 % 500), 2);
        }
        assert_eq!(s.count(), n);
        assert!(s.latencies_us.len() <= ServeStats::EXACT_CAP, "latency reservoir is capped");
        assert!(s.latency_percentile_us(99.0) < 500, "sampled percentiles stay in range");
        assert_eq!(s.mean_batch(), 2.0, "batch mean covers every request, not just the sample");
    }

    #[test]
    fn merge_adds() {
        let mut a = ServeStats::new();
        a.record(Duration::from_micros(5), 2);
        let mut b = ServeStats::new();
        b.record(Duration::from_micros(7), 4);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.mean_batch(), 3.0);
    }

    #[test]
    fn histogram_buckets_by_power_of_two() {
        let mut h = LatencyHistogram::new();
        for us in [0u64, 1, 2, 3, 4, 1000, u64::MAX] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 7);
        let nz = h.nonzero_buckets();
        // 0 → [0,1); 1 → [1,2); 2,3 → [2,4); 4 → [4,8); 1000 → [512,1024);
        // u64::MAX saturates into the last bucket.
        assert_eq!(nz[0], (0, 1, 1));
        assert_eq!(nz[1], (1, 2, 1));
        assert_eq!(nz[2], (2, 4, 2));
        assert_eq!(nz[3], (4, 8, 1));
        assert!(nz.iter().any(|&(lo, hi, n)| lo == 512 && hi == 1024 && n == 1));
        assert_eq!(nz.last().unwrap().2, 1);
        let total: u64 = nz.iter().map(|&(_, _, n)| n).sum();
        assert_eq!(total, 7);
    }

    #[test]
    fn histogram_percentile_bound_monotone() {
        let mut h = LatencyHistogram::new();
        for i in 0..1000u64 {
            h.record_us(i);
        }
        assert!(h.percentile_bound_us(50.0) <= h.percentile_bound_us(99.0));
        assert!(h.percentile_bound_us(99.0) <= 1024);
        assert_eq!(LatencyHistogram::new().percentile_bound_us(50.0), 0);
    }

    #[test]
    fn histogram_merge_adds() {
        let mut a = LatencyHistogram::new();
        a.record_us(3);
        let mut b = LatencyHistogram::new();
        b.record_us(3);
        b.record_us(100);
        a.merge(&b);
        assert_eq!(a.count(), 3);
    }

    #[test]
    fn hardware_estimate_matches_direct_evaluation() {
        use crate::accel::system;
        let net = NetworkSpec::lenet5();
        let est = HardwareEstimate::for_config(TechKind::Rfet10, 8, 32, &net);
        let direct = system::evaluate(&SystemConfig::paper(TechKind::Rfet10, 8), &net);
        assert!((est.metrics.area_mm2 - direct.metrics.area_mm2).abs() < 1e-12);
        assert!((est.metrics.energy_uj - direct.metrics.energy_uj).abs() < 1e-12);
        // Cached characterization: a second call is consistent.
        let again = HardwareEstimate::for_config(TechKind::Rfet10, 8, 32, &net);
        assert!((again.metrics.latency_us - est.metrics.latency_us).abs() < 1e-12);
    }

    #[test]
    fn session_metrics_summary_mentions_backend_and_estimate() {
        let net = NetworkSpec::lenet5();
        let mut serve = ServeStats::new();
        serve.record(Duration::from_micros(100), 4);
        let mut histogram = LatencyHistogram::new();
        histogram.record_us(100);
        let m = SessionMetrics {
            backend: "stochastic-fused".into(),
            requests: 1,
            rejected: 0,
            failed: 0,
            batches: 1,
            wall: Duration::from_millis(10),
            serve,
            histogram,
            estimate: Some(HardwareEstimate::for_config(TechKind::Rfet10, 8, 32, &net)),
        };
        let text = m.summary();
        assert!(text.contains("stochastic-fused"));
        assert!(text.contains("modeled hardware"));
        assert!(m.throughput_rps() > 0.0);
        assert!(m.estimated_total_energy_uj().unwrap() > 0.0);
    }
}
