//! Per-session serving metrics: request/latency accounting ([`ServeStats`]),
//! a log₂-bucketed [`LatencyHistogram`], and the modeled-hardware
//! [`HardwareEstimate`] derived from [`crate::accel::system::evaluate_with_channel`].
//!
//! Every [`crate::engine::Session`] owns one recorder; `serve`, `simulate`,
//! and `sweep` all report through the same [`SessionMetrics`] snapshot, so a
//! served workload, a simulated workload, and a design-space point print the
//! same figures of merit.

use crate::accel::channel::{characterize_channel, ChannelReport};
use crate::accel::layers::NetworkSpec;
use crate::accel::memory::MemoryModel;
use crate::accel::metrics::SystemMetrics;
use crate::accel::precision::PrecisionPlan;
use crate::accel::system::{evaluate_with_channel_sparse, SystemConfig};
use crate::tech::sram::SramMacro;
use crate::tech::TechKind;
use std::sync::OnceLock;
use std::time::Duration;

/// Records per-request latencies (for percentiles) and a running batch-size
/// mean. Memory is bounded: the first [`ServeStats::EXACT_CAP`] latencies
/// are kept exactly; beyond that, reservoir sampling keeps a uniform sample
/// over the whole request history, so long-lived serving sessions do not
/// grow without bound.
#[derive(Debug, Clone)]
pub struct ServeStats {
    latencies_us: Vec<u64>,
    batch_sum: u64,
    total_requests: usize,
    /// Deterministic xorshift state for reservoir replacement.
    rng: u64,
}

impl Default for ServeStats {
    fn default() -> Self {
        ServeStats {
            latencies_us: Vec::new(),
            batch_sum: 0,
            total_requests: 0,
            rng: 0x9E37_79B9_7F4A_7C15,
        }
    }
}

impl ServeStats {
    /// Latency samples kept (exactly below this count, reservoir beyond).
    pub const EXACT_CAP: usize = 1 << 16;

    /// New empty recorder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one completed request.
    pub fn record(&mut self, latency: Duration, batch: usize) {
        self.total_requests += 1;
        self.batch_sum += batch as u64;
        let us = latency.as_micros() as u64;
        if self.latencies_us.len() < Self::EXACT_CAP {
            self.latencies_us.push(us);
        } else {
            // Algorithm R: replace a random slot with probability CAP/n so
            // the reservoir stays a uniform sample of all n requests.
            self.rng ^= self.rng << 13;
            self.rng ^= self.rng >> 7;
            self.rng ^= self.rng << 17;
            let j = (self.rng % self.total_requests as u64) as usize;
            if j < Self::EXACT_CAP {
                self.latencies_us[j] = us;
            }
        }
    }

    /// Requests completed.
    pub fn count(&self) -> usize {
        self.total_requests
    }

    /// Latency percentile in microseconds (p in [0, 100]), over the
    /// (sampled) latency record.
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        if self.latencies_us.is_empty() {
            return 0;
        }
        let mut v = self.latencies_us.clone();
        v.sort_unstable();
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)]
    }

    /// Mean batch size, where "batch" is the coalesced request group the
    /// batcher handed to the backend in one call — a scheduling metric. A
    /// backend may further chunk the group internally (the XLA ladder
    /// executes e.g. 20 requests as 8+8+1+1+1+1); that executable width is
    /// not what is recorded here.
    pub fn mean_batch(&self) -> f64 {
        if self.total_requests == 0 {
            return 0.0;
        }
        self.batch_sum as f64 / self.total_requests as f64
    }

    /// Merge another recorder into this one (latency samples concatenate
    /// up to the reservoir cap).
    pub fn merge(&mut self, other: &ServeStats) {
        self.latencies_us.extend_from_slice(&other.latencies_us);
        self.latencies_us.truncate(Self::EXACT_CAP);
        self.batch_sum += other.batch_sum;
        self.total_requests += other.total_requests;
    }
}

/// Power-of-two latency histogram: bucket 0 holds sub-microsecond requests,
/// bucket `b ≥ 1` holds latencies in `[2^(b-1), 2^b)` µs. Fixed 32 buckets
/// (the last one saturates), so snapshots are cheap to clone and merge.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    buckets: [u64; 32],
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram { buckets: [0; 32] }
    }
}

impl LatencyHistogram {
    /// New empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one latency in microseconds.
    pub fn record_us(&mut self, us: u64) {
        let b = (64 - us.leading_zeros() as usize).min(31);
        self.buckets[b] += 1;
    }

    /// Total recorded samples.
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Occupied buckets as `(lo_us, hi_us_exclusive, count)` triples.
    pub fn nonzero_buckets(&self) -> Vec<(u64, u64, u64)> {
        self.buckets
            .iter()
            .enumerate()
            .filter(|&(_, &n)| n > 0)
            .map(|(b, &n)| {
                let lo = if b == 0 { 0 } else { 1u64 << (b - 1) };
                (lo, 1u64 << b, n)
            })
            .collect()
    }

    /// Upper bound (exclusive, µs) of the bucket containing percentile `p`.
    pub fn percentile_bound_us(&self, p: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (p / 100.0 * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (b, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= target {
                return 1u64 << b;
            }
        }
        1u64 << 31
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
    }
}

/// Modeled-hardware figures for the accelerator a session's datapath
/// simulates: the §V system roll-up (area / latency / energy / power /
/// TOPS-derived metrics) at the session's technology, channel count, and
/// bitstream length. `None` for the XLA backend (it models no SC hardware).
#[derive(Debug, Clone, Copy)]
pub struct HardwareEstimate {
    /// Logic technology.
    pub tech: TechKind,
    /// Channel count.
    pub channels: usize,
    /// Bitstream length the hardware is evaluated at — the largest
    /// per-stage length under a per-layer plan (the `k` for uniform
    /// plans); the schedule behind `metrics` is per-layer-k exact either
    /// way.
    pub k: usize,
    /// The system metrics (per-inference latency/energy, ADP/EDP/EDAP...).
    pub metrics: SystemMetrics,
}

impl HardwareEstimate {
    /// Evaluate the paper's system model for one uniform-`k` configuration
    /// on one workload (SRAM/memory fixed at the §V setup). Channel
    /// characterization is cached per technology for the process lifetime.
    pub fn for_config(tech: TechKind, channels: usize, k: usize, net: &NetworkSpec) -> Self {
        Self::for_plan(tech, channels, &PrecisionPlan::uniform(k.max(1), net.n_compute()), net)
    }

    /// [`HardwareEstimate::for_config`] under a per-layer
    /// [`PrecisionPlan`]: the modeled schedule costs each compute layer at
    /// its own bitstream length (`k` reports the plan's maximum).
    pub fn for_plan(
        tech: TechKind,
        channels: usize,
        precision: &PrecisionPlan,
        net: &NetworkSpec,
    ) -> Self {
        Self::for_plan_density(tech, channels, precision, net, &[])
    }

    /// [`HardwareEstimate::for_plan`] under a per-compute-layer surviving
    /// weight-lane density (see
    /// [`crate::accel::network::weight_densities`]): the modeled schedule
    /// drops pruned lanes from the SNG/APC datapath, so per-layer `k` and
    /// density compound through delay, energy, and TOPS. An empty slice
    /// models the dense plan.
    pub fn for_plan_density(
        tech: TechKind,
        channels: usize,
        precision: &PrecisionPlan,
        net: &NetworkSpec,
        densities: &[f64],
    ) -> Self {
        // Same robustness contract as for_config's k.max(1): a zero-cycle
        // stage would evaluate to a zero-latency layer and poison the
        // power quotient. (Engine paths validate plans before this.)
        let clamped;
        let precision = if precision.ks().contains(&0) {
            clamped =
                PrecisionPlan::per_layer(precision.ks().iter().map(|&k| k.max(1)).collect());
            &clamped
        } else {
            precision
        };
        let channel = cached_channel_report(tech);
        let cfg = SystemConfig {
            tech,
            channels: channels.max(1),
            k: precision.max_k().max(1),
            sram: SramMacro::paper_10kb(),
            memory: MemoryModel::gddr5_paper(),
        };
        let eval = evaluate_with_channel_sparse(&cfg, net, channel, precision, densities);
        HardwareEstimate { tech, channels: cfg.channels, k: cfg.k, metrics: eval.metrics }
    }
}

/// Channel characterization for a technology, computed once per process
/// (it is deterministic per [`TechKind`] and gate-level-simulation heavy).
pub fn cached_channel_report(tech: TechKind) -> &'static ChannelReport {
    static FINFET: OnceLock<ChannelReport> = OnceLock::new();
    static RFET: OnceLock<ChannelReport> = OnceLock::new();
    let cell = match tech {
        TechKind::Finfet10 => &FINFET,
        TechKind::Rfet10 => &RFET,
    };
    cell.get_or_init(|| characterize_channel(tech))
}

/// Snapshot of one session's serving statistics plus its modeled-hardware
/// estimate — the single reporting struct behind `serve`, `simulate`, and
/// `sweep`.
#[derive(Debug, Clone)]
pub struct SessionMetrics {
    /// Backend label (e.g. `stochastic-fused`).
    pub backend: String,
    /// Requests completed successfully.
    pub requests: usize,
    /// Requests rejected (malformed input).
    pub rejected: usize,
    /// Requests that reached the backend but failed during execution.
    pub failed: usize,
    /// Batches executed.
    pub batches: usize,
    /// Client-side deadline misses (`EngineConfig::with_deadline`): waits
    /// that resolved to a typed `Timeout` instead of a response.
    pub timeouts: usize,
    /// Times the worker fell back to a degraded precision plan after
    /// sustained SLO breaches (`EngineConfig::with_degrade`).
    pub degrade_events: usize,
    /// Warning-severity diagnostics the [`crate::analyze`] pre-flight
    /// raised when the session opened (error-severity diagnostics refuse
    /// the open with `EngineError::Analysis` instead, so a live session
    /// never carries errors here).
    pub analysis_warnings: usize,
    /// Lane-cycle products the compiled plan actually executed for the
    /// session's completed inferences (see
    /// [`crate::accel::network::ForwardPlan::ops_per_image`]). Zero for
    /// backends without a compiled SC plan (XLA).
    pub ops_executed: u64,
    /// Lane-cycle products skipped by sparsity — pruned weight lanes plus
    /// runtime zero-activation tiles. `ops_executed + ops_skipped` is
    /// invariant for a given net/precision, so the skip ratio is the
    /// fraction of dense work the plan avoided.
    pub ops_skipped: u64,
    /// Wall time since the session was opened.
    pub wall: Duration,
    /// Exact per-request records (percentiles, mean batch).
    pub serve: ServeStats,
    /// Log₂ latency histogram.
    pub histogram: LatencyHistogram,
    /// Modeled-hardware figures (None for the XLA backend).
    pub estimate: Option<HardwareEstimate>,
}

impl SessionMetrics {
    /// Mean coalesced batch size (see [`ServeStats::mean_batch`]).
    pub fn mean_batch(&self) -> f64 {
        self.serve.mean_batch()
    }

    /// Exact latency percentile in µs.
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        self.serve.latency_percentile_us(p)
    }

    /// Completed requests per second of session wall time.
    pub fn throughput_rps(&self) -> f64 {
        let secs = self.wall.as_secs_f64();
        if secs > 0.0 {
            self.requests as f64 / secs
        } else {
            0.0
        }
    }

    /// Modeled energy for every completed inference (µJ), when the session
    /// has a hardware estimate.
    pub fn estimated_total_energy_uj(&self) -> Option<f64> {
        self.estimate.map(|e| e.metrics.energy_uj * self.requests as f64)
    }

    /// Multi-line human-readable report (the common tail of `serve` /
    /// `simulate` output).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "backend {}: {} requests ({} rejected, {} failed) in {} batches, mean batch {:.1}\n",
            self.backend,
            self.requests,
            self.rejected,
            self.failed,
            self.batches,
            self.mean_batch()
        );
        s.push_str(&format!(
            "latency p50 {} µs  p99 {} µs  throughput {:.0} req/s\n",
            self.latency_percentile_us(50.0),
            self.latency_percentile_us(99.0),
            self.throughput_rps()
        ));
        if self.timeouts > 0 || self.degrade_events > 0 {
            s.push_str(&format!(
                "resilience: {} deadline timeouts, {} precision degrade events\n",
                self.timeouts, self.degrade_events
            ));
        }
        if self.analysis_warnings > 0 {
            s.push_str(&format!(
                "static analysis: {} warning(s) at open (run `scnn analyze` for details)\n",
                self.analysis_warnings
            ));
        }
        if self.ops_skipped > 0 {
            let total = self.ops_executed + self.ops_skipped;
            s.push_str(&format!(
                "sparsity: {} lane-cycle ops executed, {} skipped ({:.1}% of dense)\n",
                self.ops_executed,
                self.ops_skipped,
                100.0 * self.ops_skipped as f64 / total as f64
            ));
        }
        if let Some(e) = self.estimate {
            let m = &e.metrics;
            s.push_str(&format!(
                "modeled hardware: {} ×{}ch @ k={} — {:.3} mm², {:.2} µs, {:.3} µJ/inf, \
                 {:.2} TOPS/W",
                e.tech,
                e.channels,
                e.k,
                m.area_mm2,
                m.latency_us,
                m.energy_uj,
                m.tops_per_watt()
            ));
            if let Some(total) = self.estimated_total_energy_uj() {
                s.push_str(&format!(" ({total:.1} µJ modeled for this run)"));
            }
            s.push('\n');
        }
        s
    }
}

/// Per-tenant request accounting, recorded by the serving front door via
/// [`crate::engine::EnginePool::note_tenant`] and surfaced both here and
/// in the Prometheus exposition.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TenantStats {
    /// Tenant name (`"anonymous"` when no tenants are configured).
    pub tenant: String,
    /// Requests answered successfully.
    pub requests: u64,
    /// Requests bounced by the tenant's own token-bucket quota.
    pub quota_rejected: u64,
    /// Requests shed by pool admission control while acting for this
    /// tenant.
    pub shed: u64,
    /// Requests that failed for any other reason (backend error,
    /// timeout, malformed input).
    pub failed: u64,
}

/// Aggregated snapshot of an [`crate::engine::EnginePool`]: the merged
/// roll-up every dashboard wants (one latency record, one histogram, one
/// throughput figure) plus the per-shard [`SessionMetrics`] behind it and
/// the pool-level counters no single session can see (admission sheds,
/// reroutes, shard health).
#[derive(Debug, Clone)]
pub struct PoolMetrics {
    /// Backend label of the shards (`a+b` when heterogeneous).
    pub backend: String,
    /// Total shard count.
    pub shards: usize,
    /// Shards currently healthy (worker alive, not closed).
    pub healthy: usize,
    /// Requests completed successfully, summed over shards.
    pub requests: usize,
    /// Requests rejected by sessions (malformed input), summed.
    pub rejected: usize,
    /// Requests shed by pool admission control (typed `Rejected`).
    pub shed: usize,
    /// Requests rerouted away from a dying shard.
    pub rerouted: usize,
    /// Requests that reached a backend and failed there, summed.
    pub failed: usize,
    /// Batches executed, summed over shards.
    pub batches: usize,
    /// Client-side deadline misses, summed over shards.
    pub timeouts: usize,
    /// Precision degrade events, summed over shards — how often workers
    /// fell back to cheaper plans instead of failing their SLO.
    pub degrade_events: usize,
    /// Static-analysis warnings raised at shard open, summed over shards.
    pub analysis_warnings: usize,
    /// Lane-cycle products executed by compiled plans, summed over shards
    /// (see [`SessionMetrics::ops_executed`]).
    pub ops_executed: u64,
    /// Lane-cycle products skipped by sparsity, summed over shards (see
    /// [`SessionMetrics::ops_skipped`]).
    pub ops_skipped: u64,
    /// Wall time since the pool was opened.
    pub wall: Duration,
    /// Merged per-request latency record (percentiles, mean batch).
    pub serve: ServeStats,
    /// Merged log₂ latency histogram.
    pub histogram: LatencyHistogram,
    /// The per-shard snapshots the roll-up was built from.
    pub per_shard: Vec<SessionMetrics>,
    /// Headline modeled-hardware figures, from the **first
    /// estimate-bearing shard** (`None` only when no shard models SC
    /// hardware — e.g. an all-XLA pool). The pool-scaled roll-ups
    /// ([`PoolMetrics::modeled_area_mm2`],
    /// [`PoolMetrics::modeled_power_mw`],
    /// [`PoolMetrics::estimated_total_energy_uj`]) sum over *all* shards,
    /// so heterogeneous pools stay accounted.
    pub estimate: Option<HardwareEstimate>,
    /// Per-tenant accounting (sorted by tenant name), populated by
    /// [`crate::engine::EnginePool::metrics`] when a serving front door
    /// has recorded tenant outcomes; empty for in-process pools.
    pub tenants: Vec<TenantStats>,
}

impl PoolMetrics {
    /// Merge per-shard snapshots into the pool roll-up. The pool-level
    /// counters (`healthy`, `shed`, `rerouted`) come from the router, which
    /// is the only place they exist.
    pub fn aggregate(
        per_shard: Vec<SessionMetrics>,
        healthy: usize,
        shed: usize,
        rerouted: usize,
        wall: Duration,
    ) -> Self {
        let mut serve = ServeStats::new();
        let mut histogram = LatencyHistogram::new();
        let (mut requests, mut rejected, mut failed, mut batches) = (0, 0, 0, 0);
        let (mut timeouts, mut degrade_events, mut analysis_warnings) = (0, 0, 0);
        let (mut ops_executed, mut ops_skipped) = (0u64, 0u64);
        let mut labels: Vec<&str> = Vec::new();
        for m in &per_shard {
            serve.merge(&m.serve);
            histogram.merge(&m.histogram);
            requests += m.requests;
            rejected += m.rejected;
            failed += m.failed;
            batches += m.batches;
            timeouts += m.timeouts;
            degrade_events += m.degrade_events;
            analysis_warnings += m.analysis_warnings;
            ops_executed += m.ops_executed;
            ops_skipped += m.ops_skipped;
            if !labels.contains(&m.backend.as_str()) {
                labels.push(&m.backend);
            }
        }
        PoolMetrics {
            backend: labels.join("+"),
            shards: per_shard.len(),
            healthy,
            requests,
            rejected,
            shed,
            rerouted,
            failed,
            batches,
            timeouts,
            degrade_events,
            analysis_warnings,
            ops_executed,
            ops_skipped,
            wall,
            serve,
            histogram,
            estimate: per_shard.iter().find_map(|m| m.estimate),
            per_shard,
            tenants: Vec::new(),
        }
    }

    /// Mean coalesced batch size over all shards.
    pub fn mean_batch(&self) -> f64 {
        self.serve.mean_batch()
    }

    /// Merged latency percentile in µs.
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        self.serve.latency_percentile_us(p)
    }

    /// Completed requests per second of pool wall time.
    pub fn throughput_rps(&self) -> f64 {
        let secs = self.wall.as_secs_f64();
        if secs > 0.0 {
            self.requests as f64 / secs
        } else {
            0.0
        }
    }

    /// Per-shard throughput (req/s of each shard's own wall time), in
    /// shard order — the load-balance view.
    pub fn per_shard_throughput(&self) -> Vec<f64> {
        self.per_shard.iter().map(SessionMetrics::throughput_rps).collect()
    }

    /// Modeled silicon area of the whole pool: one accelerator instance
    /// per shard, summed (scales with shard count).
    pub fn modeled_area_mm2(&self) -> Option<f64> {
        sum_some(self.per_shard.iter().map(|m| m.estimate.map(|e| e.metrics.area_mm2)))
    }

    /// Modeled power of the whole pool (one accelerator per shard, summed).
    pub fn modeled_power_mw(&self) -> Option<f64> {
        sum_some(self.per_shard.iter().map(|m| m.estimate.map(|e| e.metrics.power_mw)))
    }

    /// Modeled energy for every completed inference across all shards (µJ).
    pub fn estimated_total_energy_uj(&self) -> Option<f64> {
        sum_some(self.per_shard.iter().map(SessionMetrics::estimated_total_energy_uj))
    }

    /// Multi-line human-readable report (the pool analogue of
    /// [`SessionMetrics::summary`]).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "pool [{}]: {}/{} shards healthy — {} requests ({} rejected, {} shed, \
             {} rerouted, {} failed) in {} batches, mean batch {:.1}\n",
            self.backend,
            self.healthy,
            self.shards,
            self.requests,
            self.rejected,
            self.shed,
            self.rerouted,
            self.failed,
            self.batches,
            self.mean_batch()
        );
        s.push_str(&format!(
            "latency p50 {} µs  p99 {} µs  throughput {:.0} req/s (per shard: {})\n",
            self.latency_percentile_us(50.0),
            self.latency_percentile_us(99.0),
            self.throughput_rps(),
            self.per_shard_throughput()
                .iter()
                .map(|t| format!("{t:.0}"))
                .collect::<Vec<_>>()
                .join("/")
        ));
        if self.timeouts > 0 || self.degrade_events > 0 {
            s.push_str(&format!(
                "resilience: {} deadline timeouts, {} precision degrade events\n",
                self.timeouts, self.degrade_events
            ));
        }
        if self.analysis_warnings > 0 {
            s.push_str(&format!(
                "static analysis: {} warning(s) at shard open\n",
                self.analysis_warnings
            ));
        }
        if let (Some(e), Some(area), Some(power)) =
            (self.estimate, self.modeled_area_mm2(), self.modeled_power_mw())
        {
            // Totals cover exactly the shards that model SC hardware; the
            // tech/k label describes the first of them (heterogeneous
            // pools may mix techs and k tiers).
            let modeled = self.per_shard.iter().filter(|m| m.estimate.is_some()).count();
            s.push_str(&format!(
                "modeled hardware ×{modeled} of {} shards (first: {} @ k={}) — \
                 {:.3} mm² total, {:.1} mW total",
                self.shards, e.tech, e.k, area, power
            ));
            if let Some(total) = self.estimated_total_energy_uj() {
                s.push_str(&format!(" ({total:.1} µJ modeled for this run)"));
            }
            s.push('\n');
        }
        for t in &self.tenants {
            s.push_str(&format!(
                "tenant {}: {} ok, {} quota-rejected, {} shed, {} failed\n",
                t.tenant, t.requests, t.quota_rejected, t.shed, t.failed
            ));
        }
        s
    }
}

/// Sum an iterator of optional figures; `None` once every element is
/// `None` (e.g. an all-XLA pool models no SC hardware).
fn sum_some(it: impl Iterator<Item = Option<f64>>) -> Option<f64> {
    let vals: Vec<f64> = it.flatten().collect();
    if vals.is_empty() {
        None
    } else {
        Some(vals.iter().sum())
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut s = ServeStats::new();
        for i in 1..=100u64 {
            s.record(Duration::from_micros(i), 1);
        }
        assert_eq!(s.count(), 100);
        assert!(s.latency_percentile_us(50.0) <= s.latency_percentile_us(99.0));
        assert_eq!(s.latency_percentile_us(0.0), 1);
        assert_eq!(s.latency_percentile_us(100.0), 100);
    }

    #[test]
    fn empty_stats_safe() {
        let s = ServeStats::new();
        assert_eq!(s.latency_percentile_us(99.0), 0);
        assert_eq!(s.mean_batch(), 0.0);
    }

    #[test]
    fn serve_stats_memory_is_bounded() {
        let mut s = ServeStats::new();
        let n = ServeStats::EXACT_CAP + 1000;
        for i in 0..n {
            s.record(Duration::from_micros(i as u64 % 500), 2);
        }
        assert_eq!(s.count(), n);
        assert!(s.latencies_us.len() <= ServeStats::EXACT_CAP, "latency reservoir is capped");
        assert!(s.latency_percentile_us(99.0) < 500, "sampled percentiles stay in range");
        assert_eq!(s.mean_batch(), 2.0, "batch mean covers every request, not just the sample");
    }

    #[test]
    fn merge_adds() {
        let mut a = ServeStats::new();
        a.record(Duration::from_micros(5), 2);
        let mut b = ServeStats::new();
        b.record(Duration::from_micros(7), 4);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.mean_batch(), 3.0);
    }

    #[test]
    fn histogram_buckets_by_power_of_two() {
        let mut h = LatencyHistogram::new();
        for us in [0u64, 1, 2, 3, 4, 1000, u64::MAX] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 7);
        let nz = h.nonzero_buckets();
        // 0 → [0,1); 1 → [1,2); 2,3 → [2,4); 4 → [4,8); 1000 → [512,1024);
        // u64::MAX saturates into the last bucket.
        assert_eq!(nz[0], (0, 1, 1));
        assert_eq!(nz[1], (1, 2, 1));
        assert_eq!(nz[2], (2, 4, 2));
        assert_eq!(nz[3], (4, 8, 1));
        assert!(nz.iter().any(|&(lo, hi, n)| lo == 512 && hi == 1024 && n == 1));
        assert_eq!(nz.last().unwrap().2, 1);
        let total: u64 = nz.iter().map(|&(_, _, n)| n).sum();
        assert_eq!(total, 7);
    }

    #[test]
    fn histogram_percentile_bound_monotone() {
        let mut h = LatencyHistogram::new();
        for i in 0..1000u64 {
            h.record_us(i);
        }
        assert!(h.percentile_bound_us(50.0) <= h.percentile_bound_us(99.0));
        assert!(h.percentile_bound_us(99.0) <= 1024);
        assert_eq!(LatencyHistogram::new().percentile_bound_us(50.0), 0);
    }

    #[test]
    fn histogram_merge_adds() {
        let mut a = LatencyHistogram::new();
        a.record_us(3);
        let mut b = LatencyHistogram::new();
        b.record_us(3);
        b.record_us(100);
        a.merge(&b);
        assert_eq!(a.count(), 3);
    }

    #[test]
    fn hardware_estimate_matches_direct_evaluation() {
        use crate::accel::system;
        let net = NetworkSpec::lenet5();
        let est = HardwareEstimate::for_config(TechKind::Rfet10, 8, 32, &net);
        let direct = system::evaluate(&SystemConfig::paper(TechKind::Rfet10, 8), &net);
        assert!((est.metrics.area_mm2 - direct.metrics.area_mm2).abs() < 1e-12);
        assert!((est.metrics.energy_uj - direct.metrics.energy_uj).abs() < 1e-12);
        // Cached characterization: a second call is consistent.
        let again = HardwareEstimate::for_config(TechKind::Rfet10, 8, 32, &net);
        assert!((again.metrics.latency_us - est.metrics.latency_us).abs() < 1e-12);
    }

    #[test]
    fn for_plan_matches_for_config_on_uniform_and_reports_max_k() {
        let net = NetworkSpec::lenet5();
        let uniform = HardwareEstimate::for_config(TechKind::Rfet10, 8, 64, &net);
        let planned =
            HardwareEstimate::for_plan(TechKind::Rfet10, 8, &PrecisionPlan::uniform(64, 5), &net);
        assert_eq!(planned.k, 64);
        assert!((planned.metrics.energy_uj - uniform.metrics.energy_uj).abs() < 1e-12);
        assert!((planned.metrics.latency_us - uniform.metrics.latency_us).abs() < 1e-12);
        let tapered = HardwareEstimate::for_plan(
            TechKind::Rfet10,
            8,
            &PrecisionPlan::per_layer(vec![64, 32, 32, 32, 64]),
            &net,
        );
        assert_eq!(tapered.k, 64, "the estimate labels the plan's largest k");
        assert!(tapered.metrics.energy_uj < uniform.metrics.energy_uj);
    }

    #[test]
    fn for_plan_density_lowers_energy_and_is_dense_on_empty() {
        let net = NetworkSpec::lenet5();
        let plan = PrecisionPlan::uniform(64, 5);
        let dense = HardwareEstimate::for_plan(TechKind::Rfet10, 8, &plan, &net);
        let empty = HardwareEstimate::for_plan_density(TechKind::Rfet10, 8, &plan, &net, &[]);
        assert!((empty.metrics.energy_uj - dense.metrics.energy_uj).abs() < 1e-12);
        assert!((empty.metrics.latency_us - dense.metrics.latency_us).abs() < 1e-12);
        let sparse = HardwareEstimate::for_plan_density(
            TechKind::Rfet10,
            8,
            &plan,
            &net,
            &[0.25; 5],
        );
        assert!(sparse.metrics.energy_uj < dense.metrics.energy_uj);
        assert!(
            (sparse.metrics.area_mm2 - dense.metrics.area_mm2).abs() < 1e-12,
            "pruning is a schedule effect, not a silicon change"
        );
    }

    fn fake_session_metrics(backend: &str, lat_us: u64, with_estimate: bool) -> SessionMetrics {
        let net = NetworkSpec::lenet5();
        let mut serve = ServeStats::new();
        serve.record(Duration::from_micros(lat_us), 2);
        serve.record(Duration::from_micros(lat_us * 2), 2);
        let mut histogram = LatencyHistogram::new();
        histogram.record_us(lat_us);
        histogram.record_us(lat_us * 2);
        SessionMetrics {
            backend: backend.into(),
            requests: 2,
            rejected: 1,
            failed: 0,
            batches: 1,
            timeouts: 1,
            degrade_events: 2,
            analysis_warnings: 0,
            ops_executed: 1000,
            ops_skipped: 0,
            wall: Duration::from_millis(10),
            serve,
            histogram,
            estimate: with_estimate
                .then(|| HardwareEstimate::for_config(TechKind::Rfet10, 8, 32, &net)),
        }
    }

    #[test]
    fn pool_metrics_merge_shards_and_scale_hardware() {
        let a = fake_session_metrics("stochastic-fused", 100, true);
        let b = fake_session_metrics("stochastic-fused", 400, true);
        let one_shard_area = a.estimate.unwrap().metrics.area_mm2;
        let one_shard_energy = a.estimated_total_energy_uj().unwrap();
        let m = PoolMetrics::aggregate(vec![a, b], 2, 3, 1, Duration::from_millis(20));
        assert_eq!(m.backend, "stochastic-fused");
        assert_eq!(m.shards, 2);
        assert_eq!(m.healthy, 2);
        assert_eq!(m.requests, 4);
        assert_eq!(m.rejected, 2);
        assert_eq!(m.shed, 3);
        assert_eq!(m.rerouted, 1);
        assert_eq!(m.batches, 2);
        assert_eq!(m.timeouts, 2, "deadline misses sum over shards");
        assert_eq!(m.degrade_events, 4, "degrade events sum over shards");
        assert_eq!(m.ops_executed, 2000, "executed lane-cycle ops sum over shards");
        assert_eq!(m.ops_skipped, 0);
        assert!(m.summary().contains("2 deadline timeouts, 4 precision degrade events"));
        assert_eq!(m.serve.count(), 4);
        assert_eq!(m.histogram.count(), 4);
        assert!(m.latency_percentile_us(50.0) <= m.latency_percentile_us(99.0));
        assert!(m.throughput_rps() > 0.0);
        assert_eq!(m.per_shard_throughput().len(), 2);
        // Hardware roll-ups scale with shard count.
        assert!((m.modeled_area_mm2().unwrap() - 2.0 * one_shard_area).abs() < 1e-9);
        assert!(
            (m.estimated_total_energy_uj().unwrap() - 2.0 * one_shard_energy).abs() < 1e-9
        );
        let text = m.summary();
        assert!(text.contains("2/2 shards healthy"), "{text}");
        assert!(text.contains("3 shed"), "{text}");
        assert!(text.contains("modeled hardware ×2 of 2 shards"), "{text}");
    }

    #[test]
    fn pool_metrics_heterogeneous_labels_and_missing_estimates() {
        let a = fake_session_metrics("xla", 50, false);
        let b = fake_session_metrics("expectation", 60, true);
        let m = PoolMetrics::aggregate(
            vec![a, b.clone()],
            1,
            0,
            0,
            Duration::from_millis(5),
        );
        assert_eq!(m.backend, "xla+expectation");
        assert!(
            m.estimate.is_some(),
            "the first estimate-bearing shard supplies the headline figures"
        );
        assert!(
            m.summary().contains("modeled hardware"),
            "a mixed pool still reports its hardware totals: {}",
            m.summary()
        );
        // The scaled roll-ups count exactly the shards that model hardware.
        let exp_area = b.estimate.unwrap().metrics.area_mm2;
        assert!((m.modeled_area_mm2().unwrap() - exp_area).abs() < 1e-12);
        let none = PoolMetrics::aggregate(
            vec![fake_session_metrics("xla", 50, false)],
            1,
            0,
            0,
            Duration::from_millis(5),
        );
        assert!(none.modeled_area_mm2().is_none());
        assert!(none.estimated_total_energy_uj().is_none());
    }

    #[test]
    fn session_metrics_summary_mentions_backend_and_estimate() {
        let net = NetworkSpec::lenet5();
        let mut serve = ServeStats::new();
        serve.record(Duration::from_micros(100), 4);
        let mut histogram = LatencyHistogram::new();
        histogram.record_us(100);
        let m = SessionMetrics {
            backend: "stochastic-fused".into(),
            requests: 1,
            rejected: 0,
            failed: 0,
            batches: 1,
            timeouts: 0,
            degrade_events: 0,
            analysis_warnings: 0,
            ops_executed: 0,
            ops_skipped: 0,
            wall: Duration::from_millis(10),
            serve,
            histogram,
            estimate: Some(HardwareEstimate::for_config(TechKind::Rfet10, 8, 32, &net)),
        };
        let text = m.summary();
        assert!(text.contains("stochastic-fused"));
        assert!(text.contains("modeled hardware"));
        assert!(
            !text.contains("resilience:"),
            "a clean run's summary carries no resilience line: {text}"
        );
        let degraded = SessionMetrics { degrade_events: 1, ..m.clone() };
        assert!(degraded.summary().contains("0 deadline timeouts, 1 precision degrade"));
        assert!(
            !m.summary().contains("static analysis:"),
            "a clean open's summary carries no analysis line"
        );
        let warned = SessionMetrics { analysis_warnings: 2, ..m.clone() };
        assert!(warned.summary().contains("static analysis: 2 warning"));
        assert!(
            !m.summary().contains("sparsity:"),
            "a dense run's summary carries no sparsity line"
        );
        let sparse = SessionMetrics { ops_executed: 750, ops_skipped: 250, ..m.clone() };
        assert!(
            sparse.summary().contains("sparsity: 750 lane-cycle ops executed, 250 skipped"),
            "{}",
            sparse.summary()
        );
        assert!(sparse.summary().contains("25.0% of dense"), "{}", sparse.summary());
        assert!(m.throughput_rps() > 0.0);
        assert!(m.estimated_total_energy_uj().unwrap() > 0.0);
    }
}
