//! Typed engine configuration: which datapath to run ([`BackendKind`]),
//! where weights come from ([`WeightSource`]), how requests coalesce
//! ([`BatchPolicy`]), and every numeric knob (bitstream length, precision,
//! threads, modeled technology) in one builder-style [`EngineConfig`] —
//! replacing the stringly `HashMap<String, String>` flag plumbing that used
//! to be hand-wired separately in `main.rs`, the examples, and the benches.

use crate::accel::layers::NetworkSpec;
use crate::accel::network::{ForwardMode, KernelPath, QuantizedWeights, SparsityPolicy};
use crate::faults::FaultPlan;
use crate::accel::precision::{
    self, AutoTuneConfig, Precision, PrecisionError, PrecisionPlan,
};
use crate::data::ModelWeights;
use crate::engine::error::EngineError;
use crate::engine::metrics::HardwareEstimate;
use crate::tech::TechKind;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::str::FromStr;
use std::sync::{Mutex, OnceLock};
use std::time::Duration;

/// Which datapath a session executes. Every kind is constructible from an
/// [`EngineConfig`] alone; see the crate-level backend matrix for the
/// accuracy/speed contract of each.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Fused word-packed bit-exact SC engine (the production path).
    StochasticFused,
    /// Per-bit allocating golden reference — slow, bit-identical to
    /// `StochasticFused` by construction (asserted in the parity tests).
    ReferencePerBit,
    /// SC expectation model (no sampling noise) over the same quantized
    /// codes — mirrors the JAX training graph.
    Expectation,
    /// Expectation plus analytic k-cycle sampling noise (§V-B methodology).
    NoisyExpectation,
    /// Plain fixed-point MAC + hard ReLU (the Fig. 12 binary baseline).
    FixedPoint,
    /// AOT-compiled HLO graphs executed through PJRT (the serving ladder).
    Xla,
}

impl BackendKind {
    /// Every backend kind, for sweeps and parity tests.
    pub const ALL: [BackendKind; 6] = [
        BackendKind::StochasticFused,
        BackendKind::ReferencePerBit,
        BackendKind::Expectation,
        BackendKind::NoisyExpectation,
        BackendKind::FixedPoint,
        BackendKind::Xla,
    ];

    /// Stable lowercase label (CLI values, metrics, bench records).
    pub fn label(self) -> &'static str {
        match self {
            BackendKind::StochasticFused => "stochastic-fused",
            BackendKind::ReferencePerBit => "reference-per-bit",
            BackendKind::Expectation => "expectation",
            BackendKind::NoisyExpectation => "noisy-expectation",
            BackendKind::FixedPoint => "fixed-point",
            BackendKind::Xla => "xla",
        }
    }

    /// The [`ForwardMode`] this kind lowers to, for the in-process plan
    /// backends (`None` for [`BackendKind::ReferencePerBit`] and
    /// [`BackendKind::Xla`], which do not run through a `ForwardPlan`).
    pub fn forward_mode(self, k: usize, seed: u32) -> Option<ForwardMode> {
        match self {
            BackendKind::StochasticFused => Some(ForwardMode::Stochastic { k, seed }),
            BackendKind::Expectation => Some(ForwardMode::Expectation),
            BackendKind::NoisyExpectation => Some(ForwardMode::NoisyExpectation { k, seed }),
            BackendKind::FixedPoint => Some(ForwardMode::FixedPoint),
            BackendKind::ReferencePerBit | BackendKind::Xla => None,
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

impl FromStr for BackendKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "stochastic" | "sc" | "fused" | "stochastic-fused" => BackendKind::StochasticFused,
            "reference" | "reference-per-bit" | "per-bit" => BackendKind::ReferencePerBit,
            "expectation" | "exp" => BackendKind::Expectation,
            "noisy" | "noisy-expectation" => BackendKind::NoisyExpectation,
            "fixed" | "fixed-point" | "binary" => BackendKind::FixedPoint,
            "xla" | "pjrt" => BackendKind::Xla,
            other => bail!(
                "unknown backend {other:?} \
                 (stochastic|reference|expectation|noisy|fixed|xla)"
            ),
        })
    }
}

/// Where a session's weights come from. `Float` and `File` weights are
/// quantized to [`EngineConfig::bits`] at open; `Quantized` weights carry
/// their own precision (which must agree with the config).
#[derive(Debug, Clone)]
pub enum WeightSource {
    /// No weights (only valid for [`BackendKind::Xla`]).
    None,
    /// Trained float weights, quantized at session open.
    Float(ModelWeights),
    /// Pre-quantized codes (bits taken from the payload).
    Quantized(QuantizedWeights),
    /// A `SCNNW1` weights file loaded (then quantized) at session open.
    File(PathBuf),
}

/// Dynamic-batching policy of a session's worker.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Largest request group executed as one batch.
    pub max_batch: usize,
    /// How long the batcher lingers to coalesce concurrent requests.
    pub linger: Duration,
    /// Backpressure bound: `submit` blocks once this many requests are
    /// in flight (queued or executing).
    pub queue_depth: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 32, linger: Duration::from_millis(2), queue_depth: 256 }
    }
}

/// Graceful-degradation policy of a session's worker: when service quality
/// breaches the SLO for a sustained window — batch latency over
/// [`DegradePolicy::latency_slo`], or a failing backend — the worker falls
/// back to a cheaper [`PrecisionPlan`] (halving every stage's `k`, floored
/// at [`DegradePolicy::min_k`]) instead of letting the session drown or
/// die. Transitions are counted in
/// [`crate::engine::SessionMetrics::degrade_events`].
#[derive(Debug, Clone, Copy)]
pub struct DegradePolicy {
    /// Per-batch service-latency objective; a batch slower than this is
    /// one breach.
    pub latency_slo: Duration,
    /// Consecutive breaches before the worker degrades one precision step.
    pub breach_window: usize,
    /// Lowest per-stage bitstream length the fallback may reach (clamped
    /// to the [`precision::WORD`] alignment the kernels require).
    pub min_k: usize,
}

impl Default for DegradePolicy {
    fn default() -> Self {
        DegradePolicy {
            latency_slo: Duration::from_millis(250),
            breach_window: 8,
            min_k: precision::WORD,
        }
    }
}

/// Typed, builder-style configuration for [`crate::engine::Engine::open`].
///
/// ```no_run
/// use scnn::accel::layers::NetworkSpec;
/// use scnn::engine::{BackendKind, Engine, EngineConfig};
///
/// let cfg = EngineConfig::new(BackendKind::StochasticFused, NetworkSpec::lenet5())
///     .with_weights_file("artifacts/lenet5_sc.weights.bin")
///     .with_k(256)
///     .with_bits(8);
/// let session = Engine::open(cfg).unwrap();
/// let _logits = session.infer(vec![0.0; 28 * 28]).unwrap();
/// ```
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Datapath to execute.
    pub backend: BackendKind,
    /// Network topology (also defines input/output lengths for XLA).
    pub net: NetworkSpec,
    /// Weight source for the in-process datapaths.
    pub weights: WeightSource,
    /// Quantization precision in bits.
    pub bits: u32,
    /// Bitstream-length policy (stochastic / noisy kinds): one global `k`
    /// ([`Precision::Uniform`], what [`EngineConfig::with_k`] sets), an
    /// explicit per-compute-layer assignment ([`Precision::PerLayer`]), or
    /// the greedy accuracy-budget autotuner ([`Precision::Auto`]). Resolved
    /// into a compiled [`PrecisionPlan`] at session open.
    pub precision: Precision,
    /// Master seed for every SNG lane / noise draw.
    pub seed: u32,
    /// Compute-thread cap for the in-process datapaths (0 = all cores).
    pub threads: usize,
    /// Dynamic-batching policy.
    pub batch: BatchPolicy,
    /// Modeled logic technology (hardware estimate).
    pub tech: TechKind,
    /// Modeled channel count (hardware estimate).
    pub channels: usize,
    /// PJRT executable ladder as (batch_size, HLO path); must include
    /// batch size 1 ([`BackendKind::Xla`] only).
    pub hlo_ladder: Vec<(usize, PathBuf)>,
    /// Optional fault-injection plan compiled into the datapath (see
    /// [`crate::faults::FaultPlan`]); `None` = clean silicon.
    pub faults: Option<FaultPlan>,
    /// Stochastic compute-kernel selection (see [`KernelPath`]):
    /// `Auto` (default) resolves to the bit-plane transposed kernel;
    /// `Fused` pins the lane-at-a-time baseline. Bit-exact either way —
    /// only [`BackendKind::StochasticFused`] plans are affected.
    pub kernel: KernelPath,
    /// Compile-time weight-sparsity policy (see [`SparsityPolicy`]):
    /// [`SparsityPolicy::OFF`] (the default) compiles dense plans
    /// bit-for-bit; an active threshold prunes near-zero weight lanes into
    /// per-channel skip lists at plan compile, on every plan backend. A
    /// compiled-artifact input: plans differing only in sparsity are
    /// distinct cache entries.
    pub sparsity: SparsityPolicy,
    /// Optional client-side deadline: `infer` / `drain` calls stop waiting
    /// after this long and return [`EngineError::Timeout`] instead of
    /// blocking forever on a stuck worker.
    pub deadline: Option<Duration>,
    /// Optional graceful-degradation policy (see [`DegradePolicy`]).
    pub degrade: Option<DegradePolicy>,
    /// Chaos hook: the worker panics (while holding the metrics lock)
    /// after serving this many requests — exercises shard-death rerouting
    /// and lock-poisoning recovery under test. Never set in production.
    pub chaos_panic_after: Option<usize>,
    /// Chaos hook: the worker sleeps this long before every batch —
    /// injects a slow shard for SLO/timeout tests. Never set in
    /// production.
    pub chaos_slow: Option<Duration>,
}

impl EngineConfig {
    /// A configuration with the paper's defaults (k = 32, 8-bit precision,
    /// RFET 10 nm × 8 channels, 32-deep dynamic batching).
    pub fn new(backend: BackendKind, net: NetworkSpec) -> Self {
        EngineConfig {
            backend,
            net,
            weights: WeightSource::None,
            bits: 8,
            precision: Precision::Uniform(32),
            seed: 7,
            threads: 0,
            batch: BatchPolicy::default(),
            tech: TechKind::Rfet10,
            channels: 8,
            hlo_ladder: Vec::new(),
            faults: None,
            kernel: KernelPath::Auto,
            sparsity: SparsityPolicy::OFF,
            deadline: None,
            degrade: None,
            chaos_panic_after: None,
            chaos_slow: None,
        }
    }

    /// Use trained float weights (quantized at [`EngineConfig::bits`]).
    pub fn with_weights(mut self, w: ModelWeights) -> Self {
        self.weights = WeightSource::Float(w);
        self
    }

    /// Use pre-quantized weights (also adopts their precision).
    pub fn with_quantized(mut self, w: QuantizedWeights) -> Self {
        self.bits = w.bits;
        self.weights = WeightSource::Quantized(w);
        self
    }

    /// Load weights from a `SCNNW1` file at session open.
    pub fn with_weights_file(mut self, path: impl Into<PathBuf>) -> Self {
        self.weights = WeightSource::File(path.into());
        self
    }

    /// Set a uniform bitstream length (shorthand for
    /// `with_precision(Precision::Uniform(k))` — the back-compat path).
    pub fn with_k(mut self, k: usize) -> Self {
        self.precision = Precision::Uniform(k);
        self
    }

    /// Set the full bitstream-length policy (uniform / per-layer / auto).
    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }

    /// `Some(k)` when the policy is a single global length (uniform, or a
    /// per-layer plan whose stages all agree) — the replacement for
    /// reading the old scalar `k` field.
    pub fn uniform_k(&self) -> Option<usize> {
        match &self.precision {
            Precision::Uniform(k) => Some(*k),
            Precision::PerLayer(ks) => precision::uniform_of(ks),
            Precision::Auto { .. } => None,
        }
    }

    /// Set the SNG/noise master seed.
    pub fn with_seed(mut self, seed: u32) -> Self {
        self.seed = seed;
        self
    }

    /// Set the quantization precision in bits.
    pub fn with_bits(mut self, bits: u32) -> Self {
        self.bits = bits;
        self
    }

    /// Cap compute threads (0 = all cores).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Set the dynamic-batching policy.
    pub fn with_batch(mut self, batch: BatchPolicy) -> Self {
        self.batch = batch;
        self
    }

    /// Set the modeled logic technology.
    pub fn with_tech(mut self, tech: TechKind) -> Self {
        self.tech = tech;
        self
    }

    /// Set the modeled channel count.
    pub fn with_channels(mut self, channels: usize) -> Self {
        self.channels = channels;
        self
    }

    /// Set the PJRT executable ladder ([`BackendKind::Xla`]).
    pub fn with_hlo_ladder(mut self, ladder: Vec<(usize, PathBuf)>) -> Self {
        self.hlo_ladder = ladder;
        self
    }

    /// Compile a fault-injection plan into the datapath.
    pub fn with_faults(mut self, faults: FaultPlan) -> Self {
        self.faults = Some(faults);
        self
    }

    /// Select the stochastic compute kernel (fused baseline vs bit-plane
    /// transposed; `Auto` = transposed). A compiled-artifact input: plans
    /// differing only in their resolved kernel are distinct cache entries.
    pub fn with_kernel(mut self, kernel: KernelPath) -> Self {
        self.kernel = kernel;
        self
    }

    /// Set the compile-time weight-sparsity policy. Like
    /// [`EngineConfig::with_kernel`] this is a compiled-artifact input:
    /// sessions differing only in their sparsity policy compile distinct
    /// plans. Degenerate thresholds (negative, non-finite, ≥ 1.0) are
    /// refused at [`EngineConfig::validate`] with
    /// [`EngineError::InvalidSparsity`].
    pub fn with_sparsity(mut self, sparsity: SparsityPolicy) -> Self {
        self.sparsity = sparsity;
        self
    }

    /// Set a client-side deadline for `infer` / `drain` waits.
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Enable graceful precision degradation under sustained SLO breach.
    pub fn with_degrade(mut self, policy: DegradePolicy) -> Self {
        self.degrade = Some(policy);
        self
    }

    /// Chaos hook: panic the worker after serving `n` requests (tests).
    pub fn with_chaos_panic_after(mut self, n: usize) -> Self {
        self.chaos_panic_after = Some(n);
        self
    }

    /// Chaos hook: sleep before every batch (slow-shard injection, tests).
    pub fn with_chaos_slow(mut self, delay: Duration) -> Self {
        self.chaos_slow = Some(delay);
        self
    }

    /// Flattened input length (c·h·w of the network input).
    pub fn input_len(&self) -> usize {
        let (c, h, w) = self.net.input;
        c * h * w
    }

    /// Flattened output length (class count).
    pub fn output_len(&self) -> usize {
        let (c, h, w) = self.net.output_shape();
        c * h * w
    }

    /// Check internal consistency without building anything. Runs the
    /// network's full shape-inference pass ([`NetworkSpec::validate`]), so
    /// malformed stacks — channel mismatches, non-divisible pool windows,
    /// dangling residuals — surface here as typed errors instead of
    /// panicking deep inside plan compilation.
    pub fn validate(&self) -> Result<()> {
        if self.net.layers.is_empty() {
            bail!("engine config: network {:?} has no layers", self.net.name);
        }
        self.net
            .validate()
            .map(|_| ())
            .map_err(|e| e.context(format!("engine config: network {:?}", self.net.name)))?;
        match self.backend {
            BackendKind::Xla => {
                if self.hlo_ladder.is_empty() {
                    bail!("engine config: the xla backend needs with_hlo_ladder(...)");
                }
            }
            kind => {
                if matches!(self.weights, WeightSource::None) {
                    bail!(
                        "engine config: backend {kind} needs weights \
                         (with_weights / with_quantized / with_weights_file)"
                    );
                }
                if self.bits == 0 || self.bits > 16 {
                    bail!("engine config: precision must be 1..=16 bits, got {}", self.bits);
                }
                self.validate_precision().map_err(|e| {
                    anyhow::Error::from(EngineError::InvalidPrecision(e.to_string()))
                        .context(format!("engine config: backend {kind}"))
                })?;
                self.sparsity.validate().map_err(|e| {
                    anyhow::Error::from(EngineError::InvalidSparsity(e))
                        .context(format!("engine config: backend {kind}"))
                })?;
            }
        }
        Ok(())
    }

    /// True when the configured backend's arithmetic depends on the
    /// bitstream length (the analytic expectation / fixed-point kinds use
    /// `k` only for the hardware estimate). Crate-visible so the
    /// [`crate::analyze`] pre-flight can skip the k-dependent lints for
    /// the analytic backends.
    pub(crate) fn k_sensitive(&self) -> bool {
        matches!(
            self.backend,
            BackendKind::StochasticFused
                | BackendKind::ReferencePerBit
                | BackendKind::NoisyExpectation
        )
    }

    /// Typed precision-policy validation: for k-sensitive backends every
    /// stage length must be a positive [`precision::WORD`]-multiple;
    /// per-layer plans must cover the compute stages exactly; autotune
    /// budgets must lie in `[0, 1)`. Before this check, a bad `k` flowed
    /// silently into the kernels. Public so estimate-only consumers (the
    /// `sweep` CLI) can refuse malformed plans with the same typed error
    /// the serving path raises at open.
    pub fn validate_precision(&self) -> Result<(), PrecisionError> {
        match &self.precision {
            Precision::Uniform(k) => {
                if self.k_sensitive() {
                    precision::check_k(*k, None)?;
                }
            }
            Precision::PerLayer(ks) => {
                let plan = PrecisionPlan::per_layer(ks.clone());
                plan.validate_for(self.net.n_compute())?;
            }
            Precision::Auto { accuracy_budget } => {
                if !(0.0..1.0).contains(accuracy_budget) {
                    return Err(PrecisionError::BadBudget { budget: *accuracy_budget });
                }
            }
        }
        Ok(())
    }

    /// Lower the non-tuning policies into their plan (`None` for
    /// [`Precision::Auto`], which needs weights) — the ONE place the
    /// Uniform/PerLayer lowering lives, shared by
    /// [`EngineConfig::resolved_precision`] and [`EngineConfig::estimate`].
    fn static_plan(&self) -> Option<PrecisionPlan> {
        match &self.precision {
            Precision::Uniform(k) => Some(PrecisionPlan::uniform(*k, self.net.n_compute())),
            Precision::PerLayer(ks) => Some(PrecisionPlan::per_layer(ks.clone())),
            Precision::Auto { .. } => None,
        }
    }

    /// Resolve the precision policy into the compiled per-layer
    /// [`PrecisionPlan`] for this network: uniform and per-layer policies
    /// lower directly; [`Precision::Auto`] runs the greedy
    /// [`precision::autotune`]r against `weights` (deterministic for a
    /// fixed config, and memoized process-wide so the shards of a
    /// homogeneous pool tune **once**).
    pub fn resolved_precision(&self, weights: &QuantizedWeights) -> Result<PrecisionPlan> {
        let plan = if let Precision::Auto { accuracy_budget } = &self.precision {
            self.tuned_plan(weights, &AutoTuneConfig::new(*accuracy_budget))?
        } else {
            self.static_plan().expect("non-Auto policies lower statically")
        };
        if self.k_sensitive() {
            plan.validate_for(self.net.n_compute()).map_err(|e| {
                anyhow::Error::from(EngineError::InvalidPrecision(e.to_string()))
            })?;
        }
        Ok(plan)
    }

    /// Autotune through the process-wide memo: the tuner is deterministic
    /// per (net, weights, seed, knobs), so identical configs — e.g. the N
    /// shards of a replicated pool — pay for exactly one tuning run.
    fn tuned_plan(
        &self,
        weights: &QuantizedWeights,
        tcfg: &AutoTuneConfig,
    ) -> Result<PrecisionPlan> {
        static TUNED: OnceLock<Mutex<HashMap<u128, PrecisionPlan>>> = OnceLock::new();
        let mut fp = Fingerprint::new();
        fp.write(format!("{:?}", self.net).as_bytes());
        write_weights(&mut fp, weights);
        fp.write(&self.seed.to_le_bytes());
        fp.write(&tcfg.accuracy_budget.to_bits().to_le_bytes());
        fp.write(&(tcfg.k_max as u64).to_le_bytes());
        fp.write(&(tcfg.k_min as u64).to_le_bytes());
        fp.write(&(tcfg.calib_images as u64).to_le_bytes());
        let key = fp.digest();
        let cache = TUNED.get_or_init(|| Mutex::new(HashMap::new()));
        if let Some(hit) = crate::engine::lock_recover(cache).get(&key) {
            return Ok(hit.clone());
        }
        // Tune OUTSIDE the lock (a tuning run is many analytic forwards);
        // determinism makes a racing duplicate harmless.
        let plan = precision::autotune(&self.net, weights, self.seed, tcfg)?;
        crate::engine::lock_recover(cache).insert(key, plan.clone());
        Ok(plan)
    }

    /// Resolve the configured [`WeightSource`] into quantized codes.
    pub fn resolve_weights(&self) -> Result<QuantizedWeights> {
        match &self.weights {
            WeightSource::Quantized(q) => {
                if q.bits != self.bits {
                    bail!(
                        "engine config: quantized weights are {}-bit but the config says {}-bit",
                        q.bits,
                        self.bits
                    );
                }
                Ok(q.clone())
            }
            WeightSource::Float(m) => Ok(m.quantize(self.bits)),
            WeightSource::File(p) => Ok(ModelWeights::load(p)?.quantize(self.bits)),
            WeightSource::None => {
                bail!("engine config: backend {} has no weight source", self.backend)
            }
        }
    }

    /// The modeled-hardware estimate for this configuration. `None` for
    /// [`BackendKind::Xla`], for a precision policy that fails
    /// [`EngineConfig::validate_precision`] (a malformed plan must not
    /// silently shape the model — `sweep` surfaces the typed error
    /// instead), or when an [`Precision::Auto`] policy cannot resolve
    /// because the weights are unavailable. Per-layer policies produce a
    /// per-layer-k-exact schedule.
    pub fn estimate(&self) -> Option<HardwareEstimate> {
        if self.backend == BackendKind::Xla
            || self.validate_precision().is_err()
            || self.sparsity.validate().is_err()
        {
            return None;
        }
        // Zero-k analytic configs are legal and clamped inside for_plan,
        // preserving the old for_config(k.max(1)) robustness.
        let plan = match self.static_plan() {
            Some(plan) => plan,
            None => {
                let w = self.resolve_weights().ok()?;
                self.resolved_precision(&w).ok()?
            }
        };
        // An active sparsity policy drops pruned lanes from the modeled
        // schedule; densities need the resolved weights, so a config whose
        // weights cannot resolve models the dense plan instead of failing.
        let densities = if self.sparsity.is_off() {
            Vec::new()
        } else {
            self.resolve_weights()
                .map(|w| crate::accel::network::weight_densities(&w, self.sparsity))
                .unwrap_or_default()
        };
        Some(HardwareEstimate::for_plan_density(
            self.tech,
            self.channels,
            &plan,
            &self.net,
            &densities,
        ))
    }

    /// Fingerprint of everything that determines the **compiled artifact**
    /// for this configuration: the backend kind, the seed and the
    /// **resolved per-layer precision plan** (folded in only where the
    /// datapath actually samples — the analytic expectation / fixed-point
    /// kinds ignore `k`), the quantization precision, the full network
    /// structure, and the resolved quantized weights. The modeled-tech
    /// knobs (`tech`, `channels`) are deliberately excluded — they shape
    /// the hardware *estimate*, not the compiled plan — so pool shards
    /// differing only in modeled tech still share one plan, and shards
    /// sharing one resolved plan (including an autotuned one) share one
    /// compiled artifact. Keys the process-wide shared-plan cache
    /// ([`crate::engine::backend::shared_plan`]).
    pub fn artifact_fingerprint(
        &self,
        weights: &QuantizedWeights,
        precision: &PrecisionPlan,
    ) -> u128 {
        let mut fp = Fingerprint::new();
        fp.write(self.backend.label().as_bytes());
        if self.k_sensitive() {
            fp.write(&self.seed.to_le_bytes());
            for &k in precision.ks() {
                fp.write(&(k as u64).to_le_bytes());
            }
        }
        // The kernel path changes the compiled layout (lane-major vs
        // transposed weight planes), so it is part of the artifact for the
        // one backend that lowers stochastic kernels. Hashing the
        // *resolved* path keeps `Auto` sharing the transposed artifact —
        // except under an active sparsity policy, where `Auto` additionally
        // resolves per stage from pruning structure (unstructured-pruned
        // shared-window stages lower to the fused kernel), so sparse
        // artifacts key on the *unresolved* selection instead.
        if self.backend == BackendKind::StochasticFused {
            let kernel = if self.sparsity.is_off() {
                self.kernel.resolved().label()
            } else {
                self.kernel.label()
            };
            fp.write(kernel.as_bytes());
        }
        // An active sparsity policy reshapes the compiled plan on every
        // plan backend (skip lists, rescaled APC floors, analytic lane
        // drops); OFF hashes like the legacy fingerprint so dense plans
        // keep their cache entries across upgrades.
        if !self.sparsity.is_off() {
            fp.write(b"sparsity");
            fp.write(&self.sparsity.threshold.to_bits().to_le_bytes());
        }
        fp.write(&self.bits.to_le_bytes());
        // NetworkSpec's Debug form covers the name, input shape, and every
        // layer descriptor — the whole topology.
        fp.write(format!("{:?}", self.net).as_bytes());
        write_weights(&mut fp, weights);
        // A compiled-in fault plan changes every injected stream (and, via
        // SRAM upsets, the effective weights), so it is part of the
        // artifact for every backend. A noop plan hashes like None, so a
        // quiet plan still shares the clean artifact.
        if let Some(f) = self.faults.as_ref().filter(|f| !f.is_noop()) {
            fp.write(b"faults");
            fp.write(&f.seed.to_le_bytes());
            fp.write(&f.bit_flip_rate.to_bits().to_le_bytes());
            fp.write(&f.sng_correlation_rate.to_bits().to_le_bytes());
            fp.write(&f.sram_upset_rate.to_bits().to_le_bytes());
            for s in &f.stuck_lanes {
                fp.write(&(s.wl as u64).to_le_bytes());
                fp.write(&(s.lane as u64).to_le_bytes());
                fp.write(&[s.stuck_one as u8]);
            }
        }
        fp.digest()
    }
}

/// Fold a quantized weight tensor into a fingerprint (shared by the
/// artifact fingerprint and the autotune memo key).
fn write_weights(fp: &mut Fingerprint, weights: &QuantizedWeights) {
    fp.write(&weights.bits.to_le_bytes());
    fp.write(&(weights.layers.len() as u64).to_le_bytes());
    for layer in &weights.layers {
        fp.write(&layer.gamma.to_bits().to_le_bytes());
        fp.write(&layer.mu.to_bits().to_le_bytes());
        fp.write(&(layer.codes.len() as u64).to_le_bytes());
        for codes in &layer.codes {
            fp.write(&(codes.len() as u64).to_le_bytes());
            for &c in codes {
                fp.write(&c.to_le_bytes());
            }
        }
    }
}

/// FNV-1a offset basis / prime — the one pair of constants behind both the
/// plan-cache fingerprint below and the pool's routing hash.
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Plain FNV-1a 64 (stable across processes, unlike `DefaultHasher`).
/// Shared by [`EngineConfig::artifact_fingerprint`]'s first lane and the
/// pool router's key hash so one audited implementation serves both.
pub(crate) fn fnv1a_64(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h = fnv1a_step(h, b);
    }
    h
}

#[inline]
fn fnv1a_step(h: u64, byte: u8) -> u64 {
    (h ^ byte as u64).wrapping_mul(FNV_PRIME)
}

/// Dual-lane FNV-1a: two independently-seeded 64-bit lanes (the second
/// additionally rotated per byte to decorrelate) concatenated into an
/// effectively 128-bit digest — collision-safe enough to key the
/// process-wide compiled-plan cache without storing full keys.
struct Fingerprint {
    a: u64,
    b: u64,
}

impl Fingerprint {
    fn new() -> Self {
        Fingerprint { a: FNV_OFFSET, b: 0x6c62_272e_07bb_0142 }
    }

    fn write(&mut self, bytes: &[u8]) {
        for &x in bytes {
            self.a = fnv1a_step(self.a, x);
            self.b = fnv1a_step(self.b, x).rotate_left(17);
        }
    }

    fn digest(&self) -> u128 {
        ((self.a as u128) << 64) | self.b as u128
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::accel::layers::{LayerKind, LayerSpec};
    use crate::accel::network::LayerWeights;
    use crate::sc::quantize_bipolar;

    fn tiny_net() -> NetworkSpec {
        NetworkSpec {
            name: "tiny".into(),
            input: (1, 2, 2),
            layers: vec![LayerSpec {
                kind: LayerKind::Dense { inputs: 4, outputs: 3 },
                relu: false,
            }],
        }
    }

    fn tiny_quantized(bits: u32) -> QuantizedWeights {
        let codes: Vec<Vec<u32>> = (0..3)
            .map(|oc| (0..4).map(|j| quantize_bipolar((oc + j) as f64 / 6.0, bits)).collect())
            .collect();
        QuantizedWeights { bits, layers: vec![LayerWeights { codes, gamma: 1.0, mu: 0.0 }] }
    }

    #[test]
    fn backend_kind_parses_aliases() {
        assert_eq!("sc".parse::<BackendKind>().unwrap(), BackendKind::StochasticFused);
        assert_eq!("pjrt".parse::<BackendKind>().unwrap(), BackendKind::Xla);
        assert_eq!("reference".parse::<BackendKind>().unwrap(), BackendKind::ReferencePerBit);
        assert_eq!("noisy".parse::<BackendKind>().unwrap(), BackendKind::NoisyExpectation);
        assert_eq!("fixed".parse::<BackendKind>().unwrap(), BackendKind::FixedPoint);
        assert!("warp-drive".parse::<BackendKind>().is_err());
        for kind in BackendKind::ALL {
            assert_eq!(kind.label().parse::<BackendKind>().unwrap(), kind);
        }
    }

    #[test]
    fn builder_sets_fields_and_lengths() {
        let cfg = EngineConfig::new(BackendKind::Expectation, tiny_net())
            .with_quantized(tiny_quantized(6))
            .with_k(128)
            .with_seed(3)
            .with_threads(2)
            .with_tech(TechKind::Finfet10)
            .with_channels(4);
        assert_eq!(cfg.bits, 6, "with_quantized adopts the payload precision");
        assert_eq!(cfg.precision, Precision::Uniform(128));
        assert_eq!(cfg.uniform_k(), Some(128));
        assert_eq!(cfg.input_len(), 4);
        assert_eq!(cfg.output_len(), 3);
        cfg.validate().unwrap();
        assert_eq!(cfg.resolve_weights().unwrap().bits, 6);
    }

    #[test]
    fn validation_rejects_inconsistent_configs() {
        // Missing weights.
        let cfg = EngineConfig::new(BackendKind::StochasticFused, tiny_net());
        assert!(cfg.validate().is_err());
        // Missing ladder for xla.
        let cfg = EngineConfig::new(BackendKind::Xla, tiny_net());
        assert!(cfg.validate().is_err());
        // k = 0 for a stochastic kind.
        let cfg = EngineConfig::new(BackendKind::ReferencePerBit, tiny_net())
            .with_quantized(tiny_quantized(8))
            .with_k(0);
        assert!(cfg.validate().is_err());
        // Precision mismatch between config and pre-quantized payload.
        let mut cfg = EngineConfig::new(BackendKind::Expectation, tiny_net())
            .with_quantized(tiny_quantized(8));
        cfg.bits = 4;
        assert!(cfg.resolve_weights().is_err());
    }

    #[test]
    fn validation_surfaces_network_shape_errors() {
        // The old silent-truncation maxpool bug, now a typed error at the
        // config boundary (Engine::open refuses instead of asserting).
        let bad = NetworkSpec {
            name: "bad-pool".into(),
            input: (1, 7, 7),
            layers: vec![
                LayerSpec::active(LayerKind::conv(1, 2, 1, 0)),
                LayerSpec::linear(LayerKind::MaxPool { size: 2 }),
            ],
        };
        let cfg =
            EngineConfig::new(BackendKind::Expectation, bad).with_quantized(tiny_quantized(8));
        let err = cfg.validate().unwrap_err().to_string();
        assert!(err.contains("does not divide"), "{err}");
    }

    #[test]
    fn estimate_present_for_sc_kinds_absent_for_xla() {
        let cfg = EngineConfig::new(BackendKind::Expectation, tiny_net())
            .with_quantized(tiny_quantized(8));
        let est = cfg.estimate().unwrap();
        assert!(est.metrics.area_mm2 > 0.0);
        assert!(est.metrics.energy_uj > 0.0);
        let cfg = EngineConfig::new(BackendKind::Xla, tiny_net());
        assert!(cfg.estimate().is_none());
    }

    #[test]
    fn artifact_fingerprint_keys_on_compiled_inputs_only() {
        let base = EngineConfig::new(BackendKind::StochasticFused, tiny_net())
            .with_quantized(tiny_quantized(8))
            .with_k(64);
        let w = base.resolve_weights().unwrap();
        let plan = |cfg: &EngineConfig| cfg.resolved_precision(&w).unwrap();
        let fp = base.artifact_fingerprint(&w, &plan(&base));
        // Deterministic.
        assert_eq!(fp, base.artifact_fingerprint(&w, &plan(&base)));
        // Modeled-tech knobs do not change the compiled artifact.
        let tech = base.clone().with_tech(TechKind::Finfet10).with_channels(4);
        assert_eq!(fp, tech.artifact_fingerprint(&w, &plan(&tech)));
        // Thread caps and batch policy are runtime knobs, not artifacts.
        let threads = base.clone().with_threads(3);
        assert_eq!(fp, threads.artifact_fingerprint(&w, &plan(&threads)));
        // k, seed, backend, weights, and topology all change the artifact.
        let k128 = base.clone().with_k(128);
        assert_ne!(fp, k128.artifact_fingerprint(&w, &plan(&k128)));
        let reseeded = base.clone().with_seed(99);
        assert_ne!(fp, reseeded.artifact_fingerprint(&w, &plan(&reseeded)));
        let exp = EngineConfig::new(BackendKind::Expectation, tiny_net())
            .with_quantized(tiny_quantized(8));
        assert_ne!(fp, exp.artifact_fingerprint(&w, &plan(&exp)));
        let mut w2 = w.clone();
        w2.layers[0].codes[0][0] ^= 1;
        assert_ne!(fp, base.artifact_fingerprint(&w2, &plan(&base)));
        // Expectation ignores k, so two expectation configs at different k
        // share one artifact.
        let exp_k = exp.clone().with_k(4096);
        assert_eq!(
            exp.artifact_fingerprint(&w, &plan(&exp)),
            exp_k.artifact_fingerprint(&w, &plan(&exp_k))
        );
        // A per-layer plan equal to the uniform one IS the same artifact;
        // a different per-layer assignment is not.
        let same = base.clone().with_precision(Precision::PerLayer(vec![64]));
        assert_eq!(fp, same.artifact_fingerprint(&w, &plan(&same)));
        let tapered = base.clone().with_precision(Precision::PerLayer(vec![32]));
        assert_ne!(fp, tapered.artifact_fingerprint(&w, &plan(&tapered)));
        // The kernel path is a compiled input: Auto resolves to the
        // transposed layout (same artifact), the fused baseline does not.
        let transposed = base.clone().with_kernel(KernelPath::Transposed);
        assert_eq!(fp, transposed.artifact_fingerprint(&w, &plan(&transposed)));
        let fused = base.clone().with_kernel(KernelPath::Fused);
        assert_ne!(fp, fused.artifact_fingerprint(&w, &plan(&fused)));
        // Analytic backends never lower a stochastic kernel, so the knob
        // does not split their cache entries.
        let exp_fused = exp.clone().with_kernel(KernelPath::Fused);
        assert_eq!(
            exp.artifact_fingerprint(&w, &plan(&exp)),
            exp_fused.artifact_fingerprint(&w, &plan(&exp_fused))
        );
    }

    #[test]
    fn sparsity_is_a_compiled_artifact_input() {
        let base = EngineConfig::new(BackendKind::StochasticFused, tiny_net())
            .with_quantized(tiny_quantized(8))
            .with_k(64);
        let w = base.resolve_weights().unwrap();
        let plan = base.resolved_precision(&w).unwrap();
        let fp = base.artifact_fingerprint(&w, &plan);
        // An explicit OFF policy hashes exactly like the legacy default,
        // so dense plans keep their cache entries.
        let off = base.clone().with_sparsity(SparsityPolicy::OFF);
        assert_eq!(fp, off.artifact_fingerprint(&w, &plan));
        // An active policy is a new artifact, and the threshold value
        // itself keys the entry.
        let sparse = base.clone().with_sparsity(SparsityPolicy::threshold(0.05));
        let sparse_fp = sparse.artifact_fingerprint(&w, &plan);
        assert_ne!(fp, sparse_fp);
        let sparser = base.clone().with_sparsity(SparsityPolicy::threshold(0.10));
        assert_ne!(sparse_fp, sparser.artifact_fingerprint(&w, &plan));
        // Under an active policy Auto resolves per stage from pruning
        // structure, so it no longer shares the pinned-transposed artifact.
        let pinned = sparse.clone().with_kernel(KernelPath::Transposed);
        assert_ne!(sparse_fp, pinned.artifact_fingerprint(&w, &plan));
        // Analytic backends prune too: sparsity splits their artifacts
        // even though the kernel knob does not.
        let exp = EngineConfig::new(BackendKind::Expectation, tiny_net())
            .with_quantized(tiny_quantized(8));
        let exp_plan = exp.resolved_precision(&w).unwrap();
        let exp_sparse = exp.clone().with_sparsity(SparsityPolicy::threshold(0.05));
        assert_ne!(
            exp.artifact_fingerprint(&w, &exp_plan),
            exp_sparse.artifact_fingerprint(&w, &exp_plan)
        );
    }

    #[test]
    fn sparsity_thresholds_validate_typed_and_shape_the_estimate() {
        let with = |t: f64| {
            EngineConfig::new(BackendKind::StochasticFused, tiny_net())
                .with_quantized(tiny_quantized(8))
                .with_k(64)
                .with_sparsity(SparsityPolicy::threshold(t))
        };
        with(0.0).validate().unwrap();
        with(0.3).validate().unwrap();
        for (t, needle) in
            [(-0.1, ">= 0.0"), (1.0, "< 1.0"), (1.5, "< 1.0"), (f64::NAN, "finite")]
        {
            let err = format!("{:?}", with(t).validate().unwrap_err());
            assert!(err.contains("sparsity threshold"), "{err}");
            assert!(err.contains(needle), "{err}");
            assert!(with(t).estimate().is_none(), "degenerate thresholds model nothing");
        }
        // tiny_quantized holds a true-zero weight (oc 0, j 0), so any
        // active threshold prunes at least one lane and the modeled energy
        // drops below the dense figure.
        let dense = with(0.0).estimate().unwrap();
        let sparse = with(0.3).estimate().unwrap();
        assert!(sparse.metrics.energy_uj < dense.metrics.energy_uj);
        assert!((sparse.metrics.area_mm2 - dense.metrics.area_mm2).abs() < 1e-12);
    }

    #[test]
    fn precision_policies_validate_typed() {
        let ok = |p: Precision| {
            EngineConfig::new(BackendKind::StochasticFused, tiny_net())
                .with_quantized(tiny_quantized(8))
                .with_precision(p)
                .validate()
        };
        ok(Precision::Uniform(64)).unwrap();
        ok(Precision::PerLayer(vec![64])).unwrap();
        ok(Precision::Auto { accuracy_budget: 0.05 }).unwrap();
        // Degenerate lengths are typed errors, not silent kernel inputs.
        let err = ok(Precision::Uniform(100)).unwrap_err().to_string();
        assert!(err.contains("invalid precision policy"), "{err}");
        assert!(err.contains("multiple"), "{err}");
        assert!(ok(Precision::Uniform(0)).is_err());
        assert!(ok(Precision::PerLayer(vec![64, 64])).is_err(), "wrong plan length");
        assert!(ok(Precision::PerLayer(vec![])).is_err());
        assert!(ok(Precision::Auto { accuracy_budget: 1.0 }).is_err());
        assert!(ok(Precision::Auto { accuracy_budget: -0.1 }).is_err());
        // Analytic backends ignore a uniform k they do not execute...
        EngineConfig::new(BackendKind::Expectation, tiny_net())
            .with_quantized(tiny_quantized(8))
            .with_precision(Precision::Uniform(100))
            .validate()
            .unwrap();
        // ...but a malformed per-layer plan is rejected everywhere.
        assert!(EngineConfig::new(BackendKind::Expectation, tiny_net())
            .with_quantized(tiny_quantized(8))
            .with_precision(Precision::PerLayer(vec![64, 64]))
            .validate()
            .is_err());
    }

    #[test]
    fn resolved_precision_lowers_policies_to_plans() {
        let base = EngineConfig::new(BackendKind::StochasticFused, tiny_net())
            .with_quantized(tiny_quantized(8));
        let w = base.resolve_weights().unwrap();
        let uni = base.clone().with_k(64).resolved_precision(&w).unwrap();
        assert_eq!(uni, PrecisionPlan::uniform(64, 1));
        let per = base
            .clone()
            .with_precision(Precision::PerLayer(vec![96]))
            .resolved_precision(&w)
            .unwrap();
        assert_eq!(per.ks(), &[96]);
        // Auto resolves deterministically (memoized process-wide) to a
        // valid word-aligned plan within the tuner's bounds.
        let auto_cfg = base.clone().with_precision(Precision::Auto { accuracy_budget: 0.2 });
        let a = auto_cfg.resolved_precision(&w).unwrap();
        let b = auto_cfg.resolved_precision(&w).unwrap();
        assert_eq!(a, b);
        a.validate_for(1).unwrap();
        assert!(a.max_k() <= 1024);
        // A k-sensitive backend refuses to resolve a degenerate plan.
        assert!(base
            .clone()
            .with_precision(Precision::PerLayer(vec![100]))
            .resolved_precision(&w)
            .is_err());
    }

    #[test]
    fn estimate_reflects_per_layer_precision() {
        let base = EngineConfig::new(BackendKind::StochasticFused, tiny_net())
            .with_quantized(tiny_quantized(8));
        let hi = base.clone().with_k(1024).estimate().unwrap();
        let lo = base
            .clone()
            .with_precision(Precision::PerLayer(vec![64]))
            .estimate()
            .unwrap();
        assert!(lo.metrics.energy_uj < hi.metrics.energy_uj);
        assert_eq!(lo.k, 64);
        // A malformed plan never silently shapes the model: estimate
        // refuses (sweep turns this into the typed InvalidPrecision).
        assert!(base
            .clone()
            .with_precision(Precision::PerLayer(vec![0]))
            .estimate()
            .is_none());
        assert!(base
            .clone()
            .with_precision(Precision::PerLayer(vec![64, 64]))
            .estimate()
            .is_none());
        assert!(base.clone().with_k(0).estimate().is_none(), "k-sensitive uniform 0");
    }

    #[test]
    fn fault_and_resilience_knobs_build_and_fingerprint() {
        let base = EngineConfig::new(BackendKind::StochasticFused, tiny_net())
            .with_quantized(tiny_quantized(8))
            .with_k(64);
        let w = base.resolve_weights().unwrap();
        let plan = base.resolved_precision(&w).unwrap();
        let fp = base.artifact_fingerprint(&w, &plan);
        // Resilience knobs that do not change the compiled artifact.
        let runtime = base
            .clone()
            .with_deadline(Duration::from_millis(50))
            .with_degrade(DegradePolicy::default())
            .with_chaos_panic_after(3)
            .with_chaos_slow(Duration::from_millis(1));
        assert_eq!(fp, runtime.artifact_fingerprint(&w, &plan));
        runtime.validate().unwrap();
        // A noop fault plan shares the clean artifact; a live one does not.
        let quiet = base.clone().with_faults(FaultPlan::new(9));
        assert_eq!(fp, quiet.artifact_fingerprint(&w, &plan));
        let flipped =
            base.clone().with_faults(FaultPlan::new(9).with_bit_flip_rate(0.01));
        assert_ne!(fp, flipped.artifact_fingerprint(&w, &plan));
        // Distinct fault plans are distinct artifacts.
        let reseeded =
            base.clone().with_faults(FaultPlan::new(10).with_bit_flip_rate(0.01));
        assert_ne!(
            flipped.artifact_fingerprint(&w, &plan),
            reseeded.artifact_fingerprint(&w, &plan)
        );
        let stuck = base.clone().with_faults(FaultPlan::new(9).with_stuck_lane(0, 1, true));
        assert_ne!(fp, stuck.artifact_fingerprint(&w, &plan));
    }

    #[test]
    fn forward_mode_lowering() {
        assert_eq!(
            BackendKind::StochasticFused.forward_mode(64, 5),
            Some(ForwardMode::Stochastic { k: 64, seed: 5 })
        );
        assert_eq!(BackendKind::Expectation.forward_mode(64, 5), Some(ForwardMode::Expectation));
        assert_eq!(BackendKind::FixedPoint.forward_mode(64, 5), Some(ForwardMode::FixedPoint));
        assert!(BackendKind::ReferencePerBit.forward_mode(64, 5).is_none());
        assert!(BackendKind::Xla.forward_mode(64, 5).is_none());
    }
}
