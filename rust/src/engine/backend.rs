//! The pluggable datapath behind a session: one [`Backend`] trait,
//! implemented by the fused bit-exact SC engine ([`StochasticFused`]), the
//! per-bit golden reference ([`ReferencePerBit`]), the analytic models
//! ([`Expectation`], covering expectation / noisy-expectation /
//! fixed-point), and the PJRT executable ladder ([`Xla`]).
//!
//! Backends are built **on the session's worker thread** from a plain
//! [`EngineConfig`] (which is `Send`), so implementations are free to hold
//! thread-affine state — raw PJRT handles, scratch arenas — without a
//! `Send` bound on the trait object.

use crate::accel::layers::NetworkSpec;
use crate::accel::network::{reference, ForwardPlan, QuantizedWeights, Scratch};
use crate::engine::config::{BackendKind, EngineConfig};
use crate::runtime;
use anyhow::{bail, Result};

/// A datapath that executes validated batches. Inputs arrive as flattened
/// images in [0, 1] (the serving dtype); implementations convert to their
/// native precision internally.
pub trait Backend {
    /// Stable label (metrics, bench records).
    fn name(&self) -> &'static str;

    /// Expected flattened input length.
    fn in_len(&self) -> usize;

    /// Flattened output length (class count).
    fn out_len(&self) -> usize;

    /// Execute one batch; `inputs` is non-empty and every image has
    /// `in_len()` elements. Returns one output per input, in order.
    fn infer_batch(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>>;
}

/// Build the configured backend. Called on the worker thread.
pub(crate) fn build(cfg: &EngineConfig) -> Result<Box<dyn Backend>> {
    cfg.validate()?;
    Ok(match cfg.backend {
        BackendKind::StochasticFused => Box::new(StochasticFused::from_config(cfg)?),
        BackendKind::Expectation | BackendKind::NoisyExpectation | BackendKind::FixedPoint => {
            Box::new(Expectation::from_config(cfg)?)
        }
        BackendKind::ReferencePerBit => Box::new(ReferencePerBit::from_config(cfg)?),
        BackendKind::Xla => Box::new(Xla::from_config(cfg)?),
    })
}

/// Shared executor for the `ForwardPlan`-based backends: one compiled plan,
/// one reusable scratch arena, and the session's thread cap.
struct PlanExec {
    plan: ForwardPlan,
    scratch: Scratch,
    threads: usize,
    fbuf: Vec<f64>,
}

impl PlanExec {
    fn new(cfg: &EngineConfig) -> Result<Self> {
        let mode = cfg
            .backend
            .forward_mode(cfg.k, cfg.seed)
            .expect("PlanExec is only built for plan-lowerable backend kinds");
        let weights = cfg.resolve_weights()?;
        // compile (not new): weight/shape mismatches surface as session
        // open errors, never as panics on the worker thread.
        let plan = ForwardPlan::compile(&cfg.net, &weights, mode)?;
        Ok(PlanExec { plan, scratch: Scratch::default(), threads: cfg.threads, fbuf: Vec::new() })
    }

    fn run(&mut self, inputs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        if inputs.len() == 1 {
            // Lone requests still get the cores (neuron-parallel); real
            // batches fan out image-parallel below. Bit-identical either way.
            self.fbuf.clear();
            self.fbuf.extend(inputs[0].iter().map(|&v| v as f64));
            let out = self.plan.run_with_threads(&self.fbuf, &mut self.scratch, self.threads);
            return vec![out.iter().map(|&v| v as f32).collect()];
        }
        let wide: Vec<Vec<f64>> =
            inputs.iter().map(|img| img.iter().map(|&v| v as f64).collect()).collect();
        self.plan
            .run_batch_threads(&wide, self.threads)
            .iter()
            .map(|out| out.iter().map(|&v| v as f32).collect())
            .collect()
    }
}

/// The fused allocation-free bit-exact SC engine (word-packed SNG lanes →
/// `add_xnor_words` → fused B2S/ReLU/S2B), parallel across neurons and
/// images. Bit-identical to [`ReferencePerBit`] for the same k and seed.
pub struct StochasticFused {
    exec: PlanExec,
}

impl StochasticFused {
    /// Build from a config with `backend == BackendKind::StochasticFused`.
    pub fn from_config(cfg: &EngineConfig) -> Result<Self> {
        Ok(StochasticFused { exec: PlanExec::new(cfg)? })
    }
}

impl Backend for StochasticFused {
    fn name(&self) -> &'static str {
        BackendKind::StochasticFused.label()
    }

    fn in_len(&self) -> usize {
        self.exec.plan.in_len()
    }

    fn out_len(&self) -> usize {
        self.exec.plan.out_len()
    }

    fn infer_batch(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        Ok(self.exec.run(inputs))
    }
}

/// The analytic models over the same quantized codes: expectation (no
/// sampling noise), noisy-expectation (analytic k-cycle noise), and the
/// fixed-point binary baseline — one backend, three [`BackendKind`]s.
pub struct Expectation {
    exec: PlanExec,
    label: &'static str,
}

impl Expectation {
    /// Build from a config with an analytic `backend` kind.
    pub fn from_config(cfg: &EngineConfig) -> Result<Self> {
        debug_assert!(matches!(
            cfg.backend,
            BackendKind::Expectation | BackendKind::NoisyExpectation | BackendKind::FixedPoint
        ));
        Ok(Expectation { exec: PlanExec::new(cfg)?, label: cfg.backend.label() })
    }
}

impl Backend for Expectation {
    fn name(&self) -> &'static str {
        self.label
    }

    fn in_len(&self) -> usize {
        self.exec.plan.in_len()
    }

    fn out_len(&self) -> usize {
        self.exec.plan.out_len()
    }

    fn infer_batch(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        Ok(self.exec.run(inputs))
    }
}

/// The pre-fusion per-bit stochastic datapath, kept as the golden model:
/// every stream generated one bit at a time, every XNOR product allocating,
/// neurons serial. Slow by design — it exists so every other backend has a
/// fixed point to agree with (see `tests/engine_parity.rs`).
pub struct ReferencePerBit {
    net: NetworkSpec,
    weights: QuantizedWeights,
    k: usize,
    seed: u32,
    in_len: usize,
    out_len: usize,
}

impl ReferencePerBit {
    /// Build from a config with `backend == BackendKind::ReferencePerBit`.
    pub fn from_config(cfg: &EngineConfig) -> Result<Self> {
        Ok(ReferencePerBit {
            net: cfg.net.clone(),
            weights: cfg.resolve_weights()?,
            k: cfg.k,
            seed: cfg.seed,
            in_len: cfg.input_len(),
            out_len: cfg.output_len(),
        })
    }
}

impl Backend for ReferencePerBit {
    fn name(&self) -> &'static str {
        BackendKind::ReferencePerBit.label()
    }

    fn in_len(&self) -> usize {
        self.in_len
    }

    fn out_len(&self) -> usize {
        self.out_len
    }

    fn infer_batch(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        Ok(inputs
            .iter()
            .map(|img| {
                let wide: Vec<f64> = img.iter().map(|&v| v as f64).collect();
                reference::forward_stochastic(&self.net, &self.weights, &wide, self.k, self.seed)
                    .iter()
                    .map(|&v| v as f32)
                    .collect()
            })
            .collect())
    }
}

/// AOT-compiled HLO graphs on the PJRT CPU client, as a (batch_size,
/// executable) ladder. The batcher's drained set is chunked greedily down
/// the ladder (largest batch first), so the ladder must include batch 1.
pub struct Xla {
    /// Ladder sorted largest batch first.
    ladder: Vec<(usize, runtime::Engine)>,
    dims: (usize, usize, usize),
    in_len: usize,
    out_len: usize,
}

impl Xla {
    /// Build from a config with `backend == BackendKind::Xla` (loads and
    /// compiles every ladder entry).
    pub fn from_config(cfg: &EngineConfig) -> Result<Self> {
        let mut ladder = Vec::with_capacity(cfg.hlo_ladder.len());
        for (b, path) in &cfg.hlo_ladder {
            ladder.push((*b, runtime::Engine::load(path)?));
        }
        ladder.sort_by(|a, b| b.0.cmp(&a.0));
        if ladder.last().map(|&(b, _)| b) != Some(1) {
            bail!("xla backend: the executable ladder must include batch size 1");
        }
        Ok(Xla { ladder, dims: cfg.net.input, in_len: cfg.input_len(), out_len: cfg.output_len() })
    }
}

impl Backend for Xla {
    fn name(&self) -> &'static str {
        BackendKind::Xla.label()
    }

    fn in_len(&self) -> usize {
        self.in_len
    }

    fn out_len(&self) -> usize {
        self.out_len
    }

    fn infer_batch(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let (c, h, w) = self.dims;
        let mut out = Vec::with_capacity(inputs.len());
        let mut idx = 0;
        while idx < inputs.len() {
            let remaining = inputs.len() - idx;
            let (bsz, engine) = self
                .ladder
                .iter()
                .find(|&&(b, _)| b <= remaining)
                .map(|(b, e)| (*b, e))
                .expect("ladder contains batch 1");
            let chunk = &inputs[idx..idx + bsz];
            let mut flat = Vec::with_capacity(bsz * self.in_len);
            for img in chunk {
                flat.extend_from_slice(img);
            }
            let dims = [bsz as i64, c as i64, h as i64, w as i64];
            let flat_out = engine.run_f32(&flat, &dims)?;
            if flat_out.len() != bsz * self.out_len {
                bail!(
                    "xla backend: graph {} returned {} values for batch {bsz} \
                     ({} expected)",
                    engine.source,
                    flat_out.len(),
                    bsz * self.out_len
                );
            }
            for logits in flat_out.chunks_exact(self.out_len) {
                out.push(logits.to_vec());
            }
            idx += bsz;
        }
        Ok(out)
    }
}
