//! The pluggable datapath behind a session: one [`Backend`] trait,
//! implemented by the fused bit-exact SC engine ([`StochasticFused`]), the
//! per-bit golden reference ([`ReferencePerBit`]), the analytic models
//! ([`Expectation`], covering expectation / noisy-expectation /
//! fixed-point), and the PJRT executable ladder ([`Xla`]).
//!
//! Backends are built **on the session's worker thread** from a plain
//! [`EngineConfig`] (which is `Send`), so implementations are free to hold
//! thread-affine state — raw PJRT handles, scratch arenas — without a
//! `Send` bound on the trait object.

use crate::accel::layers::NetworkSpec;
use crate::accel::network::{reference, ForwardPlan, QuantizedWeights, Scratch, SparsityPolicy};
use crate::accel::precision::PrecisionPlan;
use crate::engine::config::{BackendKind, EngineConfig};
use crate::runtime;
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock, Weak};

/// A datapath that executes validated batches. Inputs arrive as flattened
/// images in [0, 1] (the serving dtype); implementations convert to their
/// native precision internally.
pub trait Backend {
    /// Stable label (metrics, bench records).
    fn name(&self) -> &'static str;

    /// Expected flattened input length.
    fn in_len(&self) -> usize;

    /// Flattened output length (class count).
    fn out_len(&self) -> usize;

    /// Execute one batch; `inputs` is non-empty and every image has
    /// `in_len()` elements. Returns one output per input, in order.
    fn infer_batch(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>>;

    /// Static per-image `(executed, skipped)` lane-cycle op accounting of
    /// the compiled plan (see `ForwardPlan::ops_per_image`) — the session
    /// worker multiplies by served images to feed
    /// `SessionMetrics::{ops_executed, ops_skipped}`. Backends without a
    /// compiled plan (XLA, the per-bit reference) report `(0, 0)`.
    fn ops_per_image(&self) -> (u64, u64) {
        (0, 0)
    }

    /// Per-compute-layer surviving weight-lane density of the compiled
    /// plan (see `ForwardPlan::stage_densities`), feeding the session's
    /// density-aware hardware estimate. Empty (= model dense) for
    /// backends without a compiled plan.
    fn stage_densities(&self) -> Vec<f64> {
        Vec::new()
    }
}

/// Build the configured backend, resolving the precision policy exactly
/// once (weights + plan feed every constructor **and** travel back to the
/// session for its per-layer-k-aware hardware estimate). Called on the
/// worker thread. The plan is `None` only for [`BackendKind::Xla`], which
/// models no SC hardware.
pub(crate) fn build(
    cfg: &EngineConfig,
) -> Result<(Box<dyn Backend>, Option<PrecisionPlan>)> {
    cfg.validate()?;
    if cfg.backend == BackendKind::Xla {
        return Ok((Box::new(Xla::from_config(cfg)?), None));
    }
    let weights = cfg.resolve_weights()?;
    let precision = cfg.resolved_precision(&weights)?;
    let backend: Box<dyn Backend> = match cfg.backend {
        BackendKind::StochasticFused => {
            Box::new(StochasticFused::from_resolved(cfg, &weights, &precision)?)
        }
        BackendKind::Expectation | BackendKind::NoisyExpectation | BackendKind::FixedPoint => {
            Box::new(Expectation::from_resolved(cfg, &weights, &precision)?)
        }
        BackendKind::ReferencePerBit => {
            Box::new(ReferencePerBit::from_resolved(cfg, weights, precision.clone())?)
        }
        BackendKind::Xla => unreachable!("handled above"),
    };
    Ok((backend, Some(precision)))
}

/// Process-wide compiled-plan cache keyed by
/// [`EngineConfig::artifact_fingerprint`]. Entries are weak: a plan lives
/// exactly as long as some session holds it, so ephemeral sessions (tests,
/// sweeps) do not accumulate dead plans.
static PLAN_CACHE: OnceLock<Mutex<HashMap<u128, Weak<ForwardPlan>>>> = OnceLock::new();
/// Total plan compiles this process has performed (cache observability).
static PLAN_COMPILES: AtomicUsize = AtomicUsize::new(0);

/// Resolve the compiled [`ForwardPlan`] for a plan-lowerable configuration
/// through the process-wide shared-artifact cache: pool shards (or any
/// sessions) with identical compiled-artifact inputs — backend kind, the
/// lowered forward mode, precision, topology, and weights — share **one**
/// plan instead of recompiling per shard. `ForwardPlan`'s run methods take
/// `&self` and every stage is `Send + Sync`, so one plan serves any number
/// of worker threads; only the scratch arenas stay per-session. XLA
/// executables are *not* cached here: PJRT handles are thread-affine by
/// design (see [`crate::runtime`]), so each session loads its own ladder.
pub fn shared_plan(cfg: &EngineConfig) -> Result<Arc<ForwardPlan>> {
    let weights = cfg.resolve_weights()?;
    let precision = cfg.resolved_precision(&weights)?;
    shared_plan_with(cfg, &weights, &precision)
}

/// [`shared_plan`] with the weights and precision plan already resolved
/// (the worker-thread build path resolves them once for the backend *and*
/// the cache key, so an autotuned policy never tunes twice per open).
pub(crate) fn shared_plan_with(
    cfg: &EngineConfig,
    weights: &QuantizedWeights,
    precision: &PrecisionPlan,
) -> Result<Arc<ForwardPlan>> {
    // The mode's k is a placeholder: compile_with_precision specializes
    // every compute stage to the plan's own length.
    let mode = cfg
        .backend
        .forward_mode(precision.max_k(), cfg.seed)
        .ok_or_else(|| anyhow!("backend {} does not lower to a forward plan", cfg.backend))?;
    let key = cfg.artifact_fingerprint(weights, precision);
    let cache = PLAN_CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    if let Some(plan) =
        crate::engine::lock_recover(cache).get(&key).and_then(Weak::upgrade)
    {
        return Ok(plan);
    }
    // Compile OUTSIDE the cache lock so distinct artifacts compile
    // concurrently and cache hits never stall behind a compile. Two
    // racing opens of the *same* artifact may both compile; the insert
    // below is double-checked, so exactly one wins and the loser's copy
    // is dropped (a pool opens its shards sequentially, so the
    // homogeneous case still compiles once). compile (not new):
    // weight/shape mismatches surface as session open errors, never as
    // panics on the worker thread.
    let plan = Arc::new(ForwardPlan::compile_with_sparsity(
        &cfg.net,
        weights,
        mode,
        precision,
        cfg.faults.as_ref(),
        cfg.kernel,
        cfg.sparsity,
    )?);
    PLAN_COMPILES.fetch_add(1, Ordering::Relaxed);
    let mut g = crate::engine::lock_recover(cache);
    if let Some(existing) = g.get(&key).and_then(Weak::upgrade) {
        return Ok(existing);
    }
    g.retain(|_, w| w.strong_count() > 0);
    g.insert(key, Arc::downgrade(&plan));
    Ok(plan)
}

/// How many plan compiles this process has performed. A homogeneous
/// N-shard pool should add 1 to this, not N — asserted in the pool tests.
pub fn plan_compile_count() -> usize {
    PLAN_COMPILES.load(Ordering::Relaxed)
}

/// Shared executor for the `ForwardPlan`-based backends: one (possibly
/// cache-shared) compiled plan, one private scratch arena, and the
/// session's thread cap.
struct PlanExec {
    plan: Arc<ForwardPlan>,
    scratch: Scratch,
    threads: usize,
    fbuf: Vec<f64>,
}

impl PlanExec {
    fn new(
        cfg: &EngineConfig,
        weights: &QuantizedWeights,
        precision: &PrecisionPlan,
    ) -> Result<Self> {
        let plan = shared_plan_with(cfg, weights, precision)?;
        Ok(PlanExec { plan, scratch: Scratch::default(), threads: cfg.threads, fbuf: Vec::new() })
    }

    fn run(&mut self, inputs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        if inputs.len() == 1 {
            // Lone requests still get the cores (neuron-parallel); real
            // batches fan out image-parallel below. Bit-identical either way.
            self.fbuf.clear();
            self.fbuf.extend(inputs[0].iter().map(|&v| v as f64));
            let out = self.plan.run_with_threads(&self.fbuf, &mut self.scratch, self.threads);
            return vec![out.iter().map(|&v| v as f32).collect()];
        }
        let wide: Vec<Vec<f64>> =
            inputs.iter().map(|img| img.iter().map(|&v| v as f64).collect()).collect();
        self.plan
            .run_batch_threads(&wide, self.threads)
            .iter()
            .map(|out| out.iter().map(|&v| v as f32).collect())
            .collect()
    }
}

/// The fused allocation-free bit-exact SC engine (word-packed SNG lanes →
/// `add_xnor_words` → fused B2S/ReLU/S2B), parallel across neurons and
/// images. Bit-identical to [`ReferencePerBit`] for the same k and seed.
pub struct StochasticFused {
    exec: PlanExec,
}

impl StochasticFused {
    /// Build from a config with `backend == BackendKind::StochasticFused`
    /// (resolves weights and the precision policy itself).
    pub fn from_config(cfg: &EngineConfig) -> Result<Self> {
        let weights = cfg.resolve_weights()?;
        let precision = cfg.resolved_precision(&weights)?;
        Self::from_resolved(cfg, &weights, &precision)
    }

    /// The shared constructor body: weights and precision already
    /// resolved (the worker-thread [`build`] path resolves once for the
    /// backend *and* the session's plan report).
    fn from_resolved(
        cfg: &EngineConfig,
        weights: &QuantizedWeights,
        precision: &PrecisionPlan,
    ) -> Result<Self> {
        Ok(StochasticFused { exec: PlanExec::new(cfg, weights, precision)? })
    }
}

impl Backend for StochasticFused {
    fn name(&self) -> &'static str {
        BackendKind::StochasticFused.label()
    }

    fn in_len(&self) -> usize {
        self.exec.plan.in_len()
    }

    fn out_len(&self) -> usize {
        self.exec.plan.out_len()
    }

    fn infer_batch(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        Ok(self.exec.run(inputs))
    }

    fn ops_per_image(&self) -> (u64, u64) {
        self.exec.plan.ops_per_image()
    }

    fn stage_densities(&self) -> Vec<f64> {
        self.exec.plan.stage_densities()
    }
}

/// The analytic models over the same quantized codes: expectation (no
/// sampling noise), noisy-expectation (analytic k-cycle noise), and the
/// fixed-point binary baseline — one backend, three [`BackendKind`]s.
pub struct Expectation {
    exec: PlanExec,
    label: &'static str,
}

impl Expectation {
    /// Build from a config with an analytic `backend` kind (resolves
    /// weights and the precision policy itself).
    pub fn from_config(cfg: &EngineConfig) -> Result<Self> {
        let weights = cfg.resolve_weights()?;
        let precision = cfg.resolved_precision(&weights)?;
        Self::from_resolved(cfg, &weights, &precision)
    }

    /// The shared constructor body (see [`StochasticFused::from_resolved`]).
    fn from_resolved(
        cfg: &EngineConfig,
        weights: &QuantizedWeights,
        precision: &PrecisionPlan,
    ) -> Result<Self> {
        debug_assert!(matches!(
            cfg.backend,
            BackendKind::Expectation | BackendKind::NoisyExpectation | BackendKind::FixedPoint
        ));
        Ok(Expectation {
            exec: PlanExec::new(cfg, weights, precision)?,
            label: cfg.backend.label(),
        })
    }
}

impl Backend for Expectation {
    fn name(&self) -> &'static str {
        self.label
    }

    fn in_len(&self) -> usize {
        self.exec.plan.in_len()
    }

    fn out_len(&self) -> usize {
        self.exec.plan.out_len()
    }

    fn infer_batch(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        Ok(self.exec.run(inputs))
    }

    fn ops_per_image(&self) -> (u64, u64) {
        self.exec.plan.ops_per_image()
    }

    fn stage_densities(&self) -> Vec<f64> {
        self.exec.plan.stage_densities()
    }
}

/// The pre-fusion per-bit stochastic datapath, kept as the golden model:
/// every stream generated one bit at a time, every XNOR product allocating,
/// neurons serial. Slow by design — it exists so every other backend has a
/// fixed point to agree with (see `tests/engine_parity.rs`).
pub struct ReferencePerBit {
    net: NetworkSpec,
    weights: QuantizedWeights,
    /// Resolved per-layer bitstream lengths (the reference honors the
    /// same plan as the fused engine — parity by construction).
    precision: PrecisionPlan,
    /// Compiled-in fault plan (the reference injects the same faults as
    /// the fused engine — parity under faults by construction).
    faults: Option<crate::faults::FaultPlan>,
    /// Compiled-in sparsity policy (the reference prunes the same lanes
    /// as the fused engine — parity under pruning by construction).
    sparsity: SparsityPolicy,
    seed: u32,
    in_len: usize,
    out_len: usize,
}

impl ReferencePerBit {
    /// Build from a config with `backend == BackendKind::ReferencePerBit`
    /// (resolves weights and the precision policy itself).
    pub fn from_config(cfg: &EngineConfig) -> Result<Self> {
        let weights = cfg.resolve_weights()?;
        let precision = cfg.resolved_precision(&weights)?;
        Self::from_resolved(cfg, weights, precision)
    }

    /// The shared constructor body (see [`StochasticFused::from_resolved`]).
    /// The reference has no compile step, so the one compile-time sparsity
    /// failure — a threshold pruning some channel to fan-in 0 — is checked
    /// here, with the same typed refusal the fused engine raises.
    fn from_resolved(
        cfg: &EngineConfig,
        weights: QuantizedWeights,
        precision: PrecisionPlan,
    ) -> Result<Self> {
        if !cfg.sparsity.is_off() {
            let stats = crate::accel::network::prune_stats(&weights, cfg.sparsity);
            for (wl, st) in stats.iter().enumerate() {
                if st.lanes > 0 && st.min_fan_in == 0 {
                    return Err(crate::engine::EngineError::InvalidSparsity(format!(
                        "threshold {} prunes a channel of weight layer {wl} to fan-in 0",
                        cfg.sparsity.threshold
                    ))
                    .into());
                }
            }
        }
        Ok(ReferencePerBit {
            net: cfg.net.clone(),
            weights,
            precision,
            faults: cfg.faults.clone(),
            sparsity: cfg.sparsity,
            seed: cfg.seed,
            in_len: cfg.input_len(),
            out_len: cfg.output_len(),
        })
    }
}

impl Backend for ReferencePerBit {
    fn name(&self) -> &'static str {
        BackendKind::ReferencePerBit.label()
    }

    fn in_len(&self) -> usize {
        self.in_len
    }

    fn out_len(&self) -> usize {
        self.out_len
    }

    fn infer_batch(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        Ok(inputs
            .iter()
            .map(|img| {
                let wide: Vec<f64> = img.iter().map(|&v| v as f64).collect();
                reference::forward_stochastic_plan_sparse(
                    &self.net,
                    &self.weights,
                    &wide,
                    &self.precision,
                    self.seed,
                    self.faults.as_ref(),
                    self.sparsity,
                )
                .iter()
                .map(|&v| v as f32)
                .collect()
            })
            .collect())
    }

    fn stage_densities(&self) -> Vec<f64> {
        if self.sparsity.is_off() {
            Vec::new()
        } else {
            crate::accel::network::weight_densities(&self.weights, self.sparsity)
        }
    }
}

/// AOT-compiled HLO graphs on the PJRT CPU client, as a (batch_size,
/// executable) ladder. The batcher's drained set is chunked greedily down
/// the ladder (largest batch first), so the ladder must include batch 1.
pub struct Xla {
    /// Ladder sorted largest batch first.
    ladder: Vec<(usize, runtime::Engine)>,
    dims: (usize, usize, usize),
    in_len: usize,
    out_len: usize,
}

impl Xla {
    /// Build from a config with `backend == BackendKind::Xla` (loads and
    /// compiles every ladder entry).
    pub fn from_config(cfg: &EngineConfig) -> Result<Self> {
        let mut ladder = Vec::with_capacity(cfg.hlo_ladder.len());
        for (b, path) in &cfg.hlo_ladder {
            ladder.push((*b, runtime::Engine::load(path)?));
        }
        ladder.sort_by(|a, b| b.0.cmp(&a.0));
        if ladder.last().map(|&(b, _)| b) != Some(1) {
            bail!("xla backend: the executable ladder must include batch size 1");
        }
        Ok(Xla { ladder, dims: cfg.net.input, in_len: cfg.input_len(), out_len: cfg.output_len() })
    }
}

impl Backend for Xla {
    fn name(&self) -> &'static str {
        BackendKind::Xla.label()
    }

    fn in_len(&self) -> usize {
        self.in_len
    }

    fn out_len(&self) -> usize {
        self.out_len
    }

    fn infer_batch(&mut self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let (c, h, w) = self.dims;
        let mut out = Vec::with_capacity(inputs.len());
        let mut idx = 0;
        while idx < inputs.len() {
            let remaining = inputs.len() - idx;
            let (bsz, engine) = self
                .ladder
                .iter()
                .find(|&&(b, _)| b <= remaining)
                .map(|(b, e)| (*b, e))
                .ok_or_else(|| anyhow!("xla backend: executable ladder lost its batch-1 rung"))?;
            let chunk = &inputs[idx..idx + bsz];
            let mut flat = Vec::with_capacity(bsz * self.in_len);
            for img in chunk {
                flat.extend_from_slice(img);
            }
            let dims = [bsz as i64, c as i64, h as i64, w as i64];
            let flat_out = engine.run_f32(&flat, &dims)?;
            if flat_out.len() != bsz * self.out_len {
                bail!(
                    "xla backend: graph {} returned {} values for batch {bsz} \
                     ({} expected)",
                    engine.source,
                    flat_out.len(),
                    bsz * self.out_len
                );
            }
            for logits in flat_out.chunks_exact(self.out_len) {
                out.push(logits.to_vec());
            }
            idx += bsz;
        }
        Ok(out)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::accel::layers::{LayerKind, LayerSpec};
    use crate::accel::network::LayerWeights;
    use crate::sc::quantize_bipolar;

    fn tiny_cfg(k: usize) -> EngineConfig {
        let net = NetworkSpec {
            name: "tiny-cache".into(),
            input: (1, 2, 2),
            layers: vec![LayerSpec {
                kind: LayerKind::Dense { inputs: 4, outputs: 2 },
                relu: false,
            }],
        };
        let codes: Vec<Vec<u32>> = (0..2)
            .map(|oc| (0..4).map(|j| quantize_bipolar((oc + j) as f64 / 5.0, 8)).collect())
            .collect();
        let weights = QuantizedWeights {
            bits: 8,
            layers: vec![LayerWeights { codes, gamma: 1.0, mu: 0.0 }],
        };
        EngineConfig::new(BackendKind::StochasticFused, net).with_quantized(weights).with_k(k)
    }

    #[test]
    fn shared_plan_reuses_identical_artifacts() {
        let cfg = tiny_cfg(48);
        let before = plan_compile_count();
        let p1 = shared_plan(&cfg).unwrap();
        let p2 = shared_plan(&cfg).unwrap();
        assert!(Arc::ptr_eq(&p1, &p2), "identical artifact inputs share one plan");
        // Only runtime knobs differ: still the same plan.
        let p3 = shared_plan(&cfg.clone().with_threads(2).with_channels(2)).unwrap();
        assert!(Arc::ptr_eq(&p1, &p3));
        // A different k is a different compiled artifact.
        let p4 = shared_plan(&tiny_cfg(56)).unwrap();
        assert!(!Arc::ptr_eq(&p1, &p4));
        assert!(plan_compile_count() >= before + 2);
    }

    #[test]
    fn shared_plan_entries_die_with_their_sessions() {
        let cfg = tiny_cfg(40);
        let p1 = shared_plan(&cfg).unwrap();
        drop(p1);
        // The weak entry is dead; a fresh resolve recompiles. Sibling tests
        // compile plans concurrently, so assert monotonicity rather than an
        // exact count, plus that the fresh plan is unshared (strong count 1
        // would be 2+ if a stale strong handle had survived somewhere).
        let before = plan_compile_count();
        let p2 = shared_plan(&cfg).unwrap();
        assert!(plan_compile_count() > before, "dead weak entry recompiles");
        assert_eq!(Arc::strong_count(&p2), 1, "the recompiled plan starts unshared");
    }

    #[test]
    fn shared_plan_keys_on_the_resolved_precision_plan() {
        use crate::accel::precision::Precision;
        // A per-layer policy equal to the uniform one resolves to the SAME
        // compiled artifact; a genuinely different assignment does not.
        let uni = tiny_cfg(64);
        let same = tiny_cfg(64).with_precision(Precision::PerLayer(vec![64]));
        let diff = tiny_cfg(64).with_precision(Precision::PerLayer(vec![96]));
        let p_uni = shared_plan(&uni).unwrap();
        let p_same = shared_plan(&same).unwrap();
        let p_diff = shared_plan(&diff).unwrap();
        assert!(Arc::ptr_eq(&p_uni, &p_same), "equal plans share one artifact");
        assert!(!Arc::ptr_eq(&p_uni, &p_diff));
        assert_eq!(p_diff.precision().ks(), &[96]);
    }

    #[test]
    fn shared_plan_keys_on_the_sparsity_policy() {
        let dense = tiny_cfg(48);
        let off = tiny_cfg(48).with_sparsity(SparsityPolicy::OFF);
        let sparse = tiny_cfg(48).with_sparsity(SparsityPolicy::threshold(0.25));
        let p_dense = shared_plan(&dense).unwrap();
        let p_off = shared_plan(&off).unwrap();
        let p_sparse = shared_plan(&sparse).unwrap();
        assert!(Arc::ptr_eq(&p_dense, &p_off), "an explicit OFF shares the dense artifact");
        assert!(!Arc::ptr_eq(&p_dense, &p_sparse), "an active policy is a new artifact");
        // tiny_cfg's first channel holds a true-zero weight, so the sparse
        // plan skips real work — and the split conserves the dense count.
        let (exec, skip) = p_sparse.ops_per_image();
        let (dense_exec, dense_skip) = p_dense.ops_per_image();
        assert_eq!(dense_skip, 0);
        assert!(skip > 0);
        assert_eq!(exec + skip, dense_exec);
        assert!(p_sparse.stage_densities().iter().any(|&d| d < 1.0));
        assert!(p_dense.stage_densities().iter().all(|&d| d == 1.0));
    }

    #[test]
    fn shared_plan_rejects_non_plan_backends() {
        let mut cfg = tiny_cfg(32);
        cfg.backend = BackendKind::ReferencePerBit;
        assert!(shared_plan(&cfg).is_err());
    }
}
