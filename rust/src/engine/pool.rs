//! [`EnginePool`] — many session shards behind one front door, for serving
//! at scale.
//!
//! A pool owns N [`Session`] shards (each an [`crate::engine::Engine::open`]
//! session on its own worker thread), optionally heterogeneous — different
//! backends, `k` tiers, or even different topologies, as long as every
//! shard speaks the same input/output shape. On top of the shards it adds
//! the serving machinery no single session has:
//!
//! * **a router** with pluggable [`Placement`]: round-robin (default),
//!   least-queue-depth (pick the emptiest shard), and hash-by-request-key
//!   (stable affinity, e.g. for client-side caches);
//! * **a shared compiled-artifact cache**: shards with identical
//!   compiled-artifact inputs (backend, topology, weights, k/seed,
//!   precision) reuse **one** [`crate::accel::network::ForwardPlan`]
//!   through [`crate::engine::backend::shared_plan`] instead of
//!   recompiling per shard — opening an 8-shard homogeneous pool compiles
//!   once;
//! * **admission control**: a bounded global in-flight queue; when it is
//!   full — or when every candidate shard's own backpressure queue is full
//!   ([`crate::engine::Session::try_submit`] keeps the per-shard step
//!   non-blocking) — streamed requests are *shed* with a typed
//!   [`EngineError::Rejected`]`{ retry_after_hint }` instead of blocking —
//!   open-loop clients get an explicit backoff signal whose hint tracks
//!   recently observed service latency on both the blocking and the
//!   streaming path;
//! * **health + rerouting**: a shard whose worker dies (or is closed) is
//!   marked unhealthy and its traffic reroutes to the survivors; only when
//!   every shard is gone do callers see [`EngineError::NoHealthyShards`].
//!   Injected faults (`EngineConfig::with_chaos_panic_after`) exercise this
//!   path deterministically under test;
//! * **typed deadlines**: shards opened with `EngineConfig::with_deadline`
//!   resolve stuck waits to [`EngineError::Timeout`] — classified as a
//!   request-level failure, not a shard death, so one slow request never
//!   takes a healthy shard out of rotation;
//! * **graceful drain**: [`EnginePool::close`] refuses new work, lets every
//!   shard finish its queue, and returns when all workers have exited;
//! * **[`PoolMetrics`]**: merged latency histograms and percentiles,
//!   per-shard throughput, shed/reroute counters, and the modeled hardware
//!   estimate scaled by shard count.
//!
//! ```no_run
//! use scnn::accel::layers::NetworkSpec;
//! use scnn::engine::{BackendKind, EngineConfig, EnginePool, Placement, PoolConfig};
//!
//! let cfg = EngineConfig::new(BackendKind::StochasticFused, NetworkSpec::lenet5())
//!     .with_weights_file("artifacts/lenet5_sc.weights.bin")
//!     .with_k(256);
//! let pool = EnginePool::open(
//!     PoolConfig::replicated(cfg, 4).with_placement(Placement::LeastQueueDepth),
//! ).unwrap();
//! let _logits = pool.infer(vec![0.0; 28 * 28]).unwrap();
//! println!("{}", pool.metrics().summary());
//! ```
//!
//! **Do not submit directly to a shard session while streaming through the
//! pool** ([`EnginePool::submit`]/[`EnginePool::drain`]): the pool's
//! ordered drain assumes it is the only submitter on its shards and
//! reports a typed desynchronization error otherwise.

use crate::engine::config::EngineConfig;
use crate::engine::error::EngineError;
use crate::engine::metrics::{PoolMetrics, SessionMetrics, TenantStats};
use crate::engine::{lock_recover, Session, Ticket, TrySubmit};
use anyhow::{bail, Context, Result};
use std::collections::{BTreeMap, VecDeque};
use std::str::FromStr;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// How the router places a request on a shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// Rotate over healthy shards (the default; maximizes batching under
    /// uniform load).
    RoundRobin,
    /// Send each request to the healthy shard with the fewest requests in
    /// flight (adapts to heterogeneous shards / skewed request cost).
    LeastQueueDepth,
    /// Hash the request key onto a shard: the same key always lands on the
    /// same shard while the shard set's health is unchanged (cache
    /// affinity). Keyless requests fall back to round-robin.
    HashKey,
}

impl Placement {
    /// Stable lowercase label (CLI values, metrics).
    pub fn label(self) -> &'static str {
        match self {
            Placement::RoundRobin => "round-robin",
            Placement::LeastQueueDepth => "least-queue-depth",
            Placement::HashKey => "hash-key",
        }
    }
}

impl std::fmt::Display for Placement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.label())
    }
}

impl FromStr for Placement {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "rr" | "round-robin" | "roundrobin" => Placement::RoundRobin,
            "least" | "least-queue" | "least-queue-depth" => Placement::LeastQueueDepth,
            "hash" | "hash-key" | "affinity" => Placement::HashKey,
            other => bail!("unknown placement {other:?} (rr|least|hash)"),
        })
    }
}

/// Typed, builder-style configuration for [`EnginePool::open`].
#[derive(Debug, Clone)]
pub struct PoolConfig {
    /// One engine configuration per shard. Heterogeneous configs are
    /// allowed (different backends / k tiers / nets behind one front
    /// door) as long as every shard has the same input and output length.
    pub shards: Vec<EngineConfig>,
    /// Router placement policy.
    pub placement: Placement,
    /// Global admission bound: the most requests that may be in flight
    /// (admitted-but-unfinished) across the whole pool before further
    /// requests are shed with [`EngineError::Rejected`]. `0` (default)
    /// means the sum of the shards' per-session `BatchPolicy::queue_depth`.
    pub queue_depth: usize,
}

impl PoolConfig {
    /// A homogeneous pool: `n` shards of one configuration (the common
    /// case; the shared plan cache compiles their artifact once).
    pub fn replicated(cfg: EngineConfig, n: usize) -> Self {
        PoolConfig {
            shards: vec![cfg; n.max(1)],
            placement: Placement::RoundRobin,
            queue_depth: 0,
        }
    }

    /// A heterogeneous pool from explicit per-shard configurations.
    pub fn heterogeneous(shards: Vec<EngineConfig>) -> Self {
        PoolConfig { shards, placement: Placement::RoundRobin, queue_depth: 0 }
    }

    /// Set the router placement policy.
    pub fn with_placement(mut self, placement: Placement) -> Self {
        self.placement = placement;
        self
    }

    /// Set the global admission bound (0 = sum of shard queue depths).
    pub fn with_queue_depth(mut self, depth: usize) -> Self {
        self.queue_depth = depth;
        self
    }

    /// The admission bound [`EnginePool::open`] will enforce.
    pub fn effective_queue_depth(&self) -> usize {
        if self.queue_depth > 0 {
            self.queue_depth
        } else {
            self.shards
                .iter()
                .map(|c| c.batch.queue_depth.max(1))
                .sum::<usize>()
                .max(1)
        }
    }

    /// Check internal consistency without opening anything: at least one
    /// shard, every shard config valid, and one front door — all shards
    /// agree on input and output length.
    pub fn validate(&self) -> Result<()> {
        if self.shards.is_empty() {
            bail!("pool config: a pool needs at least one shard");
        }
        for (i, cfg) in self.shards.iter().enumerate() {
            cfg.validate().with_context(|| format!("pool config: shard {i}"))?;
        }
        let (in_len, out_len) = (self.shards[0].input_len(), self.shards[0].output_len());
        for (i, cfg) in self.shards.iter().enumerate().skip(1) {
            if cfg.input_len() != in_len || cfg.output_len() != out_len {
                bail!(
                    "pool config: shard {i} ({}, {}→{}) disagrees with shard 0 ({}→{}) — \
                     heterogeneous shards must share one input/output shape",
                    cfg.net.name,
                    cfg.input_len(),
                    cfg.output_len(),
                    in_len,
                    out_len
                );
            }
        }
        Ok(())
    }
}

/// Handle to one in-flight [`EnginePool::submit`] request. The sequence
/// number ([`PoolTicket::seq`]) counts pool submissions from 0 in order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PoolTicket(u64);

impl PoolTicket {
    /// Submission sequence number (0-based, in submission order).
    pub fn seq(self) -> u64 {
        self.0
    }
}

/// One shard: a session plus the router's view of it.
struct Shard {
    session: Session,
    /// Sticky health flag, cleared when a request observes the shard's
    /// worker gone. Combined with the session's own liveness at read time.
    healthy: AtomicBool,
    /// Requests currently routed to this shard (for least-queue-depth).
    inflight: AtomicUsize,
    /// Serializes submit-to-session with pool-pending registration so the
    /// per-shard pending order always matches pool registration order.
    submit_gate: Mutex<()>,
}

/// A pool-level outstanding submission.
struct PendingEntry {
    ticket: PoolTicket,
    shard: usize,
    inner: Ticket,
}

/// Why one routed attempt failed: the shard is gone (retry elsewhere) or
/// the request itself failed on a live shard (propagate).
enum RouteAttempt {
    ShardDown,
    Request(EngineError),
}

/// N session shards behind one router — see the module docs for the full
/// feature tour, and the crate README's "Serving at scale" section for
/// sizing guidance.
pub struct EnginePool {
    shards: Vec<Shard>,
    placement: Placement,
    queue_depth: usize,
    rr: AtomicUsize,
    /// Admitted-but-unfinished requests (the admission-control gauge).
    admitted: AtomicUsize,
    shed: AtomicUsize,
    rerouted: AtomicUsize,
    next_ticket: AtomicU64,
    pending: Mutex<VecDeque<PendingEntry>>,
    /// Serializes drains so concurrent drainers cannot split one shard's
    /// result stream between them.
    drain_gate: Mutex<()>,
    closed: AtomicBool,
    opened: Instant,
    /// Per-tenant outcome counters, keyed by tenant name. Written by the
    /// serving front door ([`EnginePool::note_tenant`]); a BTreeMap keeps
    /// the metrics exposition sorted and stable.
    tenant_counters: Mutex<BTreeMap<String, TenantCounters>>,
}

/// How a tenant-attributed request ended, for [`EnginePool::note_tenant`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TenantOutcome {
    /// Answered successfully.
    Ok,
    /// Bounced by the tenant's own quota before reaching the pool.
    QuotaRejected,
    /// Shed by pool admission control.
    Shed,
    /// Failed anywhere else (backend error, timeout, malformed input).
    Failed,
}

#[derive(Debug, Default, Clone, Copy)]
struct TenantCounters {
    ok: u64,
    quota_rejected: u64,
    shed: u64,
    failed: u64,
}

impl EnginePool {
    /// Open every shard (sequentially; the shared plan cache makes
    /// homogeneous shards compile their artifact once) and return the
    /// routing front door.
    pub fn open(config: PoolConfig) -> Result<Self> {
        config.validate()?;
        let queue_depth = config.effective_queue_depth();
        let placement = config.placement;
        let mut shards = Vec::with_capacity(config.shards.len());
        for (i, cfg) in config.shards.into_iter().enumerate() {
            let session = Session::open(cfg).with_context(|| format!("opening pool shard {i}"))?;
            shards.push(Shard {
                session,
                healthy: AtomicBool::new(true),
                inflight: AtomicUsize::new(0),
                submit_gate: Mutex::new(()),
            });
        }
        Ok(EnginePool {
            shards,
            placement,
            queue_depth,
            rr: AtomicUsize::new(0),
            admitted: AtomicUsize::new(0),
            shed: AtomicUsize::new(0),
            rerouted: AtomicUsize::new(0),
            next_ticket: AtomicU64::new(0),
            pending: Mutex::new(VecDeque::new()),
            drain_gate: Mutex::new(()),
            closed: AtomicBool::new(false),
            opened: Instant::now(),
            tenant_counters: Mutex::new(BTreeMap::new()),
        })
    }

    /// Total shard count.
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Shards currently able to serve (healthy flag + live, unclosed
    /// worker).
    pub fn healthy_shards(&self) -> usize {
        (0..self.shards.len()).filter(|&i| self.shard_healthy(i)).count()
    }

    /// Expected flattened input length (shard 0; validation guarantees all
    /// shards agree).
    pub fn in_len(&self) -> usize {
        self.shards[0].session.in_len()
    }

    /// Flattened output length (class count).
    pub fn out_len(&self) -> usize {
        self.shards[0].session.out_len()
    }

    /// Borrow one shard's session (observability, tests, failure
    /// injection). Do not stream `submit`s through it while also streaming
    /// through the pool — see the module docs.
    pub fn shard_session(&self, i: usize) -> Option<&Session> {
        self.shards.get(i).map(|s| &s.session)
    }

    /// True once [`EnginePool::close`] has been called.
    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::Acquire)
    }

    /// Number of submitted-but-undrained pool requests.
    pub fn outstanding(&self) -> usize {
        lock_recover(&self.pending).len()
    }

    /// Full liveness of a shard (sticky flag **and** the session's own
    /// state) — what [`EnginePool::healthy_shards`] and metrics report.
    fn shard_healthy(&self, i: usize) -> bool {
        let s = &self.shards[i];
        s.healthy.load(Ordering::Acquire)
            && s.session.worker_alive()
            && !s.session.is_closed()
    }

    /// What the router consults: the sticky flag alone. A shard that died
    /// without the pool noticing yet is still routable; the first request
    /// to hit it fails fast, marks it down, and reroutes — health is
    /// *discovered through traffic*, keeping the hot routing path to one
    /// atomic load.
    fn shard_routable(&self, i: usize) -> bool {
        self.shards[i].healthy.load(Ordering::Acquire)
    }

    fn mark_unhealthy(&self, i: usize) {
        self.shards[i].healthy.store(false, Ordering::Release);
    }

    /// Route one request: a starting shard from the placement policy, then
    /// a deterministic probe to the next routable shard.
    fn pick(&self, key: Option<u64>) -> Result<usize, EngineError> {
        let n = self.shards.len();
        let start = match (self.placement, key) {
            (Placement::HashKey, Some(h)) => (h % n as u64) as usize,
            (Placement::LeastQueueDepth, _) => {
                let mut best: Option<(usize, usize)> = None;
                for i in 0..n {
                    if !self.shard_routable(i) {
                        continue;
                    }
                    let q = self.shards[i].inflight.load(Ordering::Relaxed);
                    if best.is_none_or(|(_, bq)| q < bq) {
                        best = Some((i, q));
                    }
                }
                return best.map(|(i, _)| i).ok_or(EngineError::NoHealthyShards);
            }
            _ => self.rr.fetch_add(1, Ordering::Relaxed) % n,
        };
        for off in 0..n {
            let i = (start + off) % n;
            if self.shard_routable(i) {
                return Ok(i);
            }
        }
        Err(EngineError::NoHealthyShards)
    }

    /// The shard `key` maps to under hash placement right now (stable
    /// while shard health is unchanged) — exposed for affinity-aware
    /// clients and tests. This is a **pure** lookup: it consumes no
    /// routing state (safe to poll from a metrics loop). Under placements
    /// other than [`Placement::HashKey`] keyed requests ignore affinity;
    /// the value still tells you where hash placement would put the key.
    pub fn shard_for_key(&self, key: &str) -> Result<usize, EngineError> {
        let n = self.shards.len();
        let start = (hash_key(key) % n as u64) as usize;
        for off in 0..n {
            let i = (start + off) % n;
            if self.shard_routable(i) {
                return Ok(i);
            }
        }
        Err(EngineError::NoHealthyShards)
    }

    /// Candidate order for one placement decision: the placement's first
    /// choice, then every other routable shard (rotation order; sorted by
    /// queue depth under [`Placement::LeastQueueDepth`]) — so one full
    /// shard never starves a request another shard could queue.
    fn candidates(&self, key: Option<u64>) -> Result<Vec<usize>, EngineError> {
        let n = self.shards.len();
        let first = self.pick(key)?;
        let mut order = Vec::with_capacity(n);
        order.push(first);
        let mut rest: Vec<usize> = (1..n)
            .map(|off| (first + off) % n)
            .filter(|&j| self.shard_routable(j))
            .collect();
        if self.placement == Placement::LeastQueueDepth {
            rest.sort_by_key(|&j| self.shards[j].inflight.load(Ordering::Relaxed));
        }
        order.extend(rest);
        Ok(order)
    }

    /// Admission control: claim a global in-flight slot or shed.
    fn admit(&self) -> Result<(), EngineError> {
        if self.closed.load(Ordering::Acquire) {
            return Err(EngineError::Closed);
        }
        let admitted = self
            .admitted
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |n| {
                (n < self.queue_depth).then_some(n + 1)
            })
            .is_ok();
        if admitted {
            Ok(())
        } else {
            self.shed.fetch_add(1, Ordering::Relaxed);
            Err(EngineError::Rejected { retry_after_hint: self.retry_hint() })
        }
    }

    fn unadmit(&self, n: usize) {
        let _ = self.admitted.fetch_update(Ordering::AcqRel, Ordering::Acquire, |v| {
            Some(v.saturating_sub(n))
        });
    }

    /// Backoff hint for shed requests: the worst recently observed request
    /// latency across the shards, as measured by the session **workers**
    /// (enqueue → response, queueing included, client-side dally excluded
    /// — so a client that drains late cannot inflate the hint). Floored at
    /// 100 µs before any request has completed.
    fn retry_hint(&self) -> Duration {
        let worst = self
            .shards
            .iter()
            .map(|s| s.session.last_latency_us())
            .max()
            .unwrap_or(0);
        Duration::from_micros(worst.max(100))
    }

    /// One blocking attempt on one shard, consuming the image (zero-copy
    /// into the session on the happy path); classifies failures into
    /// shard-down (reroutable) vs request-level (terminal).
    fn infer_on_owned(&self, i: usize, image: Vec<f32>) -> Result<Vec<f32>, RouteAttempt> {
        let shard = &self.shards[i];
        shard.inflight.fetch_add(1, Ordering::Relaxed);
        let res = shard.session.infer(image);
        shard.inflight.fetch_sub(1, Ordering::Relaxed);
        match res {
            Ok(out) => Ok(out),
            Err(e) => {
                // Classify by the typed error first: a worker panicking
                // mid-batch fails our recv before its exit guard flips the
                // liveness flag, so the snapshot alone can race.
                let folded = EngineError::from_request(e);
                if folded.is_shard_fatal()
                    || !shard.session.worker_alive()
                    || shard.session.is_closed()
                {
                    self.mark_unhealthy(i);
                    Err(RouteAttempt::ShardDown)
                } else {
                    Err(RouteAttempt::Request(folded))
                }
            }
        }
    }

    /// True when a shard other than `except` is still routable — i.e. a
    /// reroute after a failure on `except` could actually go somewhere.
    fn another_routable(&self, except: usize) -> bool {
        (0..self.shards.len()).any(|j| j != except && self.shard_routable(j))
    }

    /// Routed inference without admission accounting, consuming the image.
    /// A retry copy is cloned only when a reroute is actually possible, so
    /// single-shard pools move the image straight through with zero extra
    /// allocation (parity with a bare session).
    fn infer_routed_owned(
        &self,
        mut image: Vec<f32>,
        key: Option<u64>,
    ) -> Result<Vec<f32>, EngineError> {
        loop {
            // Each failed attempt marks its shard unhealthy, so this loop
            // runs at most shards+1 times before NoHealthyShards.
            let i = self.pick(key)?;
            let retry = self.another_routable(i).then(|| image.clone());
            match self.infer_on_owned(i, image) {
                Ok(out) => return Ok(out),
                Err(RouteAttempt::ShardDown) => {
                    self.rerouted.fetch_add(1, Ordering::Relaxed);
                    image = match retry {
                        Some(img) => img,
                        // The failed shard was the last routable one.
                        None => return Err(EngineError::NoHealthyShards),
                    };
                }
                Err(RouteAttempt::Request(e)) => return Err(e),
            }
        }
    }

    /// [`EnginePool::infer_routed_owned`] over a borrowed image.
    fn infer_routed(&self, image: &[f32], key: Option<u64>) -> Result<Vec<f32>, EngineError> {
        self.infer_routed_owned(image.to_vec(), key)
    }

    /// Classify one image (blocking), admission-controlled: a full global
    /// queue sheds with [`EngineError::Rejected`] instead of waiting.
    pub fn infer(&self, image: Vec<f32>) -> Result<Vec<f32>, EngineError> {
        self.admit()?;
        let res = self.infer_routed_owned(image, None);
        self.unadmit(1);
        res
    }

    /// Classify one image with a routing key: under
    /// [`Placement::HashKey`], equal keys land on the same healthy shard.
    pub fn infer_keyed(&self, key: &str, image: Vec<f32>) -> Result<Vec<f32>, EngineError> {
        self.admit()?;
        let res = self.infer_routed_owned(image, Some(hash_key(key)));
        self.unadmit(1);
        res
    }

    /// Enqueue one request on a routed shard without waiting for its
    /// result; collect with [`EnginePool::drain`]. Unlike
    /// [`crate::engine::Session::submit`], a full pool **never blocks**: it
    /// sheds with [`EngineError::Rejected`]. The admission slot is held
    /// until the request is drained.
    pub fn submit(&self, image: Vec<f32>) -> Result<PoolTicket, EngineError> {
        self.submit_inner(image, None)
    }

    /// [`EnginePool::submit`] with a routing key (see
    /// [`EnginePool::infer_keyed`]).
    pub fn submit_keyed(&self, key: &str, image: Vec<f32>) -> Result<PoolTicket, EngineError> {
        self.submit_inner(image, Some(hash_key(key)))
    }

    fn submit_inner(&self, image: Vec<f32>, key: Option<u64>) -> Result<PoolTicket, EngineError> {
        self.admit()?;
        // A full shard queue never parks the caller: the per-shard step is
        // non-blocking (`Session::try_submit`), every candidate shard is
        // probed once, and only when all of them report full is the
        // request shed typed. The image *moves* through the probes —
        // try_submit hands it back on every non-accepted outcome, so the
        // streaming hot path never clones. Hash affinity gets exactly one
        // candidate — spilling a keyed request onto a neighbor would break
        // keyed caching.
        let mut image = image;
        loop {
            let mut cands = match self.candidates(key) {
                Ok(c) => c,
                Err(e) => {
                    self.unadmit(1);
                    return Err(e);
                }
            };
            if key.is_some() && self.placement == Placement::HashKey {
                cands.truncate(1);
            }
            let mut saw_full = false;
            let mut marked_down = false;
            for i in cands {
                if !self.shard_routable(i) {
                    continue; // died since the candidate list was built
                }
                // The gate orders session-submit vs pool registration per
                // shard, so drain can match tickets positionally.
                let gate = lock_recover(&self.shards[i].submit_gate);
                match self.shards[i].session.try_submit(image) {
                    TrySubmit::Accepted(inner) => {
                        let mut pending = lock_recover(&self.pending);
                        let ticket =
                            PoolTicket(self.next_ticket.fetch_add(1, Ordering::Relaxed));
                        pending.push_back(PendingEntry { ticket, shard: i, inner });
                        self.shards[i].inflight.fetch_add(1, Ordering::Relaxed);
                        return Ok(ticket);
                    }
                    TrySubmit::Full(img) => {
                        drop(gate);
                        image = img;
                        saw_full = true;
                    }
                    TrySubmit::Refused(e, img) if e.is_shard_fatal() => {
                        drop(gate);
                        image = img;
                        self.mark_unhealthy(i);
                        self.rerouted.fetch_add(1, Ordering::Relaxed);
                        marked_down = true;
                    }
                    TrySubmit::Refused(e, _) => {
                        drop(gate);
                        self.unadmit(1);
                        return Err(e);
                    }
                }
            }
            if saw_full {
                self.unadmit(1);
                self.shed.fetch_add(1, Ordering::Relaxed);
                return Err(EngineError::Rejected { retry_after_hint: self.retry_hint() });
            }
            if !marked_down {
                // Nothing accepted, nothing full, nothing newly dead: no
                // routable shard remains.
                self.unadmit(1);
                return Err(EngineError::NoHealthyShards);
            }
            // Some shards died this round: retry with fresh candidates
            // (each round marks ≥1 shard down, so this terminates).
        }
    }

    /// Wait for every outstanding [`EnginePool::submit`] and return the
    /// results in pool submission order. Items stranded on a dead or
    /// closed shard resolve to per-item typed lifecycle errors
    /// ([`EngineError::WorkerDied`] / [`EngineError::Closed`]) and the
    /// shard is marked unhealthy — a drain never hangs on a dead worker.
    /// Returns [`EngineError::EmptyQueue`] when nothing is outstanding.
    #[allow(clippy::type_complexity)]
    pub fn drain(&self) -> Result<Vec<(PoolTicket, Result<Vec<f32>, EngineError>)>, EngineError> {
        let _gate = lock_recover(&self.drain_gate);
        let entries: Vec<PendingEntry> = {
            let mut pending = lock_recover(&self.pending);
            if pending.is_empty() {
                return Err(EngineError::EmptyQueue);
            }
            pending.drain(..).collect()
        };
        Ok(entries.into_iter().map(|e| self.drain_entry(e)).collect())
    }

    /// Pop the **oldest** outstanding pool submission and wait for its
    /// result — the single-step form of [`EnginePool::drain`]. Streaming
    /// clients use it to drain incrementally on [`EngineError::Rejected`]
    /// (freeing one admission slot) instead of collapsing the whole
    /// pipeline, so the shard queues stay fed.
    #[allow(clippy::type_complexity)]
    pub fn drain_one(
        &self,
    ) -> Result<(PoolTicket, Result<Vec<f32>, EngineError>), EngineError> {
        let _gate = lock_recover(&self.drain_gate);
        let entry = match lock_recover(&self.pending).pop_front() {
            None => return Err(EngineError::EmptyQueue),
            Some(e) => e,
        };
        Ok(self.drain_entry(entry))
    }

    /// Resolve one pending entry: match it against its shard's oldest
    /// submission, fold the result typed, update health / latency /
    /// admission accounting.
    fn drain_entry(&self, e: PendingEntry) -> (PoolTicket, Result<Vec<f32>, EngineError>) {
        let res = match self.shards[e.shard].session.drain_one() {
            Ok((inner, r)) if inner == e.inner => r.map_err(EngineError::from_request),
            Ok((inner, _)) => Err(EngineError::Request(format!(
                "pool drain desynchronized on shard {}: expected ticket {:?}, got \
                 {inner:?} (were requests submitted directly to the shard session?)",
                e.shard, e.inner
            ))),
            Err(EngineError::EmptyQueue) => Err(EngineError::Request(format!(
                "pool drain desynchronized on shard {}: ticket {:?} already taken \
                 (was the shard session drained directly?)",
                e.shard, e.inner
            ))),
            Err(err) => Err(err),
        };
        let shard = &self.shards[e.shard];
        if matches!(res, Err(ref err) if err.is_shard_fatal())
            || !shard.session.worker_alive()
            || shard.session.is_closed()
        {
            self.mark_unhealthy(e.shard);
        }
        shard.inflight.fetch_sub(1, Ordering::Relaxed);
        self.unadmit(1);
        (e.ticket, res)
    }

    /// Run a whole slice through the pool, split into contiguous chunks —
    /// one per healthy shard — each pipelined through that shard's
    /// [`Session::infer_batch`] (so per-shard dynamic batches fill to
    /// `max_batch` with no linger stall, exactly like a single session);
    /// results in input order. This is the **closed-loop** path: it
    /// bypasses admission shedding (the caller is the only load source and
    /// per-shard backpressure already bounds memory). For homogeneous SC
    /// shards the outputs are bit-identical to a single session — all
    /// shards share one compiled plan, and the stochastic datapath is
    /// deterministic per image. A chunk stranded by a mid-batch shard
    /// death is retried image-by-image on the survivors.
    pub fn infer_batch(&self, images: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, EngineError> {
        if self.closed.load(Ordering::Acquire) {
            return Err(EngineError::Closed);
        }
        let n = images.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let workers: Vec<usize> =
            (0..self.shards.len()).filter(|&i| self.shard_routable(i)).collect();
        if workers.is_empty() {
            return Err(EngineError::NoHealthyShards);
        }
        let per = n.div_ceil(workers.len());
        let mut slot_init: Vec<Option<Result<Vec<f32>, EngineError>>> = Vec::new();
        slot_init.resize_with(n, || None);
        let slots = Mutex::new(slot_init);
        std::thread::scope(|scope| {
            for (ci, &wi) in workers.iter().enumerate() {
                let lo = (ci * per).min(n);
                let hi = ((ci + 1) * per).min(n);
                if lo >= hi {
                    continue;
                }
                let chunk = &images[lo..hi];
                let slots = &slots;
                scope.spawn(move || {
                    // Advertise the chunk load so LeastQueueDepth routing
                    // sees batch-saturated shards; released on completion.
                    self.shards[wi].inflight.fetch_add(hi - lo, Ordering::Relaxed);
                    match self.shards[wi].session.infer_batch(chunk) {
                        Ok(outs) => {
                            let mut g = lock_recover(slots);
                            for (off, out) in outs.into_iter().enumerate() {
                                g[lo + off] = Some(Ok(out));
                            }
                        }
                        Err(e) => {
                            // Whole-chunk failure. A dead shard strands the
                            // chunk: mark it down and reroute each image to
                            // the survivors; a request-level failure is
                            // recorded for every image of the chunk (the
                            // session's own infer_batch aborts on the
                            // first error the same way). Classify by the
                            // typed error first — the liveness snapshot
                            // races a panicking worker's exit guard.
                            let shard = &self.shards[wi].session;
                            let err = EngineError::from_request(e);
                            let shard_down = err.is_shard_fatal()
                                || !shard.worker_alive()
                                || shard.is_closed();
                            if shard_down {
                                self.mark_unhealthy(wi);
                            }
                            for (off, img) in chunk.iter().enumerate() {
                                let res = if shard_down {
                                    self.rerouted.fetch_add(1, Ordering::Relaxed);
                                    self.infer_routed(img, None)
                                } else {
                                    Err(err.clone())
                                };
                                lock_recover(slots)[lo + off] = Some(res);
                            }
                        }
                    }
                    self.shards[wi].inflight.fetch_sub(hi - lo, Ordering::Relaxed);
                });
            }
        });
        let filled = slots.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner);
        let mut out = Vec::with_capacity(n);
        for (i, slot) in filled.into_iter().enumerate() {
            match slot {
                Some(Ok(v)) => out.push(v),
                Some(Err(e)) => return Err(e),
                None => {
                    return Err(EngineError::Request(format!(
                        "image {i} was never served (batch worker exited early)"
                    )))
                }
            }
        }
        Ok(out)
    }

    /// Gracefully drain and close the pool: new requests are refused with
    /// [`EngineError::Closed`], every shard finishes its queued work, and
    /// this call returns once all workers have exited. Results of earlier
    /// submits stay collectable via [`EnginePool::drain`]. Idempotent.
    pub fn close(&self) {
        self.closed.store(true, Ordering::Release);
        for s in &self.shards {
            s.session.close();
        }
    }

    /// Aggregated pool metrics (merged histograms/percentiles, per-shard
    /// snapshots, shed/reroute counters, scaled hardware estimate).
    pub fn metrics(&self) -> PoolMetrics {
        let per_shard: Vec<SessionMetrics> =
            self.shards.iter().map(|s| s.session.metrics()).collect();
        let mut m = PoolMetrics::aggregate(
            per_shard,
            self.healthy_shards(),
            self.shed.load(Ordering::Relaxed),
            self.rerouted.load(Ordering::Relaxed),
            self.opened.elapsed(),
        );
        let counters = lock_recover(&self.tenant_counters);
        m.tenants = counters
            .iter()
            .map(|(name, c)| TenantStats {
                tenant: name.clone(),
                requests: c.ok,
                quota_rejected: c.quota_rejected,
                shed: c.shed,
                failed: c.failed,
            })
            .collect();
        m
    }

    /// Records how a tenant-attributed request ended. Called by the
    /// serving front door; the counters surface in
    /// [`PoolMetrics::tenants`] and the Prometheus exposition.
    pub fn note_tenant(&self, tenant: &str, outcome: TenantOutcome) {
        let mut counters = lock_recover(&self.tenant_counters);
        let entry = counters.entry(tenant.to_string()).or_default();
        match outcome {
            TenantOutcome::Ok => entry.ok += 1,
            TenantOutcome::QuotaRejected => entry.quota_rejected += 1,
            TenantOutcome::Shed => entry.shed += 1,
            TenantOutcome::Failed => entry.failed += 1,
        }
    }
}

/// FNV-1a over the request key (stable across processes, unlike
/// `DefaultHasher`), so hash affinity survives restarts. Shares the single
/// audited implementation with the plan-cache fingerprint.
fn hash_key(key: &str) -> u64 {
    crate::engine::config::fnv1a_64(key.as_bytes())
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::accel::layers::{LayerKind, LayerSpec, NetworkSpec};
    use crate::accel::network::{LayerWeights, QuantizedWeights};
    use crate::engine::BackendKind;
    use crate::sc::quantize_bipolar;

    fn tiny_net(name: &str) -> NetworkSpec {
        NetworkSpec {
            name: name.into(),
            input: (1, 4, 4),
            layers: vec![LayerSpec {
                kind: LayerKind::Dense { inputs: 16, outputs: 3 },
                relu: false,
            }],
        }
    }

    fn tiny_weights() -> QuantizedWeights {
        let codes: Vec<Vec<u32>> = (0..3)
            .map(|oc| {
                (0..16)
                    .map(|j| quantize_bipolar(((oc * 5 + j) % 9) as f64 / 4.5 - 1.0, 8))
                    .collect()
            })
            .collect();
        QuantizedWeights { bits: 8, layers: vec![LayerWeights { codes, gamma: 1.0, mu: 0.0 }] }
    }

    fn cfg() -> EngineConfig {
        EngineConfig::new(BackendKind::Expectation, tiny_net("tiny-pool"))
            .with_quantized(tiny_weights())
    }

    #[test]
    fn placement_parses_and_round_trips() {
        for p in [Placement::RoundRobin, Placement::LeastQueueDepth, Placement::HashKey] {
            assert_eq!(p.label().parse::<Placement>().unwrap(), p);
        }
        assert_eq!("rr".parse::<Placement>().unwrap(), Placement::RoundRobin);
        assert_eq!("least".parse::<Placement>().unwrap(), Placement::LeastQueueDepth);
        assert_eq!("hash".parse::<Placement>().unwrap(), Placement::HashKey);
        assert!("sticky".parse::<Placement>().is_err());
    }

    #[test]
    fn pool_config_validation() {
        assert!(PoolConfig::heterogeneous(Vec::new()).validate().is_err());
        // Each shard config is validated (missing weights).
        let bad = EngineConfig::new(BackendKind::StochasticFused, tiny_net("noweights"));
        assert!(PoolConfig::replicated(bad, 2).validate().is_err());
        // Front-door shape mismatch across shards.
        let other = NetworkSpec {
            name: "wide".into(),
            input: (1, 4, 4),
            layers: vec![LayerSpec {
                kind: LayerKind::Dense { inputs: 16, outputs: 5 },
                relu: false,
            }],
        };
        let codes: Vec<Vec<u32>> = (0..5)
            .map(|_| (0..16).map(|j| quantize_bipolar(j as f64 / 16.0, 8)).collect())
            .collect();
        let wide_cfg = EngineConfig::new(BackendKind::Expectation, other).with_quantized(
            QuantizedWeights { bits: 8, layers: vec![LayerWeights { codes, gamma: 1.0, mu: 0.0 }] },
        );
        let err = PoolConfig::heterogeneous(vec![cfg(), wide_cfg])
            .validate()
            .unwrap_err()
            .to_string();
        assert!(err.contains("front"), "{err}");
        // Valid homogeneous config passes and sizes its admission queue.
        let pc = PoolConfig::replicated(cfg(), 3);
        pc.validate().unwrap();
        assert_eq!(pc.effective_queue_depth(), 3 * cfg().batch.queue_depth);
        assert_eq!(pc.with_queue_depth(7).effective_queue_depth(), 7);
    }

    #[test]
    fn replicated_never_builds_an_empty_pool() {
        let pc = PoolConfig::replicated(cfg(), 0);
        assert_eq!(pc.shards.len(), 1, "0 shards clamps to 1");
    }

    #[test]
    fn hash_key_is_stable_and_spreads() {
        let a = hash_key("client-a");
        assert_eq!(a, hash_key("client-a"));
        assert_ne!(a, hash_key("client-b"));
        // Pinned value: affinity must survive process restarts.
        assert_eq!(hash_key(""), 0xcbf2_9ce4_8422_2325);
    }

    #[test]
    fn round_robin_rotates_over_healthy_shards() {
        let pool = EnginePool::open(PoolConfig::replicated(cfg(), 3)).unwrap();
        let picks: Vec<usize> = (0..6).map(|_| pool.pick(None).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        pool.mark_unhealthy(1);
        let picks: Vec<usize> = (0..4).map(|_| pool.pick(None).unwrap()).collect();
        assert!(!picks.contains(&1), "unhealthy shard skipped: {picks:?}");
        assert_eq!(pool.healthy_shards(), 2);
    }

    #[test]
    fn least_queue_depth_prefers_empty_shards() {
        let pool = EnginePool::open(
            PoolConfig::replicated(cfg(), 2).with_placement(Placement::LeastQueueDepth),
        )
        .unwrap();
        pool.shards[0].inflight.store(5, Ordering::Relaxed);
        assert_eq!(pool.pick(None).unwrap(), 1);
        pool.shards[1].inflight.store(9, Ordering::Relaxed);
        assert_eq!(pool.pick(None).unwrap(), 0);
    }

    #[test]
    fn tenant_counters_roll_up_sorted_into_metrics() {
        let pool = EnginePool::open(PoolConfig::replicated(cfg(), 1)).unwrap();
        pool.note_tenant("beta", TenantOutcome::Ok);
        pool.note_tenant("beta", TenantOutcome::Shed);
        pool.note_tenant("alpha", TenantOutcome::Ok);
        pool.note_tenant("alpha", TenantOutcome::Ok);
        pool.note_tenant("alpha", TenantOutcome::QuotaRejected);
        pool.note_tenant("alpha", TenantOutcome::Failed);
        let m = pool.metrics();
        let names: Vec<&str> = m.tenants.iter().map(|t| t.tenant.as_str()).collect();
        assert_eq!(names, vec!["alpha", "beta"], "sorted by tenant name");
        assert_eq!(m.tenants[0].requests, 2);
        assert_eq!(m.tenants[0].quota_rejected, 1);
        assert_eq!(m.tenants[0].failed, 1);
        assert_eq!(m.tenants[1].requests, 1);
        assert_eq!(m.tenants[1].shed, 1);
        assert!(m.summary().contains("tenant alpha: 2 ok"));
    }
}
