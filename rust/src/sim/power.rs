//! Activity-based switching-energy estimation.
//!
//! This reproduces what Genus's average-power report does at this
//! abstraction: run representative stimulus for many cycles, count output
//! transitions per cell, and charge each transition its cell's switching
//! energy. DFFs additionally burn clock-pin energy every cycle regardless of
//! data activity.

use crate::netlist::Netlist;
use crate::sim::eval::Evaluator;
use crate::tech::{CellKind, CellLibrary};

/// Fraction of a DFF's switching energy consumed by the internal clock
/// buffers on every cycle, independent of data toggling.
pub const DFF_CLOCK_ENERGY_FRACTION: f64 = 0.4;

/// Number of warm-up cycles excluded from activity counting (flushes the
/// all-zero reset transient).
pub const WARMUP_CYCLES: usize = 8;

/// Result of an activity-based power run.
#[derive(Debug, Clone)]
pub struct PowerReport {
    /// Average switching energy per clock cycle, fJ.
    pub energy_per_cycle_fj: f64,
    /// Static leakage power, nW.
    pub leakage_nw: f64,
    /// Counted (post-warm-up) cycles.
    pub cycles: usize,
    /// Average toggle rate across all gate outputs (diagnostics).
    pub mean_toggle_rate: f64,
}

/// Estimate average per-cycle switching energy under `stimulus`.
///
/// `stimulus(t, pi_buf)` fills the primary-input vector for cycle `t`
/// (warm-up cycles use `t = 0..WARMUP_CYCLES`, counted cycles continue the
/// numbering).
pub fn estimate<F>(nl: &Netlist, lib: &CellLibrary, cycles: usize, mut stimulus: F) -> PowerReport
where
    F: FnMut(usize, &mut Vec<bool>),
{
    assert!(cycles > 0, "need at least one counted cycle");
    let mut ev = Evaluator::new(nl);
    let mut pi_buf = vec![false; nl.primary_inputs.len()];

    // Map each net to the gate kind driving it (for energy lookup).
    let mut driver: Vec<Option<CellKind>> = vec![None; nl.num_nets()];
    for g in nl.gates() {
        for &o in &g.outputs {
            driver[o.0 as usize] = Some(g.kind);
        }
    }
    let n_dff = nl.gates().iter().filter(|g| g.kind == CellKind::Dff).count();

    let mut toggles = vec![0u64; nl.num_nets()];
    let mut prev: Vec<bool> = Vec::new();
    let total = WARMUP_CYCLES + cycles;
    for t in 0..total {
        stimulus(t, &mut pi_buf);
        ev.set_inputs(&pi_buf);
        ev.propagate();
        let now = ev.net_values();
        if t >= WARMUP_CYCLES {
            for (i, (&a, &b)) in prev.iter().zip(now.iter()).enumerate() {
                if a != b && driver[i].is_some() {
                    toggles[i] += 1;
                }
            }
        }
        prev = now.to_vec();
        ev.tick();
        // Capture DFF Q transitions caused by the clock edge as part of the
        // *next* cycle's settled-value comparison (prev holds pre-edge Qs
        // only for combinational nets; update prev with post-edge values so
        // Q toggles attribute to the edge that caused them).
        let post = ev.net_values();
        for (i, (p, &q)) in prev.iter_mut().zip(post.iter()).enumerate() {
            if *p != q {
                if t >= WARMUP_CYCLES {
                    toggles[i] += 1;
                }
                *p = q;
            }
        }
    }

    let mut energy = 0.0f64;
    let mut leakage = 0.0f64;
    let mut toggle_sum = 0.0f64;
    let mut toggle_nets = 0usize;
    for g in nl.gates() {
        let cell = lib.cell(g.kind);
        leakage += cell.leakage_nw;
        for &o in &g.outputs {
            let tg = toggles[o.0 as usize] as f64;
            energy += tg * cell.switch_energy_fj;
            toggle_sum += tg / cycles as f64;
            toggle_nets += 1;
        }
    }
    // Clock-tree/internal-clock energy of the sequential cells.
    energy += (n_dff as f64)
        * lib.cell_if(CellKind::Dff).map_or(0.0, |c| c.switch_energy_fj)
        * DFF_CLOCK_ENERGY_FRACTION
        * cycles as f64;

    PowerReport {
        energy_per_cycle_fj: energy / cycles as f64,
        leakage_nw: leakage,
        cycles,
        mean_toggle_rate: if toggle_nets > 0 { toggle_sum / toggle_nets as f64 } else { 0.0 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// xorshift32 for deterministic pseudo-random stimulus.
    fn rng_stream(seed: u32) -> impl FnMut() -> bool {
        let mut s = seed.max(1);
        move || {
            s ^= s << 13;
            s ^= s >> 17;
            s ^= s << 5;
            s & 1 == 1
        }
    }

    #[test]
    fn static_inputs_burn_no_switching_energy() {
        let lib = CellLibrary::finfet10();
        let mut nl = Netlist::new("static");
        let a = nl.input();
        let b = nl.input();
        let y = nl.and2(a, b);
        nl.mark_output(y);
        let rep = estimate(&nl, &lib, 100, |_, pi| {
            pi[0] = true;
            pi[1] = false;
        });
        assert_eq!(rep.energy_per_cycle_fj, 0.0);
        assert!(rep.leakage_nw > 0.0);
    }

    #[test]
    fn toggling_inverter_burns_one_transition_per_cycle() {
        let lib = CellLibrary::finfet10();
        let mut nl = Netlist::new("tog");
        let a = nl.input();
        let y = nl.inv(a);
        nl.mark_output(y);
        let rep = estimate(&nl, &lib, 200, |t, pi| pi[0] = t % 2 == 0);
        let e_inv = lib.cell(CellKind::Inv).switch_energy_fj;
        assert!((rep.energy_per_cycle_fj - e_inv).abs() < 1e-9);
    }

    #[test]
    fn random_inputs_give_half_toggle_rate() {
        let lib = CellLibrary::finfet10();
        let mut nl = Netlist::new("buf");
        let a = nl.input();
        let y = nl.buf(a);
        nl.mark_output(y);
        let mut rng = rng_stream(7);
        let rep = estimate(&nl, &lib, 4000, |_, pi| pi[0] = rng());
        // A buffer toggles when its input toggles: rate ≈ 0.5.
        assert!((rep.mean_toggle_rate - 0.5).abs() < 0.05, "rate={}", rep.mean_toggle_rate);
    }

    #[test]
    fn dff_pays_clock_energy_even_when_idle() {
        let lib = CellLibrary::finfet10();
        let mut nl = Netlist::new("idle_reg");
        let d = nl.input();
        let q = nl.dff(d);
        nl.mark_output(q);
        let rep = estimate(&nl, &lib, 100, |_, pi| pi[0] = false);
        let expected = lib.cell(CellKind::Dff).switch_energy_fj * DFF_CLOCK_ENERGY_FRACTION;
        assert!((rep.energy_per_cycle_fj - expected).abs() < 1e-9);
    }
}
