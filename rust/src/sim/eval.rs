//! Cycle-based logic evaluation of a [`Netlist`].
//!
//! Zero-delay semantics: within a cycle, combinational gates settle in
//! topological order; [`Evaluator::tick`] models the clock edge updating all
//! DFFs simultaneously. Combinational loops are rejected at construction.

use crate::netlist::{NetId, Netlist};
use crate::tech::CellKind;

/// Evaluates a netlist cycle by cycle.
pub struct Evaluator<'a> {
    nl: &'a Netlist,
    /// Combinational gate indices in dependency order.
    topo: Vec<usize>,
    /// DFF gate indices.
    dffs: Vec<usize>,
    /// Current value of every net.
    values: Vec<bool>,
}

impl<'a> Evaluator<'a> {
    /// Build an evaluator; panics if the combinational part has a cycle or a
    /// gate input is never driven.
    pub fn new(nl: &'a Netlist) -> Self {
        let n_nets = nl.num_nets();
        let mut driven = vec![false; n_nets];
        for &pi in &nl.primary_inputs {
            driven[pi.0 as usize] = true;
        }
        for &(c, _) in &nl.constants {
            driven[c.0 as usize] = true;
        }
        let mut dffs = Vec::new();
        for (gi, g) in nl.gates().iter().enumerate() {
            if g.kind == CellKind::Dff {
                dffs.push(gi);
                for &o in &g.outputs {
                    driven[o.0 as usize] = true;
                }
            }
        }
        // Kahn over combinational gates.
        let mut topo = Vec::with_capacity(nl.num_gates() - dffs.len());
        let mut placed = vec![false; nl.num_gates()];
        for &d in &dffs {
            placed[d] = true;
        }
        loop {
            let mut progressed = false;
            for (gi, g) in nl.gates().iter().enumerate() {
                if placed[gi] {
                    continue;
                }
                if g.inputs.iter().all(|i| driven[i.0 as usize]) {
                    for &o in &g.outputs {
                        driven[o.0 as usize] = true;
                    }
                    topo.push(gi);
                    placed[gi] = true;
                    progressed = true;
                }
            }
            if !progressed {
                break;
            }
        }
        assert!(
            placed.iter().all(|&p| p),
            "netlist '{}' has a combinational cycle or undriven gate input",
            nl.name
        );

        let mut values = vec![false; n_nets];
        for &(c, v) in &nl.constants {
            values[c.0 as usize] = v;
        }
        Evaluator { nl, topo, dffs, values }
    }

    /// Set primary-input values (must match the PI count).
    pub fn set_inputs(&mut self, vals: &[bool]) {
        assert_eq!(vals.len(), self.nl.primary_inputs.len(), "PI arity mismatch");
        for (&pi, &v) in self.nl.primary_inputs.iter().zip(vals) {
            self.values[pi.0 as usize] = v;
        }
    }

    /// Settle the combinational logic from current PI + DFF state.
    pub fn propagate(&mut self) {
        for &gi in &self.topo {
            let g = &self.nl.gates()[gi];
            let v = |n: NetId| self.values[n.0 as usize];
            match g.kind {
                CellKind::Inv => {
                    self.values[g.outputs[0].0 as usize] = !v(g.inputs[0]);
                }
                CellKind::Buf => {
                    self.values[g.outputs[0].0 as usize] = v(g.inputs[0]);
                }
                CellKind::Nand2 => {
                    self.values[g.outputs[0].0 as usize] = !(v(g.inputs[0]) & v(g.inputs[1]));
                }
                CellKind::Nor2 => {
                    self.values[g.outputs[0].0 as usize] = !(v(g.inputs[0]) | v(g.inputs[1]));
                }
                CellKind::And2 => {
                    self.values[g.outputs[0].0 as usize] = v(g.inputs[0]) & v(g.inputs[1]);
                }
                CellKind::Or2 => {
                    self.values[g.outputs[0].0 as usize] = v(g.inputs[0]) | v(g.inputs[1]);
                }
                CellKind::Xor2 => {
                    self.values[g.outputs[0].0 as usize] = v(g.inputs[0]) ^ v(g.inputs[1]);
                }
                CellKind::Xnor2 => {
                    self.values[g.outputs[0].0 as usize] = !(v(g.inputs[0]) ^ v(g.inputs[1]));
                }
                CellKind::Mux21 => {
                    let (d0, d1, s) = (v(g.inputs[0]), v(g.inputs[1]), v(g.inputs[2]));
                    self.values[g.outputs[0].0 as usize] = if s { d1 } else { d0 };
                }
                // prog = 0 → NAND, prog = 1 → NOR (Fig. 6b).
                CellKind::NandNor => {
                    let (a, b, p) = (v(g.inputs[0]), v(g.inputs[1]), v(g.inputs[2]));
                    self.values[g.outputs[0].0 as usize] =
                        if p { !(a | b) } else { !(a & b) };
                }
                CellKind::Xor3 => {
                    self.values[g.outputs[0].0 as usize] =
                        v(g.inputs[0]) ^ v(g.inputs[1]) ^ v(g.inputs[2]);
                }
                CellKind::Maj3 => {
                    let (a, b, c) = (v(g.inputs[0]), v(g.inputs[1]), v(g.inputs[2]));
                    self.values[g.outputs[0].0 as usize] = (a & b) | (a & c) | (b & c);
                }
                CellKind::HalfAdder => {
                    let (a, b) = (v(g.inputs[0]), v(g.inputs[1]));
                    self.values[g.outputs[0].0 as usize] = a ^ b;
                    self.values[g.outputs[1].0 as usize] = a & b;
                }
                CellKind::FullAdder => {
                    let (a, b, c) = (v(g.inputs[0]), v(g.inputs[1]), v(g.inputs[2]));
                    self.values[g.outputs[0].0 as usize] = a ^ b ^ c;
                    self.values[g.outputs[1].0 as usize] = (a & b) | (a & c) | (b & c);
                }
                CellKind::Dff => unreachable!("DFFs are excluded from the topo order"),
            }
        }
    }

    /// Clock edge: every DFF's Q takes its D value (simultaneously).
    pub fn tick(&mut self) {
        let sampled: Vec<(u32, bool)> = self
            .dffs
            .iter()
            .map(|&gi| {
                let g = &self.nl.gates()[gi];
                (g.outputs[0].0, self.values[g.inputs[0].0 as usize])
            })
            .collect();
        for (q, v) in sampled {
            self.values[q as usize] = v;
        }
    }

    /// Value of one net.
    pub fn value(&self, net: NetId) -> bool {
        self.values[net.0 as usize]
    }

    /// Current primary-output values.
    pub fn outputs(&self) -> Vec<bool> {
        self.nl.primary_outputs.iter().map(|&n| self.value(n)).collect()
    }

    /// Snapshot of every net (for activity counting).
    pub fn net_values(&self) -> &[bool] {
        &self.values
    }

    /// Reset all DFF state (and every other net) to 0, re-applying constants.
    pub fn reset(&mut self) {
        self.values.iter_mut().for_each(|v| *v = false);
        for &(c, v) in &self.nl.constants {
            self.values[c.0 as usize] = v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn combinational_gates_truth_tables() {
        let mut nl = Netlist::new("tt");
        let a = nl.input();
        let b = nl.input();
        let c = nl.input();
        let outs = vec![
            nl.nand2(a, b),
            nl.nor2(a, b),
            nl.xor2(a, b),
            nl.mux21(a, b, c),
            nl.nandnor(a, b, c),
            nl.xor3(a, b, c),
            nl.maj3(a, b, c),
        ];
        for &o in &outs {
            nl.mark_output(o);
        }
        let mut ev = Evaluator::new(&nl);
        for bits in 0..8u32 {
            let (a, b, c) = (bits & 1 != 0, bits & 2 != 0, bits & 4 != 0);
            ev.set_inputs(&[a, b, c]);
            ev.propagate();
            let o = ev.outputs();
            assert_eq!(o[0], !(a & b), "nand {bits}");
            assert_eq!(o[1], !(a | b), "nor {bits}");
            assert_eq!(o[2], a ^ b, "xor {bits}");
            assert_eq!(o[3], if c { b } else { a }, "mux {bits}");
            assert_eq!(o[4], if c { !(a | b) } else { !(a & b) }, "nandnor {bits}");
            assert_eq!(o[5], a ^ b ^ c, "xor3 {bits}");
            assert_eq!(o[6], (a & b) | (a & c) | (b & c), "maj3 {bits}");
        }
    }

    #[test]
    fn adders_match_arithmetic() {
        let mut nl = Netlist::new("fa");
        let ins = nl.inputs(3);
        let (s, c) = nl.full_adder_cell(ins[0], ins[1], ins[2]);
        let (s2, c2) = nl.full_adder_rfet(ins[0], ins[1], ins[2]);
        for n in [s, c, s2, c2] {
            nl.mark_output(n);
        }
        let mut ev = Evaluator::new(&nl);
        for bits in 0..8u32 {
            let v = [bits & 1 != 0, bits & 2 != 0, bits & 4 != 0];
            ev.set_inputs(&v);
            ev.propagate();
            let o = ev.outputs();
            let total = v.iter().filter(|&&x| x).count();
            assert_eq!(o[0] as usize + 2 * (o[1] as usize), total, "cell FA");
            assert_eq!(o[2] as usize + 2 * (o[3] as usize), total, "RFET FA");
        }
    }

    #[test]
    fn dff_holds_state_across_cycles() {
        let mut nl = Netlist::new("reg");
        let d = nl.input();
        let q = nl.dff(d);
        nl.mark_output(q);
        let mut ev = Evaluator::new(&nl);
        ev.set_inputs(&[true]);
        ev.propagate();
        assert_eq!(ev.outputs(), vec![false], "Q before first edge");
        ev.tick();
        ev.set_inputs(&[false]);
        ev.propagate();
        assert_eq!(ev.outputs(), vec![true], "Q holds sampled 1");
        ev.tick();
        ev.propagate();
        assert_eq!(ev.outputs(), vec![false]);
    }

    #[test]
    fn dff_chain_is_a_shift_register() {
        let mut nl = Netlist::new("shift2");
        let d = nl.input();
        let q0 = nl.dff(d);
        let q1 = nl.dff(q0);
        nl.mark_output(q1);
        let mut ev = Evaluator::new(&nl);
        let pattern = [true, false, true, true, false];
        let mut seen = Vec::new();
        for &p in &pattern {
            ev.set_inputs(&[p]);
            ev.propagate();
            seen.push(ev.outputs()[0]);
            ev.tick();
        }
        // Two-stage delay: outputs are [0, 0, pattern...].
        assert_eq!(seen, vec![false, false, true, false, true]);
    }

    #[test]
    fn absorbed_netlists_evaluate() {
        let mut inner = Netlist::new("fa");
        let ins = inner.inputs(3);
        let (s, c) = inner.full_adder_cell(ins[0], ins[1], ins[2]);
        inner.mark_output(s);
        inner.mark_output(c);

        let mut outer = Netlist::new("two_fa");
        let pins = outer.inputs(3);
        let first = outer.absorb(&inner, &pins);
        let second = outer.absorb(&inner, &[first[0], first[1], pins[2]]);
        for &n in &second {
            outer.mark_output(n);
        }
        let mut ev = Evaluator::new(&outer);
        ev.set_inputs(&[true, true, true]);
        ev.propagate();
        // FA(1,1,1) = (s=1, c=1); FA(1,1,1) again = (1,1).
        assert_eq!(ev.outputs(), vec![true, true]);
    }
}
