//! Gate-level characterization: logic evaluation ([`eval`]), static timing
//! ([`timing`]), and activity-based power ([`power`]) over
//! [`crate::netlist::Netlist`] structures — the stand-in for the paper's
//! Cadence Genus flow (see DESIGN.md §Substitutions).

pub mod eval;
pub mod power;
pub mod timing;

pub use eval::Evaluator;
pub use power::{estimate as estimate_power, PowerReport};
pub use timing::{analyze as analyze_timing, TimingReport};

use crate::netlist::Netlist;
use crate::tech::CellLibrary;

/// Area/delay/energy summary of one block under one technology — the unit
/// of comparison in the paper's Table I / Table II.
#[derive(Debug, Clone)]
pub struct BlockReport {
    /// Block name.
    pub name: String,
    /// Technology name.
    pub tech: String,
    /// Cell area × wiring overhead, µm².
    pub area_um2: f64,
    /// Critical path, ps.
    pub delay_ps: f64,
    /// Average switching energy per cycle, fJ.
    pub energy_per_cycle_fj: f64,
    /// Leakage, nW.
    pub leakage_nw: f64,
    /// Total transistors.
    pub transistors: u64,
    /// Cell instances.
    pub num_gates: usize,
}

/// Total cell area of a netlist under a library (µm², incl. wiring factor).
pub fn area(nl: &Netlist, lib: &CellLibrary) -> f64 {
    nl.gates().iter().map(|g| lib.cell(g.kind).area_um2).sum::<f64>() * lib.wiring_overhead
}

/// Total leakage (nW).
pub fn leakage(nl: &Netlist, lib: &CellLibrary) -> f64 {
    nl.gates().iter().map(|g| lib.cell(g.kind).leakage_nw).sum()
}

/// Full characterization: area + static timing + activity power under the
/// provided stimulus (see [`power::estimate`]).
pub fn characterize<F>(
    nl: &Netlist,
    lib: &CellLibrary,
    cycles: usize,
    stimulus: F,
) -> BlockReport
where
    F: FnMut(usize, &mut Vec<bool>),
{
    let t = timing::analyze(nl, lib);
    let p = power::estimate(nl, lib, cycles, stimulus);
    BlockReport {
        name: nl.name.clone(),
        tech: lib.kind.to_string(),
        area_um2: area(nl, lib),
        delay_ps: t.critical_path_ps,
        energy_per_cycle_fj: p.energy_per_cycle_fj,
        leakage_nw: p.leakage_nw,
        transistors: nl.transistors(lib),
        num_gates: nl.num_gates(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tech::CellKind;

    #[test]
    fn area_sums_cells() {
        let lib = CellLibrary::finfet10();
        let mut nl = Netlist::new("pair");
        let a = nl.input();
        let x = nl.inv(a);
        let y = nl.inv(x);
        nl.mark_output(y);
        assert!((area(&nl, &lib) - 2.0 * lib.cell(CellKind::Inv).area_um2).abs() < 1e-12);
    }

    #[test]
    fn characterize_produces_consistent_report() {
        let lib = CellLibrary::rfet10();
        let mut nl = Netlist::new("fa_rfet");
        let ins = nl.inputs(3);
        let (s, c) = nl.full_adder_rfet(ins[0], ins[1], ins[2]);
        nl.mark_output(s);
        nl.mark_output(c);
        let mut t = 0u32;
        let rep = characterize(&nl, &lib, 500, |_, pi| {
            t = t.wrapping_mul(1664525).wrapping_add(1013904223);
            for (i, p) in pi.iter_mut().enumerate() {
                *p = (t >> (i + 3)) & 1 == 1;
            }
        });
        assert!(rep.area_um2 > 0.0);
        assert!(rep.delay_ps > 0.0);
        assert!(rep.energy_per_cycle_fj > 0.0);
        assert_eq!(rep.num_gates, 4); // xor3 + maj3 + 2 inv
    }
}
