//! Static timing analysis: longest combinational path through a netlist
//! under a cell library, with a linear fanout-load delay model.
//!
//! Endpoints are primary outputs and DFF D pins; startpoints are primary
//! inputs, constants, and DFF Q pins — i.e. the reported number is the
//! minimum clock period the block supports (ignoring setup margin, which
//! Genus folds into the library; our cells are calibrated at block level so
//! the margin is absorbed by calibration).

use crate::netlist::Netlist;
use crate::sim::eval::Evaluator;
use crate::tech::{CellKind, CellLibrary};

/// Result of static timing analysis.
#[derive(Debug, Clone)]
pub struct TimingReport {
    /// Longest path in picoseconds (min clock period).
    pub critical_path_ps: f64,
    /// Arrival time of every primary output, in `primary_outputs` order.
    pub output_arrivals_ps: Vec<f64>,
}

/// Compute the longest-path arrival times.
pub fn analyze(nl: &Netlist, lib: &CellLibrary) -> TimingReport {
    // Reuse the evaluator's topological order by rebuilding it here — the
    // construction is cheap relative to characterization runs.
    let _check = Evaluator::new(nl); // validates acyclicity / driven-ness
    let fanouts = nl.fanouts();
    let mut arrival = vec![0.0f64; nl.num_nets()];

    // Topological pass identical to the evaluator's: process gates whose
    // inputs are all resolved. DFF Q pins start at t=0.
    let mut resolved = vec![false; nl.num_nets()];
    for &pi in &nl.primary_inputs {
        resolved[pi.0 as usize] = true;
    }
    for &(c, _) in &nl.constants {
        resolved[c.0 as usize] = true;
    }
    let mut placed = vec![false; nl.num_gates()];
    let mut dff_d_arrivals: Vec<f64> = Vec::new();
    for (gi, g) in nl.gates().iter().enumerate() {
        if g.kind == CellKind::Dff {
            placed[gi] = true;
            for &o in &g.outputs {
                resolved[o.0 as usize] = true;
            }
        }
        let _ = gi;
    }
    loop {
        let mut progressed = false;
        for (gi, g) in nl.gates().iter().enumerate() {
            if placed[gi] || !g.inputs.iter().all(|i| resolved[i.0 as usize]) {
                continue;
            }
            let t_in = g
                .inputs
                .iter()
                .map(|i| arrival[i.0 as usize])
                .fold(0.0f64, f64::max);
            let cell = lib.cell(g.kind);
            for &o in &g.outputs {
                let d = cell.delay_at_fanout(fanouts[o.0 as usize].max(1));
                arrival[o.0 as usize] = t_in + d;
                resolved[o.0 as usize] = true;
            }
            placed[gi] = true;
            progressed = true;
        }
        if !progressed {
            break;
        }
    }

    // Endpoint collection: PO arrivals and DFF D-pin arrivals.
    for g in nl.gates() {
        if g.kind == CellKind::Dff {
            // Add the DFF's own setup/clk-to-q as its cell delay.
            let setup = lib.cell(CellKind::Dff).delay_ps;
            dff_d_arrivals.push(arrival[g.inputs[0].0 as usize] + setup);
        }
    }
    let output_arrivals_ps: Vec<f64> =
        nl.primary_outputs.iter().map(|o| arrival[o.0 as usize]).collect();
    let critical_path_ps = output_arrivals_ps
        .iter()
        .chain(dff_d_arrivals.iter())
        .fold(0.0f64, |m, &t| m.max(t));
    TimingReport { critical_path_ps, output_arrivals_ps }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chain_delay_adds_up() {
        // 8-stage MUX chain at fanout 1 ⇒ exactly 8 × MUX delay.
        let lib = CellLibrary::finfet10();
        let mut nl = Netlist::new("mux_chain");
        let mut o = nl.constant(false);
        for _ in 0..8 {
            let x = nl.input();
            let r = nl.input();
            o = nl.mux21(o, x, r);
        }
        nl.mark_output(o);
        let rep = analyze(&nl, &lib);
        let per_stage = lib.cell(CellKind::Mux21).delay_ps;
        assert!((rep.critical_path_ps - 8.0 * per_stage).abs() < 1e-6);
    }

    #[test]
    fn fanout_increases_delay() {
        let lib = CellLibrary::finfet10();
        let mut single = Netlist::new("fo1");
        let a = single.input();
        let x = single.inv(a);
        let y = single.inv(x);
        single.mark_output(y);

        let mut multi = Netlist::new("fo3");
        let a = multi.input();
        let x = multi.inv(a);
        let y = multi.inv(x);
        let z1 = multi.inv(x);
        let z2 = multi.inv(x);
        multi.mark_output(y);
        multi.mark_output(z1);
        multi.mark_output(z2);

        assert!(
            analyze(&multi, &lib).critical_path_ps > analyze(&single, &lib).critical_path_ps
        );
    }

    #[test]
    fn dff_d_pin_is_an_endpoint() {
        let lib = CellLibrary::finfet10();
        let mut nl = Netlist::new("reg_path");
        let a = nl.input();
        let mut x = a;
        for _ in 0..5 {
            x = nl.inv(x);
        }
        let q = nl.dff(x);
        nl.mark_output(q);
        let rep = analyze(&nl, &lib);
        // Path: 5 inverters + DFF setup — must exceed the inverter chain alone.
        let inv = lib.cell(CellKind::Inv).delay_ps;
        assert!(rep.critical_path_ps >= 5.0 * inv);
    }
}
