//! Artifact loading: test datasets, trained weights, and the manifest
//! written by `python -m compile.aot` (formats documented there and in
//! python/compile/aot.py — little-endian throughout).

use crate::accel::network::{LayerWeights, QuantizedWeights};
use crate::sc::quantize_bipolar;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// A loaded test set: images as values in [0, 1], flattened (c·h·w).
#[derive(Debug, Clone)]
pub struct Dataset {
    /// (channels, height, width).
    pub shape: (usize, usize, usize),
    /// Per-image pixel values in [0, 1].
    pub images: Vec<Vec<f32>>,
    /// Class labels.
    pub labels: Vec<u8>,
}

impl Dataset {
    /// Number of samples.
    pub fn len(&self) -> usize {
        self.images.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }

    /// Load a `SCNND1` dataset file.
    pub fn load(path: &Path) -> Result<Self> {
        let buf = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        let mut r = Reader::new(&buf);
        let magic = r.bytes(8)?;
        if magic != b"SCNND1\0\0" {
            bail!("{}: bad dataset magic", path.display());
        }
        let n = r.u32()? as usize;
        let c = r.u32()? as usize;
        let h = r.u32()? as usize;
        let w = r.u32()? as usize;
        let px = c * h * w;
        let mut images = Vec::with_capacity(n);
        for _ in 0..n {
            let raw = r.bytes(px)?;
            images.push(raw.iter().map(|&b| b as f32 / 255.0).collect());
        }
        let labels = r.bytes(n)?.to_vec();
        Ok(Dataset { shape: (c, h, w), images, labels })
    }
}

/// One layer of trained float weights plus its re-encoder affine.
#[derive(Debug, Clone)]
pub struct FloatLayer {
    /// `[neuron][fan_in]` weights in [−1, 1].
    pub w: Vec<Vec<f32>>,
    /// Re-encoder gain.
    pub gamma: f32,
    /// Re-encoder offset.
    pub mu: f32,
}

/// Trained model weights (`SCNNW1` file).
#[derive(Debug, Clone)]
pub struct ModelWeights {
    /// Compute layers in order.
    pub layers: Vec<FloatLayer>,
}

impl ModelWeights {
    /// Load a `SCNNW1` weights file.
    pub fn load(path: &Path) -> Result<Self> {
        let buf = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        let mut r = Reader::new(&buf);
        if r.bytes(8)? != b"SCNNW1\0\0" {
            bail!("{}: bad weights magic", path.display());
        }
        let n_layers = r.u32()? as usize;
        let mut layers = Vec::with_capacity(n_layers);
        for _ in 0..n_layers {
            let rows = r.u32()? as usize;
            let cols = r.u32()? as usize;
            let gamma = r.f32()?;
            let mu = r.f32()?;
            let mut w = Vec::with_capacity(rows);
            for _ in 0..rows {
                let mut row = Vec::with_capacity(cols);
                for _ in 0..cols {
                    row.push(r.f32()?);
                }
                w.push(row);
            }
            layers.push(FloatLayer { w, gamma, mu });
        }
        Ok(ModelWeights { layers })
    }

    /// Quantize to `bits` for the SC datapath (same code mapping as the
    /// training-side `ref.quantize_bipolar`).
    pub fn quantize(&self, bits: u32) -> QuantizedWeights {
        QuantizedWeights {
            bits,
            layers: self
                .layers
                .iter()
                .map(|l| LayerWeights {
                    codes: l
                        .w
                        .iter()
                        .map(|row| row.iter().map(|&v| quantize_bipolar(v as f64, bits)).collect())
                        .collect(),
                    gamma: l.gamma as f64,
                    mu: l.mu as f64,
                })
                .collect(),
        }
    }
}

/// Parse the key=value `manifest.txt`.
pub fn load_manifest(path: &Path) -> Result<BTreeMap<String, String>> {
    let text = std::fs::read_to_string(path)?;
    Ok(text
        .lines()
        .filter_map(|l| l.split_once('='))
        .map(|(k, v)| (k.trim().to_string(), v.trim().to_string()))
        .collect())
}

/// Locations of everything `make artifacts` produces.
#[derive(Debug, Clone)]
pub struct Artifacts {
    /// Artifact directory.
    pub dir: PathBuf,
}

impl Artifacts {
    /// Use `dir` (default `artifacts/` relative to the repo root).
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        Artifacts { dir: dir.into() }
    }

    /// Default location.
    pub fn default_dir() -> Self {
        Artifacts::new("artifacts")
    }

    /// HLO graph for a model at a batch size.
    pub fn hlo(&self, model: &str, batch: usize) -> PathBuf {
        self.dir.join(format!("{model}_b{batch}.hlo.txt"))
    }

    /// Trained weights for a model/mode.
    pub fn weights(&self, model: &str, mode: &str) -> PathBuf {
        self.dir.join(format!("{model}_{mode}.weights.bin"))
    }

    /// Test set for a dataset name.
    pub fn dataset(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}_test.bin"))
    }

    /// The manifest.
    pub fn manifest(&self) -> PathBuf {
        self.dir.join("manifest.txt")
    }

    /// True when the core artifacts exist (built via `make artifacts`).
    pub fn present(&self) -> bool {
        self.manifest().exists() && self.hlo("lenet5", 1).exists()
    }
}

struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Reader { buf, pos: 0 }
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("truncated artifact file at offset {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_tmp(name: &str, bytes: &[u8]) -> PathBuf {
        let p = std::env::temp_dir().join(format!("scnn_test_{name}_{}", std::process::id()));
        let mut f = std::fs::File::create(&p).unwrap();
        f.write_all(bytes).unwrap();
        p
    }

    #[test]
    fn dataset_roundtrip() {
        let mut buf = Vec::new();
        buf.extend_from_slice(b"SCNND1\0\0");
        for v in [2u32, 1, 2, 2] {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        buf.extend_from_slice(&[0u8, 128, 255, 64, 10, 20, 30, 40]); // 2 images
        buf.extend_from_slice(&[3u8, 7]); // labels
        let p = write_tmp("ds", &buf);
        let ds = Dataset::load(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.shape, (1, 2, 2));
        assert_eq!(ds.labels, vec![3, 7]);
        assert!((ds.images[0][1] - 128.0 / 255.0).abs() < 1e-6);
    }

    #[test]
    fn weights_roundtrip_and_quantize() {
        let mut buf = Vec::new();
        buf.extend_from_slice(b"SCNNW1\0\0");
        buf.extend_from_slice(&1u32.to_le_bytes());
        buf.extend_from_slice(&2u32.to_le_bytes()); // rows
        buf.extend_from_slice(&3u32.to_le_bytes()); // cols
        buf.extend_from_slice(&1.5f32.to_le_bytes()); // gamma
        buf.extend_from_slice(&0.25f32.to_le_bytes()); // mu
        for v in [0.5f32, -0.5, 0.0, 1.0, -1.0, 0.25] {
            buf.extend_from_slice(&v.to_le_bytes());
        }
        let p = write_tmp("w", &buf);
        let w = ModelWeights::load(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(w.layers.len(), 1);
        assert_eq!(w.layers[0].w[0], vec![0.5, -0.5, 0.0]);
        let q = w.quantize(8);
        assert_eq!(q.layers[0].codes[0][0], crate::sc::quantize_bipolar(0.5, 8));
        assert!((q.layers[0].gamma - 1.5).abs() < 1e-6);
    }

    #[test]
    fn bad_magic_rejected() {
        let p = write_tmp("bad", b"NOTMAGIC........");
        assert!(Dataset::load(&p).is_err());
        assert!(ModelWeights::load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn manifest_parses() {
        let p = write_tmp("mf", b"acc_lenet5_sc=0.93\nbits=8\n# comment line without equals\n");
        let m = load_manifest(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(m["bits"], "8");
        assert_eq!(m["acc_lenet5_sc"], "0.93");
    }
}
