//! Serving statistics.
//!
//! [`ServeStats`] now lives in [`crate::engine::metrics`] (every engine
//! session records one); this module re-exports it so existing
//! `coordinator::ServeStats` paths keep working.

pub use crate::engine::metrics::ServeStats;
