//! Serving statistics: latency percentiles and throughput accounting.

use std::time::Duration;

/// Records per-request latencies and batch sizes.
#[derive(Debug, Default, Clone)]
pub struct ServeStats {
    latencies_us: Vec<u64>,
    batch_sizes: Vec<usize>,
    total_requests: usize,
}

impl ServeStats {
    /// New empty recorder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one completed request.
    pub fn record(&mut self, latency: Duration, batch: usize) {
        self.latencies_us.push(latency.as_micros() as u64);
        self.batch_sizes.push(batch);
        self.total_requests += 1;
    }

    /// Requests completed.
    pub fn count(&self) -> usize {
        self.total_requests
    }

    /// Latency percentile in microseconds (p in [0, 100]).
    pub fn latency_percentile_us(&self, p: f64) -> u64 {
        if self.latencies_us.is_empty() {
            return 0;
        }
        let mut v = self.latencies_us.clone();
        v.sort_unstable();
        let idx = ((p / 100.0) * (v.len() - 1) as f64).round() as usize;
        v[idx.min(v.len() - 1)]
    }

    /// Mean batch size executed.
    pub fn mean_batch(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            return 0.0;
        }
        self.batch_sizes.iter().sum::<usize>() as f64 / self.batch_sizes.len() as f64
    }

    /// Merge another recorder into this one.
    pub fn merge(&mut self, other: &ServeStats) {
        self.latencies_us.extend_from_slice(&other.latencies_us);
        self.batch_sizes.extend_from_slice(&other.batch_sizes);
        self.total_requests += other.total_requests;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_ordered() {
        let mut s = ServeStats::new();
        for i in 1..=100u64 {
            s.record(Duration::from_micros(i), 1);
        }
        assert_eq!(s.count(), 100);
        assert!(s.latency_percentile_us(50.0) <= s.latency_percentile_us(99.0));
        assert_eq!(s.latency_percentile_us(0.0), 1);
        assert_eq!(s.latency_percentile_us(100.0), 100);
    }

    #[test]
    fn empty_stats_safe() {
        let s = ServeStats::new();
        assert_eq!(s.latency_percentile_us(99.0), 0);
        assert_eq!(s.mean_batch(), 0.0);
    }

    #[test]
    fn merge_adds() {
        let mut a = ServeStats::new();
        a.record(Duration::from_micros(5), 2);
        let mut b = ServeStats::new();
        b.record(Duration::from_micros(7), 4);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.mean_batch(), 3.0);
    }
}
