//! L3 serving coordinator: request router + dynamic batcher + worker.
//!
//! Two backends share the router/batcher machinery ([`ServeBackend`]):
//!
//! * **PJRT** — engines owned by a dedicated worker thread (raw PJRT
//!   handles are not `Send`-safe to share) executing an HLO ladder;
//! * **Stochastic** — the in-process bit-exact SC engine: one
//!   [`ForwardPlan`] compiled at startup (gather tables, layer randoms and
//!   every weight SNG stream amortized across the worker's lifetime) and
//!   batches executed through the parallel `run_batch` path.
//!
//! ```text
//! clients ──infer()──▶ router queue ──batcher──▶ worker (ladder / SC plan)
//!                                            └─▶ responses (per request)
//! ```
//!
//! Batching policy: drain the queue up to `batch_max`; for PJRT, execute
//! full `batch_max`-sized chunks on the batched executable and the
//! remainder on the single-sample executable; for the SC engine, run the
//! drained set as one parallel batch. A short `linger` lets concurrent
//! clients coalesce (the classic dynamic-batching tradeoff).
//!
//! (This environment vendors no tokio; std::thread + mpsc supply the same
//! structure — see Cargo.toml note.)

pub mod stats;

pub use stats::ServeStats;

use crate::accel::layers::NetworkSpec;
use crate::accel::network::{ForwardMode, ForwardPlan, QuantizedWeights};
use crate::runtime::Engine;
use anyhow::{anyhow, Context, Result};
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// A classification request: flattened image in [0, 1].
struct Request {
    image: Vec<f32>,
    enqueued: Instant,
    respond: mpsc::Sender<Result<Vec<f32>>>,
}

/// What executes batches on the worker thread.
#[derive(Debug, Clone)]
pub enum ServeBackend {
    /// PJRT executable ladder as (batch_size, path); must include batch
    /// size 1. The batcher greedily picks the largest size ≤ pending.
    Pjrt {
        /// The (batch, HLO path) ladder.
        hlo_ladder: Vec<(usize, PathBuf)>,
    },
    /// In-process bit-exact / analytic SC inference through a compiled
    /// [`ForwardPlan`] and the parallel batched forward.
    Stochastic {
        /// Network topology.
        net: NetworkSpec,
        /// Quantized weights.
        weights: QuantizedWeights,
        /// Forward mode (any [`ForwardMode`]).
        mode: ForwardMode,
        /// Maximum requests drained into one batch.
        batch_max: usize,
    },
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// The execution backend.
    pub backend: ServeBackend,
    /// Input element count per image (c·h·w).
    pub image_len: usize,
    /// Input dims excluding batch (c, h, w).
    pub image_dims: (usize, usize, usize),
    /// Output classes.
    pub classes: usize,
    /// How long the batcher lingers for more requests.
    pub linger: Duration,
}

impl CoordinatorConfig {
    /// Largest batch the backend executes at once.
    pub fn batch_max(&self) -> usize {
        match &self.backend {
            ServeBackend::Pjrt { hlo_ladder } => {
                hlo_ladder.iter().map(|&(b, _)| b).max().unwrap_or(1)
            }
            ServeBackend::Stochastic { batch_max, .. } => (*batch_max).max(1),
        }
    }
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: mpsc::Sender<Request>,
    stats: Arc<Mutex<ServeStats>>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start the worker thread (loads + compiles executables / the SC
    /// forward plan there).
    pub fn start(cfg: CoordinatorConfig) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<Request>();
        let stats = Arc::new(Mutex::new(ServeStats::new()));
        let stats_w = Arc::clone(&stats);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let worker = std::thread::Builder::new()
            .name("scnn-worker".into())
            .spawn(move || worker_loop(cfg, rx, stats_w, ready_tx))
            .context("spawning worker")?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("worker died during startup"))??;
        Ok(Coordinator { tx, stats, worker: Some(worker) })
    }

    /// Classify one image (blocking). Returns the logits.
    pub fn infer(&self, image: Vec<f32>) -> Result<Vec<f32>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Request { image, enqueued: Instant::now(), respond: rtx })
            .map_err(|_| anyhow!("coordinator stopped"))?;
        rrx.recv().map_err(|_| anyhow!("worker dropped request"))?
    }

    /// Classify a whole set through the batcher from `threads` concurrent
    /// clients; returns predicted classes in input order.
    pub fn infer_all(&self, images: &[Vec<f32>], threads: usize) -> Result<Vec<usize>> {
        let n = images.len();
        let results: Mutex<Vec<Option<usize>>> = Mutex::new(vec![None; n]);
        let next = std::sync::atomic::AtomicUsize::new(0);
        std::thread::scope(|s| -> Result<()> {
            let mut handles = Vec::new();
            for _ in 0..threads.max(1) {
                handles.push(s.spawn(|| -> Result<()> {
                    loop {
                        let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        if i >= n {
                            return Ok(());
                        }
                        let logits = self.infer(images[i].clone())?;
                        let pred = crate::accel::network::classify(
                            &logits.iter().map(|&x| x as f64).collect::<Vec<_>>(),
                        );
                        results.lock().unwrap()[i] = Some(pred);
                    }
                }));
            }
            for h in handles {
                h.join().map_err(|_| anyhow!("client thread panicked"))??;
            }
            Ok(())
        })?;
        Ok(results.into_inner().unwrap().into_iter().map(|p| p.unwrap()).collect())
    }

    /// Snapshot of serving statistics.
    pub fn stats(&self) -> ServeStats {
        self.stats.lock().unwrap().clone()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // Closing the channel stops the worker loop.
        let (dummy_tx, _) = mpsc::channel();
        let _ = std::mem::replace(&mut self.tx, dummy_tx);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// The worker-side executor built from a [`ServeBackend`].
enum WorkerEngine {
    /// PJRT ladder, largest batch first.
    Ladder(Vec<(usize, Engine)>),
    /// Compiled SC plan.
    Plan(ForwardPlan),
}

fn build_engine(cfg: &CoordinatorConfig) -> Result<WorkerEngine> {
    match &cfg.backend {
        ServeBackend::Pjrt { hlo_ladder } => {
            let mut v = Vec::new();
            for (b, path) in hlo_ladder {
                v.push((*b, Engine::load(path)?));
            }
            v.sort_by(|a, b| b.0.cmp(&a.0));
            if v.last().map(|&(b, _)| b) != Some(1) {
                anyhow::bail!("ladder must include batch size 1");
            }
            Ok(WorkerEngine::Ladder(v))
        }
        ServeBackend::Stochastic { net, weights, mode, .. } => {
            let plan = ForwardPlan::new(net, weights, *mode);
            if plan.in_len() != cfg.image_len {
                anyhow::bail!(
                    "network expects {} inputs, config says {}",
                    plan.in_len(),
                    cfg.image_len
                );
            }
            if plan.out_len() != cfg.classes {
                anyhow::bail!(
                    "network emits {} classes, config says {}",
                    plan.out_len(),
                    cfg.classes
                );
            }
            Ok(WorkerEngine::Plan(plan))
        }
    }
}

fn worker_loop(
    cfg: CoordinatorConfig,
    rx: mpsc::Receiver<Request>,
    stats: Arc<Mutex<ServeStats>>,
    ready: mpsc::Sender<Result<()>>,
) {
    let engine = match build_engine(&cfg) {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let (c, h, w) = cfg.image_dims;
    let batch_max = cfg.batch_max();

    loop {
        // Block for the first request; then linger to coalesce more.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // coordinator dropped
        };
        let mut pending = vec![first];
        let deadline = Instant::now() + cfg.linger;
        while pending.len() < batch_max {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => pending.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }

        match &engine {
            WorkerEngine::Ladder(ladder) => {
                // Greedy chunking down the ladder.
                let mut idx = 0;
                while idx < pending.len() {
                    let remaining = pending.len() - idx;
                    let (bsz, engine) = ladder
                        .iter()
                        .find(|&&(b, _)| b <= remaining)
                        .map(|(b, e)| (*b, e))
                        .expect("ladder contains batch 1");
                    let chunk = &pending[idx..idx + bsz];
                    let dims = [bsz as i64, c as i64, h as i64, w as i64];
                    let mut flat = Vec::with_capacity(bsz * cfg.image_len);
                    for r in chunk {
                        flat.extend_from_slice(&r.image);
                    }
                    match engine.run_f32(&flat, &dims) {
                        Ok(out) => {
                            for (j, r) in chunk.iter().enumerate() {
                                let logits =
                                    out[j * cfg.classes..(j + 1) * cfg.classes].to_vec();
                                // Record before responding: clients may read
                                // stats right after their reply arrives.
                                stats.lock().unwrap().record(r.enqueued.elapsed(), bsz);
                                let _ = r.respond.send(Ok(logits));
                            }
                        }
                        Err(e) => {
                            for r in chunk {
                                let _ = r.respond.send(Err(anyhow!("exec failed: {e}")));
                            }
                        }
                    }
                    idx += bsz;
                }
            }
            WorkerEngine::Plan(plan) => {
                // Reject malformed requests individually; batch the rest.
                let mut valid = Vec::with_capacity(pending.len());
                for r in pending {
                    if r.image.len() != cfg.image_len {
                        let _ = r.respond.send(Err(anyhow!(
                            "request image has {} elements, expected {}",
                            r.image.len(),
                            cfg.image_len
                        )));
                    } else {
                        valid.push(r);
                    }
                }
                if valid.is_empty() {
                    continue;
                }
                let inputs: Vec<Vec<f64>> = valid
                    .iter()
                    .map(|r| r.image.iter().map(|&v| v as f64).collect())
                    .collect();
                // Lone requests still get the cores (neuron-parallel);
                // real batches fan out image-parallel. Bit-identical.
                let outputs = if inputs.len() == 1 {
                    vec![plan.run(&inputs[0])]
                } else {
                    plan.run_batch(&inputs)
                };
                let bsz = valid.len();
                for (r, out) in valid.iter().zip(outputs) {
                    let logits: Vec<f32> = out.iter().map(|&v| v as f32).collect();
                    stats.lock().unwrap().record(r.enqueued.elapsed(), bsz);
                    let _ = r.respond.send(Ok(logits));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::layers::{LayerKind, LayerSpec};
    use crate::accel::network::{forward, LayerWeights};
    use crate::sc::quantize_bipolar;
    use std::io::Write;

    /// Identity-ish test graphs: logits = mean over pixels broadcast with a
    /// per-class offset, so argmax is deterministic (class by image mean).
    fn fake_model_hlo(batch: usize) -> String {
        // out[b, c] = sum(x[b]) * w[c], w = [0.1, 0.2, ..., 1.0]
        format!(
            r#"HloModule fake_b{batch}, entry_computation_layout={{(f32[{batch},1,2,2]{{3,2,1,0}})->(f32[{batch},10]{{1,0}})}}

add {{
  a = f32[] parameter(0)
  b = f32[] parameter(1)
  ROOT s = f32[] add(a, b)
}}

ENTRY main {{
  x = f32[{batch},1,2,2]{{3,2,1,0}} parameter(0)
  xr = f32[{batch},4]{{1,0}} reshape(x)
  w = f32[10]{{0}} constant({{0.1,0.2,0.3,0.4,0.5,0.6,0.7,0.8,0.9,1.0}})
  zero = f32[] constant(0)
  sums = f32[{batch}]{{0}} reduce(xr, zero), dimensions={{1}}, to_apply=add
  sb = f32[{batch},10]{{1,0}} broadcast(sums), dimensions={{0}}
  wb = f32[{batch},10]{{1,0}} broadcast(w), dimensions={{1}}
  prod = f32[{batch},10]{{1,0}} multiply(sb, wb)
  ROOT out = (f32[{batch},10]{{1,0}}) tuple(prod)
}}
"#
        )
    }

    fn write_tmp(name: &str, text: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!(
            "scnn_coord_{name}_{}.hlo.txt",
            std::process::id()
        ));
        std::fs::File::create(&p).unwrap().write_all(text.as_bytes()).unwrap();
        p
    }

    fn test_cfg(batch_max: usize) -> (CoordinatorConfig, PathBuf, PathBuf) {
        let p1 = write_tmp(&format!("b1_{batch_max}"), &fake_model_hlo(1));
        let pb = write_tmp(&format!("bb_{batch_max}"), &fake_model_hlo(batch_max));
        (
            CoordinatorConfig {
                backend: ServeBackend::Pjrt {
                    hlo_ladder: vec![(1, p1.clone()), (batch_max, pb.clone())],
                },
                image_len: 4,
                image_dims: (1, 2, 2),
                classes: 10,
                linger: Duration::from_millis(5),
            },
            p1,
            pb,
        )
    }

    #[test]
    fn single_inference_roundtrip() {
        let (cfg, p1, pb) = test_cfg(4);
        let coord = Coordinator::start(cfg).unwrap();
        let logits = coord.infer(vec![0.25, 0.25, 0.25, 0.25]).unwrap();
        assert_eq!(logits.len(), 10);
        // sum = 1.0 ⇒ logits = w ⇒ argmax = class 9.
        assert!((logits[9] - 1.0).abs() < 1e-5);
        drop(coord);
        std::fs::remove_file(p1).ok();
        std::fs::remove_file(pb).ok();
    }

    #[test]
    fn concurrent_clients_are_batched() {
        let (cfg, p1, pb) = test_cfg(4);
        let coord = Coordinator::start(cfg).unwrap();
        let images: Vec<Vec<f32>> = (0..32).map(|i| vec![i as f32 / 32.0; 4]).collect();
        let preds = coord.infer_all(&images, 8).unwrap();
        // Positive-sum images all argmax to class 9; the zero image ties at 0.
        assert!(preds[1..].iter().all(|&p| p == 9));
        let st = coord.stats();
        assert_eq!(st.count(), 32);
        assert!(
            st.mean_batch() > 1.0,
            "concurrent load should produce real batches (mean {})",
            st.mean_batch()
        );
        drop(coord);
        std::fs::remove_file(p1).ok();
        std::fs::remove_file(pb).ok();
    }

    #[test]
    fn startup_failure_reported() {
        let cfg = CoordinatorConfig {
            backend: ServeBackend::Pjrt {
                hlo_ladder: vec![(1, PathBuf::from("/nonexistent.hlo.txt"))],
            },
            image_len: 4,
            image_dims: (1, 2, 2),
            classes: 10,
            linger: Duration::from_millis(1),
        };
        assert!(Coordinator::start(cfg).is_err());
    }

    fn tiny_net() -> NetworkSpec {
        NetworkSpec {
            name: "tiny".into(),
            input: (1, 4, 4),
            layers: vec![LayerSpec {
                kind: LayerKind::Dense { inputs: 16, outputs: 3 },
                relu: false,
            }],
        }
    }

    fn tiny_weights(bits: u32) -> QuantizedWeights {
        let codes: Vec<Vec<u32>> = (0..3)
            .map(|oc| {
                (0..16)
                    .map(|j| {
                        quantize_bipolar(((oc * 7 + j) % 11) as f64 / 5.5 - 1.0, bits)
                    })
                    .collect()
            })
            .collect();
        QuantizedWeights {
            bits,
            layers: vec![LayerWeights { codes, gamma: 1.0, mu: 0.0 }],
        }
    }

    fn sc_cfg(mode: ForwardMode, batch_max: usize) -> CoordinatorConfig {
        CoordinatorConfig {
            backend: ServeBackend::Stochastic {
                net: tiny_net(),
                weights: tiny_weights(8),
                mode,
                batch_max,
            },
            image_len: 16,
            image_dims: (1, 4, 4),
            classes: 3,
            linger: Duration::from_millis(5),
        }
    }

    #[test]
    fn stochastic_backend_roundtrip_matches_forward() {
        let coord = Coordinator::start(sc_cfg(ForwardMode::Expectation, 8)).unwrap();
        let image: Vec<f32> = (0..16).map(|i| i as f32 / 16.0).collect();
        let served = coord.infer(image.clone()).unwrap();
        assert_eq!(served.len(), 3);
        let direct = forward(
            &tiny_net(),
            &tiny_weights(8),
            &image.iter().map(|&v| v as f64).collect::<Vec<_>>(),
            ForwardMode::Expectation,
        );
        for (s, d) in served.iter().zip(&direct) {
            assert!((*s as f64 - d).abs() < 1e-6, "served {s} direct {d}");
        }
    }

    #[test]
    fn stochastic_backend_batches_concurrent_clients() {
        let coord =
            Coordinator::start(sc_cfg(ForwardMode::Stochastic { k: 64, seed: 9 }, 16)).unwrap();
        let images: Vec<Vec<f32>> =
            (0..24).map(|i| (0..16).map(|j| ((i + j) % 10) as f32 / 10.0).collect()).collect();
        let preds = coord.infer_all(&images, 6).unwrap();
        assert_eq!(preds.len(), 24);
        let st = coord.stats();
        assert_eq!(st.count(), 24);
        assert!(
            st.mean_batch() > 1.0,
            "concurrent load should produce real SC batches (mean {})",
            st.mean_batch()
        );
        // Served predictions must match the engine run directly (bit-exact
        // streams: same seed, same lanes).
        for (i, img) in images.iter().take(4).enumerate() {
            let direct = crate::accel::network::classify(&forward(
                &tiny_net(),
                &tiny_weights(8),
                &img.iter().map(|&v| v as f64).collect::<Vec<_>>(),
                ForwardMode::Stochastic { k: 64, seed: 9 },
            ));
            assert_eq!(preds[i], direct, "image {i}");
        }
    }

    #[test]
    fn stochastic_backend_validates_shapes() {
        // classes mismatch caught at startup.
        let mut cfg = sc_cfg(ForwardMode::Expectation, 4);
        cfg.classes = 10;
        assert!(Coordinator::start(cfg).is_err());
        // bad request length rejected per-request.
        let coord = Coordinator::start(sc_cfg(ForwardMode::Expectation, 4)).unwrap();
        assert!(coord.infer(vec![0.0; 5]).is_err());
    }
}
