//! L3 serving coordinator: request router + dynamic batcher + PJRT worker.
//!
//! The PJRT engines are owned by a dedicated worker thread (raw PJRT
//! handles are not `Send`-safe to share); requests flow through channels:
//!
//! ```text
//! clients ──infer()──▶ router queue ──batcher──▶ worker (b32 / b1 exec)
//!                                            └─▶ responses (per request)
//! ```
//!
//! Batching policy: drain the queue up to `batch_max`; execute full
//! `batch_max`-sized chunks on the batched executable and the remainder on
//! the single-sample executable; a short `linger` lets concurrent clients
//! coalesce (the classic dynamic-batching tradeoff).
//!
//! (This environment vendors no tokio; std::thread + mpsc supply the same
//! structure — see Cargo.toml note.)

pub mod stats;

pub use stats::ServeStats;

use crate::runtime::Engine;
use anyhow::{anyhow, Context, Result};
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// A classification request: flattened image in [0, 1].
struct Request {
    image: Vec<f32>,
    enqueued: Instant,
    respond: mpsc::Sender<Result<Vec<f32>>>,
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// HLO artifacts as (batch_size, path); must include batch size 1.
    /// The batcher greedily picks the largest size ≤ pending requests.
    pub hlo_ladder: Vec<(usize, PathBuf)>,
    /// Input element count per image (c·h·w).
    pub image_len: usize,
    /// Input dims excluding batch (c, h, w).
    pub image_dims: (usize, usize, usize),
    /// Output classes.
    pub classes: usize,
    /// How long the batcher lingers for more requests.
    pub linger: Duration,
}

impl CoordinatorConfig {
    /// Largest batch size in the ladder.
    pub fn batch_max(&self) -> usize {
        self.hlo_ladder.iter().map(|&(b, _)| b).max().unwrap_or(1)
    }
}

/// Handle to a running coordinator.
pub struct Coordinator {
    tx: mpsc::Sender<Request>,
    stats: Arc<Mutex<ServeStats>>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start the worker thread (loads + compiles both executables there).
    pub fn start(cfg: CoordinatorConfig) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<Request>();
        let stats = Arc::new(Mutex::new(ServeStats::new()));
        let stats_w = Arc::clone(&stats);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let worker = std::thread::Builder::new()
            .name("scnn-worker".into())
            .spawn(move || worker_loop(cfg, rx, stats_w, ready_tx))
            .context("spawning worker")?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("worker died during startup"))??;
        Ok(Coordinator { tx, stats, worker: Some(worker) })
    }

    /// Classify one image (blocking). Returns the logits.
    pub fn infer(&self, image: Vec<f32>) -> Result<Vec<f32>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Request { image, enqueued: Instant::now(), respond: rtx })
            .map_err(|_| anyhow!("coordinator stopped"))?;
        rrx.recv().map_err(|_| anyhow!("worker dropped request"))?
    }

    /// Classify a whole set through the batcher from `threads` concurrent
    /// clients; returns predicted classes in input order.
    pub fn infer_all(&self, images: &[Vec<f32>], threads: usize) -> Result<Vec<usize>> {
        let n = images.len();
        let results: Mutex<Vec<Option<usize>>> = Mutex::new(vec![None; n]);
        let next = std::sync::atomic::AtomicUsize::new(0);
        std::thread::scope(|s| -> Result<()> {
            let mut handles = Vec::new();
            for _ in 0..threads.max(1) {
                handles.push(s.spawn(|| -> Result<()> {
                    loop {
                        let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        if i >= n {
                            return Ok(());
                        }
                        let logits = self.infer(images[i].clone())?;
                        let pred = crate::accel::network::classify(
                            &logits.iter().map(|&x| x as f64).collect::<Vec<_>>(),
                        );
                        results.lock().unwrap()[i] = Some(pred);
                    }
                }));
            }
            for h in handles {
                h.join().map_err(|_| anyhow!("client thread panicked"))??;
            }
            Ok(())
        })?;
        Ok(results.into_inner().unwrap().into_iter().map(|p| p.unwrap()).collect())
    }

    /// Snapshot of serving statistics.
    pub fn stats(&self) -> ServeStats {
        self.stats.lock().unwrap().clone()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // Closing the channel stops the worker loop.
        let (dummy_tx, _) = mpsc::channel();
        let _ = std::mem::replace(&mut self.tx, dummy_tx);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    cfg: CoordinatorConfig,
    rx: mpsc::Receiver<Request>,
    stats: Arc<Mutex<ServeStats>>,
    ready: mpsc::Sender<Result<()>>,
) {
    // Ladder of executables, largest batch first.
    let engines = (|| -> Result<Vec<(usize, Engine)>> {
        let mut v = Vec::new();
        for (b, path) in &cfg.hlo_ladder {
            v.push((*b, Engine::load(path)?));
        }
        v.sort_by(|a, b| b.0.cmp(&a.0));
        if v.last().map(|&(b, _)| b) != Some(1) {
            anyhow::bail!("ladder must include batch size 1");
        }
        Ok(v)
    })();
    let ladder = match engines {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    let (c, h, w) = cfg.image_dims;
    let batch_max = cfg.batch_max();

    loop {
        // Block for the first request; then linger to coalesce more.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // coordinator dropped
        };
        let mut pending = vec![first];
        let deadline = Instant::now() + cfg.linger;
        while pending.len() < batch_max {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => pending.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }

        // Greedy chunking down the ladder.
        let mut idx = 0;
        while idx < pending.len() {
            let remaining = pending.len() - idx;
            let (bsz, engine) = ladder
                .iter()
                .find(|&&(b, _)| b <= remaining)
                .map(|(b, e)| (*b, e))
                .expect("ladder contains batch 1");
            let chunk = &pending[idx..idx + bsz];
            let dims = [bsz as i64, c as i64, h as i64, w as i64];
            let mut flat = Vec::with_capacity(bsz * cfg.image_len);
            for r in chunk {
                flat.extend_from_slice(&r.image);
            }
            match engine.run_f32(&flat, &dims) {
                Ok(out) => {
                    for (j, r) in chunk.iter().enumerate() {
                        let logits = out[j * cfg.classes..(j + 1) * cfg.classes].to_vec();
                        // Record before responding: clients may read stats
                        // immediately after their reply arrives.
                        stats.lock().unwrap().record(r.enqueued.elapsed(), bsz);
                        let _ = r.respond.send(Ok(logits));
                    }
                }
                Err(e) => {
                    for r in chunk {
                        let _ = r.respond.send(Err(anyhow!("exec failed: {e}")));
                    }
                }
            }
            idx += bsz;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    /// Identity-ish test graphs: logits = mean over pixels broadcast with a
    /// per-class offset, so argmax is deterministic (class by image mean).
    fn fake_model_hlo(batch: usize) -> String {
        // out[b, c] = sum(x[b]) * w[c], w = [0.1, 0.2, ..., 1.0]
        format!(
            r#"HloModule fake_b{batch}, entry_computation_layout={{(f32[{batch},1,2,2]{{3,2,1,0}})->(f32[{batch},10]{{1,0}})}}

add {{
  a = f32[] parameter(0)
  b = f32[] parameter(1)
  ROOT s = f32[] add(a, b)
}}

ENTRY main {{
  x = f32[{batch},1,2,2]{{3,2,1,0}} parameter(0)
  xr = f32[{batch},4]{{1,0}} reshape(x)
  w = f32[10]{{0}} constant({{0.1,0.2,0.3,0.4,0.5,0.6,0.7,0.8,0.9,1.0}})
  zero = f32[] constant(0)
  sums = f32[{batch}]{{0}} reduce(xr, zero), dimensions={{1}}, to_apply=add
  sb = f32[{batch},10]{{1,0}} broadcast(sums), dimensions={{0}}
  wb = f32[{batch},10]{{1,0}} broadcast(w), dimensions={{1}}
  prod = f32[{batch},10]{{1,0}} multiply(sb, wb)
  ROOT out = (f32[{batch},10]{{1,0}}) tuple(prod)
}}
"#
        )
    }

    fn write_tmp(name: &str, text: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!(
            "scnn_coord_{name}_{}.hlo.txt",
            std::process::id()
        ));
        std::fs::File::create(&p).unwrap().write_all(text.as_bytes()).unwrap();
        p
    }

    fn test_cfg(batch_max: usize) -> (CoordinatorConfig, PathBuf, PathBuf) {
        let p1 = write_tmp(&format!("b1_{batch_max}"), &fake_model_hlo(1));
        let pb = write_tmp(&format!("bb_{batch_max}"), &fake_model_hlo(batch_max));
        (
            CoordinatorConfig {
                hlo_ladder: vec![(1, p1.clone()), (batch_max, pb.clone())],
                image_len: 4,
                image_dims: (1, 2, 2),
                classes: 10,
                linger: Duration::from_millis(5),
            },
            p1,
            pb,
        )
    }

    #[test]
    fn single_inference_roundtrip() {
        let (cfg, p1, pb) = test_cfg(4);
        let coord = Coordinator::start(cfg).unwrap();
        let logits = coord.infer(vec![0.25, 0.25, 0.25, 0.25]).unwrap();
        assert_eq!(logits.len(), 10);
        // sum = 1.0 ⇒ logits = w ⇒ argmax = class 9.
        assert!((logits[9] - 1.0).abs() < 1e-5);
        drop(coord);
        std::fs::remove_file(p1).ok();
        std::fs::remove_file(pb).ok();
    }

    #[test]
    fn concurrent_clients_are_batched() {
        let (cfg, p1, pb) = test_cfg(4);
        let coord = Coordinator::start(cfg).unwrap();
        let images: Vec<Vec<f32>> = (0..32).map(|i| vec![i as f32 / 32.0; 4]).collect();
        let preds = coord.infer_all(&images, 8).unwrap();
        // Positive-sum images all argmax to class 9; the zero image ties at 0.
        assert!(preds[1..].iter().all(|&p| p == 9));
        let st = coord.stats();
        assert_eq!(st.count(), 32);
        assert!(
            st.mean_batch() > 1.0,
            "concurrent load should produce real batches (mean {})",
            st.mean_batch()
        );
        drop(coord);
        std::fs::remove_file(p1).ok();
        std::fs::remove_file(pb).ok();
    }

    #[test]
    fn startup_failure_reported() {
        let cfg = CoordinatorConfig {
            hlo_ladder: vec![(1, PathBuf::from("/nonexistent.hlo.txt"))],
            image_len: 4,
            image_dims: (1, 2, 2),
            classes: 10,
            linger: Duration::from_millis(1),
        };
        assert!(Coordinator::start(cfg).is_err());
    }
}
