//! L3 serving coordinator — a thin façade over [`crate::engine`]'s
//! session pool.
//!
//! Historically this module owned the request router, dynamic batcher, and
//! per-backend worker. That machinery is now the engine subsystem: a
//! [`Coordinator`] simply translates its [`CoordinatorConfig`] into a typed
//! [`PoolConfig`] (N replicated shard sessions behind one router), opens an
//! [`EnginePool`], and delegates — every backend (PJRT ladder or the
//! in-process SC datapaths) batches through the same engine workers and
//! reports through the same [`PoolMetrics`].
//!
//! ```text
//! clients ──infer()──▶ EnginePool router ──▶ shard Session ──▶ Backend
//!                          │ admission control  └─▶ per-session metrics
//!                          └─▶ reroute on shard death
//! ```
//!
//! Kept as the serving façade (start / infer / infer_all / stats) because
//! the CLI and the e2e example speak in datasets and predicted classes;
//! new code that wants streaming submission, keyed routing, or the full
//! metrics snapshot should open an [`EnginePool`] (or a single
//! [`Session`]) directly.
//!
//! The request path is panic-free: a failed request, a dead shard worker,
//! and a poisoned client-side lock all surface as typed
//! [`EngineError`]-based results ([`Coordinator::infer_all_detailed`]
//! reports them per item).

#![deny(clippy::unwrap_used)]

pub mod stats;

pub use stats::ServeStats;

use crate::accel::layers::{LayerKind, LayerSpec, NetworkSpec};
use crate::accel::network::{ForwardMode, QuantizedWeights};
use crate::engine::{
    BackendKind, BatchPolicy, EngineConfig, EngineError, EnginePool, PoolConfig, PoolMetrics,
    Session,
};
use anyhow::{anyhow, bail, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// What executes batches on the engine worker thread(s).
#[derive(Debug, Clone)]
pub enum ServeBackend {
    /// PJRT executable ladder as (batch_size, path); must include batch
    /// size 1. The batcher greedily picks the largest size ≤ pending.
    Pjrt {
        /// The (batch, HLO path) ladder.
        hlo_ladder: Vec<(usize, PathBuf)>,
    },
    /// In-process bit-exact / analytic SC inference through a compiled
    /// forward plan and the parallel batched engine.
    Stochastic {
        /// Network topology.
        net: NetworkSpec,
        /// Quantized weights.
        weights: QuantizedWeights,
        /// Forward mode (any [`ForwardMode`]).
        mode: ForwardMode,
        /// Maximum requests drained into one batch.
        batch_max: usize,
    },
}

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// The execution backend.
    pub backend: ServeBackend,
    /// Input element count per image (c·h·w).
    pub image_len: usize,
    /// Input dims excluding batch (c, h, w).
    pub image_dims: (usize, usize, usize),
    /// Output classes.
    pub classes: usize,
    /// How long the batcher lingers for more requests.
    pub linger: Duration,
    /// Session shards behind the front door (1 = the classic single
    /// session; clamped to ≥ 1). Homogeneous shards share one compiled
    /// plan through the engine's artifact cache.
    pub shards: usize,
}

impl CoordinatorConfig {
    /// Largest batch the backend executes at once.
    pub fn batch_max(&self) -> usize {
        match &self.backend {
            ServeBackend::Pjrt { hlo_ladder } => {
                hlo_ladder.iter().map(|&(b, _)| b).max().unwrap_or(1)
            }
            ServeBackend::Stochastic { batch_max, .. } => (*batch_max).max(1),
        }
    }

    /// Lower this serving configuration into a typed [`EngineConfig`]
    /// (one shard's worth).
    pub fn to_engine_config(&self) -> Result<EngineConfig> {
        let batch = BatchPolicy {
            max_batch: self.batch_max(),
            linger: self.linger,
            ..BatchPolicy::default()
        };
        match &self.backend {
            ServeBackend::Pjrt { hlo_ladder } => {
                let (c, h, w) = self.image_dims;
                if c * h * w != self.image_len {
                    bail!(
                        "image dims ({c},{h},{w}) disagree with image_len {}",
                        self.image_len
                    );
                }
                // A shape-only descriptor: the XLA backend takes its input
                // and output lengths from the network spec.
                let net = NetworkSpec {
                    name: "pjrt-graph".into(),
                    input: (c, h, w),
                    layers: vec![LayerSpec {
                        kind: LayerKind::Dense { inputs: self.image_len, outputs: self.classes },
                        relu: false,
                    }],
                };
                Ok(EngineConfig::new(BackendKind::Xla, net)
                    .with_hlo_ladder(hlo_ladder.clone())
                    .with_batch(batch))
            }
            ServeBackend::Stochastic { net, weights, mode, .. } => {
                let (kind, k, seed) = match *mode {
                    ForwardMode::Stochastic { k, seed } => (BackendKind::StochasticFused, k, seed),
                    ForwardMode::Expectation => (BackendKind::Expectation, 32, 7),
                    ForwardMode::NoisyExpectation { k, seed } => {
                        (BackendKind::NoisyExpectation, k, seed)
                    }
                    ForwardMode::FixedPoint => (BackendKind::FixedPoint, 32, 7),
                };
                Ok(EngineConfig::new(kind, net.clone())
                    .with_quantized(weights.clone())
                    .with_k(k)
                    .with_seed(seed)
                    .with_batch(batch))
            }
        }
    }

    /// Lower into the pool configuration [`Coordinator::start`] opens:
    /// `shards` replicas of [`CoordinatorConfig::to_engine_config`].
    pub fn to_pool_config(&self) -> Result<PoolConfig> {
        Ok(PoolConfig::replicated(self.to_engine_config()?, self.shards.max(1)))
    }
}

/// Handle to a running coordinator: one engine pool plus the
/// dataset-level client fan used by the CLI and the e2e example.
pub struct Coordinator {
    pool: EnginePool,
}

impl Coordinator {
    /// Open the engine pool (each shard's worker thread loads and compiles
    /// the executables / forward plan — homogeneous shards share one plan)
    /// and validate the configured shapes.
    pub fn start(cfg: CoordinatorConfig) -> Result<Self> {
        let pool = EnginePool::open(cfg.to_pool_config()?)?;
        if pool.in_len() != cfg.image_len {
            bail!(
                "backend expects {} inputs, config says {}",
                pool.in_len(),
                cfg.image_len
            );
        }
        if pool.out_len() != cfg.classes {
            bail!(
                "backend emits {} classes, config says {}",
                pool.out_len(),
                cfg.classes
            );
        }
        Ok(Coordinator { pool })
    }

    /// The underlying engine pool (streaming submit/drain, keyed routing,
    /// shard introspection, metrics).
    pub fn pool(&self) -> &EnginePool {
        &self.pool
    }

    /// The first shard's engine session (kept for callers that want the
    /// single-session API; prefer [`Coordinator::pool`]).
    pub fn session(&self) -> &Session {
        // A pool always has at least one shard (PoolConfig::validate).
        self.pool.shard_session(0).expect("pool has >= 1 shard")
    }

    /// Classify one image (blocking). Returns the logits.
    pub fn infer(&self, image: Vec<f32>) -> Result<Vec<f32>> {
        Ok(self.pool.infer(image)?)
    }

    /// Classify a whole set through the pool from `threads` concurrent
    /// clients; returns predicted classes in input order. Any failed item
    /// turns the whole call into a typed error naming the item — use
    /// [`Coordinator::infer_all_detailed`] to keep the partial results.
    pub fn infer_all(&self, images: &[Vec<f32>], threads: usize) -> Result<Vec<usize>> {
        let detailed = self.infer_all_detailed(images, threads)?;
        detailed
            .into_iter()
            .enumerate()
            .map(|(i, r)| r.map_err(|e| anyhow!("request {i} failed: {e}")))
            .collect()
    }

    /// Classify a whole set through the pool from `threads` concurrent
    /// clients, reporting a typed per-item result: one failed or shed
    /// request no longer poisons (or panics) the rest of the batch. The
    /// outer error covers batch-level failures only — a poisoned results
    /// lock ([`EngineError::LockPoisoned`]) or a panicked client thread.
    #[allow(clippy::type_complexity)]
    pub fn infer_all_detailed(
        &self,
        images: &[Vec<f32>],
        threads: usize,
    ) -> Result<Vec<Result<usize, EngineError>>, EngineError> {
        let n = images.len();
        let results: Mutex<Vec<Option<Result<usize, EngineError>>>> = {
            let mut slots = Vec::with_capacity(n);
            slots.resize_with(n, || None);
            Mutex::new(slots)
        };
        let next = AtomicUsize::new(0);
        std::thread::scope(|s| -> Result<(), EngineError> {
            let mut handles = Vec::new();
            for _ in 0..threads.max(1) {
                handles.push(s.spawn(|| -> Result<(), EngineError> {
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            return Ok(());
                        }
                        let res = self
                            .pool
                            .infer(images[i].clone())
                            .map(|logits| crate::engine::classify(&logits));
                        let mut slots = results
                            .lock()
                            .map_err(|_| EngineError::LockPoisoned("infer_all results"))?;
                        slots[i] = Some(res);
                    }
                }));
            }
            for h in handles {
                match h.join() {
                    Ok(r) => r?,
                    Err(_) => {
                        return Err(EngineError::Request(
                            "infer_all client thread panicked".into(),
                        ))
                    }
                }
            }
            Ok(())
        })?;
        let slots = results
            .into_inner()
            .map_err(|_| EngineError::LockPoisoned("infer_all results"))?;
        Ok(slots
            .into_iter()
            .enumerate()
            .map(|(i, slot)| {
                slot.unwrap_or_else(|| {
                    Err(EngineError::Request(format!("request {i} was never served")))
                })
            })
            .collect())
    }

    /// Snapshot of serving statistics, merged over all shards (exact
    /// latencies and batch sizes).
    pub fn stats(&self) -> ServeStats {
        self.pool.metrics().serve
    }

    /// Full pool metrics snapshot (merged histogram, per-shard throughput,
    /// shed/reroute counters, scaled modeled hardware estimate).
    pub fn metrics(&self) -> PoolMetrics {
        self.pool.metrics()
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::accel::network::{ForwardPlan, LayerWeights};
    use crate::sc::quantize_bipolar;
    use std::io::Write;

    /// Identity-ish test graphs: logits = mean over pixels broadcast with a
    /// per-class offset, so argmax is deterministic (class by image mean).
    fn fake_model_hlo(batch: usize) -> String {
        // out[b, c] = sum(x[b]) * w[c], w = [0.1, 0.2, ..., 1.0]
        format!(
            r#"HloModule fake_b{batch}, entry_computation_layout={{(f32[{batch},1,2,2]{{3,2,1,0}})->(f32[{batch},10]{{1,0}})}}

add {{
  a = f32[] parameter(0)
  b = f32[] parameter(1)
  ROOT s = f32[] add(a, b)
}}

ENTRY main {{
  x = f32[{batch},1,2,2]{{3,2,1,0}} parameter(0)
  xr = f32[{batch},4]{{1,0}} reshape(x)
  w = f32[10]{{0}} constant({{0.1,0.2,0.3,0.4,0.5,0.6,0.7,0.8,0.9,1.0}})
  zero = f32[] constant(0)
  sums = f32[{batch}]{{0}} reduce(xr, zero), dimensions={{1}}, to_apply=add
  sb = f32[{batch},10]{{1,0}} broadcast(sums), dimensions={{0}}
  wb = f32[{batch},10]{{1,0}} broadcast(w), dimensions={{1}}
  prod = f32[{batch},10]{{1,0}} multiply(sb, wb)
  ROOT out = (f32[{batch},10]{{1,0}}) tuple(prod)
}}
"#
        )
    }

    fn write_tmp(name: &str, text: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!(
            "scnn_coord_{name}_{}.hlo.txt",
            std::process::id()
        ));
        std::fs::File::create(&p).unwrap().write_all(text.as_bytes()).unwrap();
        p
    }

    fn test_cfg(batch_max: usize) -> (CoordinatorConfig, PathBuf, PathBuf) {
        let p1 = write_tmp(&format!("b1_{batch_max}"), &fake_model_hlo(1));
        let pb = write_tmp(&format!("bb_{batch_max}"), &fake_model_hlo(batch_max));
        (
            CoordinatorConfig {
                backend: ServeBackend::Pjrt {
                    hlo_ladder: vec![(1, p1.clone()), (batch_max, pb.clone())],
                },
                image_len: 4,
                image_dims: (1, 2, 2),
                classes: 10,
                linger: Duration::from_millis(5),
                shards: 1,
            },
            p1,
            pb,
        )
    }

    #[test]
    fn single_inference_roundtrip() {
        let (cfg, p1, pb) = test_cfg(4);
        let coord = Coordinator::start(cfg).unwrap();
        let logits = coord.infer(vec![0.25, 0.25, 0.25, 0.25]).unwrap();
        assert_eq!(logits.len(), 10);
        // sum = 1.0 ⇒ logits = w ⇒ argmax = class 9.
        assert!((logits[9] - 1.0).abs() < 1e-5);
        drop(coord);
        std::fs::remove_file(p1).ok();
        std::fs::remove_file(pb).ok();
    }

    #[test]
    fn concurrent_clients_are_batched() {
        let (cfg, p1, pb) = test_cfg(4);
        let coord = Coordinator::start(cfg).unwrap();
        let images: Vec<Vec<f32>> = (0..32).map(|i| vec![i as f32 / 32.0; 4]).collect();
        let preds = coord.infer_all(&images, 8).unwrap();
        // Positive-sum images all argmax to class 9; the zero image ties at 0.
        assert!(preds[1..].iter().all(|&p| p == 9));
        let st = coord.stats();
        assert_eq!(st.count(), 32);
        assert!(
            st.mean_batch() > 1.0,
            "concurrent load should produce real batches (mean {})",
            st.mean_batch()
        );
        // The façade and the pool report the same numbers.
        let m = coord.metrics();
        assert_eq!(m.requests, 32);
        assert_eq!(m.backend, "xla");
        assert_eq!(m.shards, 1);
        assert_eq!(m.healthy, 1);
        assert!(m.estimate.is_none(), "the PJRT path models no SC hardware");
        drop(coord);
        std::fs::remove_file(p1).ok();
        std::fs::remove_file(pb).ok();
    }

    #[test]
    fn startup_failure_reported() {
        let cfg = CoordinatorConfig {
            backend: ServeBackend::Pjrt {
                hlo_ladder: vec![(1, PathBuf::from("/nonexistent.hlo.txt"))],
            },
            image_len: 4,
            image_dims: (1, 2, 2),
            classes: 10,
            linger: Duration::from_millis(1),
            shards: 2,
        };
        assert!(Coordinator::start(cfg).is_err());
    }

    fn tiny_net() -> NetworkSpec {
        NetworkSpec {
            name: "tiny".into(),
            input: (1, 4, 4),
            layers: vec![LayerSpec {
                kind: LayerKind::Dense { inputs: 16, outputs: 3 },
                relu: false,
            }],
        }
    }

    fn tiny_weights(bits: u32) -> QuantizedWeights {
        let codes: Vec<Vec<u32>> = (0..3)
            .map(|oc| {
                (0..16)
                    .map(|j| {
                        quantize_bipolar(((oc * 7 + j) % 11) as f64 / 5.5 - 1.0, bits)
                    })
                    .collect()
            })
            .collect();
        QuantizedWeights {
            bits,
            layers: vec![LayerWeights { codes, gamma: 1.0, mu: 0.0 }],
        }
    }

    fn sc_cfg(mode: ForwardMode, batch_max: usize) -> CoordinatorConfig {
        CoordinatorConfig {
            backend: ServeBackend::Stochastic {
                net: tiny_net(),
                weights: tiny_weights(8),
                mode,
                batch_max,
            },
            image_len: 16,
            image_dims: (1, 4, 4),
            classes: 3,
            linger: Duration::from_millis(5),
            shards: 1,
        }
    }

    /// Plan-level forward for the cross-checks below (the `forward` free
    /// function was removed; this is the plan-level path).
    fn direct_forward(mode: ForwardMode, image: &[f32]) -> Vec<f64> {
        let wide: Vec<f64> = image.iter().map(|&v| v as f64).collect();
        ForwardPlan::once(&tiny_net(), &tiny_weights(8), &wide, mode)
    }

    #[test]
    fn stochastic_backend_roundtrip_matches_forward() {
        let coord = Coordinator::start(sc_cfg(ForwardMode::Expectation, 8)).unwrap();
        let image: Vec<f32> = (0..16).map(|i| i as f32 / 16.0).collect();
        let served = coord.infer(image.clone()).unwrap();
        assert_eq!(served.len(), 3);
        let direct = direct_forward(ForwardMode::Expectation, &image);
        for (s, d) in served.iter().zip(&direct) {
            assert!((*s as f64 - d).abs() < 1e-6, "served {s} direct {d}");
        }
    }

    #[test]
    fn stochastic_backend_batches_concurrent_clients() {
        let coord =
            Coordinator::start(sc_cfg(ForwardMode::Stochastic { k: 64, seed: 9 }, 16)).unwrap();
        let images: Vec<Vec<f32>> =
            (0..24).map(|i| (0..16).map(|j| ((i + j) % 10) as f32 / 10.0).collect()).collect();
        let preds = coord.infer_all(&images, 6).unwrap();
        assert_eq!(preds.len(), 24);
        let st = coord.stats();
        assert_eq!(st.count(), 24);
        assert!(
            st.mean_batch() > 1.0,
            "concurrent load should produce real SC batches (mean {})",
            st.mean_batch()
        );
        // Served predictions must match the engine run directly (bit-exact
        // streams: same seed, same lanes).
        for (i, img) in images.iter().take(4).enumerate() {
            let direct = crate::accel::network::classify(&direct_forward(
                ForwardMode::Stochastic { k: 64, seed: 9 },
                img,
            ));
            assert_eq!(preds[i], direct, "image {i}");
        }
    }

    #[test]
    fn sharded_coordinator_matches_single_shard_bit_exact() {
        let mode = ForwardMode::Stochastic { k: 64, seed: 9 };
        let mut sharded_cfg = sc_cfg(mode, 8);
        sharded_cfg.shards = 3;
        let sharded = Coordinator::start(sharded_cfg).unwrap();
        assert_eq!(sharded.pool().shards(), 3);
        let single = Coordinator::start(sc_cfg(mode, 8)).unwrap();
        let images: Vec<Vec<f32>> =
            (0..12).map(|i| (0..16).map(|j| ((i * 3 + j) % 10) as f32 / 10.0).collect()).collect();
        let a = sharded.infer_all(&images, 6).unwrap();
        let b = single.infer_all(&images, 2).unwrap();
        assert_eq!(a, b, "cross-shard results are bit-identical");
        let m = sharded.metrics();
        assert_eq!(m.requests, 12);
        assert_eq!(m.shards, 3);
    }

    #[test]
    fn infer_all_propagates_per_item_failures_typed() {
        let coord = Coordinator::start(sc_cfg(ForwardMode::Expectation, 4)).unwrap();
        let mut images: Vec<Vec<f32>> = (0..6).map(|i| vec![i as f32 / 6.0; 16]).collect();
        images[3] = vec![0.0; 5]; // failure injection: malformed request
        let detailed = coord.infer_all_detailed(&images, 3).unwrap();
        assert_eq!(detailed.len(), 6);
        for (i, r) in detailed.iter().enumerate() {
            if i == 3 {
                assert!(
                    matches!(r, Err(EngineError::Request(_))),
                    "item 3 carries the typed backend rejection, got {r:?}"
                );
            } else {
                assert!(r.is_ok(), "item {i} unaffected by item 3's failure: {r:?}");
            }
        }
        // The aggregate wrapper reports the same failure as a typed error
        // naming the item — the old code panicked here (`p.unwrap()`).
        let err = coord.infer_all(&images, 3).unwrap_err().to_string();
        assert!(err.contains("request 3"), "{err}");
    }

    #[test]
    fn infer_all_survives_an_injected_shard_death() {
        let mut cfg = sc_cfg(ForwardMode::Stochastic { k: 32, seed: 5 }, 8);
        cfg.shards = 2;
        let coord = Coordinator::start(cfg).unwrap();
        // Failure injection: kill shard 0 out from under the router.
        coord.pool().shard_session(0).unwrap().close();
        let images: Vec<Vec<f32>> = (0..10)
            .map(|i| (0..16).map(|j| ((i + j) % 7) as f32 / 7.0).collect())
            .collect();
        let preds = coord.infer_all(&images, 4).unwrap();
        assert_eq!(preds.len(), 10, "the surviving shard serves everything");
        let m = coord.metrics();
        assert_eq!(m.healthy, 1, "the dead shard is reported unhealthy");
        assert!(m.rerouted >= 1, "traffic was rerouted away from the dead shard");
    }

    #[test]
    fn stochastic_backend_validates_shapes() {
        // classes mismatch caught at startup.
        let mut cfg = sc_cfg(ForwardMode::Expectation, 4);
        cfg.classes = 10;
        assert!(Coordinator::start(cfg).is_err());
        // bad request length rejected per-request.
        let coord = Coordinator::start(sc_cfg(ForwardMode::Expectation, 4)).unwrap();
        assert!(coord.infer(vec![0.0; 5]).is_err());
    }

    #[test]
    fn serve_backend_lowers_to_typed_engine_config() {
        let cfg = sc_cfg(ForwardMode::Stochastic { k: 64, seed: 9 }, 16);
        let ecfg = cfg.to_engine_config().unwrap();
        assert_eq!(ecfg.backend, BackendKind::StochasticFused);
        assert_eq!(ecfg.precision, crate::engine::Precision::Uniform(64));
        assert_eq!(ecfg.uniform_k(), Some(64));
        assert_eq!(ecfg.seed, 9);
        assert_eq!(ecfg.batch.max_batch, 16);
        assert_eq!(ecfg.batch.linger, Duration::from_millis(5));
        let mut sharded = cfg.clone();
        sharded.shards = 4;
        let pcfg = sharded.to_pool_config().unwrap();
        assert_eq!(pcfg.shards.len(), 4);
        pcfg.validate().unwrap();
        let (pjrt, p1, pb) = test_cfg(4);
        let ecfg = pjrt.to_engine_config().unwrap();
        assert_eq!(ecfg.backend, BackendKind::Xla);
        assert_eq!(ecfg.input_len(), 4);
        assert_eq!(ecfg.output_len(), 10);
        assert_eq!(ecfg.hlo_ladder.len(), 2);
        std::fs::remove_file(p1).ok();
        std::fs::remove_file(pb).ok();
    }
}
