//! Minimal JSON parser and renderer for the serving wire format.
//!
//! The container is offline, so the HTTP front door cannot pull in serde.
//! This module implements the small JSON subset the request path needs:
//! a recursive-descent parser with a hard depth limit (malicious nesting
//! must not blow the connection thread's stack) and a renderer whose f32
//! output round-trips bit-exactly through Rust's shortest-representation
//! `Display` (non-finite values render as `null`, since `NaN`/`inf` are
//! not valid JSON).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Maximum nesting depth accepted by [`parse`]. Requests deeper than this
/// are rejected as malformed rather than risking stack exhaustion.
pub const MAX_DEPTH: usize = 32;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number, held as f64 (the wire format only carries f32s).
    Num(f64),
    /// A string literal (escapes already resolved).
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object; key order is normalized (sorted) since the wire format
    /// never depends on ordering.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Looks up `key` if this value is an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(map) => map.get(key),
            _ => None,
        }
    }

    /// Interprets this value as a dense `f32` vector.
    ///
    /// Accepts an array of finite numbers; anything else is an error
    /// naming what was found, so the server can surface a typed 400.
    pub fn as_f32_vec(&self) -> Result<Vec<f32>, String> {
        let items = match self {
            Json::Arr(items) => items,
            other => return Err(format!("expected an array of numbers, got {}", other.kind())),
        };
        let mut out = Vec::with_capacity(items.len());
        for (i, item) in items.iter().enumerate() {
            match item {
                Json::Num(n) if n.is_finite() => out.push(*n as f32),
                Json::Num(_) => return Err(format!("element {i} is not finite")),
                other => return Err(format!("element {i} is {}, expected a number", other.kind())),
            }
        }
        Ok(out)
    }

    /// Short human-readable name for this value's type, used in errors.
    pub fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "a bool",
            Json::Num(_) => "a number",
            Json::Str(_) => "a string",
            Json::Arr(_) => "an array",
            Json::Obj(_) => "an object",
        }
    }
}

/// Parses a complete JSON document; trailing non-whitespace is an error.
pub fn parse(text: &str) -> Result<Json, String> {
    let bytes = text.as_bytes();
    let mut pos = 0usize;
    let value = parse_value(bytes, &mut pos, 0)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        return Err(format!("trailing data at byte {pos}"));
    }
    Ok(value)
}

/// Renders an f32 slice as a JSON array.
///
/// Finite values use `Display`, Rust's shortest round-trip representation,
/// so `render_f32s -> parse -> as_f32_vec` is bit-exact. Non-finite values
/// become `null`.
pub fn render_f32s(values: &[f32]) -> String {
    let mut out = String::with_capacity(values.len() * 8 + 2);
    out.push('[');
    for (i, v) in values.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if v.is_finite() {
            // Infallible: writing to a String cannot fail.
            let _ = write!(out, "{v}");
        } else {
            out.push_str("null");
        }
    }
    out.push(']');
    out
}

/// Escapes a string for embedding in a JSON document (adds the quotes).
pub fn escape_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                // Infallible String write.
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

fn skip_ws(bytes: &[u8], pos: &mut usize) {
    while *pos < bytes.len() && matches!(bytes[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(bytes: &[u8], pos: &mut usize, depth: usize) -> Result<Json, String> {
    if depth > MAX_DEPTH {
        return Err(format!("nesting deeper than {MAX_DEPTH} levels"));
    }
    skip_ws(bytes, pos);
    match bytes.get(*pos) {
        None => Err("unexpected end of input".to_string()),
        Some(b'n') => parse_lit(bytes, pos, "null", Json::Null),
        Some(b't') => parse_lit(bytes, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_lit(bytes, pos, "false", Json::Bool(false)),
        Some(b'"') => parse_string(bytes, pos).map(Json::Str),
        Some(b'[') => parse_array(bytes, pos, depth),
        Some(b'{') => parse_object(bytes, pos, depth),
        Some(b'-' | b'0'..=b'9') => parse_number(bytes, pos),
        Some(&c) => Err(format!("unexpected byte {:?} at {}", c as char, *pos)),
    }
}

fn parse_lit(bytes: &[u8], pos: &mut usize, lit: &str, value: Json) -> Result<Json, String> {
    if bytes[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(value)
    } else {
        Err(format!("invalid literal at byte {}", *pos))
    }
}

fn parse_number(bytes: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    if bytes.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    while *pos < bytes.len()
        && matches!(bytes[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&bytes[start..*pos])
        .map_err(|_| format!("invalid number encoding at byte {start}"))?;
    text.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| format!("invalid number {text:?} at byte {start}"))
}

fn parse_string(bytes: &[u8], pos: &mut usize) -> Result<String, String> {
    debug_assert_eq!(bytes.get(*pos), Some(&b'"'));
    *pos += 1;
    let mut out = String::new();
    loop {
        let c = *bytes.get(*pos).ok_or_else(|| "unterminated string".to_string())?;
        *pos += 1;
        match c {
            b'"' => return Ok(out),
            b'\\' => {
                let esc = *bytes.get(*pos).ok_or_else(|| "unterminated escape".to_string())?;
                *pos += 1;
                match esc {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let hi = parse_hex4(bytes, pos)?;
                        let code = if (0xd800..0xdc00).contains(&hi) {
                            // Surrogate pair: expect \uXXXX low half next.
                            if bytes.get(*pos) == Some(&b'\\') && bytes.get(*pos + 1) == Some(&b'u')
                            {
                                *pos += 2;
                                let lo = parse_hex4(bytes, pos)?;
                                if !(0xdc00..0xe000).contains(&lo) {
                                    return Err("invalid low surrogate".to_string());
                                }
                                0x10000 + ((hi - 0xd800) << 10) + (lo - 0xdc00)
                            } else {
                                return Err("unpaired surrogate".to_string());
                            }
                        } else if (0xdc00..0xe000).contains(&hi) {
                            return Err("unpaired low surrogate".to_string());
                        } else {
                            hi
                        };
                        out.push(
                            char::from_u32(code)
                                .ok_or_else(|| format!("invalid code point {code:#x}"))?,
                        );
                    }
                    other => return Err(format!("invalid escape \\{}", other as char)),
                }
            }
            c if c < 0x20 => return Err("unescaped control byte in string".to_string()),
            c if c < 0x80 => out.push(c as char),
            _ => {
                // Multi-byte UTF-8: re-decode from the byte before `pos`.
                let start = *pos - 1;
                let len = utf8_len(c)?;
                let end = start + len;
                let chunk =
                    bytes.get(start..end).ok_or_else(|| "truncated UTF-8 sequence".to_string())?;
                let s = std::str::from_utf8(chunk)
                    .map_err(|_| format!("invalid UTF-8 at byte {start}"))?;
                out.push_str(s);
                *pos = end;
            }
        }
    }
}

fn utf8_len(first: u8) -> Result<usize, String> {
    match first {
        0xc0..=0xdf => Ok(2),
        0xe0..=0xef => Ok(3),
        0xf0..=0xf7 => Ok(4),
        _ => Err("invalid UTF-8 lead byte".to_string()),
    }
}

fn parse_hex4(bytes: &[u8], pos: &mut usize) -> Result<u32, String> {
    let chunk = bytes.get(*pos..*pos + 4).ok_or_else(|| "truncated \\u escape".to_string())?;
    let text = std::str::from_utf8(chunk).map_err(|_| "invalid \\u escape".to_string())?;
    let value = u32::from_str_radix(text, 16).map_err(|_| format!("invalid \\u{text}"))?;
    *pos += 4;
    Ok(value)
}

fn parse_array(bytes: &[u8], pos: &mut usize, depth: usize) -> Result<Json, String> {
    *pos += 1; // consume '['
    let mut items = Vec::new();
    skip_ws(bytes, pos);
    if bytes.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(bytes, pos, depth + 1)?);
        skip_ws(bytes, pos);
        match bytes.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => return Err(format!("expected ',' or ']' at byte {}", *pos)),
        }
    }
}

fn parse_object(bytes: &[u8], pos: &mut usize, depth: usize) -> Result<Json, String> {
    *pos += 1; // consume '{'
    let mut map = BTreeMap::new();
    skip_ws(bytes, pos);
    if bytes.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(map));
    }
    loop {
        skip_ws(bytes, pos);
        if bytes.get(*pos) != Some(&b'"') {
            return Err(format!("expected object key at byte {}", *pos));
        }
        let key = parse_string(bytes, pos)?;
        skip_ws(bytes, pos);
        if bytes.get(*pos) != Some(&b':') {
            return Err(format!("expected ':' at byte {}", *pos));
        }
        *pos += 1;
        let value = parse_value(bytes, pos, depth + 1)?;
        map.insert(key, value);
        skip_ws(bytes, pos);
        match bytes.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            _ => return Err(format!("expected ',' or '}}' at byte {}", *pos)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[allow(clippy::unwrap_used)]
    fn parses_scalars_and_containers() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
        let arr = parse("[1, 2, 3]").unwrap();
        assert_eq!(arr.as_f32_vec().unwrap(), vec![1.0, 2.0, 3.0]);
        let obj = parse("{\"image\": [0.5], \"id\": \"x\"}").unwrap();
        assert_eq!(obj.get("id"), Some(&Json::Str("x".into())));
    }

    #[test]
    #[allow(clippy::unwrap_used)]
    fn handles_unicode_escapes() {
        assert_eq!(parse("\"\\u00e9\"").unwrap(), Json::Str("é".into()));
        // Surrogate pair for U+1F600.
        assert_eq!(
            parse("\"\\ud83d\\ude00\"").unwrap(),
            Json::Str("\u{1f600}".into())
        );
        assert_eq!(parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "",
            "{",
            "[1,",
            "\"open",
            "nul",
            "{\"a\" 1}",
            "[1] trailing",
            "\"\\ud800\"",
            "01a",
        ] {
            assert!(parse(bad).is_err(), "expected parse error for {bad:?}");
        }
    }

    #[test]
    fn rejects_pathological_nesting() {
        let deep = "[".repeat(MAX_DEPTH + 2) + &"]".repeat(MAX_DEPTH + 2);
        assert!(parse(&deep).is_err());
    }

    #[test]
    #[allow(clippy::unwrap_used)]
    fn f32_round_trip_is_bit_exact() {
        let values = vec![0.1f32, -3.75, 1.0e-20, f32::MAX, 0.0, -0.0, f32::NAN];
        let rendered = render_f32s(&values);
        let parsed = parse(&rendered).unwrap();
        let items = match parsed {
            Json::Arr(items) => items,
            other => panic!("expected array, got {other:?}"),
        };
        for (orig, got) in values.iter().zip(items.iter()) {
            match got {
                Json::Num(n) => assert_eq!(orig.to_bits(), (*n as f32).to_bits()),
                Json::Null => assert!(!orig.is_finite()),
                other => panic!("unexpected element {other:?}"),
            }
        }
    }

    #[test]
    fn escape_str_covers_specials() {
        assert_eq!(escape_str("a\"b\\c\n"), "\"a\\\"b\\\\c\\n\"");
        assert_eq!(escape_str("\u{1}"), "\"\\u0001\"");
    }
}
