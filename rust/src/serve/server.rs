//! The listener, connection workers, router, and result-drain hub.
//!
//! One thread blocks on `accept`; each connection gets its own worker
//! thread running a keep-alive request loop. The accept path never
//! sleeps and never touches the pool, so a shed or throttled tenant can
//! only ever stall its own connection — backoff/retry for pool admission
//! rejects runs inside the connection worker that owns the request.
//!
//! Streaming (`/v1/batch`) rides the pool's submit/drain queue. Pool
//! tickets are a single global FIFO across all submitters, so a lone
//! drainer thread pulls results and a hub demultiplexes them back to the
//! waiting connection workers by ticket sequence number.

use std::collections::{BTreeMap, HashMap};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::engine::{lock_recover, EngineError, EnginePool, TenantOutcome};
use crate::serve::http::{HttpConn, HttpError, Limits, Request, Response};
use crate::serve::json::{self, Json};
use crate::serve::prometheus::{self, HttpSnapshot};
use crate::serve::tenant::{retry_after_secs, Identity, TenantRegistry};

/// Tunables for the serving front door.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Maximum request-body bytes (413 beyond this).
    pub max_body: usize,
    /// Maximum request-head bytes (431 beyond this).
    pub max_head: usize,
    /// Socket read timeout; idle keep-alive connections close after it.
    pub read_timeout: Duration,
    /// Upper bound on a single admission-reject backoff sleep.
    pub backoff_cap: Duration,
    /// Total time a `/v1/batch` worker spends retrying shed submits
    /// before giving up with 429.
    pub batch_retry_budget: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_body: 1 << 20,
            max_head: 16 * 1024,
            read_timeout: Duration::from_secs(5),
            backoff_cap: Duration::from_millis(5),
            batch_retry_budget: Duration::from_millis(250),
        }
    }
}

/// Result type flowing from the drainer back to batch waiters.
type DrainResult = std::result::Result<Vec<f32>, EngineError>;

/// Demultiplexes globally-FIFO pool drain results back to per-request
/// waiters by ticket sequence number.
struct Hub {
    state: Mutex<HubState>,
}

struct HubState {
    waiters: HashMap<u64, mpsc::Sender<DrainResult>>,
    /// Results drained before their waiter registered (submit returns
    /// the ticket *after* the drainer may have already pulled it).
    orphans: HashMap<u64, DrainResult>,
}

/// What a batch worker holds while its ticket is in flight.
enum Waiter {
    /// The drainer beat us to registration; the result is already here.
    Ready(DrainResult),
    /// Result will arrive on this channel.
    Pending(mpsc::Receiver<DrainResult>),
}

impl Hub {
    fn new() -> Self {
        Hub { state: Mutex::new(HubState { waiters: HashMap::new(), orphans: HashMap::new() }) }
    }

    fn register(&self, seq: u64) -> Waiter {
        let mut st = lock_recover(&self.state);
        if let Some(res) = st.orphans.remove(&seq) {
            return Waiter::Ready(res);
        }
        let (tx, rx) = mpsc::channel();
        st.waiters.insert(seq, tx);
        Waiter::Pending(rx)
    }

    fn deliver(&self, seq: u64, res: DrainResult) {
        let mut st = lock_recover(&self.state);
        match st.waiters.remove(&seq) {
            // A send error means the waiter gave up; drop the result.
            Some(tx) => {
                let _ = tx.send(res);
            }
            None => {
                st.orphans.insert(seq, res);
            }
        }
    }
}

impl Waiter {
    fn claim(self, timeout: Duration) -> Option<DrainResult> {
        match self {
            Waiter::Ready(res) => Some(res),
            Waiter::Pending(rx) => rx.recv_timeout(timeout).ok(),
        }
    }
}

struct Inner {
    pool: Arc<EnginePool>,
    registry: TenantRegistry,
    cfg: ServeConfig,
    stop: AtomicBool,
    addr: std::net::SocketAddr,
    started: Instant,
    connections: AtomicU64,
    active: AtomicUsize,
    responses: Mutex<BTreeMap<u16, u64>>,
    hub: Hub,
}

/// Decrements the active-connection gauge even if the worker panics.
struct ActiveGuard<'a>(&'a AtomicUsize);

impl Drop for ActiveGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Release);
    }
}

/// A running HTTP front door over an [`EnginePool`].
pub struct Server {
    inner: Arc<Inner>,
    accept: Mutex<Option<std::thread::JoinHandle<()>>>,
    drainer: Mutex<Option<std::thread::JoinHandle<()>>>,
}

impl Server {
    /// Binds `listen` (e.g. `127.0.0.1:0` for an ephemeral port) and
    /// starts the accept and drainer threads.
    pub fn start(
        pool: Arc<EnginePool>,
        registry: TenantRegistry,
        listen: &str,
        cfg: ServeConfig,
    ) -> Result<Server> {
        let listener = TcpListener::bind(listen).with_context(|| format!("binding {listen}"))?;
        let addr = listener.local_addr().context("resolving bound address")?;
        let inner = Arc::new(Inner {
            pool,
            registry,
            cfg,
            stop: AtomicBool::new(false),
            addr,
            started: Instant::now(),
            connections: AtomicU64::new(0),
            active: AtomicUsize::new(0),
            responses: Mutex::new(BTreeMap::new()),
            hub: Hub::new(),
        });
        let accept_inner = Arc::clone(&inner);
        let accept = std::thread::Builder::new()
            .name("scnn-serve-accept".to_string())
            .spawn(move || accept_loop(&accept_inner, &listener))
            .context("spawning accept thread")?;
        let drain_inner = Arc::clone(&inner);
        let drainer = std::thread::Builder::new()
            .name("scnn-serve-drain".to_string())
            .spawn(move || drain_loop(&drain_inner))
            .context("spawning drainer thread")?;
        Ok(Server {
            inner,
            accept: Mutex::new(Some(accept)),
            drainer: Mutex::new(Some(drainer)),
        })
    }

    /// The address the server is listening on.
    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.inner.addr
    }

    /// Graceful drain: stop accepting, let in-flight connections finish
    /// (bounded by the read timeout plus a grace period), close the
    /// pool, and join the worker threads. Idempotent.
    pub fn shutdown(&self) {
        if self.inner.stop.swap(true, Ordering::SeqCst) {
            return;
        }
        // Unblock the accept thread; it re-checks the stop flag per
        // connection.
        let _ = TcpStream::connect(self.inner.addr);
        if let Some(h) = lock_recover(&self.accept).take() {
            let _ = h.join();
        }
        let grace = self.inner.cfg.read_timeout + Duration::from_secs(1);
        let deadline = Instant::now() + grace;
        while self.inner.active.load(Ordering::Acquire) > 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        self.inner.pool.close();
        if let Some(h) = lock_recover(&self.drainer).take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(inner: &Arc<Inner>, listener: &TcpListener) {
    loop {
        let stream = match listener.accept() {
            Ok((stream, _)) => stream,
            Err(_) => {
                if inner.stop.load(Ordering::Acquire) {
                    return;
                }
                // Transient accept failure (fd pressure): don't spin hot.
                std::thread::sleep(Duration::from_millis(1));
                continue;
            }
        };
        if inner.stop.load(Ordering::Acquire) {
            return;
        }
        inner.connections.fetch_add(1, Ordering::Relaxed);
        inner.active.fetch_add(1, Ordering::Acquire);
        let conn_inner = Arc::clone(inner);
        let spawned = std::thread::Builder::new()
            .name("scnn-serve-conn".to_string())
            .spawn(move || {
                let _guard = ActiveGuard(&conn_inner.active);
                handle_connection(&conn_inner, stream);
            });
        if spawned.is_err() {
            inner.active.fetch_sub(1, Ordering::Release);
        }
    }
}

/// Pulls globally-ordered drain results and routes them to waiters.
fn drain_loop(inner: &Arc<Inner>) {
    loop {
        if inner.pool.outstanding() == 0 {
            if inner.stop.load(Ordering::Acquire) && inner.pool.is_closed() {
                return;
            }
            std::thread::sleep(Duration::from_micros(200));
            continue;
        }
        match inner.pool.drain_one() {
            Ok((ticket, res)) => inner.hub.deliver(ticket.seq(), res),
            Err(EngineError::EmptyQueue) => std::thread::sleep(Duration::from_micros(200)),
            Err(_) => std::thread::sleep(Duration::from_millis(1)),
        }
    }
}

fn handle_connection(inner: &Arc<Inner>, stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(inner.cfg.read_timeout));
    let _ = stream.set_nodelay(true);
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let limits = Limits { max_head: inner.cfg.max_head, max_body: inner.cfg.max_body };
    let mut conn = HttpConn::new(stream, limits);
    loop {
        match conn.next_request() {
            Ok(req) => {
                let close = req.wants_close() || inner.stop.load(Ordering::Acquire);
                let resp = route(inner, &req);
                count_response(inner, resp.status);
                if resp.write_to(&mut writer, close).is_err() || close {
                    return;
                }
            }
            Err(HttpError::Eof) | Err(HttpError::Io(_)) => return,
            Err(err) => {
                // Typed framing reject: answer once, then close (the
                // stream position is unreliable after a parse error).
                let resp = Response::json(
                    err.status(),
                    error_body("malformed_request", &err.to_string()),
                );
                count_response(inner, resp.status);
                let _ = resp.write_to(&mut writer, true);
                return;
            }
        }
    }
}

fn count_response(inner: &Inner, status: u16) {
    *lock_recover(&inner.responses).entry(status).or_insert(0) += 1;
}

fn error_body(kind: &str, msg: &str) -> String {
    format!("{{\"error\":{},\"kind\":{}}}", json::escape_str(msg), json::escape_str(kind))
}

/// Maps a pool/session error to a response, per the serving contract:
/// shed → 429 with `Retry-After`, deadline → 408, no capacity → 503,
/// malformed input → 400, anything else → 500.
fn error_response(err: &EngineError) -> Response {
    match err {
        EngineError::Rejected { retry_after_hint } => {
            Response::json(429, error_body("shed", &err.to_string()))
                .with_header("Retry-After", retry_after_secs(*retry_after_hint).to_string())
        }
        EngineError::Timeout { .. } => {
            Response::json(408, error_body("timeout", &err.to_string()))
        }
        EngineError::Closed | EngineError::NoHealthyShards | EngineError::WorkerDied => {
            Response::json(503, error_body("unavailable", &err.to_string()))
        }
        EngineError::Request(msg) => Response::json(400, error_body("bad_request", msg)),
        other => Response::json(500, error_body("internal", &other.to_string())),
    }
}

fn route(inner: &Arc<Inner>, req: &Request) -> Response {
    match (req.method.as_str(), req.path()) {
        ("GET", "/healthz") => healthz(inner),
        ("GET", "/metrics") => metrics(inner),
        ("POST", "/v1/infer") => infer(inner, req),
        ("POST", "/v1/batch") => batch(inner, req),
        (_, "/healthz" | "/metrics" | "/v1/infer" | "/v1/batch") => {
            Response::json(405, error_body("method_not_allowed", "wrong method for this path"))
        }
        (_, path) => {
            Response::json(404, error_body("not_found", &format!("no such endpoint {path}")))
        }
    }
}

fn healthz(inner: &Inner) -> Response {
    let healthy = inner.pool.healthy_shards();
    let shards = inner.pool.shards();
    let draining = inner.stop.load(Ordering::Acquire) || inner.pool.is_closed();
    let status = if draining {
        "draining"
    } else if healthy == shards {
        "ok"
    } else if healthy > 0 {
        "degraded"
    } else {
        "unhealthy"
    };
    let body = format!(
        "{{\"status\":\"{status}\",\"shards\":{shards},\"healthy\":{healthy},\
         \"outstanding\":{}}}",
        inner.pool.outstanding()
    );
    let code = if healthy > 0 && !draining { 200 } else { 503 };
    Response::json(code, body)
}

fn metrics(inner: &Inner) -> Response {
    let snapshot = HttpSnapshot {
        connections: inner.connections.load(Ordering::Relaxed),
        responses: lock_recover(&inner.responses).iter().map(|(k, v)| (*k, *v)).collect(),
        uptime_secs: inner.started.elapsed().as_secs_f64(),
    };
    Response::text(200, prometheus::render(&inner.pool.metrics(), Some(&snapshot)))
}

/// Extracts the API key from `Authorization: Bearer` or `X-Api-Key`.
fn api_key(req: &Request) -> Option<&str> {
    if let Some(auth) = req.header("authorization") {
        if let Some(rest) = auth.strip_prefix("Bearer ") {
            return Some(rest.trim());
        }
    }
    req.header("x-api-key").map(str::trim)
}

/// Authenticates and charges quota; `cost` is the token count (one per
/// image). On failure the tenant outcome is already recorded.
fn admit(inner: &Inner, req: &Request, cost: f64) -> std::result::Result<Identity, Response> {
    let id = match inner.registry.authenticate(api_key(req)) {
        Some(id) => id,
        None => {
            return Err(Response::json(
                401,
                error_body("unauthorized", "missing or unknown API key"),
            ))
        }
    };
    if let Err(wait) = inner.registry.admit(id, cost) {
        inner.pool.note_tenant(inner.registry.name(id), TenantOutcome::QuotaRejected);
        let secs = retry_after_secs(wait);
        return Err(Response::json(
            429,
            error_body("quota", &format!("tenant quota exhausted; retry in ~{secs}s")),
        )
        .with_header("Retry-After", secs.to_string()));
    }
    Ok(id)
}

/// Pulls the image vector out of `{"image": [...]}` or a bare array.
fn parse_image(body: &[u8]) -> std::result::Result<Vec<f32>, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not UTF-8".to_string())?;
    let doc = json::parse(text)?;
    match doc.get("image") {
        Some(arr) => arr.as_f32_vec(),
        None => doc.as_f32_vec(),
    }
    .map_err(|e| format!("image: {e}"))
}

/// Pulls the image list out of `{"images": [[...], ...]}` or a bare
/// array of arrays.
fn parse_images(body: &[u8]) -> std::result::Result<Vec<Vec<f32>>, String> {
    let text = std::str::from_utf8(body).map_err(|_| "body is not UTF-8".to_string())?;
    let doc = json::parse(text)?;
    let arr = match doc.get("images") {
        Some(arr) => arr,
        None => &doc,
    };
    let items = match arr {
        Json::Arr(items) => items,
        other => return Err(format!("images: expected an array, got {}", other.kind())),
    };
    let mut out = Vec::with_capacity(items.len());
    for (i, item) in items.iter().enumerate() {
        out.push(item.as_f32_vec().map_err(|e| format!("images[{i}]: {e}"))?);
    }
    Ok(out)
}

fn infer(inner: &Inner, req: &Request) -> Response {
    let id = match admit(inner, req, 1.0) {
        Ok(id) => id,
        Err(resp) => return resp,
    };
    let tenant = inner.registry.name(id).to_string();
    let image = match parse_image(&req.body) {
        Ok(image) => image,
        Err(msg) => {
            inner.pool.note_tenant(&tenant, TenantOutcome::Failed);
            return Response::json(400, error_body("bad_request", &msg));
        }
    };
    // Tenant-keyed requests keep shard affinity; open access round-robins
    // so anonymous load still spreads over every shard.
    let result = if inner.registry.is_empty() {
        inner.pool.infer(image)
    } else {
        inner.pool.infer_keyed(&tenant, image)
    };
    match result {
        Ok(output) => {
            inner.pool.note_tenant(&tenant, TenantOutcome::Ok);
            let class = crate::engine::classify(&output);
            let body =
                format!("{{\"output\":{},\"class\":{class}}}", json::render_f32s(&output));
            Response::json(200, body)
        }
        Err(err) => {
            let outcome = match err {
                EngineError::Rejected { .. } => TenantOutcome::Shed,
                _ => TenantOutcome::Failed,
            };
            inner.pool.note_tenant(&tenant, outcome);
            error_response(&err)
        }
    }
}

/// Submits one image, absorbing admission rejects with jittered backoff
/// *inside this worker thread* — the accept loop and unrelated
/// connections never sleep on another tenant's shed traffic.
fn submit_with_backoff(
    inner: &Inner,
    key: Option<&str>,
    image: &[f32],
) -> std::result::Result<u64, EngineError> {
    let deadline = Instant::now() + inner.cfg.batch_retry_budget;
    let mut attempt = 0u64;
    loop {
        let submitted = match key {
            Some(key) => inner.pool.submit_keyed(key, image.to_vec()),
            None => inner.pool.submit(image.to_vec()),
        };
        match submitted {
            Ok(ticket) => return Ok(ticket.seq()),
            Err(EngineError::Rejected { retry_after_hint }) => {
                if Instant::now() >= deadline {
                    return Err(EngineError::Rejected { retry_after_hint });
                }
                attempt += 1;
                let jitter = Duration::from_micros(crate::sc::rng::mix64(attempt) % 101);
                std::thread::sleep((retry_after_hint + jitter).min(inner.cfg.backoff_cap));
            }
            Err(err) => return Err(err),
        }
    }
}

fn batch(inner: &Inner, req: &Request) -> Response {
    let images = match parse_images(&req.body) {
        Ok(images) if !images.is_empty() => images,
        Ok(_) => return Response::json(400, error_body("bad_request", "images is empty")),
        Err(msg) => return Response::json(400, error_body("bad_request", &msg)),
    };
    let id = match admit(inner, req, images.len() as f64) {
        Ok(id) => id,
        Err(resp) => return resp,
    };
    let tenant = inner.registry.name(id).to_string();
    let key = if inner.registry.is_empty() { None } else { Some(tenant.as_str()) };

    // Submit everything first (tickets record submission order), then
    // claim results in the same order — the pool's FIFO guarantee makes
    // the response order deterministic per tenant.
    let mut waiters = Vec::with_capacity(images.len());
    let mut failure: Option<EngineError> = None;
    for image in &images {
        match submit_with_backoff(inner, key, image) {
            Ok(seq) => waiters.push(inner.hub.register(seq)),
            Err(err) => {
                failure = Some(err);
                break;
            }
        }
    }
    // Even on a mid-batch failure every registered waiter is claimed, so
    // no drained result leaks into the hub's orphan map.
    let mut results = Vec::with_capacity(waiters.len());
    for waiter in waiters {
        match waiter.claim(Duration::from_secs(30)) {
            Some(Ok(output)) => results.push(output),
            Some(Err(err)) => failure = failure.or(Some(err)),
            None => {
                failure = failure
                    .or_else(|| Some(EngineError::Request("result wait timed out".to_string())));
            }
        }
    }
    if let Some(err) = failure {
        let outcome = match err {
            EngineError::Rejected { .. } => TenantOutcome::Shed,
            _ => TenantOutcome::Failed,
        };
        inner.pool.note_tenant(&tenant, outcome);
        return error_response(&err);
    }
    inner.pool.note_tenant(&tenant, TenantOutcome::Ok);
    let mut body = String::with_capacity(results.len() * 32 + 32);
    body.push_str("{\"results\":[");
    for (i, output) in results.iter().enumerate() {
        if i > 0 {
            body.push(',');
        }
        body.push_str(&json::render_f32s(output));
    }
    body.push_str(&format!("],\"count\":{}}}", results.len()));
    Response::json(200, body)
}
