//! The network front door: a hand-rolled HTTP/1.1 server over
//! [`crate::engine::EnginePool`].
//!
//! The deployment target is an offline container, so the whole stack —
//! framing, JSON, auth, quotas, metrics exposition — is built on
//! `std::net` with no async runtime. Each accepted connection gets a
//! worker thread running a keep-alive request loop; a single drainer
//! thread demultiplexes the pool's globally-ordered ticket stream back
//! to streaming clients.
//!
//! Endpoints:
//!
//! | Endpoint        | Method | Purpose                                          |
//! |-----------------|--------|--------------------------------------------------|
//! | `/v1/infer`     | POST   | One image in, logits + argmax class out.         |
//! | `/v1/batch`     | POST   | Many images via pool submit/drain, order kept.   |
//! | `/metrics`      | GET    | Prometheus text exposition of pool + HTTP stats. |
//! | `/healthz`      | GET    | Shard health and drain state.                    |
//!
//! Multi-tenancy: [`TenantRegistry`] maps API keys to tenant names that
//! double as pool placement keys (shard affinity) and to token-bucket
//! quotas. Quota exhaustion and pool admission sheds both answer `429`
//! with a `Retry-After` header; client deadlines surface as `408` via
//! [`crate::engine::EngineError::Timeout`]; malformed or oversized
//! requests get typed `4xx` rejects from the bounded incremental parser
//! in [`http`] — never a panic.

#![deny(clippy::unwrap_used)]

pub mod http;
pub mod json;
pub mod prometheus;
pub mod server;
pub mod tenant;

pub use http::{read_response, HttpConn, HttpError, Limits, Request, Response};
pub use prometheus::HttpSnapshot;
pub use server::{ServeConfig, Server};
pub use tenant::{retry_after_secs, Identity, Tenant, TenantRegistry};
