//! Tenant registry: API-key authentication and token-bucket quotas.
//!
//! Tenants are declared with `--tenants` as `name:key[:rps[:burst]]`
//! entries separated by `;` or newlines (the flag value may also be a
//! path to a file holding the same format, so keys stay out of `ps`
//! output). Each tenant's name doubles as the pool placement key, so a
//! tenant's requests stick to one shard and its cache/queue locality,
//! and each tenant gets an independent token bucket: `rps` tokens per
//! second refill, `burst` capacity, `rps = 0` meaning unlimited.

use std::time::{Duration, Instant};

use crate::engine::lock_recover;

/// One declared tenant.
#[derive(Debug, Clone)]
pub struct Tenant {
    /// Tenant name; also used as the pool placement key.
    pub name: String,
    /// API key presented via `Authorization: Bearer` or `X-Api-Key`.
    pub key: String,
    /// Sustained requests per second (0 = unlimited).
    pub rps: f64,
    /// Token-bucket capacity.
    pub burst: f64,
}

/// Who a request is acting as, after authentication.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Identity {
    /// No tenants are configured; all requests share one identity.
    Anonymous,
    /// Index into the registry's tenant table.
    Tenant(usize),
}

struct Bucket {
    tokens: f64,
    refreshed: Instant,
}

/// Registry of tenants plus their live quota buckets.
pub struct TenantRegistry {
    tenants: Vec<Tenant>,
    buckets: std::sync::Mutex<Vec<Bucket>>,
}

impl TenantRegistry {
    /// An open registry: no tenants, no auth, no quotas.
    pub fn open() -> Self {
        TenantRegistry { tenants: Vec::new(), buckets: std::sync::Mutex::new(Vec::new()) }
    }

    /// Parses a `--tenants` spec: `name:key[:rps[:burst]]` entries
    /// separated by `;` or newlines. Empty entries are skipped; names
    /// and keys must be unique and non-empty.
    pub fn parse(spec: &str) -> Result<Self, String> {
        let mut tenants: Vec<Tenant> = Vec::new();
        for entry in spec.split(|c| c == ';' || c == '\n') {
            let entry = entry.trim();
            if entry.is_empty() || entry.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = entry.split(':').collect();
            if parts.len() < 2 || parts.len() > 4 {
                return Err(format!("tenant entry {entry:?} must be name:key[:rps[:burst]]"));
            }
            let name = parts[0].trim().to_string();
            let key = parts[1].trim().to_string();
            if name.is_empty() || key.is_empty() {
                return Err(format!("tenant entry {entry:?} has an empty name or key"));
            }
            let rps = match parts.get(2) {
                Some(v) => v
                    .trim()
                    .parse::<f64>()
                    .ok()
                    .filter(|r| r.is_finite() && *r >= 0.0)
                    .ok_or_else(|| format!("tenant {name}: bad rps {v:?}"))?,
                None => 0.0,
            };
            let burst = match parts.get(3) {
                Some(v) => v
                    .trim()
                    .parse::<f64>()
                    .ok()
                    .filter(|b| b.is_finite() && *b >= 1.0)
                    .ok_or_else(|| format!("tenant {name}: bad burst {v:?}"))?,
                None => rps.max(1.0),
            };
            if tenants.iter().any(|t| t.name == name) {
                return Err(format!("duplicate tenant name {name:?}"));
            }
            if tenants.iter().any(|t| t.key == key) {
                return Err(format!("duplicate tenant key (tenant {name:?})"));
            }
            tenants.push(Tenant { name, key, rps, burst });
        }
        let now = Instant::now();
        let mut buckets = Vec::with_capacity(tenants.len());
        for t in &tenants {
            buckets.push(Bucket { tokens: t.burst, refreshed: now });
        }
        Ok(TenantRegistry { tenants, buckets: std::sync::Mutex::new(buckets) })
    }

    /// Number of configured tenants (0 means open access).
    pub fn len(&self) -> usize {
        self.tenants.len()
    }

    /// The configured tenants, in registry order — what the
    /// [`crate::analyze`] deployment lints read to weigh aggregate
    /// sustained quotas against modeled pool throughput.
    pub fn tenants(&self) -> &[Tenant] {
        &self.tenants
    }

    /// Whether the registry has no tenants configured.
    pub fn is_empty(&self) -> bool {
        self.tenants.is_empty()
    }

    /// Maps a presented API key to an identity.
    ///
    /// With no tenants configured everyone is [`Identity::Anonymous`];
    /// otherwise a missing or unknown key is `None` (→ 401).
    pub fn authenticate(&self, key: Option<&str>) -> Option<Identity> {
        if self.tenants.is_empty() {
            return Some(Identity::Anonymous);
        }
        let key = key?;
        self.tenants.iter().position(|t| t.key == key).map(Identity::Tenant)
    }

    /// The tenant name for an identity (`"anonymous"` for open access).
    pub fn name(&self, id: Identity) -> &str {
        match id {
            Identity::Anonymous => "anonymous",
            Identity::Tenant(i) => {
                self.tenants.get(i).map(|t| t.name.as_str()).unwrap_or("anonymous")
            }
        }
    }

    /// Takes `cost` tokens from the identity's bucket, or reports how
    /// long until that many tokens will be available.
    ///
    /// Anonymous access and `rps = 0` tenants are never throttled.
    pub fn admit(&self, id: Identity, cost: f64) -> Result<(), Duration> {
        let idx = match id {
            Identity::Anonymous => return Ok(()),
            Identity::Tenant(i) => i,
        };
        let tenant = match self.tenants.get(idx) {
            Some(t) if t.rps > 0.0 => t,
            _ => return Ok(()),
        };
        let mut buckets = lock_recover(&self.buckets);
        let bucket = match buckets.get_mut(idx) {
            Some(b) => b,
            None => return Ok(()),
        };
        let now = Instant::now();
        let elapsed = now.duration_since(bucket.refreshed).as_secs_f64();
        bucket.tokens = (bucket.tokens + elapsed * tenant.rps).min(tenant.burst);
        bucket.refreshed = now;
        if bucket.tokens >= cost {
            bucket.tokens -= cost;
            Ok(())
        } else {
            let deficit = cost - bucket.tokens;
            Err(Duration::from_secs_f64(deficit / tenant.rps))
        }
    }

    /// All configured tenant names, for metric pre-registration.
    pub fn names(&self) -> Vec<String> {
        self.tenants.iter().map(|t| t.name.clone()).collect()
    }
}

/// Formats a retry hint as a `Retry-After` header value: whole seconds,
/// rounded up, at least 1.
pub fn retry_after_secs(hint: Duration) -> u64 {
    (hint.as_secs_f64().ceil() as u64).max(1)
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn parses_spec_with_defaults() {
        let reg = TenantRegistry::parse("alice:ka:10;bob:kb:2:8; carol:kc ").unwrap();
        assert_eq!(reg.len(), 3);
        assert_eq!(reg.authenticate(Some("kb")), Some(Identity::Tenant(1)));
        assert_eq!(reg.name(Identity::Tenant(2)), "carol");
        assert_eq!(reg.authenticate(Some("nope")), None);
        assert_eq!(reg.authenticate(None), None);
    }

    #[test]
    fn open_registry_admits_everyone() {
        let reg = TenantRegistry::open();
        assert_eq!(reg.authenticate(None), Some(Identity::Anonymous));
        assert_eq!(reg.name(Identity::Anonymous), "anonymous");
        assert!(reg.admit(Identity::Anonymous, 1.0).is_ok());
    }

    #[test]
    fn rejects_bad_specs() {
        for bad in [
            "justaname",
            "a:k:fast",
            "a:k:1:0.5",
            "a:k;a:k2",
            "a:k;b:k",
            ":k",
            "a:",
            "a:k:-1",
        ] {
            assert!(TenantRegistry::parse(bad).is_err(), "expected error for {bad:?}");
        }
    }

    #[test]
    fn bucket_exhaustion_reports_deficit() {
        // 0.5 rps, burst 1: the first request drains the bucket; the
        // second must wait ~2s for one token to refill.
        let reg = TenantRegistry::parse("a:k:0.5:1").unwrap();
        assert!(reg.admit(Identity::Tenant(0), 1.0).is_ok());
        let wait = reg.admit(Identity::Tenant(0), 1.0).unwrap_err();
        assert!(wait > Duration::from_millis(1500), "wait was {wait:?}");
        assert!(wait <= Duration::from_millis(2100), "wait was {wait:?}");
        assert_eq!(retry_after_secs(wait), 2);
    }

    #[test]
    fn unlimited_tenant_is_never_throttled() {
        let reg = TenantRegistry::parse("a:k").unwrap();
        for _ in 0..10_000 {
            assert!(reg.admit(Identity::Tenant(0), 1.0).is_ok());
        }
    }

    #[test]
    fn retry_after_rounds_up_to_at_least_one_second() {
        assert_eq!(retry_after_secs(Duration::from_micros(100)), 1);
        assert_eq!(retry_after_secs(Duration::from_millis(1200)), 2);
    }
}
