//! Incremental, bounded HTTP/1.1 message framing.
//!
//! The parser reads from any [`Read`] stream (loopback TCP in production,
//! [`std::io::Cursor`] in unit tests), keeps leftover bytes between
//! requests so keep-alive pipelining works, and enforces hard limits on
//! head and body size *before* buffering, so an oversized or malformed
//! client costs one bounded allocation and a typed reject — never a panic
//! and never unbounded memory.

use std::io::Read;
use std::time::Duration;

/// Maximum size of the request head (request line + headers) in bytes.
pub const DEFAULT_MAX_HEAD: usize = 16 * 1024;

/// A typed framing-layer failure. Each variant maps to exactly one HTTP
/// status so the connection worker can answer without string matching.
#[derive(Debug)]
pub enum HttpError {
    /// Malformed request line, header, or body encoding → 400.
    BadRequest(String),
    /// Request head exceeded the configured bound → 431.
    HeadersTooLarge(usize),
    /// Declared `Content-Length` exceeded the configured bound → 413.
    BodyTooLarge {
        /// What the client declared.
        declared: usize,
        /// The server's limit.
        max: usize,
    },
    /// A body-bearing method arrived without `Content-Length` → 411.
    LengthRequired,
    /// `Transfer-Encoding` is not supported by this server → 501.
    UnsupportedTransfer(String),
    /// The peer closed the connection cleanly between requests.
    Eof,
    /// The read timed out (socket read timeout) → 408.
    Timeout,
    /// Any other transport failure; the connection is dropped.
    Io(std::io::Error),
}

impl HttpError {
    /// The HTTP status code this error maps to (0 for Eof/Io, which
    /// close the connection without a response).
    pub fn status(&self) -> u16 {
        match self {
            HttpError::BadRequest(_) => 400,
            HttpError::HeadersTooLarge(_) => 431,
            HttpError::BodyTooLarge { .. } => 413,
            HttpError::LengthRequired => 411,
            HttpError::UnsupportedTransfer(_) => 501,
            HttpError::Timeout => 408,
            HttpError::Eof | HttpError::Io(_) => 0,
        }
    }
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HttpError::BadRequest(msg) => write!(f, "malformed request: {msg}"),
            HttpError::HeadersTooLarge(max) => {
                write!(f, "request head exceeds {max} bytes")
            }
            HttpError::BodyTooLarge { declared, max } => {
                write!(f, "declared body of {declared} bytes exceeds limit of {max}")
            }
            HttpError::LengthRequired => write!(f, "Content-Length required"),
            HttpError::UnsupportedTransfer(enc) => {
                write!(f, "transfer-encoding {enc:?} not supported")
            }
            HttpError::Eof => write!(f, "connection closed"),
            HttpError::Timeout => write!(f, "read timed out"),
            HttpError::Io(e) => write!(f, "i/o failure: {e}"),
        }
    }
}

impl std::error::Error for HttpError {}

/// A parsed request: method, target path, lower-cased headers, raw body.
#[derive(Debug)]
pub struct Request {
    /// Upper-case method token (`GET`, `POST`, ...).
    pub method: String,
    /// Request target as sent (path + optional query).
    pub target: String,
    /// Headers with lower-cased names, in arrival order.
    pub headers: Vec<(String, String)>,
    /// Raw body bytes (empty when no `Content-Length`).
    pub body: Vec<u8>,
}

impl Request {
    /// Case-insensitive header lookup; returns the first match.
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == name)
            .map(|(_, v)| v.as_str())
    }

    /// Path portion of the target (query string stripped).
    pub fn path(&self) -> &str {
        self.target.split('?').next().unwrap_or(&self.target)
    }

    /// Whether the client asked to close the connection after this
    /// exchange (`Connection: close`).
    pub fn wants_close(&self) -> bool {
        self.header("connection")
            .is_some_and(|v| v.eq_ignore_ascii_case("close"))
    }
}

/// Limits applied while framing a single request.
#[derive(Debug, Clone, Copy)]
pub struct Limits {
    /// Maximum request-head bytes (request line + headers).
    pub max_head: usize,
    /// Maximum declared body bytes.
    pub max_body: usize,
}

impl Default for Limits {
    fn default() -> Self {
        Limits { max_head: DEFAULT_MAX_HEAD, max_body: 1 << 20 }
    }
}

/// Incremental connection reader. Owns the leftover buffer between
/// keep-alive requests.
pub struct HttpConn<S> {
    stream: S,
    buf: Vec<u8>,
    limits: Limits,
}

impl<S: Read> HttpConn<S> {
    /// Wraps a stream with the given limits.
    pub fn new(stream: S, limits: Limits) -> Self {
        HttpConn { stream, buf: Vec::with_capacity(1024), limits }
    }

    /// Reads and parses the next request off the connection.
    pub fn next_request(&mut self) -> Result<Request, HttpError> {
        let head_end = self.read_head()?;
        let head_bytes = self.buf[..head_end].to_vec();
        // `head_end` includes the blank line; drop it from the buffer.
        self.buf.drain(..head_end + 4);
        let head = std::str::from_utf8(&head_bytes)
            .map_err(|_| HttpError::BadRequest("head is not valid UTF-8".to_string()))?;
        let mut lines = head.split("\r\n");
        let request_line = lines
            .next()
            .ok_or_else(|| HttpError::BadRequest("empty head".to_string()))?;
        let (method, target) = parse_request_line(request_line)?;
        let mut headers = Vec::new();
        for line in lines {
            if line.is_empty() {
                continue;
            }
            let (name, value) = line.split_once(':').ok_or_else(|| {
                HttpError::BadRequest(format!("header line without ':': {line:?}"))
            })?;
            if name.is_empty() || name.contains(' ') {
                return Err(HttpError::BadRequest(format!("invalid header name {name:?}")));
            }
            headers.push((name.to_ascii_lowercase(), value.trim().to_string()));
        }
        let request = Request { method, target, headers, body: Vec::new() };
        let body = self.read_body(&request)?;
        Ok(Request { body, ..request })
    }

    /// Reads until the head terminator, returning the offset of `\r\n\r\n`.
    fn read_head(&mut self) -> Result<usize, HttpError> {
        let mut scanned = 0usize;
        loop {
            if let Some(pos) = find_terminator(&self.buf, scanned.saturating_sub(3)) {
                if pos > self.limits.max_head {
                    return Err(HttpError::HeadersTooLarge(self.limits.max_head));
                }
                return Ok(pos);
            }
            scanned = self.buf.len();
            if scanned > self.limits.max_head {
                return Err(HttpError::HeadersTooLarge(self.limits.max_head));
            }
            let at_start = self.buf.is_empty();
            self.fill(at_start)?;
        }
    }

    fn read_body(&mut self, request: &Request) -> Result<Vec<u8>, HttpError> {
        if let Some(enc) = request.header("transfer-encoding") {
            return Err(HttpError::UnsupportedTransfer(enc.to_string()));
        }
        let declared = match request.header("content-length") {
            Some(v) => v
                .trim()
                .parse::<usize>()
                .map_err(|_| HttpError::BadRequest(format!("bad Content-Length {v:?}")))?,
            None => {
                if request.method == "POST" || request.method == "PUT" {
                    return Err(HttpError::LengthRequired);
                }
                return Ok(Vec::new());
            }
        };
        if declared > self.limits.max_body {
            return Err(HttpError::BodyTooLarge { declared, max: self.limits.max_body });
        }
        while self.buf.len() < declared {
            self.fill(false)?;
        }
        let body: Vec<u8> = self.buf.drain(..declared).collect();
        Ok(body)
    }

    /// Pulls more bytes from the stream into the buffer.
    ///
    /// `idle` marks a read happening between requests, where a clean
    /// close is Eof rather than a truncation error.
    fn fill(&mut self, idle: bool) -> Result<(), HttpError> {
        let mut chunk = [0u8; 4096];
        match self.stream.read(&mut chunk) {
            Ok(0) => {
                if idle {
                    Err(HttpError::Eof)
                } else {
                    Err(HttpError::BadRequest("connection closed mid-request".to_string()))
                }
            }
            Ok(n) => {
                self.buf.extend_from_slice(&chunk[..n]);
                Ok(())
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // An idle keep-alive connection timing out is a normal
                // close, not a client error; mid-request stalls are 408s.
                if idle {
                    Err(HttpError::Eof)
                } else {
                    Err(HttpError::Timeout)
                }
            }
            Err(e) => Err(HttpError::Io(e)),
        }
    }
}

fn find_terminator(buf: &[u8], from: usize) -> Option<usize> {
    buf.get(from..)
        .and_then(|tail| tail.windows(4).position(|w| w == b"\r\n\r\n"))
        .map(|p| p + from)
}

fn parse_request_line(line: &str) -> Result<(String, String), HttpError> {
    let mut parts = line.split(' ');
    let method = parts.next().unwrap_or_default();
    let target = parts.next().unwrap_or_default();
    let version = parts.next().unwrap_or_default();
    if parts.next().is_some() || method.is_empty() || target.is_empty() {
        return Err(HttpError::BadRequest(format!("bad request line {line:?}")));
    }
    if !method.bytes().all(|b| b.is_ascii_uppercase()) {
        return Err(HttpError::BadRequest(format!("bad method {method:?}")));
    }
    if !target.starts_with('/') {
        return Err(HttpError::BadRequest(format!("bad target {target:?}")));
    }
    if version != "HTTP/1.1" && version != "HTTP/1.0" {
        return Err(HttpError::BadRequest(format!("unsupported version {version:?}")));
    }
    Ok((method.to_string(), target.to_string()))
}

/// An outgoing response.
#[derive(Debug)]
pub struct Response {
    /// HTTP status code.
    pub status: u16,
    /// Extra headers beyond the auto-emitted ones.
    pub headers: Vec<(String, String)>,
    /// Response body bytes.
    pub body: Vec<u8>,
}

impl Response {
    /// A JSON response with the given status.
    pub fn json(status: u16, body: String) -> Self {
        Response {
            status,
            headers: vec![("Content-Type".to_string(), "application/json".to_string())],
            body: body.into_bytes(),
        }
    }

    /// A plain-text response with the given status.
    pub fn text(status: u16, body: String) -> Self {
        Response {
            status,
            headers: vec![(
                "Content-Type".to_string(),
                "text/plain; version=0.0.4; charset=utf-8".to_string(),
            )],
            body: body.into_bytes(),
        }
    }

    /// Adds a header, consuming and returning self for chaining.
    pub fn with_header(mut self, name: &str, value: String) -> Self {
        self.headers.push((name.to_string(), value));
        self
    }

    /// Serializes the response onto `w`. `close` controls the
    /// `Connection` header.
    pub fn write_to<W: std::io::Write>(&self, w: &mut W, close: bool) -> std::io::Result<()> {
        let mut head = format!(
            "HTTP/1.1 {} {}\r\nContent-Length: {}\r\n",
            self.status,
            status_reason(self.status),
            self.body.len()
        );
        for (name, value) in &self.headers {
            head.push_str(name);
            head.push_str(": ");
            head.push_str(value);
            head.push_str("\r\n");
        }
        if close {
            head.push_str("Connection: close\r\n\r\n");
        } else {
            head.push_str("Connection: keep-alive\r\n\r\n");
        }
        w.write_all(head.as_bytes())?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

/// Reason phrase for the status codes this server emits.
pub fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        401 => "Unauthorized",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        411 => "Length Required",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Reads one complete HTTP/1.1 response off `stream`.
///
/// A small client-side helper shared by the loopback tests and the load
/// generator; only `Content-Length`-framed responses are supported, which
/// is all this server emits.
pub fn read_response<S: Read>(
    stream: &mut S,
) -> std::io::Result<(u16, Vec<(String, String)>, Vec<u8>)> {
    let mut buf = Vec::with_capacity(1024);
    let head_end = loop {
        if let Some(pos) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break pos;
        }
        let mut chunk = [0u8; 4096];
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "connection closed before response head",
            ));
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = String::from_utf8_lossy(&buf[..head_end]).to_string();
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap_or_default();
    let status = status_line
        .split(' ')
        .nth(1)
        .and_then(|s| s.parse::<u16>().ok())
        .ok_or_else(|| {
            std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("bad status line {status_line:?}"),
            )
        })?;
    let mut headers = Vec::new();
    let mut content_length = 0usize;
    for line in lines {
        if let Some((name, value)) = line.split_once(':') {
            let name = name.to_ascii_lowercase();
            let value = value.trim().to_string();
            if name == "content-length" {
                content_length = value.parse().unwrap_or(0);
            }
            headers.push((name, value));
        }
    }
    let mut body: Vec<u8> = buf[head_end + 4..].to_vec();
    while body.len() < content_length {
        let mut chunk = [0u8; 4096];
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "connection closed mid-body",
            ));
        }
        body.extend_from_slice(&chunk[..n]);
    }
    body.truncate(content_length);
    Ok((status, headers, body))
}

/// Suggested socket read timeout for serving connections.
pub const DEFAULT_READ_TIMEOUT: Duration = Duration::from_secs(5);

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn conn(raw: &str) -> HttpConn<Cursor<Vec<u8>>> {
        HttpConn::new(Cursor::new(raw.as_bytes().to_vec()), Limits::default())
    }

    #[test]
    #[allow(clippy::unwrap_used)]
    fn parses_post_with_body() {
        let mut c = conn("POST /v1/infer HTTP/1.1\r\nHost: x\r\nContent-Length: 5\r\n\r\nhello");
        let req = c.next_request().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path(), "/v1/infer");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.body, b"hello");
    }

    #[test]
    #[allow(clippy::unwrap_used)]
    fn keep_alive_parses_pipelined_requests() {
        let mut c = conn(
            "GET /healthz HTTP/1.1\r\n\r\nGET /metrics HTTP/1.1\r\nConnection: close\r\n\r\n",
        );
        let first = c.next_request().unwrap();
        assert_eq!(first.path(), "/healthz");
        assert!(!first.wants_close());
        let second = c.next_request().unwrap();
        assert_eq!(second.path(), "/metrics");
        assert!(second.wants_close());
        assert!(matches!(c.next_request(), Err(HttpError::Eof)));
    }

    #[test]
    fn rejects_malformed_request_lines() {
        for raw in [
            "GARBAGE\r\n\r\n",
            "GET\r\n\r\n",
            "get /x HTTP/1.1\r\n\r\n",
            "GET x HTTP/1.1\r\n\r\n",
            "GET /x HTTP/2.0\r\n\r\n",
            "GET /x HTTP/1.1 extra\r\n\r\n",
        ] {
            let err = conn(raw).next_request();
            assert!(
                matches!(err, Err(HttpError::BadRequest(_))),
                "expected BadRequest for {raw:?}, got {err:?}"
            );
        }
    }

    #[test]
    fn rejects_header_without_colon() {
        let err = conn("GET / HTTP/1.1\r\nno colon here\r\n\r\n").next_request();
        assert!(matches!(err, Err(HttpError::BadRequest(_))));
    }

    #[test]
    fn rejects_oversized_declared_body_before_reading() {
        let mut c = HttpConn::new(
            Cursor::new(b"POST / HTTP/1.1\r\nContent-Length: 999999\r\n\r\n".to_vec()),
            Limits { max_head: DEFAULT_MAX_HEAD, max_body: 1024 },
        );
        match c.next_request() {
            Err(HttpError::BodyTooLarge { declared, max }) => {
                assert_eq!(declared, 999_999);
                assert_eq!(max, 1024);
            }
            other => panic!("expected BodyTooLarge, got {other:?}"),
        }
    }

    #[test]
    fn rejects_oversized_head() {
        let huge = format!("GET / HTTP/1.1\r\nX-Pad: {}\r\n\r\n", "a".repeat(64 * 1024));
        let mut c = HttpConn::new(
            Cursor::new(huge.into_bytes()),
            Limits { max_head: 1024, max_body: 1024 },
        );
        assert!(matches!(c.next_request(), Err(HttpError::HeadersTooLarge(_))));
    }

    #[test]
    fn post_without_length_is_length_required() {
        let err = conn("POST /v1/infer HTTP/1.1\r\n\r\n").next_request();
        assert!(matches!(err, Err(HttpError::LengthRequired)));
    }

    #[test]
    fn transfer_encoding_is_unsupported() {
        let err = conn("POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n").next_request();
        assert!(matches!(err, Err(HttpError::UnsupportedTransfer(_))));
    }

    #[test]
    #[allow(clippy::unwrap_used)]
    fn response_round_trips_through_client_reader() {
        let resp = Response::json(200, "{\"ok\":true}".to_string())
            .with_header("Retry-After", "2".to_string());
        let mut wire = Vec::new();
        resp.write_to(&mut wire, true).unwrap();
        let (status, headers, body) = read_response(&mut Cursor::new(wire)).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"{\"ok\":true}");
        assert!(headers.iter().any(|(k, v)| k == "retry-after" && v == "2"));
        assert!(headers.iter().any(|(k, v)| k == "connection" && v == "close"));
    }
}
