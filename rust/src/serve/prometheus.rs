//! Prometheus text exposition (version 0.0.4) for pool and HTTP metrics.
//!
//! Hand-rolled like the rest of the wire layer: the renderer walks a
//! [`PoolMetrics`] snapshot plus the server's own HTTP counters and emits
//! `# HELP`/`# TYPE` annotated families. Counter semantics hold because
//! every source counter is monotone for the life of the process.

use std::fmt::Write as _;

use crate::engine::PoolMetrics;

/// Server-side HTTP counters, sampled at scrape time.
#[derive(Debug, Clone, Default)]
pub struct HttpSnapshot {
    /// Connections accepted since startup.
    pub connections: u64,
    /// Responses emitted, by status code (sorted by code).
    pub responses: Vec<(u16, u64)>,
    /// Seconds since the server started listening.
    pub uptime_secs: f64,
}

/// Escapes a label value per the exposition format.
fn escape_label(value: &str) -> String {
    let mut out = String::with_capacity(value.len());
    for c in value.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn family(out: &mut String, name: &str, kind: &str, help: &str) {
    // Writing to a String is infallible.
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
}

/// Renders the full exposition document.
pub fn render(m: &PoolMetrics, http: Option<&HttpSnapshot>) -> String {
    let mut out = String::with_capacity(4096);

    family(&mut out, "scnn_pool_shards", "gauge", "Total shards in the pool.");
    let _ = writeln!(out, "scnn_pool_shards {}", m.shards);
    family(&mut out, "scnn_pool_healthy_shards", "gauge", "Shards currently healthy.");
    let _ = writeln!(out, "scnn_pool_healthy_shards {}", m.healthy);
    family(&mut out, "scnn_pool_uptime_seconds", "gauge", "Seconds since the pool opened.");
    let _ = writeln!(out, "scnn_pool_uptime_seconds {:.3}", m.wall.as_secs_f64());

    let counters: [(&str, usize, &str); 8] = [
        ("scnn_requests_total", m.requests, "Requests completed successfully."),
        ("scnn_requests_rejected_total", m.rejected, "Requests rejected as malformed."),
        ("scnn_requests_shed_total", m.shed, "Requests shed by admission control."),
        ("scnn_requests_rerouted_total", m.rerouted, "Requests rerouted off dying shards."),
        ("scnn_requests_failed_total", m.failed, "Requests failed in a backend."),
        ("scnn_batches_total", m.batches, "Coalesced batches executed."),
        ("scnn_timeouts_total", m.timeouts, "Client deadline misses."),
        ("scnn_degrade_events_total", m.degrade_events, "Precision degrade events."),
    ];
    for (name, value, help) in counters {
        family(&mut out, name, "counter", help);
        let _ = writeln!(out, "{name} {value}");
    }

    let ops: [(&str, u64, &str); 2] = [
        (
            "scnn_ops_executed_total",
            m.ops_executed,
            "Lane-cycle ops executed by compiled plans, summed over shards.",
        ),
        (
            "scnn_ops_skipped_total",
            m.ops_skipped,
            "Lane-cycle ops skipped by sparsity (pruned weight lanes).",
        ),
    ];
    for (name, value, help) in ops {
        family(&mut out, name, "counter", help);
        let _ = writeln!(out, "{name} {value}");
    }

    family(
        &mut out,
        "scnn_request_latency_microseconds",
        "summary",
        "Per-request latency quantiles, merged over shards.",
    );
    for (q, p) in [("0.5", 50.0), ("0.99", 99.0), ("0.999", 99.9)] {
        let _ = writeln!(
            out,
            "scnn_request_latency_microseconds{{quantile=\"{q}\"}} {}",
            m.latency_percentile_us(p)
        );
    }
    let _ = writeln!(out, "scnn_request_latency_microseconds_count {}", m.serve.count());

    family(
        &mut out,
        "scnn_request_latency_us_bucket",
        "histogram",
        "Log2 latency histogram, merged over shards.",
    );
    let mut cumulative = 0u64;
    for (_, hi, count) in m.histogram.nonzero_buckets() {
        cumulative += count;
        let _ = writeln!(out, "scnn_request_latency_us_bucket{{le=\"{hi}\"}} {cumulative}");
    }
    let _ = writeln!(out, "scnn_request_latency_us_bucket{{le=\"+Inf\"}} {cumulative}");
    let _ = writeln!(out, "scnn_request_latency_us_count {cumulative}");

    if !m.tenants.is_empty() {
        let tenant_families: [(&str, &str); 4] = [
            ("scnn_tenant_requests_total", "Requests answered per tenant."),
            ("scnn_tenant_quota_rejected_total", "Requests bounced by tenant quota."),
            ("scnn_tenant_shed_total", "Requests shed by admission control per tenant."),
            ("scnn_tenant_failed_total", "Requests failed per tenant."),
        ];
        for (i, (name, help)) in tenant_families.iter().enumerate() {
            family(&mut out, name, "counter", help);
            for t in &m.tenants {
                let value = match i {
                    0 => t.requests,
                    1 => t.quota_rejected,
                    2 => t.shed,
                    _ => t.failed,
                };
                let _ =
                    writeln!(out, "{name}{{tenant=\"{}\"}} {value}", escape_label(&t.tenant));
            }
        }
    }

    if let Some(http) = http {
        family(&mut out, "scnn_http_connections_total", "counter", "TCP connections accepted.");
        let _ = writeln!(out, "scnn_http_connections_total {}", http.connections);
        family(&mut out, "scnn_http_responses_total", "counter", "HTTP responses by status.");
        for (code, count) in &http.responses {
            let _ = writeln!(out, "scnn_http_responses_total{{code=\"{code}\"}} {count}");
        }
        family(&mut out, "scnn_http_uptime_seconds", "gauge", "Seconds since listen started.");
        let _ = writeln!(out, "scnn_http_uptime_seconds {:.3}", http.uptime_secs);
    }

    out
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::engine::{PoolMetrics, TenantStats};
    use std::time::Duration;

    fn sample() -> PoolMetrics {
        let mut m = PoolMetrics::aggregate(Vec::new(), 2, 3, 1, Duration::from_millis(1500));
        m.shards = 2;
        m.tenants = vec![TenantStats {
            tenant: "a\"b".to_string(),
            requests: 7,
            quota_rejected: 2,
            shed: 1,
            failed: 0,
        }];
        m
    }

    #[test]
    fn renders_core_families_and_labels() {
        let http = HttpSnapshot {
            connections: 5,
            responses: vec![(200, 4), (429, 1)],
            uptime_secs: 1.25,
        };
        let text = render(&sample(), Some(&http));
        assert!(text.contains("# TYPE scnn_pool_shards gauge"));
        assert!(text.contains("scnn_pool_shards 2"));
        assert!(text.contains("scnn_pool_healthy_shards 2"));
        assert!(text.contains("scnn_requests_shed_total 3"));
        assert!(text.contains("scnn_requests_rerouted_total 1"));
        assert!(text.contains("scnn_ops_executed_total 0"));
        assert!(text.contains("scnn_ops_skipped_total 0"));
        assert!(text.contains("scnn_tenant_requests_total{tenant=\"a\\\"b\"} 7"));
        assert!(text.contains("scnn_tenant_quota_rejected_total{tenant=\"a\\\"b\"} 2"));
        assert!(text.contains("scnn_http_responses_total{code=\"429\"} 1"));
        assert!(text.contains("scnn_request_latency_us_bucket{le=\"+Inf\"} 0"));
        // Every non-comment line is `name[{labels}] value`.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let mut parts = line.rsplitn(2, ' ');
            let value = parts.next().unwrap();
            assert!(value.parse::<f64>().is_ok(), "unparseable value in {line:?}");
            assert!(parts.next().is_some(), "no metric name in {line:?}");
        }
    }

    #[test]
    fn omits_tenant_and_http_families_when_absent() {
        let mut m = sample();
        m.tenants.clear();
        let text = render(&m, None);
        assert!(!text.contains("scnn_tenant_"));
        assert!(!text.contains("scnn_http_"));
    }
}
