//! Minimal benchmarking harness (criterion is not vendored in this offline
//! environment — Cargo.toml note). Provides warm-up, repeated timed runs,
//! median/mean reporting, and a tabular printer used by every
//! `rust/benches/*` target to regenerate the paper's tables and figures.

use std::time::Instant;

/// Result of one timed benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark label.
    pub name: String,
    /// Median wall time per iteration, nanoseconds.
    pub median_ns: f64,
    /// Mean wall time per iteration, nanoseconds.
    pub mean_ns: f64,
    /// Iterations measured.
    pub iters: usize,
}

impl BenchResult {
    /// Throughput in operations/second given `ops` per iteration.
    pub fn ops_per_sec(&self, ops: f64) -> f64 {
        ops / (self.median_ns * 1e-9)
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let r = BenchResult { name: name.to_string(), median_ns: median, mean_ns: mean, iters };
    println!(
        "bench {:<42} median {:>12.1} ns  mean {:>12.1} ns  ({} iters)",
        r.name, r.median_ns, r.mean_ns, r.iters
    );
    r
}

/// Print a markdown-ish table (used by the table/figure regenerators).
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    println!("{}", headers.join(" | "));
    println!("{}", headers.iter().map(|_| "---").collect::<Vec<_>>().join(" | "));
    for row in rows {
        println!("{}", row.join(" | "));
    }
}

/// Relative gain (paper convention: (base − new)/base, positive = better).
pub fn gain_pct(base: f64, new: f64) -> f64 {
    (base - new) / base * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 1, 5, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(r.median_ns >= 0.0);
        assert_eq!(r.iters, 5);
    }

    #[test]
    fn gain_sign_convention() {
        assert!((gain_pct(100.0, 90.0) - 10.0).abs() < 1e-9);
        assert!(gain_pct(100.0, 110.0) < 0.0);
    }
}
