//! Minimal benchmarking harness (criterion is not vendored in this offline
//! environment — Cargo.toml note). Provides warm-up, repeated timed runs,
//! median/mean reporting, and a tabular printer used by every
//! `rust/benches/*` target to regenerate the paper's tables and figures.

use std::time::Instant;

/// Result of one timed benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark label.
    pub name: String,
    /// Median wall time per iteration, nanoseconds.
    pub median_ns: f64,
    /// Mean wall time per iteration, nanoseconds.
    pub mean_ns: f64,
    /// Iterations measured.
    pub iters: usize,
}

impl BenchResult {
    /// Throughput in operations/second given `ops` per iteration.
    pub fn ops_per_sec(&self, ops: f64) -> f64 {
        ops / (self.median_ns * 1e-9)
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let r = BenchResult { name: name.to_string(), median_ns: median, mean_ns: mean, iters };
    println!(
        "bench {:<42} median {:>12.1} ns  mean {:>12.1} ns  ({} iters)",
        r.name, r.median_ns, r.mean_ns, r.iters
    );
    r
}

/// Collects bench results into a machine-readable JSON report (e.g.
/// `BENCH_hotpath.json`) so the perf trajectory is tracked across PRs.
/// Hand-rolled writer — serde is not vendored in this offline environment.
#[derive(Debug, Default)]
pub struct JsonReport {
    entries: Vec<String>,
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.3}")
    } else {
        "null".to_string()
    }
}

impl JsonReport {
    /// Empty report.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one result plus extra numeric fields (e.g.
    /// `("throughput_gbit_s", x)` or `("speedup_vs_reference", r)`).
    pub fn add(&mut self, r: &BenchResult, extra: &[(&str, f64)]) {
        let mut fields = vec![
            format!("\"name\": \"{}\"", json_escape(&r.name)),
            format!("\"median_ns\": {}", json_num(r.median_ns)),
            format!("\"mean_ns\": {}", json_num(r.mean_ns)),
            format!("\"iters\": {}", r.iters),
        ];
        for (k, v) in extra {
            fields.push(format!("\"{}\": {}", json_escape(k), json_num(*v)));
        }
        self.entries.push(format!("  {{{}}}", fields.join(", ")));
    }

    /// Number of recorded entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing was recorded.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Render the JSON document.
    pub fn render(&self) -> String {
        format!("[\n{}\n]\n", self.entries.join(",\n"))
    }

    /// Write to `path`.
    pub fn write(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.render())
    }
}

/// Print a markdown-ish table (used by the table/figure regenerators).
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    println!("{}", headers.join(" | "));
    println!("{}", headers.iter().map(|_| "---").collect::<Vec<_>>().join(" | "));
    for row in rows {
        println!("{}", row.join(" | "));
    }
}

/// Relative gain (paper convention: (base − new)/base, positive = better).
pub fn gain_pct(base: f64, new: f64) -> f64 {
    (base - new) / base * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 1, 5, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert!(r.median_ns >= 0.0);
        assert_eq!(r.iters, 5);
    }

    #[test]
    fn gain_sign_convention() {
        assert!((gain_pct(100.0, 90.0) - 10.0).abs() < 1e-9);
        assert!(gain_pct(100.0, 110.0) < 0.0);
    }

    #[test]
    fn json_report_renders_valid_records() {
        let mut rep = JsonReport::new();
        let r = BenchResult {
            name: "xnor(1024b) \"fused\"".to_string(),
            median_ns: 123.456,
            mean_ns: 130.0,
            iters: 10,
        };
        rep.add(&r, &[("speedup_vs_reference", 4.2), ("bad", f64::NAN)]);
        rep.add(&r, &[]);
        assert_eq!(rep.len(), 2);
        let doc = rep.render();
        assert!(doc.starts_with("[\n"));
        assert!(doc.ends_with("]\n"));
        assert!(doc.contains("\"median_ns\": 123.456"));
        assert!(doc.contains("\"speedup_vs_reference\": 4.200"));
        assert!(doc.contains("\"bad\": null"));
        // Escaped quotes survive.
        assert!(doc.contains("xnor(1024b) \\\"fused\\\""));
        // Balanced braces/brackets (cheap well-formedness check).
        assert_eq!(doc.matches('{').count(), doc.matches('}').count());
        assert_eq!(doc.matches('[').count(), doc.matches(']').count());
    }

    #[test]
    fn json_report_writes_file() {
        let mut rep = JsonReport::new();
        rep.add(
            &BenchResult { name: "t".into(), median_ns: 1.0, mean_ns: 1.0, iters: 1 },
            &[],
        );
        let p = std::env::temp_dir().join(format!("scnn_json_{}.json", std::process::id()));
        rep.write(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert!(text.contains("\"name\": \"t\""));
    }
}
