//! Deterministic fault injection for the SC datapath and serving stack.
//!
//! The paper's robustness argument (§I) is that a single upset bit in a
//! k-cycle stochastic stream perturbs the carried value by 1/k, while the
//! same upset in a binary word can flip a high-order bit and swing the
//! value by half its range. This module turns that claim into a testable
//! artifact: a seeded [`FaultPlan`] describing device-level faults that the
//! fused engine and the per-bit golden reference honor **identically**, so
//! the bit-exactness contract of `accel::network` survives any fault plan.
//!
//! Four fault classes, all derived from one seed:
//!
//! * **Stream bit flips** — every bit of every SNG lane (activation,
//!   weight, and padding streams) flips independently with probability
//!   [`FaultPlan::bit_flip_rate`]. In the analytic (binary expectation /
//!   fixed-point) datapaths the same rate flips the bits of the quantized
//!   activation codes instead — the per-bit apples-to-apples comparison
//!   behind `BENCH_faults.json`.
//! * **Stuck-at APC lanes** — selected adder-tree inputs read constant 0/1
//!   streams ([`StuckLane`]), modeling a dead XNOR/APC column.
//! * **SNG correlation faults** — selected weight lanes lose their per-lane
//!   wire shuffle and share the raw activation RNS (the correlated-stream
//!   failure mode §I warns about).
//! * **SRAM word upsets** — stored weight codes take deterministic one-bit
//!   upsets ([`FaultPlan::corrupt_weights`], via
//!   [`crate::accel::memory::upset_word`]) before plan compilation.
//!
//! Every draw is a pure function of `(plan seed, generation key)` — the
//! same keys both datapaths already use to generate the streams — so fused
//! and reference inject byte-identical faults without sharing any state.

#![deny(clippy::unwrap_used)]

use crate::accel::memory;
use crate::accel::network::QuantizedWeights;
use crate::accel::stage::StageDescriptor;
use crate::sc::rng;

/// Salt separating weight-lane correlation draws from bit-flip draws.
const CORR_SALT: u64 = 0xC0_44E1;
/// Salt separating SRAM upset draws from the stream-flip namespace.
const SRAM_SALT: u64 = 0x54A3_0B17;
/// Salt for analytic (binary-code) bit flips.
const CODE_SALT: u64 = 0xB1_4A47;

/// One adder-tree input lane forced to a constant stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StuckLane {
    /// Compute-layer index (the weight-layer index `wl`).
    pub wl: usize,
    /// Fan-in lane index within the layer's gather window.
    pub lane: usize,
    /// `true` = stuck-at-1, `false` = stuck-at-0.
    pub stuck_one: bool,
}

/// A seeded, deterministic fault-injection plan. Compiled into a
/// [`crate::accel::network::ForwardPlan`] via
/// `ForwardPlan::compile_with_precision_faults`, honored identically by the
/// per-bit reference via `reference::forward_stochastic_plan_faulted`, and
/// carried by [`crate::engine::EngineConfig::with_faults`] for serving.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Master seed for every fault draw (independent of the SNG seed).
    pub seed: u64,
    /// Per-bit upset probability in the datapath's native representation:
    /// SC stream bits for the stochastic paths, quantized activation-code
    /// bits for the analytic paths.
    pub bit_flip_rate: f64,
    /// Adder-tree lanes forced to constant streams.
    pub stuck_lanes: Vec<StuckLane>,
    /// Probability that a weight SNG lane loses its wire shuffle and
    /// shares the raw activation RNS (correlated products).
    pub sng_correlation_rate: f64,
    /// Probability that a stored weight code takes a one-bit SRAM upset.
    pub sram_upset_rate: f64,
}

impl FaultPlan {
    /// An all-quiet plan with the given seed; compose with the builders.
    pub fn new(seed: u64) -> Self {
        FaultPlan {
            seed,
            bit_flip_rate: 0.0,
            stuck_lanes: Vec::new(),
            sng_correlation_rate: 0.0,
            sram_upset_rate: 0.0,
        }
    }

    /// Set the per-bit upset probability (clamped to [0, 1]).
    pub fn with_bit_flip_rate(mut self, rate: f64) -> Self {
        self.bit_flip_rate = rate.clamp(0.0, 1.0);
        self
    }

    /// Force one adder-tree lane of compute layer `wl` to a constant.
    pub fn with_stuck_lane(mut self, wl: usize, lane: usize, stuck_one: bool) -> Self {
        self.stuck_lanes.push(StuckLane { wl, lane, stuck_one });
        self
    }

    /// Set the weight-lane RNS-correlation probability (clamped to [0, 1]).
    pub fn with_sng_correlation_rate(mut self, rate: f64) -> Self {
        self.sng_correlation_rate = rate.clamp(0.0, 1.0);
        self
    }

    /// Set the per-code SRAM upset probability (clamped to [0, 1]).
    pub fn with_sram_upset_rate(mut self, rate: f64) -> Self {
        self.sram_upset_rate = rate.clamp(0.0, 1.0);
        self
    }

    /// True when the plan injects nothing (compiles to the clean datapath).
    pub fn is_noop(&self) -> bool {
        self.bit_flip_rate <= 0.0
            && self.stuck_lanes.is_empty()
            && self.sng_correlation_rate <= 0.0
            && self.sram_upset_rate <= 0.0
    }

    /// The flip mask for word `w` of the stream generated with SNG key
    /// `(base, lane)`: bit `i` is set iff stream bit `64·w + i` flips.
    /// Pure in `(seed, base, lane, w)` — the fused engine XORs whole words,
    /// the per-bit reference picks single bits, and both see the same mask.
    pub fn flip_word(&self, base: u32, lane: u64, w: usize) -> u64 {
        if self.bit_flip_rate <= 0.0 {
            return 0;
        }
        let thr = bernoulli_threshold(self.bit_flip_rate);
        let key =
            rng::mix64((base as u64) << 32 ^ lane) ^ (w as u64).wrapping_mul(rng::GOLDEN_GAMMA);
        let mut state = rng::mix64(self.seed ^ key) | 1;
        let mut word = 0u64;
        for i in 0..64 {
            state = rng::xorshift64_step(state);
            word |= (((state as u32 as u64) < thr) as u64) << i;
        }
        word
    }

    /// Whether stream bit `t` of the `(base, lane)` stream flips — the
    /// per-bit view of [`FaultPlan::flip_word`].
    pub fn flip_bit(&self, base: u32, lane: u64, t: usize) -> bool {
        (self.flip_word(base, lane, t / 64) >> (t % 64)) & 1 == 1
    }

    /// XOR the flip masks into a packed `k`-cycle stream in place, masking
    /// the partial final word so no garbage lands past cycle `k`.
    pub fn flip_words(&self, base: u32, lane: u64, k: usize, words: &mut [u64]) {
        if self.bit_flip_rate <= 0.0 {
            return;
        }
        let last = words.len().wrapping_sub(1);
        for (w, word) in words.iter_mut().enumerate() {
            let mut m = self.flip_word(base, lane, w);
            if w == last && k % 64 != 0 {
                m &= (1u64 << (k % 64)) - 1;
            }
            *word ^= m;
        }
    }

    /// The stuck value of adder-tree lane `lane` in compute layer `wl`,
    /// `None` when the lane is healthy (first matching entry wins).
    pub fn stuck(&self, wl: usize, lane: usize) -> Option<bool> {
        self.stuck_lanes
            .iter()
            .find(|s| s.wl == wl && s.lane == lane)
            .map(|s| s.stuck_one)
    }

    /// Whether the weight lane `(wl, oc, j)` suffers the RNS-correlation
    /// fault (generated on the activation RNS at lane `j` instead of its
    /// shuffled weight-namespace key).
    pub fn correlated_weight_lane(&self, wl: usize, oc: usize, j: usize) -> bool {
        if self.sng_correlation_rate <= 0.0 {
            return false;
        }
        let thr = bernoulli_threshold(self.sng_correlation_rate);
        let key = ((wl as u64) << 44) ^ ((oc as u64) << 22) ^ j as u64;
        (rng::mix64(self.seed ^ CORR_SALT ^ rng::mix64(key)) as u32 as u64) < thr
    }

    /// The flip mask for the quantized activation code at `site` of compute
    /// layer `wl` in the **analytic** datapaths: each of the low `bits`
    /// binary-weighted bits flips with [`FaultPlan::bit_flip_rate`]. This is
    /// the binary side of the graceful-vs-cliff comparison.
    pub fn flip_code(&self, wl: usize, site: usize, bits: u32) -> u32 {
        if self.bit_flip_rate <= 0.0 {
            return 0;
        }
        let thr = bernoulli_threshold(self.bit_flip_rate);
        let key = ((wl as u64) << 40) ^ site as u64;
        let mut state = rng::mix64(self.seed ^ CODE_SALT ^ rng::mix64(key)) | 1;
        let mut mask = 0u32;
        for b in 0..bits.min(32) {
            state = rng::xorshift64_step(state);
            mask |= (((state as u32 as u64) < thr) as u32) << b;
        }
        mask
    }

    /// Check every site-addressed fault against a compiled stage chain:
    /// each [`StuckLane`] must name an existing compute layer and a lane
    /// inside that layer's fan-in. A site that misses would silently never
    /// fire — a fault campaign "surviving" faults that were never injected
    /// — so `ForwardPlan::compile_with_precision_faults` rejects such plans
    /// with the returned message (`scnn::analyze` reports the same sites as
    /// `SC006` warnings before compilation is ever attempted).
    pub fn validate_sites(&self, stages: &[StageDescriptor]) -> Result<(), String> {
        let fan_ins: Vec<usize> = stages
            .iter()
            .filter(|s| s.is_compute())
            .filter_map(|s| s.weight_shape().map(|(_, fan_in)| fan_in))
            .collect();
        for s in &self.stuck_lanes {
            let Some(&fan_in) = fan_ins.get(s.wl) else {
                return Err(format!(
                    "fault plan targets a stuck lane on compute layer {} but the network has \
                     only {} compute layers",
                    s.wl,
                    fan_ins.len()
                ));
            };
            if s.lane >= fan_in {
                return Err(format!(
                    "fault plan targets stuck lane {} on compute layer {} whose fan-in is only \
                     {fan_in}",
                    s.lane, s.wl
                ));
            }
        }
        Ok(())
    }

    /// Apply deterministic SRAM word upsets to a stored weight tensor: each
    /// code takes a one-bit upset with [`FaultPlan::sram_upset_rate`]. Both
    /// datapaths corrupt the weights through this one function before
    /// compiling, so parity under SRAM faults holds by construction.
    pub fn corrupt_weights(&self, weights: &QuantizedWeights) -> QuantizedWeights {
        let mut out = weights.clone();
        if self.sram_upset_rate <= 0.0 {
            return out;
        }
        let thr = bernoulli_threshold(self.sram_upset_rate);
        for (wl, layer) in out.layers.iter_mut().enumerate() {
            for (oc, row) in layer.codes.iter_mut().enumerate() {
                for (j, code) in row.iter_mut().enumerate() {
                    let key = ((wl as u64) << 44) ^ ((oc as u64) << 22) ^ j as u64;
                    let draw = rng::mix64(self.seed ^ SRAM_SALT ^ rng::mix64(key));
                    if (draw as u32 as u64) < thr {
                        // The high half of the same draw picks the bit, so
                        // one mix covers both decisions.
                        *code = memory::upset_word(*code, weights.bits, (draw >> 32) as u32);
                    }
                }
            }
        }
        out
    }
}

/// Map a probability in [0, 1] onto a 33-bit threshold for `u32` draws
/// (rate 1.0 exceeds every draw; rate 0.0 accepts none).
fn bernoulli_threshold(rate: f64) -> u64 {
    (rate.clamp(0.0, 1.0) * 4_294_967_296.0) as u64
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::accel::layers::NetworkSpec;

    fn weights() -> QuantizedWeights {
        QuantizedWeights::synthetic(&NetworkSpec::lenet5(), 8, 0x5EED).unwrap()
    }

    #[test]
    fn noop_plan_injects_nothing() {
        let f = FaultPlan::new(7);
        assert!(f.is_noop());
        assert_eq!(f.flip_word(3, 5, 0), 0);
        assert_eq!(f.flip_code(0, 0, 8), 0);
        assert!(f.stuck(0, 0).is_none());
        assert!(!f.correlated_weight_lane(0, 0, 0));
        let w = weights();
        let c = f.corrupt_weights(&w);
        assert_eq!(c.layers[0].codes, w.layers[0].codes);
    }

    #[test]
    fn flip_masks_are_deterministic_and_keyed() {
        let f = FaultPlan::new(42).with_bit_flip_rate(0.25);
        assert!(!f.is_noop());
        assert_eq!(f.flip_word(1, 2, 3), f.flip_word(1, 2, 3));
        // Distinct keys give distinct masks (astronomically unlikely to
        // collide at rate 0.25 over 64 bits).
        assert_ne!(f.flip_word(1, 2, 3), f.flip_word(1, 2, 4));
        assert_ne!(f.flip_word(1, 2, 3), f.flip_word(1, 3, 3));
        assert_ne!(f.flip_word(1, 2, 3), f.flip_word(2, 2, 3));
        assert_ne!(
            f.flip_word(1, 2, 3),
            FaultPlan::new(43).with_bit_flip_rate(0.25).flip_word(1, 2, 3)
        );
        // flip_bit is the per-bit view of flip_word.
        for t in [0usize, 1, 63, 64, 130] {
            assert_eq!(
                f.flip_bit(9, 9, t),
                (f.flip_word(9, 9, t / 64) >> (t % 64)) & 1 == 1
            );
        }
    }

    #[test]
    fn flip_rate_tracks_the_requested_probability() {
        let f = FaultPlan::new(11).with_bit_flip_rate(0.1);
        let n = 1000usize;
        let ones: u32 = (0..n).map(|w| f.flip_word(0, 0, w).count_ones()).sum();
        let frac = ones as f64 / (64 * n) as f64;
        assert!((frac - 0.1).abs() < 0.01, "measured flip rate {frac}");
        // Extremes behave.
        assert_eq!(FaultPlan::new(1).with_bit_flip_rate(1.0).flip_word(0, 0, 0), !0u64);
        assert_eq!(FaultPlan::new(1).with_bit_flip_rate(0.0).flip_word(0, 0, 0), 0);
    }

    #[test]
    fn flip_words_masks_the_partial_tail() {
        let f = FaultPlan::new(5).with_bit_flip_rate(1.0);
        let k = 70;
        let mut words = vec![0u64; 2];
        f.flip_words(7, 1, k, &mut words);
        assert_eq!(words[0], !0u64);
        assert_eq!(words[1], (1u64 << (k % 64)) - 1, "no flips past cycle k");
    }

    #[test]
    fn stuck_lanes_match_by_layer_and_lane() {
        let f = FaultPlan::new(1).with_stuck_lane(2, 7, true).with_stuck_lane(0, 1, false);
        assert_eq!(f.stuck(2, 7), Some(true));
        assert_eq!(f.stuck(0, 1), Some(false));
        assert_eq!(f.stuck(2, 8), None);
        assert_eq!(f.stuck(1, 7), None);
    }

    #[test]
    fn sram_upsets_flip_exactly_one_bit_per_hit() {
        let f = FaultPlan::new(99).with_sram_upset_rate(1.0);
        let w = weights();
        let c = f.corrupt_weights(&w);
        let mut hits = 0usize;
        for (lw, lc) in w.layers.iter().zip(&c.layers) {
            for (rw, rc) in lw.codes.iter().zip(&lc.codes) {
                for (&a, &b) in rw.iter().zip(rc) {
                    assert_eq!((a ^ b).count_ones(), 1, "one-bit upset per word");
                    hits += 1;
                }
            }
        }
        assert!(hits > 0);
        // Deterministic: the same plan corrupts the same way twice.
        assert_eq!(c.layers[0].codes, f.corrupt_weights(&w).layers[0].codes);
    }

    #[test]
    fn correlation_rate_tracks_probability() {
        let f = FaultPlan::new(3).with_sng_correlation_rate(0.2);
        let n = 5000usize;
        let hits = (0..n).filter(|&j| f.correlated_weight_lane(0, 0, j)).count();
        let frac = hits as f64 / n as f64;
        assert!((frac - 0.2).abs() < 0.03, "measured correlation rate {frac}");
        assert_eq!(
            f.correlated_weight_lane(1, 2, 3),
            f.correlated_weight_lane(1, 2, 3),
            "deterministic"
        );
    }

    #[test]
    fn validate_sites_rejects_lanes_outside_the_compiled_plan() {
        let stages = NetworkSpec::lenet5().stages().unwrap();
        // lenet5 compute layer 0: conv 1->6 5x5, fan-in 25.
        assert!(FaultPlan::new(1).with_stuck_lane(0, 24, true).validate_sites(&stages).is_ok());
        let e = FaultPlan::new(1)
            .with_stuck_lane(0, 25, true)
            .validate_sites(&stages)
            .unwrap_err();
        assert!(e.contains("fan-in"), "{e}");
        let e = FaultPlan::new(1)
            .with_stuck_lane(99, 0, false)
            .validate_sites(&stages)
            .unwrap_err();
        assert!(e.contains("compute layers"), "{e}");
        // Non-site faults (rates) validate against any plan.
        assert!(FaultPlan::new(1)
            .with_bit_flip_rate(0.5)
            .with_sng_correlation_rate(0.5)
            .validate_sites(&stages)
            .is_ok());
    }

    #[test]
    fn code_flips_stay_within_the_quantization_width() {
        let f = FaultPlan::new(17).with_bit_flip_rate(1.0);
        let mask = f.flip_code(0, 0, 8);
        assert_eq!(mask, 0xFF, "rate 1.0 flips every code bit");
        assert_eq!(f.flip_code(0, 0, 4) >> 4, 0, "no flips above the width");
        let none = FaultPlan::new(17).with_bit_flip_rate(0.0);
        assert_eq!(none.flip_code(0, 0, 8), 0);
    }
}
